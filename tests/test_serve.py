"""Serving: generation loop + streaming-SVD KV compression (Alg. 3 feature)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import init_params
from repro.serve import (
    KVCompressionConfig,
    compress_head_batch,
    compress_history,
    compression_error,
    generate,
    lowrank_decode_attention,
)


def test_generate_shapes_greedy_deterministic():
    cfg = ARCHS["llama3.2-1b"].smoke_config()
    params = init_params(jax.random.key(0), cfg)
    prompt = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
    out1 = generate(params, cfg, prompt, 8)
    out2 = generate(params, cfg, prompt, 8)
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_generate_matches_rerun_prefill():
    """Token t+1 from decode equals greedy argmax of a fresh prefill on the
    extended prompt (cache correctness end-to-end)."""
    from repro.models import prefill

    cfg = ARCHS["mistral-nemo-12b"].smoke_config()
    params = init_params(jax.random.key(0), cfg)
    prompt = jax.random.randint(jax.random.key(1), (1, 10), 0, cfg.vocab_size)
    toks = generate(params, cfg, prompt, 4)
    ext = jnp.concatenate([prompt, toks[:, :1]], axis=1)
    lg, _ = prefill(params, cfg, ext, cache_len=20)
    expect = jnp.argmax(lg[:, 0], -1)
    np.testing.assert_array_equal(np.asarray(toks[:, 1]), np.asarray(expect))


@pytest.mark.slow
def test_kv_compression_lowrank_history():
    """Rank-8 history compresses near-exactly at rank 16 (one pass)."""
    key = jax.random.key(2)
    U = jax.random.normal(jax.random.key(3), (512, 8))
    V = jax.random.normal(jax.random.key(4), (8, 64))
    hist = U @ V  # (S=512, d=64), rank 8
    kc = KVCompressionConfig(rank=16, oversample=4, panel=128)
    fac = compress_history(key, hist, kc)
    err = float(compression_error(hist, fac))
    assert err < 0.05, err


def test_kv_compression_memory_model():
    S, d, r = 2048, 128, 16
    kc = KVCompressionConfig(rank=r)
    hist = jax.random.normal(jax.random.key(5), (S, d))
    fac = compress_history(jax.random.key(6), hist, kc)
    dense = S * d
    compressed = fac.v_s.size + fac.sigma.size + fac.u.size
    assert dense / compressed > 5  # d/r ≈ 8x minus factor overheads


@pytest.mark.slow
def test_lowrank_decode_attention_close_to_exact():
    """Attention against factors ≈ exact attention when history is low-rank."""
    B, KV, G, S, d = 1, 2, 2, 256, 32
    key = jax.random.key(7)
    core_k = jax.random.normal(jax.random.key(8), (B, KV, S, 6)) @ \
        jax.random.normal(jax.random.key(9), (B, KV, 6, d))
    core_v = jax.random.normal(jax.random.key(10), (B, KV, S, 6)) @ \
        jax.random.normal(jax.random.key(11), (B, KV, 6, d))
    kc = KVCompressionConfig(rank=12, panel=64)
    k_fac = compress_head_batch(jax.random.key(12), core_k, kc)
    v_fac = compress_head_batch(jax.random.key(13), core_v, kc)
    q = jax.random.normal(key, (B, KV, G, d))
    out = lowrank_decode_attention(q, k_fac, v_fac, jnp.asarray(S))

    s = jnp.einsum("bkgd,bksd->bkgs", q, core_k) / np.sqrt(d)
    p = jax.nn.softmax(s, -1)
    exact = jnp.einsum("bkgs,bksd->bkgd", p, core_v)
    cos = jnp.sum(out * exact) / (jnp.linalg.norm(out) * jnp.linalg.norm(exact))
    assert float(cos) > 0.99, float(cos)


def test_temperature_sampling_in_range():
    cfg = ARCHS["musicgen-large"].smoke_config()
    params = init_params(jax.random.key(0), cfg)
    prompt = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    out = generate(params, cfg, prompt, 6, temperature=1.0, key=jax.random.key(5))
    assert int(out.min()) >= 0 and int(out.max()) < cfg.vocab_size


def test_engine_compress_matches_legacy_loop():
    """The engine-based compress_history reproduces the legacy per-panel
    sp_svd_* loop factors exactly (shared key → shared sketches)."""
    from repro.core.svd import sp_svd_finalize, sp_svd_init, sp_svd_update
    from repro.serve.kv_compress import _fac_width, _sizes

    S, d = 200, 32
    kc = KVCompressionConfig(rank=8, oversample=2, panel=64)
    hist = jax.random.normal(jax.random.key(20), (S, d))
    key = jax.random.key(21)
    fac = compress_history(key, hist, kc)

    state = sp_svd_init(key, d, S, sizes=_sizes(d, kc), dtype=jnp.float32, osnap_p=4)
    panel = min(kc.panel, S)
    hist_T = hist.T.astype(jnp.float32)
    for off in range(0, S, panel):
        state = sp_svd_update(state, hist_T[:, off : off + panel])
    U, sig, V = sp_svd_finalize(state, k=_fac_width(d, kc))
    np.testing.assert_allclose(np.asarray(fac.sigma), np.asarray(sig), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(fac.u), np.asarray(U), atol=1e-4)
    np.testing.assert_allclose(np.asarray(fac.v_s), np.asarray(V), atol=1e-4)


def test_kv_compress_has_no_legacy_loop_calls():
    """Acceptance guard: serve/kv_compress.py runs on the engine API only."""
    import inspect

    import repro.serve.kv_compress as m

    src = inspect.getsource(m)
    for banned in ("sp_svd_init", "sp_svd_update", "sp_svd_finalize"):
        assert banned not in src, banned


def test_adaptive_rank_beats_uniform_at_equal_budget():
    """Spiked-head cache: one head carries a heavy spectrum, the rest are
    near rank-1. At the same total budget KV·rank, the shared-budget
    allocation concentrates rank on the heavy head and wins on total
    reconstruction error."""
    B, KV, S, d = 1, 4, 160, 32
    rich = jax.random.normal(jax.random.key(30), (S, 12)) @ \
        jax.random.normal(jax.random.key(31), (12, d)) * 3.0
    poor = jnp.stack([
        jnp.outer(jax.random.normal(jax.random.fold_in(jax.random.key(32), i), (S,)),
                  jax.random.normal(jax.random.fold_in(jax.random.key(33), i), (d,)))
        + 0.01 * jax.random.normal(jax.random.fold_in(jax.random.key(34), i), (S, d))
        for i in range(KV - 1)
    ])
    hist = jnp.concatenate([rich[None], poor])[None]  # (1, KV, S, d)

    rank = 4  # total budget KV·rank = 16 < 12 + 3 needed for exactness
    uni = compress_head_batch(
        jax.random.key(35), hist, KVCompressionConfig(rank=rank, oversample=4, panel=64)
    )
    ada = compress_head_batch(
        jax.random.key(35), hist,
        KVCompressionConfig(rank=rank, oversample=4, panel=64,
                            adaptive=True, min_rank=1, max_rank=14),
    )
    assert int((ada.sigma > 0).sum()) <= KV * rank  # equal effective budget
    errs_u = jax.vmap(jax.vmap(compression_error))(hist, uni)
    errs_a = jax.vmap(jax.vmap(compression_error))(hist, ada)
    # energy-weighted total error: adaptive must win decisively
    w = jnp.asarray([float(jnp.linalg.norm(hist[0, i])) for i in range(KV)])
    tot_u = float(jnp.sum(errs_u[0] * w))
    tot_a = float(jnp.sum(errs_a[0] * w))
    assert tot_a < 0.5 * tot_u, (tot_a, tot_u)


def test_generate_compressed_cache_smoke():
    """Compressed-cache generation: right shape, deterministic, in-vocab."""
    cfg = ARCHS["llama3.2-1b"].smoke_config()
    params = init_params(jax.random.key(0), cfg)
    prompt = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
    kc = KVCompressionConfig(rank=8, oversample=2, panel=16, decode_panel=2, refresh_every=4)
    out1 = generate(params, cfg, prompt, 8, kv_compress=kc)
    out2 = generate(params, cfg, prompt, 8, kv_compress=kc)
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(out1.min()) >= 0 and int(out1.max()) < cfg.vocab_size


def test_fused_sampling_matches_legacy_host_loop():
    """The jit-fused decode+sample step reproduces the legacy host-side
    sampling loop token-for-token (same RNG fold chain) at temperature>0."""
    from functools import partial

    from repro.models import decode_step, prefill
    from repro.serve import sample_token

    cfg = ARCHS["llama3.2-1b"].smoke_config()
    params = init_params(jax.random.key(0), cfg)
    prompt = jax.random.randint(jax.random.key(1), (2, 10), 0, cfg.vocab_size)
    n_tokens, temperature = 6, 0.8
    key = jax.random.key(11)
    out = generate(params, cfg, prompt, n_tokens, key=key, temperature=temperature)

    logits, cache = prefill(params, cfg, prompt, prompt.shape[1] + n_tokens)
    step = jax.jit(partial(decode_step, dense_moe=False), static_argnums=(1,))
    k = key
    toks = [sample_token(k, logits, temperature)]
    for i in range(n_tokens - 1):
        k = jax.random.fold_in(k, i)
        logits, cache = step(params, cfg, cache, toks[-1])
        toks.append(sample_token(k, logits, temperature))
    legacy = jnp.concatenate(toks, axis=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(legacy))
