"""Serving: generation loop + streaming-SVD KV compression (Alg. 3 feature)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import init_params
from repro.serve import (
    KVCompressionConfig,
    compress_head_batch,
    compress_history,
    compression_error,
    generate,
    lowrank_decode_attention,
)


def test_generate_shapes_greedy_deterministic():
    cfg = ARCHS["llama3.2-1b"].smoke_config()
    params = init_params(jax.random.key(0), cfg)
    prompt = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
    out1 = generate(params, cfg, prompt, 8)
    out2 = generate(params, cfg, prompt, 8)
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_generate_matches_rerun_prefill():
    """Token t+1 from decode equals greedy argmax of a fresh prefill on the
    extended prompt (cache correctness end-to-end)."""
    from repro.models import prefill

    cfg = ARCHS["mistral-nemo-12b"].smoke_config()
    params = init_params(jax.random.key(0), cfg)
    prompt = jax.random.randint(jax.random.key(1), (1, 10), 0, cfg.vocab_size)
    toks = generate(params, cfg, prompt, 4)
    ext = jnp.concatenate([prompt, toks[:, :1]], axis=1)
    lg, _ = prefill(params, cfg, ext, cache_len=20)
    expect = jnp.argmax(lg[:, 0], -1)
    np.testing.assert_array_equal(np.asarray(toks[:, 1]), np.asarray(expect))


@pytest.mark.slow
def test_kv_compression_lowrank_history():
    """Rank-8 history compresses near-exactly at rank 16 (one pass)."""
    key = jax.random.key(2)
    U = jax.random.normal(jax.random.key(3), (512, 8))
    V = jax.random.normal(jax.random.key(4), (8, 64))
    hist = U @ V  # (S=512, d=64), rank 8
    kc = KVCompressionConfig(rank=16, oversample=4, panel=128)
    fac = compress_history(key, hist, kc)
    err = float(compression_error(hist, fac))
    assert err < 0.05, err


def test_kv_compression_memory_model():
    S, d, r = 2048, 128, 16
    kc = KVCompressionConfig(rank=r)
    hist = jax.random.normal(jax.random.key(5), (S, d))
    fac = compress_history(jax.random.key(6), hist, kc)
    dense = S * d
    compressed = fac.v_s.size + fac.sigma.size + fac.u.size
    assert dense / compressed > 5  # d/r ≈ 8x minus factor overheads


@pytest.mark.slow
def test_lowrank_decode_attention_close_to_exact():
    """Attention against factors ≈ exact attention when history is low-rank."""
    B, KV, G, S, d = 1, 2, 2, 256, 32
    key = jax.random.key(7)
    core_k = jax.random.normal(jax.random.key(8), (B, KV, S, 6)) @ \
        jax.random.normal(jax.random.key(9), (B, KV, 6, d))
    core_v = jax.random.normal(jax.random.key(10), (B, KV, S, 6)) @ \
        jax.random.normal(jax.random.key(11), (B, KV, 6, d))
    kc = KVCompressionConfig(rank=12, panel=64)
    k_fac = compress_head_batch(jax.random.key(12), core_k, kc)
    v_fac = compress_head_batch(jax.random.key(13), core_v, kc)
    q = jax.random.normal(key, (B, KV, G, d))
    out = lowrank_decode_attention(q, k_fac, v_fac, jnp.asarray(S))

    s = jnp.einsum("bkgd,bksd->bkgs", q, core_k) / np.sqrt(d)
    p = jax.nn.softmax(s, -1)
    exact = jnp.einsum("bkgs,bksd->bkgd", p, core_v)
    cos = jnp.sum(out * exact) / (jnp.linalg.norm(out) * jnp.linalg.norm(exact))
    assert float(cos) > 0.99, float(cos)


def test_temperature_sampling_in_range():
    cfg = ARCHS["musicgen-large"].smoke_config()
    params = init_params(jax.random.key(0), cfg)
    prompt = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    out = generate(params, cfg, prompt, 6, temperature=1.0, key=jax.random.key(5))
    assert int(out.min()) >= 0 and int(out.max()) < cfg.vocab_size
