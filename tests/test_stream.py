"""Unified panel-streaming engine (repro/stream/): shared contract,
DP-sharded ingestion parity, adaptive column admission, edge cases."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    fast_sp_svd,
    sp_svd_finalize,
    sp_svd_init,
    sp_svd_update,
)
from repro.cur import (
    cur_reconstruct,
    cur_relative_error,
    fast_cur,
    select_rows,
    streaming_cur_finalize,
    streaming_cur_init,
    streaming_cur_update,
)
from repro.data.synthetic import powerlaw_matrix, spiked_decay_matrix
from repro.stream import (
    adaptive_cur_finalize,
    adaptive_cur_init,
    jitted_panel_update,
    merge_states,
    shard_panel_ranges,
    simulate_sharded_stream,
    stream_panels,
)

SIZES = dict(c=24, r=24, c0=72, r0=72, s_c=72, s_r=72)
M, N = 220, 180


@pytest.fixture(scope="module")
def A():
    return powerlaw_matrix(jax.random.key(0), M, N, 1.0)


# ---------------------------------------------------------------------------
# shared engine: panel-width / ordering edge cases
# ---------------------------------------------------------------------------


def test_irregular_panel_widths_match_oneshot(A):
    """Permuted irregular panel partitions hit identical accumulators."""
    ref = sp_svd_update(sp_svd_init(jax.random.key(1), M, N, sizes=SIZES), A)
    for widths in ([37, 80, 13, 50], [80, 50, 37, 13], [1, 99, 2, 78]):
        assert sum(widths) == N
        st = sp_svd_init(jax.random.key(1), M, N, sizes=SIZES)
        off = 0
        for w in widths:
            st = sp_svd_update(st, A[:, off : off + w])
            off += w
        np.testing.assert_allclose(st.C, ref.C, atol=2e-3)
        np.testing.assert_allclose(st.R, ref.R, atol=2e-3)
        np.testing.assert_allclose(st.M, ref.M, atol=2e-3)


def test_ragged_tail_zero_padding_is_exact(A):
    """fast_sp_svd with a non-dividing panel == one whole-matrix panel."""
    outs = []
    for panel in (N, 96):  # 180 = 96 + 84 → zero-padded tail
        U, S, V = fast_sp_svd(jax.random.key(2), A, sizes=SIZES, panel=panel)
        outs.append((U * S[None]) @ V.T)
    np.testing.assert_allclose(outs[1], outs[0], atol=5e-3)


def test_jitted_step_is_cached_across_calls(A):
    """The engine step is jitted once at module scope — repeat fast_sp_svd
    calls (same shapes) must not add traces (the old per-call jax.jit
    rebuild retraced every invocation)."""
    fast_sp_svd(jax.random.key(3), A, sizes=SIZES, panel=96)
    before = jitted_panel_update._cache_size()
    fast_sp_svd(jax.random.key(4), A, sizes=SIZES, panel=96)
    fast_sp_svd(jax.random.key(5), A, sizes=SIZES, panel=96)
    assert jitted_panel_update._cache_size() == before


def test_streaming_cur_duplicate_col_idx(A):
    """Duplicate entries in col_idx fill every duplicated slot, and the
    streamed accumulators equal the one-shot sketched pieces. (U itself is
    not compared: with duplicated columns the core solve is rank-deficient,
    so U is non-unique — only the accumulators and the fit are determined.)"""
    # 8 slots / 8 rows / panel 32: shares the jitted-step cache entry with
    # the sharded-parity tests below
    ci = jnp.asarray([5, 5, 40, 171, 40, 3, 99, 120], jnp.int32)
    ri = select_rows(jax.random.key(6), A, 8, "uniform").idx
    st = streaming_cur_init(jax.random.key(7), M, N, ci, ri, sketch="countsketch", panel=32)
    st = stream_panels(st, A, 32)
    res = streaming_cur_finalize(st)
    np.testing.assert_array_equal(res.C, jnp.take(A, ci, axis=1))
    np.testing.assert_array_equal(res.R, jnp.take(A, ri, axis=0))
    np.testing.assert_allclose(st.M, st.S_R.apply_t(st.S_C.apply(A)), atol=2e-3)
    assert bool(jnp.all(jnp.isfinite(res.U)))


# ---------------------------------------------------------------------------
# DP-sharded ingestion: simulated-worker parity (acceptance criterion)
# ---------------------------------------------------------------------------


def test_shard_panel_ranges_cover_and_align():
    for n, panel, w in [(180, 64, 4), (180, 64, 2), (500, 100, 3), (64, 64, 4)]:
        ranges = shard_panel_ranges(n, panel, w)
        assert ranges[0][0] == 0 and ranges[-1][1] == n
        for (lo, hi), (lo2, _) in zip(ranges, ranges[1:]):
            assert hi == lo2 and lo % panel == 0
        assert all(lo <= hi for lo, hi in ranges)


@pytest.mark.parametrize("workers", [2, 4])
def test_sp_svd_sharded_parity(A, workers):
    """DP-sharded SP-SVD == single-host within fp32 summation tolerance."""
    single = stream_panels(sp_svd_init(jax.random.key(8), M, N, sizes=SIZES, panel=32), A, 32)
    shard = simulate_sharded_stream(
        sp_svd_init(jax.random.key(8), M, N, sizes=SIZES, panel=32), A, 32, workers
    )
    np.testing.assert_allclose(shard.C, single.C, atol=2e-3)
    np.testing.assert_allclose(shard.R, single.R, atol=2e-3)
    np.testing.assert_allclose(shard.M, single.M, atol=2e-3)
    U1, S1, V1 = sp_svd_finalize(single)
    U2, S2, V2 = sp_svd_finalize(shard)
    np.testing.assert_allclose(
        (U1 * S1[None]) @ V1.T, (U2 * S2[None]) @ V2.T, atol=5e-3
    )


@pytest.mark.parametrize("workers", [2, 4])
def test_streaming_cur_sharded_parity(A, workers):
    """DP-sharded streaming CUR == single-host factors."""
    ci = jnp.asarray([3, 50, 99, 120, 164, 7, 31, 88], jnp.int32)
    ri = select_rows(jax.random.key(9), A, 8, "uniform").idx

    def init():
        return streaming_cur_init(
            jax.random.key(10), M, N, ci, ri, sketch="countsketch", panel=32
        )

    single = streaming_cur_finalize(stream_panels(init(), A, 32))
    shard = streaming_cur_finalize(simulate_sharded_stream(init(), A, 32, workers))
    np.testing.assert_array_equal(shard.C, single.C)
    np.testing.assert_array_equal(shard.R, single.R)
    np.testing.assert_allclose(shard.U, single.U, atol=2e-3)


def test_merge_states_is_accumulator_sum(A):
    """merge_states is literally Σ_w of the worker accumulators."""
    states = []
    for w, (lo, hi) in enumerate(shard_panel_ranges(N, 32, 3)):
        st = sp_svd_init(jax.random.key(8), M, N, sizes=SIZES, panel=32)
        import dataclasses

        st = dataclasses.replace(st, offset=jnp.asarray(lo, jnp.int32))
        st = stream_panels(st, A, 32, stop=hi)
        states.append(st)
    merged = merge_states(states)
    np.testing.assert_allclose(merged.M, sum(s.M for s in states), atol=1e-6)


# ---------------------------------------------------------------------------
# adaptive column admission (acceptance criterion: beats fixed-uniform)
# ---------------------------------------------------------------------------


def test_adaptive_admits_spiked_columns():
    B, pos = spiked_decay_matrix(jax.random.key(20), 250, 200)
    ri = select_rows(jax.random.key(21), B, 20, "uniform").idx
    st = adaptive_cur_init(
        jax.random.key(22), 250, 200, 10, ri, sketch="countsketch", panel=40, panel_cap=3
    )
    st = stream_panels(st, B, 40)
    res = adaptive_cur_finalize(st)
    admitted = set(np.asarray(res.col_idx).tolist())
    missed = set(np.asarray(pos).tolist()) - admitted
    assert len(missed) <= 1, (sorted(admitted), sorted(np.asarray(pos).tolist()))


def test_adaptive_beats_fixed_uniform_at_equal_budget():
    """The §ROADMAP claim: residual admission < uniform pre-pass selection
    on a spiked-decay matrix at the same column budget c."""
    errs_a, errs_u = [], []
    for t in range(2):
        B, _ = spiked_decay_matrix(jax.random.key(30 + t), 250, 200)
        ri = select_rows(jax.random.key(40 + t), B, 20, "uniform").idx
        st = adaptive_cur_init(
            jax.random.key(50 + t), 250, 200, 10, ri, sketch="countsketch", panel=40, panel_cap=3
        )
        res_a = adaptive_cur_finalize(stream_panels(st, B, 40))
        errs_a.append(float(cur_relative_error(B, res_a)))
        ci = jax.random.choice(jax.random.key(60 + t), 200, (10,), replace=False)
        stu = streaming_cur_init(
            jax.random.key(70 + t), 250, 200, ci, ri, sketch="countsketch", panel=40
        )
        res_u = streaming_cur_finalize(stream_panels(stu, B, 40))
        errs_u.append(float(cur_relative_error(B, res_u)))
    assert np.mean(errs_a) < np.mean(errs_u), (errs_a, errs_u)


def test_adaptive_unfilled_slots_are_inert():
    """A stream with fewer interesting columns than budget leaves slots
    unfilled (col_idx −1, zero C columns, zero U rows) — finite everywhere."""
    B = 0.01 * jax.random.normal(jax.random.key(80), (250, 200))
    B = B.at[:, 13].add(9.0)
    ri = select_rows(jax.random.key(82), B, 20, "uniform").idx
    # same (m, n, c, r, panel) as the sharded test → shared compile cache
    st = adaptive_cur_init(
        jax.random.key(81), 250, 200, 8, ri, sketch="countsketch", panel=25,
        panel_cap=1, min_gain=5.0,
    )
    res = adaptive_cur_finalize(stream_panels(st, B, 25))
    idx = np.asarray(res.col_idx)
    assert (idx == -1).any() and 13 in idx.tolist()
    unfilled = idx == -1
    assert bool(jnp.all(jnp.isfinite(res.U)))
    np.testing.assert_allclose(np.asarray(res.U)[unfilled], 0.0)
    np.testing.assert_allclose(np.asarray(res.C)[:, unfilled], 0.0)


@pytest.mark.parametrize("workers", [2, 4])
def test_adaptive_sharded_still_finds_spikes(workers):
    """Distributed adaptive admission (per-worker slot ranges) still
    captures the heavy columns and stays a valid CUR factorization."""
    B, pos = spiked_decay_matrix(jax.random.key(90), 250, 200, n_spikes=4)
    ri = select_rows(jax.random.key(91), B, 20, "uniform").idx
    # panel_cap=1: with only c/W = 2–4 slots per worker, a larger cap would
    # let a worker exhaust its budget on its first panel before spikes arrive
    st = adaptive_cur_init(
        jax.random.key(92), 250, 200, 8, ri, sketch="countsketch", panel=25, panel_cap=1
    )
    res = adaptive_cur_finalize(simulate_sharded_stream(st, B, 25, workers))
    admitted = set(np.asarray(res.col_idx).tolist())
    missed = set(np.asarray(pos).tolist()) - admitted
    assert len(missed) <= 1, (sorted(admitted), sorted(np.asarray(pos).tolist()))
    assert float(cur_relative_error(B, res)) < 0.5


# ---------------------------------------------------------------------------
# multi-device shard_map path (subprocess, forced host devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_multidev_stream_parity():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    script = os.path.join(os.path.dirname(__file__), "multidev_scenario.py")
    proc = subprocess.run(
        [sys.executable, script, "stream"], capture_output=True, text=True, env=env, timeout=900
    )
    assert proc.returncode == 0, f"\nSTDOUT:{proc.stdout[-2000:]}\nSTDERR:{proc.stderr[-3000:]}"
    assert "OK scenario" in proc.stdout
