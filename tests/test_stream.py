"""Unified panel-streaming engine (repro/stream/): shared contract,
DP-sharded ingestion parity, adaptive column admission/eviction, adaptive
row admission with sketched backfill, edge cases."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    fast_sp_svd,
    sp_svd_finalize,
    sp_svd_init,
    sp_svd_update,
)
from repro.cur import (
    cur_reconstruct,
    cur_relative_error,
    fast_cur,
    select_rows,
    streaming_cur_finalize,
    streaming_cur_init,
    streaming_cur_update,
)
from repro.data.synthetic import (
    drifting_spectrum_matrix,
    late_spike_matrix,
    powerlaw_matrix,
    spiked_decay_matrix,
    spiked_rows_matrix,
)
from repro.stream import (
    adaptive_cur_finalize,
    adaptive_cur_init,
    jitted_panel_update,
    merge_states,
    padded_n,
    shard_panel_ranges,
    simulate_sharded_stream,
    stream_panels,
)

SIZES = dict(c=24, r=24, c0=72, r0=72, s_c=72, s_r=72)
M, N = 220, 180


@pytest.fixture(scope="module")
def A():
    return powerlaw_matrix(jax.random.key(0), M, N, 1.0)


# ---------------------------------------------------------------------------
# shared engine: panel-width / ordering edge cases
# ---------------------------------------------------------------------------


def test_irregular_panel_widths_match_oneshot(A):
    """Permuted irregular panel partitions hit identical accumulators."""
    ref = sp_svd_update(sp_svd_init(jax.random.key(1), M, N, sizes=SIZES), A)
    for widths in ([37, 80, 13, 50], [80, 50, 37, 13], [1, 99, 2, 78]):
        assert sum(widths) == N
        st = sp_svd_init(jax.random.key(1), M, N, sizes=SIZES)
        off = 0
        for w in widths:
            st = sp_svd_update(st, A[:, off : off + w])
            off += w
        np.testing.assert_allclose(st.C, ref.C, atol=2e-3)
        np.testing.assert_allclose(st.R, ref.R, atol=2e-3)
        np.testing.assert_allclose(st.M, ref.M, atol=2e-3)


def test_ragged_tail_zero_padding_is_exact(A):
    """fast_sp_svd with a non-dividing panel == one whole-matrix panel."""
    outs = []
    for panel in (N, 96):  # 180 = 96 + 84 → zero-padded tail
        U, S, V = fast_sp_svd(jax.random.key(2), A, sizes=SIZES, panel=panel)
        outs.append((U * S[None]) @ V.T)
    np.testing.assert_allclose(outs[1], outs[0], atol=5e-3)


def test_jitted_step_is_cached_across_calls(A):
    """The engine step is jitted once at module scope — repeat fast_sp_svd
    calls (same shapes) must not add traces (the old per-call jax.jit
    rebuild retraced every invocation)."""
    fast_sp_svd(jax.random.key(3), A, sizes=SIZES, panel=96)
    before = jitted_panel_update._cache_size()
    fast_sp_svd(jax.random.key(4), A, sizes=SIZES, panel=96)
    fast_sp_svd(jax.random.key(5), A, sizes=SIZES, panel=96)
    assert jitted_panel_update._cache_size() == before


def test_streaming_cur_duplicate_col_idx(A):
    """Duplicate entries in col_idx fill every duplicated slot, and the
    streamed accumulators equal the one-shot sketched pieces. (U itself is
    not compared: with duplicated columns the core solve is rank-deficient,
    so U is non-unique — only the accumulators and the fit are determined.)"""
    # 8 slots / 8 rows / panel 32: shares the jitted-step cache entry with
    # the sharded-parity tests below
    ci = jnp.asarray([5, 5, 40, 171, 40, 3, 99, 120], jnp.int32)
    ri = select_rows(jax.random.key(6), A, 8, "uniform").idx
    st = streaming_cur_init(jax.random.key(7), M, N, ci, ri, sketch="countsketch", panel=32)
    st = stream_panels(st, A, 32)
    res = streaming_cur_finalize(st)
    np.testing.assert_array_equal(res.C, jnp.take(A, ci, axis=1))
    np.testing.assert_array_equal(res.R, jnp.take(A, ri, axis=0))
    np.testing.assert_allclose(st.M, st.S_R.apply_t(st.S_C.apply(A)), atol=2e-3)
    assert bool(jnp.all(jnp.isfinite(res.U)))


# ---------------------------------------------------------------------------
# DP-sharded ingestion: simulated-worker parity (acceptance criterion)
# ---------------------------------------------------------------------------


def test_shard_panel_ranges_cover_and_align():
    for n, panel, w in [(180, 64, 4), (180, 64, 2), (500, 100, 3), (64, 64, 4)]:
        ranges = shard_panel_ranges(n, panel, w)
        assert ranges[0][0] == 0 and ranges[-1][1] == n
        for (lo, hi), (lo2, _) in zip(ranges, ranges[1:]):
            assert hi == lo2 and lo % panel == 0
        assert all(lo <= hi for lo, hi in ranges)


@pytest.mark.parametrize("workers", [2, 4])
def test_sp_svd_sharded_parity(A, workers):
    """DP-sharded SP-SVD == single-host within fp32 summation tolerance."""
    single = stream_panels(sp_svd_init(jax.random.key(8), M, N, sizes=SIZES, panel=32), A, 32)
    shard = simulate_sharded_stream(
        sp_svd_init(jax.random.key(8), M, N, sizes=SIZES, panel=32), A, 32, workers
    )
    np.testing.assert_allclose(shard.C, single.C, atol=2e-3)
    np.testing.assert_allclose(shard.R, single.R, atol=2e-3)
    np.testing.assert_allclose(shard.M, single.M, atol=2e-3)
    U1, S1, V1 = sp_svd_finalize(single)
    U2, S2, V2 = sp_svd_finalize(shard)
    np.testing.assert_allclose(
        (U1 * S1[None]) @ V1.T, (U2 * S2[None]) @ V2.T, atol=5e-3
    )


@pytest.mark.parametrize("workers", [2, 4])
def test_streaming_cur_sharded_parity(A, workers):
    """DP-sharded streaming CUR == single-host factors."""
    ci = jnp.asarray([3, 50, 99, 120, 164, 7, 31, 88], jnp.int32)
    ri = select_rows(jax.random.key(9), A, 8, "uniform").idx

    def init():
        return streaming_cur_init(
            jax.random.key(10), M, N, ci, ri, sketch="countsketch", panel=32
        )

    single = streaming_cur_finalize(stream_panels(init(), A, 32))
    shard = streaming_cur_finalize(simulate_sharded_stream(init(), A, 32, workers))
    np.testing.assert_array_equal(shard.C, single.C)
    np.testing.assert_array_equal(shard.R, single.R)
    np.testing.assert_allclose(shard.U, single.U, atol=2e-3)


def test_merge_states_is_accumulator_sum(A):
    """merge_states is literally Σ_w of the worker accumulators."""
    states = []
    for w, (lo, hi) in enumerate(shard_panel_ranges(N, 32, 3)):
        st = sp_svd_init(jax.random.key(8), M, N, sizes=SIZES, panel=32)
        import dataclasses

        st = dataclasses.replace(st, offset=jnp.asarray(lo, jnp.int32))
        st = stream_panels(st, A, 32, stop=hi)
        states.append(st)
    merged = merge_states(states)
    np.testing.assert_allclose(merged.M, sum(s.M for s in states), atol=1e-6)


# ---------------------------------------------------------------------------
# adaptive column admission (acceptance criterion: beats fixed-uniform)
# ---------------------------------------------------------------------------


def test_adaptive_admits_spiked_columns():
    B, pos = spiked_decay_matrix(jax.random.key(20), 250, 200)
    ri = select_rows(jax.random.key(21), B, 20, "uniform").idx
    st = adaptive_cur_init(
        jax.random.key(22), 250, 200, 10, ri, sketch="countsketch", panel=40, panel_cap=3
    )
    st = stream_panels(st, B, 40)
    res = adaptive_cur_finalize(st)
    admitted = set(np.asarray(res.col_idx).tolist())
    missed = set(np.asarray(pos).tolist()) - admitted
    assert len(missed) <= 1, (sorted(admitted), sorted(np.asarray(pos).tolist()))


def test_adaptive_beats_fixed_uniform_at_equal_budget():
    """The §ROADMAP claim: residual admission < uniform pre-pass selection
    on a spiked-decay matrix at the same column budget c."""
    errs_a, errs_u = [], []
    for t in range(2):
        B, _ = spiked_decay_matrix(jax.random.key(30 + t), 250, 200)
        ri = select_rows(jax.random.key(40 + t), B, 20, "uniform").idx
        st = adaptive_cur_init(
            jax.random.key(50 + t), 250, 200, 10, ri, sketch="countsketch", panel=40, panel_cap=3
        )
        res_a = adaptive_cur_finalize(stream_panels(st, B, 40))
        errs_a.append(float(cur_relative_error(B, res_a)))
        ci = jax.random.choice(jax.random.key(60 + t), 200, (10,), replace=False)
        stu = streaming_cur_init(
            jax.random.key(70 + t), 250, 200, ci, ri, sketch="countsketch", panel=40
        )
        res_u = streaming_cur_finalize(stream_panels(stu, B, 40))
        errs_u.append(float(cur_relative_error(B, res_u)))
    assert np.mean(errs_a) < np.mean(errs_u), (errs_a, errs_u)


def test_adaptive_unfilled_slots_are_inert():
    """A stream with fewer interesting columns than budget leaves slots
    unfilled (col_idx −1, zero C columns, zero U rows) — finite everywhere."""
    B = 0.01 * jax.random.normal(jax.random.key(80), (250, 200))
    B = B.at[:, 13].add(9.0)
    ri = select_rows(jax.random.key(82), B, 20, "uniform").idx
    # same (m, n, c, r, panel) as the sharded test → shared compile cache
    st = adaptive_cur_init(
        jax.random.key(81), 250, 200, 8, ri, sketch="countsketch", panel=25,
        panel_cap=1, min_gain=5.0,
    )
    res = adaptive_cur_finalize(stream_panels(st, B, 25))
    idx = np.asarray(res.col_idx)
    assert (idx == -1).any() and 13 in idx.tolist()
    unfilled = idx == -1
    assert bool(jnp.all(jnp.isfinite(res.U)))
    np.testing.assert_allclose(np.asarray(res.U)[unfilled], 0.0)
    np.testing.assert_allclose(np.asarray(res.C)[:, unfilled], 0.0)


@pytest.mark.parametrize("workers", [2, 4])
def test_adaptive_sharded_still_finds_spikes(workers):
    """Distributed adaptive admission (per-worker slot ranges) still
    captures the heavy columns and stays a valid CUR factorization."""
    B, pos = spiked_decay_matrix(jax.random.key(90), 250, 200, n_spikes=4)
    ri = select_rows(jax.random.key(91), B, 20, "uniform").idx
    # panel_cap=1: with only c/W = 2–4 slots per worker, a larger cap would
    # let a worker exhaust its budget on its first panel before spikes arrive
    st = adaptive_cur_init(
        jax.random.key(92), 250, 200, 8, ri, sketch="countsketch", panel=25, panel_cap=1
    )
    res = adaptive_cur_finalize(simulate_sharded_stream(st, B, 25, workers))
    admitted = set(np.asarray(res.col_idx).tolist())
    missed = set(np.asarray(pos).tolist()) - admitted
    assert len(missed) <= 1, (sorted(admitted), sorted(np.asarray(pos).tolist()))
    assert float(cur_relative_error(B, res)) < 0.5


# ---------------------------------------------------------------------------
# v2: column eviction (acceptance: beats admission-only on late-spike streams)
# ---------------------------------------------------------------------------


def _late_spike_run(key_data, swap_gain, c=8, m=300, n=240, panel=40):
    A, early, late = late_spike_matrix(key_data, m, n)
    ri = select_rows(jax.random.key(101), A, 16, "uniform").idx
    # panel_cap=c//2: the early/weaker spikes genuinely fill the budget
    # before the heavy late ones arrive — the regime eviction exists for
    st = adaptive_cur_init(
        jax.random.key(102), m, n, c, ri, sketch="countsketch", panel=panel,
        panel_cap=c // 2, swap_gain=swap_gain,
    )
    st = stream_panels(st, A, panel)
    return A, late, st, adaptive_cur_finalize(st)


def test_eviction_recovers_late_spikes():
    """Acceptance criterion: at equal (c, r) budget on a late-spike stream,
    eviction-enabled adaptive CUR strictly beats PR 2's admission-only
    policy, because it swaps the late heavy columns over its weakest
    admits."""
    errs = {}
    for sg in (None, 2.0):
        A, late, st, res = _late_spike_run(jax.random.key(100), sg)
        errs[sg] = float(cur_relative_error(A, res))
        captured = len(set(np.asarray(late).tolist()) & set(np.asarray(res.col_idx).tolist()))
        if sg is None:
            assert int(st.ctx.n_evicted) == 0
            n_admit_only_late = captured
        else:
            assert int(st.ctx.n_evicted) > 0
            assert captured > n_admit_only_late, (captured, n_admit_only_late)
    assert errs[2.0] < errs[None], errs


def test_eviction_on_drifting_spectrum():
    """Admission-only locks onto the weak early blocks of a drifting
    spectrum; eviction follows the drift and lands much lower error."""
    A, _bounds = drifting_spectrum_matrix(jax.random.key(110), 300, 240)
    ri = select_rows(jax.random.key(111), A, 16, "uniform").idx
    errs = {}
    for sg in (None, 2.0):
        st = adaptive_cur_init(
            jax.random.key(112), 300, 240, 8, ri, sketch="countsketch", panel=40,
            panel_cap=4, swap_gain=sg,
        )
        res = adaptive_cur_finalize(stream_panels(st, A, 40))
        errs[sg] = float(cur_relative_error(A, res))
    assert errs[2.0] < errs[None], errs


def test_eviction_keeps_slot_invariants():
    """Evictions overwrite in place: col_idx entries stay unique and
    in-range, C columns match the claimed source columns exactly, and the
    filled count never exceeds the budget."""
    A, _late, st, res = _late_spike_run(jax.random.key(120), 2.0)
    idx = np.asarray(res.col_idx)
    filled = idx[idx >= 0]
    assert len(np.unique(filled)) == len(filled)  # no duplicate admissions
    assert np.all(filled < 240)
    np.testing.assert_array_equal(
        np.asarray(res.C)[:, idx >= 0], np.asarray(jnp.take(A, jnp.asarray(filled), axis=1))
    )
    assert int(st.ctx.n_filled) <= 8


# ---------------------------------------------------------------------------
# v2: adaptive row admission + sketched backfill
# ---------------------------------------------------------------------------


def test_adaptive_rows_beat_fixed_prepass():
    """Acceptance criterion: in-stream row admission beats fixed pre-pass
    uniform rows at equal r budget on a spiked-rows matrix (same adaptive
    column policy on both sides)."""
    errs = {}
    for t in range(2):
        A, rpos = spiked_rows_matrix(jax.random.key(130 + t), 300, 240)
        for method in ("fixed", "adaptive"):
            kw = (
                dict(row_idx=select_rows(jax.random.key(140 + t), A, 8, "uniform").idx)
                if method == "fixed"
                else dict(row_idx=None, r=8, panel_cap_rows=2)
            )
            st = adaptive_cur_init(
                jax.random.key(150 + t), 300, 240, 12, sketch="countsketch",
                panel=40, panel_cap=2, **kw,
            )
            res = adaptive_cur_finalize(stream_panels(st, A, 40))
            errs.setdefault(method, []).append(float(cur_relative_error(A, res)))
            if method == "adaptive":
                admitted = set(np.asarray(res.row_idx).tolist())
                missed = set(np.asarray(rpos).tolist()) - admitted
                assert len(missed) <= 1, (sorted(admitted), sorted(np.asarray(rpos).tolist()))
    assert np.mean(errs["adaptive"]) < np.mean(errs["fixed"]), errs


def test_row_backfill_beats_zero_prefix():
    """A row whose energy only appears mid-stream is admitted late; its
    missed column prefix is backfilled from the sketched min-norm
    reconstruction, which must be strictly closer to the true prefix than
    the zeros it replaces (it recovers the prefix's projection onto the
    s_r-dimensional row space of S_R)."""
    m, n, panel = 200, 240, 40
    A = 0.02 * jax.random.normal(jax.random.key(160), (m, n))
    # row 77: sub-threshold structure early, heavy only from column 120 on
    A = A.at[77, :120].set(0.04 * jnp.sin(jnp.arange(120) / 7.0))
    A = A.at[77, 120:].add(8.0 * jax.random.normal(jax.random.key(161), (n - 120,)))
    st = adaptive_cur_init(
        jax.random.key(162), m, n, 6, None, r=4, sketch="countsketch",
        panel=panel, panel_cap=1, panel_cap_rows=1, s_r=96, min_gain_rows=4.0,
    )
    st = stream_panels(st, A, panel)
    res = adaptive_cur_finalize(st)
    idx = np.asarray(res.row_idx)
    assert 77 in idx.tolist()
    slot = int(np.where(idx == 77)[0][0])
    admit_off = int(np.asarray(st.ctx.rows.admit_off)[slot])
    assert admit_off >= 120  # admitted only once the heavy block streamed by
    true_prefix = np.asarray(A)[77, :admit_off]
    got_prefix = np.asarray(res.R)[slot, :admit_off]
    err = np.linalg.norm(got_prefix - true_prefix)
    assert err < 0.95 * np.linalg.norm(true_prefix), (err, np.linalg.norm(true_prefix))
    # and the seen suffix is copied exactly, not reconstructed
    np.testing.assert_array_equal(
        np.asarray(res.R)[slot, admit_off + panel:], np.asarray(A)[77, admit_off + panel:]
    )


def test_unfilled_row_slots_are_inert():
    """A stream with fewer interesting rows than budget leaves row slots
    unfilled (row_idx −1, zero R rows, zero U columns) — finite everywhere."""
    B = 0.01 * jax.random.normal(jax.random.key(170), (200, 240))
    B = B.at[42, :].add(7.0)
    st = adaptive_cur_init(
        jax.random.key(171), 200, 240, 6, None, r=6, sketch="countsketch",
        panel=40, panel_cap=1, panel_cap_rows=1, min_gain_rows=5.0,
    )
    res = adaptive_cur_finalize(stream_panels(st, B, 40))
    idx = np.asarray(res.row_idx)
    assert (idx == -1).any() and 42 in idx.tolist()
    unfilled = idx == -1
    assert bool(jnp.all(jnp.isfinite(res.U)))
    np.testing.assert_allclose(np.asarray(res.U)[:, unfilled], 0.0)
    np.testing.assert_allclose(np.asarray(res.R)[unfilled, :], 0.0)


# ---------------------------------------------------------------------------
# v2: DP-sharded ingestion with eviction + row admission (acceptance)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("workers", [2, 4])
def test_v2_sharded_parity_eviction_and_rows(workers):
    """simulate_sharded_stream with eviction + adaptive rows enabled:
    disjoint per-worker slot ranges merge into a valid, finite
    factorization that still captures the planted structure (the adaptive
    paths' parity contract — admission decisions are worker-local, so the
    merge is a valid outcome rather than bitwise single-host equality)."""
    A, rpos = spiked_rows_matrix(jax.random.key(180), 300, 240)
    st = adaptive_cur_init(
        jax.random.key(181), 300, 240, 8, None, r=8, sketch="countsketch",
        panel=20, panel_cap=1, panel_cap_rows=1, swap_gain=2.0,
    )
    res = adaptive_cur_finalize(simulate_sharded_stream(st, A, 20, workers))
    err = float(cur_relative_error(A, res))
    assert np.isfinite(err) and err < 1.0, err
    admitted = set(np.asarray(res.row_idx).tolist())
    missed = set(np.asarray(rpos).tolist()) - admitted
    assert len(missed) <= 2, (sorted(admitted), sorted(np.asarray(rpos).tolist()))
    # slot-range discipline survived the merge: unique filled indices
    for idx in (np.asarray(res.col_idx), ):
        filled = idx[idx >= 0]
        assert len(np.unique(filled)) == len(filled)


def test_cross_worker_row_dedup():
    """ROADMAP open item: rows are global, so two workers admit the same
    heavy row into different slots. The merge_state hook must consolidate
    the duplicates into the lowest slot (summing their disjoint-support R
    pieces — here recovering the *full* row exactly) and free the rest,
    on the scan and per-panel sharded drivers alike."""
    m, n, panel = 200, 240, 40
    A = 0.02 * jax.random.normal(jax.random.key(400), (m, n))
    # rows 77/131 are heavy across the whole stream → every worker admits them
    A = A.at[77, :].add(8.0 * jax.random.normal(jax.random.key(401), (n,)))
    A = A.at[131, :].add(5.0 * jax.random.normal(jax.random.key(402), (n,)))
    for jit in ("scan", "per-panel"):
        st = adaptive_cur_init(
            jax.random.key(403), m, n, 6, None, r=4, sketch="countsketch",
            panel=panel, panel_cap=1, panel_cap_rows=1,
        )
        st_out = simulate_sharded_stream(st, A, panel, 2, jit=jit)
        res = adaptive_cur_finalize(st_out)
        idx = np.asarray(res.row_idx)
        filled = idx[idx >= 0]
        assert len(np.unique(filled)) == len(filled), (jit, idx)
        assert {77, 131} <= set(filled.tolist()), (jit, idx)
        # consolidation: the kept slot holds the union of both workers'
        # column ranges — the complete true row, not a half-zeroed one
        slot = int(np.where(idx == 77)[0][0])
        np.testing.assert_allclose(
            np.asarray(res.R)[slot], np.asarray(A)[77], atol=1e-5
        )
        # freed slots are fully inert: zero R rows, zero U columns, and the
        # filled-count accounting reflects the dedup
        unfilled = idx == -1
        np.testing.assert_allclose(np.asarray(res.R)[unfilled], 0.0)
        np.testing.assert_allclose(np.asarray(res.U)[:, unfilled], 0.0)
        assert int(st_out.ctx.rows.n_filled) == len(filled), jit
        assert bool(jnp.all(jnp.isfinite(res.U)))


def test_v2_shard_budget_must_divide():
    """prep_shard refuses budgets that don't split across workers."""
    st = adaptive_cur_init(
        jax.random.key(190), 100, 120, 10, None, r=6, sketch="countsketch", panel=20
    )
    with pytest.raises(ValueError, match="row budget"):
        simulate_sharded_stream(st, jnp.zeros((100, 120)), 20, 5)


# ---------------------------------------------------------------------------
# multi-device shard_map path (subprocess, forced host devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_multidev_stream_parity():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    script = os.path.join(os.path.dirname(__file__), "multidev_scenario.py")
    proc = subprocess.run(
        [sys.executable, script, "stream"], capture_output=True, text=True, env=env, timeout=900
    )
    assert proc.returncode == 0, f"\nSTDOUT:{proc.stdout[-2000:]}\nSTDERR:{proc.stderr[-3000:]}"
    assert "OK scenario" in proc.stdout


# ---------------------------------------------------------------------------
# scan-path parity: the compiled lax.scan driver (default) must reproduce the
# per-panel jitted_panel_update loop for every configuration
# ---------------------------------------------------------------------------


def _assert_states_close(a, b, atol=2e-5):
    np.testing.assert_allclose(a.C, b.C, atol=atol)
    np.testing.assert_allclose(a.R, b.R, atol=atol)
    np.testing.assert_allclose(a.M, b.M, atol=atol)
    assert int(a.offset) == int(b.offset)


def test_scan_parity_spsvd_fixed(A):
    """SP-SVD: scan path vs per-panel path, including a ragged tail
    (N=180, panel=48 → 3 full panels + 36-column zero-padded tail)."""
    for panel in (45, 48):  # dividing and ragged
        ref = stream_panels(
            sp_svd_init(jax.random.key(201), M, N, sizes=SIZES, panel=panel),
            A, panel, jit="per-panel",
        )
        got = stream_panels(
            sp_svd_init(jax.random.key(201), M, N, sizes=SIZES, panel=panel),
            A, panel, jit="scan",
        )
        _assert_states_close(got, ref)


def test_scan_parity_streaming_cur_fixed(A):
    ci = jnp.asarray([3, 50, 99, 120, 164, 7, 31, 88], jnp.int32)
    ri = select_rows(jax.random.key(202), A, 8, "uniform").idx
    for panel in (32, 50):  # 180 % 50 != 0 → ragged tail
        def init():
            return streaming_cur_init(
                jax.random.key(203), M, N, ci, ri, sketch="countsketch", panel=panel
            )
        ref = stream_panels(init(), A, panel, jit="per-panel")
        got = stream_panels(init(), A, panel, jit="scan")
        _assert_states_close(got, ref)
        np.testing.assert_array_equal(got.C, ref.C)
        np.testing.assert_array_equal(got.R, ref.R)


def test_scan_parity_adaptive_cols_evict_rows():
    """Adaptive CUR with eviction + adaptive rows: the scan carry includes
    the whole AdaptiveCURCtx/AdaptiveRowState — admission decisions, slot
    tables, backfills must match the per-panel driver decision-for-decision."""
    m, n, panel = 300, 240, 40
    B, _ = spiked_rows_matrix(jax.random.key(210), m, n)

    def init():
        return adaptive_cur_init(
            jax.random.key(211), m, n, 8, None, r=8, sketch="countsketch",
            panel=panel, panel_cap=2, panel_cap_rows=1, swap_gain=2.0,
        )

    ref = stream_panels(init(), B, panel, jit="per-panel")
    got = stream_panels(init(), B, panel, jit="scan")
    _assert_states_close(got, ref)
    np.testing.assert_array_equal(got.ctx.col_idx, ref.ctx.col_idx)
    np.testing.assert_array_equal(got.ctx.row_idx, ref.ctx.row_idx)
    np.testing.assert_array_equal(got.ctx.rows.admit_off, ref.ctx.rows.admit_off)
    assert int(got.ctx.n_evicted) == int(ref.ctx.n_evicted)
    np.testing.assert_allclose(got.ctx.ScC, ref.ctx.ScC, atol=2e-5)
    np.testing.assert_allclose(
        got.ctx.rows.row_sketch, ref.ctx.rows.row_sketch, atol=2e-4
    )


def test_scan_parity_adaptive_ragged_tail():
    """Adaptive CUR on a stream where n is not a panel multiple (250 = 6×40
    + 10): the zero-padded tail must admit/score identically on both paths."""
    m, n, panel = 200, 250, 40
    B, _ = spiked_decay_matrix(jax.random.key(212), m, n)
    ri = select_rows(jax.random.key(213), B, 12, "uniform").idx

    def init():
        return adaptive_cur_init(
            jax.random.key(214), m, n, 10, ri, sketch="countsketch",
            panel=panel, panel_cap=2,
        )

    ref = stream_panels(init(), B, panel, jit="per-panel")
    got = stream_panels(init(), B, panel, jit="scan")
    _assert_states_close(got, ref)
    np.testing.assert_array_equal(got.ctx.col_idx, ref.ctx.col_idx)
    res_ref = adaptive_cur_finalize(ref)
    res_got = adaptive_cur_finalize(got)
    np.testing.assert_allclose(res_got.U, res_ref.U, atol=2e-4)


@pytest.mark.parametrize("workers", [2, 4])
def test_scan_parity_sharded_fixed(A, workers):
    """simulate_sharded_stream: fused single-program driver vs the per-panel
    per-worker loop (fixed-index ops — chained accumulators are provably the
    merged accumulators)."""
    ci = jnp.asarray([3, 50, 99, 120, 164, 7, 31, 88], jnp.int32)
    ri = select_rows(jax.random.key(220), A, 8, "uniform").idx

    def init():
        return streaming_cur_init(
            jax.random.key(221), M, N, ci, ri, sketch="countsketch", panel=32
        )

    ref = simulate_sharded_stream(init(), A, 32, workers, jit="per-panel")
    got = simulate_sharded_stream(init(), A, 32, workers, jit="scan")
    _assert_states_close(got, ref)
    np.testing.assert_array_equal(got.C, ref.C)
    np.testing.assert_array_equal(got.R, ref.R)


@pytest.mark.parametrize("workers", [2, 4])
def test_scan_parity_sharded_adaptive(workers):
    """Sharded adaptive (divergent per-worker ctx → true per-worker
    accumulators + in-program merge): same admissions as the per-panel
    sharded driver, worker for worker."""
    m, n, panel = 300, 240, 20
    B, _ = spiked_rows_matrix(jax.random.key(230), m, n)

    def init():
        return adaptive_cur_init(
            jax.random.key(231), m, n, 8, None, r=8, sketch="countsketch",
            panel=panel, panel_cap=1, panel_cap_rows=1, swap_gain=2.0,
        )

    ref = simulate_sharded_stream(init(), B, panel, workers, jit="per-panel")
    got = simulate_sharded_stream(init(), B, panel, workers, jit="scan")
    _assert_states_close(got, ref, atol=2e-4)
    np.testing.assert_array_equal(got.ctx.col_idx, ref.ctx.col_idx)
    np.testing.assert_array_equal(got.ctx.row_idx, ref.ctx.row_idx)
    assert int(got.ctx.n_filled) == int(ref.ctx.n_filled)


def test_scan_stream_is_compile_cached(A):
    """Repeated scan-path streams of the same shape must reuse the
    module-scope compiled entry (no per-call retrace)."""
    from repro.stream.engine import _scan_stream_panels

    def run():
        st = sp_svd_init(jax.random.key(240), M, N, sizes=SIZES, panel=45)
        return stream_panels(st, A, 45)

    run()
    before = _scan_stream_panels._cache_size()
    run()
    run()
    assert _scan_stream_panels._cache_size() == before


def test_donation_consumes_input_state(A):
    """The scan path donates the input state's buffers — using the input
    after streaming must raise, and caller-provided index arrays must stay
    alive (init copies them)."""
    ci = jnp.asarray([3, 50, 99, 120, 164, 7, 31, 88], jnp.int32)
    ri = select_rows(jax.random.key(250), A, 8, "uniform").idx
    st0 = streaming_cur_init(jax.random.key(251), M, N, ci, ri, sketch="countsketch", panel=32)
    st1 = stream_panels(st0, A, 32)
    assert int(st1.offset) == padded_n(N, 32)  # tail panel zero-padded
    # caller-held arrays survive (defensive copies at init)
    np.testing.assert_array_equal(np.asarray(ci)[:3], [3, 50, 99])
    _ = np.asarray(ri)
    if st0.C.is_deleted():  # donation active on this backend
        with pytest.raises(RuntimeError):
            _ = np.asarray(st0.C)


def test_adaptive_scorer_survives_duplicate_admissions():
    """Near-duplicate heavy columns make the admitted Gram numerically
    rank-deficient; the whitened-basis scorer must stay NaN-free (the
    no-NaN contract of the floored-QR path it replaced) and keep admitting
    later structure instead of silently going dead."""
    m, n, panel = 200, 240, 40
    B = 0.01 * jax.random.normal(jax.random.key(300), (m, n))
    spike = jax.random.normal(jax.random.key(301), (m,)) * 9.0
    # two (near-)identical heavy columns in the first panel...
    B = B.at[:, 3].add(spike).at[:, 17].add(spike)
    # ...and a genuinely new heavy column long after
    B = B.at[:, 200].add(9.0 * jax.random.normal(jax.random.key(302), (m,)))
    ri = select_rows(jax.random.key(303), B, 8, "uniform").idx
    st = adaptive_cur_init(
        jax.random.key(304), m, n, 6, ri, sketch="countsketch", panel=panel, panel_cap=2
    )
    st = stream_panels(st, B, panel)
    res = adaptive_cur_finalize(st)
    assert bool(jnp.all(jnp.isfinite(res.U)))
    assert bool(jnp.all(jnp.isfinite(st.ctx.slot_score)))
    admitted = set(np.asarray(res.col_idx).tolist())
    assert {3, 17} & admitted  # the duplicates were scoreable
    assert 200 in admitted, sorted(admitted)  # scorer still alive afterwards
