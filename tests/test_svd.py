"""§5 single-pass SVD: Algorithm 3 streaming semantics + Theorem 4 claims."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import powerlaw_matrix
from repro.core import (
    fast_sp_svd,
    practical_sp_svd,
    sp_svd_finalize,
    sp_svd_init,
    sp_svd_update,
    svd_error_ratio,
)


@pytest.fixture(scope="module")
def A():
    return powerlaw_matrix(jax.random.key(0), 500, 400, 1.0)


SIZES = dict(c=40, r=40, c0=120, r0=120, s_c=120, s_r=120)


def test_streaming_matches_oneshot(A):
    """Panel-streamed accumulators == single-panel pass (algebraic identity)."""
    m, n = A.shape
    s1 = sp_svd_init(jax.random.key(1), m, n, sizes=SIZES)
    for off in range(0, n, 100):
        s1 = sp_svd_update(s1, A[:, off : off + 100])
    s2 = sp_svd_init(jax.random.key(1), m, n, sizes=SIZES)
    s2 = sp_svd_update(s2, A)
    np.testing.assert_allclose(s1.C, s2.C, atol=2e-3)
    np.testing.assert_allclose(s1.R, s2.R, atol=2e-3)
    np.testing.assert_allclose(s1.M, s2.M, atol=2e-3)


def test_panel_size_invariance(A):
    """Different L panels give identical finalized factors (same sketches)."""
    outs = []
    for panel in (64, 200):
        U, S, V = fast_sp_svd(jax.random.key(2), A, sizes=SIZES, panel=panel)
        outs.append((U * S[None]) @ V.T)
    np.testing.assert_allclose(outs[0], outs[1], atol=5e-3)


def test_relative_error_bound(A):
    """Theorem 4: (1+ε) error vs ||A − A_k||_F at moderate sketch sizes."""
    k = 10
    errs = [
        float(svd_error_ratio(A, *fast_sp_svd(jax.random.key(10 + t), A, sizes=SIZES), k))
        for t in range(3)
    ]
    assert np.mean(errs) < 0.5, errs


def test_fast_beats_practical(A):
    """§6.3 headline: Fast SP-SVD ≪ Practical SP-SVD at equal budget."""
    k = 10
    e_fast = np.mean([
        float(svd_error_ratio(A, *fast_sp_svd(jax.random.key(20 + t), A, sizes=SIZES), k))
        for t in range(3)
    ])
    e_prac = np.mean([
        float(svd_error_ratio(A, *practical_sp_svd(jax.random.key(30 + t), A, c=40, r=40), k))
        for t in range(3)
    ])
    assert e_fast < e_prac, (e_fast, e_prac)


@pytest.mark.slow
def test_error_decreases_with_budget(A):
    k = 10
    errs = []
    for f in (2, 6):
        sizes = dict(c=f * k, r=f * k, c0=3 * f * k, r0=3 * f * k, s_c=3 * f * k, s_r=3 * f * k)
        e = np.mean([
            float(svd_error_ratio(A, *fast_sp_svd(jax.random.key(40 + t), A, sizes=sizes), k))
            for t in range(3)
        ])
        errs.append(e)
    assert errs[1] < errs[0], errs


def test_fixed_rank_truncation(A):
    U, S, V = fast_sp_svd(jax.random.key(3), A, sizes=SIZES, fixed_rank=10)
    assert U.shape[1] == 10 and S.shape == (10,) and V.shape[1] == 10


def test_orthonormal_outputs(A):
    U, S, V = fast_sp_svd(jax.random.key(4), A, sizes=SIZES)
    np.testing.assert_allclose(U.T @ U, np.eye(U.shape[1]), atol=1e-4)
    np.testing.assert_allclose(V.T @ V, np.eye(V.shape[1]), atol=1e-4)
    assert bool(jnp.all(S[:-1] >= S[1:]))  # sorted singular values
