"""§3.2 projections: Proposition 1 + Theorem 2 machinery."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import psd_project, sym_project


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 2**30), n=st.integers(3, 30))
def test_proposition1_nonexpansive_sym(seed, n):
    """||X − Π(X̂)||_F ≤ ||X − X̂||_F for X in the convex set (symmetric)."""
    key = jax.random.key(seed)
    S = jax.random.normal(key, (n, n))
    X = 0.5 * (S + S.T)  # a point inside H^n
    Xhat = X + jax.random.normal(jax.random.fold_in(key, 1), (n, n))
    proj = sym_project(Xhat)
    assert float(jnp.linalg.norm(X - proj)) <= float(jnp.linalg.norm(X - Xhat)) + 1e-5


@settings(deadline=None, max_examples=20)
@given(seed=st.integers(0, 2**30), n=st.integers(3, 25))
def test_proposition1_nonexpansive_psd(seed, n):
    key = jax.random.key(seed)
    B = jax.random.normal(key, (n, n))
    X = B @ B.T  # PSD point
    Xhat = X + 0.7 * jax.random.normal(jax.random.fold_in(key, 1), (n, n))
    proj = psd_project(Xhat)
    assert float(jnp.linalg.norm(X - proj)) <= float(jnp.linalg.norm(X - Xhat)) + 1e-4


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 2**30))
def test_psd_project_is_psd_and_idempotent(seed):
    X = jax.random.normal(jax.random.key(seed), (20, 20))
    P = psd_project(X)
    ev = jnp.linalg.eigvalsh(0.5 * (P + P.T))
    assert float(ev.min()) > -1e-4
    np.testing.assert_allclose(psd_project(P), P, atol=1e-4)


def test_sym_project_formula():
    X = jax.random.normal(jax.random.key(0), (9, 9))
    np.testing.assert_allclose(sym_project(X), (X + X.T) / 2)
