"""Observability subsystem (repro.obs): telemetry parity, the a-posteriori
error estimator, and the host-side metrics/spans registry.

The load-bearing guarantees, in test order:

* telemetry **off** compiles the byte-identical scan program (tel=None has
  no pytree leaves — jit keys, donation layout and HLO are untouched);
* telemetry **on** leaves every factor bit-identical (the hook runs after
  the C/R/M updates and only writes the diagnostics frame);
* the in-stream test sketch ``Ψ = A Ω_test`` is exact (single-host and
  simulated-sharded), and the estimator lands inside a 2× band of the true
  relative error on the three synthetic stream families;
* worker telemetry frames merge by summation to the single-stream frame.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.cur import cur_relative_error, streaming_cur_finalize, streaming_cur_init
from repro.data.synthetic import (
    drifting_spectrum_matrix,
    late_spike_matrix,
    powerlaw_matrix,
    spiked_decay_matrix,
)
from repro.obs import (
    EVENT_BUDGET_FULL,
    MetricsRegistry,
    default_registry,
    estimate_rel_error,
    render_timeline,
    set_registry,
    span,
    telemetry_summary,
)
from repro.spsd import (
    adaptive_spsd_finalize,
    adaptive_spsd_init,
    streaming_spsd_finalize,
    streaming_spsd_init,
)
from repro.stream import (
    adaptive_cur_finalize,
    adaptive_cur_init,
    simulate_sharded_stream,
    stream_panels,
)
from repro.stream.engine import scan_chunk

M, N, PANEL = 160, 128, 32
CI = jnp.asarray([3, 17, 40, 63, 77, 90, 101, 120], jnp.int32)
RI = jnp.asarray([5, 12, 30, 44, 61, 80, 99, 140], jnp.int32)


def _A():
    A, _pos = spiked_decay_matrix(jax.random.key(0), M, N)
    return A


def _fixed_state(telemetry: bool):
    return streaming_cur_init(
        jax.random.key(2), M, N, CI, RI, sketch="countsketch", panel=PANEL,
        telemetry=telemetry,
    )


def _adaptive_state(telemetry: bool):
    # eviction + adaptive rows on: the richest telemetry surface
    return adaptive_cur_init(
        jax.random.key(3), M, N, 8, None, r=8, sketch="countsketch",
        panel=PANEL, panel_cap=1, panel_cap_rows=1, swap_gain=2.0,
        telemetry=telemetry,
    )


# ---------------------------------------------------------------- HLO parity


def _chunk_hlo(state) -> str:
    # fresh (non-donating) jit wrapper so the census text is cache-independent
    chunk = jax.ShapeDtypeStruct((M, N), jnp.float32)
    fn = jax.jit(scan_chunk, static_argnames="panel")
    return fn.lower(state, chunk, panel=PANEL).compile().as_text()


def test_telemetry_off_is_hlo_identical():
    """tel=None contributes no leaves: the scan program of a telemetry=False
    state is byte-identical to one built before the telemetry field existed
    (same init, default kwarg)."""
    st_default = streaming_cur_init(
        jax.random.key(2), M, N, CI, RI, sketch="countsketch", panel=PANEL
    )
    assert _chunk_hlo(_fixed_state(False)) == _chunk_hlo(st_default)
    assert _chunk_hlo(_fixed_state(True)) != _chunk_hlo(st_default)


# ----------------------------------------------------- bit-identical factors


@pytest.mark.parametrize(
    "make,finalize",
    [
        (_fixed_state, streaming_cur_finalize),
        (_adaptive_state, adaptive_cur_finalize),
    ],
    ids=["fixed_cur", "adaptive_cur"],
)
def test_factors_bitwise_identical_on_off(make, finalize):
    A = _A()
    off = stream_panels(make(False), A, PANEL)
    on = stream_panels(make(True), A, PANEL)
    np.testing.assert_array_equal(np.asarray(off.C), np.asarray(on.C))
    np.testing.assert_array_equal(np.asarray(off.R), np.asarray(on.R))
    np.testing.assert_array_equal(np.asarray(off.M), np.asarray(on.M))
    r_off, r_on = finalize(off), finalize(on)
    np.testing.assert_array_equal(np.asarray(r_off.U), np.asarray(r_on.U))


def test_spsd_factors_bitwise_identical_on_off():
    n = 128
    G = powerlaw_matrix(jax.random.key(8), n, 32, 1.0)
    K = G @ G.T + 0.01 * jnp.eye(n)
    ki = jnp.asarray([3, 17, 40, 63, 77, 90, 101, 120], jnp.int32)

    def fixed(telemetry):
        return streaming_spsd_init(
            jax.random.key(9), n, ki, s=48, panel=PANEL, telemetry=telemetry
        )

    def adaptive(telemetry):
        return adaptive_spsd_init(
            jax.random.key(10), n, 8, s=48, panel=PANEL, panel_cap=2,
            swap_gain=2.0, telemetry=telemetry,
        )

    for make, finalize in ((fixed, streaming_spsd_finalize), (adaptive, adaptive_spsd_finalize)):
        off = stream_panels(make(False), K, PANEL)
        on = stream_panels(make(True), K, PANEL)
        np.testing.assert_array_equal(np.asarray(off.C), np.asarray(on.C))
        np.testing.assert_array_equal(np.asarray(off.M), np.asarray(on.M))
        np.testing.assert_array_equal(
            np.asarray(finalize(off).X), np.asarray(finalize(on).X)
        )


# ----------------------------------------------------------- telemetry frame


def test_psi_is_exact_and_counts_consistent():
    """Ψ accumulated panel-by-panel equals A·Ω_test in one shot, and the
    fixed-index frame's counters match the static selection table."""
    A = _A()
    st = stream_panels(_fixed_state(True), A, PANEL)
    tel = st.tel
    np.testing.assert_allclose(
        np.asarray(tel.psi), np.asarray(A @ tel.omega[:N]), rtol=1e-5, atol=1e-4
    )
    s = telemetry_summary(st)
    assert s["total_admitted"] == CI.shape[0]
    assert s["occupancy"][-1] == CI.shape[0]
    assert s["panels_seen"] == N // PANEL
    assert np.asarray(tel.events)[-1] & EVENT_BUDGET_FULL
    assert s["energy_mass"] > 0


def test_adaptive_counters_match_ctx():
    A = _A()
    st = stream_panels(_adaptive_state(True), A, PANEL)
    s = telemetry_summary(st)
    assert s["total_admitted"] == int(st.ctx.n_filled)
    assert s["total_evicted"] == int(st.ctx.n_evicted)
    assert s["total_rows_admitted"] == int(st.ctx.rows.n_filled)
    # panel-local deltas, never cumulative — each slot ≤ the panel admission cap
    assert s["admitted"].max() <= PANEL


def test_sharded_telemetry_merges_to_single_stream():
    """Worker frames merge by summation: Ψ stays exact and the fixed-index
    frame is bitwise identical at any worker count (global formulas +
    disjoint panel writes)."""
    A = _A()
    single = stream_panels(_fixed_state(True), A, PANEL)
    for w in (2, 4):
        shard = simulate_sharded_stream(_fixed_state(True), A, PANEL, w)
        np.testing.assert_allclose(
            np.asarray(shard.tel.psi), np.asarray(A @ shard.tel.omega[:N]),
            rtol=1e-5, atol=1e-4,
        )
        for leaf in ("admitted", "occupancy", "events", "panels_seen"):
            np.testing.assert_array_equal(
                np.asarray(getattr(shard.tel, leaf)),
                np.asarray(getattr(single.tel, leaf)),
            )
    # adaptive: per-worker slot ranges — merged totals must equal ctx counters
    for w in (2, 4):
        st = simulate_sharded_stream(_adaptive_state(True), A, PANEL, w)
        s = telemetry_summary(st)
        assert s["total_admitted"] == int(st.ctx.n_filled), w


def test_telemetry_requires_panel():
    with pytest.raises(ValueError, match="panel"):
        streaming_cur_init(jax.random.key(0), M, N, CI, RI, telemetry=True)
    with pytest.raises(ValueError, match="panel"):
        adaptive_cur_init(jax.random.key(0), M, N, 8, RI, telemetry=True)


# ------------------------------------------------------------ error estimate


@pytest.mark.parametrize("family", ["spiked", "late-spike", "drift"])
def test_estimator_within_2x_band(family):
    """est = ‖Ψ − ÂΩ‖/‖Ψ‖ lands within 2× of the true relative Frobenius
    error (both directions) on each synthetic stream family, single-pass."""
    m, n, panel = 200, 160, 32
    if family == "spiked":
        A, _ = spiked_decay_matrix(jax.random.key(21), m, n)
    elif family == "late-spike":
        A, _e, _l = late_spike_matrix(jax.random.key(22), m, n)
    else:
        A, _b = drifting_spectrum_matrix(jax.random.key(23), m, n)
    st = adaptive_cur_init(
        jax.random.key(24), m, n, 12, None, r=12, sketch="countsketch",
        panel=panel, panel_cap=2, panel_cap_rows=2, swap_gain=2.0,
        telemetry=True,
    )
    st = stream_panels(st, A, panel)
    est = float(estimate_rel_error(st))
    true = float(cur_relative_error(A, adaptive_cur_finalize(st)))
    assert 0.5 * true <= est <= 2.0 * true, (family, est, true)


def test_estimator_spsd_band():
    n, panel = 192, 32
    G = powerlaw_matrix(jax.random.key(30), n, 24, 1.0)
    K = G @ G.T + 0.01 * jnp.eye(n)
    ki = jnp.asarray(np.arange(0, n, n // 12)[:12], jnp.int32)
    st = stream_panels(
        streaming_spsd_init(jax.random.key(31), n, ki, s=64, panel=panel, telemetry=True),
        K, panel,
    )
    res = streaming_spsd_finalize(st)
    recon = np.asarray(res.C) @ np.asarray(res.X) @ np.asarray(res.C).T
    true = float(np.linalg.norm(np.asarray(K) - recon) / np.linalg.norm(np.asarray(K)))
    est = float(estimate_rel_error(st))
    assert 0.5 * true <= est <= 2.0 * true, (est, true)


def test_estimator_mid_stream_cur():
    """CUR mid-stream semantics: the estimate covers the columns seen so far
    (R and Ψ are both zero on unseen columns)."""
    A = _A()
    stop = (N // PANEL) // 2 * PANEL
    st = stream_panels(_fixed_state(True), A, PANEL, stop=stop)
    est = float(estimate_rel_error(st))
    res = streaming_cur_finalize(st)
    ahat = np.asarray(res.C) @ np.asarray(res.U) @ np.asarray(res.R)
    seen = np.asarray(A)[:, :stop]
    true = float(np.linalg.norm(seen - ahat[:, :stop]) / np.linalg.norm(seen))
    assert 0.5 * true <= est <= 2.0 * true, (est, true)


def test_estimator_requires_telemetry():
    A = _A()
    st = stream_panels(_fixed_state(False), A, PANEL)
    with pytest.raises(ValueError, match="telemetry"):
        estimate_rel_error(st)


# ------------------------------------------------------------- host registry


def test_registry_instruments_and_jsonl(tmp_path):
    reg = MetricsRegistry()
    reg.inc("a/count")
    reg.inc("a/count", 4)
    reg.set_gauge("a/gauge", 2.5)
    for v in (1.0, 2.0, 3.0, 10.0):
        reg.observe("a/hist", v)
    summ = reg.histogram_summary("a/hist")
    assert summ["count"] == 4 and summ["min"] == 1.0 and summ["max"] == 10.0
    with span("outer", reg):
        with span("inner", reg):
            pass
    assert [s.name for s in reg.spans] == ["inner", "outer"]  # closed order
    assert reg.spans[0].depth == 1 and reg.spans[1].depth == 0
    path = tmp_path / "metrics.jsonl"
    reg.dump_jsonl(path)
    recs = [json.loads(line) for line in path.read_text().splitlines()]
    assert {"counter", "gauge", "histogram", "span"} <= {r["type"] for r in recs}
    assert next(r for r in recs if r["name"] == "a/count")["value"] == 5
    tl = render_timeline(reg)
    assert "outer" in tl and "inner" in tl
    assert render_timeline(MetricsRegistry()) == "(no spans recorded)"


def test_disabled_registry_is_inert():
    reg = MetricsRegistry(enabled=False)
    reg.inc("x")
    reg.set_gauge("x", 1.0)
    reg.observe("x", 1.0)
    with span("x", reg):
        pass
    assert not reg.counters and not reg.gauges and not reg.histograms and not reg.spans


def test_default_registry_swap_and_engine_spans():
    """Library spans are inert by default; an enabled process registry
    captures the engine's scan span without any plumbing."""
    assert default_registry().enabled is False
    prev = set_registry(MetricsRegistry())
    try:
        stream_panels(_fixed_state(False), _A(), PANEL)
        names = [s.name for s in default_registry().spans]
        assert "stream/streaming_cur/scan" in names
    finally:
        set_registry(prev)


def test_record_stream_telemetry():
    reg = MetricsRegistry()
    st = stream_panels(_adaptive_state(True), _A(), PANEL)
    reg.record_stream_telemetry(st)
    assert reg.counters["stream/admitted"] == int(st.ctx.n_filled)
    assert reg.counters["stream/panels"] == N // PANEL
    assert reg.histograms["stream/panel_energy"]
    assert "stream/energy_mass" in reg.gauges


# --------------------------------------------------- serve / train surfaces


def test_kv_compress_metrics():
    from repro.serve.kv_compress import KVCompressionConfig, compress_head_batch

    reg = MetricsRegistry()
    hist = jax.random.normal(jax.random.key(40), (1, 2, 64, 16))
    kc = KVCompressionConfig(rank=4, oversample=2, panel=32)
    compress_head_batch(jax.random.key(41), hist, kc, registry=reg)
    assert reg.counters["serve/kv_heads_compressed"] == 2
    assert len(reg.histograms["serve/kv_rel_err"]) == 2
    assert reg.gauges["serve/kv_compression_ratio"] > 1.0
    assert "serve/kv_compress/head_batch" in [s.name for s in reg.spans]


def test_grad_compress_stats():
    from repro.distributed.sharding import shard_map_compat
    from repro.train.grad_compress import CompressionConfig, compressed_mean_grads

    ccfg = CompressionConfig(rank=8, sketch_factor=2, min_dim=64)
    g = {
        "w": jax.random.normal(jax.random.key(50), (128, 128)),
        "b": jnp.ones((16,)),
    }
    e = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), g)
    mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))
    stat_keys = (
        "comp/wire_floats", "comp/dense_floats", "comp/ratio",
        "comp/ef_norm", "comp/rel_err",
    )

    def f(g, e, key):
        _gbar, _ne, stats = compressed_mean_grads(
            g, e, key, ccfg, ("dp",), with_stats=True
        )
        return stats

    spec = jax.tree.map(lambda _: P(), g)
    fn = shard_map_compat(
        f, mesh=mesh, in_specs=(spec, spec, P()),
        out_specs={k: P() for k in stat_keys}, axis_names={"dp"}, check_vma=True,
    )
    stats = jax.jit(fn)(g, e, jax.random.key(51))
    wire, dense = float(stats["comp/wire_floats"]), float(stats["comp/dense_floats"])
    assert dense == 128 * 128 + 16
    assert 0 < wire < dense and float(stats["comp/ratio"]) > 1.0
    # a full-rank Gaussian "gradient" is the compressor's worst case — the
    # stat just has to be a finite, positive health signal
    assert 0.0 < float(stats["comp/rel_err"]) < 10.0
    assert np.isfinite(float(stats["comp/ef_norm"]))


# ---------------------------------------------------------- multi-device lane


@pytest.mark.slow
def test_multidev_telemetry_merge():
    """Real shard_map telemetry merge at 2/4 devices (subprocess with forced
    host devices — see tests/multidev_scenario.py)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    script = os.path.join(os.path.dirname(__file__), "multidev_scenario.py")
    proc = subprocess.run(
        [sys.executable, script, "telemetry"],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, f"\nSTDOUT:{proc.stdout[-2000:]}\nSTDERR:{proc.stderr[-3000:]}"
    assert "OK scenario_telemetry_mesh_merge" in proc.stdout
