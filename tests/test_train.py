"""Training substrate: optimizer math, microbatching, GMR compression."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import init_params
from repro.train import (
    CompressionConfig,
    OptimizerConfig,
    compression_ratio,
    cross_entropy,
    init_opt_state,
    make_train_step,
)
from repro.train.grad_compress import compress, decompress, is_compressible
from repro.train.optimizer import adamw_update, global_norm, lr_at


def _tiny_cfg():
    cfg = ARCHS["llama3.2-1b"].smoke_config()
    return dataclasses.replace(cfg, d_model=64, d_ff=256, vocab_size=128)


def test_adamw_matches_numpy_reference():
    oc = OptimizerConfig(lr=1e-2, warmup_steps=0, total_steps=100, clip_norm=None,
                         weight_decay=0.1, min_lr_ratio=1.0)
    params = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    grads = {"w": jnp.asarray([[0.1, -0.2], [0.3, 0.4]])}
    st = init_opt_state(params, oc)
    new_p, st, _ = adamw_update(grads, st, params, oc)

    g = np.asarray(grads["w"])
    m = 0.1 * g
    v = 0.05 * g * g
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.95)
    w = np.asarray(params["w"])
    expect = w - 1e-2 * (mhat / (np.sqrt(vhat) + oc.eps) + 0.1 * w)
    np.testing.assert_allclose(new_p["w"], expect, rtol=1e-5)


def test_lr_schedule():
    oc = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
    assert float(lr_at(jnp.asarray(5), oc)) == pytest.approx(0.5)
    assert float(lr_at(jnp.asarray(10), oc)) == pytest.approx(1.0)
    assert float(lr_at(jnp.asarray(110), oc)) == pytest.approx(0.1, abs=1e-3)


def test_cross_entropy_matches_naive():
    logits = jax.random.normal(jax.random.key(0), (2, 8, 32))
    labels = jax.random.randint(jax.random.key(1), (2, 8), 0, 32)
    naive = -jnp.mean(jnp.take_along_axis(jax.nn.log_softmax(logits), labels[..., None], -1))
    np.testing.assert_allclose(cross_entropy(logits, labels), naive, rtol=1e-5)


@pytest.mark.slow
def test_microbatch_equivalence():
    """grad accumulation over 4 microbatches == single big batch (linear loss)."""
    cfg = _tiny_cfg()
    oc = OptimizerConfig(lr=1e-3, clip_norm=None)
    params = init_params(jax.random.key(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab_size)}
    outs = []
    for micro in (1, 4):
        state = {"params": jax.tree.map(jnp.copy, params), "opt": init_opt_state(params, oc)}
        step = make_train_step(cfg, oc, remat=None, microbatch=micro)
        state, metrics = step(state, batch)
        outs.append(state["params"])
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_allclose(a, b, atol=2e-5)


@pytest.mark.slow
def test_loss_decreases():
    from repro.data import DataConfig, SyntheticLM

    cfg = _tiny_cfg()
    oc = OptimizerConfig(lr=5e-3, warmup_steps=2, total_steps=40)
    params = init_params(jax.random.key(0), cfg)
    state = {"params": params, "opt": init_opt_state(params, oc)}
    step = jax.jit(make_train_step(cfg, oc, remat=None), donate_argnums=(0,))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, batch=8, seq_len=64))
    losses = []
    for i in range(30):
        state, m = step(state, data.batch_at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


@pytest.mark.slow
def test_remat_grad_equivalence():
    """remat=full/dots produce the same update as no remat."""
    cfg = _tiny_cfg()
    oc = OptimizerConfig(lr=1e-3, clip_norm=None)
    params = init_params(jax.random.key(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)}
    ref = None
    for remat in (None, "dots", "full"):
        state = {"params": jax.tree.map(jnp.copy, params), "opt": init_opt_state(params, oc)}
        state, _ = make_train_step(cfg, oc, remat=remat)(state, batch)
        leaves = jax.tree.leaves(state["params"])
        if ref is None:
            ref = leaves
        else:
            for a, b in zip(ref, leaves):
                np.testing.assert_allclose(a, b, atol=2e-5)


# ---- GMR gradient compression ----


def test_compress_linearity():
    """sketch(G1) + sketch(G2) == sketch(G1 + G2) — the psum-exactness property."""
    ccfg = CompressionConfig(rank=16, sketch_factor=4, min_dim=32)
    key = jax.random.key(3)
    G1 = jax.random.normal(jax.random.key(1), (128, 96))
    G2 = jax.random.normal(jax.random.key(2), (128, 96))
    t1 = compress(key, G1, ccfg)
    t2 = compress(key, G2, ccfg)
    t12 = compress(key, G1 + G2, ccfg)
    for a, b, ab in zip(t1, t2, t12):
        np.testing.assert_allclose(a + b, ab, atol=1e-3)


def test_compress_decompress_lowrank_exact():
    """A rank-r gradient reconstructs near-exactly when rank ≥ r."""
    ccfg = CompressionConfig(rank=24, sketch_factor=6, min_dim=32)
    key = jax.random.key(4)
    U = jax.random.normal(jax.random.key(5), (200, 8))
    V = jax.random.normal(jax.random.key(6), (8, 160))
    G = U @ V
    triple = compress(key, G, ccfg)
    Ghat = decompress(key, triple, G.shape, ccfg)
    rel = float(jnp.linalg.norm(G - Ghat) / jnp.linalg.norm(G))
    assert rel < 0.02, rel


def test_compression_ratio_large_model():
    """On production-size weights the DP volume shrinks >5x."""
    fake = {"w1": jnp.zeros((4096, 14336)), "w2": jnp.zeros((14336, 4096)),
            "norm": jnp.zeros((4096,))}
    ccfg = CompressionConfig(rank=64, sketch_factor=4, min_dim=1024)
    assert compression_ratio(fake, ccfg) > 5


def test_is_compressible_rules():
    ccfg = CompressionConfig(min_dim=512)
    assert is_compressible(jnp.zeros((512, 2048)), ccfg)
    assert not is_compressible(jnp.zeros((128, 2048)), ccfg)
    assert not is_compressible(jnp.zeros((2048,)), ccfg)
    # scan-stacked (L, m, n) weights compress per layer slice
    assert is_compressible(jnp.zeros((4, 512, 512)), ccfg)
    assert not is_compressible(jnp.zeros((4, 128, 512)), ccfg)
    assert not is_compressible(jnp.zeros((2, 4, 512, 512)), ccfg)


def test_compress_stacked_lowrank():
    """(L, m, n) gradients reconstruct per-slice with shared sketches."""
    ccfg = CompressionConfig(rank=24, sketch_factor=6, min_dim=32)
    key = jax.random.key(11)
    U = jax.random.normal(jax.random.key(12), (4, 100, 8))
    V = jax.random.normal(jax.random.key(13), (4, 8, 120))
    G = jnp.einsum("lmr,lrn->lmn", U, V)
    Ghat = decompress(key, compress(key, G, ccfg), G.shape, ccfg)
    rel = float(jnp.linalg.norm(G - Ghat) / jnp.linalg.norm(G))
    assert rel < 0.03, rel


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)
