"""Regression locks on the §Perf hillclimb results (pure artifact reads)."""

import glob
import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "artifacts", "dryrun")

PEAK, HBM, ICI = 197e12, 819e9, 50e9


def _load(name):
    path = os.path.join(ART, name)
    if not os.path.exists(path):
        pytest.skip(f"artifact {name} not generated in this environment")
    with open(path) as f:
        return json.load(f)


def _terms(r):
    wire = sum(v["wire_bytes"] for v in r["collectives"].values())
    return r["flops_per_device"] / PEAK, r["hbm_bytes_per_device"] / HBM, wire / ICI


def test_A1_flash_vjp_cuts_memory_term():
    base = _load("llama3.2-1b__train_4k__16x16.json")
    opt = _load("llama3.2-1b__train_4k__16x16__A1_flashvjp.json")
    _, m0, _ = _terms(base)
    _, m1, _ = _terms(opt)
    assert m1 < 0.75 * m0, (m0, m1)


def test_A2_microbatch_fits_hbm():
    opt = _load("llama3.2-1b__train_4k__16x16__A2_flashvjp_micro2.json")
    assert opt["memory"]["peak_estimate_bytes"] < 16e9


def test_B5_grouped_dispatch_kills_replicated_compute():
    base = _load("kimi-k2-1t-a32b__train_4k__16x16.json")
    opt = _load("kimi-k2-1t-a32b__train_4k__16x16__B5_grouped_dispatch.json")
    c0, m0, _ = _terms(base)
    c1, m1, _ = _terms(opt)
    assert c1 < 0.3 * c0, (c0, c1)
    assert m1 < 0.7 * m0, (m0, m1)


def test_C2_seq_parallel_cuts_collective_term():
    base = _load("mamba2-1.3b__prefill_32k__16x16.json")
    opt = _load("mamba2-1.3b__prefill_32k__16x16__C2_seqparallel_chunk512.json")
    _, _, k0 = _terms(base)
    _, _, k1 = _terms(opt)
    assert k1 < 0.5 * k0, (k0, k1)


def test_baseline_cells_complete_on_both_meshes():
    untagged = [p for p in glob.glob(os.path.join(ART, "*.json"))
                if json.load(open(p)).get("tag", "") == ""]
    if not untagged:
        pytest.skip("no artifacts")
    meshes = {"16x16": 0, "2x16x16": 0}
    for p in untagged:
        meshes[json.load(open(p))["mesh"]] += 1
    assert meshes["16x16"] == 33 and meshes["2x16x16"] == 33, meshes
