"""Sketching library: §2.3 families, Lemma 1 properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.sketching import draw_sketch, fwht

KINDS = ["gaussian", "srht", "countsketch", "osnap", "uniform", "osnap+gaussian"]


@pytest.mark.parametrize("kind", KINDS)
def test_apply_matches_materialized(kind):
    key = jax.random.key(0)
    m, n, s = 150, 37, 64
    A = jax.random.normal(jax.random.key(1), (m, n))
    S = draw_sketch(key, kind, s, m)
    Smat = S.materialize()
    np.testing.assert_allclose(S.apply(A), Smat @ A, rtol=0, atol=2e-5)
    np.testing.assert_allclose(S.apply_t(A.T), A.T @ Smat.T, rtol=0, atol=2e-5)


@pytest.mark.parametrize("kind", ["gaussian", "countsketch", "osnap", "osnap+gaussian"])
def test_cols_slicing(kind):
    """Streaming sub-sketch == column slice of the materialized sketch."""
    key = jax.random.key(2)
    S = draw_sketch(key, kind, 32, 200)
    sub = S.cols(40, 100)
    np.testing.assert_allclose(
        sub.materialize(), S.materialize()[:, 40:140], rtol=0, atol=1e-6
    )


@pytest.mark.slow
@settings(deadline=None, max_examples=15)
@given(
    kind=st.sampled_from(["gaussian", "countsketch", "osnap", "srht"]),
    m=st.integers(40, 300),
    seed=st.integers(0, 2**30),
)
def test_subspace_embedding_property(kind, m, seed):
    """Lemma 1 property 1: singular values of S·U within [1−η, 1+η] for an
    orthonormal U, at generous sketch size (η ≤ 0.7 w.h.p.)."""
    k = 8
    key = jax.random.key(seed)
    U, _ = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1), (m, k)))
    s = min(m, 40 * k)
    S = draw_sketch(jax.random.fold_in(key, 2), kind, s, m)
    sv = jnp.linalg.svd(S.apply(U), compute_uv=False)
    assert float(sv.max()) < 1.8 and float(sv.min()) > 0.3, (kind, sv)


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 2**30))
def test_matrix_product_preservation(seed):
    """Lemma 1 property 2: ||Bᵀ Sᵀ S A − Bᵀ A||_F ≤ ε ||A||_F ||B||_F."""
    key = jax.random.key(seed)
    m = 200
    A = jax.random.normal(jax.random.fold_in(key, 1), (m, 12))
    B = jax.random.normal(jax.random.fold_in(key, 2), (m, 9))
    errs = []
    for t in range(5):
        S = draw_sketch(jax.random.fold_in(key, 10 + t), "countsketch", 400, m)
        err = jnp.linalg.norm(B.T @ S.materialize().T @ S.apply(A) - B.T @ A)
        errs.append(float(err / (jnp.linalg.norm(A) * jnp.linalg.norm(B))))
    assert np.mean(errs) < 0.3, errs


def test_fwht_orthogonality():
    m = 64
    H = fwht(jnp.eye(m))
    np.testing.assert_allclose(H @ H.T / m, jnp.eye(m), atol=1e-5)


def test_unbiasedness_sts():
    """E[SᵀS] ≈ I over many draws (Gaussian & CountSketch)."""
    m, s, reps = 24, 48, 200
    for kind in ("gaussian", "countsketch"):
        acc = jnp.zeros((m, m))
        for t in range(reps):
            S = draw_sketch(jax.random.key(t), kind, s, m).materialize()
            acc = acc + S.T @ S
        acc = acc / reps
        assert float(jnp.max(jnp.abs(acc - jnp.eye(m)))) < 0.25


def test_seed_determinism():
    """Identical keys ⇒ identical sketches (gradient compression relies on it)."""
    for kind in KINDS:
        a = draw_sketch(jax.random.key(7), kind, 16, 100).materialize()
        b = draw_sketch(jax.random.key(7), kind, 16, 100).materialize()
        np.testing.assert_array_equal(a, b)
