"""CUR subsystem (repro/cur/): selection, fast core, streaming, batched."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cur import (
    batched_fast_cur,
    cur_error_ratio,
    cur_reconstruct,
    cur_relative_error,
    cur_sketch_sizes,
    draw_shared_sketches,
    exact_cur,
    fast_cur,
    select_columns,
    select_rows,
    streaming_cur_finalize,
    streaming_cur_init,
    streaming_cur_update,
)
from repro.data.synthetic import lowrank_plus_noise, powerlaw_matrix, spiked_decay_matrix


@pytest.fixture(scope="module")
def A():
    return powerlaw_matrix(jax.random.key(0), 400, 300, 1.0)


# ---------------------------------------------------------------------------
# selection
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["uniform", "leverage", "approx_leverage"])
def test_selection_probabilities_sane(A, policy):
    sel = select_columns(jax.random.key(1), A, 12, policy)
    assert sel.idx.shape == (12,)
    assert len(np.unique(np.asarray(sel.idx))) == 12  # without replacement
    assert np.all((np.asarray(sel.idx) >= 0) & (np.asarray(sel.idx) < A.shape[1]))
    probs = np.asarray(sel.probs)
    assert probs.shape == (A.shape[1],)
    assert np.all(probs >= 0)
    np.testing.assert_allclose(probs.sum(), 1.0, rtol=1e-5)


def test_pivoted_qr_picks_dominant_columns():
    """Greedy QR must pick the two orthogonal heavy columns first."""
    A = jnp.zeros((50, 40)).at[:, 7].set(10.0 * jnp.ones(50))
    A = A.at[:25, 13].set(8.0)
    A = A + 0.01 * jax.random.normal(jax.random.key(2), A.shape)
    sel = select_columns(jax.random.key(0), A, 2, "pivoted_qr")
    assert sel.probs is None
    assert set(np.asarray(sel.idx).tolist()) == {7, 13}


def test_leverage_concentrates_on_lowrank_support():
    """Rank-k leverage scores upweight the columns carrying the signal."""
    A = lowrank_plus_noise(jax.random.key(3), 200, 150, rank=5, snr=50.0)
    A = A.at[:, 60:].multiply(0.01)  # kill signal outside the first 60 columns
    sel = select_columns(jax.random.key(4), A, 10, "leverage", k=5)
    probs = np.asarray(sel.probs)
    assert probs[:60].sum() > 0.9


def test_select_rows_matches_transposed_columns(A):
    s_r = select_rows(jax.random.key(5), A, 9, "leverage")
    s_c = select_columns(jax.random.key(5), A.T, 9, "leverage")
    np.testing.assert_array_equal(s_r.idx, s_c.idx)


# ---------------------------------------------------------------------------
# selection edge cases
# ---------------------------------------------------------------------------


def test_select_columns_rejects_c_beyond_n():
    """The budget is clamped by validation, not silently wrapped: c > n (and
    c ≤ 0) raise instead of sampling out-of-range indices."""
    A = powerlaw_matrix(jax.random.key(30), 20, 10, 1.0)
    with pytest.raises(ValueError, match="0 < c <= n"):
        select_columns(jax.random.key(31), A, 11, "uniform")
    with pytest.raises(ValueError, match="0 < c <= n"):
        select_columns(jax.random.key(31), A, 0, "uniform")
    # k beyond min(m, n) is clamped, not an error (full-subspace leverage)
    sel = select_columns(jax.random.key(32), A, 5, "leverage", k=999)
    assert sel.idx.shape == (5,) and len(np.unique(np.asarray(sel.idx))) == 5


@pytest.mark.parametrize("policy", ["leverage", "approx_leverage"])
def test_sketched_leverage_on_degenerate_spectrum_stays_distinct(policy):
    """Rank-1 input concentrates the (sketched) leverage distribution on a
    single direction — sampling without replacement must still return c
    distinct, in-range indices even when most probabilities are ~0."""
    u = jax.random.normal(jax.random.key(33), (60, 1))
    v = jnp.zeros((40, 1)).at[7, 0].set(1.0)
    A = (u @ v.T) + 1e-6 * jax.random.normal(jax.random.key(34), (60, 40))
    sel = select_columns(jax.random.key(35), A, 6, policy, k=1)
    idx = np.asarray(sel.idx)
    assert len(np.unique(idx)) == 6, idx
    assert np.all((idx >= 0) & (idx < 40))
    assert 7 in idx.tolist()  # the support column is (near-)surely kept


def test_duplicate_indices_keep_fast_cur_finite():
    """Sketched-leverage sampling *with replacement* (or a user-fed index
    list) can hand fast_cur duplicated columns; the floored core solve must
    absorb the rank deficiency instead of producing NaN/inf."""
    A = powerlaw_matrix(jax.random.key(36), 80, 60, 1.0)
    ci = jnp.asarray([3, 3, 17, 17, 41, 5], jnp.int32)  # deliberate duplicates
    ri = jnp.asarray([2, 9, 9, 30, 55, 55], jnp.int32)
    res = fast_cur(jax.random.key(37), A, col_idx=ci, row_idx=ri, sketch="countsketch")
    # The guarantee is *finiteness* (sign-preserving absolute floor in
    # _solve_least_squares), not accuracy: exactly-duplicated columns make
    # the core solve rank-deficient, so U is non-unique.
    assert bool(jnp.all(jnp.isfinite(res.U)))
    np.testing.assert_array_equal(res.C, jnp.take(A, ci, axis=1))
    np.testing.assert_array_equal(res.R, jnp.take(A, ri, axis=0))
    assert bool(jnp.all(jnp.isfinite(cur_reconstruct(res))))


def test_pivoted_qr_rank_deficient_input():
    """Greedy pivoted QR asked for more columns than the numerical rank:
    the taken-mask must keep indices distinct (deflation residues would
    otherwise be re-picked) and the early picks must cover the true rank."""
    k1, k2 = jax.random.split(jax.random.key(38))
    L = jax.random.normal(k1, (50, 3))
    Rf = jax.random.normal(k2, (3, 30))
    A = L @ Rf  # exact rank 3, no noise
    sel = select_columns(jax.random.key(39), A, 8, "pivoted_qr")
    idx = np.asarray(sel.idx)
    assert len(np.unique(idx)) == 8, idx
    assert np.all((idx >= 0) & (idx < 30))
    # the first 3 picks span the column space: projecting A onto them is exact
    C = np.asarray(A)[:, idx[:3]]
    proj = C @ np.linalg.lstsq(C, np.asarray(A), rcond=None)[0]
    np.testing.assert_allclose(proj, np.asarray(A), atol=1e-3)


# ---------------------------------------------------------------------------
# sketch sizes (Table 2 + ρ branch)
# ---------------------------------------------------------------------------


def test_sketch_sizes_branch_selection():
    small_rho = cur_sketch_sizes(20, 20, eps=0.05, rho=0.5)
    big_rho = cur_sketch_sizes(20, 20, eps=0.05, rho=10.0)
    assert small_rho["s_c"] > big_rho["s_c"]  # 1/(ε ρ²) branch dominates at small ρ
    # past the ε^{-1/4} crossover the ε^{-1/2} branch is active and ρ-independent
    assert big_rho["s_c"] == cur_sketch_sizes(20, 20, eps=0.05, rho=100.0)["s_c"]
    assert cur_sketch_sizes(20, 20, eps=0.01)["s_c"] > cur_sketch_sizes(20, 20, eps=0.1)["s_c"]


# ---------------------------------------------------------------------------
# exact vs fast core
# ---------------------------------------------------------------------------


def test_fast_cur_within_tolerance_of_exact(A):
    """Acceptance: ≤1.05× the Frobenius error of exact CUR (same C, R) at
    Table-2 default sketch sizes."""
    ratios = []
    for t in range(3):
        res_e = exact_cur(A, key=jax.random.key(20 + t), c=15, r=15)
        res_f = fast_cur(
            jax.random.key(40 + t), A, col_idx=res_e.col_idx, row_idx=res_e.row_idx
        )
        num = float(jnp.linalg.norm(A - cur_reconstruct(res_f)))
        den = float(jnp.linalg.norm(A - cur_reconstruct(res_e)))
        ratios.append(num / den)
    assert np.mean(ratios) <= 1.05, ratios
    assert np.max(ratios) <= 1.10, ratios


@pytest.mark.parametrize("sketch", ["gaussian", "countsketch", "leverage"])
def test_fast_cur_sketch_families(A, sketch):
    res = fast_cur(jax.random.key(6), A, 15, 15, sketch=sketch)
    assert float(cur_error_ratio(A, res)) < 0.25
    assert float(cur_relative_error(A, res)) < 1.0


def test_error_ratio_nonnegative(A):
    """exact core is the minimizer: any sketched core can only do worse."""
    res = fast_cur(jax.random.key(7), A, 12, 12, s_c=60, s_r=60)
    assert float(cur_error_ratio(A, res)) > -1e-3


def test_cur_factors_are_actual_columns_and_rows(A):
    res = fast_cur(jax.random.key(8), A, 10, 14)
    np.testing.assert_array_equal(res.C, jnp.take(A, res.col_idx, axis=1))
    np.testing.assert_array_equal(res.R, jnp.take(A, res.row_idx, axis=0))
    assert res.U.shape == (10, 14)


# ---------------------------------------------------------------------------
# streaming
# ---------------------------------------------------------------------------


def test_streaming_matches_oneshot(A):
    """Panel-streamed CUR == one-shot fast_cur on identical sketches."""
    m, n = A.shape
    ci = select_columns(jax.random.key(9), A, 12, "uniform").idx
    ri = select_rows(jax.random.key(10), A, 12, "uniform").idx
    state = streaming_cur_init(jax.random.key(11), m, n, ci, ri, sketch="countsketch")
    sketches = (state.S_C, state.S_R)
    for off in range(0, n, 64):
        state = streaming_cur_update(state, A[:, off : off + min(64, n - off)])
    res_s = streaming_cur_finalize(state)
    res_o = fast_cur(jax.random.key(0), A, col_idx=ci, row_idx=ri, sketches=sketches)
    np.testing.assert_array_equal(res_s.C, res_o.C)
    np.testing.assert_array_equal(res_s.R, res_o.R)
    np.testing.assert_allclose(res_s.U, res_o.U, atol=2e-3)


def test_streaming_panel_size_invariance(A):
    """Different L give the same factors (same sketches) — jit'd update."""
    m, n = A.shape
    ci = select_columns(jax.random.key(12), A, 10, "uniform").idx
    ri = select_rows(jax.random.key(13), A, 10, "uniform").idx
    outs = []
    for panel in (50, 150):
        state = streaming_cur_init(jax.random.key(14), m, n, ci, ri, sketch="osnap")
        step = jax.jit(streaming_cur_update)
        for off in range(0, n, panel):
            state = step(state, A[:, off : off + panel])  # n divisible by 50/150
        res = streaming_cur_finalize(state)
        outs.append(cur_reconstruct(res))
    np.testing.assert_allclose(outs[0], outs[1], atol=5e-3)


def test_streaming_rejects_nonsliceable_sketch(A):
    ci = jnp.arange(5)
    with pytest.raises(NotImplementedError):
        streaming_cur_init(jax.random.key(15), *A.shape, ci, ci, sketch="srht")


# ---------------------------------------------------------------------------
# batched
# ---------------------------------------------------------------------------


def test_batched_matches_loop():
    """vmapped batched CUR ≡ per-item fast_cur with the same shared sketches."""
    B, m, n = 3, 96, 80
    Ab = jnp.stack([powerlaw_matrix(jax.random.key(30 + i), m, n, 1.0) for i in range(B)])
    sketches = draw_shared_sketches(jax.random.key(16), m, n, 48, 48)
    res = batched_fast_cur(jax.random.key(17), Ab, 8, 8, sketches=sketches, use_kernel=False)
    assert res.C.shape == (B, m, 8) and res.U.shape == (B, 8, 8) and res.R.shape == (B, 8, n)
    for b in range(B):
        item = fast_cur(
            jax.random.key(0), Ab[b],
            col_idx=res.col_idx[b], row_idx=res.row_idx[b], sketches=sketches,
        )
        np.testing.assert_allclose(res.U[b], item.U, atol=1e-4)


def test_batched_kernel_path_matches_einsum():
    """The fused Pallas twoside_sketch route gives the same cores."""
    B, m, n = 2, 64, 64
    Ab = jnp.stack([powerlaw_matrix(jax.random.key(40 + i), m, n, 1.0) for i in range(B)])
    sketches = draw_shared_sketches(jax.random.key(18), m, n, 32, 32)
    kw = dict(sketches=sketches)
    res_k = batched_fast_cur(jax.random.key(19), Ab, 6, 6, use_kernel=True, **kw)
    res_e = batched_fast_cur(jax.random.key(19), Ab, 6, 6, use_kernel=False, **kw)
    np.testing.assert_allclose(res_k.U, res_e.U, atol=1e-4)
    np.testing.assert_array_equal(res_k.col_idx, res_e.col_idx)


def test_batched_is_jittable():
    B, m, n = 2, 48, 40
    Ab = jnp.stack([powerlaw_matrix(jax.random.key(50 + i), m, n, 1.0) for i in range(B)])
    fn = jax.jit(lambda k, a: batched_fast_cur(k, a, 6, 6, s_c=24, s_r=24, use_kernel=False).U)
    U = fn(jax.random.key(20), Ab)
    assert U.shape == (B, 6, 6) and bool(jnp.all(jnp.isfinite(U)))


def test_batched_leverage_selection_matches_policy_loop():
    """selection="approx_leverage" vmaps the one-shot sketched-leverage
    policy: per-item indices equal a python loop of select_columns/
    select_rows with the same folded keys."""
    from repro.cur.selection import select_columns, select_rows

    B, m, n, c, r = 3, 100, 80, 8, 8
    Ab = jnp.stack([spiked_decay_matrix(jax.random.key(60 + i), m, n)[0] for i in range(B)])
    res = batched_fast_cur(
        jax.random.key(21), Ab, c, r, selection="approx_leverage", use_kernel=False
    )
    k_sel, _ = jax.random.split(jax.random.key(21))
    keys = jax.random.split(k_sel, B)
    for b in range(B):
        k_c, k_r = jax.random.split(keys[b])
        np.testing.assert_array_equal(
            res.col_idx[b], select_columns(k_c, Ab[b], c, "approx_leverage").idx
        )
        np.testing.assert_array_equal(
            res.row_idx[b], select_rows(k_r, Ab[b], r, "approx_leverage").idx
        )


def test_batched_leverage_beats_uniform_on_spiked_stacks():
    """ROADMAP open item closed: per-item sketched-leverage selection lands
    lower relative error than uniform at equal (c, r) on spiked stacks."""
    B, m, n, c, r = 4, 120, 100, 10, 10
    Ab = jnp.stack([spiked_decay_matrix(jax.random.key(70 + i), m, n)[0] for i in range(B)])
    errs = {}
    for sel in ("uniform", "approx_leverage"):
        res = batched_fast_cur(jax.random.key(22), Ab, c, r, selection=sel, use_kernel=False)
        errs[sel] = np.mean([
            float(cur_relative_error(Ab[b], jax.tree_util.tree_map(lambda x: x[b], res)))
            for b in range(B)
        ])
    assert errs["approx_leverage"] < errs["uniform"], errs


def test_batched_rejects_unknown_selection():
    Ab = jnp.zeros((2, 16, 16))
    with pytest.raises(ValueError, match="selection"):
        batched_fast_cur(jax.random.key(0), Ab, 4, 4, selection="pivoted_qr")
