"""End-to-end system behaviour: train → checkpoint → restore → serve."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, run_resilient_loop, latest_step
from repro.configs import ARCHS, supported_cells
from repro.data import DataConfig, SyntheticLM
from repro.models import init_params
from repro.serve import generate
from repro.train import OptimizerConfig, init_opt_state, make_train_step


@pytest.mark.slow
def test_train_checkpoint_restore_serve(tmp_path):
    """The full lifecycle on one device: loss falls, crash mid-run recovers
    from checkpoint, the final model serves tokens deterministically."""
    cfg = dataclasses.replace(
        ARCHS["llama3.2-1b"].smoke_config(), d_model=64, d_ff=256, vocab_size=128
    )
    oc = OptimizerConfig(lr=5e-3, warmup_steps=2, total_steps=40)
    params = init_params(jax.random.key(0), cfg)
    state = {"params": params, "opt": init_opt_state(params, oc)}
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, batch=8, seq_len=64))
    jstep = jax.jit(make_train_step(cfg, oc, remat=None), donate_argnums=(0,))

    report = run_resilient_loop(
        state=state,
        step_fn=lambda s, b, i: jstep(s, b),
        batch_fn=data.batch_at,
        n_steps=30,
        ckpt_dir=str(tmp_path),
        ckpt_every=10,
        fail_at_step=17,  # injected crash mid-run
    )
    assert report.restarts == 1
    assert report.losses[-1] < report.losses[0] - 0.3
    assert latest_step(str(tmp_path)) == 30

    # restore and serve
    final, extra, step = restore(str(tmp_path), state)
    prompt = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    out1 = generate(final["params"], cfg, prompt, 8)
    out2 = generate(final["params"], cfg, prompt, 8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 8)


def test_assigned_cell_coverage():
    """40 assigned (arch × shape) cells: 33 runnable + 7 documented skips
    (pure full-attention archs × long_500k)."""
    cells = supported_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(runnable) == 33
    assert all(shape == "long_500k" for _, shape, _ in skipped)
    skip_archs = {a for a, _, _ in skipped}
    assert skip_archs == {
        "kimi-k2-1t-a32b", "deepseek-v2-lite-16b", "llama3.2-1b", "phi4-mini-3.8b",
        "mistral-nemo-12b", "musicgen-large", "llama-3.2-vision-90b",
    }


def test_dryrun_artifacts_complete():
    """Every runnable cell has a baseline artifact on BOTH meshes."""
    art = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "artifacts", "dryrun")
    if not os.path.isdir(art):
        pytest.skip("dry-run artifacts not generated in this environment")
    missing = []
    for arch, shape, ok in supported_cells():
        if not ok:
            continue
        for mesh in ("16x16", "2x16x16"):
            if not os.path.exists(os.path.join(art, f"{arch}__{shape}__{mesh}.json")):
                missing.append((arch, shape, mesh))
    assert not missing, missing


def test_dryrun_artifacts_sane():
    import json, glob

    art = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "artifacts", "dryrun")
    if not os.path.isdir(art):
        pytest.skip("no artifacts")
    for path in glob.glob(os.path.join(art, "*__16x16.json")):
        with open(path) as f:
            r = json.load(f)
        assert r["flops_per_device"] > 0, path
        assert r["memory"]["peak_estimate_bytes"] > 0, path
        if r["shape"] == "train_4k":
            assert "all-reduce" in r["collectives"], path  # DP/TP reductions must exist
