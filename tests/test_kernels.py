"""Per-kernel allclose sweeps: Pallas (interpret) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    countsketch_apply,
    countsketch_ref,
    panel_score,
    panel_score_ref,
    twoside_sketch,
    twoside_sketch_ref,
)

TWOSIDE_SHAPES = [
    (64, 300, 200, 64),  # unaligned m/n → padding path
    (128, 512, 512, 96),
    (32, 130, 260, 48),
    (256, 1024, 384, 128),
    (128, 256, 256, 128),  # exactly aligned
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", TWOSIDE_SHAPES)
def test_twoside_sketch_allclose(shape, dtype):
    s_c, m, n, s_r = shape
    ks = jax.random.split(jax.random.key(sum(shape)), 3)
    Sc = jax.random.normal(ks[0], (s_c, m), jnp.float32).astype(dtype)
    A = jax.random.normal(ks[1], (m, n), jnp.float32).astype(dtype)
    SrT = jax.random.normal(ks[2], (n, s_r), jnp.float32).astype(dtype)
    out = twoside_sketch(Sc, A, SrT, interpret=True)
    ref = twoside_sketch_ref(Sc, A, SrT)
    tol = 1e-5 if dtype == jnp.float32 else 2.5e-2
    rel = float(jnp.max(jnp.abs(out - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < tol, (shape, dtype, rel)


CS_SHAPES = [(64, 300, 200), (100, 512, 384), (200, 1000, 130), (128, 256, 256)]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", CS_SHAPES)
def test_countsketch_allclose(shape, dtype):
    s, m, n = shape
    ks = jax.random.split(jax.random.key(sum(shape)), 3)
    h = jax.random.randint(ks[0], (m,), 0, s)
    sg = jax.random.rademacher(ks[1], (m,), jnp.float32)
    A = jax.random.normal(ks[2], (m, n), jnp.float32).astype(dtype)
    out = countsketch_apply(h, sg, A, s, interpret=True)
    ref = countsketch_ref(h, sg, A, s)
    tol = 1e-5 if dtype == jnp.float32 else 2.5e-2
    rel = float(jnp.max(jnp.abs(out - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < tol, (shape, dtype, rel)


def test_countsketch_padding_no_bucket_pollution():
    """Padded rows must not contribute to any bucket (zero signs)."""
    s, m, n = 64, 100, 50  # m=100 pads to 256
    ks = jax.random.split(jax.random.key(0), 3)
    h = jax.random.randint(ks[0], (m,), 0, s)
    sg = jax.random.rademacher(ks[1], (m,), jnp.float32)
    A = jnp.ones((m, n))
    out = countsketch_apply(h, sg, A, s, interpret=True)
    ref = countsketch_ref(h, sg, A, s)
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_twoside_block_shape_sweep():
    """Same result across BlockSpec tilings (grid decomposition invariance)."""
    s_c, m, n, s_r = 128, 512, 512, 128
    ks = jax.random.split(jax.random.key(1), 3)
    Sc = jax.random.normal(ks[0], (s_c, m))
    A = jax.random.normal(ks[1], (m, n))
    SrT = jax.random.normal(ks[2], (n, s_r))
    ref = twoside_sketch_ref(Sc, A, SrT)
    scale = float(jnp.max(jnp.abs(ref)))
    for bm, bn in [(128, 128), (256, 256), (512, 128)]:
        out = twoside_sketch(Sc, A, SrT, block_m=bm, block_n=bn, interpret=True)
        # different tilings reorder the fp32 reduction; tolerance scales with |M|
        np.testing.assert_allclose(out, ref, rtol=0, atol=2e-4 * scale)


# ---------------------------------------------------------------------------
# panel_score: fused streaming panel scoring (sc_a + resid2 + energy)
# ---------------------------------------------------------------------------

PS_SHAPES = [
    (72, 300, 96, 16),  # every dim unaligned → padding path
    (240, 1024, 128, 16),  # the adaptive-CUR bench shape
    (128, 512, 256, 32),  # aligned
    (64, 130, 40, 8),  # tiny ragged panel
]


@pytest.mark.parametrize("shape", PS_SHAPES)
def test_panel_score_allclose(shape):
    s_c, m, L, c = shape
    ks = jax.random.split(jax.random.key(sum(shape)), 3)
    Sc = jax.random.normal(ks[0], (s_c, m))
    A_L = jax.random.normal(ks[1], (m, L))
    Q, _ = jnp.linalg.qr(jax.random.normal(ks[2], (s_c, c)))
    Qm = Q * (jnp.arange(c) < max(1, c // 2))
    sc_a, r2, en = panel_score(Sc, A_L, Qm, interpret=True)
    sc_ref, r2_ref, en_ref = panel_score_ref(Sc, A_L, Qm)
    scale = float(jnp.max(en_ref)) + 1e-9
    np.testing.assert_allclose(sc_a, sc_ref, atol=1e-4 * float(jnp.max(jnp.abs(sc_ref))))
    np.testing.assert_allclose(r2, r2_ref, atol=2e-4 * scale)
    np.testing.assert_allclose(en, en_ref, atol=2e-4 * scale)


def test_panel_score_empty_and_full_basis():
    """Unfilled basis ⇒ resid2 == energy; full orthonormal basis that spans
    the sketch space ⇒ resid2 == 0."""
    s_c, m, L = 32, 200, 64
    ks = jax.random.split(jax.random.key(7), 2)
    Sc = jax.random.normal(ks[0], (s_c, m))
    A_L = jax.random.normal(ks[1], (m, L))
    zero_q = jnp.zeros((s_c, 8))
    _, r2, en = panel_score(Sc, A_L, zero_q, interpret=True)
    np.testing.assert_allclose(r2, en, rtol=1e-6)
    full_q = jnp.eye(s_c)  # spans everything
    _, r2f, enf = panel_score(Sc, A_L, full_q, interpret=True)
    np.testing.assert_allclose(r2f, jnp.zeros_like(r2f), atol=2e-3 * float(jnp.max(enf)))


def test_panel_score_block_shape_sweep():
    """Grid-decomposition invariance across (block_m, block_l) tilings."""
    s_c, m, L, c = 128, 512, 256, 16
    ks = jax.random.split(jax.random.key(11), 3)
    Sc = jax.random.normal(ks[0], (s_c, m))
    A_L = jax.random.normal(ks[1], (m, L))
    Q, _ = jnp.linalg.qr(jax.random.normal(ks[2], (s_c, c)))
    _, r2_ref, en_ref = panel_score_ref(Sc, A_L, Q)
    scale = float(jnp.max(en_ref))
    for bm, bl in [(128, 128), (256, 128), (512, 256)]:
        _, r2, en = panel_score(Sc, A_L, Q, block_m=bm, block_l=bl, interpret=True)
        np.testing.assert_allclose(r2, r2_ref, rtol=0, atol=2e-4 * scale)
        np.testing.assert_allclose(en, en_ref, rtol=0, atol=2e-4 * scale)
