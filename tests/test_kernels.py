"""Per-kernel allclose sweeps: Pallas (interpret) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import countsketch_apply, countsketch_ref, twoside_sketch, twoside_sketch_ref

TWOSIDE_SHAPES = [
    (64, 300, 200, 64),  # unaligned m/n → padding path
    (128, 512, 512, 96),
    (32, 130, 260, 48),
    (256, 1024, 384, 128),
    (128, 256, 256, 128),  # exactly aligned
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", TWOSIDE_SHAPES)
def test_twoside_sketch_allclose(shape, dtype):
    s_c, m, n, s_r = shape
    ks = jax.random.split(jax.random.key(sum(shape)), 3)
    Sc = jax.random.normal(ks[0], (s_c, m), jnp.float32).astype(dtype)
    A = jax.random.normal(ks[1], (m, n), jnp.float32).astype(dtype)
    SrT = jax.random.normal(ks[2], (n, s_r), jnp.float32).astype(dtype)
    out = twoside_sketch(Sc, A, SrT, interpret=True)
    ref = twoside_sketch_ref(Sc, A, SrT)
    tol = 1e-5 if dtype == jnp.float32 else 2.5e-2
    rel = float(jnp.max(jnp.abs(out - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < tol, (shape, dtype, rel)


CS_SHAPES = [(64, 300, 200), (100, 512, 384), (200, 1000, 130), (128, 256, 256)]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", CS_SHAPES)
def test_countsketch_allclose(shape, dtype):
    s, m, n = shape
    ks = jax.random.split(jax.random.key(sum(shape)), 3)
    h = jax.random.randint(ks[0], (m,), 0, s)
    sg = jax.random.rademacher(ks[1], (m,), jnp.float32)
    A = jax.random.normal(ks[2], (m, n), jnp.float32).astype(dtype)
    out = countsketch_apply(h, sg, A, s, interpret=True)
    ref = countsketch_ref(h, sg, A, s)
    tol = 1e-5 if dtype == jnp.float32 else 2.5e-2
    rel = float(jnp.max(jnp.abs(out - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < tol, (shape, dtype, rel)


def test_countsketch_padding_no_bucket_pollution():
    """Padded rows must not contribute to any bucket (zero signs)."""
    s, m, n = 64, 100, 50  # m=100 pads to 256
    ks = jax.random.split(jax.random.key(0), 3)
    h = jax.random.randint(ks[0], (m,), 0, s)
    sg = jax.random.rademacher(ks[1], (m,), jnp.float32)
    A = jnp.ones((m, n))
    out = countsketch_apply(h, sg, A, s, interpret=True)
    ref = countsketch_ref(h, sg, A, s)
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_twoside_block_shape_sweep():
    """Same result across BlockSpec tilings (grid decomposition invariance)."""
    s_c, m, n, s_r = 128, 512, 512, 128
    ks = jax.random.split(jax.random.key(1), 3)
    Sc = jax.random.normal(ks[0], (s_c, m))
    A = jax.random.normal(ks[1], (m, n))
    SrT = jax.random.normal(ks[2], (n, s_r))
    ref = twoside_sketch_ref(Sc, A, SrT)
    scale = float(jnp.max(jnp.abs(ref)))
    for bm, bn in [(128, 128), (256, 256), (512, 128)]:
        out = twoside_sketch(Sc, A, SrT, block_m=bm, block_n=bn, interpret=True)
        # different tilings reorder the fp32 reduction; tolerance scales with |M|
        np.testing.assert_allclose(out, ref, rtol=0, atol=2e-4 * scale)
