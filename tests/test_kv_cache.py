"""Decode-native compressed KV cache: engine parity, refresh, attention."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.svd import spsvd_engine_finalize
from repro.models.attention import decode_attention
from repro.serve import KVCompressionConfig, compression_error, LowRankKV
from repro.serve.kv_cache import _convert_one, _head_keys, cache_nbytes, init_compressed_kv
from repro.serve.kv_compress import _engine_init
from repro.stream.engine import panel_update


def _lowrank_heads(key, B, KV, S, d, r):
    ka, kb = jax.random.split(key)
    coef = jax.random.normal(ka, (B, KV, S, r))
    basis = jax.random.normal(kb, (B, KV, r, d))
    return jnp.einsum("bksr,bkrd->bksd", coef, basis)  # (B, KV, S, d)


def _decode_stream(cache, k_seq, v_seq, q_seq, start):
    """Drive append_attend over k_seq/v_seq (B, T, KV, hd); returns outputs."""
    step = jax.jit(lambda c, q, k, v, ln: c.append_attend(q, k, v, ln))
    outs = []
    for t in range(k_seq.shape[1]):
        ln = jnp.asarray(start + t, jnp.int32)
        o, cache = step(cache, q_seq[:, t][:, None], k_seq[:, t][:, None], v_seq[:, t][:, None], ln)
        outs.append(o)
    return jnp.concatenate(outs, axis=1), cache


def test_append_engine_state_matches_manual_stream():
    """Strict parity: the cache's fold path produces the same per-head engine
    accumulators as manually panel-updating a reference engine built from
    the documented key derivation."""
    B, KV, hd, n_max = 1, 2, 16, 64
    kc = KVCompressionConfig(rank=4, oversample=2, panel=16, decode_panel=4, refresh_every=8)
    hist = _lowrank_heads(jax.random.key(0), B, KV, n_max, hd, 3)
    k_dense = hist.transpose(0, 2, 1, 3)
    v_dense = k_dense[..., ::-1]
    prompt = 24
    key = jax.random.key(42)
    cache = _convert_one(key, k_dense, v_dense, prompt_len=prompt, kc=kc)

    # reference: same key derivation, stream prompt then the decode panels
    ref_keys = _head_keys(jax.random.fold_in(key, 0), B, KV)
    ref = jax.vmap(jax.vmap(lambda k: _engine_init(k, hd, n_max, kc)))(ref_keys)
    upd = jax.vmap(jax.vmap(panel_update))
    hists = k_dense.transpose(0, 2, 3, 1).astype(jnp.float32)  # (B,KV,hd,n_max)
    ref = upd(ref, hists[..., :16])
    ref = upd(ref, hists[..., 16:prompt])
    np.testing.assert_allclose(np.asarray(cache.k_eng.C), np.asarray(ref.C), atol=1e-4)
    np.testing.assert_allclose(np.asarray(cache.k_eng.M), np.asarray(ref.M), atol=1e-4)
    np.testing.assert_allclose(np.asarray(cache.k_eng.R), np.asarray(ref.R), atol=1e-4)

    # decode two panels (8 tokens) → one fold boundary + refresh at 32
    T = 8
    k_seq = k_dense[:, prompt : prompt + T]
    v_seq = v_dense[:, prompt : prompt + T]
    q_seq = jax.random.normal(jax.random.key(3), (B, T, KV * 2, hd))
    _, cache = _decode_stream(cache, k_seq, v_seq, q_seq, prompt)
    assert int(cache.eng_len) == prompt + T
    assert int(cache.fac_len) == prompt + T  # refresh fired at 24+8

    for lo in range(prompt, prompt + T, kc.decode_panel):
        ref = upd(ref, hists[..., lo : lo + kc.decode_panel])
    np.testing.assert_allclose(np.asarray(cache.k_eng.C), np.asarray(ref.C), atol=1e-4)
    np.testing.assert_allclose(np.asarray(cache.k_eng.M), np.asarray(ref.M), atol=1e-4)
    np.testing.assert_allclose(np.asarray(cache.k_eng.R), np.asarray(ref.R), atol=1e-4)


def test_incremental_refresh_matches_recompress_from_scratch():
    """After a refresh, the incrementally maintained factors reconstruct the
    full prefix as well as a from-scratch single-shot compression (same
    sketches → same accumulators up to fp summation order)."""
    B, KV, hd, n_max = 1, 2, 16, 96
    kc = KVCompressionConfig(rank=6, oversample=2, panel=32, decode_panel=4, refresh_every=16)
    hist = _lowrank_heads(jax.random.key(1), B, KV, n_max, hd, 4)
    k_dense = hist.transpose(0, 2, 1, 3)
    v_dense = k_dense
    prompt, T = 32, 16
    key = jax.random.key(7)
    cache = _convert_one(key, k_dense, v_dense, prompt_len=prompt, kc=kc)
    q_seq = jax.random.normal(jax.random.key(4), (B, T, KV * 2, hd))
    _, cache = _decode_stream(
        cache, k_dense[:, prompt : prompt + T], v_dense[:, prompt : prompt + T], q_seq, prompt
    )
    covered = prompt + T
    assert int(cache.fac_len) == covered  # refresh at 48

    # from-scratch: stream tokens [0, covered) through a fresh engine with
    # the SAME key derivation → factors must agree to fp tolerance
    scratch = _convert_one(key, k_dense, v_dense, prompt_len=covered, kc=kc)
    fw = cache.k_fac.sigma.shape[-1]
    np.testing.assert_allclose(
        np.asarray(cache.k_fac.sigma), np.asarray(scratch.k_fac.sigma), rtol=1e-3, atol=1e-4
    )
    # compare reconstructions (factor signs/rotations can differ)
    def rec(fac):
        return jnp.einsum(
            "bksr,bkr,bkdr->bksd", fac.v_s[:, :, :covered], fac.sigma, fac.u
        )
    np.testing.assert_allclose(
        np.asarray(rec(cache.k_fac)), np.asarray(rec(scratch.k_fac)), atol=1e-3
    )
    # and both reconstruct the true low-rank history
    err = jnp.linalg.norm(rec(cache.k_fac) - hist[:, :, :covered]) / jnp.linalg.norm(
        hist[:, :, :covered]
    )
    assert float(err) < 0.05, float(err)


def test_append_attend_matches_dense_attention():
    """On low-rank history the compressed cache's joint factor+recent
    attention tracks exact dense decode attention through folds/refreshes."""
    B, KV, G, hd, n_max = 2, 2, 2, 16, 96
    H = KV * G
    kc = KVCompressionConfig(rank=6, oversample=2, panel=32, decode_panel=4, refresh_every=8)
    k_hist = _lowrank_heads(jax.random.key(5), B, KV, n_max, hd, 4)
    v_hist = _lowrank_heads(jax.random.key(6), B, KV, n_max, hd, 4)
    k_dense = k_hist.transpose(0, 2, 1, 3)
    v_dense = v_hist.transpose(0, 2, 1, 3)
    prompt, T = 37, 30
    cache = _convert_one(jax.random.key(8), k_dense, v_dense, prompt_len=prompt, kc=kc)

    dk = k_dense.at[:, prompt:].set(0.0)
    dv = v_dense.at[:, prompt:].set(0.0)
    step = jax.jit(lambda c, q, k, v, ln: c.append_attend(q, k, v, ln))
    for t in range(T):
        ln = jnp.asarray(prompt + t, jnp.int32)
        q = jax.random.normal(jax.random.fold_in(jax.random.key(9), t), (B, 1, H, hd))
        kn, vn = k_dense[:, prompt + t][:, None], v_dense[:, prompt + t][:, None]
        dk = jax.lax.dynamic_update_slice_in_dim(dk, kn, prompt + t, axis=1)
        dv = jax.lax.dynamic_update_slice_in_dim(dv, vn, prompt + t, axis=1)
        o_ref = decode_attention(q, dk, dv, ln + 1)
        o, cache = step(cache, q, kn, vn, ln)
        cos = jnp.sum(o * o_ref) / (jnp.linalg.norm(o) * jnp.linalg.norm(o_ref))
        assert float(cos) > 0.999, (t, float(cos))


def test_init_compressed_kv_empty_then_stream():
    """A fresh cache (no prefix) decodes from token 0: factors stay inert
    until the first refresh, the recent window carries everything."""
    B, KV, G, hd = 1, 2, 2, 16
    H = KV * G
    kc = KVCompressionConfig(rank=4, oversample=2, panel=16, decode_panel=2, refresh_every=4)
    cache = init_compressed_kv(
        jax.random.key(0), kc, batch=B, n_kv_heads=KV, head_dim=hd, n_max=32
    )
    assert int(cache.fac_len) == 0 and int(cache.eng_len) == 0
    k_hist = _lowrank_heads(jax.random.key(2), B, KV, 12, hd, 3).transpose(0, 2, 1, 3)
    q_seq = jax.random.normal(jax.random.key(3), (B, 12, H, hd))
    outs, cache = _decode_stream(cache, k_hist, k_hist, q_seq, 0)
    assert outs.shape == (B, 12, H, hd)
    assert int(cache.eng_len) == 12
    assert int(cache.fac_len) == 12  # refreshes every 4 tokens
    assert np.isfinite(np.asarray(outs)).all()


def test_cache_nbytes_counts_engine_state():
    """Honest accounting: the engine carry (C/R/M + sketches) is included,
    and the total is itemsize-aware."""
    kc = KVCompressionConfig(rank=4, oversample=2, panel=16, decode_panel=4, refresh_every=8)
    cache = init_compressed_kv(
        jax.random.key(0), kc, batch=1, n_kv_heads=2, head_dim=16, n_max=64
    )
    total = cache_nbytes(cache)
    eng = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache.k_eng))
    fac = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache.k_fac))
    assert total > 2 * eng and total > 2 * fac  # both halves counted
    assert cache_nbytes({"k": jnp.zeros((4, 4), jnp.bfloat16)}) == 32


def test_config_validation():
    with pytest.raises(ValueError, match="multiple"):
        KVCompressionConfig(decode_panel=3, refresh_every=8)
    with pytest.raises(ValueError, match="floor"):
        KVCompressionConfig(rank=4, adaptive=True, min_rank=8)
