"""Resilient streaming ingestion (repro/stream/resilient.py): kill-and-resume
bitwise parity, fault injection, quarantine semantics, per-worker sharded
resume."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cur.streaming import streaming_cur_init
from repro.data.synthetic import powerlaw_matrix
from repro.obs import EVENT_QUARANTINED, MetricsRegistry, set_registry, telemetry_summary
from repro.spsd.streaming import streaming_spsd_init
from repro.stream import (
    ArrayPanelSource,
    FaultInjector,
    FaultPlan,
    InjectedCrash,
    QuarantineAbort,
    adaptive_cur_init,
    run_resilient_sharded_stream,
    run_resilient_stream,
    simulate_sharded_stream,
    stream_panels,
    with_quarantine,
    zero_nonfinite_panels,
)

M, N, PANEL = 96, 144, 16  # 9 whole panels
NUM_PANELS = N // PANEL


@pytest.fixture(scope="module")
def A():
    return powerlaw_matrix(jax.random.key(0), M, N, 1.0)


@pytest.fixture(scope="module")
def K():
    G = powerlaw_matrix(jax.random.key(8), N, 32, 1.0)
    return G @ G.T + 0.01 * jnp.eye(N)


COL = jnp.asarray([3, 40, 99, 120, 7, 31], jnp.int32)
ROW = jnp.asarray([5, 17, 40, 77, 90, 60], jnp.int32)


def _fixed_init():
    return streaming_cur_init(
        jax.random.key(1), M, N, COL, ROW, panel=PANEL, telemetry=True
    )


def _adaptive_init():
    return adaptive_cur_init(
        jax.random.key(5), M, N, 8, ROW[:4], panel=PANEL, panel_cap=2, telemetry=True
    )


def _spsd_init():
    return streaming_spsd_init(
        jax.random.key(9), N, COL[:4], s=48, panel=PANEL, telemetry=True
    )


CONFIGS = {
    "fixed_cur": (_fixed_init, "A"),
    "adaptive_cur": (_adaptive_init, "A"),
    "spsd": (_spsd_init, "K"),
}

FACTORS = ("C", "R", "M")
TEL_INT = ("admitted", "evicted", "rows_admitted", "occupancy", "events", "panels_seen")


def _operand(name, A, K):
    return A if name == "A" else K


def _assert_states_equal(a, b, *, psi=True):
    for f in FACTORS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)), err_msg=f
        )
    for leaf in TEL_INT:
        np.testing.assert_array_equal(
            np.asarray(getattr(a.tel, leaf)), np.asarray(getattr(b.tel, leaf)),
            err_msg=leaf,
        )
    if psi:
        np.testing.assert_array_equal(np.asarray(a.tel.psi), np.asarray(b.tel.psi))


# ---------------------------------------------------------------------------
# kill-and-resume bitwise parity (the acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("config", list(CONFIGS))
@pytest.mark.parametrize("crash_panel", [2, 5, NUM_PANELS - 1], ids=["first", "middle", "last"])
def test_kill_and_resume_bitwise_parity(config, crash_panel, A, K, tmp_path):
    """A stream interrupted by an injected crash, resumed from the latest
    checkpoint in a *separate invocation*, is bitwise-identical — factors and
    telemetry counters — to the uninterrupted run at the same chunk cadence.
    Crash placement selects which checkpoint (first / middle / last) the
    resume replays from."""
    init, operand = CONFIGS[config]
    Aop = _operand(operand, A, K)
    src = ArrayPanelSource(Aop, PANEL)
    ref, ref_rep = run_resilient_stream(init(), src, chunk_panels=2)
    assert ref_rep.panels_consumed == NUM_PANELS

    inj = FaultInjector(src, FaultPlan(crash_at_panel=crash_panel))
    d = str(tmp_path / config)
    with pytest.raises(InjectedCrash):
        run_resilient_stream(init(), inj, chunk_panels=2, ckpt_dir=d, ckpt_every=1)
    st, rep = run_resilient_stream(init(), inj, chunk_panels=2, ckpt_dir=d, ckpt_every=1)
    # the resume replayed only unconsumed panels, from the newest checkpoint
    # strictly before the crash point
    assert rep.resumed_from == (crash_panel // 2) * 2
    assert rep.panels_consumed == NUM_PANELS
    _assert_states_equal(ref, st)


@pytest.mark.parametrize("config", list(CONFIGS))
def test_in_process_restart_parity(config, A, K, tmp_path):
    """Same parity with the restart handled inside one invocation
    (``max_restarts``) instead of across invocations."""
    init, operand = CONFIGS[config]
    src = ArrayPanelSource(_operand(operand, A, K), PANEL)
    ref, _ = run_resilient_stream(init(), src, chunk_panels=3)
    inj = FaultInjector(src, FaultPlan(crash_at_panel=7))
    st, rep = run_resilient_stream(
        init(), inj, chunk_panels=3, ckpt_dir=str(tmp_path), ckpt_every=1, max_restarts=1
    )
    assert rep.restarts == 1
    _assert_states_equal(ref, st)


def test_resume_false_ignores_stale_checkpoints(A, tmp_path):
    """resume=False treats the directory as write-only: a second drive into
    a directory holding the first drive's final checkpoint replays the whole
    stream (instead of restoring-and-no-oping) and still matches the clean
    run bitwise. In-process restarts only roll back to this drive's saves."""
    src = ArrayPanelSource(A, PANEL)
    ref, _ = run_resilient_stream(_fixed_init(), src, chunk_panels=2)
    d = str(tmp_path)
    _, rep1 = run_resilient_stream(
        _fixed_init(), src, chunk_panels=2, ckpt_dir=d, ckpt_every=1
    )
    assert rep1.resumed_from is None and rep1.panels_consumed == NUM_PANELS
    st, rep2 = run_resilient_stream(
        _fixed_init(), src, chunk_panels=2, ckpt_dir=d, ckpt_every=1, resume=False
    )
    assert rep2.resumed_from is None  # did not resume the stale final ckpt
    assert rep2.panels_consumed == NUM_PANELS
    _assert_states_equal(ref, st)

    # in-process restart under resume=False still works off this drive's saves
    inj = FaultInjector(src, FaultPlan(crash_at_panel=6))
    st2, rep3 = run_resilient_stream(
        _fixed_init(), inj, chunk_panels=2, ckpt_dir=d, ckpt_every=1,
        resume=False, max_restarts=1,
    )
    assert rep3.restarts == 1
    _assert_states_equal(ref, st2)


def test_crash_without_checkpoint_restarts_from_scratch(A):
    """No ckpt_dir: an in-process restart replays the whole stream from the
    pristine initial state — still bitwise-equal (donation never corrupted
    the template)."""
    src = ArrayPanelSource(A, PANEL)
    ref, _ = run_resilient_stream(_fixed_init(), src, chunk_panels=2)
    inj = FaultInjector(src, FaultPlan(crash_at_panel=6))
    st, rep = run_resilient_stream(_fixed_init(), inj, chunk_panels=2, max_restarts=1)
    assert rep.restarts == 1
    _assert_states_equal(ref, st)


def test_resumed_factors_match_per_panel_driver(A, tmp_path):
    """Cross-driver check: the resumed scan-path factors equal the whole
    stream driven per panel (C/R/M are cadence- and driver-independent;
    Ψ association is chunk-cadence-dependent, so it is excluded here)."""
    src = ArrayPanelSource(A, PANEL)
    inj = FaultInjector(src, FaultPlan(crash_at_panel=5))
    d = str(tmp_path)
    with pytest.raises(InjectedCrash):
        run_resilient_stream(_fixed_init(), inj, chunk_panels=2, ckpt_dir=d, ckpt_every=1)
    st, _ = run_resilient_stream(_fixed_init(), inj, chunk_panels=2, ckpt_dir=d, ckpt_every=1)
    whole = stream_panels(_fixed_init(), A, PANEL, jit="per-panel")
    for f in FACTORS:
        np.testing.assert_array_equal(
            np.asarray(getattr(st, f)), np.asarray(getattr(whole, f)), err_msg=f
        )
    for leaf in TEL_INT:
        np.testing.assert_array_equal(
            np.asarray(getattr(st.tel, leaf)), np.asarray(getattr(whole.tel, leaf))
        )


def test_restored_state_is_fresh_buffer(A, tmp_path):
    """Donation contract: a checkpoint restores into fresh buffers, so the
    same checkpoint can be restored and streamed twice with identical
    results (a donated restore would invalidate the second run's input)."""
    from repro.stream import restore_stream_state, save_stream_state

    src = ArrayPanelSource(A, PANEL)
    st, _ = run_resilient_stream(_fixed_init(), src, chunk_panels=2, stop_panel=4)
    save_stream_state(str(tmp_path), st, 4)
    out = []
    for _ in range(2):
        restored, cursor, _ = restore_stream_state(str(tmp_path), _fixed_init())
        assert cursor == 4
        done, _ = run_resilient_stream(restored, src, chunk_panels=2, start_panel=cursor)
        out.append(done)
    _assert_states_equal(out[0], out[1])


# ---------------------------------------------------------------------------
# fault injection: drops, duplicates, stragglers
# ---------------------------------------------------------------------------


def test_drop_duplicate_straggler_do_not_diverge(A):
    src = ArrayPanelSource(A, PANEL)
    ref, _ = run_resilient_stream(_fixed_init(), src, chunk_panels=3)
    inj = FaultInjector(
        src,
        FaultPlan(
            drop_panels=(2,), duplicate_panels=(5,),
            straggler_panels=(4,), straggler_delay_s=0.001,
        ),
    )
    st, rep = run_resilient_stream(_fixed_init(), inj, chunk_panels=3)
    assert rep.retries >= 2  # one drop re-read + one stale-tag re-request
    _assert_states_equal(ref, st)


def test_drop_exhausts_retries(A):
    class AlwaysDrop(ArrayPanelSource):
        def read_chunk(self, lo, num):
            from repro.stream import TransientReadError

            raise TransientReadError("flaky source")

    with pytest.raises(Exception, match="flaky|retries"):
        run_resilient_stream(
            _fixed_init(), AlwaysDrop(A, PANEL), chunk_panels=2, max_retries=2
        )


# ---------------------------------------------------------------------------
# graceful degradation: quarantine + strict mode
# ---------------------------------------------------------------------------


def test_quarantine_equals_zeroed_panel(A):
    """The defined semantics: a quarantined panel contributes exactly what an
    all-zero panel would — C/R/M, telemetry counters and Ψ all match the
    clean run on the zeroed operand bitwise."""
    bad_panels = (3, 6)
    A_zero = A
    for t in bad_panels:
        A_zero = A_zero.at[:, t * PANEL : (t + 1) * PANEL].set(0.0)
    ref, _ = run_resilient_stream(_fixed_init(), ArrayPanelSource(A_zero, PANEL), chunk_panels=2)
    inj = FaultInjector(ArrayPanelSource(A, PANEL), FaultPlan(corrupt_panels=bad_panels))
    st, rep = run_resilient_stream(_fixed_init(), inj, chunk_panels=2, quarantine=True)
    assert rep.quarantined == len(bad_panels)
    assert int(st.quarantined) == len(bad_panels)
    for f in FACTORS:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, f)), np.asarray(getattr(st, f)), err_msg=f
        )
    np.testing.assert_array_equal(np.asarray(ref.tel.psi), np.asarray(st.tel.psi))
    # EVENT_QUARANTINED flags exactly the corrupted panels
    events = np.asarray(st.tel.events)
    flagged = set(np.nonzero(events & EVENT_QUARANTINED)[0].tolist())
    assert flagged == set(bad_panels)
    summ = telemetry_summary(st)
    for t in bad_panels:
        assert "quarantined" in summ["events"][t]


def test_quarantine_metrics_counters(A):
    reg = MetricsRegistry(enabled=True)
    set_registry(reg)
    try:
        inj = FaultInjector(ArrayPanelSource(A, PANEL), FaultPlan(corrupt_panels=(4,)))
        run_resilient_stream(_fixed_init(), inj, chunk_panels=2, quarantine=True)
        assert reg.counters.get("stream/resilient/quarantined") == 1
        inj2 = FaultInjector(
            ArrayPanelSource(A, PANEL), FaultPlan(crash_at_panel=5, drop_panels=(2,))
        )
        run_resilient_stream(_fixed_init(), inj2, chunk_panels=2, max_restarts=1)
        assert reg.counters.get("stream/resilient/restarts") == 1
        assert reg.counters.get("stream/resilient/retries") == 1
    finally:
        set_registry(MetricsRegistry(enabled=False))


def test_strict_mode_aborts_to_last_checkpoint(A, tmp_path):
    inj = FaultInjector(ArrayPanelSource(A, PANEL), FaultPlan(corrupt_panels=(5,)))
    with pytest.raises(QuarantineAbort) as exc:
        run_resilient_stream(
            _fixed_init(), inj, chunk_panels=1, ckpt_dir=str(tmp_path),
            ckpt_every=1, strict=True,
        )
    e = exc.value
    # rolled back to the checkpoint at panel 5 — the corrupt panel unconsumed
    assert e.panels_consumed == 5
    assert int(e.state.offset) == 5 * PANEL
    assert int(e.state.quarantined) == 0
    # the rolled-back state is live: repair the source and finish the stream
    clean = ArrayPanelSource(A, PANEL)
    done, _ = run_resilient_stream(
        e.state, clean, chunk_panels=1, start_panel=e.panels_consumed
    )
    ref, _ = run_resilient_stream(_fixed_init(), clean, chunk_panels=1, quarantine=True)
    _assert_states_equal(ref, done)


def test_zero_nonfinite_panels_masks_only_bad_panels(A):
    blk = A[:, : 4 * PANEL]
    bad = blk.at[:, PANEL + 3].set(jnp.inf)
    out = zero_nonfinite_panels(bad, PANEL)
    np.testing.assert_array_equal(np.asarray(out[:, :PANEL]), np.asarray(blk[:, :PANEL]))
    np.testing.assert_array_equal(
        np.asarray(out[:, PANEL : 2 * PANEL]), np.zeros((M, PANEL), np.float32)
    )
    np.testing.assert_array_equal(
        np.asarray(out[:, 2 * PANEL :]), np.asarray(blk[:, 2 * PANEL :])
    )


def test_quarantine_off_state_unarmed(A):
    st = stream_panels(_fixed_init(), A, PANEL)
    assert st.quarantined is None
    armed = with_quarantine(_fixed_init())
    assert int(armed.quarantined) == 0
    assert with_quarantine(armed) is armed  # idempotent


# ---------------------------------------------------------------------------
# distributed resume: per-worker checkpoints
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("num_workers", [2, 4])
@pytest.mark.parametrize("config", list(CONFIGS))
def test_sharded_worker_crash_resume_parity(config, num_workers, A, K, tmp_path):
    """A single worker crash, resumed from that worker's own checkpoint
    directory and re-merged, is bitwise-identical to the all-healthy sharded
    run — which itself matches the per-worker simulate oracle."""
    init, operand = CONFIGS[config]
    Aop = _operand(operand, A, K)
    src = ArrayPanelSource(Aop, PANEL)
    healthy, _ = run_resilient_sharded_stream(init(), src, num_workers, chunk_panels=2)
    oracle = simulate_sharded_stream(init(), Aop, PANEL, num_workers, jit="per-panel")
    for f in FACTORS:
        np.testing.assert_array_equal(
            np.asarray(getattr(healthy, f)), np.asarray(getattr(oracle, f)), err_msg=f
        )

    # crash inside some worker's range; one-shot, so the second invocation
    # resumes that worker from its checkpoint and replays nothing elsewhere
    d = str(tmp_path / f"{config}_{num_workers}")
    inj = FaultInjector(src, FaultPlan(crash_at_panel=NUM_PANELS // 2))
    with pytest.raises(InjectedCrash):
        run_resilient_sharded_stream(
            init(), inj, num_workers, ckpt_dir=d, chunk_panels=2, ckpt_every=1
        )
    st, reps = run_resilient_sharded_stream(
        init(), inj, num_workers, ckpt_dir=d, chunk_panels=2, ckpt_every=1
    )
    assert any(r.resumed_from is not None for r in reps)
    _assert_states_equal(healthy, st)


def test_sharded_needs_fresh_state(A):
    st, _ = run_resilient_stream(
        _fixed_init(), ArrayPanelSource(A, PANEL), chunk_panels=2, stop_panel=2
    )
    with pytest.raises(ValueError, match="fresh state"):
        run_resilient_sharded_stream(st, ArrayPanelSource(A, PANEL), 2)


# ---------------------------------------------------------------------------
# multi-device mesh path (subprocess, forced host devices)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_multidev_resilient_parity():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    script = os.path.join(os.path.dirname(__file__), "multidev_scenario.py")
    proc = subprocess.run(
        [sys.executable, script, "resilient"],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert proc.returncode == 0, f"\nSTDOUT:{proc.stdout[-2000:]}\nSTDERR:{proc.stderr[-3000:]}"
    assert "OK scenario_resilient_worker_crash" in proc.stdout
