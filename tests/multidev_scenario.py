"""Multi-device scenarios, re-executed in a subprocess with 8 host devices
(so the main pytest session keeps the default single device).

Run directly:  XLA_FLAGS=--xla_force_host_platform_device_count=8 python tests/multidev_scenario.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ARCHS
from repro.data import DataConfig, SyntheticLM
from repro.distributed.sharding import (
    ParallelismRules,
    activation_sharding,
    batch_pspec,
    leaf_pspec,
    param_shardings,
)
from repro.models import init_params
from repro.train import (
    CompressionConfig,
    OptimizerConfig,
    init_opt_state,
    make_compressed_train_step,
    make_train_step,
)


def tiny_cfg():
    cfg = ARCHS["llama3.2-1b"].smoke_config()
    return dataclasses.replace(
        cfg, d_model=128, d_ff=512, n_heads=8, n_kv_heads=4, head_dim=16, vocab_size=512
    )


def scenario_sharded_equals_single():
    """Sharded (4×2 mesh) train step == single-device step bit-for-bit-ish."""
    cfg = tiny_cfg()
    oc = OptimizerConfig(lr=1e-2, clip_norm=None)
    params = init_params(jax.random.key(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 64), 0, cfg.vocab_size)}

    # single device
    st1 = {"params": jax.tree.map(jnp.copy, params), "opt": init_opt_state(params, oc)}
    st1, m1 = make_train_step(cfg, oc, remat=None)(st1, batch)

    # sharded
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = ParallelismRules(dp_axes=("data",))
    pshard = param_shardings(params, rules, mesh)
    st2 = {"params": jax.device_put(params, pshard), "opt": init_opt_state(params, oc)}
    b2 = jax.device_put(batch, {"tokens": NamedSharding(mesh, batch_pspec(rules))})
    step = make_train_step(cfg, oc, remat=None)

    def traced(state, batch):
        with activation_sharding(mesh, rules):
            return step(state, batch)

    st2, m2 = jax.jit(traced)(st2, b2)

    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4, (m1["loss"], m2["loss"])
    for a, b in zip(jax.tree.leaves(st1["params"]), jax.tree.leaves(st2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(jax.device_get(b)), atol=3e-3)
    print("OK scenario_sharded_equals_single")


def scenario_compressed_step_converges():
    cfg = tiny_cfg()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = ParallelismRules(dp_axes=("data",))
    oc = OptimizerConfig(lr=1e-2, warmup_steps=2, total_steps=30)
    ccfg = CompressionConfig(rank=16, sketch_factor=4, min_dim=128)
    params = jax.device_put(init_params(jax.random.key(0), cfg), param_shardings(init_params(jax.random.key(0), cfg), rules, mesh))
    cstep, init_err = make_compressed_train_step(cfg, oc, ccfg, mesh, rules, remat=None)
    state = {"params": params, "opt": init_opt_state(params, oc), "err": init_err(params)}
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, batch=16, seq_len=64))
    bshard = {"tokens": NamedSharding(mesh, batch_pspec(rules))}
    losses = []
    for i in range(25):
        state, m = cstep(state, jax.device_put(data.batch_at(i), bshard), jax.random.fold_in(jax.random.key(9), i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])
    print(f"OK scenario_compressed_step_converges {losses[0]:.3f}->{losses[-1]:.3f}")


def scenario_compressed_reduces_wire_bytes():
    """HLO census: the compressed step moves fewer all-reduce bytes than the
    plain step — the paper's technique visible in the compiled collectives."""
    from repro.launch.hlo_census import census

    cfg = dataclasses.replace(tiny_cfg(), d_model=512, d_ff=2048, vocab_size=512)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = ParallelismRules(dp_axes=("data",))
    oc = OptimizerConfig(lr=1e-2)
    params = init_params(jax.random.key(0), cfg)
    pshard = param_shardings(params, rules, mesh)
    state = {"params": jax.device_put(params, pshard), "opt": init_opt_state(params, oc)}
    batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32, sharding=NamedSharding(mesh, batch_pspec(rules)))}

    step = make_train_step(cfg, oc, remat=None)

    def traced(state, b):
        with activation_sharding(mesh, rules):
            return step(state, b)

    c_plain = census(jax.jit(traced).lower(state, batch).compile().as_text())

    ccfg = CompressionConfig(rank=8, sketch_factor=2, min_dim=512)
    cstep, init_err = make_compressed_train_step(cfg, oc, ccfg, mesh, rules, remat=None)
    state2 = {**state, "err": init_err(params)}
    c_comp = census(jax.jit(cstep).lower(state2, batch, jax.random.key(1)).compile().as_text())

    ar_plain = c_plain["collectives"].get("all-reduce", {}).get("wire_bytes", 0)
    ar_comp = c_comp["collectives"].get("all-reduce", {}).get("wire_bytes", 0)
    assert ar_comp < ar_plain, (ar_comp, ar_plain)
    print(
        f"OK scenario_compressed_reduces_wire_bytes plain={ar_plain/1e6:.1f}MB "
        f"compressed={ar_comp/1e6:.1f}MB ({ar_plain/max(ar_comp,1):.1f}x less)"
    )


def scenario_stream_sharded_equals_single():
    """mesh_sharded_stream (shard_map over data=4) == single-host panel
    streaming for both SP-SVD and streaming CUR, and adaptive-CUR admission
    runs under shard_map (per-worker slot ranges) producing a finite, valid
    factorization."""
    import numpy as np
    from jax.sharding import Mesh

    from repro.core.svd import sp_svd_finalize, sp_svd_init
    from repro.cur.streaming import streaming_cur_finalize, streaming_cur_init
    from repro.data.synthetic import powerlaw_matrix
    from repro.stream import (
        adaptive_cur_finalize,
        adaptive_cur_init,
        mesh_sharded_stream,
        stream_panels,
    )

    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
    m, n, panel = 200, 256, 32
    A = powerlaw_matrix(jax.random.key(0), m, n, 1.0)
    sizes = dict(c=20, r=20, c0=60, r0=60, s_c=60, s_r=60)

    # SP-SVD parity
    single = stream_panels(sp_svd_init(jax.random.key(1), m, n, sizes=sizes, panel=panel), A, panel)
    shard = mesh_sharded_stream(
        sp_svd_init(jax.random.key(1), m, n, sizes=sizes, panel=panel), A, panel, mesh
    )
    np.testing.assert_allclose(np.asarray(shard.M), np.asarray(single.M), atol=2e-3)
    np.testing.assert_allclose(np.asarray(shard.C), np.asarray(single.C), atol=2e-3)
    np.testing.assert_allclose(np.asarray(shard.R), np.asarray(single.R), atol=2e-3)
    U1, S1, V1 = sp_svd_finalize(single)
    U2, S2, V2 = sp_svd_finalize(shard)
    np.testing.assert_allclose(
        np.asarray((U1 * S1[None]) @ V1.T), np.asarray((U2 * S2[None]) @ V2.T), atol=5e-3
    )

    # streaming-CUR parity
    ci = jnp.asarray([3, 50, 99, 120, 200, 7, 31, 88], jnp.int32)
    ri = jnp.asarray([5, 17, 40, 77, 90, 120, 150, 199], jnp.int32)

    def cinit():
        return streaming_cur_init(jax.random.key(2), m, n, ci, ri, sketch="countsketch", panel=panel)

    res1 = streaming_cur_finalize(stream_panels(cinit(), A, panel))
    res2 = streaming_cur_finalize(mesh_sharded_stream(cinit(), A, panel, mesh))
    np.testing.assert_array_equal(np.asarray(res1.C), np.asarray(res2.C))
    np.testing.assert_allclose(np.asarray(res1.U), np.asarray(res2.U), atol=2e-3)

    # adaptive admission under shard_map: finds the planted spikes
    B = 0.05 * powerlaw_matrix(jax.random.key(3), m, n, 1.5)
    pos = jnp.asarray([17, 77, 130, 222])
    B = B.at[:, pos].add(6.0 * jax.random.normal(jax.random.key(4), (m, 4)))
    st = adaptive_cur_init(
        jax.random.key(5), m, n, 8, ri, sketch="countsketch", panel=panel, panel_cap=2
    )
    res = adaptive_cur_finalize(mesh_sharded_stream(st, B, panel, mesh))
    admitted = set(np.asarray(res.col_idx).tolist())
    missed = set(np.asarray(pos).tolist()) - admitted
    assert len(missed) <= 1, (sorted(admitted), np.asarray(pos).tolist())
    recon = np.asarray(res.C) @ np.asarray(res.U) @ np.asarray(res.R)
    rel = np.linalg.norm(np.asarray(B) - recon) / np.linalg.norm(np.asarray(B))
    assert np.isfinite(rel) and rel < 0.5, rel

    # v2 parity (acceptance): eviction + adaptive row admission under
    # shard_map at 2 and 4 workers — disjoint per-worker slot ranges psum
    # into a valid, finite factorization that still captures the spikes
    from repro.data.synthetic import spiked_rows_matrix

    D, rpos = spiked_rows_matrix(jax.random.key(6), m, n)
    for W in (2, 4):
        mesh_w = Mesh(np.array(jax.devices()[:W]), ("data",))
        st2 = adaptive_cur_init(
            jax.random.key(7), m, n, 8, None, r=8, sketch="countsketch",
            panel=panel, panel_cap=1, panel_cap_rows=1, swap_gain=2.0,
        )
        res2 = adaptive_cur_finalize(mesh_sharded_stream(st2, D, panel, mesh_w))
        recon2 = np.asarray(res2.C) @ np.asarray(res2.U) @ np.asarray(res2.R)
        rel2 = np.linalg.norm(np.asarray(D) - recon2) / np.linalg.norm(np.asarray(D))
        assert np.isfinite(rel2) and rel2 < 1.0, (W, rel2)
        admitted_r = set(np.asarray(res2.row_idx).tolist())
        missed_r = set(np.asarray(rpos).tolist()) - admitted_r
        assert len(missed_r) <= 2, (W, sorted(admitted_r), np.asarray(rpos).tolist())
        ci = np.asarray(res2.col_idx)
        filled = ci[ci >= 0]
        assert len(np.unique(filled)) == len(filled), (W, ci)
        ri2 = np.asarray(res2.row_idx)
        filled_r = ri2[ri2 >= 0]  # cross-worker row dedup holds under psum too
        assert len(np.unique(filled_r)) == len(filled_r), (W, ri2)

    # symmetric (tied-operand) streaming SPSD: mesh psum == single-host,
    # with the (0, n_pad) R placeholder riding the shard_map untouched
    from repro.spsd import streaming_spsd_finalize, streaming_spsd_init

    nk = 256
    G = powerlaw_matrix(jax.random.key(8), nk, 48, 1.0)
    K = G @ G.T + 0.01 * jnp.eye(nk)
    ki = jnp.asarray([3, 40, 99, 120, 200, 7, 31, 88], jnp.int32)

    def kinit():
        return streaming_spsd_init(jax.random.key(9), nk, ki, s=64, panel=panel)

    ks = streaming_spsd_finalize(stream_panels(kinit(), K, panel))
    km = streaming_spsd_finalize(mesh_sharded_stream(kinit(), K, panel, mesh))
    np.testing.assert_array_equal(np.asarray(ks.C), np.asarray(km.C))
    np.testing.assert_allclose(np.asarray(km.X), np.asarray(ks.X), atol=2e-3)
    print("OK scenario_stream_sharded_equals_single")


def scenario_telemetry_mesh_merge():
    """Telemetry frames psum-merged under real shard_map (2 and 4 devices)
    equal the single-host frame: integer diagnostics bitwise, float running
    sums to fp32 summation order, and Ψ = A·Ω_test stays exact. Factors are
    bit-identical with telemetry on or off on the mesh path too."""
    from jax.sharding import Mesh

    from repro.cur.streaming import streaming_cur_init
    from repro.data.synthetic import spiked_decay_matrix
    from repro.stream import adaptive_cur_init, mesh_sharded_stream, stream_panels

    m, n, panel = 200, 256, 32
    A, _pos = spiked_decay_matrix(jax.random.key(30), m, n)
    ci = jnp.asarray([3, 50, 99, 120, 200, 7, 31, 88], jnp.int32)
    ri = jnp.asarray([5, 17, 40, 77, 90, 120, 150, 199], jnp.int32)

    def finit(telemetry=True):
        return streaming_cur_init(
            jax.random.key(31), m, n, ci, ri, sketch="countsketch", panel=panel,
            telemetry=telemetry,
        )

    single = stream_panels(finit(), A, panel)
    int_leaves = ("admitted", "evicted", "rows_admitted", "occupancy", "events", "panels_seen")
    float_leaves = ("panel_scores", "panel_energy", "energy_mass", "psi")
    for W in (2, 4):
        mesh_w = Mesh(np.array(jax.devices()[:W]), ("data",))
        shard = mesh_sharded_stream(finit(), A, panel, mesh_w)
        for leaf in int_leaves:
            np.testing.assert_array_equal(
                np.asarray(getattr(shard.tel, leaf)),
                np.asarray(getattr(single.tel, leaf)),
                err_msg=f"W={W} {leaf}",
            )
        for leaf in float_leaves:
            np.testing.assert_allclose(
                np.asarray(getattr(shard.tel, leaf)),
                np.asarray(getattr(single.tel, leaf)),
                rtol=1e-4, atol=1e-4, err_msg=f"W={W} {leaf}",
            )
        np.testing.assert_allclose(
            np.asarray(shard.tel.psi), np.asarray(A @ shard.tel.omega[:n]),
            rtol=1e-4, atol=1e-3,
        )
        # telemetry never perturbs the mesh-path factors
        plain = mesh_sharded_stream(finit(telemetry=False), A, panel, mesh_w)
        np.testing.assert_array_equal(np.asarray(plain.C), np.asarray(shard.C))
        np.testing.assert_array_equal(np.asarray(plain.M), np.asarray(shard.M))

    # adaptive policy: per-worker slot ranges — merged admission totals must
    # account for every filled slot, and the audit summary stays consistent
    from repro.obs import telemetry_summary

    for W in (2, 4):
        mesh_w = Mesh(np.array(jax.devices()[:W]), ("data",))
        st = adaptive_cur_init(
            jax.random.key(32), m, n, 8, ri, sketch="countsketch", panel=panel,
            panel_cap=2, swap_gain=2.0, telemetry=True,
        )
        st = mesh_sharded_stream(st, A, panel, mesh_w)
        s = telemetry_summary(st)
        assert s["total_admitted"] == int(st.ctx.n_filled), (W, s["total_admitted"])
        assert s["panels_seen"] == n // panel, (W, s["panels_seen"])
    print("OK scenario_telemetry_mesh_merge")


def scenario_resilient_worker_crash():
    """Per-worker checkpointed resume re-merges to the real shard_map path:
    a single worker crash, restored from that worker's checkpoint directory,
    matches the all-healthy ``mesh_sharded_stream`` run at 2 and 4 workers —
    disjoint-write leaves (C, R, integer telemetry) bitwise, running float
    sums (M, Ψ) to psum summation order."""
    import tempfile

    from jax.sharding import Mesh

    from repro.cur.streaming import streaming_cur_init
    from repro.data.synthetic import powerlaw_matrix
    from repro.stream import (
        ArrayPanelSource,
        FaultInjector,
        FaultPlan,
        InjectedCrash,
        mesh_sharded_stream,
        run_resilient_sharded_stream,
    )

    m, n, panel = 200, 256, 32
    A = powerlaw_matrix(jax.random.key(0), m, n, 1.0)
    ci = jnp.asarray([3, 50, 99, 120, 200, 7, 31, 88], jnp.int32)
    ri = jnp.asarray([5, 17, 40, 77, 90, 120, 150, 199], jnp.int32)

    def finit():
        return streaming_cur_init(
            jax.random.key(31), m, n, ci, ri, panel=panel, telemetry=True
        )

    src = ArrayPanelSource(A, panel)
    for W in (2, 4):
        mesh_w = Mesh(np.array(jax.devices()[:W]), ("data",))
        healthy = mesh_sharded_stream(finit(), A, panel, mesh_w)
        with tempfile.TemporaryDirectory() as d:
            inj = FaultInjector(src, FaultPlan(crash_at_panel=(n // panel) // 2))
            try:
                run_resilient_sharded_stream(
                    finit(), inj, W, ckpt_dir=d, chunk_panels=2, ckpt_every=1
                )
                raise AssertionError("injected crash did not fire")
            except InjectedCrash:
                pass
            st, reps = run_resilient_sharded_stream(
                finit(), inj, W, ckpt_dir=d, chunk_panels=2, ckpt_every=1
            )
        assert any(r.resumed_from is not None for r in reps), reps
        np.testing.assert_array_equal(np.asarray(st.C), np.asarray(healthy.C))
        np.testing.assert_array_equal(np.asarray(st.R), np.asarray(healthy.R))
        np.testing.assert_allclose(
            np.asarray(st.M), np.asarray(healthy.M), rtol=1e-5, atol=1e-5
        )
        for leaf in ("admitted", "evicted", "occupancy", "events", "panels_seen"):
            np.testing.assert_array_equal(
                np.asarray(getattr(st.tel, leaf)),
                np.asarray(getattr(healthy.tel, leaf)),
                err_msg=f"W={W} {leaf}",
            )
        np.testing.assert_allclose(
            np.asarray(st.tel.psi), np.asarray(healthy.tel.psi), rtol=1e-5, atol=1e-5
        )
    print("OK scenario_resilient_worker_crash")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    fns = {
        "sharded": scenario_sharded_equals_single,
        "compressed": scenario_compressed_step_converges,
        "wire": scenario_compressed_reduces_wire_bytes,
        "stream": scenario_stream_sharded_equals_single,
        "telemetry": scenario_telemetry_mesh_merge,
        "resilient": scenario_resilient_worker_crash,
    }
    if which == "all":
        for fn in fns.values():
            fn()
    else:
        fns[which]()
    print("MULTIDEV SCENARIOS PASSED")
