"""REQUIRED per-arch smoke tests: reduced same-family configs, one forward
/ train step on CPU, asserting output shapes + no NaNs; plus prefill/decode
consistency and the family-specific numerics (SSD scan, flash attention)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_arch
from repro.models import (
    decode_step,
    init_cache,
    init_params,
    param_count,
    prefill,
    segments,
    train_logits,
)
from repro.models.attention import decode_attention, flash_attention
from repro.models.modality import synth_patch_embeddings
from repro.models.ssm import init_mamba2, init_mamba2_state, mamba2_decode, mamba2_forward, ssd_chunked

ARCH_IDS = list(ARCHS)

# Heavy smoke archs (tens of seconds each on CPU) run in the `slow` lane;
# the default tier-1 lane keeps the cheapest attention arch as the canary.
_SLOW_ARCHS = {
    "zamba2-1.2b", "mamba2-1.3b", "kimi-k2-1t-a32b", "phi4-mini-3.8b",
    "deepseek-v2-lite-16b", "gemma3-12b", "llama-3.2-vision-90b",
}
assert _SLOW_ARCHS <= set(ARCH_IDS), _SLOW_ARCHS - set(ARCH_IDS)  # catch arch renames
SMOKE_ARCH_IDS = [
    pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS else a for a in ARCH_IDS
]


@pytest.mark.parametrize("arch_id", SMOKE_ARCH_IDS)
def test_smoke_forward_and_decode(arch_id):
    cfg = ARCHS[arch_id].smoke_config()
    params = init_params(jax.random.key(1), cfg)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    vision = synth_patch_embeddings(jax.random.key(3), cfg, B) if cfg.d_vision else None

    logits, aux = train_logits(params, cfg, toks, vision, dense_moe=True)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch_id}: NaN in logits"
    assert bool(jnp.isfinite(aux))

    lg, cache = prefill(params, cfg, toks, cache_len=S + 4, vision=vision, dense_moe=True)
    np.testing.assert_allclose(lg[:, 0], logits[:, -1], atol=1e-4)

    lg2, cache = decode_step(params, cfg, cache, jnp.argmax(lg, -1).astype(jnp.int32), dense_moe=True)
    assert lg2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(lg2)))


@pytest.mark.parametrize("arch_id", SMOKE_ARCH_IDS)
def test_smoke_train_step(arch_id):
    """One gradient step: finite loss + grads with the right structure."""
    from repro.train import OptimizerConfig, init_opt_state, make_train_step

    cfg = ARCHS[arch_id].smoke_config()
    oc = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    params = init_params(jax.random.key(1), cfg)
    state = {"params": params, "opt": init_opt_state(params, oc)}
    step = make_train_step(cfg, oc, remat=None, dense_moe=True)
    batch = {"tokens": jax.random.randint(jax.random.key(4), (2, 16), 0, cfg.vocab_size)}
    if cfg.d_vision:
        batch["vision"] = synth_patch_embeddings(jax.random.key(5), cfg, 2)
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), (arch_id, metrics)
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_matches_assignment(arch_id):
    """Exact assignment numbers in every full config (no allocation)."""
    spec = {
        "mamba2-1.3b": dict(n_layers=48, d_model=2048, vocab_size=50280, ssm_state=128),
        "zamba2-1.2b": dict(n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=32000, ssm_state=64),
        "kimi-k2-1t-a32b": dict(n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff_expert=2048, vocab_size=163840, n_experts=384, moe_top_k=8),
        "deepseek-v2-lite-16b": dict(n_layers=27, d_model=2048, n_heads=16, d_ff_expert=1408, vocab_size=102400, n_experts=64, moe_top_k=6, kv_lora_rank=512, n_shared_experts=2),
        "llama3.2-1b": dict(n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192, vocab_size=128256),
        "phi4-mini-3.8b": dict(n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=8192, vocab_size=200064),
        "gemma3-12b": dict(n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, d_ff=15360, vocab_size=262144),
        "mistral-nemo-12b": dict(n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=131072),
        "musicgen-large": dict(n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=2048),
        "llama-3.2-vision-90b": dict(n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672, vocab_size=128256),
    }[arch_id]
    cfg = ARCHS[arch_id].full_config()
    for k, v in spec.items():
        assert getattr(cfg, k) == v, (arch_id, k, getattr(cfg, k), v)


def test_gemma3_pattern_is_5to1():
    cfg = ARCHS["gemma3-12b"].full_config()
    kinds = [b.mixer for b in cfg.pattern]
    assert kinds.count("attn") == 8 and kinds.count("attn_local") == 40
    for i in range(5, 48, 6):
        assert kinds[i] == "attn"


def test_zamba2_shared_blocks():
    cfg = ARCHS["zamba2-1.2b"].full_config()
    shared = [i for i, b in enumerate(cfg.pattern) if b.mixer == "shared_attn"]
    assert len(shared) == 6
    params = init_params(jax.random.key(0), ARCHS["zamba2-1.2b"].smoke_config())
    assert "shared" in params  # single weight collection for all occurrences


def test_segment_compilation():
    """compile_pattern factors every arch into few scan segments."""
    for arch_id, mod in ARCHS.items():
        segs = segments(mod.full_config())
        n = sum(len(s.unit) * s.n_repeat for s in segs)
        assert n == mod.full_config().n_layers, arch_id
        assert len(segs) <= 3, (arch_id, len(segs))


# ---- family numerics ----


def _ref_attn(q, k, v, window=None):
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bphd->bhqp", q, kk).astype(jnp.float32) / np.sqrt(D)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = kp <= qp
    if window is not None:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None, None], s, -1e30)
    return jnp.einsum("bhqp,bphd->bqhd", jax.nn.softmax(s, -1), vv).astype(q.dtype)


@pytest.mark.parametrize("case", [(2, 128, 4, 2, 16, None, 32), (1, 200, 8, 8, 8, None, 64),
                                  (2, 256, 4, 1, 16, 48, 32), (1, 96, 2, 2, 8, 20, 32)])
def test_flash_attention_matches_naive(case):
    B, S, H, KV, D, window, chunk = case
    ks = jax.random.split(jax.random.key(sum(x or 0 for x in case)), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    np.testing.assert_allclose(
        flash_attention(q, k, v, window=window, chunk=chunk), _ref_attn(q, k, v, window), atol=2e-5
    )
    g1 = jax.grad(lambda q: jnp.sum(flash_attention(q, k, v, window=window, chunk=chunk) ** 2))(q)
    g2 = jax.grad(lambda q: jnp.sum(_ref_attn(q, k, v, window) ** 2))(q)
    np.testing.assert_allclose(g1, g2, atol=5e-4)


def test_ssd_chunked_matches_sequential():
    B, S, H, P, G, N = 2, 100, 4, 8, 2, 16
    ks = jax.random.split(jax.random.key(0), 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.3
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3

    St = jnp.zeros((B, H, N, P))
    ys = []
    for t in range(S):
        dcy = jnp.exp(dt[:, t] * A[None])
        Bt = jnp.repeat(Bm[:, t], H // G, axis=1)
        Ct = jnp.repeat(Cm[:, t], H // G, axis=1)
        St = St * dcy[..., None, None] + jnp.einsum("bhn,bhd->bhnd", Bt, xh[:, t] * dt[:, t][..., None])
        ys.append(jnp.einsum("bhn,bhnd->bhd", Ct, St))
    y_ref = jnp.stack(ys, axis=1)
    y, S_final = ssd_chunked(xh, dt, A, Bm, Cm, chunk=32)
    np.testing.assert_allclose(y, y_ref, atol=2e-5)
    np.testing.assert_allclose(S_final, St, atol=2e-5)


def test_mamba2_forward_decode_consistency():
    from repro.models.config import MAMBA2, NONE, BlockSpec, ModelConfig

    cfg = ModelConfig(name="t", family="ssm", n_layers=1, d_model=32, n_heads=0, n_kv_heads=0,
                      d_ff=0, vocab_size=64, pattern=(BlockSpec(MAMBA2, NONE),),
                      ssm_state=16, ssm_heads=4, ssm_head_dim=16, ssm_groups=2, ssm_chunk=16,
                      dtype="float32")
    p = init_mamba2(jax.random.key(1), cfg)
    x = jax.random.normal(jax.random.key(2), (2, 24, 32))
    y_full, (cx, cbc, st) = mamba2_forward(p, x, cfg)
    cx2, cbc2, st2 = init_mamba2_state(cfg, 2)
    outs = []
    for t in range(24):
        o, (cx2, cbc2, st2) = mamba2_decode(p, x[:, t : t + 1], cfg, cx2, cbc2, st2)
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), y_full, atol=2e-5)
    np.testing.assert_allclose(st2, st, atol=2e-5)
