"""§4 SPSD approximation: Algorithm 2 vs baselines (Theorem 3 claims)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import clustered_points, tune_rbf_sigma
from repro.core import (
    fast_spsd_wang,
    faster_spsd,
    nystrom,
    optimal_core,
    rbf_kernel_oracle,
    spsd_error_ratio,
)


@pytest.fixture(scope="module")
def kernel_setup():
    n, d, k = 600, 24, 15
    X = clustered_points(jax.random.key(0), n, d, n_clusters=10, spread=0.6)
    sigma = tune_rbf_sigma(X, k=k, target_eta=0.75)
    oracle = rbf_kernel_oracle(X, sigma)
    return n, oracle, oracle(None, None)


def _mean_err(fn, K, trials=3):
    return float(np.mean([float(spsd_error_ratio(K, fn(jax.random.key(31 * t)))) for t in range(trials)]))


@pytest.mark.slow
def test_alg2_close_to_optimal_at_s10c(kernel_setup):
    """§6.2: faster-SPSD ≈ optimal once s = 10c."""
    n, oracle, K = kernel_setup
    c = 30
    ours = _mean_err(lambda k: faster_spsd(k, oracle, n, c, 10 * c), K)
    opt = _mean_err(lambda k: optimal_core(k, oracle, n, c), K)
    assert ours < opt * 1.10, (ours, opt)


def test_alg2_beats_wang16_at_small_s(kernel_setup):
    """Table 7 pattern: fast-SPSD (Wang'16b) much worse at small s."""
    n, oracle, K = kernel_setup
    c, s = 30, 8 * 30
    ours = _mean_err(lambda k: faster_spsd(k, oracle, n, c, s), K)
    wang = _mean_err(lambda k: fast_spsd_wang(k, oracle, n, c, s), K)
    assert ours < wang, (ours, wang)


def test_alg2_beats_nystrom(kernel_setup):
    n, oracle, K = kernel_setup
    c = 30
    ours = _mean_err(lambda k: faster_spsd(k, oracle, n, c, 10 * c), K, trials=4)
    nys = _mean_err(lambda k: nystrom(k, oracle, n, c), K, trials=4)
    assert ours <= nys * 1.02, (ours, nys)


def test_core_is_psd(kernel_setup):
    n, oracle, K = kernel_setup
    res = faster_spsd(jax.random.key(5), oracle, n, 30, 200)
    ev = jnp.linalg.eigvalsh(0.5 * (res.X + res.X.T))
    assert float(ev.min()) > -1e-4


def test_entry_observation_accounting(kernel_setup):
    """Theorem 3 / Table 4: exact entry counts for all four batch paths."""
    n, oracle, K = kernel_setup
    c, s = 30, 150
    assert faster_spsd(jax.random.key(6), oracle, n, c, s).entries_observed == n * c + s * s
    assert nystrom(jax.random.key(7), oracle, n, c).entries_observed == n * c
    assert fast_spsd_wang(jax.random.key(8), oracle, n, c, s).entries_observed == n * c + s * s
    assert optimal_core(jax.random.key(9), oracle, n, c).entries_observed == n * n


# ---------------------------------------------------------------------------
# input validation + edge cases (rank-deficient kernels, duplicate samples)
# ---------------------------------------------------------------------------


def test_sample_size_validation(kernel_setup):
    """c > n (or c ≤ 0, s ≤ 0) must fail with a clear ValueError, not the
    opaque shape error jax.random.choice(replace=False) raises."""
    n, oracle, _ = kernel_setup
    for fn in (
        lambda: nystrom(jax.random.key(0), oracle, n, n + 1),
        lambda: optimal_core(jax.random.key(0), oracle, n, 0),
        lambda: fast_spsd_wang(jax.random.key(0), oracle, n, n + 5, 100),
        lambda: faster_spsd(jax.random.key(0), oracle, n, -1, 100),
    ):
        with pytest.raises(ValueError, match="0 < c <= n"):
            fn()
    for fn in (
        lambda: fast_spsd_wang(jax.random.key(0), oracle, n, 10, 0),
        lambda: faster_spsd(jax.random.key(0), oracle, n, 10, -3),
    ):
        with pytest.raises(ValueError, match="s > 0"):
            fn()


def test_rank_deficient_kernel_duplicated_points():
    """Duplicated data points make K (and any sampled C) exactly
    rank-deficient; every batch path must stay finite with a sane fit."""
    n, d = 300, 16
    X = clustered_points(jax.random.key(40), n, d, n_clusters=8, spread=0.5)
    X = X.at[50:100].set(X[0])  # 51 identical points
    sigma = tune_rbf_sigma(X, k=10, target_eta=0.75)
    oracle = rbf_kernel_oracle(X, sigma)
    K = oracle(None, None)
    c, s = 24, 120
    for fn in (
        lambda k: nystrom(k, oracle, n, c),
        lambda k: optimal_core(k, oracle, n, c),
        lambda k: fast_spsd_wang(k, oracle, n, c, s),
        lambda k: faster_spsd(k, oracle, n, c, s),
    ):
        res = fn(jax.random.key(41))
        assert bool(jnp.all(jnp.isfinite(res.X))), fn
        err = float(spsd_error_ratio(K, res))
        assert np.isfinite(err) and err < 1.0, (fn, err)


def test_duplicate_leverage_samples_survive(kernel_setup):
    """s ≫ n forces duplicate sampled indices in S₁/S₂ (sampling is with
    replacement) and near-duplicate rows in the sketched operands; the
    floored solves must stay finite and the PSD projection must hold for
    both leverage-sampling paths."""
    n, oracle, K = kernel_setup
    c, s = 30, 2 * n  # pigeonhole: every index set has duplicates
    for fn in (
        lambda k: fast_spsd_wang(k, oracle, n, c, s),
        lambda k: faster_spsd(k, oracle, n, c, s),
    ):
        res = fn(jax.random.key(42))
        assert bool(jnp.all(jnp.isfinite(res.X)))
        ev = jnp.linalg.eigvalsh(0.5 * (res.X + res.X.T))
        assert float(ev.min()) > -1e-4
        assert float(spsd_error_ratio(K, res)) < 1.0
