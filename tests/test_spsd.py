"""§4 SPSD approximation: Algorithm 2 vs baselines (Theorem 3 claims)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.common import clustered_points, tune_rbf_sigma
from repro.core import (
    fast_spsd_wang,
    faster_spsd,
    nystrom,
    optimal_core,
    rbf_kernel_oracle,
    spsd_error_ratio,
)


@pytest.fixture(scope="module")
def kernel_setup():
    n, d, k = 600, 24, 15
    X = clustered_points(jax.random.key(0), n, d, n_clusters=10, spread=0.6)
    sigma = tune_rbf_sigma(X, k=k, target_eta=0.75)
    oracle = rbf_kernel_oracle(X, sigma)
    return n, oracle, oracle(None, None)


def _mean_err(fn, K, trials=3):
    return float(np.mean([float(spsd_error_ratio(K, fn(jax.random.key(31 * t)))) for t in range(trials)]))


@pytest.mark.slow
def test_alg2_close_to_optimal_at_s10c(kernel_setup):
    """§6.2: faster-SPSD ≈ optimal once s = 10c."""
    n, oracle, K = kernel_setup
    c = 30
    ours = _mean_err(lambda k: faster_spsd(k, oracle, n, c, 10 * c), K)
    opt = _mean_err(lambda k: optimal_core(k, oracle, n, c), K)
    assert ours < opt * 1.10, (ours, opt)


def test_alg2_beats_wang16_at_small_s(kernel_setup):
    """Table 7 pattern: fast-SPSD (Wang'16b) much worse at small s."""
    n, oracle, K = kernel_setup
    c, s = 30, 8 * 30
    ours = _mean_err(lambda k: faster_spsd(k, oracle, n, c, s), K)
    wang = _mean_err(lambda k: fast_spsd_wang(k, oracle, n, c, s), K)
    assert ours < wang, (ours, wang)


def test_alg2_beats_nystrom(kernel_setup):
    n, oracle, K = kernel_setup
    c = 30
    ours = _mean_err(lambda k: faster_spsd(k, oracle, n, c, 10 * c), K, trials=4)
    nys = _mean_err(lambda k: nystrom(k, oracle, n, c), K, trials=4)
    assert ours <= nys * 1.02, (ours, nys)


def test_core_is_psd(kernel_setup):
    n, oracle, K = kernel_setup
    res = faster_spsd(jax.random.key(5), oracle, n, 30, 200)
    ev = jnp.linalg.eigvalsh(0.5 * (res.X + res.X.T))
    assert float(ev.min()) > -1e-4


def test_entry_observation_accounting(kernel_setup):
    """Theorem 3: N = nc + s² entries."""
    n, oracle, K = kernel_setup
    c, s = 30, 150
    res = faster_spsd(jax.random.key(6), oracle, n, c, s)
    assert res.entries_observed == n * c + s * s
    res2 = nystrom(jax.random.key(7), oracle, n, c)
    assert res2.entries_observed == n * c
