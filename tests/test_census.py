"""Loop-aware HLO census: the roofline's measurement layer."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_census import _wire_factor, census


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_flops_plain_matmul():
    f = lambda a, b: a @ b
    txt = _compile_text(f, jax.ShapeDtypeStruct((64, 128), jnp.float32), jax.ShapeDtypeStruct((128, 96), jnp.float32))
    c = census(txt)
    assert abs(c["flops"] - 2 * 64 * 128 * 96) / (2 * 64 * 128 * 96) < 1e-6


def test_flops_scan_multiplied():
    def f(w, x):
        def body(x, _):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x, None, length=8)[0]

    txt = _compile_text(f, jax.ShapeDtypeStruct((256, 256), jnp.float32), jax.ShapeDtypeStruct((64, 256), jnp.float32))
    c = census(txt)
    true = 8 * 2 * 64 * 256 * 256
    assert abs(c["flops"] - true) / true < 1e-6
    assert c["while_trip_counts"][0]["trip"] == 8


def test_flops_nested_scan():
    def g(w, x):
        def outer(x, _):
            def inner(x, _):
                return x @ w, None
            return jax.lax.scan(inner, x, None, length=4)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]

    txt = _compile_text(g, jax.ShapeDtypeStruct((128, 128), jnp.float32), jax.ShapeDtypeStruct((32, 128), jnp.float32))
    c = census(txt)
    true = 12 * 2 * 32 * 128 * 128
    assert abs(c["flops"] - true) / true < 1e-6
    assert sorted(t["trip"] for t in c["while_trip_counts"]) == [3, 4]


def test_batched_dot_flops():
    f = lambda a, b: jnp.einsum("bik,bkj->bij", a, b)
    txt = _compile_text(f, jax.ShapeDtypeStruct((4, 128, 64), jnp.float32), jax.ShapeDtypeStruct((4, 64, 96), jnp.float32))
    c = census(txt)
    true = 2 * 4 * 128 * 64 * 96
    assert abs(c["flops"] - true) / true < 1e-6


def test_wire_factors():
    assert _wire_factor("all-reduce", 16) == pytest.approx(2 * 15 / 16)
    assert _wire_factor("all-gather", 16) == pytest.approx(15 / 16)
    assert _wire_factor("reduce-scatter", 16) == 15
    assert _wire_factor("collective-permute", 2) == 1.0


def test_hbm_bytes_reasonable():
    """bytes of a simple matmul ≥ operands + result, ≤ a few passes."""
    m, k, n = 512, 512, 512
    f = lambda a, b: a @ b
    txt = _compile_text(f, jax.ShapeDtypeStruct((m, k), jnp.float32), jax.ShapeDtypeStruct((k, n), jnp.float32))
    c = census(txt)
    lo = 4 * (m * k + k * n + m * n)
    assert lo <= c["hbm_bytes"] <= 4 * lo
