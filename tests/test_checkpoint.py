"""Checkpointing + fault tolerance."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, list_steps, restore, run_resilient_loop, save
from repro.checkpoint.checkpoint import _leaf_name


def _tree(key=0):
    k = jax.random.key(key)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros((16,), jnp.bfloat16)},
        "opt": {"m": jnp.ones((8, 16)), "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 10, t, extra={"data_state": {"step": 10}})
    out, extra, step = restore(str(tmp_path), t)
    assert step == 10 and extra["data_state"]["step"] == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_keep_last_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, t, keep_last=2)
    assert list_steps(str(tmp_path)) == [4, 5]


def test_latest_and_specific_step(tmp_path):
    t = _tree()
    save(str(tmp_path), 1, t, keep_last=10)
    save(str(tmp_path), 2, jax.tree.map(lambda x: x + 1 if x.dtype != jnp.int32 else x, t), keep_last=10)
    assert latest_step(str(tmp_path)) == 2
    out1, _, _ = restore(str(tmp_path), t, step=1)
    out2, _, _ = restore(str(tmp_path), t, step=2)
    assert not np.allclose(np.asarray(out1["params"]["w"]), np.asarray(out2["params"]["w"]))


def test_atomicity_no_tmp_dirs_visible(tmp_path):
    t = _tree()
    save(str(tmp_path), 3, t)
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_async_save(tmp_path):
    t = _tree()
    th = save(str(tmp_path), 4, t, async_=True)
    th.join(timeout=30)
    assert latest_step(str(tmp_path)) == 4


def test_shape_mismatch_raises(tmp_path):
    t = _tree()
    save(str(tmp_path), 1, t)
    bad = jax.tree.map(lambda x: jnp.zeros((3, 3)), t)
    with pytest.raises(ValueError):
        restore(str(tmp_path), bad)


def test_resilient_loop_restart(tmp_path):
    """Crash at step 12 → restore from step-10 checkpoint → finish 20 steps."""
    state = {"x": jnp.zeros(())}

    def step_fn(state, batch, step):
        return {"x": state["x"] + 1.0}, {"loss": state["x"]}

    report = run_resilient_loop(
        state=state, step_fn=step_fn, batch_fn=lambda s: None, n_steps=20,
        ckpt_dir=str(tmp_path), ckpt_every=5, fail_at_step=12,
    )
    assert report.restarts == 1
    assert latest_step(str(tmp_path)) == 20
    final, _, _ = restore(str(tmp_path), state)
    assert float(final["x"]) == 20.0  # replayed 10→20 deterministically


def test_resilient_loop_straggler_detection(tmp_path):
    calls = {"n": 0}

    def step_fn(state, batch, step):
        calls["n"] += 1
        if step == 15:
            time.sleep(1.0)  # injected straggler
        return state, {"loss": jnp.zeros(())}

    report = run_resilient_loop(
        state={"x": jnp.zeros(())}, step_fn=step_fn, batch_fn=lambda s: None,
        n_steps=20, ckpt_dir=str(tmp_path), ckpt_every=50, straggler_factor=3.0,
    )
    assert report.stragglers >= 1


def test_leaf_name_sanitization():
    import jax.tree_util as jtu

    t = {"a b": {"c/d": jnp.zeros(1)}}
    leaves, _ = jtu.tree_flatten_with_path(t)
    name = _leaf_name(leaves[0][0])
    assert "/" not in name and " " not in name
