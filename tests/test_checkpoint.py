"""Checkpointing + fault tolerance."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, list_steps, restore, run_resilient_loop, save
from repro.checkpoint.checkpoint import _leaf_name


def _tree(key=0):
    k = jax.random.key(key)
    return {
        "params": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros((16,), jnp.bfloat16)},
        "opt": {"m": jnp.ones((8, 16)), "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 10, t, extra={"data_state": {"step": 10}})
    out, extra, step = restore(str(tmp_path), t)
    assert step == 10 and extra["data_state"]["step"] == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_keep_last_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, t, keep_last=2)
    assert list_steps(str(tmp_path)) == [4, 5]


def test_latest_and_specific_step(tmp_path):
    t = _tree()
    save(str(tmp_path), 1, t, keep_last=10)
    save(str(tmp_path), 2, jax.tree.map(lambda x: x + 1 if x.dtype != jnp.int32 else x, t), keep_last=10)
    assert latest_step(str(tmp_path)) == 2
    out1, _, _ = restore(str(tmp_path), t, step=1)
    out2, _, _ = restore(str(tmp_path), t, step=2)
    assert not np.allclose(np.asarray(out1["params"]["w"]), np.asarray(out2["params"]["w"]))


def test_atomicity_no_tmp_dirs_visible(tmp_path):
    t = _tree()
    save(str(tmp_path), 3, t)
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_async_save(tmp_path):
    t = _tree()
    th = save(str(tmp_path), 4, t, async_=True)
    th.join(timeout=30)
    assert latest_step(str(tmp_path)) == 4


def test_shape_mismatch_raises(tmp_path):
    t = _tree()
    save(str(tmp_path), 1, t)
    bad = jax.tree.map(lambda x: jnp.zeros((3, 3)), t)
    with pytest.raises(ValueError):
        restore(str(tmp_path), bad)


def test_resilient_loop_restart(tmp_path):
    """Crash at step 12 → restore from step-10 checkpoint → finish 20 steps."""
    state = {"x": jnp.zeros(())}

    def step_fn(state, batch, step):
        return {"x": state["x"] + 1.0}, {"loss": state["x"]}

    report = run_resilient_loop(
        state=state, step_fn=step_fn, batch_fn=lambda s: None, n_steps=20,
        ckpt_dir=str(tmp_path), ckpt_every=5, fail_at_step=12,
    )
    assert report.restarts == 1
    assert latest_step(str(tmp_path)) == 20
    final, _, _ = restore(str(tmp_path), state)
    assert float(final["x"]) == 20.0  # replayed 10→20 deterministically


def test_resilient_loop_straggler_detection(tmp_path):
    calls = {"n": 0}

    def step_fn(state, batch, step):
        calls["n"] += 1
        if step == 15:
            time.sleep(1.0)  # injected straggler
        return state, {"loss": jnp.zeros(())}

    report = run_resilient_loop(
        state={"x": jnp.zeros(())}, step_fn=step_fn, batch_fn=lambda s: None,
        n_steps=20, ckpt_dir=str(tmp_path), ckpt_every=50, straggler_factor=3.0,
    )
    assert report.stragglers >= 1


def test_torn_write_skipped(tmp_path):
    """A checkpoint directory damaged mid-save (truncated leaf, missing leaf,
    garbage manifest) must never brick resume: it is skipped and the newest
    intact step wins."""
    t = _tree()
    save(str(tmp_path), 1, t, keep_last=10)
    save(str(tmp_path), 2, t, keep_last=10)

    # truncate one leaf file of step 2 to zero bytes
    step2 = tmp_path / "step_00000002"
    leaf = next(p for p in step2.iterdir() if p.suffix == ".npy")
    leaf.write_bytes(b"")
    assert list_steps(str(tmp_path)) == [1]
    assert latest_step(str(tmp_path)) == 1
    out, _, step = restore(str(tmp_path), t)  # falls back, no crash
    assert step == 1

    # missing leaf file
    leaf.unlink()
    assert latest_step(str(tmp_path)) == 1

    # garbage manifest
    (step2 / "manifest.json").write_text("{not json")
    assert latest_step(str(tmp_path)) == 1

    # explicitly requesting the torn step raises a clear error
    with pytest.raises(FileNotFoundError, match="torn"):
        restore(str(tmp_path), t, step=2)


def test_no_part_files_after_save(tmp_path):
    save(str(tmp_path), 5, _tree())
    ckpt = tmp_path / "step_00000005"
    assert not any(p.name.endswith(".part") for p in ckpt.iterdir())


def test_all_checkpoints_torn_raises(tmp_path):
    save(str(tmp_path), 1, _tree())
    for p in (tmp_path / "step_00000001").iterdir():
        if p.suffix == ".npy":
            p.write_bytes(b"")
    with pytest.raises(FileNotFoundError, match="no intact"):
        restore(str(tmp_path), _tree())


def test_resilient_loop_preserves_restored_extra(tmp_path):
    """Restart hygiene: the extra metadata restored in the exception path is
    kept — recorded on the report and re-written by subsequent saves."""
    def step_fn(state, batch, step):
        return {"x": state["x"] + 1.0}, {"loss": state["x"]}

    report = run_resilient_loop(
        state={"x": jnp.zeros(())}, step_fn=step_fn, batch_fn=lambda s: None,
        n_steps=20, ckpt_dir=str(tmp_path), ckpt_every=5, fail_at_step=12,
        extra_meta={"run_name": "hygiene"},
    )
    assert report.restarts == 1
    assert report.restored_extra is not None
    assert report.restored_extra["run_name"] == "hygiene"
    _, extra, step = restore(str(tmp_path), {"x": jnp.zeros(())})
    assert step == 20 and extra["run_name"] == "hygiene"


def test_resilient_loop_restart_not_flagged_straggler(tmp_path):
    """Restart hygiene: step times reset after a restore, so the slow first
    post-restart step (recompile stand-in: injected sleep) is not flagged
    against the pre-crash median."""
    calls = {10: 0}

    def step_fn(state, batch, step):
        time.sleep(0.005)  # stable baseline so the median is not timer jitter
        if step == 10:
            calls[10] += 1
            if calls[10] == 2:  # only the replayed execution is slow
                time.sleep(0.3)
        return {"x": state["x"] + 1.0}, {"loss": state["x"]}

    report = run_resilient_loop(
        state={"x": jnp.zeros(())}, step_fn=step_fn, batch_fn=lambda s: None,
        n_steps=20, ckpt_dir=str(tmp_path), ckpt_every=5, fail_at_step=12,
        straggler_factor=3.0,
    )
    assert report.restarts == 1 and calls[10] == 2
    assert report.stragglers == 0


def test_packed_roundtrip(tmp_path):
    """pack=True writes a single step_<N>.ckpt file whose restore is
    bit/dtype-identical (incl. the bfloat16 viewed path) to the tree."""
    t = _tree()
    path = save(str(tmp_path), 10, t, extra={"panels_consumed": 12}, pack=True)
    assert path.endswith("step_00000010.ckpt") and os.path.isfile(path)
    assert not os.path.isdir(tmp_path / "step_00000010")
    out, extra, step = restore(str(tmp_path), t)
    assert step == 10 and extra["panels_consumed"] == 12
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_packed_torn_file_skipped(tmp_path):
    """Truncated or garbage-magic .ckpt files are skipped, newest intact wins."""
    t = _tree()
    save(str(tmp_path), 1, t, keep_last=10, pack=True)
    save(str(tmp_path), 2, t, keep_last=10, pack=True)
    f2 = tmp_path / "step_00000002.ckpt"
    f2.write_bytes(f2.read_bytes()[:-5])  # torn tail: size != header claim
    assert list_steps(str(tmp_path)) == [1]
    out, _, step = restore(str(tmp_path), t)
    assert step == 1
    f2.write_bytes(b"NOTMAGIC" + b"\x00" * 64)
    assert latest_step(str(tmp_path)) == 1


def test_packed_and_dir_layouts_interoperate(tmp_path):
    """list_steps/GC/restore see both layouts in one directory."""
    t = _tree()
    save(str(tmp_path), 1, t, keep_last=10)  # per-leaf dir
    save(str(tmp_path), 2, t, keep_last=10, pack=True)
    assert list_steps(str(tmp_path)) == [1, 2]
    _, _, step = restore(str(tmp_path), t)
    assert step == 2
    for s in (3, 4):
        save(str(tmp_path), s, t, keep_last=2, pack=True)
    assert list_steps(str(tmp_path)) == [3, 4]  # GC evicted both layouts


def test_durable_false_roundtrip(tmp_path):
    """durable=False drops the fsync but the committed file restores fine."""
    t = _tree()
    save(str(tmp_path), 6, t, durable=False, pack=True)
    out, _, step = restore(str(tmp_path), t)
    assert step == 6
    np.testing.assert_array_equal(
        np.asarray(t["params"]["w"]), np.asarray(out["params"]["w"])
    )
    assert not any(p.name.endswith(".part") for p in tmp_path.iterdir())


def test_leaf_name_sanitization():
    import jax.tree_util as jtu

    t = {"a b": {"c/d": jnp.zeros(1)}}
    leaves, _ = jtu.tree_flatten_with_path(t)
    name = _leaf_name(leaves[0][0])
    assert "/" not in name and " " not in name
