"""Sharding rules (single-device) + multi-device scenarios via subprocess."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.distributed.sharding import ParallelismRules, leaf_pspec, shard_act


class FakeMesh:
    """Minimal mesh stand-in for rule unit tests (axis sizes only)."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def _spec(path_names, shape, rules, mesh):
    import jax.tree_util as jtu

    path = tuple(jtu.DictKey(n) for n in path_names)
    # leaf_pspec only reads .ndim/.shape — a ShapeDtypeStruct avoids
    # materializing multi-GB zero buffers for the large-tensor rule cases
    return leaf_pspec(path, jax.ShapeDtypeStruct(shape, jnp.float32), rules, mesh)


MESH = FakeMesh({"data": 16, "model": 16})
RULES = ParallelismRules(dp_axes=("data",))


def test_tp_rules_column_row_parallel():
    assert _spec(("mixer", "w_q"), (2048, 2048), RULES, MESH) == jax.sharding.PartitionSpec(None, "model")
    assert _spec(("mixer", "w_o"), (2048, 2048), RULES, MESH) == jax.sharding.PartitionSpec("model", None)
    assert _spec(("ffn", "w_down"), (8192, 2048), RULES, MESH) == jax.sharding.PartitionSpec("model", None)


def test_divisibility_fallback():
    # vocab 50280 is not divisible by 16 → replicated
    assert _spec(("embed", "tok"), (50280, 2048), RULES, MESH)[0] is None
    assert _spec(("embed", "tok"), (163840, 2048), RULES, MESH)[0] == "model"


def test_moe_expert_sharding():
    spec = _spec(("ffn", "w_up"), (384, 7168, 2048), RULES, MESH)
    assert spec[0] == "model"  # expert-parallel dim


def test_fsdp_adds_data_axis():
    rules = ParallelismRules(dp_axes=("data",), fsdp=True)
    spec = _spec(("mixer", "w_q"), (8192, 8192), rules, MESH)
    assert spec == jax.sharding.PartitionSpec(("data",), "model")


def test_stacked_leading_dims_unsharded():
    spec = _spec(("segments", "w_q"), (16, 2048, 2048), RULES, MESH)
    assert spec[0] is None and spec[2] == "model"


def test_norms_and_scalars():
    assert _spec(("norm1", "scale"), (2048,), RULES, MESH) == jax.sharding.PartitionSpec(None)
    assert _spec(("mixer", "gate"), (), RULES, MESH) == jax.sharding.PartitionSpec()


def test_shard_act_noop_outside_context():
    x = jnp.ones((4, 8, 16))
    assert shard_act(x, "btd") is x


def _run_scenario(name):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    script = os.path.join(os.path.dirname(__file__), "multidev_scenario.py")
    proc = subprocess.run(
        [sys.executable, script, name], capture_output=True, text=True, env=env, timeout=900
    )
    assert proc.returncode == 0, f"\nSTDOUT:{proc.stdout[-2000:]}\nSTDERR:{proc.stderr[-3000:]}"
    assert f"OK scenario" in proc.stdout


@pytest.mark.slow
def test_multidev_sharded_equals_single():
    _run_scenario("sharded")


# The compressed-gradient scenarios run shard_map *partial-auto* (manual dp,
# auto model axis). On jax < 0.6 XLA rejects replicated rank-1 inputs (the
# PRNG key) under partial-auto tile validation — the feature generation this
# code targets simply isn't present; skip rather than exercise known-broken
# partitioner paths.
_PARTIAL_AUTO_OK = hasattr(jax, "shard_map")


@pytest.mark.slow
@pytest.mark.skipif(not _PARTIAL_AUTO_OK, reason="partial-auto shard_map unsupported on this jax")
def test_multidev_compressed_converges():
    _run_scenario("compressed")


@pytest.mark.slow
@pytest.mark.skipif(not _PARTIAL_AUTO_OK, reason="partial-auto shard_map unsupported on this jax")
def test_multidev_compressed_wire_bytes():
    _run_scenario("wire")
