"""Test session config. NOTE: no XLA_FLAGS here by design — unit/smoke
tests run on the single real CPU device; multi-device scenarios re-exec
themselves in a subprocess (tests/multidev_scenario.py).

``hypothesis`` is optional: when it is not installed (bare interpreter,
minimal CI images) we install a deterministic stand-in into ``sys.modules``
*before* test modules import it. The stand-in replays each ``@given`` test
over a small fixed grid of strategy samples — weaker than real
property-based search, but it keeps the full tier-1 suite collecting and
exercising the same assertions everywhere.
"""

import os
import sys
import types

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # for `benchmarks`

try:  # pragma: no cover - trivially absent on bare interpreters
    import hypothesis  # noqa: F401
except ImportError:
    class _Strategy:
        """A fixed, deterministic sample list standing in for a strategy."""

        def __init__(self, samples):
            self.samples = list(samples)

    def _integers(lo, hi):
        span = hi - lo
        return _Strategy([lo, hi, lo + span // 2, lo + span // 3, lo + (2 * span) // 3])

    def _floats(lo, hi):
        span = hi - lo
        return _Strategy([lo, hi, lo + 0.5 * span, lo + 0.25 * span, lo + 0.75 * span])

    def _sampled_from(seq):
        return _Strategy(seq)

    def _given(**strategies):
        names = list(strategies)
        n = max(len(s.samples) for s in strategies.values())

        def deco(fn):
            # NB: no functools.wraps — pytest must see a zero-arg signature,
            # not the original one (whose params would be mistaken for fixtures).
            def wrapper():
                for i in range(n):
                    drawn = {k: strategies[k].samples[i % len(strategies[k].samples)] for k in names}
                    fn(**drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.hypothesis_stub = True
            return wrapper

        return deco

    def _settings(**_kwargs):
        return lambda fn: fn

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.sampled_from = _sampled_from
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__stub__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

import jax

jax.config.update("jax_enable_x64", False)
