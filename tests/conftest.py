"""Test session config. NOTE: no XLA_FLAGS here by design — unit/smoke
tests run on the single real CPU device; multi-device scenarios re-exec
themselves in a subprocess (tests/multidev_scenario.py)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # for `benchmarks`

import jax

jax.config.update("jax_enable_x64", False)
