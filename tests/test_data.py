"""Data pipeline: determinism, restartability, structure."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataConfig, SyntheticLM


def test_deterministic_batches():
    dc = DataConfig(vocab_size=256, batch=4, seq_len=32, seed=7)
    a = SyntheticLM(dc).batch_at(5)["tokens"]
    b = SyntheticLM(dc).batch_at(5)["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_reproducibility():
    """Restarting at step k yields the same stream as never stopping."""
    dc = DataConfig(vocab_size=128, batch=2, seq_len=16, seed=1)
    data = SyntheticLM(dc)
    full = [np.asarray(data.batch_at(i)["tokens"]) for i in range(10)]
    resumed = [np.asarray(SyntheticLM(dc).batch_at(i)["tokens"]) for i in range(5, 10)]
    for a, b in zip(full[5:], resumed):
        np.testing.assert_array_equal(a, b)


def test_distinct_steps_distinct_batches():
    dc = DataConfig(vocab_size=256, batch=4, seq_len=32)
    data = SyntheticLM(dc)
    a, b = data.batch_at(0)["tokens"], data.batch_at(1)["tokens"]
    assert not np.array_equal(np.asarray(a), np.asarray(b))


def test_token_range_and_structure():
    dc = DataConfig(vocab_size=100, batch=8, seq_len=64)
    toks = SyntheticLM(dc).batch_at(3)["tokens"]
    assert toks.shape == (8, 64) and toks.dtype == jnp.int32
    assert int(toks.min()) >= 0 and int(toks.max()) < 100


def test_state_roundtrip():
    dc = DataConfig(vocab_size=100, batch=2, seq_len=8, seed=3)
    st = SyntheticLM(dc).state(42)
    assert st == {"step": 42, "seed": 3}
