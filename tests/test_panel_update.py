"""Fused panel-update megakernel: interpret parity + stream-route parity.

Two layers of evidence:

* kernel vs :func:`repro.kernels.panel_update_ref` (the unfused XLA oracle)
  across ragged tails, tied symmetric operands, empty admission masks and
  bf16 inputs with fp32 accumulation — interpret mode executes the real
  kernel body, so the admission arithmetic (threshold, rank-based slot
  assignment, one-hot C scatter) is checked bit-for-bit against the
  ``top_k``/cumsum path it replaces;
* the engine routes — the fused scan body (``fused=True`` default) and the
  forced kernel route (``_FORCE_KERNEL_ROUTE``) — vs the per-panel oracle
  driver on whole streams, so the megakernel's wiring into
  :mod:`repro.stream.engine` reproduces the committed factors.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import spiked_decay_matrix
from repro.kernels import panel_update, panel_update_ref
from repro.stream.adaptive import adaptive_cur_init
from repro.stream.engine import stream_panels

from test_stream import _assert_states_close


def _inputs(key, s_c, m, L, c, s_r, filled=None, dtype=jnp.float32):
    """Half-filled basis + partially filled C/M, matching mid-stream state."""
    ks = jax.random.split(key, 6)
    filled = max(1, c // 2) if filled is None else filled
    sc = jax.random.normal(ks[0], (s_c, m), jnp.float32).astype(dtype)
    a_l = jax.random.normal(ks[1], (m, L), jnp.float32).astype(dtype)
    srt = jax.random.normal(ks[2], (L, s_r), jnp.float32).astype(dtype)
    Q, _ = jnp.linalg.qr(jax.random.normal(ks[3], (s_c, c), jnp.float32))
    q = Q * (jnp.arange(c) < filled)
    C = jax.random.normal(ks[4], (m, c), jnp.float32) * (jnp.arange(c) < filled)
    M = jax.random.normal(ks[5], (s_c, s_r), jnp.float32)
    kw = dict(min_gain=0.5, run_mean=0.0, true_cols=float(L),
              n_filled=filled, free=c - filled, panel_cap=3)
    return sc, a_l, srt, q, C, M, kw


def _check(out, ref, atol_scale=1e-5):
    for got, want, name in zip(out[:5], ref[:5], ("C", "M", "sc_a", "resid2", "energy")):
        scale = float(jnp.max(jnp.abs(want))) + 1e-30
        np.testing.assert_allclose(got, want, rtol=0, atol=atol_scale * scale,
                                   err_msg=name)
    np.testing.assert_array_equal(out[5], ref[5], err_msg="slots")


PU_SHAPES = [
    (72, 300, 96, 16, 72),  # every dim unaligned → padding path
    (240, 1024, 128, 16, 240),  # the adaptive-CUR acceptance shape
    (64, 256, 40, 8, 48),  # ragged panel, L < LANE
    (128, 512, 256, 32, 128),  # aligned
]


@pytest.mark.parametrize("shape", PU_SHAPES)
def test_panel_update_allclose(shape):
    s_c, m, L, c, s_r = shape
    args = _inputs(jax.random.key(sum(shape)), *shape)
    sc, a_l, srt, q, C, M, kw = args
    out = panel_update(sc, a_l, srt, q, C, M, interpret=True, **kw)
    ref = panel_update_ref(sc, a_l, srt, q, C, M, **kw)
    _check(out, ref)
    # admitted count within both budgets
    admitted = int(jnp.sum(out[5] < c))
    assert admitted <= min(kw["panel_cap"], kw["free"])


def test_panel_update_empty_admission_mask():
    """Nothing eligible (huge min_gain): C must pass through untouched,
    every slot the sentinel — but M still folds the panel's sketch."""
    s_c, m, L, c, s_r = 64, 256, 40, 8, 64
    sc, a_l, srt, q, C, M, kw = _inputs(jax.random.key(5), s_c, m, L, c, s_r)
    kw["min_gain"] = 1e9
    out = panel_update(sc, a_l, srt, q, C, M, interpret=True, **kw)
    ref = panel_update_ref(sc, a_l, srt, q, C, M, **kw)
    _check(out, ref)
    np.testing.assert_array_equal(out[0], C)
    np.testing.assert_array_equal(out[5], jnp.full((L,), c, jnp.int32))
    assert float(jnp.max(jnp.abs(out[1] - M))) > 0.0  # M fold still happened


def test_panel_update_budget_exhausted():
    """``free == 0``: eligible columns exist but none may be admitted."""
    s_c, m, L, c, s_r = 64, 256, 64, 8, 64
    sc, a_l, srt, q, C, M, kw = _inputs(jax.random.key(6), s_c, m, L, c, s_r,
                                        filled=c)
    assert kw["free"] == 0
    out = panel_update(sc, a_l, srt, q, C, M, interpret=True, **kw)
    ref = panel_update_ref(sc, a_l, srt, q, C, M, **kw)
    _check(out, ref)
    np.testing.assert_array_equal(out[5], jnp.full((L,), c, jnp.int32))


def test_panel_update_symmetric_tied_operands():
    """SPSD-symmetric mode: one sketch on both sides (``S_C == S_R``), the
    ``srt`` window a transposed slice of the same ``sc`` buffer."""
    s_c, m, L, c = 64, 256, 64, 8
    off = 96
    sc, a_l, _, q, C, M, kw = _inputs(jax.random.key(7), s_c, m, L, c, s_c)
    srt = jax.lax.dynamic_slice_in_dim(sc, off, L, axis=1).T  # tied operand
    out = panel_update(sc, a_l, srt, q, C, M, interpret=True, **kw)
    ref = panel_update_ref(sc, a_l, srt, q, C, M, **kw)
    _check(out, ref)


def test_panel_update_bf16_inputs_fp32_accum():
    """bf16 panel/sketch inputs: the kernel must accumulate in fp32 —
    outputs land in fp32 and match the fp32-accumulating oracle to bf16
    input precision (not bf16 accumulation precision, which would drift
    far beyond 3e-2 at m=1024)."""
    s_c, m, L, c, s_r = 72, 1024, 96, 16, 72
    sc, a_l, srt, q, C, M, kw = _inputs(jax.random.key(8), s_c, m, L, c, s_r,
                                        dtype=jnp.bfloat16)
    out = panel_update(sc, a_l, srt, q, C, M, interpret=True, **kw)
    ref = panel_update_ref(sc, a_l, srt, q, C, M, **kw)
    assert out[2].dtype == jnp.float32  # sc_a
    assert out[3].dtype == jnp.float32  # resid2
    _check(out, ref, atol_scale=3e-2)


# ---------------------------------------------------------------------------
# engine routes: fused scan body + forced kernel route vs the per-panel oracle
# ---------------------------------------------------------------------------


def test_fused_scan_flag_parity():
    """``fused=False`` (legacy per-panel scan body) and ``fused=True`` (the
    chunk-hoisted fused body) must produce identical factors and identical
    admission decisions on an adaptive stream."""
    m, n, panel = 200, 250, 40
    B, _ = spiked_decay_matrix(jax.random.key(30), m, n)

    def init():
        return adaptive_cur_init(
            jax.random.key(31), m, n, 10, jnp.arange(12, dtype=jnp.int32),
            sketch="countsketch", panel=panel, panel_cap=2,
        )

    legacy = stream_panels(init(), B, panel, jit="scan", fused=False)
    fused = stream_panels(init(), B, panel, jit="scan", fused=True)
    _assert_states_close(fused, legacy)
    np.testing.assert_array_equal(fused.ctx.col_idx, legacy.ctx.col_idx)
    np.testing.assert_allclose(fused.ctx.ScC, legacy.ctx.ScC, atol=2e-5)


def test_evict_stream_stays_on_oracle_body():
    """Eviction-enabled adaptive CUR (no adaptive rows) declines the fused
    body via ``supports_fused`` — the scan route must still match the
    per-panel driver decision-for-decision."""
    m, n, panel = 200, 200, 40
    B, _ = spiked_decay_matrix(jax.random.key(40), m, n)

    def init():
        return adaptive_cur_init(
            jax.random.key(41), m, n, 8, jnp.arange(8, dtype=jnp.int32),
            sketch="countsketch", panel=panel, panel_cap=2, swap_gain=2.0,
        )

    ref = stream_panels(init(), B, panel, jit="per-panel")
    got = stream_panels(init(), B, panel, jit="scan", fused=True)
    _assert_states_close(got, ref)
    np.testing.assert_array_equal(got.ctx.col_idx, ref.ctx.col_idx)
    assert int(got.ctx.n_evicted) == int(ref.ctx.n_evicted)


@pytest.mark.parametrize("jit", ["per-panel", "scan"])
def test_forced_kernel_route_end_to_end(jit):
    """Route B: with ``_FORCE_KERNEL_ROUTE`` the engine sends every panel of
    a gaussian-sketch admission-only stream through the Pallas megakernel
    (interpret mode on CPU). Factors and admissions must match the normal
    XLA path on the whole stream."""
    from repro.kernels import ops as kops

    m, n, panel = 256, 160, 32
    B, _ = spiked_decay_matrix(jax.random.key(50), m, n)

    def init():
        return adaptive_cur_init(
            jax.random.key(51), m, n, 8, jnp.arange(8, dtype=jnp.int32),
            s_c=64, s_r=64, sketch="gaussian", panel=panel, panel_cap=2,
        )

    ref = stream_panels(init(), B, panel, jit=jit)
    assert not kops.kernel_route_enabled()  # CPU: kernel off by default
    kops._FORCE_KERNEL_ROUTE = True
    try:
        got = stream_panels(init(), B, panel, jit=jit)
    finally:
        kops._FORCE_KERNEL_ROUTE = False
    _assert_states_close(got, ref, atol=2e-5)
    np.testing.assert_array_equal(got.ctx.col_idx, ref.ctx.col_idx)
    np.testing.assert_allclose(got.ctx.ScC, ref.ctx.ScC, atol=2e-4)
    np.testing.assert_allclose(got.ctx.slot_score, ref.ctx.slot_score, atol=2e-4)
    assert int(got.ctx.n_filled) == int(ref.ctx.n_filled)
