"""Fast GMR (Algorithm 1, Theorem 1) — core correctness + properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import error_ratio, exact_gmr, fast_gmr, fast_gmr_core, rho, sketched_fro_norm
from repro.core.gmr import _solve_least_squares


def _problem(key, m=300, n=250, c=12, r=12, decay=1.0):
    ks = jax.random.split(key, 3)
    rank = min(m, n)
    U, _ = jnp.linalg.qr(jax.random.normal(ks[0], (m, rank)))
    V, _ = jnp.linalg.qr(jax.random.normal(ks[1], (n, rank)))
    sv = jnp.arange(1, rank + 1, dtype=jnp.float32) ** -decay
    A = (U * sv[None]) @ V.T
    GC = jax.random.normal(jax.random.fold_in(key, 5), (n, c))
    GR = jax.random.normal(jax.random.fold_in(key, 6), (r, m))
    return A, A @ GC, GR @ A


def test_exact_gmr_is_optimal():
    """X* minimizes — any perturbation increases the residual (Lemma 2)."""
    A, C, R = _problem(jax.random.key(0))
    X = exact_gmr(A, C, R)
    base = float(jnp.linalg.norm(A - C @ X @ R))
    for t in range(5):
        dX = 0.1 * jax.random.normal(jax.random.key(10 + t), X.shape)
        assert float(jnp.linalg.norm(A - C @ (X + dX) @ R)) >= base - 1e-4


def test_lemma2_pythagorean():
    """||A − CX̃R||² = ||A − CX*R||² + ||C(X*−X̃)R||² for any X̃."""
    A, C, R = _problem(jax.random.key(1))
    Xs = exact_gmr(A, C, R)
    for t in range(3):
        Xt = Xs + 0.2 * jax.random.normal(jax.random.key(t), Xs.shape)
        lhs = jnp.linalg.norm(A - C @ Xt @ R) ** 2
        rhs = jnp.linalg.norm(A - C @ Xs @ R) ** 2 + jnp.linalg.norm(C @ (Xs - Xt) @ R) ** 2
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4)


@pytest.mark.parametrize(
    "sketch",
    ["gaussian", "countsketch", "osnap", pytest.param("srht", marks=pytest.mark.slow)],
)
def test_fast_gmr_relative_error(sketch):
    """Theorem 1: moderate sketch sizes give small relative error."""
    A, C, R = _problem(jax.random.key(2))
    errs = [
        float(error_ratio(A, C, fast_gmr(jax.random.key(50 + t), A, C, R, 120, 120, sketch_c=sketch), R))
        for t in range(3)
    ]
    assert np.mean(errs) < 0.35, (sketch, errs)


def test_error_decreases_with_sketch_size():
    A, C, R = _problem(jax.random.key(3))
    means = []
    for s in (24, 72, 144):
        errs = [
            float(error_ratio(A, C, fast_gmr(jax.random.key(70 + t), A, C, R, s, s), R))
            for t in range(4)
        ]
        means.append(np.mean(errs))
    assert means[2] < means[0], means


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 2**30), decay=st.floats(0.3, 1.5))
def test_error_ratio_nonnegative(seed, decay):
    """error_ratio ≥ −ε for ANY sketched solution (X* is the minimizer)."""
    A, C, R = _problem(jax.random.key(seed), m=120, n=100, c=6, r=6, decay=decay)
    X = fast_gmr(jax.random.fold_in(jax.random.key(seed), 1), A, C, R, 40, 40)
    assert float(error_ratio(A, C, X, R)) > -1e-3


def test_rho_positive_and_finite():
    A, C, R = _problem(jax.random.key(4))
    val = float(rho(A, C, R))
    assert 0 < val < 100


def test_lstsq_all_zero_operand_is_finite():
    """Regression: an all-zero sketched block (e.g. a CountSketch-collision-
    wiped panel) must yield a finite (zero) core, not NaN. The old relative
    floor `eps·max|d|·k` collapsed to 0 on an all-zero operand, letting zero
    diagonals through to solve_triangular (0/0 → NaN)."""
    Z = jnp.zeros((40, 8))
    Y = jnp.zeros((40, 6))
    X = _solve_least_squares(Z, Y)
    assert bool(jnp.all(jnp.isfinite(X))), X
    np.testing.assert_allclose(X, 0.0)
    # full GMR core path: all three sketched pieces wiped
    core = fast_gmr_core(jnp.zeros((30, 5)), jnp.zeros((30, 25)), jnp.zeros((4, 25)))
    assert bool(jnp.all(jnp.isfinite(core))), core
    np.testing.assert_allclose(core, 0.0)


def test_lstsq_floor_is_sign_preserving():
    """Tiny and exactly-zero pivots get the same magnitude floor (the old
    guard double-floored exact zeros to 2·eps while tiny entries got eps),
    and negative pivots keep their sign through the floor."""
    Y = jnp.ones((2, 1), jnp.float32)
    xs = {
        d2: float(_solve_least_squares(jnp.diag(jnp.asarray([1.0, d2], jnp.float32)), Y)[1, 0])
        for d2 in (0.0, 1e-30, -1e-30)
    }
    assert all(np.isfinite(v) for v in xs.values()), xs
    assert xs[-1e-30] < 0 < xs[1e-30], xs  # sign preserved
    np.testing.assert_allclose(xs[0.0], xs[1e-30])  # zero == tiny floor
    np.testing.assert_allclose(xs[0.0], -xs[-1e-30])  # symmetric magnitude


def test_lstsq_solver_matches_numpy():
    key = jax.random.key(5)
    B = jax.random.normal(key, (50, 8))
    Y = jax.random.normal(jax.random.fold_in(key, 1), (50, 6))
    X = _solve_least_squares(B, Y)
    Xnp, *_ = np.linalg.lstsq(np.asarray(B), np.asarray(Y), rcond=None)
    np.testing.assert_allclose(X, Xnp, atol=1e-4)


def test_fast_gmr_core_matches_full():
    """Core solve from pre-sketched pieces == fast_gmr with same sketches."""
    from repro.core.sketching import draw_sketch

    A, C, R = _problem(jax.random.key(6))
    k1, k2 = jax.random.split(jax.random.key(7))
    S_C = draw_sketch(k1, "gaussian", 100, A.shape[0])
    S_R = draw_sketch(k2, "gaussian", 100, A.shape[1])
    X1 = fast_gmr_core(S_C.apply(C), S_R.apply_t(S_C.apply(A)), S_R.apply_t(R))
    err = float(error_ratio(A, C, X1, R))
    assert err < 0.5


def test_sketched_fro_norm():
    A = jax.random.normal(jax.random.key(8), (400, 300))
    est = float(sketched_fro_norm(jax.random.key(9), A, 2000, 2000))
    true = float(jnp.linalg.norm(A))
    assert abs(est - true) / true < 0.15
