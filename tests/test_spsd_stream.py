"""Symmetric (tied-operand) streaming subsystem: engine contract, streaming
SPSD ↔ batch parity (single-host + DP-sharded), adaptive kernel-column
admission, and symmetric CUR over every selection policy."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sketching import CountSketch, RowSampling
from repro.cur import (
    SELECTION_POLICIES,
    cur_relative_error,
    spsd_to_cur,
    symmetric_cur,
)
from repro.spsd import (
    adaptive_spsd_finalize,
    adaptive_spsd_init,
    faster_spsd,
    leverage_sampling_sketches,
    matrix_oracle,
    spsd_error_ratio,
    streaming_spsd_finalize,
    streaming_spsd_init,
)
from repro.stream import (
    PanelOps,
    simulate_sharded_stream,
    stream_panels,
    truncated_R,
)

N = 240


@pytest.fixture(scope="module")
def K():
    """Low-rank-plus-ridge SPSD matrix with localized heavy structure."""
    base = 0.01 * jax.random.normal(jax.random.key(0), (N, 64))
    K = base @ base.T + 0.001 * jnp.eye(N)
    for i, p in enumerate(_SPIKES):
        v = jnp.zeros((N,)).at[p].set(1.0) + 0.05 * jax.random.normal(
            jax.random.key(10 + i), (N,)
        )
        K = K + 9.0 * jnp.outer(v, v)
    return K


_SPIKES = (17, 60, 133, 201)


# ---------------------------------------------------------------------------
# engine: symmetric PanelOps contract
# ---------------------------------------------------------------------------


def test_symmetric_ops_reject_r_hooks():
    """A symmetric ops derives R = Cᵀ — declaring an R hook is a bug."""
    with pytest.raises(ValueError, match="symmetric"):
        PanelOps(
            name="bad",
            core_sketches=lambda ctx: (None, None),
            update_c=lambda *a: a[:2],
            r_block=lambda *a: None,
            symmetric=True,
        )
    # and the non-symmetric exactly-one rule is unchanged
    with pytest.raises(ValueError, match="exactly one"):
        PanelOps(
            name="bad2", core_sketches=lambda ctx: (None, None), update_c=lambda *a: a[:2]
        )


def test_symmetric_truncated_r_is_c_transpose(K):
    """truncated_R derives the tied row factor; the stored R stays the
    (0, n_pad) placeholder through streaming (scan and per-panel alike)."""
    ci = jnp.asarray([3, 17, 60, 99], jnp.int32)
    for jit in ("scan", "per-panel"):
        st = streaming_spsd_init(jax.random.key(1), N, ci, s=48, panel=50)
        st = stream_panels(st, K, 50, jit=jit)  # 240 = 4×50 + ragged 40
        assert st.R.shape[0] == 0
        np.testing.assert_array_equal(truncated_R(st), st.C.T)
        np.testing.assert_array_equal(st.C, jnp.take(K, ci, axis=1))


def test_rowsampling_window_slices_match_dense():
    """RowSampling.cols/pad_cols obey the engine's exact window contract:
    windowed apply_t equals the dense slice, and windows past the true
    source dim contribute nothing."""
    S = RowSampling.draw(jax.random.key(2), 16, 100)
    A = jax.random.normal(jax.random.key(3), (7, 100))
    dense = S.materialize()
    for off, size in ((0, 30), (30, 30), (90, 10)):
        got = S.cols(off, size).apply_t(A[:, off : off + size])
        np.testing.assert_allclose(
            got, A[:, off : off + size] @ dense[:, off : off + size].T, atol=1e-5
        )
    padded = S.pad_cols(128)
    tail = padded.cols(100, 28).apply_t(jnp.ones((7, 28)))
    np.testing.assert_array_equal(tail, jnp.zeros((7, 16)))


# ---------------------------------------------------------------------------
# streaming SPSD ↔ batch Algorithm 2 parity (acceptance criterion)
# ---------------------------------------------------------------------------


def _shared_pieces(K, c=20, s=120):
    """One (col_idx, S₁, S₂) draw shared by the batch and streaming paths."""
    idx = jax.random.choice(jax.random.key(4), N, (c,), replace=False).astype(jnp.int32)
    C = jnp.take(K, idx, axis=1)
    S1, S2 = leverage_sampling_sketches(jax.random.key(5), C, s)
    return idx, (S1, S2)


def test_streaming_matches_batch_faster_spsd(K):
    """Acceptance: streamed X == batch faster_spsd X on the same sampled
    columns and the same leverage-sampling sketch pair — each M entry gets
    exactly one nonzero panel contribution, so the match is essentially
    exact, ragged tails included."""
    idx, sketches = _shared_pieces(K)
    res_b = faster_spsd(
        jax.random.key(6), matrix_oracle(K), N, idx.shape[0], sketches[0].s,
        col_idx=idx, sketches=sketches,
    )
    scale = float(jnp.max(jnp.abs(res_b.X)))
    for panel in (60, 64):  # dividing and ragged (240 = 3×64 + 48)
        st = streaming_spsd_init(jax.random.key(7), N, idx, sketches=sketches, panel=panel)
        res_s = streaming_spsd_finalize(stream_panels(st, K, panel))
        np.testing.assert_array_equal(res_s.C, res_b.C)
        np.testing.assert_allclose(res_s.X, res_b.X, atol=1e-4 * scale)
        err_b = float(spsd_error_ratio(K, res_b))
        err_s = float(spsd_error_ratio(K, res_s))
        assert abs(err_b - err_s) < 1e-4, (err_b, err_s)


@pytest.mark.parametrize("workers", [2, 4])
def test_streaming_spsd_sharded_parity(K, workers):
    """Acceptance: DP-sharded tied-operand ingestion == single-host (the
    hook-less symmetric ops chain exactly; R placeholder rides untouched)."""
    idx, sketches = _shared_pieces(K)

    def init():
        return streaming_spsd_init(jax.random.key(8), N, idx, sketches=sketches, panel=40)

    single = streaming_spsd_finalize(stream_panels(init(), K, 40))
    shard = streaming_spsd_finalize(simulate_sharded_stream(init(), K, 40, workers))
    np.testing.assert_array_equal(shard.C, single.C)
    np.testing.assert_allclose(shard.X, single.X, atol=2e-5)


def test_streaming_spsd_scan_parity(K):
    """Scan-compiled driver vs the per-panel jitted oracle, symmetric ops."""
    idx, sketches = _shared_pieces(K)

    def init():
        return streaming_spsd_init(jax.random.key(9), N, idx, sketches=sketches, panel=64)

    ref = stream_panels(init(), K, 64, jit="per-panel")
    got = stream_panels(init(), K, 64, jit="scan")
    np.testing.assert_array_equal(got.C, ref.C)
    np.testing.assert_allclose(got.M, ref.M, atol=2e-4)
    assert int(got.offset) == int(ref.offset)


def test_streaming_init_validation():
    """The streaming inits enforce the same clear-ValueError convention as
    the batch paths: in-range col_idx, 0 < c ≤ n, s > 0."""
    with pytest.raises(ValueError, match="col_idx entries"):
        streaming_spsd_init(jax.random.key(0), N, jnp.asarray([0, N]), panel=40)
    with pytest.raises(ValueError, match="col_idx entries"):
        streaming_spsd_init(jax.random.key(0), N, jnp.asarray([-1, 5]), panel=40)
    with pytest.raises(ValueError, match="0 < c <= n"):
        adaptive_spsd_init(jax.random.key(0), N, 0, panel=40)
    with pytest.raises(ValueError, match="0 < c <= n"):
        adaptive_spsd_init(jax.random.key(0), N, N + 1, panel=40)
    with pytest.raises(ValueError, match="s > 0"):
        adaptive_spsd_init(jax.random.key(0), N, 8, s=-3, panel=40)


def test_streaming_spsd_core_is_psd(K):
    """Theorem 2: the projected streamed core is PSD."""
    idx, sketches = _shared_pieces(K)
    st = streaming_spsd_init(jax.random.key(10), N, idx, sketches=sketches, panel=64)
    res = streaming_spsd_finalize(stream_panels(st, K, 64))
    ev = jnp.linalg.eigvalsh(0.5 * (res.X + res.X.T))
    assert float(ev.min()) > -1e-4
    assert res.entries_observed == N * N  # every entry streamed through once


# ---------------------------------------------------------------------------
# adaptive kernel-column admission (stream/adaptive.py hook reuse)
# ---------------------------------------------------------------------------


def test_adaptive_spsd_admits_spiked_kernel_columns(K):
    """The adaptive residual scorer applied to kernel columns captures the
    planted heavy columns and beats fixed-uniform streaming SPSD at equal
    (c, s) budget."""
    st = adaptive_spsd_init(jax.random.key(11), N, 8, s=96, panel=40, panel_cap=2)
    res = adaptive_spsd_finalize(stream_panels(st, K, 40))
    admitted = set(np.asarray(res.col_idx).tolist())
    assert set(_SPIKES) <= admitted, sorted(admitted)
    err_a = float(spsd_error_ratio(K, res))
    ci = jax.random.choice(jax.random.key(12), N, (8,), replace=False)
    stu = streaming_spsd_init(jax.random.key(13), N, ci, s=96, panel=40)
    err_u = float(spsd_error_ratio(K, streaming_spsd_finalize(stream_panels(stu, K, 40))))
    assert err_a < err_u, (err_a, err_u)


def test_adaptive_spsd_unfilled_slots_are_inert():
    """A kernel with less structure than budget leaves slots unfilled —
    col_idx −1, zero C columns, zero X rows/cols, core still PSD/finite."""
    B = 0.01 * jax.random.normal(jax.random.key(14), (N, 32))
    K = B @ B.T + 1e-4 * jnp.eye(N)
    v = jnp.zeros((N,)).at[13].set(1.0)
    K = K + 9.0 * jnp.outer(v, v)
    st = adaptive_spsd_init(
        jax.random.key(15), N, 6, s=64, panel=40, panel_cap=1, min_gain=5.0
    )
    res = adaptive_spsd_finalize(stream_panels(st, K, 40))
    idx = np.asarray(res.col_idx)
    assert (idx == -1).any() and 13 in idx.tolist()
    unfilled = idx == -1
    assert bool(jnp.all(jnp.isfinite(res.X)))
    np.testing.assert_allclose(np.asarray(res.X)[unfilled, :], 0.0)
    np.testing.assert_allclose(np.asarray(res.X)[:, unfilled], 0.0)
    np.testing.assert_allclose(np.asarray(res.C)[:, unfilled], 0.0)
    ev = jnp.linalg.eigvalsh(0.5 * (res.X + res.X.T))
    assert float(ev.min()) > -1e-4


@pytest.mark.parametrize("workers", [2, 4])
def test_adaptive_spsd_sharded_still_finds_spikes(K, workers):
    """Sharded adaptive SPSD (disjoint per-worker slot ranges on the
    symmetric engine) still captures the heavy kernel columns."""
    st = adaptive_spsd_init(jax.random.key(16), N, 8, s=96, panel=40, panel_cap=1)
    res = adaptive_spsd_finalize(simulate_sharded_stream(st, K, 40, workers))
    admitted = set(np.asarray(res.col_idx).tolist())
    missed = set(_SPIKES) - admitted
    assert len(missed) <= 1, sorted(admitted)
    assert float(spsd_error_ratio(K, res)) < 0.1


def test_adaptive_spsd_scan_parity(K):
    """Adaptive symmetric stream: scan carry (full AdaptiveCURCtx, no rows)
    matches the per-panel driver decision-for-decision."""

    def init():
        return adaptive_spsd_init(
            jax.random.key(17), N, 8, s=96, panel=40, panel_cap=2, swap_gain=2.0
        )

    ref = stream_panels(init(), K, 40, jit="per-panel")
    got = stream_panels(init(), K, 40, jit="scan")
    np.testing.assert_array_equal(got.ctx.col_idx, ref.ctx.col_idx)
    assert int(got.ctx.n_evicted) == int(ref.ctx.n_evicted)
    np.testing.assert_allclose(got.M, ref.M, atol=2e-4)
    np.testing.assert_allclose(got.ctx.ScC, ref.ctx.ScC, atol=2e-5)


# ---------------------------------------------------------------------------
# symmetric CUR (R = Cᵀ) over every selection policy (acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", SELECTION_POLICIES)
def test_symmetric_cur_quality_per_policy(K, policy):
    """Every cur/selection policy drives a valid symmetric factorization:
    PSD core, sane error, Theorem-3 entry accounting, and the CUR adapter
    reproduces the same fit with R = Cᵀ tied."""
    c = 12
    res = symmetric_cur(jax.random.key(18), K, c, policy=policy)
    err = float(spsd_error_ratio(K, res))
    assert np.isfinite(err) and err < 0.15, (policy, err)
    ev = jnp.linalg.eigvalsh(0.5 * (res.X + res.X.T))
    assert float(ev.min()) > -1e-4
    assert res.entries_observed == N * c + min(10 * c, N) ** 2
    cur = spsd_to_cur(res)
    np.testing.assert_array_equal(cur.R, res.C.T)
    np.testing.assert_array_equal(cur.row_idx, cur.col_idx)
    assert abs(float(cur_relative_error(K, cur)) - err) < 1e-5


def test_symmetric_cur_exact_core(K):
    """method="exact" returns the PSD-projected oracle core at n² entries."""
    res = symmetric_cur(jax.random.key(19), K, 12, policy="leverage", method="exact")
    assert res.entries_observed == N * N
    assert float(spsd_error_ratio(K, res)) < 0.15


def test_symmetric_cur_validation(K):
    with pytest.raises(ValueError, match="square"):
        symmetric_cur(jax.random.key(20), K[:, :100], 8)
    with pytest.raises(ValueError, match="col_idx"):
        symmetric_cur(jax.random.key(21), K)
    with pytest.raises(ValueError, match="unknown method"):
        symmetric_cur(jax.random.key(22), K, 8, method="bogus")


# ---------------------------------------------------------------------------
# batch sketch-injection guard
# ---------------------------------------------------------------------------


def test_batch_sketch_injection_requires_sampling(K):
    """The entry-oracle contract: dense sketches would need n² oracle
    entries, so injection is restricted to RowSampling operators."""
    S = CountSketch.draw(jax.random.key(23), 64, N)
    with pytest.raises(TypeError, match="RowSampling"):
        faster_spsd(
            jax.random.key(24), matrix_oracle(K), N, 8, 64, sketches=(S, S)
        )
