"""Docs gate for `make docs-check` (CI-enforced).

Two checks:

1. **Docstring audit** — every *public* API in the audited packages
   (``repro.stream``, ``repro.cur``, ``repro.spsd``, ``repro.obs``,
   ``repro.serve``) must
   carry a docstring: module-level
   functions and classes, public methods/properties of public classes, and
   the modules themselves. Public = not ``_``-prefixed and defined inside
   the audited package (re-exports are attributed to their home module).
   Auto-generated dataclass machinery (``__init__`` etc.) is exempt.

2. **Paper-map audit** — ``docs/paper_map.md`` must exist, cover every
   Algorithm/Table/§-metric of the paper (the REQUIRED_SECTIONS list), and
   every ``path/to/file.py:<line>`` anchor it cites must point at an
   existing file with at least that many lines (so the map cannot silently
   rot as code moves).

Exit code 0 = clean; nonzero prints every violation.

  PYTHONPATH=src python tools/check_docstrings.py
"""

from __future__ import annotations

import importlib
import inspect
import os
import pkgutil
import re
import sys

AUDITED_PACKAGES = ["repro.stream", "repro.cur", "repro.spsd", "repro.obs", "repro.serve"]

PAPER_MAP = os.path.join(os.path.dirname(__file__), "..", "docs", "paper_map.md")

# Every algorithm / table / metric of the source paper that the map must cover.
REQUIRED_SECTIONS = [
    "Algorithm 1",  # Fast GMR
    "Algorithm 2",  # SPSD approximation
    "Algorithm 3",  # Fast single-pass SVD
    "Algorithm 4",  # Practical single-pass SVD (Tropp baseline)
    "Table 2",      # sketch sizes
    "Table 3",      # leverage-sampling sketch sizes
    "§2.3",         # sketching families
    "§6.1",         # evaluation metrics
]


def iter_modules(pkg_name: str):
    pkg = importlib.import_module(pkg_name)
    yield pkg_name, pkg
    for info in pkgutil.iter_modules(pkg.__path__, prefix=pkg_name + "."):
        yield info.name, importlib.import_module(info.name)


def has_doc(obj) -> bool:
    doc = inspect.getdoc(obj)
    return bool(doc and doc.strip())


def audit_docstrings() -> list:
    problems = []
    for pkg_name in AUDITED_PACKAGES:
        for mod_name, mod in iter_modules(pkg_name):
            if not has_doc(mod):
                problems.append(f"{mod_name}: module has no docstring")
            for name, obj in vars(mod).items():
                if name.startswith("_"):
                    continue
                if not (inspect.isfunction(obj) or inspect.isclass(obj)):
                    continue
                if getattr(obj, "__module__", None) != mod_name:
                    continue  # re-export; audited where it is defined
                qual = f"{mod_name}.{name}"
                if not has_doc(obj):
                    problems.append(f"{qual}: missing docstring")
                if inspect.isclass(obj):
                    for mname, member in vars(obj).items():
                        if mname.startswith("_"):
                            continue
                        target = member.fget if isinstance(member, property) else member
                        if not (inspect.isfunction(target) or isinstance(member, (property, staticmethod, classmethod))):
                            continue
                        if isinstance(member, (staticmethod, classmethod)):
                            target = member.__func__
                        if target is None or not inspect.isfunction(target):
                            continue
                        if not has_doc(target):
                            problems.append(f"{qual}.{mname}: missing docstring")
    return problems


def audit_paper_map() -> list:
    problems = []
    path = os.path.normpath(PAPER_MAP)
    if not os.path.exists(path):
        return [f"{path}: missing (docs/paper_map.md is required)"]
    text = open(path).read()
    for section in REQUIRED_SECTIONS:
        if section not in text:
            problems.append(f"paper_map.md: no coverage of {section!r}")
    root = os.path.join(os.path.dirname(__file__), "..")
    for ref in re.finditer(r"`([\w./-]+\.(?:py|md)):(\d+)`", text):
        rel, line = ref.group(1), int(ref.group(2))
        target = os.path.normpath(os.path.join(root, rel))
        if not os.path.exists(target):
            problems.append(f"paper_map.md: anchor {rel}:{line} — file does not exist")
        elif sum(1 for _ in open(target)) < line:
            problems.append(f"paper_map.md: anchor {rel}:{line} — file has fewer lines")
    return problems


def main() -> int:
    problems = audit_docstrings() + audit_paper_map()
    if problems:
        print(f"docs-check: {len(problems)} problem(s)")
        for p in problems:
            print(f"  - {p}")
        return 1
    n_mods = sum(1 for pkg in AUDITED_PACKAGES for _ in iter_modules(pkg))
    print(f"docs-check: OK ({n_mods} modules audited, paper_map anchors verified)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
