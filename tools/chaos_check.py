#!/usr/bin/env python
"""Chaos lane: drive the streaming suite under a seeded ``FaultPlan`` and
assert zero factor divergence.

For each streaming config (fixed CUR, adaptive CUR, symmetric SPSD) a
seed-derived fault schedule — one injected crash, NaN-corrupted panels, a
straggler delay, plus a dropped and a duplicated delivery — is applied at
the source boundary while the production driver handles it: retry/dedup for
deliveries, checkpoint-resume for the crash, in-scan quarantine for the
NaN panels. The run must produce **bitwise-identical** C/R/M (and integer
telemetry counters) to the reference run on a clean source with the
corrupted panels zeroed (the quarantine contract: a quarantined panel ≡ an
all-zero panel). A sharded variant kills one worker at 2 and 4 workers and
asserts the re-merged result against the all-healthy sharded run.

Usage:  PYTHONPATH=src python tools/chaos_check.py [--seed N]
Exit 0 == no divergence anywhere. Wired as ``make chaos-check`` and a CI
step next to perf-check/obs-check.
"""

from __future__ import annotations

import argparse
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def _fault_schedule(rng: np.random.RandomState, num_panels: int):
    """Seed-derived deterministic fault plan over ``num_panels`` panels."""
    panels = rng.permutation(num_panels)
    return dict(
        crash_at_panel=int(panels[0]),
        corrupt_panels=tuple(sorted(int(p) for p in panels[1:3])),
        drop_panels=(int(panels[3]),),
        duplicate_panels=(int(panels[4]),),
        straggler_panels=(int(panels[5]),),
    )


def _assert_equal(ref, st, which: str):
    for f in ("C", "R", "M"):
        a, b = np.asarray(getattr(ref, f)), np.asarray(getattr(st, f))
        if not np.array_equal(a, b):
            raise AssertionError(
                f"{which}: factor {f} diverged "
                f"(max |Δ| = {np.max(np.abs(a - b)):.3e})"
            )
    for leaf in ("admitted", "evicted", "rows_admitted", "occupancy", "panels_seen"):
        a = np.asarray(getattr(ref.tel, leaf))
        b = np.asarray(getattr(st.tel, leaf))
        if not np.array_equal(a, b):
            raise AssertionError(f"{which}: telemetry counter {leaf} diverged")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0, help="fault-schedule seed")
    args = ap.parse_args(argv)

    from repro.cur.streaming import streaming_cur_init
    from repro.data.synthetic import powerlaw_matrix
    from repro.spsd.streaming import streaming_spsd_init
    from repro.stream import (
        ArrayPanelSource,
        FaultInjector,
        FaultPlan,
        InjectedCrash,
        adaptive_cur_init,
        run_resilient_sharded_stream,
        run_resilient_stream,
    )

    m, n, panel = 128, 192, 16
    num_panels = n // panel
    A = powerlaw_matrix(jax.random.key(0), m, n, 1.0)
    G = powerlaw_matrix(jax.random.key(8), n, 32, 1.0)
    K = G @ G.T + 0.01 * jnp.eye(n)
    ci = jnp.asarray([3, 40, 99, 120, 7, 31], jnp.int32)
    ri = jnp.asarray([5, 17, 40, 77, 90, 60], jnp.int32)

    configs = {
        "fixed_cur": (
            lambda: streaming_cur_init(jax.random.key(1), m, n, ci, ri, panel=panel, telemetry=True),
            A,
        ),
        "adaptive_cur": (
            lambda: adaptive_cur_init(jax.random.key(5), m, n, 8, ri[:4], panel=panel, panel_cap=2, telemetry=True),
            A,
        ),
        "spsd": (
            lambda: streaming_spsd_init(jax.random.key(9), n, ci[:4], s=48, panel=panel, telemetry=True),
            K,
        ),
    }

    rng = np.random.RandomState(args.seed)
    failures = 0
    for name, (init, op) in configs.items():
        sched = _fault_schedule(rng, num_panels)
        plan = FaultPlan(straggler_delay_s=0.002, **sched)
        print(f"[chaos] {name}: {sched}")

        # reference: clean source with the to-be-corrupted panels zeroed
        # (quarantine contract: a quarantined panel ≡ an all-zero panel)
        op_zero = op
        for t in plan.corrupt_panels:
            op_zero = op_zero.at[:, t * panel : (t + 1) * panel].set(0.0)
        ref, _ = run_resilient_stream(
            init(), ArrayPanelSource(op_zero, panel), chunk_panels=2, quarantine=True
        )

        inj = FaultInjector(ArrayPanelSource(op, panel), plan)
        with tempfile.TemporaryDirectory() as d:
            try:
                run_resilient_stream(
                    init(), inj, chunk_panels=2, ckpt_dir=d, ckpt_every=1,
                    quarantine=True,
                )
                print(f"[chaos] {name}: FAIL — injected crash never fired")
                failures += 1
                continue
            except InjectedCrash:
                pass
            st, rep = run_resilient_stream(
                init(), inj, chunk_panels=2, ckpt_dir=d, ckpt_every=1,
                quarantine=True,
            )
        try:
            _assert_equal(ref, st, name)
        except AssertionError as e:
            print(f"[chaos] FAIL: {e}")
            failures += 1
            continue
        if rep.quarantined != len(plan.corrupt_panels):
            print(
                f"[chaos] {name}: FAIL — quarantined {rep.quarantined} "
                f"!= {len(plan.corrupt_panels)} corrupted"
            )
            failures += 1
            continue
        print(
            f"[chaos] {name}: OK (resumed from panel {rep.resumed_from}, "
            f"retries={rep.retries}, quarantined={rep.quarantined})"
        )

    # sharded: kill one worker, resume from its per-worker checkpoints
    init, op = configs["fixed_cur"]
    src = ArrayPanelSource(op, panel)
    for W in (2, 4):
        healthy, _ = run_resilient_sharded_stream(init(), src, W, chunk_panels=2)
        inj = FaultInjector(src, FaultPlan(crash_at_panel=int(rng.randint(num_panels))))
        with tempfile.TemporaryDirectory() as d:
            try:
                run_resilient_sharded_stream(
                    init(), inj, W, ckpt_dir=d, chunk_panels=2, ckpt_every=1
                )
                print(f"[chaos] sharded w{W}: FAIL — injected crash never fired")
                failures += 1
                continue
            except InjectedCrash:
                pass
            st, reps = run_resilient_sharded_stream(
                init(), inj, W, ckpt_dir=d, chunk_panels=2, ckpt_every=1
            )
        try:
            _assert_equal(healthy, st, f"sharded w{W}")
        except AssertionError as e:
            print(f"[chaos] FAIL: {e}")
            failures += 1
            continue
        print(f"[chaos] sharded w{W}: OK (resumed={[r.resumed_from for r in reps]})")

    if failures:
        print(f"[chaos] {failures} divergence(s) — FAIL")
        return 1
    print("[chaos] zero factor divergence under seeded faults — PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
