"""Scan-body HLO census gate: fused vs unfused streaming programs.

Compiles the engine's ``scan_chunk`` with ``fused=True`` and ``fused=False``
on two small reference configs (fixed-index streaming CUR, adaptive CUR with
fixed rows — the acceptance config of the fused-megakernel PR), runs the
loop-aware census of :mod:`repro.launch.hlo_census` on both programs, and
fails (exit 1) when:

  * the fused scan body's HBM bytes-per-panel is not at least 25 % below
    the unfused body's (``scan_body_bytes_per_panel`` — the steady-state
    marginal traffic of one scan iteration; the chunk-hoisted sketch is
    amortized prologue and is gated separately via the whole-program
    number), or
  * the fused whole-program bytes-per-panel exceeds the unfused one
    (the hoist must never cost more than it saves), or
  * any censused number (bytes-per-panel, scan-body bytes-per-panel,
    weighted top-level op count) exceeds its committed budget in
    ``benchmarks/baselines/census_budget.json`` by more than the
    tolerance (default 10 % — the census parses compiled HLO text, which
    shifts slightly across XLA versions).

The census is structural (compiled-program analysis, no execution), so the
gate is wall-clock- and host-invariant. Regenerate the budgets after an
intentional change with::

  PYTHONPATH=src python tools/census_check.py --update

Wired into ``make census-check`` and CI.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp

BUDGET_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "benchmarks", "baselines", "census_budget.json",
)

# committed gate constants
FUSED_BODY_MAX_RATIO = 0.75  # fused scan body must be >=25% leaner
TOLERANCE = 0.10  # budget slack for cross-version HLO-text drift

METRICS = ("bytes_per_panel", "scan_body_bytes_per_panel", "n_ops", "scan_body_n_ops")


def _configs():
    """(name, state, A, panel) for the censused reference programs."""
    from repro.cur.streaming import streaming_cur_init
    from repro.stream.adaptive import adaptive_cur_init

    out = []

    # Fixed-index streaming CUR, small: the chunk_fold removes ALL factor
    # writes from the scan body (pure copies folded once per chunk).
    m, n, panel, c, r = 512, 512, 128, 16, 16
    key = jax.random.PRNGKey(0)
    st = streaming_cur_init(
        key, m, n,
        col_idx=jnp.arange(c, dtype=jnp.int32),
        row_idx=jnp.arange(r, dtype=jnp.int32),
        sketch="countsketch", panel=panel,
    )
    out.append((f"streaming_cur/{m}x{n}_p{panel}_c{c}", st, jnp.zeros((m, n), jnp.float32), panel))

    # Adaptive CUR, fixed rows — the acceptance config of the fused
    # panel-update PR: m=2048, n=1024, panel=256, c=r=16, panel_cap=4,
    # countsketch core sketches (s_c=s_r=240 via the Table-2 defaults).
    m, n, panel, c, r = 2048, 1024, 256, 16, 16
    st = adaptive_cur_init(
        jax.random.PRNGKey(1), m, n, c,
        row_idx=jnp.arange(r, dtype=jnp.int32),
        panel_cap=4, sketch="countsketch", panel=panel,
    )
    out.append((f"adaptive_cur/{m}x{n}_p{panel}_c{c}", st, jnp.zeros((m, n), jnp.float32), panel))
    return out


def measure() -> dict:
    from repro.launch.hlo_census import census_stream_program

    results = {}
    for name, st, A, panel in _configs():
        pair = {}
        for fused in (True, False):
            cen = census_stream_program(st, A, panel, fused=fused)
            pair["fused" if fused else "unfused"] = {k: cen[k] for k in METRICS}
        results[name] = pair
    return results


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update", action="store_true",
                    help="write the measured numbers as the new committed budget")
    args = ap.parse_args()

    results = measure()
    failures = []

    for name, pair in results.items():
        f, u = pair["fused"], pair["unfused"]
        body_ratio = f["scan_body_bytes_per_panel"] / max(u["scan_body_bytes_per_panel"], 1.0)
        total_ratio = f["bytes_per_panel"] / max(u["bytes_per_panel"], 1.0)
        print(f"{name}:")
        print(f"  scan-body bytes/panel   fused {f['scan_body_bytes_per_panel']:.3e}  "
              f"unfused {u['scan_body_bytes_per_panel']:.3e}  ratio {body_ratio:.3f}")
        print(f"  whole-program bytes/panel fused {f['bytes_per_panel']:.3e}  "
              f"unfused {u['bytes_per_panel']:.3e}  ratio {total_ratio:.3f}")
        print(f"  n_ops fused {f['n_ops']:.0f} unfused {u['n_ops']:.0f}  "
              f"scan-body n_ops fused {f['scan_body_n_ops']:.0f} unfused {u['scan_body_n_ops']:.0f}")
        if body_ratio > FUSED_BODY_MAX_RATIO:
            failures.append(
                f"{name}: fused scan-body bytes/panel ratio {body_ratio:.3f} "
                f"> {FUSED_BODY_MAX_RATIO} (fused body must be >=25% leaner)"
            )
        if total_ratio > 1.0:
            failures.append(
                f"{name}: fused whole-program bytes/panel ratio {total_ratio:.3f} > 1.0 "
                "(the chunk hoist must not cost more than it saves)"
            )

    if args.update:
        budget = {
            "fused_body_max_ratio": FUSED_BODY_MAX_RATIO,
            "tolerance": TOLERANCE,
            "configs": results,
        }
        os.makedirs(os.path.dirname(BUDGET_PATH), exist_ok=True)
        with open(BUDGET_PATH, "w") as fh:
            json.dump(budget, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {BUDGET_PATH}")
    elif not os.path.exists(BUDGET_PATH):
        failures.append(
            f"no committed budget at {BUDGET_PATH} — run with --update and commit it"
        )
    else:
        with open(BUDGET_PATH) as fh:
            budget = json.load(fh)
        tol = budget.get("tolerance", TOLERANCE)
        for name, pair in results.items():
            committed = budget.get("configs", {}).get(name)
            if committed is None:
                failures.append(f"{name}: missing from committed budget — rerun --update")
                continue
            for variant in ("fused", "unfused"):
                for metric in METRICS:
                    fresh = pair[variant][metric]
                    limit = committed[variant][metric] * (1.0 + tol)
                    if fresh > limit:
                        failures.append(
                            f"{name}/{variant}/{metric}: {fresh:.4e} exceeds committed "
                            f"{committed[variant][metric]:.4e} (+{tol:.0%} tol)"
                        )

    if failures:
        print("\nCENSUS GATE FAILURES:", file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print("\ncensus gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
