"""Kernel-matrix approximation service (paper §4): approximate an RBF
kernel while *observing only a small fraction of its entries* — the
query-complexity win of Algorithm 2 (Theorem 3: nc + s² entries).

  PYTHONPATH=src python examples/kernel_approximation.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp

from benchmarks.common import clustered_points, tune_rbf_sigma
from repro.core import (
    fast_spsd_wang,
    faster_spsd,
    nystrom,
    optimal_core,
    rbf_kernel_oracle,
    spsd_error_ratio,
)

n, d, k = 1200, 32, 15
X = clustered_points(jax.random.key(0), n, d, n_clusters=10, spread=0.7)
sigma = tune_rbf_sigma(X, k=k, target_eta=0.75)
oracle = rbf_kernel_oracle(X, sigma)
K = oracle(None, None)  # ground truth for evaluation only

c = 2 * k
print(f"RBF kernel {n}×{n} (σ={sigma:.2e}), c = {c} columns; full matrix = {n*n:,} entries\n")
print(f"{'method':22s} {'err ratio':>10s} {'entries':>12s} {'fraction':>9s}")
for name, fn in [
    ("nystrom", lambda key: nystrom(key, oracle, n, c)),
    ("fast-SPSD (Wang16b)", lambda key: fast_spsd_wang(key, oracle, n, c, 10 * c)),
    ("faster-SPSD (Alg 2)", lambda key: faster_spsd(key, oracle, n, c, 10 * c)),
    ("optimal core", lambda key: optimal_core(key, oracle, n, c)),
]:
    res = fn(jax.random.key(42))
    err = float(spsd_error_ratio(K, res))
    print(f"{name:22s} {err:10.4f} {res.entries_observed:12,} {res.entries_observed/(n*n):9.1%}")

print("\nAlgorithm 2 ≈ optimal accuracy at ~5% of the kernel entries.")

# --- single-pass streaming: K arrives as column panels, never retained ----
# (symmetric engine: R = Cᵀ is derived, memory is C (n·c) + M (s²))
from repro.cur import SELECTION_POLICIES, symmetric_cur
from repro.spsd import streaming_spsd_finalize, streaming_spsd_init
from repro.stream import stream_panels

panel = 256
ci = jax.random.choice(jax.random.key(7), n, (c,), replace=False)
st = streaming_spsd_init(jax.random.key(8), n, ci, s=10 * c, panel=panel)
st = stream_panels(st, K, panel)  # one pass over kernel-column panels
res = streaming_spsd_finalize(st)
print(f"\nstreaming Alg 2 (panel={panel}): err ratio "
      f"{float(spsd_error_ratio(K, res)):.4f} — memory C({n}x{c}) + M({10*c}x{10*c})")

# --- symmetric CUR: policy-driven landmark selection, R = Cᵀ tied ---------
print(f"\n{'symmetric CUR policy':22s} {'err ratio':>10s}")
for policy in SELECTION_POLICIES:
    res = symmetric_cur(jax.random.key(9), K, c, policy=policy)
    print(f"{policy:22s} {float(spsd_error_ratio(K, res)):10.4f}")
