"""End-to-end driver: train a ~100M-param llama-style model for a few
hundred steps on an 8-device host mesh, with GMR gradient compression
(the paper's Algorithm 1 replacing the dense DP all-reduce) vs the plain
baseline, checkpoint/restart enabled.

  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--plain]

(device count is set below before jax import — 8 host devices)
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--plain", action="store_true", help="disable GMR compression")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--fail-at-step", type=int, default=-1)
    args = ap.parse_args()

    # ~100M params: 12 layers × d512 + 32k-vocab embeddings. The default
    # batch 8×128 is sized for this CPU container (~5s/step); on a real
    # accelerator mesh raise --batch/--seq (the step is the same SPMD code).
    argv = [
        "--arch", "llama3.2-1b",
        "--d-model", "512", "--d-ff", "2048", "--layers", "12",
        "--heads", "8", "--kv-heads", "4", "--head-dim", "64",
        "--vocab", "32768",
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--steps", str(args.steps),
        "--mesh", "8x1",
        "--lr", "3e-3",
        "--ckpt-every", "100",
    ]
    if args.fail_at_step >= 0:
        argv += ["--fail-at-step", str(args.fail_at_step)]
    if not args.plain:
        argv += ["--grad-compress", "--compress-rank", "32", "--compress-factor", "4"]
    report = train_mod.main(argv)
    assert report.losses[-1] < report.losses[0], "loss did not decrease"
    print("train_lm example OK")


if __name__ == "__main__":
    main()
