"""CUR decomposition via Fast GMR (paper §1 application 1) — three modes:

1. one-shot: exact vs Algorithm-1 sketched core on a power-law matrix
2. streaming: single-pass CUR over column panels of a matrix we never hold
3. batched serving: a stack of per-user matrices in one dispatch

  PYTHONPATH=src python examples/cur_demo.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.cur import (
    batched_fast_cur,
    cur_error_ratio,
    cur_reconstruct,
    cur_sketch_sizes,
    exact_cur,
    fast_cur,
    select_columns,
    select_rows,
    streaming_cur_finalize,
    streaming_cur_init,
    streaming_cur_update,
)
from repro.data.synthetic import powerlaw_matrix

# ---- 1. one-shot: sketched core vs oracle core -----------------------------
m, n, c, r = 2048, 1536, 20, 20
A = powerlaw_matrix(jax.random.key(0), m, n, 1.0)

sel_c = select_columns(jax.random.key(1), A, c, "approx_leverage")
sel_r = select_rows(jax.random.key(2), A, r, "approx_leverage")

exact_fn = jax.jit(lambda: exact_cur(A, sel_c.idx, sel_r.idx))
fast_fn = jax.jit(lambda k: fast_cur(k, A, col_idx=sel_c.idx, row_idx=sel_r.idx))
res_exact, res_fast = exact_fn(), fast_fn(jax.random.key(3))  # compile warmup
t0 = time.perf_counter()
res_exact = jax.block_until_ready(exact_fn())
t_exact = time.perf_counter() - t0
t0 = time.perf_counter()
res_fast = jax.block_until_ready(fast_fn(jax.random.key(3)))
t_fast = time.perf_counter() - t0

sizes = cur_sketch_sizes(c, r)
base = float(jnp.linalg.norm(A - cur_reconstruct(res_exact)))
fast = float(jnp.linalg.norm(A - cur_reconstruct(res_fast)))
print(f"exact CUR  (U = C† A R†):          {t_exact*1e3:7.1f} ms   resid = {base:.4f}")
print(f"fast  CUR  (Alg 1, s={sizes['s_c']}):         {t_fast*1e3:7.1f} ms   "
      f"resid = {fast:.4f}  ({fast/base:.3f}x oracle)")
print(f"error ratio (§6.1 metric):          {float(cur_error_ratio(A, res_fast)):+.4f}")

# ---- 2. streaming: one pass over column panels -----------------------------
panel = 256
state = streaming_cur_init(jax.random.key(4), m, n, sel_c.idx, sel_r.idx, sketch="countsketch")
for off in range(0, n, panel):  # the "stream": panels could be generated on demand
    state = streaming_cur_update(state, A[:, off : off + panel])
res_stream = streaming_cur_finalize(state)
resid = float(jnp.linalg.norm(A - cur_reconstruct(res_stream)))
mem = (m * c + r * n + state.M.size) * 4 / 1e6
print(f"streaming CUR ({n//panel} panels, {mem:.1f} MB working set): resid = {resid:.4f}")

# ---- 3. batched serving: many small matrices, one dispatch -----------------
B, mb, nb = 32, 256, 192
Ab = jax.vmap(lambda k: powerlaw_matrix(k, mb, nb, 1.0))(jax.random.split(jax.random.key(5), B))
batched_fn = jax.jit(lambda k, a: batched_fast_cur(k, a, 12, 12, s_c=96, s_r=96))
jax.block_until_ready(batched_fn(jax.random.key(6), Ab))  # compile warmup
t0 = time.perf_counter()
res_b = jax.block_until_ready(batched_fn(jax.random.key(6), Ab))
t_b = time.perf_counter() - t0
errs = jnp.linalg.norm(Ab - cur_reconstruct(res_b), axis=(1, 2)) / jnp.linalg.norm(Ab, axis=(1, 2))
print(f"batched CUR: {B} matrices of {mb}x{nb} in {t_b*1e3:.1f} ms "
      f"({t_b/B*1e6:.0f} us/matrix), rel err p50 = {float(jnp.median(errs)):.4f}")
