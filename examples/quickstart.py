"""Quickstart: the paper's three algorithms in ten lines each.

  python examples/quickstart.py   (or with PYTHONPATH=src)
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

import repro.core as core

key = jax.random.key(0)

# ---- a low-rank-ish test matrix -------------------------------------------
m, n, c, r = 800, 600, 20, 20
U, _ = jnp.linalg.qr(jax.random.normal(jax.random.key(1), (m, n)))
V, _ = jnp.linalg.qr(jax.random.normal(jax.random.key(2), (n, n)))
A = (U * (jnp.arange(1, n + 1.0) ** -1.0)[None]) @ V.T

# ---- 1. Fast GMR (Algorithm 1) --------------------------------------------
C = A @ jax.random.normal(jax.random.key(3), (n, c))
R = jax.random.normal(jax.random.key(4), (r, m)) @ A
X_fast = core.fast_gmr(key, A, C, R, s_c=8 * c, s_r=8 * r)  # sketched solve
print(f"Fast GMR      : error ratio = {float(core.error_ratio(A, C, X_fast, R)):+.4f} "
      f"(0 = optimal; Theorem 1 bound with s = 8c)")

# ---- 2. Faster SPSD kernel approximation (Algorithm 2) --------------------
pts = jax.random.normal(jax.random.key(5), (500, 16))
oracle = core.rbf_kernel_oracle(pts, sigma=0.05)
res = core.faster_spsd(key, oracle, n=500, c=30, s=300)
K = oracle(None, None)
print(f"Faster SPSD   : ||K − CXCᵀ||/||K|| = {float(core.spsd_error_ratio(K, res)):.4f}, "
      f"kernel entries observed = {res.entries_observed} of {500 * 500}")

# ---- 3. Fast single-pass SVD (Algorithm 3), streaming ----------------------
from repro.stream import stream_panels

state = core.sp_svd_init(key, m, n, sizes=dict(c=40, r=40, c0=120, r0=120, s_c=120, s_r=120),
                         panel=100)
state = stream_panels(state, A, 100)  # one fused scan over panels; A never stored
Uo, S, Vo = core.sp_svd_finalize(state)
print(f"Fast SP-SVD   : error ratio vs ||A−A₁₀||_F = "
      f"{float(core.svd_error_ratio(A, Uo, S, Vo, k=10)):+.4f} (can be negative)")
