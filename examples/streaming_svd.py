"""Streaming single-pass SVD (paper §5) + the serving integration: low-rank
KV-cache compression for long-context decode (DESIGN.md §4.2).

  PYTHONPATH=src python examples/streaming_svd.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import practical_sp_svd, sp_svd_finalize, sp_svd_init, svd_error_ratio
from repro.serve import KVCompressionConfig, compress_history, compression_error, lowrank_decode_attention, LowRankKV
from repro.stream import scan_chunk

# ---- 1. stream a matrix we never hold in memory ---------------------------
m, n, k = 2000, 1600, 10
key = jax.random.key(0)
U, _ = jnp.linalg.qr(jax.random.normal(jax.random.key(1), (m, 400)))
V, _ = jnp.linalg.qr(jax.random.normal(jax.random.key(2), (n, 400)))
sv = jnp.arange(1, 401.0) ** -1.2


def column_panel(off, width):  # the "stream": panels generated on demand
    return (U * sv[None]) @ V[off : off + width].T


sizes = dict(c=40, r=40, c0=120, r0=120, s_c=160, s_r=160)
panel, chunk = 200, 400  # each arriving chunk is scan-compiled as 2 panels
state = sp_svd_init(key, m, n, sizes=sizes, panel=panel)
# per-chunk arrays need the relative-indexed scan (offset lives in the carry);
# donating the carry keeps the accumulators in place across chunks
fold = jax.jit(scan_chunk, static_argnames="panel", donate_argnums=(0,))
for off in range(0, n, chunk):
    state = fold(state, column_panel(off, chunk), panel)
Uo, S, Vo = sp_svd_finalize(state)

A = (U * sv[None]) @ V.T  # materialized ONLY to evaluate
e_fast = float(svd_error_ratio(A, Uo, S, Vo, k))
Up, Sp_, Vp = practical_sp_svd(jax.random.key(3), A, c=40, r=40)
e_prac = float(svd_error_ratio(A, Up, Sp_, Vp, k))
print(f"Fast SP-SVD (Alg 3, one pass, {(m+n)*40*4/1e6:.1f} MB working set): err = {e_fast:+.4f}")
print(f"Practical SP-SVD (Tropp'17, same budget):                          err = {e_prac:+.4f}")

# ---- 2. KV-cache compression for decode ------------------------------------
S_len, d_head = 4096, 128
hist = (jax.random.normal(jax.random.key(4), (S_len, 12)) @
        jax.random.normal(jax.random.key(5), (12, d_head)))  # near-low-rank K history
kc = KVCompressionConfig(rank=24, panel=512)
fac = compress_history(jax.random.key(6), hist, kc)
dense_bytes = S_len * d_head * 2
comp_bytes = (fac.v_s.size + fac.sigma.size + fac.u.size) * 2
print(f"\nKV compression: {S_len}-token head history, rank {kc.rank}: "
      f"rel err = {float(compression_error(hist, fac)):.4f}, "
      f"cache {dense_bytes/1e3:.0f}KB -> {comp_bytes/1e3:.0f}KB "
      f"({dense_bytes/comp_bytes:.1f}x smaller)")
