"""Batched serving example: prefill + decode a smoke-scale model on an
8-device (data×model) mesh.

  PYTHONPATH=src python examples/serve_lm.py [--arch gemma3-12b]
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--kv-compress", type=int, default=8, metavar="RANK",
                    help="KV compression rank for full-attention layers (0 = dense)")
    args = ap.parse_args()
    serve_mod.main([
        "--arch", args.arch, "--smoke",
        "--batch", "4", "--prompt-len", "48", "--gen", "24", "--mesh", "4x2",
        "--kv-compress", str(args.kv_compress),
    ])
    print("serve_lm example OK")


if __name__ == "__main__":
    main()
