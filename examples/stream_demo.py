"""Unified panel-streaming engine (repro/stream/) — five modes:

1. one engine, two applications: SP-SVD and streaming CUR share the panel
   accumulator contract (and one jitted step)
2. DP-sharded ingestion: the column stream split over simulated workers,
   merged exactly at finalize
3. adaptive column admission: streaming CUR that discovers heavy columns
   mid-stream instead of fixing indices before the pass
4. slot eviction (v2): a late heavy column arriving after the budget fills
   evicts the weakest admitted slot — admission-only provably loses here
5. adaptive row admission (v2): heavy rows discovered mid-stream, missed
   prefixes backfilled from the sketched reconstruction

  PYTHONPATH=src python examples/stream_demo.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.svd import sp_svd_finalize, sp_svd_init, svd_error_ratio
from repro.cur import cur_relative_error, select_rows, streaming_cur_finalize, streaming_cur_init
from repro.data.synthetic import powerlaw_matrix
from repro.stream import (
    adaptive_cur_finalize,
    adaptive_cur_init,
    simulate_sharded_stream,
    stream_panels,
)

m, n, panel = 1536, 1200, 256
A = powerlaw_matrix(jax.random.key(0), m, n, 1.0)

# ---- 1. one engine, two applications ---------------------------------------
sizes = dict(c=40, r=40, c0=120, r0=120, s_c=120, s_r=120)
t0 = time.perf_counter()
st = stream_panels(sp_svd_init(jax.random.key(1), m, n, sizes=sizes, panel=panel), A, panel)
U, S, V = sp_svd_finalize(st)
t_svd = time.perf_counter() - t0
print(f"SP-SVD   : {n // panel + 1} panels in {t_svd*1e3:6.1f} ms, "
      f"err ratio (k=10) = {float(svd_error_ratio(A, U, S, V, 10)):+.4f}")

ci = jax.random.choice(jax.random.key(2), n, (20,), replace=False)
ri = select_rows(jax.random.key(3), A, 20, "uniform").idx
t0 = time.perf_counter()
stc = streaming_cur_init(jax.random.key(4), m, n, ci, ri, sketch="countsketch", panel=panel)
res = streaming_cur_finalize(stream_panels(stc, A, panel))
t_cur = time.perf_counter() - t0
print(f"CUR      : same panel loop in {t_cur*1e3:6.1f} ms, "
      f"rel err = {float(cur_relative_error(A, res)):.4f}")

# ---- 2. DP-sharded ingestion ------------------------------------------------
single = stream_panels(sp_svd_init(jax.random.key(1), m, n, sizes=sizes, panel=panel), A, panel)
for W in (2, 4):
    shard = simulate_sharded_stream(
        sp_svd_init(jax.random.key(1), m, n, sizes=sizes, panel=panel), A, panel, W
    )
    delta = float(jnp.max(jnp.abs(shard.M - single.M)))
    print(f"DP x{W}    : sharded panel stream merged exactly (max |ΔM| = {delta:.2e})")

# ---- 3. adaptive column admission -------------------------------------------
B = 0.05 * powerlaw_matrix(jax.random.key(5), m, n, 1.5)
spikes = jax.random.choice(jax.random.key(6), n, (8,), replace=False)
B = B.at[:, spikes].add(6.0 * jax.random.normal(jax.random.key(7), (m, 8)))

sta = adaptive_cur_init(jax.random.key(8), m, n, 12, ri, sketch="countsketch",
                        panel=panel, panel_cap=3)
res_a = adaptive_cur_finalize(stream_panels(sta, B, panel))
found = sorted(set(np.asarray(spikes).tolist()) & set(np.asarray(res_a.col_idx).tolist()))

cu = jax.random.choice(jax.random.key(9), n, (12,), replace=False)
stu = streaming_cur_init(jax.random.key(10), m, n, cu, ri, sketch="countsketch", panel=panel)
res_u = streaming_cur_finalize(stream_panels(stu, B, panel))

print(f"adaptive : admitted {len(found)}/8 planted spikes mid-stream, "
      f"rel err = {float(cur_relative_error(B, res_a)):.4f} "
      f"vs fixed-uniform {float(cur_relative_error(B, res_u)):.4f} at equal c")

# ---- 4. slot eviction: late heavy columns after the budget fills -------------
from repro.data.synthetic import late_spike_matrix, spiked_rows_matrix

D, early_pos, late_pos = late_spike_matrix(jax.random.key(11), m, n)
early_set = set(np.asarray(early_pos).tolist())
late_set = set(np.asarray(late_pos).tolist())
c = 8
runs = {}
for label, sg in (("admission-only", None), ("eviction", 2.0)):
    st = adaptive_cur_init(jax.random.key(12), m, n, c, ri, sketch="countsketch",
                           panel=panel, panel_cap=c // 2, swap_gain=sg)
    st = stream_panels(st, D, panel)
    res = adaptive_cur_finalize(st)
    runs[label] = (st, res, float(cur_relative_error(D, res)))

(st0, res0, err0), (st1, res1, err1) = runs["admission-only"], runs["eviction"]
held0 = set(np.asarray(res0.col_idx).tolist())
held1 = set(np.asarray(res1.col_idx).tolist())
evicted = sorted(held0 - held1 - {-1})
print(f"eviction : {len(early_set)} early spikes fill the c={c} budget, then "
      f"{len(late_set)} heavier ones arrive late")
print(f"           admission-only holds {sorted(held0 - {-1})} "
      f"(late captured {len(held0 & late_set)}/{len(late_set)}), rel err = {err0:.4f}")
print(f"           eviction ({int(st1.ctx.n_evicted)} swaps) evicted {evicted}, now holds "
      f"{sorted(held1 - {-1})} (late captured {len(held1 & late_set)}/{len(late_set)}), "
      f"rel err = {err1:.4f}")

# ---- 5. adaptive row admission with sketched backfill ------------------------
E, row_pos = spiked_rows_matrix(jax.random.key(13), m, n)
st_f = adaptive_cur_init(jax.random.key(14), m, n, 12, ri, sketch="countsketch",
                         panel=panel, panel_cap=3)
res_f = adaptive_cur_finalize(stream_panels(st_f, E, panel))
st_r = adaptive_cur_init(jax.random.key(14), m, n, 12, None, r=ri.shape[0],
                         sketch="countsketch", panel=panel, panel_cap=3, panel_cap_rows=2)
st_r = stream_panels(st_r, E, panel)
res_r = adaptive_cur_finalize(st_r)
got = sorted(set(np.asarray(row_pos).tolist()) & set(np.asarray(res_r.row_idx).tolist()))
offs = np.asarray(st_r.ctx.rows.admit_off)
print(f"rows     : admitted {len(got)}/{len(np.asarray(row_pos))} planted heavy rows "
      f"(admit offsets {sorted(int(o) for o in offs[offs >= 0])}), "
      f"rel err = {float(cur_relative_error(E, res_r)):.4f} "
      f"vs fixed pre-pass rows {float(cur_relative_error(E, res_f)):.4f} at equal r")
