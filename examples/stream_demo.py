"""Unified panel-streaming engine (repro/stream/) — three modes:

1. one engine, two applications: SP-SVD and streaming CUR share the panel
   accumulator contract (and one jitted step)
2. DP-sharded ingestion: the column stream split over simulated workers,
   merged exactly at finalize
3. adaptive column admission: streaming CUR that discovers heavy columns
   mid-stream instead of fixing indices before the pass

  PYTHONPATH=src python examples/stream_demo.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.svd import sp_svd_finalize, sp_svd_init, svd_error_ratio
from repro.cur import cur_relative_error, select_rows, streaming_cur_finalize, streaming_cur_init
from repro.data.synthetic import powerlaw_matrix
from repro.stream import (
    adaptive_cur_finalize,
    adaptive_cur_init,
    simulate_sharded_stream,
    stream_panels,
)

m, n, panel = 1536, 1200, 256
A = powerlaw_matrix(jax.random.key(0), m, n, 1.0)

# ---- 1. one engine, two applications ---------------------------------------
sizes = dict(c=40, r=40, c0=120, r0=120, s_c=120, s_r=120)
t0 = time.perf_counter()
st = stream_panels(sp_svd_init(jax.random.key(1), m, n, sizes=sizes, panel=panel), A, panel)
U, S, V = sp_svd_finalize(st)
t_svd = time.perf_counter() - t0
print(f"SP-SVD   : {n // panel + 1} panels in {t_svd*1e3:6.1f} ms, "
      f"err ratio (k=10) = {float(svd_error_ratio(A, U, S, V, 10)):+.4f}")

ci = jax.random.choice(jax.random.key(2), n, (20,), replace=False)
ri = select_rows(jax.random.key(3), A, 20, "uniform").idx
t0 = time.perf_counter()
stc = streaming_cur_init(jax.random.key(4), m, n, ci, ri, sketch="countsketch", panel=panel)
res = streaming_cur_finalize(stream_panels(stc, A, panel))
t_cur = time.perf_counter() - t0
print(f"CUR      : same panel loop in {t_cur*1e3:6.1f} ms, "
      f"rel err = {float(cur_relative_error(A, res)):.4f}")

# ---- 2. DP-sharded ingestion ------------------------------------------------
single = stream_panels(sp_svd_init(jax.random.key(1), m, n, sizes=sizes, panel=panel), A, panel)
for W in (2, 4):
    shard = simulate_sharded_stream(
        sp_svd_init(jax.random.key(1), m, n, sizes=sizes, panel=panel), A, panel, W
    )
    delta = float(jnp.max(jnp.abs(shard.M - single.M)))
    print(f"DP x{W}    : sharded panel stream merged exactly (max |ΔM| = {delta:.2e})")

# ---- 3. adaptive column admission -------------------------------------------
B = 0.05 * powerlaw_matrix(jax.random.key(5), m, n, 1.5)
spikes = jax.random.choice(jax.random.key(6), n, (8,), replace=False)
B = B.at[:, spikes].add(6.0 * jax.random.normal(jax.random.key(7), (m, 8)))

sta = adaptive_cur_init(jax.random.key(8), m, n, 12, ri, sketch="countsketch",
                        panel=panel, panel_cap=3)
res_a = adaptive_cur_finalize(stream_panels(sta, B, panel))
found = sorted(set(np.asarray(spikes).tolist()) & set(np.asarray(res_a.col_idx).tolist()))

cu = jax.random.choice(jax.random.key(9), n, (12,), replace=False)
stu = streaming_cur_init(jax.random.key(10), m, n, cu, ri, sketch="countsketch", panel=panel)
res_u = streaming_cur_finalize(stream_panels(stu, B, panel))

print(f"adaptive : admitted {len(found)}/8 planted spikes mid-stream, "
      f"rel err = {float(cur_relative_error(B, res_a)):.4f} "
      f"vs fixed-uniform {float(cur_relative_error(B, res_u)):.4f} at equal c")
