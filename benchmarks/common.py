"""Shared benchmark utilities: timing, synthetic matrices, CSV rows."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def time_call(fn, *args, warmup: int = 1, iters: int = 3, **kw) -> float:
    """Median wall-time in microseconds of fn(*args) (jit-compiled callers)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def powerlaw_matrix(key, m: int, n: int, decay: float = 1.0, dtype=jnp.float32):
    """Dense matrix with σ_i ∝ i^-decay (the spectral profile of the paper's
    dense LIBSVM datasets; offline substitution — see DESIGN.md §8)."""
    k1, k2 = jax.random.split(key)
    r = min(m, n)
    U, _ = jnp.linalg.qr(jax.random.normal(k1, (m, r), dtype))
    V, _ = jnp.linalg.qr(jax.random.normal(k2, (n, r), dtype))
    sv = jnp.arange(1, r + 1, dtype=dtype) ** (-decay)
    return (U * sv[None, :]) @ V.T


def sparse_matrix(key, m: int, n: int, density: float = 0.002, dtype=jnp.float32):
    """Sparse-profile matrix (rcv1/news20 substitution): Bernoulli mask × normal."""
    k1, k2 = jax.random.split(key)
    mask = jax.random.bernoulli(k1, density, (m, n))
    vals = jax.random.normal(k2, (m, n), dtype)
    return jnp.where(mask, vals, 0.0)


def clustered_points(key, n: int, d: int, n_clusters: int = 10, spread: float = 1.0):
    """Clustered Gaussian data for RBF kernels (§6.2 datasets substitution)."""
    k1, k2, k3 = jax.random.split(key, 3)
    centers = jax.random.normal(k1, (n_clusters, d)) * 3.0
    assign = jax.random.randint(k2, (n,), 0, n_clusters)
    return centers[assign] + spread * jax.random.normal(k3, (n, d))


def tune_rbf_sigma(X, k: int = 15, target_eta: float = 0.7, iters: int = 20) -> float:
    """Bisect σ so that η = ||K_k||²_F/||K||²_F ≈ target (paper Table 6 protocol)."""
    from repro.core.spsd import rbf_kernel_oracle

    lo, hi = 1e-6, 1e2
    for _ in range(iters):
        mid = float(np.sqrt(lo * hi))
        K = rbf_kernel_oracle(X, mid)(None, None)
        ev = jnp.linalg.eigvalsh(K.astype(jnp.float64) if False else K)
        ev2 = jnp.sort(ev**2)[::-1]
        eta = float(jnp.sum(ev2[:k]) / jnp.sum(ev2))
        if eta > target_eta:
            lo = mid  # kernel too close to low rank? raise sigma decreases eta
        else:
            hi = mid
        if abs(eta - target_eta) < 0.05:
            return mid
    return float(np.sqrt(lo * hi))
