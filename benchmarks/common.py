"""Shared benchmark utilities: timing, synthetic matrices, CSV rows, and
the standard ``BENCH_<module>.json`` artifact writer (every table/figure
module — ``gmr_error``, ``cur_decomp``, ``spsd_approx``,
``single_pass_svd``, ``sketch_perf``, ``stream_bench`` — writes through
it; ``check_regression`` gates any artifact with a committed baseline)."""

from __future__ import annotations

import json
import os
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import (  # noqa: F401 — re-export
    drifting_spectrum_matrix,
    late_spike_matrix,
    lowrank_plus_noise,
    powerlaw_matrix,
    sparse_matrix,
    spiked_decay_matrix,
    spiked_rows_matrix,
)


def write_bench_json(module: str, rows: list, meta: dict | None = None, out_dir: str | None = None) -> str:
    """Write the standard ``BENCH_<module>.json`` artifact and return its path.

    Shape: ``{"bench", "schema", "meta", "rows"}`` where each row keeps the
    CSV contract keys (``name``, ``us_per_call``, ``derived``); private
    ``_``-prefixed keys are stripped.
    """
    clean = [{k: v for k, v in row.items() if not k.startswith("_")} for row in rows]
    artifact = {
        "bench": module,
        "schema": 1,
        "meta": {
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "platform": platform.platform(),
            **(meta or {}),
        },
        "rows": clean,
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir or os.getcwd(), f"BENCH_{module}.json")
    with open(path, "w") as f:
        json.dump(artifact, f, indent=2)
    return path


def time_call(fn, *args, warmup: int = 1, iters: int = 3, **kw) -> float:
    """Median wall-time in microseconds of fn(*args) (jit-compiled callers)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def time_calls_interleaved(fns: dict, warmup: int = 1, rounds: int = 7) -> dict:
    """Best (min) wall-time in µs per named thunk, interleaved in a
    **randomized order per round** (seeded — reproducible).

    Timing the configurations of a comparison back-to-back (all iterations
    of A, then all of B) folds ambient drift — CPU frequency, container
    neighbours, allocator state — into the *difference* being measured.
    Interleaving one iteration of every configuration per round exposes
    each to the same drift. The per-round order is a fresh seeded
    permutation rather than a fixed cycle: a fixed cycle gives every
    config a *constant predecessor*, and the tail of the predecessor's
    call (async deallocation, cache displacement) lands on the successor's
    timer — a persistent few-percent adjacency bias that min-of-rounds
    cannot remove because it is systematic, not noise (observed as
    byte-identical programs timing 2–4% apart). Random permutations make
    predecessors uniform, so the per-config min over enough rounds is
    order-unbiased and identical workloads measure equal.
    """
    for fn in fns.values():
        for _ in range(warmup):
            jax.block_until_ready(fn())
    items = list(fns.items())
    rounds = max(rounds, 2 * len(items))
    rng = np.random.default_rng(0)
    best = {name: float("inf") for name in fns}
    for _ in range(rounds):
        for j in rng.permutation(len(items)):
            name, fn = items[j]
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best[name] = min(best[name], (time.perf_counter() - t0) * 1e6)
    return best


def clustered_points(key, n: int, d: int, n_clusters: int = 10, spread: float = 1.0):
    """Clustered Gaussian data for RBF kernels (§6.2 datasets substitution)."""
    k1, k2, k3 = jax.random.split(key, 3)
    centers = jax.random.normal(k1, (n_clusters, d)) * 3.0
    assign = jax.random.randint(k2, (n,), 0, n_clusters)
    return centers[assign] + spread * jax.random.normal(k3, (n, d))


def tune_rbf_sigma(X, k: int = 15, target_eta: float = 0.7, iters: int = 20) -> float:
    """Bisect σ so that η = ||K_k||²_F/||K||²_F ≈ target (paper Table 6 protocol)."""
    from repro.core.spsd import rbf_kernel_oracle

    lo, hi = 1e-6, 1e2
    for _ in range(iters):
        mid = float(np.sqrt(lo * hi))
        K = rbf_kernel_oracle(X, mid)(None, None)
        ev = jnp.linalg.eigvalsh(K.astype(jnp.float64) if False else K)
        ev2 = jnp.sort(ev**2)[::-1]
        eta = float(jnp.sum(ev2[:k]) / jnp.sum(ev2))
        if eta > target_eta:
            lo = mid  # kernel too close to low rank? raise sigma decreases eta
        else:
            hi = mid
        if abs(eta - target_eta) < 0.05:
            return mid
    return float(np.sqrt(lo * hi))
