"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only gmr_error,...]

Prints ``name,us_per_call,derived`` CSV rows (the skeleton contract).
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="reduced sweeps for CI")
    ap.add_argument("--only", default="", help="comma-separated module subset")
    args = ap.parse_args()

    from . import (
        cur_decomp,
        gmr_error,
        roofline,
        serve_bench,
        single_pass_svd,
        sketch_perf,
        spsd_approx,
        stream_bench,
    )

    modules = {
        "gmr_error": gmr_error,        # paper Fig. 1  (§6.1)
        "cur_decomp": cur_decomp,      # paper §1 application 1 (repro/cur/)
        "spsd_approx": spsd_approx,    # paper Fig. 2 + Table 7 (§6.2)
        "single_pass_svd": single_pass_svd,  # paper Fig. 3 (§6.3)
        "sketch_perf": sketch_perf,    # kernel layer
        "roofline": roofline,          # §Roofline terms from dry-run artifacts
        "stream_bench": stream_bench,  # streaming engine: adaptive/evict/rows + DP parity
        "serve_bench": serve_bench,    # serving: decode throughput + KV compression
    }
    if args.only:
        keep = set(args.only.split(","))
        modules = {k: v for k, v in modules.items() if k in keep}

    print("name,us_per_call,derived")
    for name, mod in modules.items():
        t0 = time.time()
        try:
            rows = mod.run(quick=args.quick)
        except Exception as e:  # noqa: BLE001 — surface per-module failures in CSV
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            continue
        for row in rows:
            derived = str(row["derived"]).replace(",", ";")
            print(f"{row['name']},{row['us_per_call']},{derived}")
        print(f"{name}/_total,{(time.time()-t0)*1e6:.0f},module_wall_time", file=sys.stderr)


if __name__ == "__main__":
    main()
