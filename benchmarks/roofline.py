"""§Roofline: three-term roofline per (arch × shape) from dry-run artifacts.

  compute    = flops_per_device / 197 TFLOP/s          (v5e bf16 peak)
  memory     = hbm_bytes_per_device / 819 GB/s         (v5e HBM BW)
  collective = wire_bytes_per_device / 50 GB/s         (ICI, ring-adjusted
               per-op wire bytes; see launch/hlo_census.py)

flops / hbm_bytes / wire_bytes come from the loop-aware HLO census of the
*compiled per-device module* (XLA's own cost_analysis counts while bodies
once — verified and documented; both numbers are in the artifacts).

MODEL_FLOPS = 6·N·T (train), 2·N·T (prefill), 2·N·B (decode step), with
N = active params for MoE. The useful-compute ratio MODEL_FLOPS/HLO_FLOPs
exposes remat recompute and attention/dispatch overheads.

Streaming-engine programs get the same treatment live (no artifacts):
``stream_rows`` compiles the engine's scan fused/unfused, censuses the
compiled HLO, and reports HBM-bound seconds per panel on the v5e numbers.
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link (assignment constant; 1 effective link — conservative)

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def model_flops_global(rec: dict, shapes: dict) -> float:
    cell = shapes[rec["shape"]]
    N = rec["n_active_params"]
    if cell.kind == "train":
        return 6.0 * N * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * N * cell.global_batch * cell.seq_len
    return 2.0 * N * cell.global_batch  # decode: one token per sequence


def terms(rec: dict) -> dict:
    compute = rec["flops_per_device"] / PEAK_FLOPS
    memory = rec["hbm_bytes_per_device"] / HBM_BW
    wire = sum(v["wire_bytes"] for v in rec["collectives"].values())
    collective = wire / ICI_BW
    dom = max(("compute", compute), ("memory", memory), ("collective", collective), key=lambda t: t[1])
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dom[0],
        "bound_s": dom[1],
        "wire_gb": wire / 1e9,
    }


def load_records(mesh: str = "16x16", tag: str = ""):
    recs = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, f"*__{mesh}{('__' + tag) if tag else ''}.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("tag", "") != tag:
            continue
        recs.append(r)
    return recs


def advice(rec: dict, t: dict) -> str:
    if t["dominant"] == "collective":
        ag = rec["collectives"].get("all-gather", {}).get("wire_bytes", 0)
        ar = rec["collectives"].get("all-reduce", {}).get("wire_bytes", 0)
        if ag > ar:
            return "all-gather bound: reduce FSDP regathers (bigger TP share / persistent gathered weights / EP dispatch)"
        return "all-reduce bound: bf16 grad reduction, GMR gradient compression, fewer activation psums"
    if t["dominant"] == "memory":
        return "HBM bound: fuse sketches (Pallas), bf16 moments, cut remat re-reads / logit round-trips"
    ratio = model_flops_global(rec, _shapes()) / max(rec["flops_per_device"] * _chips(rec), 1.0)
    if ratio < 0.5:
        return "compute bound w/ low useful ratio: reduce remat refwd, trim attention/dispatch overcompute"
    return "compute bound near model flops: healthy; next win is overlap of collectives with compute"


def _chips(rec) -> int:
    return 512 if rec["mesh"] == "2x16x16" else 256


def _shapes():
    from repro.configs import SHAPES

    return SHAPES


def build_table(mesh: str = "16x16", tag: str = "") -> str:
    shapes = _shapes()
    recs = load_records(mesh, tag)
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | mem/dev GB | MODEL_TFLOP | useful ratio | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        t = terms(r)
        mf = model_flops_global(r, shapes)
        hlo_global = r["flops_per_device"] * _chips(r)
        ratio = mf / max(hlo_global, 1.0)
        mem = r["memory"]["peak_estimate_bytes"] / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | {t['memory_s']:.3e} | "
            f"{t['collective_s']:.3e} | **{t['dominant']}** | {mem:.1f} | {mf/1e12:.1f} | "
            f"{ratio:.2f} | {advice(r, t)} |"
        )
    return "\n".join(lines)


def stream_rows(quick: bool = False) -> list:
    """Roofline rows for compiled streaming-engine programs (live census).

    Compiles the engine's ``scan_chunk`` fused and unfused on a reference
    config, runs the loop-aware HLO census on each program, and converts
    bytes-per-panel into the memory roofline term (the streaming engine is
    HBM-bound by construction — there is no collective term and the flop
    term is negligible at these panel shapes). The fused/unfused pair puts
    the scan-body traffic win of the fused route on the same axis as the
    dry-run rooflines above.
    """
    import jax
    import jax.numpy as jnp

    from repro.cur.streaming import streaming_cur_init
    from repro.launch.hlo_census import census_stream_program

    m, n, panel, c, r = (512, 512, 128, 16, 16)
    st = streaming_cur_init(
        jax.random.PRNGKey(0), m, n,
        col_idx=jnp.arange(c, dtype=jnp.int32),
        row_idx=jnp.arange(r, dtype=jnp.int32),
        sketch="countsketch", panel=panel,
    )
    A = jnp.zeros((m, n), jnp.float32)
    rows = []
    for fused in (True, False) if not quick else (True,):
        cen = census_stream_program(st, A, panel, fused=fused)
        mem_s = cen["bytes_per_panel"] / HBM_BW
        body_s = cen["scan_body_bytes_per_panel"] / HBM_BW
        rows.append({
            "name": f"roofline/stream/cur_{m}x{n}_p{panel}/{'fused' if fused else 'unfused'}",
            "us_per_call": round(mem_s * 1e6, 3),  # HBM-bound time per panel
            "derived": (
                f"dominant=memory;memory_s={mem_s:.3e};scan_body_memory_s={body_s:.3e};"
                f"bytes_per_panel={cen['bytes_per_panel']:.3e};"
                f"scan_body_bytes_per_panel={cen['scan_body_bytes_per_panel']:.3e};"
                f"n_ops={cen['n_ops']:.0f}"
            ),
        })
    return rows


def run(trials: int = 1, quick: bool = False) -> list:
    rows = stream_rows(quick)
    shapes = _shapes()
    for mesh in ("16x16", "2x16x16"):
        for r in load_records(mesh):
            t = terms(r)
            mf = model_flops_global(r, shapes)
            ratio = mf / max(r["flops_per_device"] * _chips(r), 1.0)
            rows.append({
                "name": f"roofline/{r['arch']}/{r['shape']}/{mesh}",
                "us_per_call": round(t["bound_s"] * 1e6, 1),  # roofline-bound step time
                "derived": (
                    f"dominant={t['dominant']};compute_s={t['compute_s']:.3e};"
                    f"memory_s={t['memory_s']:.3e};collective_s={t['collective_s']:.3e};"
                    f"useful_ratio={ratio:.2f};mem_gb={r['memory']['peak_estimate_bytes']/1e9:.1f}"
                ),
            })
    if not rows:
        rows.append({"name": "roofline/NO_ARTIFACTS", "us_per_call": 0.0,
                     "derived": "run `python -m repro.launch.dryrun --all --both-meshes` first"})
    return rows


if __name__ == "__main__":
    print(build_table("16x16"))
