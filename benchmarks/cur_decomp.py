"""CUR decomposition benchmark: error-vs-time for the core-solve paths.

Sweeps matrix sizes (up to 4096² in full mode) and methods:

* ``exact``       — oracle core ``U* = C† A R†`` (O(c·m·n) matmul-bound)
* ``fast-lev``    — Algorithm-1 sketched core with leverage-score *sampling*
                    sketches (row gathers; Table-3) — the deployable path
* ``fast-gauss``  — Algorithm-1 with dense Gaussian sketches (Table-2)
* ``cross``       — uniform-Nyström-style baseline ``U = W†`` with
                    ``W = A[row_idx][:, col_idx]`` (cheapest, weakest error)

All methods share one (col_idx, row_idx) set per matrix so the reported
``resid_ratio`` (= ‖A−CUR‖_F / ‖A−CU*R‖_F) isolates core quality.
Emits CSV rows via ``benchmarks.run`` and the standard
``BENCH_cur_decomp.json`` artifact (``benchmarks.common.write_bench_json``).

  PYTHONPATH=src python -m benchmarks.cur_decomp [--smoke]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.cur import cur_sketch_sizes, exact_cur, fast_cur
from repro.cur.selection import select_columns, select_rows

from .common import powerlaw_matrix, sparse_matrix, time_call, write_bench_json


def _cross_core(A, col_idx, row_idx):
    """Uniform-Nyström-style: pinv of the intersection block W."""
    W = jnp.take(jnp.take(A, row_idx, axis=0), col_idx, axis=1)  # (r, c)
    dt = jnp.promote_types(A.dtype, jnp.float32)
    return jnp.linalg.pinv(W.astype(dt), rtol=1e-6).astype(A.dtype)  # (c, r)


def run(trials: int = 3, quick: bool = False) -> list:
    rows = []
    c = r = 20
    eps, rho_est = 0.05, 2.0
    shapes = [("powerlaw", 512, 512)] if quick else [
        ("powerlaw", 1024, 1024),
        ("powerlaw", 4096, 4096),
        ("sparse", 4096, 4096),
    ]
    sizes = cur_sketch_sizes(c, r, eps=eps, rho=rho_est)
    for ds, m, n in shapes:
        key = jax.random.key(m + n)
        A = powerlaw_matrix(key, m, n, 1.0) if ds == "powerlaw" else sparse_matrix(key, m, n, 0.002)
        ci = select_columns(jax.random.key(1), A, c, "uniform").idx
        ri = select_rows(jax.random.key(2), A, r, "uniform").idx
        s_c, s_r = min(sizes["s_c"], m), min(sizes["s_r"], n)

        res_exact = exact_cur(A, ci, ri)
        base = float(jnp.linalg.norm(A - res_exact.C @ res_exact.U @ res_exact.R))
        base = max(base, 1e-12)

        methods = {
            "exact": jax.jit(lambda k: exact_cur(A, ci, ri).U),
            "fast-lev": jax.jit(
                lambda k: fast_cur(k, A, col_idx=ci, row_idx=ri, sketch="leverage",
                                   s_c=s_c, s_r=s_r).U
            ),
            "fast-gauss": jax.jit(
                lambda k: fast_cur(k, A, col_idx=ci, row_idx=ri, sketch="gaussian",
                                   s_c=s_c, s_r=s_r).U
            ),
            "cross": jax.jit(lambda k: _cross_core(A, ci, ri)),
        }
        us_by_method = {}
        for name, fn in methods.items():
            resids = []
            for t in range(trials):
                U = fn(jax.random.key(100 + t))
                resids.append(float(jnp.linalg.norm(A - res_exact.C @ U @ res_exact.R)))
            us = time_call(fn, jax.random.key(0))
            us_by_method[name] = us
            ratio = float(np.mean(resids)) / base
            rows.append({
                "name": f"cur/{ds}/{m}x{n}/{name}",
                "us_per_call": round(us, 1),
                "derived": f"resid_ratio={ratio:.4f};s_c={s_c};s_r={s_r};c={c};r={r}",
                "_resid_ratio": ratio,
            })
        speedup = us_by_method["exact"] / max(us_by_method["fast-lev"], 1e-9)
        rows.append({
            "name": f"cur/{ds}/{m}x{n}/sketch_speedup",
            "us_per_call": 0.0,
            "derived": f"exact_over_fastlev={speedup:.2f}x"
                       f"({'PASS' if (m < 4096 or speedup > 1.0) else 'FAIL'}@4k-criterion)",
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="single small shape, 1 trial (CI)")
    ap.add_argument("--out-dir", default=None, help="where to write BENCH_cur_decomp.json")
    args = ap.parse_args()
    rows = run(trials=1 if args.smoke else 3, quick=args.smoke)
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']},{str(row['derived']).replace(',', ';')}")
    path = write_bench_json("cur_decomp", rows, meta={"smoke": args.smoke}, out_dir=args.out_dir)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
