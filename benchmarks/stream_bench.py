"""Panel-streaming engine benchmark: adaptive vs fixed-uniform streaming CUR
and DP-sharded ingestion, on spiked-decay matrices.

Rows (→ ``BENCH_stream.json`` via ``benchmarks.common.write_bench_json``):

* ``stream/cur/<m>x<n>/fixed-uniform/w<W>``  — pre-pass uniform col_idx
* ``stream/cur/<m>x<n>/adaptive/w<W>``       — residual-driven in-stream
  admission (same column budget c, same row_idx) on 1/2/4 simulated DP
  workers; ``derived`` records the relative Frobenius error so the
  adaptive-beats-uniform claim is auditable from the artifact.
* ``stream/spsvd/<m>x<n>/parity/w<W>``       — max |Δ| between DP-sharded
  and single-host SP-SVD accumulators (exactness evidence).

  PYTHONPATH=src python -m benchmarks.stream_bench [--smoke]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.svd import sp_svd_init
from repro.cur import cur_relative_error, select_rows, streaming_cur_finalize, streaming_cur_init
from repro.stream import (
    adaptive_cur_finalize,
    adaptive_cur_init,
    simulate_sharded_stream,
    stream_panels,
)

from .common import spiked_decay_matrix, time_call, write_bench_json


def _stream(state, A, panel, workers):
    if workers == 1:
        return stream_panels(state, A, panel)
    return simulate_sharded_stream(state, A, panel, workers)


def run(trials: int = 3, quick: bool = False) -> list:
    rows = []
    shapes = [(384, 320, 64)] if quick else [(1024, 768, 128), (2048, 1024, 128)]
    c = r = 16
    for m, n, panel in shapes:
        A, pos = spiked_decay_matrix(jax.random.key(m + n), m, n)
        ri = select_rows(jax.random.key(1), A, r, "uniform").idx
        errs = {}
        for workers in (1, 2, 4):
            for method in ("fixed-uniform", "adaptive"):
                per_trial = []
                admitted_spikes = []
                for t in range(trials):
                    if method == "fixed-uniform":
                        ci = jax.random.choice(jax.random.key(100 + t), n, (c,), replace=False)
                        st = streaming_cur_init(
                            jax.random.key(200 + t), m, n, ci, ri,
                            sketch="countsketch", panel=panel,
                        )
                        res = streaming_cur_finalize(_stream(st, A, panel, workers))
                    else:
                        st = adaptive_cur_init(
                            jax.random.key(200 + t), m, n, c, ri,
                            sketch="countsketch", panel=panel, panel_cap=2,
                        )
                        res = adaptive_cur_finalize(_stream(st, A, panel, workers))
                        admitted_spikes.append(
                            len(set(np.asarray(pos).tolist()) & set(np.asarray(res.col_idx).tolist()))
                        )
                    per_trial.append(float(cur_relative_error(A, res)))
                rel = float(np.mean(per_trial))
                errs[(method, workers)] = rel

                def once(method=method, workers=workers):
                    if method == "fixed-uniform":
                        ci = jax.random.choice(jax.random.key(100), n, (c,), replace=False)
                        st = streaming_cur_init(
                            jax.random.key(200), m, n, ci, ri, sketch="countsketch", panel=panel
                        )
                        return streaming_cur_finalize(_stream(st, A, panel, workers)).U
                    st = adaptive_cur_init(
                        jax.random.key(200), m, n, c, ri,
                        sketch="countsketch", panel=panel, panel_cap=2,
                    )
                    return adaptive_cur_finalize(_stream(st, A, panel, workers)).U

                us = time_call(once, warmup=1, iters=1 if quick else 2)
                derived = f"rel_err={rel:.4f};c={c};panel={panel}"
                if method == "adaptive":
                    derived += f";spikes_admitted={np.mean(admitted_spikes):.1f}/{len(pos)}"
                rows.append({
                    "name": f"stream/cur/{m}x{n}/{method}/w{workers}",
                    "us_per_call": round(us, 1),
                    "derived": derived,
                    "_rel_err": rel,
                })
        for workers in (1, 2, 4):
            win = errs[("fixed-uniform", workers)] / max(errs[("adaptive", workers)], 1e-12)
            rows.append({
                "name": f"stream/cur/{m}x{n}/adaptive_win/w{workers}",
                "us_per_call": 0.0,
                "derived": f"uniform_over_adaptive={win:.2f}x"
                           f"({'PASS' if win > 1.0 else 'FAIL'}@equal-c)",
            })

        # SP-SVD DP-sharded parity evidence
        sizes = dict(c=2 * c, r=2 * r, c0=6 * c, r0=6 * r, s_c=6 * c, s_r=6 * r)
        single = stream_panels(
            sp_svd_init(jax.random.key(3), m, n, sizes=sizes, panel=panel), A, panel
        )
        for workers in (2, 4):
            shard = simulate_sharded_stream(
                sp_svd_init(jax.random.key(3), m, n, sizes=sizes, panel=panel), A, panel, workers
            )
            delta = max(
                float(jnp.max(jnp.abs(shard.C - single.C))),
                float(jnp.max(jnp.abs(shard.R - single.R))),
                float(jnp.max(jnp.abs(shard.M - single.M))),
            )
            rows.append({
                "name": f"stream/spsvd/{m}x{n}/parity/w{workers}",
                "us_per_call": 0.0,
                "derived": f"max_abs_delta={delta:.2e}",
            })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="single small shape, 1 trial (CI)")
    ap.add_argument("--out-dir", default=None, help="where to write BENCH_stream.json")
    args = ap.parse_args()
    rows = run(trials=1 if args.smoke else 3, quick=args.smoke)
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']},{str(row['derived']).replace(',', ';')}")
    path = write_bench_json("stream", rows, meta={"smoke": args.smoke}, out_dir=args.out_dir)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
