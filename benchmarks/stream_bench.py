"""Panel-streaming engine benchmark: adaptive vs fixed-uniform streaming CUR,
eviction vs admission-only, adaptive vs fixed rows, and DP-sharded
ingestion, on spiked / late-spike / drifting-spectrum matrices.

Rows (→ ``BENCH_stream.json`` via ``benchmarks.common.write_bench_json``):

* ``stream/cur/<m>x<n>/fixed-uniform/w<W>``  — pre-pass uniform col_idx
* ``stream/cur/<m>x<n>/adaptive/w<W>``       — residual-driven in-stream
  admission (same column budget c, same row_idx) on 1/2/4 simulated DP
  workers; ``derived`` records the relative Frobenius error so the
  adaptive-beats-uniform claim is auditable from the artifact.
* ``stream/cur/<scenario>/<m>x<n>/admit-only|evict`` — the v2 replacement
  policy on streams where admission-only *provably* loses: ``late-spike``
  (heavy columns arriving after the budget fills) and ``drift`` (dominant
  subspace drifting stronger block by block). ``evict_win`` rows record the
  admission-only/evict error ratio with PASS/FAIL at equal (c, r) budget.
* ``stream/cur/rows/<m>x<n>/fixed|adaptive`` — fixed pre-pass uniform rows
  vs in-stream row admission (equal r budget, identical adaptive columns)
  on spiked-rows matrices, plus a ``row_win`` PASS/FAIL row.
* ``stream/cur/<m>x<n>/adaptive+tel/w<W>`` — the adaptive config re-timed
  with the in-scan telemetry frame attached (``telemetry=True``); the
  ``+tel`` suffix pairs each row with its untelemetered twin so
  ``check_regression.py --overhead-suffix "+tel"`` can gate the overhead
  (acceptance: ≤ 1.3×) *within* one artifact, host-invariantly.
* ``stream/obs/est/<family>/<m>x<n>`` — the a-posteriori error estimator
  (``repro.obs.estimate_rel_error``) vs the true relative Frobenius error
  on each stream family; ``ratio`` must sit inside the 2× band.
* ``stream/spsvd/<m>x<n>/parity/w<W>``       — max |Δ| between DP-sharded
  and single-host SP-SVD accumulators (exactness evidence).
* ``stream/resilient/<m>x<n>/w<W>[+ckpt8]`` — the resilient driver
  (``run_resilient_stream`` / ``run_resilient_sharded_stream``) with and
  without packed checkpointing at cadence 8 (one non-durable single-file
  save per 8 chunks, plus a final save). The ``+ckpt8`` suffix pairs each
  row with its checkpoint-free twin so ``check_regression.py
  --overhead-suffix "+ckpt8" --overhead-threshold 1.1`` gates the
  checkpoint overhead *within* one artifact (acceptance: ≤ 1.1×).

When ``--out-dir`` is given the run's host metrics (stream telemetry
summaries + profiling spans, via :mod:`repro.obs.metrics`) are dumped as
``BENCH_stream.metrics.jsonl`` next to the artifact.

  PYTHONPATH=src python -m benchmarks.stream_bench [--smoke]
"""

from __future__ import annotations

import argparse
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.svd import sp_svd_init
from repro.cur import cur_relative_error, select_rows, streaming_cur_finalize, streaming_cur_init
from repro.obs import MetricsRegistry, default_registry, estimate_rel_error, set_registry
from repro.stream import (
    ArrayPanelSource,
    adaptive_cur_finalize,
    adaptive_cur_init,
    run_resilient_sharded_stream,
    run_resilient_stream,
    simulate_sharded_stream,
    stream_panels,
)

from .common import (
    drifting_spectrum_matrix,
    late_spike_matrix,
    spiked_decay_matrix,
    spiked_rows_matrix,
    time_calls_interleaved,
    write_bench_json,
)


def _stream(state, A, panel, workers):
    """Every worker count — including w1 — runs the same sharded driver
    (one fused program either way), so the w-scaling rows measure what the
    driver actually costs per worker count. Note what that means per
    method: for *fixed-uniform* (hook-less ops) the fused driver provably
    chains the contiguous worker partition into the single-host scan, so
    its w1/w2/w4 rows execute the same program — equal rows are the
    *result* of that optimization (the pre-PR4 per-worker dispatch loop is
    what made w2/w4 ≥ 2× slower), not evidence of parallel speedup. The
    *adaptive* rows keep genuinely divergent per-worker admission state +
    in-program merge. Real multi-device execution (`mesh_sharded_stream`)
    is exercised for parity in the slow test lane, not timed here."""
    return simulate_sharded_stream(state, A, panel, workers)


def _win_row(name: str, lose_err: float, win_err: float, label: str) -> dict:
    ratio = lose_err / max(win_err, 1e-12)
    return {
        "name": name,
        "us_per_call": 0.0,
        "derived": f"{label}={ratio:.2f}x({'PASS' if ratio > 1.0 else 'FAIL'}@equal-budget)",
    }


def run_adaptive_vs_uniform(shapes, trials: int, quick: bool) -> list:
    """PR-2 scenario kept intact: admission vs fixed-uniform at equal c.

    Timing methodology (perf acceptance rows): every (method, workers)
    configuration of a shape is timed **interleaved** (one call per config
    per round, min over rounds — see
    :func:`benchmarks.common.time_calls_interleaved`) so the
    adaptive-vs-fixed and w4-vs-w1 comparisons are not polluted by ambient
    drift between sequentially-timed rows.
    """
    rows = []
    c = r = 16
    for m, n, panel in shapes:
        # Wider panels than the adversarial-stream scenarios: the adaptive
        # policy pays a per-panel constant (whitened-basis solve + admission
        # chain), so fewer, larger panels amortize it; panel_cap scales up
        # so the admission budget per column stays the same.
        panel, panel_cap = 2 * panel, 4
        A, pos = spiked_decay_matrix(jax.random.key(m + n), m, n)
        ri = select_rows(jax.random.key(1), A, r, "uniform").idx
        errs = {}
        stats = {}
        for workers in (1, 2, 4):
            for method in ("fixed-uniform", "adaptive"):
                per_trial = []
                admitted_spikes = []
                for t in range(trials):
                    if method == "fixed-uniform":
                        ci = jax.random.choice(jax.random.key(100 + t), n, (c,), replace=False)
                        st = streaming_cur_init(
                            jax.random.key(200 + t), m, n, ci, ri,
                            sketch="countsketch", panel=panel,
                        )
                        res = streaming_cur_finalize(_stream(st, A, panel, workers))
                    else:
                        st = adaptive_cur_init(
                            jax.random.key(200 + t), m, n, c, ri,
                            sketch="countsketch", panel=panel, panel_cap=panel_cap,
                        )
                        res = adaptive_cur_finalize(_stream(st, A, panel, workers))
                        admitted_spikes.append(
                            len(set(np.asarray(pos).tolist()) & set(np.asarray(res.col_idx).tolist()))
                        )
                    per_trial.append(float(cur_relative_error(A, res)))
                errs[(method, workers)] = float(np.mean(per_trial))
                stats[(method, workers)] = admitted_spikes

        # Timed calls are end-to-end (init + stream + finalize) with the init
        # compiled in the closure: fewer host dispatches per call → a much
        # tighter min-floor on a noisy shared-CPU container.
        ci0 = jax.random.choice(jax.random.key(100), n, (c,), replace=False)
        fixed_init = jax.jit(lambda key: streaming_cur_init(
            key, m, n, ci0, ri, sketch="countsketch", panel=panel))
        adapt_init = jax.jit(lambda key: adaptive_cur_init(
            key, m, n, c, ri, sketch="countsketch", panel=panel, panel_cap=panel_cap))
        # telemetered twin of the adaptive config: identical policy + shapes,
        # plus the in-scan diagnostics frame — its rows pair with the plain
        # adaptive rows via the "+tel" suffix for the overhead gate
        adapt_tel_init = jax.jit(lambda key: adaptive_cur_init(
            key, m, n, c, ri, sketch="countsketch", panel=panel,
            panel_cap=panel_cap, telemetry=True))

        def once(method, workers):
            if method == "fixed-uniform":
                st = fixed_init(jax.random.key(200))
                return streaming_cur_finalize(_stream(st, A, panel, workers)).U
            init = adapt_tel_init if method == "adaptive+tel" else adapt_init
            st = init(jax.random.key(200))
            return adaptive_cur_finalize(_stream(st, A, panel, workers)).U

        # Cyclic measurement order keeps each w's fixed/adaptive pair and the
        # w4/w1 fixed pair adjacent, so sustained contention windows hit both
        # sides of every compared pair; rotation + min handles the rest.
        fns = {
            (method, workers): (lambda method=method, workers=workers: once(method, workers))
            for workers in (4, 1, 2)
            for method in ("fixed-uniform", "adaptive", "adaptive+tel")
        }
        # rounds stretch the session across several contention cycles of the
        # shared container, so every config touches its true floor; the quick
        # lane still needs enough rounds that the telemetry-overhead gate
        # (±1.3x on paired rows) sits on converged minima, not first-touch noise
        times = time_calls_interleaved(fns, warmup=1, rounds=40 if quick else 100)
        for workers in (1, 2, 4):
            for method in ("fixed-uniform", "adaptive"):
                rel = errs[(method, workers)]
                derived = f"rel_err={rel:.4f};c={c};panel={panel}"
                if method == "adaptive":
                    derived += f";spikes_admitted={np.mean(stats[(method, workers)]):.1f}/{len(pos)}"
                rows.append({
                    "name": f"stream/cur/{m}x{n}/{method}/w{workers}",
                    "us_per_call": round(times[(method, workers)], 1),
                    "derived": derived,
                    "_rel_err": rel,
                })
            overhead = times[("adaptive+tel", workers)] / max(times[("adaptive", workers)], 1e-9)
            rows.append({
                "name": f"stream/cur/{m}x{n}/adaptive+tel/w{workers}",
                "us_per_call": round(times[("adaptive+tel", workers)], 1),
                "derived": f"telemetry_overhead={overhead:.2f}x;c={c};panel={panel}",
            })
        for workers in (1, 2, 4):
            win = errs[("fixed-uniform", workers)] / max(errs[("adaptive", workers)], 1e-12)
            rows.append({
                "name": f"stream/cur/{m}x{n}/adaptive_win/w{workers}",
                "us_per_call": 0.0,
                "derived": f"uniform_over_adaptive={win:.2f}x"
                           f"({'PASS' if win > 1.0 else 'FAIL'}@equal-c)",
            })
    return rows


def run_eviction(shapes, trials: int) -> list:
    """v2 acceptance scenario: admission-only vs eviction at equal (c, r)
    budget on streams engineered so admission-only loses (the budget fills
    on early/weaker columns before the heavy ones arrive)."""
    rows = []
    c, r = 8, 16
    for m, n, panel in shapes:
        for scenario in ("late-spike", "drift"):
            errs = {"admit-only": [], "evict": []}
            evictions = []
            for t in range(trials):
                if scenario == "late-spike":
                    A, _early, _late = late_spike_matrix(jax.random.key(m + n + 7 * t), m, n)
                else:
                    A, _bounds = drifting_spectrum_matrix(jax.random.key(m + n + 7 * t), m, n)
                ri = select_rows(jax.random.key(11 + t), A, r, "uniform").idx
                for method, sg in (("admit-only", None), ("evict", 2.0)):
                    # panel_cap = c//2 so the early/weak columns genuinely fill
                    # the budget before the heavy ones arrive — the failure
                    # mode eviction exists for
                    st = adaptive_cur_init(
                        jax.random.key(300 + t), m, n, c, ri,
                        sketch="countsketch", panel=panel, panel_cap=c // 2, swap_gain=sg,
                    )
                    st = stream_panels(st, A, panel)
                    if method == "evict":
                        evictions.append(int(st.ctx.n_evicted))
                    errs[method].append(
                        float(cur_relative_error(A, adaptive_cur_finalize(st)))
                    )
            e_admit = float(np.mean(errs["admit-only"]))
            e_evict = float(np.mean(errs["evict"]))
            rows.append({
                "name": f"stream/cur/{scenario}/{m}x{n}/admit-only",
                "us_per_call": 0.0,
                "derived": f"rel_err={e_admit:.4f};c={c};panel={panel}",
                "_rel_err": e_admit,
            })
            rows.append({
                "name": f"stream/cur/{scenario}/{m}x{n}/evict",
                "us_per_call": 0.0,
                "derived": f"rel_err={e_evict:.4f};c={c};panel={panel}"
                           f";evictions={np.mean(evictions):.1f};swap_gain=2.0",
                "_rel_err": e_evict,
            })
            rows.append(_win_row(
                f"stream/cur/{scenario}/{m}x{n}/evict_win",
                e_admit, e_evict, "admit_only_over_evict",
            ))
    return rows


def run_row_admission(shapes, trials: int) -> list:
    """v2 acceptance scenario: fixed pre-pass uniform rows vs in-stream row
    admission at equal r budget (identical adaptive-column settings), on
    matrices with planted heavy rows."""
    rows = []
    c, r = 12, 8
    for m, n, panel in shapes:
        errs = {"fixed": [], "adaptive": []}
        captured = []
        for t in range(trials):
            A, rpos = spiked_rows_matrix(jax.random.key(m + 3 * n + 13 * t), m, n)
            for method in ("fixed", "adaptive"):
                kw = (
                    dict(row_idx=select_rows(jax.random.key(21 + t), A, r, "uniform").idx)
                    if method == "fixed"
                    else dict(row_idx=None, r=r, panel_cap_rows=2)
                )
                st = adaptive_cur_init(
                    jax.random.key(400 + t), m, n, c,
                    sketch="countsketch", panel=panel, panel_cap=2, **kw,
                )
                st = stream_panels(st, A, panel)
                res = adaptive_cur_finalize(st)
                if method == "adaptive":
                    captured.append(
                        len(set(np.asarray(rpos).tolist()) & set(np.asarray(res.row_idx).tolist()))
                    )
                errs[method].append(float(cur_relative_error(A, res)))
        e_fixed = float(np.mean(errs["fixed"]))
        e_adapt = float(np.mean(errs["adaptive"]))
        rows.append({
            "name": f"stream/cur/rows/{m}x{n}/fixed",
            "us_per_call": 0.0,
            "derived": f"rel_err={e_fixed:.4f};r={r};panel={panel}",
            "_rel_err": e_fixed,
        })
        rows.append({
            "name": f"stream/cur/rows/{m}x{n}/adaptive",
            "us_per_call": 0.0,
            "derived": f"rel_err={e_adapt:.4f};r={r};panel={panel}"
                       f";spiked_rows_admitted={np.mean(captured):.1f}/6",
            "_rel_err": e_adapt,
        })
        rows.append(_win_row(
            f"stream/cur/rows/{m}x{n}/row_win", e_fixed, e_adapt, "fixed_over_adaptive"
        ))
    return rows


def run_error_estimator(shapes, trials: int) -> list:
    """A-posteriori estimator audit rows: ``estimate_rel_error`` (the
    single-pass Ψ-vs-ÂΩ estimate) against the true relative Frobenius error
    on each stream family. Acceptance: ``ratio`` inside the 2× band in both
    directions. The final telemetry frame of each family is folded into the
    process metrics registry (→ ``BENCH_stream.metrics.jsonl``) so the
    per-panel admission/eviction audit ships with the artifact."""
    rows = []
    c = r = 16
    reg = default_registry()
    for m, n, panel in shapes:
        for family in ("spiked", "late-spike", "drift"):
            ests, trues = [], []
            for t in range(trials):
                key = jax.random.key(m + n + 17 * t)
                if family == "spiked":
                    A, _pos = spiked_decay_matrix(key, m, n)
                elif family == "late-spike":
                    A, _e, _l = late_spike_matrix(key, m, n)
                else:
                    A, _b = drifting_spectrum_matrix(key, m, n)
                st = adaptive_cur_init(
                    jax.random.key(500 + t), m, n, c, None, r=r,
                    sketch="countsketch", panel=panel, panel_cap=2,
                    panel_cap_rows=2, swap_gain=2.0, telemetry=True,
                )
                st = stream_panels(st, A, panel)
                ests.append(float(estimate_rel_error(st)))
                trues.append(float(cur_relative_error(A, adaptive_cur_finalize(st))))
                if t == 0:
                    reg.record_stream_telemetry(st, prefix=f"stream/{family}/{m}x{n}")
            est, true = float(np.mean(ests)), float(np.mean(trues))
            ratio = est / max(true, 1e-12)
            rows.append({
                "name": f"stream/obs/est/{family}/{m}x{n}",
                "us_per_call": 0.0,
                "derived": f"est={est:.4f};true={true:.4f};ratio={ratio:.2f}"
                           f"({'PASS' if 0.5 <= ratio <= 2.0 else 'FAIL'}@2x-band)",
            })
    return rows


def run_spsvd_parity(shapes) -> list:
    """SP-SVD DP-sharded parity evidence (exactness, not speed)."""
    rows = []
    c = r = 16
    for m, n, panel in shapes:
        A, _pos = spiked_decay_matrix(jax.random.key(m + n), m, n)
        sizes = dict(c=2 * c, r=2 * r, c0=6 * c, r0=6 * r, s_c=6 * c, s_r=6 * r)
        single = stream_panels(
            sp_svd_init(jax.random.key(3), m, n, sizes=sizes, panel=panel), A, panel
        )
        for workers in (2, 4):
            shard = simulate_sharded_stream(
                sp_svd_init(jax.random.key(3), m, n, sizes=sizes, panel=panel), A, panel, workers
            )
            delta = max(
                float(jnp.max(jnp.abs(shard.C - single.C))),
                float(jnp.max(jnp.abs(shard.R - single.R))),
                float(jnp.max(jnp.abs(shard.M - single.M))),
            )
            rows.append({
                "name": f"stream/spsvd/{m}x{n}/parity/w{workers}",
                "us_per_call": 0.0,
                "derived": f"max_abs_delta={delta:.2e}",
            })
    return rows


def run_resilient_overhead(quick: bool) -> list:
    """Checkpoint-overhead acceptance rows for the resilient driver.

    A tall fixed-CUR stream (8192×1024, panel 16, 4-panel chunks → 16
    chunks at w1) is driven through the resilient driver with and without
    checkpointing at cadence 8, on 1/2/4 workers, timed interleaved like
    the other perf rows. The geometry is deliberately compute-bound: each
    chunk costs ~milliseconds of scan work, so the ~0.5 ms packed
    non-durable save amortizes to a few percent — the property the ≤ 1.1×
    ``+ckpt8`` gate locks in.

    Methodology notes (hard-won stability constraints):

    * Checkpoint dirs live on tmpfs (``/dev/shm`` when present) so disk
      tail latency doesn't hit only the ``+ckpt8`` side of a pair.
    * ONE directory per worker config, reused across all rounds with
      ``resume=False`` (write-only): every call overwrites the same step
      ids in place, so no per-round dir accumulation, no GC churn, and no
      memory-pressure spikes from hundreds of stale tmpfs checkpoints.
    * Saves are non-durable (no fsync): the rename commit is already
      atomic against the process-crash fault model the driver defends.
    """
    rows = []
    m, n, panel, chunk_panels, cadence = 8192, 1024, 16, 4, 8
    A, _pos = spiked_decay_matrix(jax.random.key(m + n), m, n)
    ci = jax.random.choice(jax.random.key(31), n, (16,), replace=False)
    ri = jax.random.choice(jax.random.key(32), m, (16,), replace=False)
    src = ArrayPanelSource(A, panel)

    def once(workers, ckpt_dir):
        st = streaming_cur_init(jax.random.key(7), m, n, ci, ri, panel=panel)
        if workers == 1:
            st, _rep = run_resilient_stream(
                st, src, chunk_panels=chunk_panels, ckpt_dir=ckpt_dir,
                ckpt_every=cadence, keep_last=2, resume=False,
            )
        else:
            st, _reps = run_resilient_sharded_stream(
                st, src, workers, chunk_panels=chunk_panels, ckpt_dir=ckpt_dir,
                ckpt_every=cadence, keep_last=2, resume=False,
            )
        return st.C

    root = "/dev/shm" if os.path.isdir("/dev/shm") else None
    base = tempfile.mkdtemp(prefix="bench_resilient_", dir=root)
    fns = {}
    for workers in (4, 1, 2):
        fns[f"w{workers}"] = lambda workers=workers: once(workers, None)
        d = os.path.join(base, f"w{workers}")
        fns[f"w{workers}+ckpt8"] = lambda workers=workers, d=d: once(workers, d)
    try:
        # enough rounds that every config's min-floor converges: the gate
        # margin is only ~3% at w4 (4 final saves on an ~88 ms call), so
        # first-touch noise on either side of a pair must be rotated out
        times = time_calls_interleaved(fns, warmup=1, rounds=20 if quick else 30)
    finally:
        shutil.rmtree(base, ignore_errors=True)

    num_panels = n // panel
    for workers in (1, 2, 4):
        per_worker = -(-num_panels // workers)
        chunks = -(-per_worker // chunk_panels)
        base_t = times[f"w{workers}"]
        ckpt_t = times[f"w{workers}+ckpt8"]
        rows.append({
            "name": f"stream/resilient/{m}x{n}/w{workers}",
            "us_per_call": round(base_t, 1),
            "derived": f"panel={panel};chunk_panels={chunk_panels}"
                       f";chunks_per_worker={chunks}",
        })
        overhead = ckpt_t / max(base_t, 1e-9)
        rows.append({
            "name": f"stream/resilient/{m}x{n}/w{workers}+ckpt8",
            "us_per_call": round(ckpt_t, 1),
            "derived": f"ckpt_overhead={overhead:.2f}x;cadence={cadence}"
                       f";packed;durable=False;tmpfs={root is not None}",
        })
    return rows


def run(trials: int = 3, quick: bool = False) -> list:
    shapes = [(384, 320, 64)] if quick else [(1024, 768, 128), (2048, 1024, 128)]
    rows = run_adaptive_vs_uniform(shapes, trials, quick)
    rows += run_eviction(shapes, trials)
    rows += run_row_admission(shapes, trials)
    rows += run_error_estimator(shapes, trials)
    rows += run_spsvd_parity(shapes)
    rows += run_resilient_overhead(quick)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="single small shape, 1 trial (CI)")
    ap.add_argument("--out-dir", default=None, help="where to write BENCH_stream.json")
    args = ap.parse_args()
    # enabled registry for the run: captures the engine's profiling spans and
    # the estimator scenario's telemetry summaries alongside the artifact
    prev = set_registry(MetricsRegistry())
    try:
        rows = run(trials=1 if args.smoke else 3, quick=args.smoke)
        print("name,us_per_call,derived")
        for row in rows:
            print(f"{row['name']},{row['us_per_call']},{str(row['derived']).replace(',', ';')}")
        path = write_bench_json("stream", rows, meta={"smoke": args.smoke}, out_dir=args.out_dir)
        print(f"wrote {path}")
        metrics_path = os.path.join(
            os.path.dirname(path) or os.getcwd(), "BENCH_stream.metrics.jsonl"
        )
        default_registry().dump_jsonl(metrics_path)
        print(f"wrote {metrics_path}")
    finally:
        set_registry(prev)


if __name__ == "__main__":
    main()
