"""Paper §6.1 / Figure 1: Fast GMR error ratio vs sketch factor a.

Protocol (verbatim from the paper): C = A·G_C, R = G_R·A with Gaussian
G (c = r = 20); sketches S_C/S_R Gaussian for dense A, CountSketch for
sparse A; s_c = a·c, s_r = a·r with a ∈ {2..12} (dense) / {3..13} (sparse).
Claim validated: error ratio ∝ 1/a²  (⇔ sketch size ∝ ε^{-1/2}, Theorem 1).

Datasets: offline container → synthetic matrices with matched spectral /
sparsity profiles (see DESIGN.md §8).

  PYTHONPATH=src python -m benchmarks.gmr_error [--smoke]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import error_ratio, exact_gmr, fast_gmr, rho

from .common import powerlaw_matrix, sparse_matrix, time_call, write_bench_json


DATASETS = {
    "dense-powerlaw1.0": lambda key: powerlaw_matrix(key, 1500, 1200, 1.0),
    "dense-powerlaw0.5": lambda key: powerlaw_matrix(key, 2000, 800, 0.5),
    "sparse-0.2%": lambda key: sparse_matrix(key, 3000, 2500, 0.002),
}


def run(trials: int = 3, quick: bool = False) -> list:
    rows = []
    c = r = 20
    for name, make in DATASETS.items():
        sparse = name.startswith("sparse")
        A = make(jax.random.key(hash(name) % 2**31))
        GC = jax.random.normal(jax.random.key(1), (A.shape[1], c), A.dtype)
        GR = jax.random.normal(jax.random.key(2), (r, A.shape[0]), A.dtype)
        C, R = A @ GC, GR @ A
        rho_val = float(rho(A, C, R))
        sketch = "countsketch" if sparse else "gaussian"
        a_values = ([3, 7, 13] if quick else [3, 5, 7, 9, 11, 13]) if sparse else (
            [2, 6, 12] if quick else [2, 4, 6, 8, 10, 12])
        fgmr = jax.jit(lambda k, sc, sr: fast_gmr(k, A, C, R, sc, sr, sketch_c=sketch),
                       static_argnums=(1, 2))
        for a in a_values:
            errs = []
            for t in range(trials):
                X = fgmr(jax.random.key(100 + t), a * c, a * r)
                errs.append(float(error_ratio(A, C, X, R)))
            us = time_call(fgmr, jax.random.key(0), a * c, a * r)
            err = float(np.mean(errs))
            rows.append({
                "name": f"gmr_error/{name}/a={a}",
                "us_per_call": round(us, 1),
                "derived": f"err_ratio={err:.4f};err_x_a2={err*a*a:.3f};rho={rho_val:.3f}",
                "_err": err,
                "_a": a,
                "_ds": name,
            })
    # slope check per dataset: err·a² should be ~constant (1/a² law)
    for name in DATASETS:
        sub = [(row["_a"], row["_err"]) for row in rows if row.get("_ds") == name]
        consts = [e * a * a for a, e in sub]
        spread = max(consts) / max(min(consts), 1e-12)
        rows.append({
            "name": f"gmr_error/{name}/inv_a2_law",
            "us_per_call": 0.0,
            "derived": f"err_x_a2_spread={spread:.2f}(≲4 validates Thm1 eps^-1/2)",
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="reduced a-sweep, 1 trial (CI)")
    ap.add_argument("--out-dir", default=None, help="where to write BENCH_gmr_error.json")
    args = ap.parse_args()
    rows = run(trials=1 if args.smoke else 3, quick=args.smoke)
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']},{str(row['derived']).replace(',', ';')}")
    path = write_bench_json("gmr_error", rows, meta={"smoke": args.smoke}, out_dir=args.out_dir)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
