"""Serving benchmark: decode throughput + KV-cache compression quality.

Rows → ``BENCH_serve.json`` (committed smoke baseline under
``benchmarks/baselines/``, gated by ``make perf-check``):

* ``serve/gen/<model>/{dense,compressed}`` — **timed** full generation
  (prefill + fused per-token decode loop) on a smoke-sized model; the
  compressed row runs the decode-native :class:`repro.serve.CompressedKV`
  path (fold + periodic refactorization inside the jitted step).
  ``derived`` carries tokens/sec.
* ``serve/kv/bytes_per_user`` — derived: dense cache bytes vs compressed
  cache bytes per request (honest accounting — engine carry included).
* ``serve/kv/rel_err/r=<r>`` — derived: head-batch relative reconstruction
  error vs rank on a synthetic low-rank-plus-noise cache.
* ``serve/kv/adaptive_win`` — derived PASS/FAIL: adaptive per-head rank vs
  uniform rank at the same total budget ``KV·rank`` on a spiked-head
  cache (one heavy-spectrum head among near-rank-1 heads).

  PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models import init_params
from repro.serve import (
    KVCompressionConfig,
    cache_nbytes,
    compress_head_batch,
    compression_error,
    generate,
    init_compressed_kv,
)

from .common import time_calls_interleaved, write_bench_json

MODEL = "llama3.2-1b"


def _spiked_head_batch(KV: int, S: int, d: int):
    # one heavy-spectrum head among near-rank-1 heads (the adaptive
    # allocator's target regime)
    rich = jax.random.normal(jax.random.key(30), (S, 12)) @ \
        jax.random.normal(jax.random.key(31), (12, d)) * 3.0
    poor = jnp.stack([
        jnp.outer(jax.random.normal(jax.random.fold_in(jax.random.key(32), i), (S,)),
                  jax.random.normal(jax.random.fold_in(jax.random.key(33), i), (d,)))
        + 0.01 * jax.random.normal(jax.random.fold_in(jax.random.key(34), i), (S, d))
        for i in range(KV - 1)
    ])
    return jnp.concatenate([rich[None], poor])[None]  # (1, KV, S, d)


def run_generation(quick: bool) -> list:
    """Timed dense-vs-compressed generation + cache-size row."""
    cfg = ARCHS[MODEL].smoke_config()
    params = init_params(jax.random.key(0), cfg)
    B, S, n_tok = (2, 16, 8) if quick else (4, 32, 24)
    prompt = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    kc = KVCompressionConfig(rank=8, oversample=2, panel=16, decode_panel=4, refresh_every=8)

    fns = {
        "dense": lambda: generate(params, cfg, prompt, n_tok),
        "compressed": lambda: generate(params, cfg, prompt, n_tok, kv_compress=kc),
    }
    times = time_calls_interleaved(fns, rounds=5 if quick else 7)
    rows = [
        {
            "name": f"serve/gen/{MODEL}/{name}",
            "us_per_call": round(us, 1),
            "derived": f"tok_per_s={n_tok * B / (us / 1e6):.1f};B={B};S={S};n_tok={n_tok}",
        }
        for name, us in times.items()
    ]

    # cache bytes per user per layer at serving scale (long context,
    # realistic head dims — the smoke model's 24-token cache would be
    # dominated by the engine's fixed sketch overheads). Honest totals:
    # the decode-native carry (engine R is the O(c·n) term) is included,
    # and the factors-only footprint (the steady-state representation
    # between refreshes) is reported alongside.
    KVh, hd, n_max = 8, 128, 4096
    skc = KVCompressionConfig(rank=16, oversample=2, decode_panel=64, refresh_every=256)
    ckv = init_compressed_kv(
        jax.random.key(2), skc, batch=1, n_kv_heads=KVh, head_dim=hd, n_max=n_max
    )
    dense_b = 2 * n_max * KVh * hd * 4  # k+v, fp32
    comp_b = cache_nbytes(ckv)
    fac_b = sum(
        l.size * l.dtype.itemsize for f in (ckv.k_fac, ckv.v_fac) for l in jax.tree.leaves(f)
    )
    rows.append({
        "name": "serve/kv/bytes_per_user",
        "us_per_call": 0.0,
        "derived": f"dense={dense_b};compressed={comp_b};factors_only={fac_b};"
                   f"ratio={dense_b / comp_b:.2f}x;factors_ratio={dense_b / fac_b:.2f}x;"
                   f"n_max={n_max};hd={hd};KV={KVh};rank={skc.rank}",
    })
    return rows


def run_quality(quick: bool) -> list:
    """Rel-err vs rank sweep + the adaptive-vs-uniform win row."""
    rows = []
    KV, S, d = 4, (160 if quick else 512), 32
    base = jax.random.normal(jax.random.key(40), (1, KV, S, 8)) @ \
        jax.random.normal(jax.random.key(41), (1, KV, 8, d))
    hist = base + 0.05 * jax.random.normal(jax.random.key(42), (1, KV, S, d))
    err_fn = jax.jit(jax.vmap(jax.vmap(compression_error)))
    for r in (4, 8, 16):
        kc = KVCompressionConfig(rank=r, oversample=4, panel=64)
        fac = compress_head_batch(jax.random.key(43), hist, kc)
        err = float(jnp.mean(err_fn(hist, fac)))
        rows.append({
            "name": f"serve/kv/rel_err/r={r}",
            "us_per_call": 0.0,
            "derived": f"rel_err={err:.4f};KV={KV};S={S};d={d}",
        })

    spiked = _spiked_head_batch(KV, 160 if quick else 320, d)
    rank = 4
    uni = compress_head_batch(
        jax.random.key(44), spiked, KVCompressionConfig(rank=rank, oversample=4, panel=64)
    )
    ada = compress_head_batch(
        jax.random.key(44), spiked,
        KVCompressionConfig(rank=rank, oversample=4, panel=64,
                            adaptive=True, min_rank=1, max_rank=14),
    )
    budget_ok = int((ada.sigma > 0).sum()) <= KV * rank
    w = jnp.linalg.norm(spiked[0], axis=(1, 2))  # energy weights per head
    tot_u = float(jnp.sum(err_fn(spiked, uni)[0] * w))
    tot_a = float(jnp.sum(err_fn(spiked, ada)[0] * w))
    ratio = tot_u / max(tot_a, 1e-12)
    ok = budget_ok and ratio > 1.0
    rows.append({
        "name": "serve/kv/adaptive_win",
        "us_per_call": 0.0,
        "derived": f"uniform_over_adaptive={ratio:.2f}x"
                   f"({'PASS' if ok else 'FAIL'}@equal-budget=KV*{rank};"
                   f"budget_respected={budget_ok})",
    })
    return rows


def run(quick: bool) -> list:
    """Harness entry (``benchmarks.run`` contract): all serve rows."""
    return run_generation(quick) + run_quality(quick)


def main() -> None:
    """CLI entry: CSV to stdout + the standard ``BENCH_serve.json`` artifact."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="small shapes, fewer rounds (CI)")
    ap.add_argument("--out-dir", default=None, help="where to write BENCH_serve.json")
    args = ap.parse_args()
    rows = run(quick=args.smoke)
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']},{str(row['derived']).replace(',', ';')}")
    path = write_bench_json("serve", rows, meta={"smoke": args.smoke}, out_dir=args.out_dir)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
