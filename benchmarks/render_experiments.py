"""Render §Dry-run / §Roofline / §Perf into EXPERIMENTS.md from artifacts."""

import glob
import json
import os
import re

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")
EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")

PEAK, HBM, ICI = 197e12, 819e9, 50e9


def load(path):
    with open(path) as f:
        return json.load(f)


def terms(r):
    wire = sum(v["wire_bytes"] for v in r["collectives"].values())
    c = r["flops_per_device"] / PEAK
    m = r["hbm_bytes_per_device"] / HBM
    k = wire / ICI
    dom = max([("compute", c), ("memory", m), ("collective", k)], key=lambda t: t[1])
    return c, m, k, dom[0], wire


def dryrun_table():
    rows = ["| arch | shape | mesh | compile s | mem/dev GB | flops/dev | wire/dev GB | collectives |",
            "|---|---|---|---|---|---|---|---|"]
    recs = []
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        r = load(p)
        if r.get("tag"):
            continue
        recs.append(r)
    recs.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    for r in recs:
        wire = sum(v["wire_bytes"] for v in r["collectives"].values())
        colls = ", ".join(f"{k}:{int(v['count'])}" for k, v in sorted(r["collectives"].items()))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']} | "
            f"{r['memory']['peak_estimate_bytes']/1e9:.1f} | {r['flops_per_device']:.2e} | "
            f"{wire/1e9:.2f} | {colls} |"
        )
    return "\n".join(rows)


def roofline_table():
    from repro.configs import SHAPES

    rows = ["| arch | shape | compute s | memory s | collective s | dominant | MODEL TFLOP | useful ratio |",
            "|---|---|---|---|---|---|---|---|"]
    recs = [load(p) for p in sorted(glob.glob(os.path.join(ART, "*__16x16.json")))]
    recs = [r for r in recs if not r.get("tag")]
    recs.sort(key=lambda r: (r["arch"], r["shape"]))
    for r in recs:
        c, m, k, dom, _ = terms(r)
        cell = SHAPES[r["shape"]]
        N = r["n_active_params"]
        if cell.kind == "train":
            mf = 6.0 * N * cell.global_batch * cell.seq_len
        elif cell.kind == "prefill":
            mf = 2.0 * N * cell.global_batch * cell.seq_len
        else:
            mf = 2.0 * N * cell.global_batch
        ratio = mf / max(r["flops_per_device"] * 256, 1.0)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {c:.3e} | {m:.3e} | {k:.3e} | "
            f"**{dom}** | {mf/1e12:.1f} | {ratio:.2f} |"
        )
    return "\n".join(rows)


def perf_variants_table():
    rows = ["| cell / variant | compute s | memory s | collective s | mem/dev GB | vs baseline (c/m/k/mem) |",
            "|---|---|---|---|---|---|"]
    cells = [("llama3.2-1b", "train_4k"), ("kimi-k2-1t-a32b", "train_4k"), ("mamba2-1.3b", "prefill_32k")]
    for arch, shape in cells:
        base = load(os.path.join(ART, f"{arch}__{shape}__16x16.json"))
        bc, bm, bk, _, _ = terms(base)
        bmem = base["memory"]["peak_estimate_bytes"] / 1e9
        rows.append(f"| **{arch} / {shape} (baseline)** | {bc:.3e} | {bm:.3e} | {bk:.3e} | {bmem:.1f} | — |")
        for p in sorted(glob.glob(os.path.join(ART, f"{arch}__{shape}__16x16__*.json"))):
            r = load(p)
            c, m, k, _, _ = terms(r)
            mem = r["memory"]["peak_estimate_bytes"] / 1e9
            rows.append(
                f"| &nbsp;&nbsp;{r['tag']} | {c:.3e} | {m:.3e} | {k:.3e} | {mem:.1f} | "
                f"{c/bc:.2f}× / {m/bm:.2f}× / {k/bk:.2f}× / {mem/bmem:.2f}× |"
            )
    return "\n".join(rows)


def main():
    with open(EXP) as f:
        text = f.read()
    text = text.replace("<!-- DRYRUN_TABLE -->", dryrun_table())
    text = text.replace("<!-- ROOFLINE_TABLE -->", roofline_table())
    text = text.replace("<!-- PERF_TABLE -->", perf_variants_table())
    with open(EXP, "w") as f:
        f.write(text)
    print("rendered tables into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
