"""Paper §6.3 / Figure 3: single-pass SVD comparison.

Fast SP-SVD (**Algorithm 3**, streaming) vs Practical SP-SVD (Tropp et al.
2017, Algorithm 4). Protocol: k = 10, c = r = f·k/2 with (c+r)/k ∈
{4..12}; Fast SP-SVD inner sketches s = 3c√a (paper §6.3); error ratio
= ||A − UΣVᵀ||_F / ||A − A_k||_F − 1 (can be negative: ranks exceed k).

Claim validated: Fast SP-SVD ≪ Practical SP-SVD at equal sketch budget,
dramatically so at small budgets (§5.3's ill-conditioning of N' at c = r);
we also report Tropp's recommended asymmetric r = 2c allocation.

  PYTHONPATH=src python -m benchmarks.single_pass_svd [--smoke]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fast_sp_svd, practical_sp_svd, svd_error_ratio

from .common import powerlaw_matrix, sparse_matrix, time_call, write_bench_json


DATASETS = {
    "dense-powerlaw1.0": lambda key: powerlaw_matrix(key, 2500, 2000, 1.0),
    "dense-powerlaw0.7": lambda key: powerlaw_matrix(key, 3000, 1500, 0.7),
    "sparse-0.2%": lambda key: sparse_matrix(key, 4000, 3000, 0.002),
}


def run(trials: int = 2, quick: bool = False) -> list:
    rows = []
    k = 10
    factors = [4, 8] if quick else [4, 6, 8, 10, 12]
    for ds, make in DATASETS.items():
        A = make(jax.random.key(hash(ds) % 2**31))
        for f in factors:
            c = r = f * k // 2
            a = f / 2
            s = int(3 * c * np.sqrt(a))
            sizes = dict(c=c, r=r, c0=3 * c, r0=3 * r, s_c=s, s_r=s)
            e_fast, e_prac, e_prac2 = [], [], []
            for t in range(trials):
                U, S, V = fast_sp_svd(jax.random.key(500 + t), A, sizes=sizes, panel=512)
                e_fast.append(float(svd_error_ratio(A, U, S, V, k)))
                U, S, V = practical_sp_svd(jax.random.key(600 + t), A, c=c, r=r)
                e_prac.append(float(svd_error_ratio(A, U, S, V, k)))
                # Tropp-recommended asymmetric allocation, same total budget
                c2 = max(k, (c + r) // 3)
                U, S, V = practical_sp_svd(jax.random.key(700 + t), A, c=c2, r=2 * c2)
                e_prac2.append(float(svd_error_ratio(A, U, S, V, k)))
            us = time_call(
                lambda key: fast_sp_svd(key, A, sizes=sizes, panel=512), jax.random.key(0), iters=1
            )
            rows.append({
                "name": f"spsvd/{ds}/(c+r)/k={f}",
                "us_per_call": round(us, 1),
                "derived": (
                    f"fast={np.mean(e_fast):.4f};practical_cr={np.mean(e_prac):.4f};"
                    f"practical_r2c={np.mean(e_prac2):.4f};"
                    f"fast_wins={np.mean(e_fast) < min(np.mean(e_prac), np.mean(e_prac2))}"
                ),
            })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="reduced budget sweep, 1 trial (CI)")
    ap.add_argument("--out-dir", default=None, help="where to write BENCH_spsvd_compare.json")
    args = ap.parse_args()
    rows = run(trials=1 if args.smoke else 2, quick=args.smoke)
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']},{str(row['derived']).replace(',', ';')}")
    path = write_bench_json("spsvd_compare", rows, meta={"smoke": args.smoke}, out_dir=args.out_dir)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
