"""Paper §6.2 / Figure 2 + Table 7: SPSD kernel approximation comparison.

Methods: Nyström (Williams & Seeger), fast SPSD (Wang et al. 2016b),
faster SPSD (**Algorithm 2**, ours), optimal core X = C†K(C†)ᵀ.
Protocol: RBF kernel, k = 15, c = 2k uniform columns, s = a·c with
a ∈ {3..16}; error ratio = ||K − C X Cᵀ||_F / ||K||_F.
Claims validated: (i) faster-SPSD ≈ optimal by s = 10c; (ii) fast-SPSD
(Wang'16b) is much worse than Nyström at small s (Table 7 pattern);
(iii) faster-SPSD < Nyström.

**Streaming scenario** (``spsd/stream/...`` rows → ``BENCH_spsd.json``,
gated by ``make perf-check`` against ``benchmarks/baselines/``): the same
Algorithm-2 factorization run single-pass over kernel-column panels through
the symmetric engine (:mod:`repro.spsd.streaming`) —

* ``spsd/stream/<n>/batch_alg2``        — batch faster-SPSD reference
* ``spsd/stream/<n>/fixed/w{1,2,4}``    — fixed-column streaming on 1/2/4
  simulated DP workers (tied-operand sharding: one psum-equivalent merge)
* ``spsd/stream/<n>/adaptive/w1``       — in-stream kernel-column admission
* derived rows: batch↔stream parity (max |ΔX| on shared sketches) and the
  adaptive-vs-uniform error ratio at equal (c, s) budget, both PASS/FAIL.

  PYTHONPATH=src python -m benchmarks.spsd_approx [--smoke]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    fast_spsd_wang,
    faster_spsd,
    leverage_sampling_sketches,
    matrix_oracle,
    nystrom,
    optimal_core,
    rbf_kernel_oracle,
    spsd_error_ratio,
)
from repro.spsd import (
    adaptive_spsd_finalize,
    adaptive_spsd_init,
    streaming_spsd_finalize,
    streaming_spsd_init,
)
from repro.stream import simulate_sharded_stream, stream_panels

from .common import (
    clustered_points,
    time_call,
    time_calls_interleaved,
    tune_rbf_sigma,
    write_bench_json,
)


def _spiked_kernel(key, n: int, rank: int = 48, n_spikes: int = 6, amp: float = 9.0):
    """SPSD matrix with near-localized heavy atoms (skewed leverage): a
    diffuse low-rank base plus ``amp·v vᵀ`` spikes with ``v ≈ e_p`` — the
    regime where a uniform pre-pass provably under-covers (each spike's
    energy lives in essentially one column) and in-stream admission earns
    its keep. Smooth RBF kernels are the opposite regime: their columns are
    incoherent, uniform sampling is already near-optimal there, and the
    adaptive scorer has nothing to find — which is why the adaptive row
    uses this kernel and the timing/parity rows use the RBF one."""
    k1, k2 = jax.random.split(key)
    base = 0.01 * jax.random.normal(k1, (n, rank))
    K = base @ base.T + 1e-3 * jnp.eye(n)
    pos = (jnp.arange(1, n_spikes + 1) * n) // (n_spikes + 1)
    for i, p in enumerate(np.asarray(pos).tolist()):
        v = jnp.zeros((n,)).at[p].set(1.0) + 0.005 * jax.random.normal(
            jax.random.fold_in(k2, i), (n,)
        )
        K = K + amp * jnp.outer(v, v)
    return K, pos


def run_streaming(quick: bool = False) -> list:
    """Streaming-SPSD scenario: wall time + quality vs the batch reference."""
    rows = []
    n, d, k = (512, 24, 10) if quick else (1536, 40, 15)
    c = 2 * k
    s = 10 * c
    panel = 128
    X = clustered_points(jax.random.key(7), n, d, n_clusters=10, spread=0.7)
    sigma = tune_rbf_sigma(X, k=k, target_eta=0.75)
    oracle = rbf_kernel_oracle(X, sigma)
    K = oracle(None, None)  # the stream (panels of K)

    # shared pieces so the parity row compares identical math
    idx = jax.random.choice(jax.random.key(8), n, (c,), replace=False).astype(jnp.int32)
    S1, S2 = leverage_sampling_sketches(jax.random.key(9), jnp.take(K, idx, axis=1), s)
    res_batch = faster_spsd(
        jax.random.key(10), matrix_oracle(K), n, c, s, col_idx=idx, sketches=(S1, S2)
    )

    def run_fixed(workers: int):
        st = streaming_spsd_init(
            jax.random.key(11), n, idx, sketches=(S1, S2), panel=panel
        )
        if workers == 1:
            st = stream_panels(st, K, panel)
        else:
            st = simulate_sharded_stream(st, K, panel, workers)
        return streaming_spsd_finalize(st)

    ck, sk = 10, 100
    Ks, spike_pos = _spiked_kernel(jax.random.key(12), n)

    def run_adaptive():
        st = adaptive_spsd_init(
            jax.random.key(14), n, ck, s=sk, panel=panel, panel_cap=2
        )
        return adaptive_spsd_finalize(stream_panels(st, Ks, panel))

    def run_uniform_on_spiked(t: int = 0):
        ci = jax.random.choice(jax.random.key(100 + t), n, (ck,), replace=False)
        st = streaming_spsd_init(jax.random.key(15), n, ci, s=sk, panel=panel)
        return streaming_spsd_finalize(stream_panels(st, Ks, panel))

    fns = {
        "batch_alg2": lambda: faster_spsd(jax.random.key(13), oracle, n, c, s),
        "fixed/w1": lambda: run_fixed(1),
        "fixed/w2": lambda: run_fixed(2),
        "fixed/w4": lambda: run_fixed(4),
        "adaptive/w1": run_adaptive,
    }
    # quick mode keeps enough rounds for a stable min — these rows feed the
    # 1.5× perf gate, and with only 5 timed rows one noisy min can trip it
    times = time_calls_interleaved(fns, rounds=5 if quick else 7)
    res_w1 = run_fixed(1)  # deterministic: one result serves err + parity rows
    res_a = run_adaptive()
    captured = len(
        set(np.asarray(spike_pos).tolist()) & set(np.asarray(res_a.col_idx).tolist())
    )
    err_a = float(spsd_error_ratio(Ks, res_a))
    err_u = float(np.mean([
        float(spsd_error_ratio(Ks, run_uniform_on_spiked(t))) for t in range(3)
    ]))
    errs = {
        "batch_alg2": float(spsd_error_ratio(K, res_batch)),
        "fixed/w1": float(spsd_error_ratio(K, res_w1)),
        "adaptive/w1": err_a,
    }
    errs["fixed/w2"] = errs["fixed/w4"] = errs["fixed/w1"]  # exact parity (see below)
    for name, us in times.items():
        cfg = (
            f"c={ck};s={sk};panel={panel};kernel=spiked;spikes={captured}/6"
            if name.startswith("adaptive")
            else f"c={c};s={s};panel={panel};kernel=rbf"
        )
        rows.append({
            "name": f"spsd/stream/{n}/{name}",
            "us_per_call": round(us, 1),
            "derived": f"err_ratio={errs[name]:.4f};{cfg}",
        })
    # batch ↔ stream parity on shared (col_idx, S₁, S₂)
    delta = float(jnp.max(jnp.abs(res_w1.X - res_batch.X)))
    scale = float(jnp.max(jnp.abs(res_batch.X)))
    rows.append({
        "name": f"spsd/stream/{n}/parity",
        "us_per_call": 0.0,
        "derived": f"max_abs_dX={delta:.2e};scale={scale:.2e};"
                   f"{'PASS' if delta < 1e-3 * max(scale, 1.0) else 'FAIL'}",
    })
    # adaptive vs fixed-uniform at equal (c, s) on the spiked kernel:
    # ratio > 1 means in-stream admission wins
    ratio = err_u / max(err_a, 1e-12)
    rows.append({
        "name": f"spsd/stream/{n}/adaptive_win",
        "us_per_call": 0.0,
        "derived": f"uniform_over_adaptive={ratio:.2f}x"
                   f"({'PASS' if ratio > 1.0 else 'FAIL'}@equal-budget;kernel=spiked)",
    })
    return rows


def run(trials: int = 3, quick: bool = False) -> list:
    rows = []
    n, d, k = (500, 24, 10) if quick else (1500, 40, 15)
    c = 2 * k
    for ds, (n_clusters, spread) in {"clustered-tight": (12, 0.6), "clustered-wide": (6, 1.4)}.items():
        X = clustered_points(jax.random.key(hash(ds) % 2**31), n, d, n_clusters, spread)
        sigma = tune_rbf_sigma(X, k=k, target_eta=0.75)
        oracle = rbf_kernel_oracle(X, sigma)
        K = oracle(None, None)
        ev2 = jnp.sort(jnp.linalg.eigvalsh(K) ** 2)[::-1]
        eta = float(jnp.sum(ev2[:k]) / jnp.sum(ev2))

        a_values = [4, 10, 16] if quick else [3, 4, 6, 8, 10, 12, 16]
        methods = {
            "nystrom": lambda key, s: nystrom(key, oracle, n, c),
            "fast_spsd_wang16": lambda key, s: fast_spsd_wang(key, oracle, n, c, s),
            "faster_spsd_alg2": lambda key, s: faster_spsd(key, oracle, n, c, s),
            "optimal": lambda key, s: optimal_core(key, oracle, n, c),
        }
        for a in a_values:
            s = a * c
            for mname, fn in methods.items():
                if mname in ("nystrom", "optimal") and a != a_values[0]:
                    continue  # s-independent baselines: run once
                errs, entries = [], 0
                for t in range(trials):
                    res = fn(jax.random.key(1000 + 17 * t), s)
                    errs.append(float(spsd_error_ratio(K, res)))
                    entries = res.entries_observed
                # wall time is informational only (single-shot timing of the
                # quality sweep is too noisy to gate — the perf-gated rows
                # are the interleaved-timed spsd/stream/* scenario below),
                # so it rides in `derived`: us_per_call > 0 is the gate's
                # "timed row" marker (see benchmarks.check_regression).
                us = time_call(fn, jax.random.key(0), s, iters=1)
                rows.append({
                    "name": f"spsd/{ds}/{mname}/a={a}",
                    "us_per_call": 0.0,
                    "derived": f"err_ratio={np.mean(errs):.4f};entries={entries};"
                               f"eta={eta:.2f};us={us:.1f}",
                    "_m": mname, "_a": a, "_e": float(np.mean(errs)), "_ds": ds,
                })
    # claim summaries
    for ds in {row["_ds"] for row in rows if "_ds" in row}:
        sub = {(row["_m"], row["_a"]): row["_e"] for row in rows if row.get("_ds") == ds}
        amax = max(a for (_, a) in sub if _ == "faster_spsd_alg2")
        ours = sub[("faster_spsd_alg2", amax)]
        opt = next(v for (m, _), v in sub.items() if m == "optimal")
        nys = next(v for (m, _), v in sub.items() if m == "nystrom")
        wang = sub.get(("fast_spsd_wang16", amax), float("nan"))
        rows.append({
            "name": f"spsd/{ds}/claims",
            "us_per_call": 0.0,
            "derived": (
                f"ours_at_max_a={ours:.4f};optimal={opt:.4f};nystrom={nys:.4f};"
                f"wang16={wang:.4f};ours_beats_nystrom={ours < nys};"
                f"ours_within_5pct_optimal={ours < opt * 1.05}"
            ),
        })
    rows += run_streaming(quick=quick)
    return rows


def main() -> None:
    """CLI entry: CSV to stdout + the standard ``BENCH_spsd.json`` artifact."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="small shapes, 1 trial (CI)")
    ap.add_argument("--out-dir", default=None, help="where to write BENCH_spsd.json")
    args = ap.parse_args()
    rows = run(trials=1 if args.smoke else 3, quick=args.smoke)
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']},{str(row['derived']).replace(',', ';')}")
    path = write_bench_json("spsd", rows, meta={"smoke": args.smoke}, out_dir=args.out_dir)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
