"""Paper §6.2 / Figure 2 + Table 7: SPSD kernel approximation comparison.

Methods: Nyström (Williams & Seeger), fast SPSD (Wang et al. 2016b),
faster SPSD (**Algorithm 2**, ours), optimal core X = C†K(C†)ᵀ.
Protocol: RBF kernel, k = 15, c = 2k uniform columns, s = a·c with
a ∈ {3..16}; error ratio = ||K − C X Cᵀ||_F / ||K||_F.
Claims validated: (i) faster-SPSD ≈ optimal by s = 10c; (ii) fast-SPSD
(Wang'16b) is much worse than Nyström at small s (Table 7 pattern);
(iii) faster-SPSD < Nyström.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    fast_spsd_wang,
    faster_spsd,
    nystrom,
    optimal_core,
    rbf_kernel_oracle,
    spsd_error_ratio,
)

from .common import clustered_points, time_call, tune_rbf_sigma


def run(trials: int = 3, quick: bool = False) -> list:
    rows = []
    n, d, k = 1500, 40, 15
    c = 2 * k
    for ds, (n_clusters, spread) in {"clustered-tight": (12, 0.6), "clustered-wide": (6, 1.4)}.items():
        X = clustered_points(jax.random.key(hash(ds) % 2**31), n, d, n_clusters, spread)
        sigma = tune_rbf_sigma(X, k=k, target_eta=0.75)
        oracle = rbf_kernel_oracle(X, sigma)
        K = oracle(None, None)
        ev2 = jnp.sort(jnp.linalg.eigvalsh(K) ** 2)[::-1]
        eta = float(jnp.sum(ev2[:k]) / jnp.sum(ev2))

        a_values = [4, 10, 16] if quick else [3, 4, 6, 8, 10, 12, 16]
        methods = {
            "nystrom": lambda key, s: nystrom(key, oracle, n, c),
            "fast_spsd_wang16": lambda key, s: fast_spsd_wang(key, oracle, n, c, s),
            "faster_spsd_alg2": lambda key, s: faster_spsd(key, oracle, n, c, s),
            "optimal": lambda key, s: optimal_core(key, oracle, n, c),
        }
        for a in a_values:
            s = a * c
            for mname, fn in methods.items():
                if mname in ("nystrom", "optimal") and a != a_values[0]:
                    continue  # s-independent baselines: run once
                errs, entries = [], 0
                for t in range(trials):
                    res = fn(jax.random.key(1000 + 17 * t), s)
                    errs.append(float(spsd_error_ratio(K, res)))
                    entries = res.entries_observed
                us = time_call(fn, jax.random.key(0), s, iters=1)
                rows.append({
                    "name": f"spsd/{ds}/{mname}/a={a}",
                    "us_per_call": round(us, 1),
                    "derived": f"err_ratio={np.mean(errs):.4f};entries={entries};eta={eta:.2f}",
                    "_m": mname, "_a": a, "_e": float(np.mean(errs)), "_ds": ds,
                })
    # claim summaries
    for ds in {row["_ds"] for row in rows if "_ds" in row}:
        sub = {(row["_m"], row["_a"]): row["_e"] for row in rows if row.get("_ds") == ds}
        amax = max(a for (_, a) in sub if _ == "faster_spsd_alg2")
        ours = sub[("faster_spsd_alg2", amax)]
        opt = next(v for (m, _), v in sub.items() if m == "optimal")
        nys = next(v for (m, _), v in sub.items() if m == "nystrom")
        wang = sub.get(("fast_spsd_wang16", amax), float("nan"))
        rows.append({
            "name": f"spsd/{ds}/claims",
            "us_per_call": 0.0,
            "derived": (
                f"ours_at_max_a={ours:.4f};optimal={opt:.4f};nystrom={nys:.4f};"
                f"wang16={wang:.4f};ours_beats_nystrom={ours < nys};"
                f"ours_within_5pct_optimal={ours < opt * 1.05}"
            ),
        })
    return rows
