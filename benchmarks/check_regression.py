"""Perf-regression gate: fresh ``BENCH_<module>.json`` vs committed baseline.

Compares every *timed* row (``us_per_call > 0``; derived-only rows — win
ratios, parity deltas — carry 0.0 and are skipped) of a freshly generated
benchmark artifact against the committed snapshot under
``benchmarks/baselines/`` and fails (exit 1) when any row regresses by more
than ``--threshold`` (default 1.5×).

By default rows are **host-normalized** before comparison: each side's
rows are divided by that side's median timed row, so a CI runner that is
uniformly 2× slower (or faster) than the machine that produced the
baseline neither fails every row nor masks a real one — what the gate
detects is a row regressing relative to its peers (a de-optimized code
path), which is host-invariant. ``--absolute`` compares raw wall-times
instead (meaningful when fresh and baseline come from the same machine,
e.g. ``make perf-check`` on the dev container after regenerating the
baseline there).

Rows the fresh artifact has but the baseline lacks are reported and pass
(new scenarios land before their baseline is regenerated); a baseline row
**missing from the fresh artifact fails the gate** with an explicit
message — a silently dropped row is indistinguishable from a deleted
scenario, and the stale-baseline drift it causes is exactly what this
gate exists to catch (regenerate the baselines after intentional
renames). A smoke artifact is only comparable to the smoke baseline
(different shapes), so mismatched ``meta.smoke`` flags are an error.

``--overhead-suffix SUFFIX`` switches to a *within-artifact* gate: every
timed row whose name contains ``SUFFIX`` is paired with the row named
``name.replace(SUFFIX, "")`` in the **same** artifact and their ratio is
checked against ``--overhead-threshold`` (default 1.3×). No baseline is
involved, so the check is host-invariant by construction — used by
``make obs-check`` to enforce the ≤1.3× telemetry-overhead acceptance on
the ``stream/cur/.../adaptive+tel/w<W>`` rows.

The gate is artifact-generic: the committed snapshot is resolved from the
artifact's own ``bench`` name and smoke flag
(``benchmarks/baselines/BENCH_<bench>[.smoke].json``), so any module using
``benchmarks.common.write_bench_json`` — currently ``stream_bench`` and
``spsd_approx`` — plugs in by committing a baseline.

Wired into ``make perf-check`` and the CI workflow (after the benchmark
smokes). Regenerate the baselines intentionally with::

  PYTHONPATH=src python -m benchmarks.stream_bench --out-dir benchmarks/baselines
  PYTHONPATH=src python -m benchmarks.stream_bench --smoke --out-dir /tmp/smoke \
      && python -m benchmarks.check_regression --update-smoke-baseline /tmp/smoke/BENCH_stream.json

(and the same two commands with ``benchmarks.spsd_approx`` / ``BENCH_spsd.json``).

Usage::

  PYTHONPATH=src python -m benchmarks.check_regression --fresh BENCH_stream.json
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baselines")


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _timed_rows(artifact: dict) -> dict:
    return {
        row["name"]: float(row["us_per_call"])
        for row in artifact["rows"]
        if float(row.get("us_per_call", 0.0)) > 0.0
    }


def baseline_path_for(artifact: dict) -> str:
    """The committed snapshot matching the artifact's bench name and
    smoke/full flavour (``BENCH_<bench>[.smoke].json``)."""
    smoke = bool(artifact.get("meta", {}).get("smoke", False))
    bench = artifact.get("bench", "stream")
    name = f"BENCH_{bench}.smoke.json" if smoke else f"BENCH_{bench}.json"
    return os.path.join(BASELINE_DIR, name)


def _median(values) -> float:
    vals = sorted(values)
    k = len(vals) // 2
    return vals[k] if len(vals) % 2 else 0.5 * (vals[k - 1] + vals[k])


def compare(fresh: dict, baseline: dict, threshold: float, absolute: bool = False) -> list:
    """Return a list of violation strings (empty = gate passes)."""
    if bool(fresh["meta"].get("smoke")) != bool(baseline["meta"].get("smoke")):
        return [
            "smoke/full mismatch: fresh smoke="
            f"{fresh['meta'].get('smoke')} vs baseline smoke={baseline['meta'].get('smoke')}"
        ]
    fresh_rows, base_rows = _timed_rows(fresh), _timed_rows(baseline)
    shared = sorted(set(fresh_rows) & set(base_rows))
    # host-speed normalizer: each side's median timed row (over shared rows)
    scale = 1.0
    if not absolute and shared:
        f_med = _median([fresh_rows[n] for n in shared])
        b_med = _median([base_rows[n] for n in shared])
        if f_med > 0 and b_med > 0:
            scale = b_med / f_med
            print(f"  host normalizer: fresh median {f_med:.1f}us vs baseline "
                  f"median {b_med:.1f}us (x{1/scale:.2f} host speed)")
    violations = []
    for name in shared:
        ratio = fresh_rows[name] * scale / base_rows[name]
        status = "FAIL" if ratio > threshold else "ok"
        print(
            f"  {status:>4}  {name}: {fresh_rows[name]:.1f}us vs baseline "
            f"{base_rows[name]:.1f}us ({ratio:.2f}x normalized)"
        )
        if ratio > threshold:
            violations.append(f"{name}: {ratio:.2f}x > {threshold}x")
    for name in sorted(set(fresh_rows) - set(base_rows)):
        print(f"  new   {name}: {fresh_rows[name]:.1f}us (no baseline)")
    for name in sorted(set(base_rows) - set(fresh_rows)):
        print(f"  GONE  {name}: baseline row missing from fresh artifact")
        violations.append(
            f"{name}: baseline row missing from fresh artifact — scenario "
            "dropped or renamed? regenerate the committed baseline if intentional"
        )
    return violations


def check_overhead(artifact: dict, suffix: str, threshold: float) -> list:
    """Within-artifact overhead gate: every timed ``…SUFFIX…`` row vs its
    suffix-stripped twin. Returns violation strings (empty = gate passes)."""
    rows = _timed_rows(artifact)
    violations, pairs = [], 0
    for name in sorted(rows):
        if suffix not in name:
            continue
        base = name.replace(suffix, "")
        if base not in rows:
            violations.append(f"{name}: no paired row {base!r} in the artifact")
            continue
        pairs += 1
        ratio = rows[name] / max(rows[base], 1e-9)
        status = "FAIL" if ratio > threshold else "ok"
        print(
            f"  {status:>4}  {name}: {rows[name]:.1f}us vs {base} "
            f"{rows[base]:.1f}us ({ratio:.2f}x overhead)"
        )
        if ratio > threshold:
            violations.append(f"{name}: {ratio:.2f}x > {threshold}x overhead")
    if pairs == 0:
        violations.append(
            f"no timed row pairs with suffix {suffix!r} — nothing to gate "
            "(did the benchmark drop its telemetered configs?)"
        )
    return violations


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh", default="BENCH_stream.json", help="freshly generated artifact")
    ap.add_argument("--baseline", default=None, help="override the committed snapshot path")
    ap.add_argument("--threshold", type=float, default=1.5, help="max allowed fresh/baseline ratio")
    ap.add_argument(
        "--absolute", action="store_true",
        help="compare raw wall-times (same-host runs) instead of host-normalized rows",
    )
    ap.add_argument(
        "--update-smoke-baseline", metavar="ARTIFACT", default=None,
        help="copy ARTIFACT over the committed smoke baseline and exit",
    )
    ap.add_argument(
        "--overhead-suffix", default=None, metavar="SUFFIX",
        help="within-artifact mode: gate each ...SUFFIX... row against its "
             "suffix-stripped twin instead of comparing to a baseline",
    )
    ap.add_argument(
        "--overhead-threshold", type=float, default=1.3,
        help="max allowed paired-row overhead ratio for --overhead-suffix",
    )
    args = ap.parse_args()
    if args.update_smoke_baseline:
        os.makedirs(BASELINE_DIR, exist_ok=True)
        bench = _load(args.update_smoke_baseline).get("bench", "stream")
        dst = os.path.join(BASELINE_DIR, f"BENCH_{bench}.smoke.json")
        shutil.copy(args.update_smoke_baseline, dst)
        print(f"updated {dst}")
        return 0
    fresh = _load(args.fresh)
    if args.overhead_suffix:
        print(
            f"check_regression: {args.fresh} within-artifact overhead gate "
            f"(suffix {args.overhead_suffix!r}, threshold {args.overhead_threshold}x)"
        )
        violations = check_overhead(fresh, args.overhead_suffix, args.overhead_threshold)
        if violations:
            print(f"check_regression: {len(violations)} overhead violation(s)")
            for v in violations:
                print(f"  - {v}")
            return 1
        print("check_regression: OK")
        return 0
    baseline_path = args.baseline or baseline_path_for(fresh)
    if not os.path.exists(baseline_path):
        print(f"check_regression: no baseline at {baseline_path} — failing (commit one)")
        return 1
    baseline = _load(baseline_path)
    print(
        f"check_regression: {args.fresh} vs {baseline_path} (threshold {args.threshold}x, "
        f"{'absolute' if args.absolute else 'host-normalized'})"
    )
    violations = compare(fresh, baseline, args.threshold, absolute=args.absolute)
    if violations:
        print(f"check_regression: {len(violations)} perf regression(s)")
        for v in violations:
            print(f"  - {v}")
        return 1
    print("check_regression: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
