"""Kernel-layer benchmark: Pallas kernels vs pure-jnp oracles.

On this CPU container the Pallas bodies execute in interpret mode (Python)
— wall-time there is meaningless, so we report (i) correctness deltas vs
the ref oracle, (ii) XLA wall-time of the oracle path (the deployable CPU
fallback), and (iii) the *structural* HBM-traffic model of the fused
kernel vs the sequential evaluation — the quantity that decides TPU perf
(memory-bound regime; see kernels/twoside_sketch.py docstring).

  PYTHONPATH=src python -m benchmarks.sketch_perf [--smoke]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (
    countsketch_apply,
    countsketch_ref,
    panel_score,
    panel_score_ref,
    panel_update,
    panel_update_ref,
    twoside_sketch,
    twoside_sketch_ref,
)

from .common import time_call, write_bench_json


def _traffic_model(m, n, s_c, s_r, dtype_bytes=2):
    fused = (m * n + m * s_c + n * s_r + s_c * s_r) * dtype_bytes
    sequential = (m * n + m * s_c + 2 * s_c * n + n * s_r + s_c * s_r) * dtype_bytes
    return fused, sequential


def _panel_score_traffic(s_c, m, L, c, block_l=128, dtype_bytes=4):
    """HBM bytes: fused kernel vs the unfused three-op evaluation.

    Unfused: sc_a = S_C·A_L is written to HBM once and read back twice (the
    energy reduction and the Qᵀ·sc_a projection). Fused: the (s_c, bl) tile
    never leaves VMEM between the matmul and the two reductions — sc_a is
    written exactly once as an output and the extra traffic is just the
    (8, L) stats row. The fused side does re-fetch the S_C stripe once per
    L-block (its block index varies along the m-reduction, so it cannot
    stay resident across j sweeps — ``s_c·m·ceil(L/bl)`` bytes, matching
    the kernel docstring's traffic formula); A_L tiles and Q are read once.
    """
    l_sweeps = -(-L // block_l)
    fused = (m * L + s_c * m * l_sweeps + s_c * c + s_c * L + 8 * L) * dtype_bytes
    unfused = (m * L + s_c * m + s_c * c + 3 * s_c * L + c * L + 2 * L) * dtype_bytes
    return fused, unfused


def _panel_update_traffic(s_c, m, L, c, s_r, block_m=256, dtype_bytes=4):
    """HBM bytes: fused megakernel vs the unfused five-op panel update.

    Unfused: ``sc_a`` is written once and read back three times (energy,
    Qᵀ projection, M fold), the candidate columns of ``A_L`` are gathered a
    second time for the C scatter, and C/M each make a full read+write
    round-trip through XLA's scatter. Fused: ``sc_a`` stays VMEM-resident
    (written once as an output, zero read-backs), ``A_L`` tiles are read at
    most twice (sketch reduction + the C write of admitted row blocks), and
    C/M are aliased in place — C traffic is the admitted row-blocks'
    read+write, counted here at the full ``m·c`` worst case.
    """
    fused = (2 * m * L + s_c * m + s_c * c + L * s_r + 2 * s_c * s_r
             + 2 * m * c + s_c * L + 2 * 8 * L) * dtype_bytes
    unfused = (2 * m * L + s_c * m + s_c * c + L * s_r + 2 * s_c * s_r
               + 2 * m * c + 4 * s_c * L + c * L + 2 * L) * dtype_bytes
    return fused, unfused


def run(trials: int = 3, quick: bool = False) -> list:
    rows = []
    shapes = [(256, 2048, 2048, 256)] if quick else [
        (128, 1024, 1024, 128),
        (256, 2048, 2048, 256),
        (256, 4096, 8192, 256),
    ]
    for s_c, m, n, s_r in shapes:
        ks = jax.random.split(jax.random.key(0), 3)
        Sc = jax.random.normal(ks[0], (s_c, m), jnp.float32)
        A = jax.random.normal(ks[1], (m, n), jnp.float32)
        SrT = jax.random.normal(ks[2], (n, s_r), jnp.float32)
        out = twoside_sketch(Sc, A, SrT)
        ref = twoside_sketch_ref(Sc, A, SrT)
        rel = float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))
        us_ref = time_call(jax.jit(twoside_sketch_ref), Sc, A, SrT)
        fused, seq = _traffic_model(m, n, s_c, s_r)
        rows.append({
            "name": f"kernel/twoside/{s_c}x{m}x{n}x{s_r}",
            "us_per_call": round(us_ref, 1),
            "derived": f"pallas_rel_err={rel:.2e};hbm_fused={fused/1e6:.1f}MB;"
                       f"hbm_seq={seq/1e6:.1f}MB;traffic_save={seq/fused:.2f}x",
        })

    # Fused panel-scoring kernel (adaptive streaming CUR hot path): interpret
    # mode executes the kernel body for correctness; the XLA wall-time of the
    # unfused three-op reference is the deployable CPU fallback, and the
    # traffic model is what decides the TPU win (memory-bound regime).
    ps_shapes = [(240, 2048, 128, 16)] if quick else [
        (240, 1024, 128, 16),
        (240, 2048, 128, 16),
        (512, 4096, 256, 32),
    ]
    for s_c, m, L, c in ps_shapes:
        ks = jax.random.split(jax.random.key(2), 3)
        Sc = jax.random.normal(ks[0], (s_c, m), jnp.float32)
        A_L = jax.random.normal(ks[1], (m, L), jnp.float32)
        Q, _ = jnp.linalg.qr(jax.random.normal(ks[2], (s_c, c), jnp.float32))
        Qm = Q * (jnp.arange(c) < max(1, c // 2))  # half-filled admitted basis
        sc_a, r2, en = panel_score(Sc, A_L, Qm, interpret=True)
        sc_ref, r2_ref, en_ref = panel_score_ref(Sc, A_L, Qm)
        scale = float(jnp.max(jnp.abs(en_ref)))
        rel = max(
            float(jnp.max(jnp.abs(sc_a - sc_ref)) / jnp.max(jnp.abs(sc_ref))),
            float(jnp.max(jnp.abs(r2 - r2_ref))) / scale,
            float(jnp.max(jnp.abs(en - en_ref))) / scale,
        )
        us_ref = time_call(jax.jit(panel_score_ref), Sc, A_L, Qm)
        fused, unfused = _panel_score_traffic(s_c, m, L, c)
        rows.append({
            "name": f"kernel/panel_score/{s_c}x{m}x{L}_c{c}",
            "us_per_call": round(us_ref, 1),
            "derived": f"pallas_rel_err={rel:.2e};hbm_fused={fused/1e6:.1f}MB;"
                       f"hbm_unfused={unfused/1e6:.1f}MB;traffic_save={unfused/fused:.2f}x;"
                       f"sc_a_hbm_roundtrips=0vs2",
        })

    # Fused panel-update megakernel (sketch + score + admission + C scatter
    # + M fold in one launch, C/M aliased in place). Interpret mode executes
    # the kernel body against the unfused XLA oracle; the oracle wall-time
    # is the CPU fallback and the traffic model the TPU-decisive number.
    pu_shapes = [(240, 2048, 256, 16, 240)] if quick else [
        (240, 1024, 128, 16, 240),
        (240, 2048, 256, 16, 240),
        (512, 4096, 256, 32, 512),
    ]
    for s_c, m, L, c, s_r in pu_shapes:
        ks = jax.random.split(jax.random.key(3), 6)
        Sc = jax.random.normal(ks[0], (s_c, m), jnp.float32)
        A_L = jax.random.normal(ks[1], (m, L), jnp.float32)
        SrT = jax.random.normal(ks[2], (L, s_r), jnp.float32)
        Q, _ = jnp.linalg.qr(jax.random.normal(ks[3], (s_c, c), jnp.float32))
        Qm = Q * (jnp.arange(c) < max(1, c // 2))
        C = jax.random.normal(ks[4], (m, c), jnp.float32)
        M = jax.random.normal(ks[5], (s_c, s_r), jnp.float32)
        kw = dict(min_gain=0.5, run_mean=0.0, true_cols=float(L),
                  n_filled=c // 2, free=c - c // 2, panel_cap=4)
        out = panel_update(Sc, A_L, SrT, Qm, C, M, interpret=True, **kw)
        ref = panel_update_ref(Sc, A_L, SrT, Qm, C, M, **kw)
        rel = 0.0
        for o, rf in zip(out[:5], ref[:5]):  # C, M, sc_a, resid2, energy
            scale = float(jnp.max(jnp.abs(rf))) + 1e-30
            rel = max(rel, float(jnp.max(jnp.abs(o - rf))) / scale)
        slots_equal = bool(jnp.array_equal(out[5], ref[5]))
        us_ref = time_call(
            jax.jit(lambda *a: panel_update_ref(*a, **kw)), Sc, A_L, SrT, Qm, C, M
        )
        fused, unfused = _panel_update_traffic(s_c, m, L, c, s_r)
        rows.append({
            "name": f"kernel/panel_update/{s_c}x{m}x{L}_c{c}",
            "us_per_call": round(us_ref, 1),
            "derived": f"pallas_rel_err={rel:.2e};slots_exact={slots_equal};"
                       f"hbm_fused={fused/1e6:.1f}MB;hbm_unfused={unfused/1e6:.1f}MB;"
                       f"traffic_save={unfused/fused:.2f}x;sc_a_hbm_roundtrips=0vs3",
        })

    cs_shapes = [(256, 4096, 1024)] if quick else [(128, 2048, 512), (256, 4096, 1024), (512, 8192, 2048)]
    for s, m, n in cs_shapes:
        ks = jax.random.split(jax.random.key(1), 3)
        h = jax.random.randint(ks[0], (m,), 0, s)
        sg = jax.random.rademacher(ks[1], (m,), jnp.float32)
        A = jax.random.normal(ks[2], (m, n), jnp.float32)
        out = countsketch_apply(h, sg, A, s)
        ref = countsketch_ref(h, sg, A, s)
        rel = float(jnp.max(jnp.abs(out - ref)) / (jnp.max(jnp.abs(ref)) + 1e-30))
        us_ref = time_call(jax.jit(countsketch_ref, static_argnums=3), h, sg, A, s)
        rows.append({
            "name": f"kernel/countsketch/s{s}_{m}x{n}",
            "us_per_call": round(us_ref, 1),
            "derived": f"pallas_rel_err={rel:.2e};hbm_passes_over_A=1",
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="single shape per kernel (CI)")
    ap.add_argument("--out-dir", default=None, help="where to write BENCH_kernels.json")
    args = ap.parse_args()
    rows = run(trials=1 if args.smoke else 3, quick=args.smoke)
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row['name']},{row['us_per_call']},{str(row['derived']).replace(',', ';')}")
    path = write_bench_json("kernels", rows, meta={"smoke": args.smoke}, out_dir=args.out_dir)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
