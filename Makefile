# Convenience targets mirroring CI. Tier-1 verify == `make test`.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast smoke bench

test:
	$(PY) -m pytest -x -q

test-fast:  ## skip the slow multi-device subprocess scenarios
	$(PY) -m pytest -x -q -m "not slow"

smoke:  ## quick CUR benchmark (CI artifact check)
	$(PY) -m benchmarks.cur_decomp --smoke

bench:  ## full benchmark harness, CSV on stdout
	$(PY) -m benchmarks.run
