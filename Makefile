# Convenience targets mirroring CI. Tier-1 verify == `make test`
# (the default lane; `slow`-marked sweeps run via `make test-slow`).
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-slow test-all smoke bench docs-check perf-check obs-check chaos-check census-check

test:  ## default tier-1 lane (slow sweeps excluded via pyproject addopts)
	$(PY) -m pytest -x -q

docs-check:  ## docstring audit (repro.stream/cur/spsd/obs/serve) + docs/paper_map.md anchors
	$(PY) tools/check_docstrings.py

test-slow:  ## heavy sweeps + multi-device subprocess scenarios
	$(PY) -m pytest -x -q -m slow

test-all:  ## both lanes
	$(PY) -m pytest -x -q -m "slow or not slow"

smoke:  ## quick benchmark artifacts (CI)
	$(PY) -m benchmarks.cur_decomp --smoke
	$(PY) -m benchmarks.stream_bench --smoke
	$(PY) -m benchmarks.spsd_approx --smoke
	$(PY) -m benchmarks.serve_bench --smoke

perf-check:  ## regenerate the smoke benches and gate vs benchmarks/baselines/
	$(PY) -m benchmarks.stream_bench --smoke --out-dir /tmp/perf-check
	$(PY) -m benchmarks.check_regression --fresh /tmp/perf-check/BENCH_stream.json
	$(PY) -m benchmarks.spsd_approx --smoke --out-dir /tmp/perf-check
	$(PY) -m benchmarks.check_regression --fresh /tmp/perf-check/BENCH_spsd.json
	$(PY) -m benchmarks.serve_bench --smoke --out-dir /tmp/perf-check
	$(PY) -m benchmarks.check_regression --fresh /tmp/perf-check/BENCH_serve.json
	$(PY) -m benchmarks.sketch_perf --smoke --out-dir /tmp/perf-check
	$(PY) -m benchmarks.check_regression --fresh /tmp/perf-check/BENCH_kernels.json

census-check:  ## scan-body HLO census: fused >=25% leaner + committed budgets
	$(PY) tools/census_check.py

obs-check:  ## telemetry acceptance: <=1.3x paired-row overhead + HLO/bitwise identity
	$(PY) -m benchmarks.stream_bench --smoke --out-dir /tmp/obs-check
	$(PY) -m benchmarks.check_regression --fresh /tmp/obs-check/BENCH_stream.json \
	    --overhead-suffix "+tel" --overhead-threshold 1.3
	$(PY) -m pytest -q tests/test_obs.py -k "hlo or bitwise"

chaos-check:  ## stream suite under seeded FaultPlan (crash + NaN + straggler): zero factor divergence
	$(PY) tools/chaos_check.py
	$(PY) -m pytest -q tests/test_resilient.py

bench:  ## full benchmark harness, CSV on stdout
	$(PY) -m benchmarks.run
