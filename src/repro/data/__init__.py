"""Deterministic restartable data pipeline."""
from .synthetic import DataConfig, SyntheticLM
