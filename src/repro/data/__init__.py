"""Deterministic restartable data pipeline + synthetic test matrices."""
from .synthetic import (
    DataConfig,
    SyntheticLM,
    lowrank_plus_noise,
    powerlaw_matrix,
    sparse_matrix,
    spiked_decay_matrix,
)
