"""Deterministic synthetic data: test matrices + shardable token pipeline.

Matrix generators (:func:`powerlaw_matrix`, :func:`sparse_matrix`,
:func:`lowrank_plus_noise`) are the offline substitutions for the paper's
LIBSVM datasets (matched spectral / sparsity profiles, DESIGN.md §8) and
the ground truth for the CUR / GMR / SVD test-and-benchmark suites —
``benchmarks/common.py`` re-exports them.

Stateless-by-construction: ``batch_at(step)`` derives every batch from
``fold_in(seed, step)``, so restart-from-checkpoint only needs the step
counter — no iterator state files, no skew between hosts (each host can
slice its DP shard of the same deterministic batch).

Token stream: a Zipf-ish unigram mixture with short Markov motifs so the
loss has real structure to learn (pure uniform tokens give a flat loss and
hide optimizer bugs).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Test matrices (paper §6 dataset substitutions)
# ---------------------------------------------------------------------------


def powerlaw_matrix(key, m: int, n: int, decay: float = 1.0, dtype=jnp.float32):
    """Dense matrix with σ_i ∝ i^-decay (the spectral profile of the paper's
    dense LIBSVM datasets)."""
    k1, k2 = jax.random.split(key)
    r = min(m, n)
    U, _ = jnp.linalg.qr(jax.random.normal(k1, (m, r), dtype))
    V, _ = jnp.linalg.qr(jax.random.normal(k2, (n, r), dtype))
    sv = jnp.arange(1, r + 1, dtype=dtype) ** (-decay)
    return (U * sv[None, :]) @ V.T


def sparse_matrix(key, m: int, n: int, density: float = 0.002, dtype=jnp.float32):
    """Sparse-profile matrix (rcv1/news20 substitution): Bernoulli mask × normal."""
    k1, k2 = jax.random.split(key)
    mask = jax.random.bernoulli(k1, density, (m, n))
    vals = jax.random.normal(k2, (m, n), dtype)
    return jnp.where(mask, vals, 0.0)


def lowrank_plus_noise(key, m: int, n: int, rank: int = 10, snr: float = 10.0, dtype=jnp.float32):
    """Exactly-rank-k signal plus white noise at the given signal-to-noise
    ratio — the regime where CUR / randomized SVD guarantees are sharpest."""
    k1, k2, k3 = jax.random.split(key, 3)
    L = jax.random.normal(k1, (m, rank), dtype)
    Rf = jax.random.normal(k2, (rank, n), dtype)
    signal = (L @ Rf) / np.sqrt(rank)
    noise = jax.random.normal(k3, (m, n), dtype)
    return signal + (jnp.linalg.norm(signal) / (snr * jnp.linalg.norm(noise))) * noise


def spiked_decay_matrix(
    key, m: int, n: int, n_spikes: int = 8, spike: float = 6.0, noise: float = 0.05,
    dtype=jnp.float32,
):
    """Fast-decaying background plus a few heavy columns at random positions
    — the regime where adaptive (residual-driven) column selection separates
    from uniform pre-pass selection. Returns ``(A, spike_positions)``."""
    k1, k2, k3 = jax.random.split(key, 3)
    B = noise * powerlaw_matrix(k1, m, n, 1.5, dtype=dtype)
    pos = jax.random.choice(k2, n, (n_spikes,), replace=False)
    return B.at[:, pos].add(spike * jax.random.normal(k3, (m, n_spikes), dtype)), pos


def late_spike_matrix(
    key, m: int, n: int, n_early: int = 8, n_late: int = 6,
    early: float = 3.0, late: float = 9.0, noise: float = 0.05,
    early_frac: float = 0.3, late_frac: float = 0.7, dtype=jnp.float32,
):
    """The adversarial stream for admission-*only* policies: enough
    moderately-heavy columns early in the stream to fill any column budget
    ``c ≤ n_early``, then strictly heavier columns after ``late_frac·n`` —
    by which point an admission-only policy has no free slots left and loses
    them, while an eviction policy swaps its weakest admits out. Returns
    ``(A, early_positions, late_positions)``."""
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    B = noise * powerlaw_matrix(k1, m, n, 1.5, dtype=dtype)
    n_head = max(int(early_frac * n), n_early)
    late_lo = min(int(late_frac * n), n - n_late)
    if n_head > late_lo:
        raise ValueError(
            f"early window [0, {n_head}) overlaps late window [{late_lo}, {n}); "
            f"need a larger n (or fewer/narrower spike windows) for m×n={m}×{n}"
        )
    early_pos = jax.random.choice(k2, n_head, (n_early,), replace=False)
    late_pos = late_lo + jax.random.choice(k3, n - late_lo, (n_late,), replace=False)
    B = B.at[:, early_pos].add(early * jax.random.normal(k4, (m, n_early), dtype))
    B = B.at[:, late_pos].add(late * jax.random.normal(k5, (m, n_late), dtype))
    return B, early_pos, late_pos


def spiked_rows_matrix(
    key, m: int, n: int, n_spikes: int = 6, spike: float = 6.0, noise: float = 0.05,
    dtype=jnp.float32,
):
    """Transposed analogue of :func:`spiked_decay_matrix`: a few heavy *rows*
    at random positions over a decaying background — the regime where
    adaptive in-stream row admission separates from fixed pre-pass (uniform)
    row selection. Returns ``(A, spiked_row_positions)``."""
    k1, k2, k3 = jax.random.split(key, 3)
    B = noise * powerlaw_matrix(k1, m, n, 1.5, dtype=dtype)
    pos = jax.random.choice(k2, m, (n_spikes,), replace=False)
    return B.at[pos, :].add(spike * jax.random.normal(k3, (n_spikes, n), dtype)), pos


def drifting_spectrum_matrix(
    key, m: int, n: int, n_blocks: int = 4, rank: int = 4, ramp: float = 2.5,
    noise: float = 0.05, dtype=jnp.float32,
):
    """Column stream whose dominant subspace *drifts*: each successive
    column block carries a fresh random rank-``rank`` subspace whose energy
    grows by ``ramp×`` per block. Early blocks clear any data-relative
    admission threshold and fill the budget; the strictly stronger late
    blocks then require eviction to be represented. Returns ``(A,
    block_bounds)`` with ``block_bounds`` the (n_blocks+1,) column offsets."""
    keys = jax.random.split(key, n_blocks + 1)
    B = noise * jax.random.normal(keys[0], (m, n), dtype)
    bounds = np.linspace(0, n, n_blocks + 1).astype(int)
    for b in range(n_blocks):
        lo, hi = int(bounds[b]), int(bounds[b + 1])
        kL, kR = jax.random.split(keys[b + 1])
        L = jax.random.normal(kL, (m, rank), dtype)
        Rf = jax.random.normal(kR, (rank, hi - lo), dtype)
        B = B.at[:, lo:hi].add((ramp ** b) * (L @ Rf) / np.sqrt(rank))
    return B, jnp.asarray(bounds, jnp.int32)


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8


class SyntheticLM:
    def __init__(self, dc: DataConfig):
        self.dc = dc
        probs = 1.0 / np.arange(1, dc.vocab_size + 1) ** dc.zipf_a
        self._logits = jnp.asarray(np.log(probs / probs.sum()), jnp.float32)
        self._base = jax.random.key(dc.seed)
        self._batch_at = jax.jit(self._make_batch, static_argnums=())

    def _make_batch(self, step):
        dc = self.dc
        key = jax.random.fold_in(self._base, step)
        k1, k2, k3 = jax.random.split(key, 3)
        toks = jax.random.categorical(
            k1, jnp.broadcast_to(self._logits, (dc.batch, dc.seq_len, dc.vocab_size))
        )
        # overlay deterministic motifs: every motif_len-run repeats its first token
        # with p=0.5 — gives learnable bigram structure
        rep = jnp.repeat(
            toks[:, :: dc.motif_len], dc.motif_len, axis=1
        )[:, : dc.seq_len]
        gate = jax.random.bernoulli(k2, 0.5, toks.shape)
        toks = jnp.where(gate, rep, toks)
        return {"tokens": toks.astype(jnp.int32)}

    def batch_at(self, step: int):
        return self._batch_at(jnp.asarray(step, jnp.int32))

    def state(self, step: int) -> dict:
        """Checkpointable iterator state (trivially the step)."""
        return {"step": int(step), "seed": self.dc.seed}
