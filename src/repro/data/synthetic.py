"""Deterministic, shardable, restartable synthetic token pipeline.

Stateless-by-construction: ``batch_at(step)`` derives every batch from
``fold_in(seed, step)``, so restart-from-checkpoint only needs the step
counter — no iterator state files, no skew between hosts (each host can
slice its DP shard of the same deterministic batch).

Token stream: a Zipf-ish unigram mixture with short Markov motifs so the
loss has real structure to learn (pure uniform tokens give a flat loss and
hide optimizer bugs).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8


class SyntheticLM:
    def __init__(self, dc: DataConfig):
        self.dc = dc
        probs = 1.0 / np.arange(1, dc.vocab_size + 1) ** dc.zipf_a
        self._logits = jnp.asarray(np.log(probs / probs.sum()), jnp.float32)
        self._base = jax.random.key(dc.seed)
        self._batch_at = jax.jit(self._make_batch, static_argnums=())

    def _make_batch(self, step):
        dc = self.dc
        key = jax.random.fold_in(self._base, step)
        k1, k2, k3 = jax.random.split(key, 3)
        toks = jax.random.categorical(
            k1, jnp.broadcast_to(self._logits, (dc.batch, dc.seq_len, dc.vocab_size))
        )
        # overlay deterministic motifs: every motif_len-run repeats its first token
        # with p=0.5 — gives learnable bigram structure
        rep = jnp.repeat(
            toks[:, :: dc.motif_len], dc.motif_len, axis=1
        )[:, : dc.seq_len]
        gate = jax.random.bernoulli(k2, 0.5, toks.shape)
        toks = jnp.where(gate, rep, toks)
        return {"tokens": toks.astype(jnp.int32)}

    def batch_at(self, step: int):
        return self._batch_at(jnp.asarray(step, jnp.int32))

    def state(self, step: int) -> dict:
        """Checkpointable iterator state (trivially the step)."""
        return {"step": int(step), "seed": self.dc.seed}
