"""Deterministic synthetic data: test matrices + shardable token pipeline.

Matrix generators (:func:`powerlaw_matrix`, :func:`sparse_matrix`,
:func:`lowrank_plus_noise`) are the offline substitutions for the paper's
LIBSVM datasets (matched spectral / sparsity profiles, DESIGN.md §8) and
the ground truth for the CUR / GMR / SVD test-and-benchmark suites —
``benchmarks/common.py`` re-exports them.

Stateless-by-construction: ``batch_at(step)`` derives every batch from
``fold_in(seed, step)``, so restart-from-checkpoint only needs the step
counter — no iterator state files, no skew between hosts (each host can
slice its DP shard of the same deterministic batch).

Token stream: a Zipf-ish unigram mixture with short Markov motifs so the
loss has real structure to learn (pure uniform tokens give a flat loss and
hide optimizer bugs).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Test matrices (paper §6 dataset substitutions)
# ---------------------------------------------------------------------------


def powerlaw_matrix(key, m: int, n: int, decay: float = 1.0, dtype=jnp.float32):
    """Dense matrix with σ_i ∝ i^-decay (the spectral profile of the paper's
    dense LIBSVM datasets)."""
    k1, k2 = jax.random.split(key)
    r = min(m, n)
    U, _ = jnp.linalg.qr(jax.random.normal(k1, (m, r), dtype))
    V, _ = jnp.linalg.qr(jax.random.normal(k2, (n, r), dtype))
    sv = jnp.arange(1, r + 1, dtype=dtype) ** (-decay)
    return (U * sv[None, :]) @ V.T


def sparse_matrix(key, m: int, n: int, density: float = 0.002, dtype=jnp.float32):
    """Sparse-profile matrix (rcv1/news20 substitution): Bernoulli mask × normal."""
    k1, k2 = jax.random.split(key)
    mask = jax.random.bernoulli(k1, density, (m, n))
    vals = jax.random.normal(k2, (m, n), dtype)
    return jnp.where(mask, vals, 0.0)


def lowrank_plus_noise(key, m: int, n: int, rank: int = 10, snr: float = 10.0, dtype=jnp.float32):
    """Exactly-rank-k signal plus white noise at the given signal-to-noise
    ratio — the regime where CUR / randomized SVD guarantees are sharpest."""
    k1, k2, k3 = jax.random.split(key, 3)
    L = jax.random.normal(k1, (m, rank), dtype)
    Rf = jax.random.normal(k2, (rank, n), dtype)
    signal = (L @ Rf) / np.sqrt(rank)
    noise = jax.random.normal(k3, (m, n), dtype)
    return signal + (jnp.linalg.norm(signal) / (snr * jnp.linalg.norm(noise))) * noise


def spiked_decay_matrix(
    key, m: int, n: int, n_spikes: int = 8, spike: float = 6.0, noise: float = 0.05,
    dtype=jnp.float32,
):
    """Fast-decaying background plus a few heavy columns at random positions
    — the regime where adaptive (residual-driven) column selection separates
    from uniform pre-pass selection. Returns ``(A, spike_positions)``."""
    k1, k2, k3 = jax.random.split(key, 3)
    B = noise * powerlaw_matrix(k1, m, n, 1.5, dtype=dtype)
    pos = jax.random.choice(k2, n, (n_spikes,), replace=False)
    return B.at[:, pos].add(spike * jax.random.normal(k3, (m, n_spikes), dtype)), pos


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8


class SyntheticLM:
    def __init__(self, dc: DataConfig):
        self.dc = dc
        probs = 1.0 / np.arange(1, dc.vocab_size + 1) ** dc.zipf_a
        self._logits = jnp.asarray(np.log(probs / probs.sum()), jnp.float32)
        self._base = jax.random.key(dc.seed)
        self._batch_at = jax.jit(self._make_batch, static_argnums=())

    def _make_batch(self, step):
        dc = self.dc
        key = jax.random.fold_in(self._base, step)
        k1, k2, k3 = jax.random.split(key, 3)
        toks = jax.random.categorical(
            k1, jnp.broadcast_to(self._logits, (dc.batch, dc.seq_len, dc.vocab_size))
        )
        # overlay deterministic motifs: every motif_len-run repeats its first token
        # with p=0.5 — gives learnable bigram structure
        rep = jnp.repeat(
            toks[:, :: dc.motif_len], dc.motif_len, axis=1
        )[:, : dc.seq_len]
        gate = jax.random.bernoulli(k2, 0.5, toks.shape)
        toks = jnp.where(gate, rep, toks)
        return {"tokens": toks.astype(jnp.int32)}

    def batch_at(self, step: int):
        return self._batch_at(jnp.asarray(step, jnp.int32))

    def state(self, step: int) -> dict:
        """Checkpointable iterator state (trivially the step)."""
        return {"step": int(step), "seed": self.dc.seed}
