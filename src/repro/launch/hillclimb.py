import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ before any other import (see dryrun.py)

"""§Perf hillclimbing driver: run tagged variants of the three chosen cells
and print hypothesis → before → after per roofline term.

  PYTHONPATH=src python -m repro.launch.hillclimb [--cell A|B|C] [--variant NAME]
"""

import argparse
import json

from repro.launch.dryrun import ARTIFACT_DIR, run_cell

OUT = os.path.abspath(ARTIFACT_DIR)

PEAK, HBM, ICI = 197e12, 819e9, 50e9

# cell → list of (variant_tag, overrides, hypothesis)
PLAN = {
    "A": ("llama3.2-1b", "train_4k", [
        ("A1_flashvjp", {"attn_impl": "custom_vjp"},
         "scan-AD attention stacks per-pair residuals w/ full-buffer convert "
         "round-trips (~60% of HBM traffic); flash custom-VJP saves only "
         "(q,k,v,out,lse) → expect memory term down 2-3x, flops +~15% (p recompute)"),
        ("A2_flashvjp_micro2", {"attn_impl": "custom_vjp", "_microbatch": 2},
         "activation working set halves with 2 microbatches → peak mem under "
         "16GB; HBM traffic ~flat (same bytes, two passes); grads accumulate in fp32"),
        ("A3_flashvjp_gmr64", {"attn_impl": "custom_vjp", "_compress_rank": 64,
                               "_compress_min_dim": 1024, "_remat": None},
         "paper's Algorithm 1 replaces the dense DP grad all-reduce: sketch "
         "(C,R,M) psums ≈ (m+n)·64+256² floats per big matrix vs m·n → expect "
         "all-reduce wire bytes down ~2x (activation psums remain), small flops add"),
        ("A4_flashvjp_bf16mom", {"attn_impl": "custom_vjp", "_moments_dtype": "bfloat16"},
         "Adam m/v in bf16: optimizer HBM traffic and resident bytes halve; "
         "expect peak mem −~4GB and memory term slightly down"),
    ]),
    "B": ("kimi-k2-1t-a32b", "train_4k", [
        ("B1_flashvjp", {"attn_impl": "custom_vjp"},
         "attention dominates kimi flops at S=4096 (S² term ≫ per-token expert "
         "compute); flash VJP kills stacked-residual traffic across 61 layers "
         "→ expect memory term down ~2x"),
        ("B2_flashvjp_bf16mom", {"attn_impl": "custom_vjp", "_moments_dtype": "bfloat16"},
         "1T params × fp32 m+v = 31GB/dev resident + traffic; bf16 moments "
         "halve it → peak mem −~15GB"),
        ("B3_flashvjp_bf16mom_cap1_micro4",
         {"attn_impl": "custom_vjp", "_moments_dtype": "bfloat16",
          "capacity_factor": 1.0, "_microbatch": 4},
         "MoE dispatch buffers (E,cap,D) scale with tokens-in-flight: capacity "
         "1.25→1.0 and 4 microbatches cut buffer bytes ~5x → memory term and "
         "peak mem sharply down; wire/flops ~flat"),
        ("B4_ecd_dp_shard",
         {"attn_impl": "custom_vjp", "_moments_dtype": "bfloat16"},
         "census shows MoE expert einsum flops ~16x the unique work: the "
         "(E,cap,D) dispatch buffer was replicated over `data`, so every data "
         "rank recomputed every expert; a sharding HINT on cap should cut "
         "compute — REFUTED: the scatter overrides the constraint (see B5)"),
        ("B5_grouped_dispatch",
         {"attn_impl": "custom_vjp", "_moments_dtype": "bfloat16",
          "moe_dispatch_shards": 16},
         "restructure dispatch into 16 token groups with a leading dim aligned "
         "to the data sharding: batched scatter/einsum stay local per data "
         "rank -> expect compute term down ~10x (MoE no longer replicated), "
         "memory down similarly"),
        ("B6_combined",
         {"attn_impl": "custom_vjp", "_moments_dtype": "bfloat16",
          "moe_dispatch_shards": 16, "capacity_factor": 1.0, "_microbatch": 2},
         "stack B5 with capacity 1.0 and 2 microbatches: dispatch buffers "
         "-2.5x more, activations halve; micro=2 doubles FSDP regathers "
         "(collective up some) -> net bound term should still drop"),
    ]),
    "C": ("mamba2-1.3b", "prefill_32k", [
        ("C1_seqparallel", {"_seq_parallel": 1},
         "TP psums move the full (B,S,D) residual twice per layer (96 ARs, "
         "48GB wire) though per-chip compute is tiny; sequence-parallel SSM "
         "prefill (S over `model`, weights replicated — SSM state hand-off is "
         "only conv halos + chunk states) → expect collective term down ~10x"),
        ("C2_seqparallel_chunk512", {"_seq_parallel": 1, "ssm_chunk": 512},
         "with S local per shard, bigger SSD chunks (256→512) halve the "
         "inter-chunk scan length → fewer small ops, HBM traffic down slightly"),
    ]),
}


def terms(rec):
    wire = sum(v["wire_bytes"] for v in rec["collectives"].values())
    return dict(
        compute=rec["flops_per_device"] / PEAK,
        memory=rec["hbm_bytes_per_device"] / HBM,
        collective=wire / ICI,
        mem_gb=rec["memory"]["peak_estimate_bytes"] / 1e9,
    )


def show(label, t, base=None):
    def d(k):
        if base is None:
            return ""
        b = base[k]
        return f" ({t[k]/b:5.2f}x)" if b > 0 else ""

    print(f"  {label:28s} compute={t['compute']:9.3e}{d('compute')}  "
          f"memory={t['memory']:9.3e}{d('memory')}  collective={t['collective']:9.3e}{d('collective')}  "
          f"mem/dev={t['mem_gb']:7.1f}GB{d('mem_gb')}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all", choices=["A", "B", "C", "all"])
    ap.add_argument("--variant", default="")
    args = ap.parse_args()

    cells = PLAN if args.cell == "all" else {args.cell: PLAN[args.cell]}
    for cell_id, (arch, shape, variants) in cells.items():
        base_path = os.path.join(OUT, f"{arch}__{shape}__16x16.json")
        with open(base_path) as f:
            base = terms(json.load(f))
        print(f"\n=== Cell {cell_id}: {arch} / {shape} ===")
        show("baseline (paper-faithful)", base)
        for tag, overrides, hypothesis in variants:
            if args.variant and args.variant != tag:
                continue
            print(f"  -- {tag}: {hypothesis[:110]}...")
            rec = run_cell(arch, shape, multi_pod=False, out_dir=OUT,
                           overrides=dict(overrides), tag=tag, verbose=False)
            show(tag, terms(rec), base)


if __name__ == "__main__":
    main()
