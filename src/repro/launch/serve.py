"""Batched serving driver: prefill + decode loop with timing.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \\
      --batch 4 --prompt-len 64 --gen 32 --mesh 4x2
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.distributed.sharding import ParallelismRules, activation_sharding, param_shardings
from repro.models import init_params, param_count
from repro.models.modality import synth_patch_embeddings
from repro.serve import KVCompressionConfig, generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mesh", default="4x2")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-compress", type=int, default=0, metavar="RANK",
                    help="compress full-attention KV caches at this rank "
                         "(decode-native streaming SVD; 0 = dense caches)")
    ap.add_argument("--kv-adaptive", action="store_true",
                    help="share the rank budget adaptively across heads")
    args = ap.parse_args(argv)

    kc = None
    if args.kv_compress:
        kc = KVCompressionConfig(rank=args.kv_compress, oversample=2, panel=32,
                                 decode_panel=8, refresh_every=32,
                                 adaptive=args.kv_adaptive,
                                 min_rank=max(1, args.kv_compress // 4))

    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh((d, m), ("data", "model"))
    rules = ParallelismRules(dp_axes=("data",))
    mod = get_arch(args.arch)
    cfg = mod.smoke_config() if args.smoke else mod.full_config()

    params = init_params(jax.random.key(args.seed), cfg)
    params = jax.device_put(params, param_shardings(params, rules, mesh))
    print(f"[serve] {cfg.name}: {param_count(params)/1e6:.2f}M params")

    key = jax.random.key(args.seed + 1)
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)
    vision = synth_patch_embeddings(key, cfg, args.batch) if cfg.d_vision else None

    with mesh, activation_sharding(mesh, rules):
        t0 = time.time()
        out = generate(params, cfg, prompt, args.gen, key=key,
                       temperature=args.temperature, vision=vision, dense_moe=True,
                       kv_compress=kc)
        out.block_until_ready()
    dt = time.time() - t0
    n_tok = args.batch * args.gen
    mode = f"compressed kv @ rank {kc.rank}" + (" adaptive" if kc.adaptive else "") \
        if kc else "dense kv"
    print(f"[serve] generated {out.shape} in {dt:.2f}s ({n_tok/dt:.1f} tok/s incl. compile, {mode})")
    print("[serve] sample:", out[0, :16].tolist())
    return out


if __name__ == "__main__":
    main()
