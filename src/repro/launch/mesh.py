"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (device count is locked at first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2×16×16 = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 4, model: int = 2):
    """CI-scale mesh for tests/examples on the local host devices."""
    return jax.make_mesh((data, model), ("data", "model"))
