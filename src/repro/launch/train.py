"""End-to-end training driver (CPU-host scale; the multi-pod path is the
same code under the production mesh via launch/dryrun.py).

Example — the ~100M run used by examples/train_lm.py:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.train \\
      --arch llama3.2-1b --d-model 512 --layers 12 --heads 8 --kv-heads 4 \\
      --d-ff 2048 --vocab 8192 --batch 16 --seq 256 --steps 200 \\
      --mesh 4x2 [--grad-compress --compress-rank 32]
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint import run_resilient_loop
from repro.configs import get_arch
from repro.data import DataConfig, SyntheticLM
from repro.distributed.sharding import (
    ParallelismRules,
    activation_sharding,
    batch_pspec,
    param_shardings,
)
from repro.models import init_params, param_count
from repro.train import (
    CompressionConfig,
    OptimizerConfig,
    compression_ratio,
    init_opt_state,
    make_compressed_train_step,
    make_train_step,
)


def build_config(args):
    cfg = get_arch(args.arch).smoke_config() if args.smoke else get_arch(args.arch).full_config()
    over = {}
    if args.d_model:
        over.update(d_model=args.d_model, d_ff=args.d_ff or 4 * args.d_model)
    if args.layers:
        mod = get_arch(args.arch)
        base = mod.full_config()
        # rebuild the pattern at the requested depth with the same block mix
        unit = base.pattern[: max(1, len(base.pattern) // base.n_layers)]
        reps = base.pattern * ((args.layers // len(base.pattern)) + 1)
        over.update(n_layers=args.layers, pattern=tuple(reps[: args.layers]))
    if args.heads:
        over.update(n_heads=args.heads)
    if args.kv_heads:
        over.update(n_kv_heads=args.kv_heads)
    if args.head_dim:
        over.update(head_dim=args.head_dim)
    if args.vocab:
        over.update(vocab_size=args.vocab)
    if args.dtype:
        over.update(dtype=args.dtype)
    if over:
        cfg = dataclasses.replace(cfg, **over)
    return cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--d-ff", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--heads", type=int, default=0)
    ap.add_argument("--kv-heads", type=int, default=0)
    ap.add_argument("--head-dim", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default="4x2", help="dataxmodel, e.g. 4x2")
    ap.add_argument("--remat", default="dots", choices=["dots", "full", "none"])
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--compress-rank", type=int, default=32)
    ap.add_argument("--compress-factor", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at-step", type=int, default=-1, help="inject a crash (FT demo)")
    args = ap.parse_args(argv)

    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh((d, m), ("data", "model"))
    rules = ParallelismRules(dp_axes=("data",))
    cfg = build_config(args)

    params = init_params(jax.random.key(args.seed), cfg)
    pshard = param_shardings(params, rules, mesh)
    params = jax.device_put(params, pshard)
    oc = OptimizerConfig(lr=args.lr, warmup_steps=min(20, args.steps // 10 + 1), total_steps=args.steps)
    state = {"params": params, "opt": init_opt_state(params, oc)}
    print(f"[train] {cfg.name}: {param_count(params)/1e6:.1f}M params, mesh {d}x{m}, "
          f"{args.steps} steps @ batch {args.batch}x{args.seq}")

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, batch=args.batch, seq_len=args.seq, seed=args.seed))
    bshard = {"tokens": NamedSharding(mesh, batch_pspec(rules))}
    remat = None if args.remat == "none" else args.remat

    if args.grad_compress:
        ccfg = CompressionConfig(rank=args.compress_rank, sketch_factor=args.compress_factor,
                                 min_dim=min(512, cfg.d_model))
        print(f"[train] GMR gradient compression: rank={ccfg.rank} s={ccfg.s} "
              f"DP volume ratio={compression_ratio(params, ccfg):.1f}x")
        cstep, init_err = make_compressed_train_step(cfg, oc, ccfg, mesh, rules, remat=remat)
        state["err"] = init_err(params)

        def step_fn(state, batch, step):
            with activation_sharding(mesh, rules):
                return cstep(state, batch, jax.random.fold_in(jax.random.key(9), step))
    else:
        base_step = make_train_step(cfg, oc, remat=remat, microbatch=args.microbatch)

        def traced(state, batch):
            with activation_sharding(mesh, rules):
                return base_step(state, batch)

        jstep = jax.jit(traced, donate_argnums=(0,))

        def step_fn(state, batch, step):
            return jstep(state, batch)

    ckpt_dir = args.ckpt_dir or os.path.join("/tmp", f"repro_ckpt_{cfg.name}")
    t0 = time.time()
    report = run_resilient_loop(
        state=state,
        step_fn=step_fn,
        batch_fn=lambda s: jax.device_put(data.batch_at(s), bshard),
        n_steps=args.steps,
        ckpt_dir=ckpt_dir,
        ckpt_every=args.ckpt_every,
        fail_at_step=args.fail_at_step if args.fail_at_step >= 0 else None,
    )
    dt = time.time() - t0
    print(f"[train] done: {report.steps_run} steps in {dt:.1f}s "
          f"({dt/max(report.steps_run,1)*1e3:.0f} ms/step), restarts={report.restarts}, "
          f"loss {report.losses[0]:.4f} -> {report.losses[-1]:.4f}")
    return report


if __name__ == "__main__":
    main()
