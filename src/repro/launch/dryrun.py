import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this emits
  * ``compiled.memory_analysis()``   — per-device bytes (proves it fits),
  * ``compiled.cost_analysis()``     — per-device FLOPs / bytes accessed,
  * a collective census parsed from ``compiled.as_text()`` (op kind,
    result bytes, group size, algorithm-adjusted wire bytes),
into ``benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json``; the
roofline analysis (benchmarks/roofline.py, EXPERIMENTS.md §Roofline) reads
these artifacts.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from collections import defaultdict
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_arch
from repro.distributed.sharding import (
    ParallelismRules,
    activation_sharding,
    batch_pspec,
    cache_shardings,
    param_shardings,
)
from repro.launch.hlo_census import census as hlo_census
from repro.launch.mesh import make_production_mesh
from repro.models import decode_step, init_cache, init_params, prefill
from repro.models.config import ModelConfig
from repro.train import OptimizerConfig, init_opt_state, make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "benchmarks", "artifacts", "dryrun")

# archs whose TP-only weight shards exceed one v5e's HBM → FSDP over data
FSDP_ARCHS = {"kimi-k2-1t-a32b", "llama-3.2-vision-90b"}


def rules_for(arch_id: str, mesh, knobs: dict | None = None) -> ParallelismRules:
    rules = ParallelismRules(fsdp=arch_id in FSDP_ARCHS).with_mesh(mesh)
    knobs = knobs or {}
    if knobs.get("_no_fsdp"):
        rules = dataclasses.replace(rules, fsdp=False)
    if knobs.get("_seq_parallel"):
        rules = dataclasses.replace(rules, seq_parallel=True, tp_enabled=False)
    return rules


def _attach(structs, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh), structs, shardings
    )


def input_specs(arch_id: str, shape_name: str, mesh, *, overrides: dict | None = None):
    """Abstract (ShapeDtypeStruct + sharding) inputs for one cell.

    Returns (step_fn, args tuple, in_shardings-attached args, donate_argnums,
    out_shardings hint or None).
    """
    mod = get_arch(arch_id)
    cfg = mod.full_config()
    # underscore-prefixed overrides are step-level knobs, not config fields
    overrides = dict(overrides or {})
    knobs = {k: overrides.pop(k) for k in list(overrides) if k.startswith("_")}
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cell = SHAPES[shape_name]
    rules = rules_for(arch_id, mesh, knobs)
    key = jax.random.key(0)

    pshape = jax.eval_shape(lambda k: init_params(k, cfg), key)
    pshard = param_shardings(pshape, rules, mesh)
    bspec = NamedSharding(mesh, batch_pspec(rules))

    def tok_struct(batch, seq):
        return jax.ShapeDtypeStruct((batch, seq), jnp.int32, sharding=bspec)

    vision_struct = None
    if cfg.d_vision:
        vision_struct = jax.ShapeDtypeStruct(
            (cell.global_batch, cfg.n_patches, cfg.d_vision),
            cfg.param_dtype,
            sharding=NamedSharding(mesh, P(rules.dp_axes, None, None)),
        )

    if cell.kind == "train":
        oc = OptimizerConfig(moments_dtype=knobs.get("_moments_dtype", "float32"))
        oshape = jax.eval_shape(lambda p: init_opt_state(p, oc), pshape)
        # moments follow the param shardings; step scalar replicated
        oshard = {
            "m": jax.tree.map(lambda sh: sh, pshard),
            "v": jax.tree.map(lambda sh: sh, pshard),
            "step": NamedSharding(mesh, P()),
        }
        state = {
            "params": _attach(pshape, pshard),
            "opt": _attach(oshape, oshard),
        }
        batch = {"tokens": tok_struct(cell.global_batch, cell.seq_len)}
        if vision_struct is not None:
            batch["vision"] = vision_struct
        remat = knobs.get("_remat", "full")
        micro = knobs.get("_microbatch", 1)

        if knobs.get("_compress_rank"):
            from repro.train import CompressionConfig, make_compressed_train_step
            from repro.train.grad_compress import init_error_state

            ccfg = CompressionConfig(
                rank=int(knobs["_compress_rank"]),
                sketch_factor=int(knobs.get("_compress_factor", 4)),
                min_dim=int(knobs.get("_compress_min_dim", 1024)),
            )
            cstep, _ = make_compressed_train_step(cfg, oc, ccfg, mesh, rules, remat=remat)
            nw = int(np.prod([mesh.shape[a] for a in rules.dp_axes]))
            eshape = jax.eval_shape(lambda p: init_error_state(p, ccfg, nw), pshape)
            eshard = jax.tree.map(
                lambda s: NamedSharding(mesh, P(rules.dp_axes, *([None] * (s.ndim - 1)))),
                eshape,
            )
            state["err"] = _attach(eshape, eshard)
            key_in = jax.random.key(7)

            def step(state, batch):
                return cstep(state, batch, key_in)

            return step, (state, batch), ()

        step = make_train_step(cfg, oc, remat=remat, microbatch=micro)
        return step, (state, batch), (0,)

    if cell.kind == "prefill":
        params = _attach(pshape, pshard)
        tokens = tok_struct(cell.global_batch, cell.seq_len)

        def step(params, tokens, vision=None):
            return prefill(params, cfg, tokens, cell.seq_len, vision=vision)

        if vision_struct is not None:
            return step, (params, tokens, vision_struct), ()
        return step, (params, tokens), ()

    # decode: serve_step = one token against a seq_len cache
    params = _attach(pshape, pshard)
    cshape = jax.eval_shape(
        lambda: init_cache(cfg, cell.global_batch, cell.seq_len)
    )
    seq_shard = shape_name == "long_500k"
    cshard = cache_shardings(cshape, rules, mesh, seq_shard=seq_shard)
    cache = _attach(cshape, cshard)
    token = jax.ShapeDtypeStruct(
        (cell.global_batch, 1), jnp.int32,
        sharding=NamedSharding(mesh, batch_pspec(rules) if not seq_shard else P(None, None)),
    )

    def step(params, cache, token):
        return decode_step(params, cfg, cache, token)

    return step, (params, cache, token), (1,)


def active_param_count(cfg: ModelConfig, pshape) -> int:
    """Parameters touched per token: total minus the inactive expert share."""
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(pshape))
    if not cfg.n_experts:
        return total
    expert = 3 * cfg.d_model * cfg.d_ff_expert  # gate+up+down per expert
    n_moe_layers = sum(1 for b in cfg.pattern if b.ffn == "moe")
    inactive = n_moe_layers * (cfg.n_experts - cfg.moe_top_k) * expert
    return total - inactive


# ---------------------------------------------------------------------------
# Collective census (naive single-count version; the loop-aware census in
# hlo_census.py supersedes this — kept for cross-checking in tests)
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "u32": 4, "s32": 4, "u64": 8,
                "s64": 8, "u16": 2, "s16": 2, "u8": 1, "s8": 1, "pred": 1, "c64": 8, "f8": 1}
_COLL_RE = re.compile(
    r"= \(?([a-z0-9]+)\[([0-9,]*)\][^)]*?\)? (all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _wire_factor(op: str, g: int) -> float:
    """Ring-algorithm wire bytes per device, as a multiple of the RESULT bytes."""
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op == "all-gather":
        return (g - 1) / g  # result is the gathered (full) tensor
    if op == "reduce-scatter":
        return float(g - 1)  # result is the scattered piece; input = g × result
    if op == "all-to-all":
        return (g - 1) / g
    if op == "collective-permute":
        return 1.0
    return 1.0


def collective_census(hlo_text: str) -> dict:
    stats = defaultdict(lambda: {"count": 0, "result_bytes": 0, "wire_bytes": 0.0})
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, op = m.groups()
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        numel = 1
        if dims:
            for d in dims.split(","):
                if d:
                    numel *= int(d)
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gm2 = _GROUPS_EXPL_RE.search(line)
            if gm2:
                g = len(gm2.group(1).split(","))
        result_bytes = numel * nbytes
        stats[op]["count"] += 1
        stats[op]["result_bytes"] += result_bytes
        stats[op]["wire_bytes"] += result_bytes * _wire_factor(op, max(g, 1))
    return {k: dict(v) for k, v in stats.items()}


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool, out_dir: str,
             overrides: dict | None = None, tag: str = "", verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    knobs = {k: v for k, v in (overrides or {}).items() if k.startswith("_")}
    step, args, donate = input_specs(arch_id, shape_name, mesh, overrides=overrides)

    rules = rules_for(arch_id, mesh, knobs)
    with mesh, activation_sharding(mesh, rules):
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    cen = hlo_census(txt)  # loop-aware: flops / hbm bytes / collectives

    mod = get_arch(arch_id)
    cfg = mod.full_config()
    pshape = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(pshape))
    n_active = active_param_count(cfg, pshape)

    record = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": mesh_name,
        "tag": tag,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "n_params": n_params,
        "n_active_params": n_active,
        # loop-aware census (per device)
        "flops_per_device": cen["flops"],
        "hbm_bytes_per_device": cen["hbm_bytes"],
        "collectives": cen["collectives"],
        "while_trip_counts": cen["while_trip_counts"][:40],
        # raw cost_analysis (counts while bodies ONCE — recorded for reference)
        "xla_cost_analysis": {
            "flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
        },
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        },
    }
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fname = f"{arch_id.replace('/', '_')}__{shape_name}__{mesh_name}{suffix}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(record, f, indent=1)
    if verbose:
        mem_gb = record["memory"]["peak_estimate_bytes"] / 1e9
        wire = sum(v["wire_bytes"] for v in cen["collectives"].values())
        print(
            f"[dryrun] {arch_id:22s} {shape_name:12s} {mesh_name:8s} "
            f"compile={t_compile:6.1f}s flops/dev={record['flops_per_device']:.3e} "
            f"mem/dev={mem_gb:7.2f}GB wire/dev={wire/1e9:8.3f}GB "
            f"colls={{{', '.join(f'{k}:{int(v['count'])}' for k, v in cen['collectives'].items())}}}"
        )
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch_id, mod in ARCHS.items():
            for shape in mod.SUPPORTED_SHAPES:
                cells.append((arch_id, shape))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells.append((args.arch, args.shape))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch_id, shape in cells:
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            fname = os.path.join(args.out, f"{arch_id}__{shape}__{mesh_name}.json")
            if args.skip_existing and os.path.exists(fname):
                print(f"[dryrun] skip existing {arch_id} {shape} {mesh_name}")
                continue
            try:
                run_cell(arch_id, shape, multi_pod=mp, out_dir=args.out)
            except Exception as e:  # noqa: BLE001 — report all cell failures at the end
                failures.append((arch_id, shape, mesh_name, repr(e)))
                print(f"[dryrun] FAIL {arch_id} {shape} {mesh_name}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL CELLS OK")


if __name__ == "__main__":
    main()
