"""Loop-aware census of a compiled HLO module.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified: an
8-iteration scan reports 1/8 of the unrolled flops), and collectives inside
a layer scan appear once in the HLO text. Since every transformer here is a
scan-over-layers, naive counting under-reports by ~n_layers×.

This walker parses ``compiled.as_text()`` into computations, builds the
call graph (while bodies, fusions, calls), extracts each while loop's trip
count from its condition's integer bound, and weights every instruction by
the product of enclosing trip counts. It reports:

  * flops        — 2·M·N·K per ``dot`` (batch/contract dims parsed per-op)
  * hbm_bytes    — Σ (result + operand bytes) of top-level instructions
                   (fusion internals excluded: values inside a fusion never
                   round-trip through HBM)
  * n_ops        — weighted count of *real* top-level instructions (pure
                   bookkeeping — parameter/constant/tuple/gte/bitcast/
                   reshape — excluded): a dispatch-overhead proxy that a
                   fused scan body should shrink alongside its traffic
  * collectives  — per-op count / result bytes / ring-algorithm wire bytes

All numbers are per-device (the SPMD module is the per-device program).

:func:`stream_scan_hlo` / :func:`census_stream_program` extend the census
to arbitrary compiled *streaming* programs: they lower the engine's
``scan_chunk`` / ``scan_panels`` for a given state and operand, so the
fused-vs-unfused scan bodies become comparable committed numbers
(HBM bytes per panel; gated by ``tools/census_check.py``).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "u64": 8, "s64": 8, "u32": 4, "s32": 4, "u16": 2, "s16": 2,
    "u8": 1, "s8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\((.*)\)\s*->.*\{\s*$")
_TRIP_COUNT = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_SHAPE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|u64|s64|u32|s32|u16|s16|u8|s8|pred|c64|c128)\[([0-9,]*)\]")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPKIND = re.compile(r"([a-z][a-z0-9\-]*)\(")
_OPERANDS = re.compile(r"%([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_DOT_DIMS = re.compile(
    r"lhs_batch_dims=\{([0-9,]*)\}.*?lhs_contracting_dims=\{([0-9,]*)\}"
    r".*?rhs_batch_dims=\{([0-9,]*)\}.*?rhs_contracting_dims=\{([0-9,]*)\}"
)
_DOT_DIMS_NOBATCH = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}.*?rhs_contracting_dims=\{([0-9,]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes appearing in a type string."""
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_bytes(type_str: str) -> int:
    m = _SHAPE.search(type_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _shape_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instruction:
    name: str
    body: str  # everything after '='
    kind: str
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]  # param name -> type string
    instructions: List[Instruction]
    is_fusion: bool


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m:
                name, params_str = m.groups()
                params = {}
                for p in params_str.split(","):
                    p = p.strip()
                    if not p:
                        continue
                    if ":" in p:
                        pname, ptype = p.split(":", 1)
                        params[pname.strip().lstrip("%")] = ptype.strip()
                cur = Computation(
                    name=name,
                    params=params,
                    instructions=[],
                    is_fusion="fused_computation" in name or name.startswith("region"),
                )
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            iname, body = m.groups()
            km = _OPKIND.search(body)
            kind = km.group(1) if km else "unknown"
            cur.instructions.append(
                Instruction(
                    name=iname, body=body, kind=kind,
                    is_root=line.lstrip().startswith("ROOT"),
                )
            )
    return comps


def _trip_count(while_body: str, cond: Optional[Computation]) -> int:
    """XLA records the analyzed bound in backend_config; fall back to the
    largest integer constant in the condition computation."""
    m = _TRIP_COUNT.search(while_body)
    if m:
        return int(m.group(1))
    best = 1
    if cond is not None:
        for ins in cond.instructions:
            for c in _CONST_INT.findall(ins.body):
                best = max(best, int(c))
    return best


def _wire_factor(op: str, g: int) -> float:
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op == "all-gather":
        return (g - 1) / g
    if op == "reduce-scatter":
        return float(g - 1)
    if op == "all-to-all":
        return (g - 1) / g
    if op == "collective-permute":
        return 1.0
    return 1.0


def _dot_flops(ins: Instruction, type_of: Dict[str, str]) -> float:
    ops = _OPERANDS.findall(ins.body.split("(", 1)[1])
    if len(ops) < 2:
        return 0.0
    lhs_t, rhs_t = type_of.get(ops[0]), type_of.get(ops[1])
    if lhs_t is None or rhs_t is None:
        return 0.0
    lhs, rhs = _shape_dims(lhs_t), _shape_dims(rhs_t)
    if lhs is None or rhs is None:
        return 0.0
    m = _DOT_DIMS.search(ins.body)
    if m:
        lb = [int(x) for x in m.group(1).split(",") if x]
        lc = [int(x) for x in m.group(2).split(",") if x]
        rb = [int(x) for x in m.group(3).split(",") if x]
        rc = [int(x) for x in m.group(4).split(",") if x]
    else:
        m2 = _DOT_DIMS_NOBATCH.search(ins.body)
        lb, rb = [], []
        if m2:
            lc = [int(x) for x in m2.group(1).split(",") if x]
            rc = [int(x) for x in m2.group(2).split(",") if x]
        else:
            lc, rc = [len(lhs) - 1], [0]
    batch = 1
    for d in lb:
        batch *= lhs[d]
    contract = 1
    for d in lc:
        contract *= lhs[d]
    lhs_free = 1
    for i, d in enumerate(lhs):
        if i not in lb and i not in lc:
            lhs_free *= d
    rhs_free = 1
    for i, d in enumerate(rhs):
        if i not in rb and i not in rc:
            rhs_free *= d
    return 2.0 * batch * contract * lhs_free * rhs_free


def _op_shape_bytes(name: str, type_of: Dict[str, str]) -> int:
    t = type_of.get(name)
    if not t:
        return 0
    return _first_shape_bytes(t[: t.find("(")] if "(" in t else t)


def _fusion_traffic(ins: Instruction, type_of: Dict[str, str], comps: Dict[str, "Computation"]) -> Optional[float]:
    """Honest HBM traffic of a fusion: per-parameter read sizes (a parameter
    consumed only through dynamic-slice reads only the slice; the aliased
    buffer of a root dynamic-update-slice reads nothing) + write sizes (a
    root DUS writes only the update region)."""
    callees = _CALLS.findall(ins.body)
    if not callees or callees[0] not in comps:
        return None
    fused = comps[callees[0]]
    param_names = list(fused.params)
    argpart = ins.body[ins.body.find("(") :] if "(" in ins.body else ""
    operand_names = _OPERANDS.findall(argpart)[: len(param_names)]

    by_name: Dict[str, str] = dict(fused.params)
    root = None
    for fin in fused.instructions:
        by_name[fin.name] = fin.body
        if fin.is_root:
            root = fin
    if root is None and fused.instructions:
        root = fused.instructions[-1]
    if root is None:
        return None

    def result_bytes_of(name: str) -> float:
        b = by_name.get(name, "")
        return float(_first_shape_bytes(b[: b.find("(")] if "(" in b else b))

    def op_list(body: str):
        return _OPERANDS.findall(body[body.find("(") :]) if "(" in body else []

    # classify every fusion parameter by how it is consumed — per use, so a
    # carry buffer that is dynamic-sliced AND the aliased root-DUS target
    # (XLA CPU's serial scatter lowering: read row, add, write row back)
    # charges only the sliced rows, not the whole accumulator per trip
    reads = 0.0
    for pname, oname in zip(param_names, operand_names):
        uses = []
        for fin in fused.instructions:
            if pname in op_list(fin.body):
                uses.append(fin)
        full = _op_shape_bytes(oname, type_of) or result_bytes_of(pname)
        if not uses:
            continue
        sliced = 0.0
        fallback = False
        for u in uses:
            if u.kind == "dynamic-slice":
                sliced += result_bytes_of(u.name)
            elif u.kind == "gather" and op_list(u.body)[0] == pname:
                # sparse read: a k-column/row gather touches ~result bytes
                sliced += result_bytes_of(u.name)
            elif u.kind == "dynamic-update-slice" and op_list(u.body)[0] == pname:
                pass  # aliased in-place carry buffer: reads nothing
            else:
                fallback = True
        reads += full if fallback else sliced

    # writes: root DUS (possibly behind bitcast / in a tuple) writes updates only
    def write_bytes(rname: str, depth=0) -> float:
        body = by_name.get(rname, "")
        kind_m = _OPKIND.search(body)
        kind = kind_m.group(1) if kind_m else ""
        if kind == "dynamic-update-slice":
            ops = op_list(body)
            return result_bytes_of(ops[1]) if len(ops) > 1 else 0.0
        if kind in ("bitcast", "copy") and depth < 3:
            ops = op_list(body)
            if ops:
                return write_bytes(ops[0], depth + 1)
        if kind == "tuple":
            return sum(write_bytes(o, depth + 1) for o in op_list(body))
        head = body[: body.find("(")] if "(" in body else body
        return float(_shape_bytes(head))

    writes = write_bytes(root.name)
    return reads + writes


def _instr_traffic(ins: Instruction, type_of: Dict[str, str], comps: Optional[Dict[str, "Computation"]] = None) -> float:
    """HBM bytes touched by one top-level instruction.

    In-place semantics honoured: dynamic-update-slice / scatter (bare or as
    fusion roots) rewrite only the updated region (XLA aliases the carried
    buffer), so they charge 2×update bytes, not operand+result.
    """
    kind = ins.kind
    head = ins.body[: ins.body.find("(")] if "(" in ins.body else ins.body
    rb = _shape_bytes(head)
    argpart = ins.body[ins.body.find("(") :] if "(" in ins.body else ""
    ops = _OPERANDS.findall(argpart)

    if kind in ("reshape", "bitcast", "get-tuple-element", "tuple", "parameter", "constant"):
        return 0.0
    if kind == "dynamic-update-slice":
        upd = _op_shape_bytes(ops[1], type_of) if len(ops) > 1 else 0
        return 2.0 * upd
    if kind == "scatter":
        upd = _op_shape_bytes(ops[2], type_of) if len(ops) > 2 else 0
        idx = _op_shape_bytes(ops[1], type_of) if len(ops) > 1 else 0
        return 2.0 * upd + idx
    if kind in ("dynamic-slice", "slice", "copy", "transpose", "concatenate", "gather"):
        return 2.0 * rb
    if kind == "fusion" and comps is not None:
        t = _fusion_traffic(ins, type_of, comps)
        if t is not None:
            return t
    if kind in ("fusion", "dot", "convert", "broadcast", "reduce", "pad",
                "select-and-scatter", "sort", "custom-call", "iota", "rng",
                "cholesky", "triangular-solve") or kind in COLLECTIVE_OPS:
        ob = sum(_op_shape_bytes(o, type_of) for o in ops[:8])
        return rb + ob
    return 0.0


def census(hlo: str, entry: Optional[str] = None) -> dict:
    """Loop-aware census of ``hlo``. ``entry`` overrides the root computation
    (default: the module's ENTRY) — pass a while-loop *body* to census one
    iteration of that loop (e.g. one panel of a streaming scan)."""
    comps = parse_computations(hlo)
    entry_name = entry
    if entry_name is None:
        for raw in hlo.splitlines():
            s = raw.strip()
            if s.startswith("ENTRY"):
                m = _COMP_HEADER.match(s)
                if m:
                    entry_name = m.group(1)
                    break
    if entry_name is None or entry_name not in comps:
        # fall back: the computation with the most instructions
        entry_name = max(comps, key=lambda c: len(comps[c].instructions))

    # weights: BFS through the call graph multiplying while trip counts
    weights: Dict[str, float] = defaultdict(float)
    weights[entry_name] = 1.0
    order = [entry_name]
    seen = {entry_name}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps[cname]
        w = weights[cname]
        for ins in comp.instructions:
            callees = _CALLS.findall(ins.body)
            cond = _COND.findall(ins.body)
            mult = 1.0
            if ins.kind == "while":
                mult = float(
                    _trip_count(ins.body, comps.get(cond[0]) if cond else None)
                )
            for callee in callees + cond:
                if callee in comps:
                    weights[callee] += w * (mult if callee not in cond else 1.0)
                    if callee not in seen:
                        seen.add(callee)
                        order.append(callee)

    flops = 0.0
    hbm_bytes = 0.0
    n_ops = 0.0
    colls = defaultdict(lambda: {"count": 0.0, "result_bytes": 0.0, "wire_bytes": 0.0})
    trip_info = []
    _BOOKKEEPING = ("parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "reshape")

    for cname, comp in comps.items():
        w = weights.get(cname, 0.0)
        if w <= 0:
            continue
        type_of: Dict[str, str] = dict(comp.params)
        for ins in comp.instructions:
            type_of[ins.name] = ins.body
        for ins in comp.instructions:
            if ins.kind == "dot":
                flops += w * _dot_flops(ins, type_of)
            if ins.kind in COLLECTIVE_OPS:
                rb = _shape_bytes(ins.body.split(" ", 1)[0] if False else ins.body[: ins.body.find("(")])
                g = 1
                gm = _GROUPS_RE.search(ins.body)
                if gm:
                    g = int(gm.group(2))
                else:
                    gm2 = _GROUPS_EXPL_RE.search(ins.body)
                    if gm2:
                        g = len(gm2.group(1).split(","))
                colls[ins.kind]["count"] += w
                colls[ins.kind]["result_bytes"] += w * rb
                colls[ins.kind]["wire_bytes"] += w * rb * _wire_factor(ins.kind, max(g, 1))
            if not comp.is_fusion:
                hbm_bytes += w * _instr_traffic(ins, type_of, comps)
                if ins.kind not in _BOOKKEEPING:
                    n_ops += w

    # record while trip counts for transparency
    for cname, comp in comps.items():
        for ins in comp.instructions:
            if ins.kind == "while":
                cond = _COND.findall(ins.body)
                trip_info.append({
                    "while_in": cname,
                    "trip": _trip_count(ins.body, comps.get(cond[0]) if cond else None),
                })

    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "n_ops": n_ops,
        "collectives": {k: dict(v) for k, v in colls.items()},
        "while_trip_counts": trip_info,
        "n_computations": len(comps),
    }


# ---------------------------------------------------------------------------
# streaming-program census (scan_chunk / scan_panels)
# ---------------------------------------------------------------------------


def stream_scan_hlo(state, A, panel: int, *, fused: bool = True, route: str = "chunk") -> str:
    """Compiled HLO text of one streaming scan program over ``A``.

    Lowers the engine's jitted scan for the given state — ``route="chunk"``
    compiles :func:`repro.stream.engine.scan_chunk` on a chunk-shaped
    operand (``A``'s width must be whole panels), ``route="panels"``
    compiles :func:`repro.stream.engine.scan_panels` on the full stream
    operand. ``fused`` selects the fused scan body vs the legacy per-panel
    body — the pair the census compares. Lazy imports keep this module
    importable without the streaming stack.
    """
    import jax  # deferred: the census parser itself is dependency-free

    from ..stream import engine

    if route == "panels":
        num_panels = A.shape[1] // panel
        lowered = jax.jit(
            engine.scan_panels, static_argnames=("num_panels", "panel", "fused")
        ).lower(state, A, num_panels=num_panels, panel=panel, fused=fused)
    elif route == "chunk":
        if A.shape[1] % panel:
            raise ValueError(
                f"chunk width {A.shape[1]} must be whole panels of {panel}"
            )
        lowered = jax.jit(engine.scan_chunk, static_argnames=("panel", "fused")).lower(
            state, A, panel=panel, fused=fused
        )
    else:
        raise ValueError(f"route must be 'chunk' or 'panels', got {route!r}")
    return lowered.compile().as_text()


def scan_body_computation(hlo: str, num_panels: int) -> Optional[str]:
    """Name of the scan's while-*body* computation: the while loop whose
    analyzed trip count equals ``num_panels`` (ties broken by body size —
    nested helper loops of the same trip count are smaller)."""
    comps = parse_computations(hlo)
    best = None
    for comp in comps.values():
        for ins in comp.instructions:
            if ins.kind != "while":
                continue
            cond = _COND.findall(ins.body)
            trip = _trip_count(ins.body, comps.get(cond[0]) if cond else None)
            if trip != num_panels:
                continue
            bodies = _CALLS.findall(ins.body)
            if bodies and bodies[0] in comps:
                cand = bodies[0]
                if best is None or len(comps[cand].instructions) > len(comps[best].instructions):
                    best = cand
    return best


def census_stream_program(
    state, A, panel: int, *, fused: bool = True, route: str = "chunk"
) -> dict:
    """Loop-aware census of one compiled streaming scan, per-panel normalized.

    Returns the :func:`census` dict plus:

      * ``num_panels``
      * ``bytes_per_panel``  — whole-program hbm_bytes / num_panels (the
        amortized cost including any chunk-hoisted prologue work)
      * ``scan_body_bytes_per_panel`` / ``scan_body_n_ops`` — the census of
        ONE iteration of the scan's while body: the steady-state marginal
        traffic per panel. This is where the fused body's win shows up —
        the hoisted chunk sketch leaves the loop entirely — and the number
        the ≥25 % fused-vs-unfused regression gate is on.

    Committed in ``benchmarks/baselines/census_budget.json`` and gated by
    ``make census-check``.
    """
    num_panels = A.shape[1] // panel
    hlo = stream_scan_hlo(state, A, panel, fused=fused, route=route)
    c = census(hlo)
    c["num_panels"] = num_panels
    c["bytes_per_panel"] = c["hbm_bytes"] / max(num_panels, 1)
    body = scan_body_computation(hlo, num_panels)
    if body is not None:
        bc = census(hlo, entry=body)
        c["scan_body_bytes_per_panel"] = bc["hbm_bytes"]
        c["scan_body_n_ops"] = bc["n_ops"]
    else:  # degenerate single-panel program: the whole module is the body
        c["scan_body_bytes_per_panel"] = c["bytes_per_panel"]
        c["scan_body_n_ops"] = c["n_ops"]
    c["fused"] = fused
    return c
