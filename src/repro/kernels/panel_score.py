"""Pallas TPU kernel: fused streaming panel scoring for adaptive CUR.

Per panel, the adaptive admission policy (``repro.stream.adaptive``) needs
three quantities from the same data:

* ``sc_a = S_C · A_L``                       — the panel sketch (also feeds
  the engine's shared ``M`` update);
* ``energy_j = ‖sc_a[:, j]‖²``               — per-column sketch energies
  (the admission threshold's denominator);
* ``resid2_j = energy_j − ‖Qᵀ sc_a[:, j]‖²`` — residual energy outside the
  admitted basis, with ``Q`` an (s_c × c) whitened (or orthonormal) basis
  of the admitted columns' sketches; unfilled slots' all-zero columns are
  inert (see ``repro.stream.adaptive._whitened_basis``).

Evaluated as three separate XLA ops this is three HBM round-trips per
panel: write ``sc_a``, read it back for the energies, read it again for the
projection. The fused kernel keeps the ``(s_c × bl)`` panel-sketch tile in
VMEM scratch across the whole m-reduction (the accumulator pattern of
``twoside_sketch.py``) and computes both scores from the still-resident
tile on the last reduction step — each ``A_L`` tile is read exactly once
and ``sc_a`` never makes an HBM round-trip:

    HBM traffic:  m·L + s_c·m·(L/bl) + s_c·c + s_c·L + 8·L
    vs unfused:   m·L + s_c·m·(L/bl) + s_c·c + 3·s_c·L + … (sc_a written
                  once and re-read twice)

Grid (j, l) = (L blocks, m blocks), reduction over l; scores land in rows
0 (resid2) / 1 (energy) of an (8, L) stats output (sublane-padded for the
f32 (8, 128) tile floor). All dims are pre-padded to block multiples by
``ops.panel_score`` — zero rows/columns contribute nothing to any of the
three outputs. fp32 accumulation regardless of input dtype.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(sc_ref, a_ref, q_ref, sca_ref, stats_ref, acc_ref):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (s_c, bm) @ (bm, bl) → (s_c, bl), fp32 accumulate on the MXU
    acc_ref[...] += jnp.dot(sc_ref[...], a_ref[...], preferred_element_type=jnp.float32)

    @pl.when(l == pl.num_programs(1) - 1)
    def _():
        y = acc_ref[...]  # (s_c, bl) — the finished panel-sketch tile
        sca_ref[...] = y.astype(sca_ref.dtype)
        # t = Qᵀ y without materializing the transpose: contract dim 0 ⊗ dim 0
        t = jax.lax.dot_general(
            q_ref[...], y, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (c, bl)
        energy = jnp.sum(y * y, axis=0, keepdims=True)  # (1, bl)
        resid2 = jnp.maximum(energy - jnp.sum(t * t, axis=0, keepdims=True), 0.0)
        pad = jnp.zeros((stats_ref.shape[0] - 2, y.shape[1]), jnp.float32)
        stats_ref[...] = jnp.concatenate([resid2, energy, pad], axis=0)


def panel_score_kernel(
    sc: jax.Array,  # (s_c, m) dense column sketch
    a_l: jax.Array,  # (m, L) panel
    q: jax.Array,  # (s_c, c) zero-masked orthonormal basis of admitted sketches
    *,
    block_m: int = 256,
    block_l: int = 128,
    interpret: bool = False,
) -> tuple:
    """All dims must already be padded to their block multiples (see ops.py).

    Returns ``(sc_a (s_c, L) f32, stats (8, L) f32)`` with ``stats[0] =
    resid2`` and ``stats[1] = energy``.
    """
    s_c, m = sc.shape
    _, L = a_l.shape
    c = q.shape[1]
    assert a_l.shape[0] == m and q.shape[0] == s_c
    assert s_c % 8 == 0 and c % 128 == 0
    assert m % block_m == 0 and L % block_l == 0

    grid = (L // block_l, m // block_m)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s_c, block_m), lambda j, l: (0, l)),
            pl.BlockSpec((block_m, block_l), lambda j, l: (l, j)),
            pl.BlockSpec((s_c, c), lambda j, l: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((s_c, block_l), lambda j, l: (0, j)),
            pl.BlockSpec((8, block_l), lambda j, l: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s_c, L), jnp.float32),
            jax.ShapeDtypeStruct((8, L), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((s_c, block_l), jnp.float32)],
        interpret=interpret,
    )(sc, a_l, q)
