"""jit'd public wrappers: shape padding, dtype policy, interpret fallback.

On this CPU container ``interpret=True`` executes the kernel bodies in
Python for correctness; on TPU the same code lowers to Mosaic. The
wrappers pad every dim to its block multiple with zeros (mathematically a
no-op for both kernels: zero rows/cols contribute nothing) and slice the
result back.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .countsketch import countsketch_kernel
from .panel_score import panel_score_kernel
from .ref import countsketch_ref, panel_score_ref, twoside_sketch_ref
from .twoside_sketch import twoside_sketch_kernel


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jax.Array, mults) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p for _, p in pads):
        return jnp.pad(x, pads)
    return x


@partial(jax.jit, static_argnames=("block_sc", "block_sr", "block_m", "block_n", "interpret"))
def twoside_sketch(
    sc: jax.Array,
    a: jax.Array,
    srt: jax.Array,
    *,
    block_sc: int = 128,
    block_sr: int = 128,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """M = S_C · A · S_Rᵀ (fused, fp32 out). Shapes: (s_c,m)·(m,n)·(n,s_r)."""
    interpret = _on_cpu() if interpret is None else interpret
    s_c, m = sc.shape
    n, s_r = srt.shape
    scp = _pad_to(sc, (block_sc, block_m))
    ap = _pad_to(a, (block_m, block_n))
    srtp = _pad_to(srt, (block_n, block_sr))
    out = twoside_sketch_kernel(
        scp, ap, srtp,
        block_sc=block_sc, block_sr=block_sr, block_m=block_m, block_n=block_n,
        interpret=interpret,
    )
    return out[:s_c, :s_r]


@partial(jax.jit, static_argnames=("s", "block_m", "block_n", "interpret"))
def countsketch_apply(
    hashes: jax.Array,
    signs: jax.Array,
    a: jax.Array,
    s: int,
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """S·A for a CountSketch given (hash, sign) vectors. Returns (s, n) fp32."""
    interpret = _on_cpu() if interpret is None else interpret
    m, n = a.shape
    s_pad = s + ((-s) % 128)
    ap = _pad_to(a, (block_m, block_n))
    # padded rows must not pollute bucket 0: send them to the padding bucket
    hp = _pad_to(hashes, (block_m,))
    if hp.shape[0] != m:
        filler = jnp.full((hp.shape[0] - m,), s_pad - 1 if s_pad > s else s - 1, hp.dtype)
        hp = hp.at[m:].set(filler)
    sgp = _pad_to(signs, (block_m,))  # zero signs ⇒ padded rows contribute 0
    out = countsketch_kernel(
        hp, sgp, ap, s_pad, block_m=block_m, block_n=block_n, interpret=interpret
    )
    return out[:s, : n]


@partial(jax.jit, static_argnames=("block_m", "block_l", "interpret"))
def panel_score(
    sc: jax.Array,
    a_l: jax.Array,
    q: jax.Array,
    *,
    block_m: int = 256,
    block_l: int = 128,
    interpret: bool | None = None,
) -> tuple:
    """Fused panel scoring: ``(S_C·A_L, resid2, energy)`` in one VMEM pass.

    Shapes: ``sc (s_c, m)``, ``a_l (m, L)``, ``q (s_c, c)`` where ``q`` is
    a (whitened or orthonormal) basis of the admitted columns' sketches —
    ``resid2 = energy − ‖qᵀ·‖²`` scores against ``span(q)``; all-zero
    columns of ``q`` are inert (see ``repro.stream.adaptive``). Returns
    ``(sc_a (s_c, L), resid2 (L,), energy (L,))`` fp32. Zero-padding every
    dim to its block multiple is mathematically a no-op for all three
    outputs.
    """
    interpret = _on_cpu() if interpret is None else interpret
    s_c, m = sc.shape
    L = a_l.shape[1]
    c = q.shape[1]
    scp = _pad_to(sc, (8, block_m))
    ap = _pad_to(a_l, (block_m, block_l))
    qp = _pad_to(q, (8, 128))
    sc_a, stats = panel_score_kernel(
        scp, ap, qp, block_m=block_m, block_l=block_l, interpret=interpret
    )
    return sc_a[:s_c, :L], stats[0, :L], stats[1, :L]


__all__ = [
    "twoside_sketch",
    "countsketch_apply",
    "panel_score",
    "twoside_sketch_ref",
    "countsketch_ref",
    "panel_score_ref",
]
