"""jit'd public wrappers: shape padding, dtype policy, interpret fallback.

On non-TPU backends ``interpret=True`` executes the kernel bodies in
Python for correctness; on TPU the same code lowers to Mosaic. The
wrappers pad every dim to its block multiple with zeros (mathematically a
no-op for every kernel: zero rows/cols contribute nothing) and slice the
result back.

All four kernels share one block scheme (:data:`LANE`/:data:`SUBLANE`
tile floor, :func:`pad_dims` zero-padding, :func:`interpret_default`
backend dispatch), so a re-tiling decision is made once here rather than
per kernel.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .countsketch import countsketch_kernel
from .panel_score import panel_score_kernel
from .panel_update import panel_update_kernel
from .ref import countsketch_ref, panel_score_ref, panel_update_ref, twoside_sketch_ref
from .twoside_sketch import twoside_sketch_kernel

# The fp32 TPU register tile is (8, 128): every kernel operand's trailing
# two dims are padded to multiples of these (block sizes are themselves
# multiples, so padding to the block is padding to the tile).
SUBLANE = 8
LANE = 128

# Test hook (see kernel_route_enabled): force the Mosaic-route *dispatch
# decision* on a non-TPU backend so the engine's panel_kernel path can be
# exercised end-to-end in interpret mode. Never set in production code.
_FORCE_KERNEL_ROUTE = False


def interpret_default() -> bool:
    """Interpret unless the backend is actually TPU.

    Mosaic lowering exists only for TPU — ``interpret = not on_cpu`` would
    send a GPU (or any other) backend down a lowering path that fails, so
    the dispatch question is "is this a TPU?", not "is this a CPU?".
    """
    return jax.default_backend() != "tpu"


def kernel_route_enabled() -> bool:
    """Should engine hooks route panels through the Pallas kernels?

    True on TPU (Mosaic execution) and when tests force the route
    (interpret-mode execution of the same kernel bodies). Distinct from
    :func:`interpret_default`: this gates whether a *caller* picks the
    kernel at all, that gates how a picked kernel executes.
    """
    return _FORCE_KERNEL_ROUTE or jax.default_backend() == "tpu"


def _on_cpu() -> bool:  # retained for external callers of the old helper
    return jax.default_backend() == "cpu"


def _pad_to(x: jax.Array, mults) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p for _, p in pads):
        return jnp.pad(x, pads)
    return x


def pad_dims(*pairs):
    """Shared padding step: ``pad_dims((x, mults), ...)`` zero-pads every
    array's dims to their block multiples (no-op when already aligned)."""
    return tuple(_pad_to(x, mults) for x, mults in pairs)


@partial(jax.jit, static_argnames=("block_sc", "block_sr", "block_m", "block_n", "interpret"))
def twoside_sketch(
    sc: jax.Array,
    a: jax.Array,
    srt: jax.Array,
    *,
    block_sc: int = 128,
    block_sr: int = 128,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """M = S_C · A · S_Rᵀ (fused, fp32 out). Shapes: (s_c,m)·(m,n)·(n,s_r)."""
    interpret = interpret_default() if interpret is None else interpret
    s_c, m = sc.shape
    n, s_r = srt.shape
    scp, ap, srtp = pad_dims(
        (sc, (block_sc, block_m)), (a, (block_m, block_n)), (srt, (block_n, block_sr))
    )
    out = twoside_sketch_kernel(
        scp, ap, srtp,
        block_sc=block_sc, block_sr=block_sr, block_m=block_m, block_n=block_n,
        interpret=interpret,
    )
    return out[:s_c, :s_r]


@partial(jax.jit, static_argnames=("s", "block_m", "block_n", "interpret"))
def countsketch_apply(
    hashes: jax.Array,
    signs: jax.Array,
    a: jax.Array,
    s: int,
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """S·A for a CountSketch given (hash, sign) vectors. Returns (s, n) fp32."""
    interpret = interpret_default() if interpret is None else interpret
    m, n = a.shape
    s_pad = s + ((-s) % LANE)
    (ap,) = pad_dims((a, (block_m, block_n)))
    # padded rows must not pollute bucket 0: send them to the padding bucket
    (hp,) = pad_dims((hashes, (block_m,)))
    if hp.shape[0] != m:
        filler = jnp.full((hp.shape[0] - m,), s_pad - 1 if s_pad > s else s - 1, hp.dtype)
        hp = hp.at[m:].set(filler)
    (sgp,) = pad_dims((signs, (block_m,)))  # zero signs ⇒ padded rows contribute 0
    out = countsketch_kernel(
        hp, sgp, ap, s_pad, block_m=block_m, block_n=block_n, interpret=interpret
    )
    return out[:s, : n]


@partial(jax.jit, static_argnames=("block_m", "block_l", "interpret"))
def panel_score(
    sc: jax.Array,
    a_l: jax.Array,
    q: jax.Array,
    *,
    block_m: int = 256,
    block_l: int = 128,
    interpret: bool | None = None,
) -> tuple:
    """Fused panel scoring: ``(S_C·A_L, resid2, energy)`` in one VMEM pass.

    Shapes: ``sc (s_c, m)``, ``a_l (m, L)``, ``q (s_c, c)`` where ``q`` is
    a (whitened or orthonormal) basis of the admitted columns' sketches —
    ``resid2 = energy − ‖qᵀ·‖²`` scores against ``span(q)``; all-zero
    columns of ``q`` are inert (see ``repro.stream.adaptive``). Returns
    ``(sc_a (s_c, L), resid2 (L,), energy (L,))`` fp32. Zero-padding every
    dim to its block multiple is mathematically a no-op for all three
    outputs.
    """
    interpret = interpret_default() if interpret is None else interpret
    s_c, m = sc.shape
    L = a_l.shape[1]
    scp, ap, qp = pad_dims(
        (sc, (SUBLANE, block_m)), (a_l, (block_m, block_l)), (q, (SUBLANE, LANE))
    )
    sc_a, stats = panel_score_kernel(
        scp, ap, qp, block_m=block_m, block_l=block_l, interpret=interpret
    )
    return sc_a[:s_c, :L], stats[0, :L], stats[1, :L]


@partial(jax.jit, static_argnames=("panel_cap", "block_m", "interpret"))
def panel_update(
    sc: jax.Array,
    a_l: jax.Array,
    srt: jax.Array,
    q: jax.Array,
    C: jax.Array,
    M: jax.Array,
    *,
    min_gain: jax.Array,
    run_mean: jax.Array,
    true_cols: jax.Array,
    n_filled: jax.Array,
    free: jax.Array,
    panel_cap: int,
    block_m: int = 256,
    interpret: bool | None = None,
) -> tuple:
    """Fused per-panel megakernel: sketch + scores + admission + C/M writes.

    One VMEM pass per panel of the adaptive admission-only update
    (:mod:`repro.stream.adaptive`): computes ``sc_a = S_C·A_L`` and the
    per-column ``(resid2, energy)`` scores (the ``panel_score`` math),
    resolves the admission *inside the kernel* (eligibility threshold +
    rank-based slot assignment, provably the same selection as the XLA
    ``top_k``/cumsum path), folds ``M += sc_a · S_Rᵀ`` from the
    still-resident tile, and scatters the admitted panel columns into ``C``
    via a one-hot matmul — ``sc_a`` never makes an HBM round-trip and each
    ``A_L`` tile is read at most twice (once for the sketch reduction, once
    for the C write of its row block).

    Args:
        sc: ``(s_c, m)`` dense column sketch.
        a_l: ``(m, L)`` panel.
        srt: ``(L, s_r)`` dense transposed S_R window at this panel's offset.
        q: ``(s_c, c_local)`` whitened basis of the admitted sketches.
        C, M: accumulators; returned updated (buffers are aliased through
            the kernel, so on TPU the update is in place).
        min_gain, run_mean, true_cols: admission threshold scalars —
            ``thresh = min_gain · max(run_mean, Σenergy/true_cols)``.
        n_filled, free: next free slot and remaining budget of the calling
            worker's slot range.
        panel_cap: static max admissions per panel.

    Returns:
        ``(C', M', sc_a (s_c, L) f32, resid2 (L,) f32, energy (L,) f32,
        slots (L,) int32)`` — ``slots[j]`` is the C slot column ``j`` was
        admitted into, or the ``C.shape[1]`` sentinel (OOB for the
        caller's ``mode='drop'`` index scatters) when it was not.
    """
    interpret = interpret_default() if interpret is None else interpret
    s_c, m = sc.shape
    L = a_l.shape[1]
    c_total = C.shape[1]
    s_r = srt.shape[1]
    scp, ap, srtp, qp, Cp, Mp = pad_dims(
        (sc, (SUBLANE, block_m)),
        (a_l, (block_m, LANE)),
        (srt, (LANE, LANE)),
        (q, (SUBLANE, LANE)),
        (C, (block_m, LANE)),
        (M, (SUBLANE, LANE)),
    )
    scal_f = jnp.zeros((8,), jnp.float32)
    scal_f = scal_f.at[0].set(min_gain).at[1].set(run_mean).at[2].set(true_cols)
    scal_i = jnp.zeros((8,), jnp.int32)
    scal_i = scal_i.at[0].set(n_filled).at[1].set(free)
    Cp, Mp, sc_a, stats, slots = panel_update_kernel(
        scp, ap, srtp, qp, Cp, Mp, scal_f, scal_i,
        L=L, c_total=c_total, panel_cap=min(panel_cap, L),
        block_m=block_m, interpret=interpret,
    )
    return (
        Cp[:C.shape[0], :c_total],
        Mp[:s_c, :s_r],
        sc_a[:s_c, :L],
        stats[0, :L],
        stats[1, :L],
        slots[0, :L],
    )


__all__ = [
    "LANE",
    "SUBLANE",
    "pad_dims",
    "interpret_default",
    "kernel_route_enabled",
    "twoside_sketch",
    "countsketch_apply",
    "panel_score",
    "panel_update",
    "twoside_sketch_ref",
    "countsketch_ref",
    "panel_score_ref",
    "panel_update_ref",
]
