"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def twoside_sketch_ref(sc: jax.Array, a: jax.Array, srt: jax.Array) -> jax.Array:
    """M = S_C · A · S_Rᵀ in fp32."""
    dt = jnp.float32
    return (sc.astype(dt) @ a.astype(dt)) @ srt.astype(dt)


def countsketch_ref(hashes: jax.Array, signs: jax.Array, a: jax.Array, s: int) -> jax.Array:
    """Signed segment-sum (the CPU input-sparsity algorithm)."""
    signed = a.astype(jnp.float32) * signs.astype(jnp.float32)[:, None]
    return jax.ops.segment_sum(signed, hashes, num_segments=s)


def panel_score_ref(sc: jax.Array, a_l: jax.Array, q: jax.Array) -> tuple:
    """Unfused three-op oracle for the panel-scoring kernel.

    ``sc_a = S_C A_L``, per-column energies, and projection residuals
    against the zero-masked orthonormal basis ``q`` — each op a separate
    HBM round-trip over ``sc_a`` (the traffic the fused kernel removes).
    Returns ``(sc_a, resid2, energy)`` in fp32.
    """
    dt = jnp.float32
    sc_a = sc.astype(dt) @ a_l.astype(dt)  # (s_c, L)
    energy = jnp.sum(sc_a * sc_a, axis=0)  # (L,)
    t = q.astype(dt).T @ sc_a  # (c, L)
    resid2 = jnp.maximum(energy - jnp.sum(t * t, axis=0), 0.0)
    return sc_a, resid2, energy


def panel_update_ref(
    sc: jax.Array,
    a_l: jax.Array,
    srt: jax.Array,
    q: jax.Array,
    C: jax.Array,
    M: jax.Array,
    *,
    min_gain,
    run_mean,
    true_cols,
    n_filled,
    free,
    panel_cap: int,
) -> tuple:
    """Unfused oracle for the fused panel-update megakernel.

    The exact admission-only panel update of
    :mod:`repro.stream.adaptive`, as the separate XLA ops the megakernel
    replaces: score (three ``sc_a`` round-trips), threshold, stable
    ``top_k`` + cumsum slot assignment, scatter into ``C``, and the
    ``M += sc_a · S_Rᵀ|window`` fold. Returns
    ``(C', M', sc_a, resid2, energy, slots)`` with ``slots[j]`` the C slot
    column ``j`` was admitted into or the ``C.shape[1]`` sentinel.
    """
    sc_a, resid2, energy = panel_score_ref(sc, a_l, q)
    L = a_l.shape[1]
    c_total = C.shape[1]
    panel_mean = jnp.sum(energy) / true_cols
    thresh = min_gain * jnp.maximum(run_mean, panel_mean)
    eligible = resid2 > thresh
    K = min(panel_cap, L)
    cand_res, cand = jax.lax.top_k(jnp.where(eligible, resid2, -1.0), K)
    cand_ok = jnp.take(eligible, cand)
    ranks = jnp.cumsum(cand_ok.astype(jnp.int32)) - 1
    admit = cand_ok & (ranks < free)
    cand_slots = jnp.where(admit, n_filled + ranks, c_total)
    C = C.at[:, cand_slots].set(
        jnp.take(a_l, cand, axis=1).astype(C.dtype), mode="drop"
    )
    # admitted slots back in panel-column order; non-admitted candidates
    # write the sentinel they already hold (cand indices are distinct)
    slots = jnp.full((L,), c_total, jnp.int32).at[cand].set(
        cand_slots.astype(jnp.int32)
    )
    M = M + (sc_a @ srt.astype(jnp.float32)).astype(M.dtype)
    return C, M, sc_a, resid2, energy, slots
