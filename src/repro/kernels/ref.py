"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def twoside_sketch_ref(sc: jax.Array, a: jax.Array, srt: jax.Array) -> jax.Array:
    """M = S_C · A · S_Rᵀ in fp32."""
    dt = jnp.float32
    return (sc.astype(dt) @ a.astype(dt)) @ srt.astype(dt)


def countsketch_ref(hashes: jax.Array, signs: jax.Array, a: jax.Array, s: int) -> jax.Array:
    """Signed segment-sum (the CPU input-sparsity algorithm)."""
    signed = a.astype(jnp.float32) * signs.astype(jnp.float32)[:, None]
    return jax.ops.segment_sum(signed, hashes, num_segments=s)
