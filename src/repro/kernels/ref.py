"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def twoside_sketch_ref(sc: jax.Array, a: jax.Array, srt: jax.Array) -> jax.Array:
    """M = S_C · A · S_Rᵀ in fp32."""
    dt = jnp.float32
    return (sc.astype(dt) @ a.astype(dt)) @ srt.astype(dt)


def countsketch_ref(hashes: jax.Array, signs: jax.Array, a: jax.Array, s: int) -> jax.Array:
    """Signed segment-sum (the CPU input-sparsity algorithm)."""
    signed = a.astype(jnp.float32) * signs.astype(jnp.float32)[:, None]
    return jax.ops.segment_sum(signed, hashes, num_segments=s)


def panel_score_ref(sc: jax.Array, a_l: jax.Array, q: jax.Array) -> tuple:
    """Unfused three-op oracle for the panel-scoring kernel.

    ``sc_a = S_C A_L``, per-column energies, and projection residuals
    against the zero-masked orthonormal basis ``q`` — each op a separate
    HBM round-trip over ``sc_a`` (the traffic the fused kernel removes).
    Returns ``(sc_a, resid2, energy)`` in fp32.
    """
    dt = jnp.float32
    sc_a = sc.astype(dt) @ a_l.astype(dt)  # (s_c, L)
    energy = jnp.sum(sc_a * sc_a, axis=0)  # (L,)
    t = q.astype(dt).T @ sc_a  # (c, L)
    resid2 = jnp.maximum(energy - jnp.sum(t * t, axis=0), 0.0)
    return sc_a, resid2, energy
