"""Pallas TPU kernels for the paper's compute hot spots.

twoside_sketch — fused S_C·A·S_Rᵀ (Algorithm 1/3 inner sketch)
countsketch    — TPU-adapted input-sparsity CountSketch (one-hot MXU matmul)
panel_score    — fused streaming panel scoring: S_C·A_L + column energies +
                 admitted-basis residuals in one VMEM pass (adaptive CUR)
panel_update   — fused panel-update megakernel: panel_score's triple plus
                 the in-kernel admission decision, the M fold and the C
                 scatter, with C/M aliased in place (adaptive CUR)
Each has a pure-jnp oracle in ref.py; ops.py holds the jit'd wrappers and
the shared padding/dispatch scheme (pad_dims / interpret_default).
"""
from .ops import (
    countsketch_apply,
    countsketch_ref,
    kernel_route_enabled,
    panel_score,
    panel_score_ref,
    panel_update,
    panel_update_ref,
    twoside_sketch,
    twoside_sketch_ref,
)
