"""Pallas TPU kernels for the paper's compute hot spots.

twoside_sketch — fused S_C·A·S_Rᵀ (Algorithm 1/3 inner sketch)
countsketch    — TPU-adapted input-sparsity CountSketch (one-hot MXU matmul)
panel_score    — fused streaming panel scoring: S_C·A_L + column energies +
                 admitted-basis residuals in one VMEM pass (adaptive CUR)
Each has a pure-jnp oracle in ref.py; ops.py holds the jit'd wrappers.
"""
from .ops import (
    countsketch_apply,
    countsketch_ref,
    panel_score,
    panel_score_ref,
    twoside_sketch,
    twoside_sketch_ref,
)
