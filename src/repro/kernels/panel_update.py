"""Pallas TPU megakernel: fused streaming panel *update* for adaptive CUR.

``panel_score.py`` fused the three scoring reads of a panel into one VMEM
pass but still returned ``sc_a`` to HBM for XLA to finish the panel: the
``M += sc_a · S_Rᵀ`` fold, the admission decision, and the scatter of the
admitted columns into ``C`` each re-read data the kernel just held in
registers. This kernel extends the same accumulator pattern to the whole
admission-only panel update (:mod:`repro.stream.adaptive`):

* ``sc_a = S_C · A_L`` accumulated in VMEM scratch across the m-reduction
  (never an HBM round-trip between its producers and consumers);
* scores ``(resid2, energy)`` from the still-resident tile (the
  ``panel_score`` math);
* the admission decision itself — eligibility threshold + slot assignment
  — resolved in-kernel by a pairwise rank over the L panel columns:

      rank_j = #{i eligible : resid2_i > resid2_j
                              or (resid2_i = resid2_j and i < j)}
      admit_j ⇔ eligible_j and rank_j < min(free, panel_cap)
      slot_j  = n_filled + rank_j   (else the c_total sentinel)

  For eligible columns ``resid2 > thresh ≥ 0 > −1``, so this is exactly
  the selection of the XLA route's stable ``top_k`` over the −1-masked
  residuals followed by ``cumsum`` ranking (``top_k`` breaks ties by
  lower index — the same tie-break the rank formula encodes), at O(L²)
  vector ops instead of a sort;
* ``M_out = M_in + sc_a · S_Rᵀ|window`` from the resident tile (``M``
  aliased in/out — updated in place);
* the admitted columns scattered into ``C`` as a one-hot matmul
  ``C ← C·keep + A_L·P`` with ``P[j, s] = [slot_j = s]`` (the
  ``countsketch.py`` slab idiom — a scatter the MXU can execute), ``C``
  aliased in/out.

Grid ``(2, m/block_m)`` — phase-major, m-blocks fastest. Phase 0 runs the
m-reduction and, on its last step, scores + admission + the M/sc_a/stats
writes, parking the slot map in scratch; phase 1 revisits the m-blocks to
apply the C scatter row-block by row-block (``A_L`` is read once per
phase — the second read is the unavoidable one: ``C``'s row blocks need
the admitted columns' full m extent, which the phase-0 reduction has
already retired block by block). Phase 0 writes ``C`` through unchanged:
an aliased output block that is visited but never written would flush
whatever the window buffer holds.

All dims are pre-padded to block multiples by ``ops.panel_update``; zero
padding is inert everywhere (zero columns have zero energy and are never
eligible — the threshold comparison is strict — and the ``c_total``
sentinel lands either in a sliced-off padded C column or out of bounds).
fp32 accumulation regardless of input dtype.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    sc_ref, a_ref, srt_ref, q_ref, cin_ref, min_ref, sf_ref, si_ref,
    cout_ref, mout_ref, sca_ref, stats_ref, slots_ref,
    acc_ref, slot_ref, *, c_total: int, panel_cap: int, L: int,
):
    p = pl.program_id(0)
    k = pl.program_id(1)
    nm = pl.num_programs(1)
    Lp = acc_ref.shape[1]

    @pl.when(p == 0)
    def _phase0():
        @pl.when(k == 0)
        def _():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        # (s_c, bm) @ (bm, Lp) → (s_c, Lp), fp32 accumulate on the MXU
        acc_ref[...] += jnp.dot(
            sc_ref[...], a_ref[...], preferred_element_type=jnp.float32
        )
        # write-through: this C row block is revisited (and really written)
        # in phase 1; an aliased output block left unwritten flushes garbage
        cout_ref[...] = cin_ref[...]

        @pl.when(k == nm - 1)
        def _():
            y = acc_ref[...]  # (s_c, Lp) — the finished panel-sketch tile
            sca_ref[...] = y
            # t = Qᵀ y without materializing the transpose
            t = jax.lax.dot_general(
                q_ref[...], y, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # (c_local, Lp)
            energy = jnp.sum(y * y, axis=0, keepdims=True)  # (1, Lp)
            resid2 = jnp.maximum(
                energy - jnp.sum(t * t, axis=0, keepdims=True), 0.0
            )
            # admission threshold (repro.stream.adaptive._update_c): the
            # panel mean is over *true* columns; padded columns have zero
            # energy so the in-kernel sum needs no mask
            panel_mean = jnp.sum(energy) / sf_ref[2]
            thresh = sf_ref[0] * jnp.maximum(sf_ref[1], panel_mean)
            lane = jax.lax.broadcasted_iota(jnp.int32, (1, Lp), 1)
            eligible = (resid2 > thresh) & (lane < L)
            # pairwise rank ≡ stable-top_k order (ties broken by lower index)
            ii = jax.lax.broadcasted_iota(jnp.int32, (Lp, Lp), 0)
            jj = jax.lax.broadcasted_iota(jnp.int32, (Lp, Lp), 1)
            ri = jnp.transpose(resid2)  # (Lp, 1)
            better = jnp.transpose(eligible) & (
                (ri > resid2) | ((ri == resid2) & (ii < jj))
            )
            rank = jnp.sum(better.astype(jnp.int32), axis=0, keepdims=True)
            limit = jnp.minimum(si_ref[1], panel_cap)  # min(free, cap)
            admit = eligible & (rank < limit)
            slot = jnp.where(admit, si_ref[0] + rank, c_total)  # (1, Lp)
            slot_ref[...] = jnp.broadcast_to(slot, slot_ref.shape)
            slots_ref[...] = jnp.broadcast_to(slot, slots_ref.shape)
            pad = jnp.zeros((stats_ref.shape[0] - 2, Lp), jnp.float32)
            stats_ref[...] = jnp.concatenate([resid2, energy, pad], axis=0)
            # M fold from the resident tile: (s_c, Lp) @ (Lp, s_r)
            mout_ref[...] = min_ref[...] + jnp.dot(
                y, srt_ref[...], preferred_element_type=jnp.float32
            ).astype(mout_ref.dtype)

    @pl.when(p == 1)
    def _phase1():
        # scatter-as-matmul (the countsketch slab idiom): P[j, s] = [slot_j = s]
        slot = slot_ref[0:1, :]  # (1, Lp)
        cols = jax.lax.broadcasted_iota(jnp.int32, (Lp, cin_ref.shape[1]), 1)
        P = (jnp.transpose(slot) == cols).astype(jnp.float32)  # (Lp, c_pad)
        keep = (jnp.sum(P, axis=0, keepdims=True) == 0.0).astype(jnp.float32)
        newc = jnp.dot(
            a_ref[...].astype(jnp.float32), P, preferred_element_type=jnp.float32
        )  # (bm, c_pad) — exact copies: one-hot columns select single A entries
        cout_ref[...] = (
            cin_ref[...].astype(jnp.float32) * keep + newc
        ).astype(cout_ref.dtype)


@partial(
    jax.jit, static_argnames=("L", "c_total", "panel_cap", "block_m", "interpret")
)
def panel_update_kernel(
    sc: jax.Array,  # (s_c, m) dense column sketch
    a_l: jax.Array,  # (m, Lp) panel
    srt: jax.Array,  # (Lp, s_r) dense transposed S_R window at this offset
    q: jax.Array,  # (s_c, c_q) zero-masked whitened basis of admitted sketches
    C: jax.Array,  # (m, c_pad) column factor — aliased to the first output
    M: jax.Array,  # (s_c, s_r) core sketch — aliased to the second output
    scal_f: jax.Array,  # (8,) f32 [min_gain, run_mean, true_cols, …]
    scal_i: jax.Array,  # (8,) i32 [n_filled, free, …]
    *,
    L: int,  # true (unpadded) panel width
    c_total: int,  # true C column count — the not-admitted slot sentinel
    panel_cap: int,
    block_m: int = 256,
    interpret: bool = False,
) -> tuple:
    """All dims must already be padded to their block multiples (see ops.py).

    Returns ``(C', M', sc_a (s_c, Lp) f32, stats (8, Lp) f32, slots (8, Lp)
    i32)`` with ``stats[0] = resid2``, ``stats[1] = energy`` and
    ``slots[0]`` the per-column admission slot (``c_total`` sentinel).
    """
    s_c, m = sc.shape
    _, Lp = a_l.shape
    s_r = srt.shape[1]
    c_pad = C.shape[1]
    assert a_l.shape[0] == m and q.shape[0] == s_c and srt.shape[0] == Lp
    assert C.shape[0] == m and M.shape == (s_c, s_r)
    assert s_c % 8 == 0 and Lp % 128 == 0 and s_r % 128 == 0
    assert q.shape[1] % 128 == 0 and c_pad % 128 == 0 and m % block_m == 0

    grid = (2, m // block_m)
    kernel = partial(_kernel, c_total=c_total, panel_cap=panel_cap, L=L)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s_c, block_m), lambda p, k: (0, k)),
            pl.BlockSpec((block_m, Lp), lambda p, k: (k, 0)),
            pl.BlockSpec((Lp, s_r), lambda p, k: (0, 0)),
            pl.BlockSpec((s_c, q.shape[1]), lambda p, k: (0, 0)),
            pl.BlockSpec((block_m, c_pad), lambda p, k: (k, 0)),
            pl.BlockSpec((s_c, s_r), lambda p, k: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((block_m, c_pad), lambda p, k: (k, 0)),
            pl.BlockSpec((s_c, s_r), lambda p, k: (0, 0)),
            pl.BlockSpec((s_c, Lp), lambda p, k: (0, 0)),
            pl.BlockSpec((8, Lp), lambda p, k: (0, 0)),
            pl.BlockSpec((8, Lp), lambda p, k: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(C.shape, C.dtype),
            jax.ShapeDtypeStruct(M.shape, M.dtype),
            jax.ShapeDtypeStruct((s_c, Lp), jnp.float32),
            jax.ShapeDtypeStruct((8, Lp), jnp.float32),
            jax.ShapeDtypeStruct((8, Lp), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((s_c, Lp), jnp.float32),
            pltpu.VMEM((8, Lp), jnp.int32),
        ],
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
    )(sc, a_l, srt, q, C, M, scal_f, scal_i)
