"""Pallas TPU kernel: fused two-sided sketch  M = S_C · A · S_Rᵀ.

The hot spot of Algorithm 1 / Algorithm 3 step 8 (``M += S_C A_L S_R``) and
of gradient compression. Computing ``(S_C A)`` first writes an s_c×n
intermediate through HBM and reads it back; the fused kernel keeps the
``(bsc × bsr)`` output accumulator in VMEM scratch across the whole
(m, n) reduction, so each A tile is read exactly once:

    HBM traffic:  m·n  +  (m/bm)·s_c·bm  +  (n/bn)·s_r·bn  + s_c·s_r
    vs sequential: m·n + 2·s_c·n + …

Grid (i, j, k, l) = (s_c blocks, s_r blocks, m blocks, n blocks), reduction
over (k, l); two MXU matmuls per step:  (bsc×bm)(bm×bn) → (bsc×bn), then
(bsc×bn)(bn×bsr). All tile dims are 128-multiples (MXU-aligned); fp32
accumulation regardless of input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(sc_ref, a_ref, srt_ref, out_ref, acc_ref):
    k, l = pl.program_id(2), pl.program_id(3)

    @pl.when((k == 0) & (l == 0))
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # (bsc, bm) @ (bm, bn) @ (bn, bsr), fp32 accumulate on the MXU
    t = jnp.dot(sc_ref[...], a_ref[...], preferred_element_type=jnp.float32)
    acc_ref[...] += jnp.dot(
        t.astype(srt_ref.dtype), srt_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when((k == pl.num_programs(2) - 1) & (l == pl.num_programs(3) - 1))
    def _():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def twoside_sketch_kernel(
    sc: jax.Array,  # (s_c, m)
    a: jax.Array,  # (m, n)
    srt: jax.Array,  # (n, s_r)
    *,
    block_sc: int = 128,
    block_sr: int = 128,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """All dims must already be padded to their block multiples (see ops.py)."""
    s_c, m = sc.shape
    n, s_r = srt.shape
    assert a.shape == (m, n)
    assert s_c % block_sc == 0 and s_r % block_sr == 0
    assert m % block_m == 0 and n % block_n == 0

    grid = (s_c // block_sc, s_r // block_sr, m // block_m, n // block_n)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_sc, block_m), lambda i, j, k, l: (i, k)),
            pl.BlockSpec((block_m, block_n), lambda i, j, k, l: (k, l)),
            pl.BlockSpec((block_n, block_sr), lambda i, j, k, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((block_sc, block_sr), lambda i, j, k, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s_c, s_r), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_sc, block_sr), jnp.float32)],
        interpret=interpret,
    )(sc, a, srt)
