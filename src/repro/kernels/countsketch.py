"""Pallas TPU kernel: CountSketch  S·A  as a blocked one-hot MXU matmul.

The paper's input-sparsity CountSketch is a scatter-add — no TPU analogue
(no scatter units; see DESIGN.md §5). The TPU-native restatement: for each
(bm=128)-row block of A, materialize the signed one-hot slab
P = onehot(h[block]) ⊙ σ[block]  (s × bm) *inside VMEM* from the integer
hash/sign vectors (broadcasted-iota compare — the slab never exists in
HBM), and accumulate  P @ A_block  on the MXU into an (s, bn) scratch.

One HBM pass over A — bandwidth-bound, which is the O(nnz) insight
restated for a dense-tile machine.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(h_ref, sg_ref, a_ref, out_ref, acc_ref, *, s_pad: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    h = h_ref[...]  # (bm,) int32
    sg = sg_ref[...]  # (bm,)
    bm = h.shape[0]
    # signed one-hot slab (s_pad, bm) built in-register: rows=sketch buckets
    rows = jax.lax.broadcasted_iota(jnp.int32, (s_pad, bm), 0)
    slab = jnp.where(rows == h[None, :], sg[None, :], 0).astype(a_ref.dtype)
    acc_ref[...] += jnp.dot(slab, a_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(1) - 1)
    def _():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def countsketch_kernel(
    hashes: jax.Array,  # (m,) int32 in [0, s)
    signs: jax.Array,  # (m,) ±1
    a: jax.Array,  # (m, n)
    s: int,
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """dims must be pre-padded to block multiples; s padded to 128 (ops.py)."""
    m, n = a.shape
    assert m % block_m == 0 and n % block_n == 0 and s % 128 == 0
    grid = (n // block_n, m // block_m)

    import functools

    return pl.pallas_call(
        functools.partial(_kernel, s_pad=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m,), lambda j, k: (k,)),
            pl.BlockSpec((block_m,), lambda j, k: (k,)),
            pl.BlockSpec((block_m, block_n), lambda j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((s, block_n), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((s, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((s, block_n), jnp.float32)],
        interpret=interpret,
    )(hashes, signs, a)
