"""CUR decomposition via Fast GMR (the paper's first named application).

``A ≈ C U R`` with ``C = A[:, col_idx]``, ``R = A[row_idx, :]`` actual
columns/rows of ``A``. The optimal core for fixed C, R is the GMR solution

    ``U* = C† A R†``            (:func:`exact_cur`, O(mn·min(c,r)))

and Algorithm 1 makes it sketched:

    ``Ũ = (S_C C)† (S_C A S_Rᵀ) (R S_Rᵀ)†``   (:func:`fast_cur`,
    O(sketch cost + s_c c² + s_r r²) — Theorem 1's (1+ε) bound).

Sketch-size defaults follow Table 2's ``s = ν · max{c/√ε, c/(ε ρ²)}`` with
the ρ-based branch selection: the ε^{-1/2} branch is active once the
problem constant ρ (Eqn. 3.2) exceeds ε^{-1/4}; pass the measured
:func:`repro.core.gmr.rho` as ``rho_est`` to refine, or keep the Θ(1)
default the paper observes in practice.

The default core sketch family is ``"leverage"`` — leverage-score row
sampling w.r.t. range(C)/range(Rᵀ) (Table 3), whose ``S_C A`` is a row
*gather*: the sketched solve then costs O(s_c·n + s_c·s_r) data movement
and beats the exact ``C† A R†`` path's O(c·m·n) matmul by orders of
magnitude at serving scale (see ``benchmarks/cur_decomp.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.gmr import error_ratio, exact_gmr, fast_gmr_core
from ..core.leverage import leverage_scores
from ..core.sketching import RowSampling, draw_sketch
from .selection import Selection, select_columns, select_rows

__all__ = [
    "CURResult",
    "cur_sketch_sizes",
    "exact_cur",
    "fast_cur",
    "cur_reconstruct",
    "cur_error_ratio",
    "cur_relative_error",
]


@dataclasses.dataclass(frozen=True)
class CURResult:
    """Factors ``A ≈ C U R`` plus the index sets that produced them.

    Arrays may carry leading batch dimensions (see ``repro.cur.batched``).
    """

    C: jax.Array  # (..., m, c)
    U: jax.Array  # (..., c, r)
    R: jax.Array  # (..., r, n)
    col_idx: jax.Array  # (..., c)
    row_idx: jax.Array  # (..., r)


jax.tree_util.register_dataclass(
    CURResult, data_fields=["C", "U", "R", "col_idx", "row_idx"], meta_fields=[]
)


def cur_sketch_sizes(
    c: int,
    r: int,
    eps: float = 0.05,
    rho: float = 2.0,
    nu: float = 3.0,
) -> dict:
    """Table-2 sketch sizes with ρ-branch selection: ``s = ν·max{c/√ε, c/(ε ρ²)}``.

    ``rho`` is the Eqn.-3.2 problem constant (ε^{-1/4} is the crossover; the
    paper observes ρ = Θ(1) on real spectra). ``nu`` matches the constant
    used by :func:`repro.core.svd.sp_svd_sizes`.
    """
    if eps <= 0:
        raise ValueError(f"eps must be positive, got {eps}")
    branch = max(1.0 / np.sqrt(eps), 1.0 / (eps * rho * rho))
    return dict(s_c=int(np.ceil(nu * c * branch)), s_r=int(np.ceil(nu * r * branch)))


def _resolve_indices(
    key,
    A: jax.Array,
    c: Optional[int],
    r: Optional[int],
    policy: str,
    col_idx,
    row_idx,
) -> Tuple[jax.Array, jax.Array]:
    k_c, k_r = jax.random.split(key)
    if col_idx is None:
        if c is None:
            raise ValueError("pass either `c` or explicit `col_idx`")
        col_idx = select_columns(k_c, A, c, policy).idx
    if row_idx is None:
        if r is None:
            raise ValueError("pass either `r` or explicit `row_idx`")
        row_idx = select_rows(k_r, A, r, policy).idx
    return jnp.asarray(col_idx), jnp.asarray(row_idx)


def exact_cur(
    A: jax.Array,
    col_idx: Optional[jax.Array] = None,
    row_idx: Optional[jax.Array] = None,
    *,
    key=None,
    c: Optional[int] = None,
    r: Optional[int] = None,
    policy: str = "uniform",
) -> CURResult:
    """Oracle CUR: ``U* = C† A R†`` (the minimizer for the chosen C, R)."""
    if col_idx is None or row_idx is None:
        if key is None:
            raise ValueError("pass `key` when indices are not explicit")
        col_idx, row_idx = _resolve_indices(key, A, c, r, policy, col_idx, row_idx)
    col_idx, row_idx = jnp.asarray(col_idx), jnp.asarray(row_idx)
    C = jnp.take(A, col_idx, axis=1)
    R = jnp.take(A, row_idx, axis=0)
    U = exact_gmr(A, C, R)
    return CURResult(C=C, U=U, R=R, col_idx=col_idx, row_idx=row_idx)


def _draw_core_sketches(key, C, R, s_c: int, s_r: int, sketch: str):
    """Draw S_C (s_c×m) / S_R (s_r×n) of the requested Table-2/3 family."""
    m, n = C.shape[0], R.shape[1]
    k_sc, k_sr = jax.random.split(key)
    if sketch == "leverage":
        lev_c = leverage_scores(C)
        lev_r = leverage_scores(R.T)
        S_C = RowSampling.draw(k_sc, s_c, m, probs=lev_c, dtype=C.dtype)
        S_R = RowSampling.draw(k_sr, s_r, n, probs=lev_r, dtype=C.dtype)
    else:
        S_C = draw_sketch(k_sc, sketch, s_c, m, dtype=C.dtype)
        S_R = draw_sketch(k_sr, sketch, s_r, n, dtype=C.dtype)
    return S_C, S_R


def fast_cur(
    key,
    A: jax.Array,
    c: Optional[int] = None,
    r: Optional[int] = None,
    *,
    policy: str = "uniform",
    sketch: str = "leverage",
    eps: float = 0.05,
    rho_est: float = 2.0,
    s_c: Optional[int] = None,
    s_r: Optional[int] = None,
    col_idx: Optional[jax.Array] = None,
    row_idx: Optional[jax.Array] = None,
    sketches=None,
) -> CURResult:
    """Algorithm-1 CUR: selection → core sketches → sketched GMR solve.

    ``sketches=(S_C, S_R)`` injects pre-drawn operators (the streaming /
    batched paths use this to share randomness); ``s_c``/``s_r`` override
    the Table-2 defaults computed from ``(eps, rho_est)``.
    """
    m, n = A.shape
    k_sel, k_skt = jax.random.split(key)
    col_idx, row_idx = _resolve_indices(k_sel, A, c, r, policy, col_idx, row_idx)
    C = jnp.take(A, col_idx, axis=1)
    R = jnp.take(A, row_idx, axis=0)

    if sketches is None:
        sizes = cur_sketch_sizes(C.shape[1], R.shape[0], eps=eps, rho=rho_est)
        s_c = min(s_c or sizes["s_c"], m)
        s_r = min(s_r or sizes["s_r"], n)
        S_C, S_R = _draw_core_sketches(k_skt, C, R, s_c, s_r, sketch)
    else:
        S_C, S_R = sketches

    ScC = S_C.apply(C)  # (s_c, c)
    RSr = S_R.apply_t(R)  # (r, s_r)
    ScASr = S_R.apply_t(S_C.apply(A))  # (s_c, s_r)
    U = fast_gmr_core(ScC, ScASr, RSr)
    return CURResult(C=C, U=U, R=R, col_idx=col_idx, row_idx=row_idx)


def cur_reconstruct(res: CURResult) -> jax.Array:
    """``C U R`` (batched-aware)."""
    return res.C @ res.U @ res.R


def cur_error_ratio(A: jax.Array, res: CURResult) -> jax.Array:
    """§6.1 metric vs the oracle core: ``||A−CUR||_F / ||A−CU*R||_F − 1``."""
    return error_ratio(A, res.C, res.U, res.R)


def cur_relative_error(A: jax.Array, res: CURResult) -> jax.Array:
    """``||A − C U R||_F / ||A||_F``."""
    dt = jnp.promote_types(A.dtype, jnp.float32)
    diff = A.astype(dt) - cur_reconstruct(res).astype(dt)
    return jnp.linalg.norm(diff) / jnp.maximum(jnp.linalg.norm(A.astype(dt)), jnp.finfo(dt).tiny)
