"""Symmetric CUR decomposition: ``K ≈ C X Cᵀ`` with ``R = Cᵀ`` tied.

For an SPSD matrix the natural CUR factorization samples *one* index set —
selecting rows independently of columns wastes half the budget and breaks
symmetry. Symmetric CUR keeps ``R = Cᵀ`` by construction (the tied-operand
form of paper §4 / ROADMAP "SPSD path for symmetric CUR"), which makes it
exactly the SPSD approximation problem: the core solve *is* Algorithm 2's
``X̃ = (S₁C)† (S₁ K S₂ᵀ) (Cᵀ S₂ᵀ)†`` followed by the PSD projection
(Theorem 2), so this module reuses :mod:`repro.spsd.batch` for the solve
and contributes what the SPSD side lacks: **column selection policies**.
Every :mod:`repro.cur.selection` policy (uniform / leverage /
approx_leverage / pivoted_qr) can drive the sampled index set — on kernel
matrices the leverage and pivoted-QR policies concentrate the budget on the
landmark points the uniform draw misses.

Results keep the full SPSD contract — an
:class:`~repro.spsd.batch.SPSDResult` whose ``X`` is PSD and whose quality
is measured by :func:`~repro.spsd.batch.spsd_error_ratio`; the
entry-observation accounting is preserved (``nc + s²`` for the sketched
core, ``n²`` for the exact one). :func:`spsd_to_cur` adapts the result to
the :class:`~repro.cur.cur.CURResult` surface (``U = X``, ``R = Cᵀ``,
``row_idx = col_idx``) for CUR-generic consumers.

The single-pass streaming variant of the same factorization lives in
:mod:`repro.spsd.streaming` (symmetric engine plug-in, fixed or adaptively
admitted columns).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..spsd.batch import SPSDResult, faster_spsd, matrix_oracle, optimal_core
from .cur import CURResult
from .selection import select_columns

__all__ = ["symmetric_cur", "spsd_to_cur"]


def symmetric_cur(
    key,
    K: jax.Array,
    c: Optional[int] = None,
    *,
    policy: str = "uniform",
    col_idx: Optional[jax.Array] = None,
    s: Optional[int] = None,
    k: Optional[int] = None,
    method: str = "faster",
) -> SPSDResult:
    """Policy-driven symmetric CUR of an SPSD matrix: ``K ≈ C X Cᵀ``.

    Args:
        key: PRNG key (selection + core sketches).
        K: the SPSD matrix, (n, n). Materialized input — the policies score
            actual columns; for oracle-bound access with uniform sampling
            use :func:`repro.spsd.faster_spsd` directly, and for
            single-pass access :mod:`repro.spsd.streaming`.
        c: number of columns to select (ignored when ``col_idx`` given).
        policy: any :data:`repro.cur.selection.SELECTION_POLICIES` entry;
            selection runs on ``K`` itself (leverage of an SPSD matrix's
            columns equals that of its rows, so one draw serves both sides).
        col_idx: explicit index set overriding the policy draw.
        s: sketch size for the ``"faster"`` core (default ``min(10·c, n)``,
            the paper's §6.2 "≈ optimal" operating point).
        k: target subspace rank for the leverage policies (defaults to
            ``c`` inside :func:`~repro.cur.selection.select_columns`).
        method: ``"faster"`` — Algorithm 2 sketched core (nc + s² entry
            accounting); ``"exact"`` — the oracle core ``C† K (C†)ᵀ`` (n²).

    Returns:
        An :class:`~repro.spsd.batch.SPSDResult`; ``X`` is PSD
        (projection applied on both methods) and
        :func:`~repro.spsd.batch.spsd_error_ratio` measures the fit.
    """
    n, n2 = K.shape
    if n != n2:
        raise ValueError(f"symmetric CUR needs a square SPSD matrix, got {K.shape}")
    k_sel, k_core = jax.random.split(key)
    if col_idx is None:
        if c is None:
            raise ValueError("pass either `c` or explicit `col_idx`")
        col_idx = select_columns(k_sel, K, c, policy, k=k).idx
    col_idx = jnp.asarray(col_idx, jnp.int32)
    c = col_idx.shape[0]
    oracle = matrix_oracle(K)
    if method == "exact":
        return optimal_core(k_core, oracle, n, c, col_idx=col_idx)
    if method != "faster":
        raise ValueError(f"unknown method {method!r}; expected 'faster' or 'exact'")
    if s is None:
        s = min(10 * c, n)
    return faster_spsd(k_core, oracle, n, c, s, col_idx=col_idx)


def spsd_to_cur(res: SPSDResult) -> CURResult:
    """Adapt an SPSD factorization to the CUR surface: ``U = X``, ``R = Cᵀ``.

    ``row_idx`` aliases ``col_idx`` (the tied index set), so CUR-generic
    consumers (``cur_reconstruct``, ``cur_relative_error``, serving code)
    work unchanged on symmetric factorizations.
    """
    return CURResult(
        C=res.C, U=res.X, R=res.C.T, col_idx=res.col_idx, row_idx=res.col_idx
    )
