"""Single-pass streaming CUR over L-column panels.

Same streaming contract as ``repro.core.svd.sp_svd_update`` (Algorithm 3) —
both now ride the shared :mod:`repro.stream.engine`: ``A`` arrives as column
panels ``A_L`` and is never retained. Per panel:

* ``C``: the panel's selected columns land in their slots (selected column
  j with ``offset ≤ col_idx[j] < offset+L`` is copied out of the panel);
* ``R[:, cols] = A_L[row_idx, :]`` — selected rows accumulate left→right;
* ``M += (S_C A_L) · S_R[:, cols]ᵀ`` via the ``cols()`` sketch-window
  primitive of ``repro.core.sketching`` (column-sliceable families only:
  gaussian / countsketch / osnap / sampling).

Memory: C (m·c) + R (r·n) + M (s_c·s_r) — the factors themselves plus a
constant-size core sketch; ``finalize`` then runs the Fast-GMR core solve.
Because ``Σ_L S_C A_L S_R[:,cols]ᵀ = S_C A S_Rᵀ`` exactly, the finalized
factors match one-shot :func:`repro.cur.fast_cur` on identical sketches up
to fp32 summation order (tested in ``tests/test_cur.py``). Drive the state
with :func:`repro.stream.stream_panels` — scan-compiled by default (one
program per chunk, donated buffers), with the per-panel jitted step behind
``jit="per-panel"``.

This module keeps *fixed* pre-pass indices (uniform, or scores from a prior
epoch / sketched estimate). For residual-driven in-stream column
admission/eviction and adaptive row admission (the v2 replacement policy)
see :mod:`repro.stream.adaptive`; for DP-sharded ingestion of either
variant see :mod:`repro.stream.distributed`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.gmr import fast_gmr_core
from ..core.sketching import draw_sketch
from ..obs.telemetry import fixed_stream_telemetry, init_telemetry
from ..stream.engine import (
    PanelOps,
    PanelState,
    copy_selected_columns,
    fresh_pytree,
    padded_n,
    panel_update,
    truncated_R,
)
from .cur import CURResult, cur_sketch_sizes

__all__ = [
    "StreamingCURState",
    "CURStreamCtx",
    "STREAMING_CUR_OPS",
    "STREAMING_CUR_TEL_OPS",
    "streaming_cur_init",
    "streaming_cur_update",
    "streaming_cur_finalize",
]


@dataclasses.dataclass(frozen=True)
class CURStreamCtx:
    """Fixed selection indices + the shared core sketching operators."""

    col_idx: jax.Array  # (c,)
    row_idx: jax.Array  # (r,)
    S_C: object  # column-sliceable sketch, (s_c, m)
    S_R: object  # column-sliceable sketch, (s_r, n_pad)


jax.tree_util.register_dataclass(
    CURStreamCtx, data_fields=["col_idx", "row_idx", "S_C", "S_R"], meta_fields=[]
)


def _cur_core_sketches(ctx: CURStreamCtx):
    return ctx.S_C, ctx.S_R


def _cur_update_c(ctx: CURStreamCtx, C, A_L, sc_a, off):
    # selected columns that live in this panel → their C slots
    return ctx, copy_selected_columns(ctx.col_idx, C, A_L, off)


def _cur_r_block(ctx: CURStreamCtx, A_L, off):
    # selected rows of the panel → R[:, off:off+L]
    return jnp.take(A_L, ctx.row_idx, axis=0)  # (r, L)


def _cur_chunk_fold(ctx: CURStreamCtx, C, R, block, bcol0, start, width):
    """Fused-scan hook: the whole chunk's C/R writes in one pass.

    Fixed indices make every panel's factor write a pure copy of ``A``
    entries, so the per-panel loop is unnecessary: the selected columns
    falling inside ``[start, start+width)`` are gathered once into their C
    slots, and the selected rows' chunk stripe lands in ``R`` with one
    window write — bitwise the values the per-panel path copies.
    """
    rel = ctx.col_idx - start
    in_chunk = (rel >= 0) & (rel < width)
    picked = jnp.take(block, bcol0 + jnp.clip(rel, 0, width - 1), axis=1)
    C = jnp.where(in_chunk[None, :], picked.astype(C.dtype), C)
    stripe = jax.lax.dynamic_slice_in_dim(
        jnp.take(block, ctx.row_idx, axis=0), bcol0, width, axis=1
    )
    R = jax.lax.dynamic_update_slice_in_dim(
        R, stripe.astype(R.dtype), start, axis=1
    )
    return ctx, C, R


STREAMING_CUR_OPS = PanelOps(
    name="streaming_cur",
    core_sketches=_cur_core_sketches,
    update_c=_cur_update_c,
    r_block=_cur_r_block,
    chunk_fold=_cur_chunk_fold,
)

# Telemetered twin — same hooks plus the fixed-index diagnostics fold; one
# module-level instance so telemetered inits share jit caches.
STREAMING_CUR_TEL_OPS = dataclasses.replace(
    STREAMING_CUR_OPS, telemetry=fixed_stream_telemetry
)

# Streaming state: the generic engine state with ctx = CURStreamCtx
# (``state.S_C`` etc. resolve through to ctx for back-compat).
StreamingCURState = PanelState


def streaming_cur_init(
    key,
    m: int,
    n: int,
    col_idx: jax.Array,
    row_idx: jax.Array,
    *,
    s_c: Optional[int] = None,
    s_r: Optional[int] = None,
    eps: float = 0.05,
    rho_est: float = 2.0,
    sketch: str = "countsketch",
    osnap_p: int = 2,
    dtype=jnp.float32,
    sketches=None,
    panel: Optional[int] = None,
    telemetry: bool = False,
) -> StreamingCURState:
    """Draw column-sliceable core sketches and allocate zero accumulators.

    Args:
        key: PRNG key for the core sketches (ignored when ``sketches`` given).
        m, n: stream shape — ``A`` is (m, n), arriving as column panels.
        col_idx, row_idx: fixed pre-pass selections, (c,) / (r,) int32.
        s_c, s_r: core sketch sizes; default to the Table-2
            :func:`cur_sketch_sizes` for ``(c, r, eps, rho_est)``.
        eps, rho_est: Table-2 sketch-size parameters (ε target, ρ estimate).
        sketch: column-sliceable family (``countsketch``/``osnap``/``gaussian``).
        osnap_p: nonzeros per column for the OSNAP family.
        dtype: accumulator dtype.
        sketches: optional pre-drawn ``(S_C, S_R)`` pair (shared randomness
            with a one-shot :func:`repro.cur.fast_cur` for parity tests).
        panel: fixed streaming width — pre-pads ``R``/``S_R`` to a whole
            number of panels so ragged tails can be zero-padded (exact; see
            :mod:`repro.stream.engine`).
        telemetry: attach an in-scan diagnostics frame
            (:class:`repro.obs.telemetry.TelemetryFrame`) + the a-posteriori
            error estimator's test sketch (:func:`repro.obs.estimate_rel_error`).
            Requires ``panel=``; factors are bit-identical with it on or off.

    Returns:
        A fresh :class:`StreamingCURState` with zero (m,c)/(r,n_pad)/(s_c,s_r)
        accumulators, ready for :func:`streaming_cur_update` /
        :func:`repro.stream.stream_panels`.
    """
    # Copies, not views: the scan path donates the state's buffers, and a
    # zero-copy asarray would hand the caller's own arrays to the donor.
    col_idx = jnp.array(col_idx, jnp.int32)
    row_idx = jnp.array(row_idx, jnp.int32)
    c, r = col_idx.shape[0], row_idx.shape[0]
    if sketches is None:
        sizes = cur_sketch_sizes(c, r, eps=eps, rho=rho_est)
        s_c = min(s_c or sizes["s_c"], m)
        s_r = min(s_r or sizes["s_r"], n)
        k_sc, k_sr = jax.random.split(key)
        S_C = draw_sketch(k_sc, sketch, s_c, m, p=osnap_p, dtype=dtype)
        S_R = draw_sketch(k_sr, sketch, s_r, n, p=osnap_p, dtype=dtype)
    else:
        S_C, S_R = fresh_pytree(sketches)  # donation-safe copies
        s_c, s_r = S_C.s, S_R.s
    S_R.cols(0, 1)  # fail fast on non-sliceable families (srht)
    n_pad = padded_n(n, panel) if panel else n
    ctx = CURStreamCtx(col_idx=col_idx, row_idx=row_idx, S_C=S_C, S_R=S_R.pad_cols(n_pad))
    tel = None
    ops = STREAMING_CUR_OPS
    if telemetry:
        if panel is None:
            raise ValueError(
                "telemetry=True requires a fixed panel= width (the diagnostics "
                "frame is indexed by global panel id)"
            )
        # Held-out estimator sketch: fold a constant so the draw is disjoint
        # from the split(key) core-sketch draws but reproducible from one seed.
        tel = init_telemetry(jax.random.fold_in(key, 7), m, n, panel)
        ops = STREAMING_CUR_TEL_OPS
    return StreamingCURState(
        C=jnp.zeros((m, c), dtype),
        R=jnp.zeros((r, n_pad), dtype),
        M=jnp.zeros((s_c, s_r), dtype),
        offset=jnp.zeros((), jnp.int32),
        ctx=ctx,
        ops=ops,
        n=n,
        tel=tel,
    )


def streaming_cur_update(state: StreamingCURState, A_L: jax.Array) -> StreamingCURState:
    """Consume one (m, L) column panel ``A_L`` at the state's current offset.

    jit-compatible (L static per panel width); thin alias of the shared
    :func:`repro.stream.engine.panel_update`.
    """
    return panel_update(state, A_L)


def streaming_cur_finalize(state: StreamingCURState) -> CURResult:
    """Fast-GMR core solve on the accumulated pieces (Algorithm 1 step 11).

    Computes ``U = (S_C C)† M (R S_Rᵀ)†`` from the streamed (m,c)/(r,n)
    factors and the (s_c, s_r) core sketch ``M = S_C A S_Rᵀ``; returns a
    :class:`~repro.cur.cur.CURResult` matching one-shot
    :func:`repro.cur.fast_cur` on identical sketches up to fp32 summation
    order.
    """
    ctx = state.ctx
    R = truncated_R(state)
    ScC = ctx.S_C.apply(state.C)  # (s_c, c)
    RSr = ctx.S_R.apply_t(R)  # (r, s_r)
    U = fast_gmr_core(ScC, state.M, RSr)
    return CURResult(C=state.C, U=U, R=R, col_idx=ctx.col_idx, row_idx=ctx.row_idx)


# Compiled at module scope (one trace per shape); the state is NOT donated —
# callers inspect it after finalizing.
streaming_cur_finalize = jax.jit(streaming_cur_finalize)
