"""Single-pass streaming CUR over L-column panels.

Same streaming contract as ``repro.core.svd.sp_svd_update`` (Algorithm 3):
``A`` arrives as column panels ``A_L`` and is never retained. Per panel:

* ``C``: the panel's selected columns land in their slots (selected column
  j with ``offset ≤ col_idx[j] < offset+L`` is copied out of the panel);
* ``R[:, cols] = A_L[row_idx, :]`` — selected rows accumulate left→right;
* ``M += (S_C A_L) · S_R[:, cols]ᵀ`` via the ``cols()`` sketch-window
  primitive of ``repro.core.sketching`` (column-sliceable families only:
  gaussian / countsketch / osnap).

Memory: C (m·c) + R (r·n) + M (s_c·s_r) — the factors themselves plus a
constant-size core sketch; ``finalize`` then runs the Fast-GMR core solve.
Because ``Σ_L S_C A_L S_R[:,cols]ᵀ = S_C A S_Rᵀ`` exactly, the finalized
factors match one-shot :func:`repro.cur.fast_cur` on identical sketches up
to fp32 summation order (tested in ``tests/test_cur.py``).

Selection indices must be fixed before the pass (uniform, or scores from a
prior epoch / sketched estimate) — the single-pass constraint; adaptive
in-stream column addition is a ROADMAP open item.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.gmr import fast_gmr_core
from ..core.sketching import draw_sketch
from .cur import CURResult, cur_sketch_sizes

__all__ = ["StreamingCURState", "streaming_cur_init", "streaming_cur_update", "streaming_cur_finalize"]


@dataclasses.dataclass
class StreamingCURState:
    """Streaming accumulators + the shared sketching operators."""

    C: jax.Array  # (m, c) — filled as selected columns stream past
    R: jax.Array  # (r, n) — filled panel-by-panel
    M: jax.Array  # (s_c, s_r) — running S_C A S_Rᵀ
    offset: jax.Array  # columns consumed so far
    col_idx: jax.Array  # (c,)
    row_idx: jax.Array  # (r,)
    S_C: object  # column-sliceable sketch, (s_c, m)
    S_R: object  # column-sliceable sketch, (s_r, n)


jax.tree_util.register_dataclass(
    StreamingCURState,
    data_fields=["C", "R", "M", "offset", "col_idx", "row_idx", "S_C", "S_R"],
    meta_fields=[],
)


def streaming_cur_init(
    key,
    m: int,
    n: int,
    col_idx: jax.Array,
    row_idx: jax.Array,
    *,
    s_c: Optional[int] = None,
    s_r: Optional[int] = None,
    eps: float = 0.05,
    rho_est: float = 2.0,
    sketch: str = "countsketch",
    osnap_p: int = 2,
    dtype=jnp.float32,
    sketches=None,
) -> StreamingCURState:
    """Draw column-sliceable core sketches and allocate zero accumulators."""
    col_idx = jnp.asarray(col_idx, jnp.int32)
    row_idx = jnp.asarray(row_idx, jnp.int32)
    c, r = col_idx.shape[0], row_idx.shape[0]
    if sketches is None:
        sizes = cur_sketch_sizes(c, r, eps=eps, rho=rho_est)
        s_c = min(s_c or sizes["s_c"], m)
        s_r = min(s_r or sizes["s_r"], n)
        k_sc, k_sr = jax.random.split(key)
        S_C = draw_sketch(k_sc, sketch, s_c, m, p=osnap_p, dtype=dtype)
        S_R = draw_sketch(k_sr, sketch, s_r, n, p=osnap_p, dtype=dtype)
    else:
        S_C, S_R = sketches
        s_c, s_r = S_C.s, S_R.s
    S_R.cols(0, 1)  # fail fast on non-sliceable families (srht / sampling)
    return StreamingCURState(
        C=jnp.zeros((m, c), dtype),
        R=jnp.zeros((r, n), dtype),
        M=jnp.zeros((s_c, s_r), dtype),
        offset=jnp.zeros((), jnp.int32),
        col_idx=col_idx,
        row_idx=row_idx,
        S_C=S_C,
        S_R=S_R,
    )


def streaming_cur_update(state: StreamingCURState, A_L: jax.Array) -> StreamingCURState:
    """Consume one L-column panel. jit-compatible (L static per panel width)."""
    L = A_L.shape[1]
    off = state.offset

    # selected columns that live in this panel → their C slots
    rel = state.col_idx - off
    in_panel = (rel >= 0) & (rel < L)
    picked = jnp.take(A_L, jnp.clip(rel, 0, L - 1), axis=1)  # (m, c)
    C = jnp.where(in_panel[None, :], picked.astype(state.C.dtype), state.C)

    # selected rows of the panel → R[:, off:off+L]
    r_block = jnp.take(A_L, state.row_idx, axis=0).astype(state.R.dtype)  # (r, L)
    R = jax.lax.dynamic_update_slice_in_dim(state.R, r_block, off, axis=1)

    # M += (S_C A_L) · S_R[:, cols]ᵀ
    sc_a = state.S_C.apply(A_L)  # (s_c, L)
    M = state.M + state.S_R.cols(off, L).apply_t(sc_a).astype(state.M.dtype)

    return dataclasses.replace(state, C=C, R=R, M=M, offset=off + L)


def streaming_cur_finalize(state: StreamingCURState) -> CURResult:
    """Fast-GMR core solve on the accumulated pieces (Algorithm 1 step 11)."""
    ScC = state.S_C.apply(state.C)  # (s_c, c)
    RSr = state.S_R.apply_t(state.R)  # (r, s_r)
    U = fast_gmr_core(ScC, state.M, RSr)
    return CURResult(C=state.C, U=U, R=state.R, col_idx=state.col_idx, row_idx=state.row_idx)
