"""Column/row selection policies for CUR decomposition.

CUR quality is decided first by *which* columns/rows are kept, then by the
core matrix. Every policy sits behind one API:

    ``select_columns(key, A, c, policy)`` → :class:`Selection` (idx, probs)

Policies (Wang & Zhang 2015-style taxonomy):

* ``uniform``          — uniform sampling without replacement, O(1) per draw.
* ``leverage``         — exact rank-k *subspace* leverage scores
                         ``ℓ_j = ||V_k[j, :]||²`` from the top-k right
                         singular subspace (Drineas & Mahoney CUR; k
                         defaults to c — full-rank leverage of a square/tall
                         slice is uniform and useless).
* ``approx_leverage``  — the same scores from a row-sketched ``S·A``
                         (CountSketch, O(nnz(A)) + O(s²n) small SVD) — the
                         large-scale default, Drineas et al. 2012 style.
* ``pivoted_qr``       — deterministic greedy pivoted-QR baseline: repeatedly
                         pick the column with the largest residual norm and
                         deflate (Golub-Businger pivoting, O(m n c)).

``probs`` is the sampling distribution actually used (uniform vector for
``uniform``; None for the deterministic ``pivoted_qr``) so callers can feed
the same distribution into leverage-sampling core sketches (Table 2/3).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core.sketching import CountSketch

__all__ = ["Selection", "SELECTION_POLICIES", "select_columns", "select_rows"]

SELECTION_POLICIES = ("uniform", "leverage", "approx_leverage", "pivoted_qr")


class Selection(NamedTuple):
    """Chosen indices plus the sampling distribution that produced them."""

    idx: jax.Array  # (c,) int32 indices into the selected axis
    probs: Optional[jax.Array]  # (n,) distribution used, or None (deterministic)


def _pivoted_qr_idx(A: jax.Array, c: int) -> jax.Array:
    """Greedy column-pivoted QR: argmax residual column norm, Gram-Schmidt deflate."""
    dt = jnp.promote_types(A.dtype, jnp.float32)
    res = A.astype(dt)
    taken = jnp.zeros((A.shape[1],), bool)
    picked = []
    for _ in range(c):
        # mask already-picked columns: deflation leaves fp-noise residuals
        # that argmax could otherwise re-select past the numerical rank
        norms = jnp.where(taken, -jnp.inf, jnp.sum(res * res, axis=0))
        j = jnp.argmax(norms)
        picked.append(j)
        taken = taken.at[j].set(True)
        q = res[:, j] / jnp.maximum(jnp.sqrt(norms[j]), jnp.finfo(dt).tiny)
        res = res - q[:, None] * (q @ res)[None, :]
    return jnp.stack(picked).astype(jnp.int32)


def _subspace_leverage(Vt: jax.Array, k: int) -> jax.Array:
    """Column scores ``ℓ_j = ||V_k[j, :]||²`` given rows-of-Vᵀ; sums to ≤ k."""
    return jnp.sum(Vt[:k] * Vt[:k], axis=0)


def select_columns(
    key,
    A: jax.Array,
    c: int,
    policy: str = "uniform",
    *,
    k: Optional[int] = None,
    probs: Optional[jax.Array] = None,
) -> Selection:
    """Pick ``c`` column indices of ``A`` under the given policy.

    ``k`` is the target subspace rank for the leverage policies (default
    ``c``). ``probs`` overrides the policy's distribution entirely (e.g.
    precomputed scores for the streaming path, where ``A`` is never
    materialized).
    """
    m, n = A.shape
    if not 0 < c <= n:
        raise ValueError(f"need 0 < c <= n, got c={c}, n={n}")
    if policy == "pivoted_qr":
        return Selection(idx=_pivoted_qr_idx(A, c), probs=None)

    if probs is None:
        k = min(k or c, m, n)
        dt = jnp.promote_types(A.dtype, jnp.float32)
        if policy == "uniform":
            probs = jnp.full((n,), 1.0 / n, jnp.float32)
        elif policy == "leverage":
            Vt = jnp.linalg.svd(A.astype(dt), full_matrices=False)[2]
            lev = _subspace_leverage(Vt, k)
            probs = lev / jnp.sum(lev)
        elif policy == "approx_leverage":
            key, sub = jax.random.split(key)
            s = min(m, max(4 * k, k + 8))
            S = CountSketch.draw(sub, s, m, dtype=A.dtype)
            Vt = jnp.linalg.svd(S.apply(A).astype(dt), full_matrices=False)[2]
            lev = _subspace_leverage(Vt, k)
            probs = lev / jnp.sum(lev)
        else:
            raise ValueError(f"unknown policy {policy!r}; expected one of {SELECTION_POLICIES}")
    else:
        probs = probs / jnp.sum(probs)
    idx = jax.random.choice(key, n, (c,), replace=False, p=probs).astype(jnp.int32)
    return Selection(idx=idx, probs=probs)


def select_rows(
    key,
    A: jax.Array,
    r: int,
    policy: str = "uniform",
    *,
    k: Optional[int] = None,
    probs: Optional[jax.Array] = None,
) -> Selection:
    """Pick ``r`` row indices of ``A`` — :func:`select_columns` on ``Aᵀ``."""
    return select_columns(key, A.T, r, policy, k=k, probs=probs)
