"""Batched CUR for serving: many small matrices per request.

The serving shape (kernel blocks per user, per-head KV panels, per-shard
gradient blocks) is a stack ``A (B, m, n)`` of small matrices that must be
decomposed inside one device dispatch. Two choices make this
vmap/jit-friendly and fast:

* **Shared core sketches** ``S_C (s_c×m)``, ``S_R (s_r×n)`` across the
  batch (dense Gaussian): amortizes the draw, keeps every batch element on
  the same compute graph, and turns the hot spot ``M_b = S_C A_b S_Rᵀ``
  into a batched fused product routed through the
  ``repro.kernels.ops.twoside_sketch`` Pallas kernel (one HBM pass over
  each ``A_b``; `jax.vmap` lifts the kernel grid over the batch).
* **Per-item selection** via `vmap` over folded keys — independent across
  users. ``selection="uniform"`` stays O(1) per draw;
  ``selection="approx_leverage"`` vmaps the sketched-leverage policy of
  :mod:`repro.cur.selection` (CountSketch → small SVD → subspace leverage
  scores → weighted sampling without replacement) over the batch, per item
  for both columns and rows — the quality policy at serving shapes, still
  one device dispatch.

``batched_fast_cur(...)`` ≡ a python loop of :func:`repro.cur.fast_cur`
with the same shared sketches and per-item indices (tested), but executes
as a single jittable program.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.gmr import fast_gmr_core
from ..core.sketching import GaussianSketch
from ..kernels.ops import twoside_sketch
from .cur import CURResult, cur_sketch_sizes
from .selection import select_columns, select_rows

__all__ = ["batched_fast_cur", "draw_shared_sketches"]


def draw_shared_sketches(
    key, m: int, n: int, s_c: int, s_r: int, dtype=jnp.float32
) -> Tuple[GaussianSketch, GaussianSketch]:
    """One Gaussian (S_C, S_R) pair shared by every matrix in the batch."""
    k_sc, k_sr = jax.random.split(key)
    return (
        GaussianSketch.draw(k_sc, s_c, m, dtype),
        GaussianSketch.draw(k_sr, s_r, n, dtype),
    )


def batched_fast_cur(
    key,
    A: jax.Array,
    c: int,
    r: int,
    *,
    s_c: Optional[int] = None,
    s_r: Optional[int] = None,
    eps: float = 0.05,
    rho_est: float = 2.0,
    sketches: Optional[Tuple[GaussianSketch, GaussianSketch]] = None,
    use_kernel: Optional[bool] = None,
    selection: str = "uniform",
    k: Optional[int] = None,
) -> CURResult:
    """Fast CUR of a stack ``A (B, m, n)`` in one dispatch.

    Returns a :class:`CURResult` whose arrays carry a leading batch dim.
    ``use_kernel=None`` routes the fused ``S_C A S_Rᵀ`` product through the
    Pallas kernel on TPU and through XLA einsum elsewhere (on CPU the
    kernel would run in slow interpret mode; on GPU the Mosaic kernel
    cannot lower at all).

    ``selection`` picks the per-item index policy: ``"uniform"`` (O(1)
    draws) or ``"approx_leverage"`` — the sketched rank-``k`` leverage
    policy of :func:`repro.cur.selection.select_columns`, vmapped over the
    batch with per-item folded keys for both the column and the row draw
    (``k`` defaults to the budget, as in the one-shot policy). Identical to
    a python loop of the one-shot policy per item (same keys ⇒ same
    indices), but batched into the single dispatch.
    """
    if A.ndim != 3:
        raise ValueError(f"expected A of shape (B, m, n), got {A.shape}")
    if selection not in ("uniform", "approx_leverage"):
        raise ValueError(
            f"selection must be 'uniform' or 'approx_leverage', got {selection!r}"
        )
    B, m, n = A.shape
    use_kernel = (jax.default_backend() == "tpu") if use_kernel is None else use_kernel

    k_sel, k_skt = jax.random.split(key)
    if sketches is None:
        sizes = cur_sketch_sizes(c, r, eps=eps, rho=rho_est)
        s_c = min(s_c or sizes["s_c"], m)
        s_r = min(s_r or sizes["s_r"], n)
        sketches = draw_shared_sketches(k_skt, m, n, s_c, s_r, dtype=A.dtype)
    S_C, S_R = sketches

    sel_keys = jax.random.split(k_sel, B)

    if selection == "uniform":

        def pick(kk, a):
            k_c, k_r = jax.random.split(kk)
            ci = jax.random.choice(k_c, n, (c,), replace=False).astype(jnp.int32)
            ri = jax.random.choice(k_r, m, (r,), replace=False).astype(jnp.int32)
            return ci, ri

    else:  # per-item sketched-leverage (ROADMAP open item)

        def pick(kk, a):
            k_c, k_r = jax.random.split(kk)
            ci = select_columns(k_c, a, c, "approx_leverage", k=k).idx
            ri = select_rows(k_r, a, r, "approx_leverage", k=k).idx
            return ci, ri

    col_idx, row_idx = jax.vmap(pick)(sel_keys, A)  # (B, c), (B, r)

    C = jax.vmap(lambda a, ci: jnp.take(a, ci, axis=1))(A, col_idx)  # (B, m, c)
    R = jax.vmap(lambda a, ri: jnp.take(a, ri, axis=0))(A, row_idx)  # (B, r, n)

    # hot spot: M_b = S_C A_b S_Rᵀ — fused Pallas kernel or one einsum
    if use_kernel:
        M = jax.vmap(lambda a: twoside_sketch(S_C.mat, a, S_R.mat.T))(A)
        M = M.astype(A.dtype)
    else:
        M = jnp.einsum("sm,bmn,tn->bst", S_C.mat, A, S_R.mat)

    ScC = jnp.einsum("sm,bmc->bsc", S_C.mat, C)  # S_C C per item
    RSr = jnp.einsum("brn,tn->brt", R, S_R.mat)  # R S_Rᵀ per item
    U = jax.vmap(fast_gmr_core)(ScC, M, RSr)  # (B, c, r)
    return CURResult(C=C, U=U, R=R, col_idx=col_idx, row_idx=row_idx)
