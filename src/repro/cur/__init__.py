"""CUR decomposition on top of Fast GMR (paper §1's first application).

Layered subsystem:

* :mod:`repro.cur.selection` — which columns/rows to keep
  (uniform / leverage / sketched-leverage / pivoted-QR policies).
* :mod:`repro.cur.cur`       — :func:`exact_cur` oracle and the Algorithm-1
  :func:`fast_cur` with Table-2 sketch-size defaults + ρ-branch selection.
* :mod:`repro.cur.streaming` — single-pass CUR over L-column panels (the
  shared :mod:`repro.stream` engine contract) for matrices that never fit
  in memory; adaptive in-stream column admission/eviction, adaptive row
  admission and DP-sharded ingestion live in :mod:`repro.stream`
  (re-exported here).
* :mod:`repro.cur.batched`   — vmapped CUR of matrix stacks for serving,
  fused-Pallas-kernel core product.
* :mod:`repro.cur.symmetric_cur` — symmetric CUR for SPSD matrices
  (``R = Cᵀ`` tied): every selection policy above drives the sampled index
  set, the core is Algorithm 2's sketched solve + PSD projection
  (delegated to :mod:`repro.spsd`), and results keep the
  ``spsd_error_ratio`` contract. Streaming variant in
  :mod:`repro.spsd.streaming`.
"""

from .selection import SELECTION_POLICIES, Selection, select_columns, select_rows
from .cur import (
    CURResult,
    cur_error_ratio,
    cur_reconstruct,
    cur_relative_error,
    cur_sketch_sizes,
    exact_cur,
    fast_cur,
)
from .streaming import (
    StreamingCURState,
    streaming_cur_finalize,
    streaming_cur_init,
    streaming_cur_update,
)
from .batched import batched_fast_cur, draw_shared_sketches
from .symmetric_cur import spsd_to_cur, symmetric_cur
from ..stream.adaptive import adaptive_cur_finalize, adaptive_cur_init

__all__ = [
    "SELECTION_POLICIES", "Selection", "select_columns", "select_rows",
    "CURResult", "cur_error_ratio", "cur_reconstruct", "cur_relative_error",
    "cur_sketch_sizes", "exact_cur", "fast_cur",
    "StreamingCURState", "streaming_cur_finalize", "streaming_cur_init", "streaming_cur_update",
    "adaptive_cur_finalize", "adaptive_cur_init",
    "batched_fast_cur", "draw_shared_sketches",
    "symmetric_cur", "spsd_to_cur",
]
