"""Resilient streaming ingestion: resumable PanelState, fault injection,
graceful degradation.

The single-pass setting is exactly where failures hurt most: panels are
never retained, so a crash at panel k of a long stream loses the entire
ingest — yet the carried :class:`~repro.stream.engine.PanelState` (C/R/M +
adaptive ctx + telemetry) is only O(sketch-size), i.e. cheap to
checkpoint, and the factors can be maintained and finalized from that
state alone without a second pass (Tropp et al.'s practical-sketching
argument, PAPERS.md). This module owns the fault story in three layers:

* **Resumable streams** — :func:`run_resilient_stream` consumes panels
  from a :class:`PanelSource` in fixed chunks through the engine's scan
  entry point, checkpoints the full state every ``ckpt_every`` chunks
  through :mod:`repro.checkpoint` (atomic tmp+rename writes, torn
  checkpoints skipped on restore) with a ``panels_consumed`` cursor in the
  manifest, and on restart replays *only unconsumed panels*. Because the
  per-panel math is a pure fold over the chunk sequence, a restored run is
  **bitwise-equal** to an uninterrupted run at the same chunk cadence
  (``tests/test_resilient.py`` asserts this for fixed/adaptive CUR, SPSD
  and both drivers). Restores honor the engine's donation contract: a
  restored state is freshly materialized from disk, never a donated
  buffer.
* **Panel-level fault injection** — a deterministic :class:`FaultPlan`
  (crash-at-panel, NaN/Inf corruption, dropped / duplicated delivery,
  straggler delay) applied by :class:`FaultInjector` at the source
  boundary, so the driver's retry / dedup / restart handling is exercised
  by tests and the ``make chaos-check`` lane without touching the engine.
* **Graceful degradation** — :func:`repro.stream.engine.with_quarantine`
  arms the in-scan non-finite guard: a corrupt panel contributes exactly
  what an all-zero panel would, the state counts it, telemetry flags the
  panel with ``EVENT_QUARANTINED``, and the host driver mirrors the count
  into :mod:`repro.obs.metrics`. ``strict=True`` instead rolls the state
  back to the last checkpoint and raises :class:`QuarantineAbort`.

Distributed resume: :func:`run_resilient_sharded_stream` gives every
worker of a :func:`~repro.stream.distributed.simulate_sharded_stream` /
``mesh_sharded_stream``-style partition its own checkpoint directory, so a
single worker crash restores that worker's panel range and re-merges —
exact parity with the all-healthy run (asserted at 2 and 4 workers,
including against ``mesh_sharded_stream``).

Checkpoint cadence trades write cost against replay cost — see
``docs/resilience.md`` for the tradeoff and a worker-crash walkthrough.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, List, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp

from ..checkpoint.checkpoint import latest_step, restore, save
from ..obs.metrics import default_registry
from ..obs.spans import span
from . import engine
from .distributed import merge_states, shard_panel_ranges
from .engine import PanelState, fresh_pytree, padded_n, with_quarantine

__all__ = [
    "PanelSource",
    "ArrayPanelSource",
    "FaultPlan",
    "FaultInjector",
    "TransientReadError",
    "InjectedCrash",
    "QuarantineAbort",
    "StreamReport",
    "save_stream_state",
    "restore_stream_state",
    "run_resilient_stream",
    "run_resilient_sharded_stream",
]


class TransientReadError(RuntimeError):
    """A chunk read failed in a retryable way (dropped delivery)."""


class InjectedCrash(RuntimeError):
    """Deterministic process-death stand-in raised *before* the chunk
    containing ``FaultPlan.crash_at_panel`` is consumed."""


class QuarantineAbort(RuntimeError):
    """Strict-mode abort: a non-finite panel was detected and the stream
    state was rolled back to the last checkpoint.

    ``state`` is the rolled-back (fresh, never-donated) state and
    ``panels_consumed`` its cursor — re-invoke ``run_resilient_stream``
    with them once the source is repaired."""

    def __init__(self, msg: str, *, state: PanelState, panels_consumed: int):
        super().__init__(msg)
        self.state = state
        self.panels_consumed = panels_consumed


class PanelSource(Protocol):
    """Pull-model panel stream: idempotent, addressable chunk reads.

    ``read_chunk(lo_panel, num_panels)`` returns ``(tag, chunk)`` where
    ``chunk`` is the ``num_panels · panel`` column block starting at panel
    ``lo_panel`` (zero-padded past the true column count ``n``) and ``tag``
    identifies which panel the delivery actually starts at — the driver
    re-requests on a stale tag (duplicated delivery). Reads must be
    idempotent: replay after restore re-reads the same panels.
    """

    panel: int
    n: int
    num_panels: int

    def read_chunk(self, lo_panel: int, num_panels: int) -> Tuple[int, jax.Array]:
        """Return ``(tag, chunk)`` for the panel window (see class docs)."""
        ...


class ArrayPanelSource:
    """In-memory :class:`PanelSource` over a materialized operand ``A``
    (what the tests, benchmarks and the chaos lane stream from)."""

    def __init__(self, A: jax.Array, panel: int, *, n: Optional[int] = None):
        self.A = jnp.asarray(A)
        self.panel = panel
        self.n = self.A.shape[1] if n is None else n
        self.num_panels = padded_n(self.n, panel) // panel

    def read_chunk(self, lo_panel: int, num_panels: int) -> Tuple[int, jax.Array]:
        """Slice the window out of ``A``, zero-padding past column ``n``."""
        start = lo_panel * self.panel
        stop = min(start + num_panels * self.panel, self.n)
        chunk = self.A[:, start:stop]
        want = num_panels * self.panel
        if chunk.shape[1] < want:
            chunk = jnp.pad(chunk, ((0, 0), (0, want - chunk.shape[1])))
        return lo_panel, chunk


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic per-panel fault schedule (all panel ids are global).

    One-shot faults (crash, drop, duplicate, straggle) fire on the first
    read that covers the panel and never again — replay after a restart
    sees a healthy source, exactly like a real transient. ``corrupt_panels``
    is *persistent*: every read of those panels returns NaN data, so the
    quarantine guard's outcome is identical on replay.
    """

    crash_at_panel: Optional[int] = None  # raise InjectedCrash before consuming it
    corrupt_panels: Tuple[int, ...] = ()  # NaN-fill these panels (persistent)
    drop_panels: Tuple[int, ...] = ()  # first read covering it raises (one-shot)
    duplicate_panels: Tuple[int, ...] = ()  # first read re-delivers the previous chunk
    straggler_panels: Tuple[int, ...] = ()  # first read sleeps straggler_delay_s
    straggler_delay_s: float = 0.01


class FaultInjector:
    """Wrap a :class:`PanelSource` with a :class:`FaultPlan`.

    Faults fire at the read boundary — the engine and driver under test are
    unmodified production code. The injector is stateful (one-shot flags,
    last-delivery buffer for duplicates) and is deliberately *shared* across
    restarts within a process so a replayed read sees the post-fault
    source.
    """

    def __init__(self, source: PanelSource, plan: FaultPlan):
        self.source = source
        self.plan = plan
        self.panel = source.panel
        self.n = source.n
        self.num_panels = source.num_panels
        self._crashed = False
        self._dropped: set = set()
        self._duplicated: set = set()
        self._delayed: set = set()
        self._last: Optional[Tuple[int, jax.Array]] = None

    def read_chunk(self, lo_panel: int, num_panels: int) -> Tuple[int, jax.Array]:
        """Delegate to the wrapped source, firing any scheduled faults
        whose panel falls inside the requested window."""
        covered = range(lo_panel, lo_panel + num_panels)
        plan = self.plan
        if (
            plan.crash_at_panel is not None
            and plan.crash_at_panel in covered
            and not self._crashed
        ):
            self._crashed = True
            raise InjectedCrash(
                f"injected crash before consuming panel {plan.crash_at_panel}"
            )
        for t in plan.drop_panels:
            if t in covered and t not in self._dropped:
                self._dropped.add(t)
                raise TransientReadError(f"injected drop of panel {t}")
        for t in plan.straggler_panels:
            if t in covered and t not in self._delayed:
                self._delayed.add(t)
                time.sleep(plan.straggler_delay_s)
        for t in plan.duplicate_panels:
            if t in covered and t not in self._duplicated and self._last is not None:
                self._duplicated.add(t)
                return self._last  # stale tag — driver detects and re-requests
        tag, chunk = self.source.read_chunk(lo_panel, num_panels)
        for t in plan.corrupt_panels:
            if t in covered:
                rel = (t - lo_panel) * self.panel
                chunk = chunk.at[:, rel : rel + self.panel].set(jnp.nan)
        self._last = (tag, chunk)
        return tag, chunk


@dataclasses.dataclass
class StreamReport:
    """Host-side outcome of one resilient drive (per worker when sharded)."""

    chunks: int = 0  # chunks consumed (including replayed ones)
    panels_consumed: int = 0  # absolute cursor after the drive
    retries: int = 0  # dropped/duplicated deliveries re-requested
    restarts: int = 0  # in-process restore-and-replay cycles
    checkpoints: int = 0  # checkpoints written
    quarantined: Optional[int] = None  # final in-scan quarantine count (if armed)
    resumed_from: Optional[int] = None  # cursor restored at entry (cross-invocation)


def save_stream_state(
    directory: str,
    state: PanelState,
    panels_consumed: int,
    *,
    keep_last: int = 3,
    extra: Optional[dict] = None,
    durable: bool = True,
    async_: bool = False,
):
    """Checkpoint a :class:`PanelState` with its ``panels_consumed`` cursor.

    The step id *is* the cursor, so ``latest_step`` is "most panels
    consumed" and replay-from-latest is minimal. ``ops``/``n`` are static
    metadata and live in the restore template, not on disk. A PanelState
    is O(sketch size), so the **packed** single-file checkpoint layout is
    used — one write + one rename per save instead of one file per leaf.
    ``async_=True`` snapshots to host synchronously (donation safety) and
    writes on a worker thread, returning the Thread; ``durable=False``
    skips the fsync (process-crash atomicity only)."""
    meta = {
        "panels_consumed": int(panels_consumed),
        "stream": state.ops.name,
        **(extra or {}),
    }
    return save(
        directory, int(panels_consumed), state, extra=meta, keep_last=keep_last,
        durable=durable, async_=async_, pack=True,
    )




def restore_stream_state(directory: str, template: PanelState, *, step=None):
    """Restore ``(state, panels_consumed, extra)`` from the newest intact
    checkpoint.

    ``template`` supplies the pytree structure and the static ``ops``/``n``
    metadata (its array values are ignored); the returned state is freshly
    materialized from disk — never a donated buffer — so it can go straight
    back into the donating scan path."""
    tree, extra, step = restore(directory, template, step=step)
    return tree, int(extra.get("panels_consumed", step)), extra


def _read_with_retry(source, lo_panel, num, *, max_retries, backoff_s, report, reg):
    """One chunk read with bounded retry: transient errors back off
    exponentially, stale tags (duplicated delivery) re-request immediately."""
    for attempt in range(max_retries + 1):
        try:
            tag, chunk = source.read_chunk(lo_panel, num)
        except TransientReadError:
            if attempt >= max_retries:
                raise
            report.retries += 1
            reg.inc("stream/resilient/retries")
            if backoff_s:
                time.sleep(backoff_s * (2**attempt))
            continue
        if tag != lo_panel:
            if attempt >= max_retries:
                raise TransientReadError(
                    f"chunk at panel {lo_panel} kept arriving with stale tag {tag}"
                )
            report.retries += 1
            reg.inc("stream/resilient/retries")
            continue
        return chunk
    raise TransientReadError(f"chunk at panel {lo_panel} failed after retries")


def run_resilient_stream(
    state: PanelState,
    source: PanelSource,
    *,
    chunk_panels: int = 4,
    start_panel: Optional[int] = None,
    stop_panel: Optional[int] = None,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 2,
    keep_last: int = 3,
    max_retries: int = 3,
    backoff_s: float = 0.0,
    max_restarts: int = 0,
    strict: bool = False,
    quarantine: bool = False,
    durable: bool = False,
    resume: bool = True,
) -> Tuple[PanelState, StreamReport]:
    """Drive panels ``[start_panel, stop_panel)`` of ``source`` through the
    engine with checkpoint/retry/restart handling.

    Chunks of ``chunk_panels`` panels run through the engine's donating
    scan program (:func:`repro.stream.engine.scan_chunk`); the input
    ``state`` is *consumed* per the engine contract — keep only the
    returned state. Factor bits depend only on the panel sequence, so two
    drives over the same source at the same ``chunk_panels`` produce
    bitwise-identical factors regardless of how many crash/restore cycles
    either suffered (the Ψ estimator folds once per chunk, hence the "same
    cadence" clause).

    * ``ckpt_dir`` — enables checkpointing every ``ckpt_every`` chunks plus
      once at completion, and **resume**: if the directory already holds an
      intact checkpoint, the drive restores it and replays only unconsumed
      panels (the passed ``state`` then only serves as the restore
      template).
    * ``max_restarts`` — in-process restore-and-replay budget for
      :class:`InjectedCrash`; beyond it (or without a budget) the crash
      propagates and a later invocation resumes from ``ckpt_dir``.
    * ``resume=False`` treats ``ckpt_dir`` as write-only: checkpoints left
      by an earlier drive are ignored (and overwritten in place), and
      in-process restarts/rollbacks only ever restore checkpoints written
      by *this* drive. Use it to re-run a fresh drive into the same
      directory — repeated benchmark drives would otherwise resume the
      previous run's final checkpoint and no-op.
    * ``quarantine`` — arm the in-scan non-finite guard
      (:func:`~repro.stream.engine.with_quarantine`); with ``strict=True``
      a quarantined panel instead rolls back to the last checkpoint and
      raises :class:`QuarantineAbort`.
    * Checkpoints use the packed single-file layout (one write + one
      rename per save — a PanelState is only O(sketch size), so the
      per-leaf directory layout's syscall count would dominate at stream
      cadence). ``durable`` defaults to False because the subsystem's
      fault model is process death (``InjectedCrash``), where the rename
      commit alone is atomic; pass True to also survive power loss at the
      price of an fsync per save. The ``+ckpt8`` rows of
      ``benchmarks/stream_bench.py`` gate the cadence-8 overhead at ≤1.1×.
    """
    panel = source.panel
    if quarantine or strict:
        state = with_quarantine(state)
    start = int(state.offset) // panel if start_panel is None else start_panel
    stop = source.num_panels if stop_panel is None else stop_panel
    report = StreamReport()
    reg = default_registry()
    # pristine copy for scratch restarts / rollbacks and as restore template
    # (restore only reads leaf shape/dtype, never the — possibly donated —
    # buffers, but scratch restart needs live buffers of its own)
    state0 = fresh_pytree(state)
    cursor = start
    last_saved: Optional[int] = None  # newest step written by THIS drive
    if resume and ckpt_dir is not None and latest_step(ckpt_dir) is not None:
        state, cursor, _ = restore_stream_state(ckpt_dir, state0)
        report.resumed_from = cursor
        last_saved = cursor

    def _rollback_step() -> Optional[int]:
        """The step a restart/rollback may restore: newest on disk when
        resuming, else only what this drive has written."""
        if ckpt_dir is None:
            return None
        return latest_step(ckpt_dir) if resume else last_saved

    armed = state.quarantined is not None
    q_seen = int(state.quarantined) if armed else 0
    chunks_since_ckpt = 0
    with span(f"stream/{state.ops.name}/resilient"):
        while cursor < stop:
            num = min(chunk_panels, stop - cursor)
            try:
                chunk = _read_with_retry(
                    source,
                    cursor,
                    num,
                    max_retries=max_retries,
                    backoff_s=backoff_s,
                    report=report,
                    reg=reg,
                )
                state = engine._scan_stream_chunk(state, chunk, panel=panel)
            except InjectedCrash:
                if report.restarts >= max_restarts:
                    raise
                report.restarts += 1
                reg.inc("stream/resilient/restarts")
                step = _rollback_step()
                if step is not None:
                    state, cursor, _ = restore_stream_state(ckpt_dir, state0, step=step)
                else:
                    state, cursor = fresh_pytree(state0), start
                q_seen = int(state.quarantined) if armed else 0
                chunks_since_ckpt = 0
                continue
            report.chunks += 1
            if armed:
                q_now = int(state.quarantined)
                if q_now > q_seen:
                    reg.inc("stream/resilient/quarantined", q_now - q_seen)
                    if strict:
                        step = _rollback_step()
                        if step is not None:
                            st, cur, _ = restore_stream_state(
                                ckpt_dir, state0, step=step
                            )
                        else:
                            st, cur = fresh_pytree(state0), start
                        raise QuarantineAbort(
                            f"non-finite panel in chunk [{cursor}, {cursor + num}); "
                            f"state rolled back to panel {cur}",
                            state=st,
                            panels_consumed=cur,
                        )
                q_seen = q_now
            cursor += num
            chunks_since_ckpt += 1
            if ckpt_dir is not None and (
                chunks_since_ckpt >= ckpt_every or cursor >= stop
            ):
                save_stream_state(
                    ckpt_dir, state, cursor, keep_last=keep_last, durable=durable
                )
                last_saved = cursor
                report.checkpoints += 1
                reg.inc("stream/resilient/checkpoints")
                chunks_since_ckpt = 0
    report.panels_consumed = cursor
    if armed:
        report.quarantined = q_seen
    return state, report


def run_resilient_sharded_stream(
    state0: PanelState,
    source: PanelSource,
    num_workers: int,
    *,
    ckpt_dir: Optional[str] = None,
    chunk_panels: int = 4,
    ckpt_every: int = 2,
    keep_last: int = 3,
    max_retries: int = 3,
    backoff_s: float = 0.0,
    max_restarts: int = 0,
    strict: bool = False,
    quarantine: bool = False,
    durable: bool = False,
    resume: bool = True,
) -> Tuple[PanelState, List[StreamReport]]:
    """Resilient counterpart of
    :func:`~repro.stream.distributed.simulate_sharded_stream`: every worker
    drives its contiguous panel-aligned range through
    :func:`run_resilient_stream` with its **own** checkpoint directory
    (``<ckpt_dir>/worker_<w>``), then the worker states merge exactly as
    the healthy path does (:func:`~repro.stream.distributed.merge_states`).

    A crash in one worker therefore loses at most that worker's
    panels-since-checkpoint: re-invoking with the same ``ckpt_dir`` resumes
    every completed worker from its final checkpoint (replaying nothing),
    restores the crashed worker's range, and re-merges — bitwise parity
    with the all-healthy run, including against ``mesh_sharded_stream``
    (``tests/test_resilient.py`` asserts both at 2/4 workers).

    ``state0`` must be fresh (offset 0) and is used purely as a template —
    each worker streams a deep copy, so ``state0`` survives a crashed
    invocation and can be passed again to resume.
    """
    if int(state0.offset) != 0:
        raise ValueError(
            "run_resilient_sharded_stream needs a fresh state: every worker "
            f"clones state0's accumulators (offset={int(state0.offset)})"
        )
    panel = source.panel
    if quarantine or strict:
        state0 = with_quarantine(state0)
    ops = state0.ops
    ranges = shard_panel_ranges(source.n, panel, num_workers)
    ctx0 = state0.ctx
    if ops.prep_shard is not None:
        ctx0 = ops.prep_shard(ctx0, num_workers)
    state0 = dataclasses.replace(state0, ctx=ctx0)
    shards: List[PanelState] = []
    reports: List[StreamReport] = []
    for w, (lo, hi) in enumerate(ranges):
        ctx = ctx0
        if ops.bind_shard is not None:
            ctx = ops.bind_shard(ctx, jnp.asarray(w, jnp.int32))
        st = fresh_pytree(
            dataclasses.replace(state0, ctx=ctx, offset=jnp.asarray(lo, jnp.int32))
        )
        lo_p = lo // panel
        hi_p = lo_p + padded_n(hi - lo, panel) // panel
        wdir = os.path.join(ckpt_dir, f"worker_{w:02d}") if ckpt_dir else None
        st, rep = run_resilient_stream(
            st,
            source,
            chunk_panels=chunk_panels,
            start_panel=lo_p,
            stop_panel=hi_p,
            ckpt_dir=wdir,
            ckpt_every=ckpt_every,
            keep_last=keep_last,
            max_retries=max_retries,
            backoff_s=backoff_s,
            max_restarts=max_restarts,
            strict=strict,
            quarantine=quarantine,
            durable=durable,
            resume=resume,
        )
        shards.append(st)
        reports.append(rep)
    return merge_states(shards), reports
