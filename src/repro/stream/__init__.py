"""Unified panel-streaming subsystem.

One engine (:mod:`~repro.stream.engine`) owns the per-panel accumulator
contract shared by the paper's streaming applications — single-pass SVD
(Algorithm 3, :mod:`repro.core.svd`), streaming CUR
(:mod:`repro.cur.streaming`), and single-pass SPSD approximation
(Algorithm 2, :mod:`repro.spsd.streaming`, via the **symmetric
tied-operand mode**: ``PanelOps(symmetric=True)`` skips the R half and
derives ``R = Cᵀ``) — which plug in as :class:`PanelOps`. On top:

* :mod:`~repro.stream.distributed` — DP-sharded ingestion: bit-identical
  sketches per shared seed + disjoint panel ranges + psum/merge finalize
  reproduce the single-host factors exactly (fp32 summation order aside).
* :mod:`~repro.stream.adaptive` — residual-driven streaming CUR v2: column
  admission **and eviction** (``swap_gain`` replacement of the weakest
  admitted slot) plus in-stream row admission with sketched prefix
  backfill, all scored from the sketches alone — fused per panel through
  the engine's ``sketch_panel`` hook (Pallas ``panel_score`` kernel on
  TPU).
* :mod:`~repro.stream.resilient` — fault-tolerant ingestion: resumable
  checkpointed drives with a ``panels_consumed`` cursor
  (``run_resilient_stream``), deterministic panel-level fault injection
  (``FaultPlan``), in-scan quarantine of non-finite panels
  (``with_quarantine``), and per-worker checkpointed sharded resume
  (``run_resilient_sharded_stream``) — see ``docs/resilience.md``.

The hot path is scan-compiled: :func:`stream_panels` runs each chunk as one
``lax.scan`` program with donated state buffers (input states are
*consumed*), and the sharded drivers run as single fused programs — see
``docs/streaming.md`` §7.

See ``docs/streaming.md`` for the architecture guide and
``docs/paper_map.md`` for the paper-equation → code map.
"""

from .engine import (
    PanelOps,
    PanelState,
    copy_selected_columns,
    fresh_pytree,
    jitted_panel_update,
    padded_n,
    panel_update,
    scan_chunk,
    scan_panels,
    stream_panels,
    truncated_R,
    with_quarantine,
    zero_nonfinite_panels,
)
from .distributed import (
    merge_states,
    mesh_sharded_stream,
    shard_panel_ranges,
    simulate_sharded_stream,
)
from .adaptive import (
    ADAPTIVE_CUR_OPS,
    AdaptiveCURCtx,
    AdaptiveRowState,
    adaptive_cur_finalize,
    adaptive_cur_init,
    allocate_shared_budget,
)
from .resilient import (
    ArrayPanelSource,
    FaultInjector,
    FaultPlan,
    InjectedCrash,
    PanelSource,
    QuarantineAbort,
    StreamReport,
    TransientReadError,
    restore_stream_state,
    run_resilient_sharded_stream,
    run_resilient_stream,
    save_stream_state,
)

__all__ = [
    "PanelOps", "PanelState", "panel_update", "jitted_panel_update",
    "stream_panels", "scan_chunk", "scan_panels", "fresh_pytree",
    "padded_n", "copy_selected_columns", "truncated_R",
    "with_quarantine", "zero_nonfinite_panels",
    "merge_states", "mesh_sharded_stream", "shard_panel_ranges", "simulate_sharded_stream",
    "ADAPTIVE_CUR_OPS", "AdaptiveCURCtx", "AdaptiveRowState",
    "adaptive_cur_finalize", "adaptive_cur_init", "allocate_shared_budget",
    "ArrayPanelSource", "FaultInjector", "FaultPlan", "InjectedCrash",
    "PanelSource", "QuarantineAbort", "StreamReport", "TransientReadError",
    "restore_stream_state", "run_resilient_sharded_stream",
    "run_resilient_stream", "save_stream_state",
]
