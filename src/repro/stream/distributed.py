"""DP-sharded panel-stream ingestion.

The sketches inside a :class:`~repro.stream.engine.PanelState` are fully
determined by the init key, so every data-parallel worker holds *bit-identical*
operators. Each worker then consumes a disjoint, contiguous, panel-aligned
column range of the stream at its correct global offset, and because all three
accumulators are sums of per-panel contributions into zero-initialised
buffers (``C`` and ``R`` writes are disjoint slots/blocks, ``M`` is a running
sum), the single-host result is recovered *exactly* (up to fp32 summation
order) by summing the worker accumulators:

    ``Σ_w state_w.{C,R,M}  ==  single-host state.{C,R,M}``

Two execution modes share the same math:

* :func:`simulate_sharded_stream` — run the workers sequentially in-process
  (any device count; what the parity tests and benchmarks use);
* :func:`mesh_sharded_stream` — one ``shard_map`` program over a named mesh
  axis, panels consumed in a ``fori_loop`` per shard and accumulators
  all-reduced with ``psum`` at the end (the real multi-device path, exercised
  by ``tests/multidev_scenario.py`` under forced host devices).

Application context that *does* diverge across workers (the adaptive-CUR
admission state) is reconciled through the optional ``PanelOps`` hooks
``prep_shard`` / ``bind_shard`` / ``merge_ctx`` / ``collective_ctx``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard_map_compat
from .engine import PanelState, padded_n, panel_update, stream_panels

__all__ = [
    "shard_panel_ranges",
    "simulate_sharded_stream",
    "merge_states",
    "mesh_sharded_stream",
]


def shard_panel_ranges(n: int, panel: int, num_workers: int) -> List[Tuple[int, int]]:
    """Contiguous, panel-aligned column ranges ``[lo, hi)`` per worker.

    Panels are dealt out as evenly as possible; only the last worker's range
    can end ragged (at ``n``). Workers past the panel count get empty ranges.
    """
    num_panels = (n + panel - 1) // panel
    bounds = [round(i * num_panels / num_workers) for i in range(num_workers + 1)]
    return [
        (min(bounds[i] * panel, n), min(bounds[i + 1] * panel, n))
        for i in range(num_workers)
    ]


def _worker_state(state0: PanelState, ctx, lo: int) -> PanelState:
    return dataclasses.replace(state0, ctx=ctx, offset=jnp.asarray(lo, jnp.int32))


def merge_states(states: Sequence[PanelState]) -> PanelState:
    """Sum worker accumulators into the equivalent single-host state."""
    states = list(states)
    base = states[0]
    C = sum((s.C for s in states[1:]), base.C)
    R = sum((s.R for s in states[1:]), base.R)
    M = sum((s.M for s in states[1:]), base.M)
    if base.ops.merge_ctx is not None:
        ctx = base.ops.merge_ctx([s.ctx for s in states])
    else:
        ctx = base.ctx
    return dataclasses.replace(
        base, C=C, R=R, M=M, offset=jnp.asarray(base.n, jnp.int32), ctx=ctx
    )


def simulate_sharded_stream(
    state0: PanelState, A: jax.Array, panel: int, num_workers: int
) -> PanelState:
    """Run ``num_workers`` DP workers sequentially in-process and merge.

    Exact parity with single-host streaming for SP-SVD and fixed-index CUR;
    for adaptive CUR each worker admits into its own slot range (see
    ``repro.stream.adaptive``), so the merged state is a valid — but not
    bitwise-identical — admission outcome.
    """
    if int(state0.offset) != 0:
        raise ValueError(
            "simulate_sharded_stream needs a fresh state: every worker clones "
            "state0's accumulators, so a partially-streamed prefix would be "
            f"summed once per worker (offset={int(state0.offset)})"
        )
    n = min(A.shape[1], state0.n)
    ranges = shard_panel_ranges(n, panel, num_workers)
    ctx0 = state0.ctx
    if state0.ops.prep_shard is not None:
        ctx0 = state0.ops.prep_shard(ctx0, num_workers)
    shards = []
    for w, (lo, hi) in enumerate(ranges):
        ctx = ctx0
        if state0.ops.bind_shard is not None:
            ctx = state0.ops.bind_shard(ctx, jnp.asarray(w, jnp.int32))
        st = _worker_state(state0, ctx, lo)
        if hi > lo:
            st = stream_panels(st, A, panel, stop=hi)
        shards.append(st)
    # NB: every worker starts from state0's zero accumulators, so the merge
    # sum is exact only for a fresh (un-streamed) state0.
    return merge_states(shards)


def mesh_sharded_stream(
    state0: PanelState,
    A: jax.Array,
    panel: int,
    mesh,
    axis: str = "data",
) -> PanelState:
    """One ``shard_map`` program: shard ``A``'s columns over ``mesh[axis]``,
    stream panels per shard at global offsets, ``psum`` the accumulators.

    Requires the (padded) column count to split into whole panels per worker:
    ``n_pad % (W · panel) == 0`` with ``W = mesh.shape[axis]``.
    """
    if int(state0.offset) != 0:
        raise ValueError(
            "mesh_sharded_stream needs a fresh state: every shard starts from "
            "state0's accumulators, so a partially-streamed prefix would be "
            f"psum-multiplied (offset={int(state0.offset)})"
        )
    n = state0.n
    W = int(mesh.shape[axis])
    n_pad = padded_n(n, panel)
    if n_pad % W or (n_pad // W) % panel:
        raise ValueError(
            f"padded column count {n_pad} must split into whole panels per "
            f"worker (W={W}, panel={panel})"
        )
    shard_n = n_pad // W
    if A.shape[1] != n_pad:
        A = jnp.pad(A, ((0, 0), (0, n_pad - A.shape[1])))
    if state0.R.shape[1] != n_pad:
        raise ValueError("state was initialised without `panel=`; R is unpadded")
    ops = state0.ops
    ctx0 = state0.ctx
    if ops.prep_shard is not None:
        ctx0 = ops.prep_shard(ctx0, W)
    state0 = dataclasses.replace(state0, ctx=ctx0)

    from jax.sharding import PartitionSpec as P

    def body(state, A_shard):
        w = jax.lax.axis_index(axis)
        ctx = state.ctx
        if ops.bind_shard is not None:
            ctx = ops.bind_shard(ctx, w)
        st = dataclasses.replace(state, ctx=ctx, offset=(w * shard_n).astype(jnp.int32))

        def step(i, st):
            A_L = jax.lax.dynamic_slice_in_dim(A_shard, i * panel, panel, axis=1)
            return panel_update(st, A_L)

        st = jax.lax.fori_loop(0, shard_n // panel, step, st)
        ctx = st.ctx
        if ops.collective_ctx is not None:
            ctx = ops.collective_ctx(ctx, axis)
        return dataclasses.replace(
            st,
            C=jax.lax.psum(st.C, axis),
            R=jax.lax.psum(st.R, axis),
            M=jax.lax.psum(st.M, axis),
            offset=jnp.asarray(n, jnp.int32),
            ctx=ctx,
        )

    state_specs = jax.tree_util.tree_map(lambda _: P(), state0)
    out_specs = jax.tree_util.tree_map(lambda _: P(), state0)
    f = shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(state_specs, P(None, axis)),
        out_specs=out_specs,
        check_vma=False,
    )
    return f(state0, A)
