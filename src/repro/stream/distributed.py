"""DP-sharded panel-stream ingestion.

The sketches inside a :class:`~repro.stream.engine.PanelState` are fully
determined by the init key, so every data-parallel worker holds *bit-identical*
operators. Each worker then consumes a disjoint, contiguous, panel-aligned
column range of the stream at its correct global offset, and because all three
accumulators are sums of per-panel contributions into zero-initialised
buffers (``C`` and ``R`` writes are disjoint slots/blocks, ``M`` is a running
sum), the single-host result is recovered *exactly* (up to fp32 summation
order) by summing the worker accumulators:

    ``Σ_w state_w.{C,R,M}  ==  single-host state.{C,R,M}``

Two execution modes share the same math:

* :func:`simulate_sharded_stream` — run the workers in-process (any device
  count; what the parity tests and benchmarks use). Default execution is
  **one compiled program**: every worker's panel range runs as a local
  ``lax.scan`` (:func:`repro.stream.engine.scan_chunk`) and the merge happens
  inside the same dispatch, so a W-worker simulation costs one XLA call —
  the per-worker-per-panel dispatch & re-materialization overhead that used
  to make w2/w4 *slower* than single-host is gone. The pre-scan per-panel
  loop is retained behind ``jit="per-panel"`` as the parity oracle.
* :func:`mesh_sharded_stream` — one ``shard_map`` program over a named mesh
  axis: each shard scans its whole panel chunk locally, then the
  accumulators are all-reduced with **one ``psum`` per chunk** (never per
  panel — collective cadence is per streamed chunk, the real multi-device
  path, exercised by ``tests/multidev_scenario.py`` under forced host
  devices).

Application context that *does* diverge across workers (the adaptive-CUR
admission state) is reconciled through the optional ``PanelOps`` hooks
``prep_shard`` / ``bind_shard`` / ``merge_ctx`` / ``collective_ctx``, and
cross-worker repairs that must see the merged *accumulators* (adaptive row
dedup) run through ``merge_state`` after every merge path.

Symmetric (tied-operand) streams — SPSD / kernel approximation with
``R = Cᵀ`` (:mod:`repro.spsd.streaming`) — ride the same machinery
unchanged: their ``R`` is the ``(0, n_pad)`` placeholder (merge-sum and
psum are no-ops on it) while ``C`` and ``M`` obey the same
disjoint-write/running-sum algebra, so sharded tied-operand ingestion
reproduces the single-host factors exactly as well.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard_map_compat
from ..obs.spans import span
from .engine import PanelState, padded_n, scan_chunk, scan_panels, stream_panels

__all__ = [
    "shard_panel_ranges",
    "simulate_sharded_stream",
    "merge_states",
    "mesh_sharded_stream",
]


def shard_panel_ranges(n: int, panel: int, num_workers: int) -> List[Tuple[int, int]]:
    """Contiguous, panel-aligned column ranges ``[lo, hi)`` per worker.

    Panels are dealt out as evenly as possible; only the last worker's range
    can end ragged (at ``n``). Workers past the panel count get empty ranges.
    """
    num_panels = (n + panel - 1) // panel
    bounds = [round(i * num_panels / num_workers) for i in range(num_workers + 1)]
    return [
        (min(bounds[i] * panel, n), min(bounds[i + 1] * panel, n))
        for i in range(num_workers)
    ]


def _worker_state(state0: PanelState, ctx, lo: int) -> PanelState:
    return dataclasses.replace(state0, ctx=ctx, offset=jnp.asarray(lo, jnp.int32))


def merge_states(states: Sequence[PanelState]) -> PanelState:
    """Sum worker accumulators into the equivalent single-host state.

    When the application declares a ``merge_state`` hook (cross-worker
    repairs that touch the accumulators, e.g. adaptive row dedup), it runs
    last — after the accumulator sum and the ctx merge.

    Telemetry frames ride the same algebra: per-panel slots are disjoint
    worker writes and the rest are running sums, so
    ``TelemetryFrame.merge`` sums them (the constant test sketch excepted).
    """
    states = list(states)
    base = states[0]
    C = sum((s.C for s in states[1:]), base.C)
    R = sum((s.R for s in states[1:]), base.R)
    M = sum((s.M for s in states[1:]), base.M)
    if base.ops.merge_ctx is not None:
        ctx = base.ops.merge_ctx([s.ctx for s in states])
    else:
        ctx = base.ctx
    tel = base.tel
    if tel is not None:
        tel = tel.merge([s.tel for s in states])
    quarantined = base.quarantined
    if quarantined is not None:
        # per-worker quarantine counts are disjoint panel tallies — sum
        quarantined = sum((s.quarantined for s in states[1:]), quarantined)
    merged = dataclasses.replace(
        base, C=C, R=R, M=M, offset=jnp.asarray(base.n, jnp.int32), ctx=ctx, tel=tel,
        quarantined=quarantined,
    )
    if base.ops.merge_state is not None:
        merged = base.ops.merge_state(merged)
    return merged


def _scan_range(st: PanelState, A: jax.Array, lo: int, hi: int, panel: int) -> PanelState:
    """Scan one worker's ``[lo, hi)`` column range (traced; ``st.offset == lo``)."""
    from .engine import panel_update

    num_panels = padded_n(hi - lo, panel) // panel
    if hi - lo == num_panels * panel:
        if num_panels == 1:
            # single whole panel: no loop machinery, one unrolled step
            return panel_update(st, jax.lax.dynamic_slice_in_dim(A, lo, panel, axis=1))
        # aligned range: slice panels out of the shared A — no chunk copy
        return scan_panels(st, A, num_panels, panel)
    chunk = jnp.pad(A[:, lo:hi], ((0, 0), (0, num_panels * panel - (hi - lo))))
    return scan_chunk(st, chunk, panel)


@partial(jax.jit, static_argnames=("ranges", "panel"), donate_argnums=(0,))
def _fused_simulate(state0: PanelState, A: jax.Array, ranges, panel: int) -> PanelState:
    """One compiled program: every worker's local scan + the merge.

    ``ranges`` is the static per-worker panel partition. Two regimes:

    * **No shard hooks** (fixed-index CUR, SP-SVD): every accumulator update
      is a running sum or a disjoint slot/block write into zero-init
      buffers, so per-worker accumulators followed by a merge-sum are
      *provably identical* to chaining one state through the workers'
      ranges in order (and the chained fp summation order equals the
      single-host order exactly). The fused program therefore chains —
      W-worker simulation costs the single-host stream, no per-worker
      accumulator materialization, no merge. The un-chained per-worker
      machinery stays covered by ``jit="per-panel"`` and the mesh path.
    * **Shard hooks present** (adaptive CUR): only the admission *context*
      genuinely diverges per worker — the C/R/M accumulators remain
      disjoint-slot/disjoint-range writes and running sums even under
      adaptive admission (each worker only ever touches its own slot range
      and its own column range), so the accumulators chain through the
      workers exactly like the hook-less case while each worker's ctx
      starts from its own ``bind_shard`` binding; only the ctxs are merged
      (``merge_ctx``), with no per-worker accumulator materialization.

    ``state0`` is donated: on backends with buffer donation the fresh
    accumulators are reused for the output.
    """
    ops = state0.ops
    chainable = (
        ops.bind_shard is None and ops.merge_ctx is None and ops.collective_ctx is None
    )
    if chainable:
        st = state0
        if all(a[1] == b[0] for a, b in zip(ranges, ranges[1:])):
            # contiguous partition (always true for shard_panel_ranges):
            # chaining collapses to ONE scan over the union range — the
            # W-worker program IS the single-host program
            lo, hi = ranges[0][0], ranges[-1][1]
            if hi > lo:
                st = dataclasses.replace(st, offset=jnp.asarray(lo, jnp.int32))
                st = _scan_range(st, A, lo, hi, panel)
        else:  # pragma: no cover — defensive: non-contiguous custom ranges
            for lo, hi in ranges:
                if hi > lo:
                    st = dataclasses.replace(st, offset=jnp.asarray(lo, jnp.int32))
                    st = _scan_range(st, A, lo, hi, panel)
        st = dataclasses.replace(st, offset=jnp.asarray(state0.n, jnp.int32))
        return ops.merge_state(st) if ops.merge_state is not None else st
    worker_ctxs = []
    st = state0
    for w, (lo, hi) in enumerate(ranges):
        ctx = state0.ctx  # each worker's ctx starts fresh from the prepped base
        if ops.bind_shard is not None:
            ctx = ops.bind_shard(ctx, jnp.asarray(w, jnp.int32))
        # accumulators chain; ctx is swapped per worker
        st = dataclasses.replace(st, ctx=ctx, offset=jnp.asarray(lo, jnp.int32))
        if hi > lo:
            st = _scan_range(st, A, lo, hi, panel)
        worker_ctxs.append(st.ctx)
    ctx = ops.merge_ctx(worker_ctxs) if ops.merge_ctx is not None else state0.ctx
    st = dataclasses.replace(st, ctx=ctx, offset=jnp.asarray(state0.n, jnp.int32))
    return ops.merge_state(st) if ops.merge_state is not None else st


def simulate_sharded_stream(
    state0: PanelState, A: jax.Array, panel: int, num_workers: int, *, jit="scan"
) -> PanelState:
    """Run ``num_workers`` DP workers in-process and merge.

    Exact parity with single-host streaming for SP-SVD and fixed-index CUR;
    for adaptive CUR each worker admits into its own slot range (see
    ``repro.stream.adaptive``), so the merged state is a valid — but not
    bitwise-identical — admission outcome.

    ``jit="scan"`` (default) runs all workers *and* the merge as one
    compiled program (:func:`_fused_simulate` — ``state0`` is consumed, per
    the engine's donation contract); ``jit="per-panel"`` / ``jit=False``
    keep the pre-scan driver: one python loop over workers, each worker
    dispatching per panel — the parity oracle for the scan path.
    """
    if int(state0.offset) != 0:
        raise ValueError(
            "simulate_sharded_stream needs a fresh state: every worker clones "
            "state0's accumulators, so a partially-streamed prefix would be "
            f"summed once per worker (offset={int(state0.offset)})"
        )
    n = min(A.shape[1], state0.n)
    ranges = shard_panel_ranges(n, panel, num_workers)
    ctx0 = state0.ctx
    if state0.ops.prep_shard is not None:
        ctx0 = state0.ops.prep_shard(ctx0, num_workers)
    state0 = dataclasses.replace(state0, ctx=ctx0)
    if jit in ("scan", True):
        with span(f"stream/{state0.ops.name}/sharded_simulate"):
            return _fused_simulate(state0, A, tuple(ranges), panel)
    shards = []
    for w, (lo, hi) in enumerate(ranges):
        ctx = ctx0
        if state0.ops.bind_shard is not None:
            ctx = state0.ops.bind_shard(ctx, jnp.asarray(w, jnp.int32))
        st = _worker_state(state0, ctx, lo)
        if hi > lo:
            st = stream_panels(st, A, panel, stop=hi, jit=jit)
        shards.append(st)
    # NB: every worker starts from state0's zero accumulators, so the merge
    # sum is exact only for a fresh (un-streamed) state0.
    return merge_states(shards)


def mesh_sharded_stream(
    state0: PanelState,
    A: jax.Array,
    panel: int,
    mesh,
    axis: str = "data",
) -> PanelState:
    """One ``shard_map`` program: shard ``A``'s columns over ``mesh[axis]``,
    scan each shard's whole panel chunk locally, ``psum`` the accumulators
    **once per chunk** (never per panel — the collective cadence is one
    all-reduce per streamed chunk regardless of panel count).

    Requires the (padded) column count to split into whole panels per worker:
    ``n_pad % (W · panel) == 0`` with ``W = mesh.shape[axis]``.
    """
    if int(state0.offset) != 0:
        raise ValueError(
            "mesh_sharded_stream needs a fresh state: every shard starts from "
            "state0's accumulators, so a partially-streamed prefix would be "
            f"psum-multiplied (offset={int(state0.offset)})"
        )
    n = state0.n
    W = int(mesh.shape[axis])
    n_pad = padded_n(n, panel)
    if n_pad % W or (n_pad // W) % panel:
        raise ValueError(
            f"padded column count {n_pad} must split into whole panels per "
            f"worker (W={W}, panel={panel})"
        )
    shard_n = n_pad // W
    if A.shape[1] != n_pad:
        A = jnp.pad(A, ((0, 0), (0, n_pad - A.shape[1])))
    if state0.R.shape[1] != n_pad:
        raise ValueError("state was initialised without `panel=`; R is unpadded")
    ops = state0.ops
    ctx0 = state0.ctx
    if ops.prep_shard is not None:
        ctx0 = ops.prep_shard(ctx0, W)
    state0 = dataclasses.replace(state0, ctx=ctx0)

    from jax.sharding import PartitionSpec as P

    def body(state, A_shard):
        w = jax.lax.axis_index(axis)
        ctx = state.ctx
        if ops.bind_shard is not None:
            ctx = ops.bind_shard(ctx, w)
        st = dataclasses.replace(state, ctx=ctx, offset=(w * shard_n).astype(jnp.int32))
        st = scan_chunk(st, A_shard, panel)  # local scan; collectives below
        ctx = st.ctx
        if ops.collective_ctx is not None:
            ctx = ops.collective_ctx(ctx, axis)
        st = dataclasses.replace(
            st,
            C=jax.lax.psum(st.C, axis),
            # symmetric streams carry the (0, n_pad) placeholder — nothing to reduce
            R=jax.lax.psum(st.R, axis) if st.R.size else st.R,
            M=jax.lax.psum(st.M, axis),
            offset=jnp.asarray(n, jnp.int32),
            ctx=ctx,
            # telemetry reduces with the same disjoint-write algebra as C/R/M
            tel=st.tel.collective(axis) if st.tel is not None else None,
            quarantined=(
                jax.lax.psum(st.quarantined, axis)
                if st.quarantined is not None
                else None
            ),
        )
        return ops.merge_state(st) if ops.merge_state is not None else st

    state_specs = jax.tree_util.tree_map(lambda _: P(), state0)
    out_specs = jax.tree_util.tree_map(lambda _: P(), state0)
    f = shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(state_specs, P(None, axis)),
        out_specs=out_specs,
        check_vma=False,
    )
    with span(f"stream/{ops.name}/sharded_mesh"):
        return f(state0, A)
