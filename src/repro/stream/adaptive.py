"""Adaptive streaming CUR v2: column admission **and eviction**, plus
in-stream row admission.

Fixed-index streaming CUR must pick its ``col_idx``/``row_idx`` before the
pass — a single uniform pre-pass draw misses the heavy columns/rows of
spiked spectra. This module closes that gap (ROADMAP open items 1–2) with a
*residual-driven* replacement policy in the spirit of Wang & Zhang 2016's
adaptive sampling, computable **from the sketches alone** so the
single-pass contract is kept.

Column scoring (admission + eviction)
-------------------------------------
Scoring is fused with the panel sketch through the engine's
``sketch_panel`` hook: one pass computes ``sc_a = S_C A_L`` (shared with
the M update), the per-column energies, and for each panel column
``y = S_C a_j`` how much of it lies outside the span of the
already-admitted (sketched) columns ``S_C C``:

    ``score_j = ||y||² − ||Qᵀ y||²``

where ``Q`` is the Gram-whitened basis of the worker's admitted-slot
sketches (:func:`_whitened_basis` — unfilled slots' zero columns are
inert) — a λ-regularized projection residual, equal up to the tiny ridge
to the sketched least-squares residual ``||y − (S_C C)(S_C C)⁺ y||²``
(``S_C`` preserves these norms to (1±ε) by the subspace-embedding
property). On TPU the whole
triple runs as the fused ``repro.kernels.panel_score`` Pallas kernel (one
VMEM pass instead of three HBM round-trips); elsewhere the same math runs
as XLA ops on the structured sketch apply. A column is *admitted* into the
next free ``C`` slot when its score clears ``min_gain ×`` the mean column
energy — the larger of the running-stream mean and the current panel's mean,
so noise columns are never "eligible by default" on a cold start — with at
most ``panel_cap`` admissions per panel so the budget isn't exhausted early.

**Eviction** (v2): every admitted slot remembers the residual energy it
carried at admission time (``slot_score`` — its *retained energy*: how much
of the column lay outside the then-current basis). Once the budget is full,
an eligible candidate whose score clears ``swap_gain ×`` the weakest
admitted slot's retained energy *evicts* that slot: the victim's ``C``
column, ``ScC`` sketch, ``col_idx`` entry and score are overwritten in
place, inside the same jitted panel step. This is what admission-only
single-pass policies structurally cannot do: a heavy column arriving after
the budget fills (late-spike / drifting-spectrum streams) is no longer
lost. ``swap_gain=None`` (the default) disables eviction and reproduces the
v1 admission-only policy exactly.

Row admission (v2)
------------------
Rows are scored with the transposed sketch: each panel contributes
``A_L S_R[:, cols]ᵀ`` to a running accumulator ``row_sketch = A S_Rᵀ``
(m × s_r — the same order as the ``C`` factor), which after panel ``t``
holds every row's *exact* sketch over the columns seen so far. Rows are
scored by their residual against the span of the admitted rows' live
sketches and admitted into free ``R`` slots under the same
``min_gain``/``panel_cap`` knobs (``min_gain_rows``/``panel_cap_rows``).

Because ``R`` rows are gathered mid-stream, a row admitted at offset
``off`` has already missed columns ``[0, off)``. Those entries are
*backfilled* from the sketched reconstruction: with ``y`` the row's
accumulated sketch restricted to the missed prefix (kept per-slot in the
``backfill`` buffer at admission) and ``S`` the prefix window of ``S_R``,
the minimum-norm reconstruction ``x = Sᵀ(SSᵀ + λI)⁻¹ y`` is written into
``R[slot, :off]``. This needs writes *outside* the current panel window,
which is why :class:`~repro.stream.engine.PanelOps` grew the ``update_r``
hook. Row *eviction* is future work (backfill would have to be re-run for
the replacement row).

Bookkeeping is O(s_c·c + r·s_r) extra memory plus the O(m·s_r)
``row_sketch`` accumulator (adaptive rows only), and the scorers are one
(s_c × c_local) and one (s_r × r_local) QR per panel. Everything is
jit-compatible: admission/eviction use rank/slot scatters with
``mode='drop'`` so traced shapes stay static.

Distributed: each DP worker admits into its own ``c/W`` column-slot and
``r/W`` row-slot range (``prep_shard``/``bind_shard``), so merged states
never collide (disjoint-slot semantics); the merged result is a valid
admission outcome but — unlike the fixed-index paths — not bitwise equal to
single-host admission (workers score against their local basis only, and a
worker's backfill can only reconstruct the column range it has seen).
Because rows are global, two workers can admit the *same* heavy row; the
post-merge ``merge_state`` hook (:func:`_merge_state`) consolidates such
duplicates into the lowest-numbered slot (summing their disjoint-support
``R`` rows) and frees the rest, so duplicate admissions no longer waste
budget.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.gmr import fast_gmr_core
from ..core.sketching import GaussianSketch, draw_sketch
from ..kernels.ops import kernel_route_enabled, panel_score
from ..kernels.ops import panel_update as kernel_panel_update
from ..obs.telemetry import adaptive_stream_telemetry, init_telemetry
from .engine import PanelOps, PanelState, fresh_pytree, padded_n, truncated_R

__all__ = [
    "AdaptiveCURCtx",
    "AdaptiveRowState",
    "ADAPTIVE_CUR_OPS",
    "ADAPTIVE_CUR_TEL_OPS",
    "adaptive_cur_init",
    "adaptive_cur_finalize",
    "allocate_shared_budget",
]


def allocate_shared_budget(
    scores: jax.Array, budget: int, *, floor: int = 0, cap: "int | None" = None
) -> jax.Array:
    """Split a shared rank ``budget`` across groups by greedy marginal gain.

    The streaming-CUR admission machinery above scores *columns* and spends
    a slot budget on the highest-residual ones; this is the same greedy at
    *group* granularity (the serving stack's groups are KV heads): each
    group ``g`` offers marginal gains ``scores[g, j]`` for its ``j``-th rank
    unit, and the budget is spent one unit at a time on the globally best
    remaining marginal — one fused :func:`jax.lax.top_k` over the flattened
    eligible window, exactly the admission kernel's selection primitive.

    Args:
        scores: ``(G, K)`` per-group marginal-gain ladders, **sorted
            descending along the last axis** (e.g. singular values or
            energies ``σ²``); with non-increasing ladders the global greedy
            is prefix-consistent, so the result is a valid per-group rank.
        budget: total units to allocate (static). Must satisfy
            ``budget >= G * floor``.
        floor: guaranteed minimum units per group (static).
        cap: per-group maximum (static; default ``K``). Units beyond ``cap``
            are never allocated even if budget remains.

    Returns:
        ``(G,)`` int32 allocation with ``floor <= out[g] <= cap`` and
        ``out.sum() <= budget``. Non-positive marginals are never bought
        (a group with a dead spectrum tail keeps its floor), so the sum can
        undershoot the budget.
    """
    G, K = scores.shape
    cap = K if cap is None else min(int(cap), K)
    if floor < 0 or cap < floor:
        raise ValueError(f"need 0 <= floor <= cap, got floor={floor} cap={cap}")
    extra = int(budget) - G * floor
    if extra < 0:
        raise ValueError(f"budget {budget} cannot cover floor {floor} x {G} groups")
    W = cap - floor
    if W == 0 or extra == 0:
        return jnp.full((G,), floor, jnp.int32)
    window = scores[:, floor:cap].reshape(-1)  # (G*W,) marginal gains
    k = min(extra, G * W)
    vals, idx = jax.lax.top_k(window, k)
    picks = (vals > 0).astype(jnp.int32)  # dead marginals are never bought
    counts = jnp.zeros((G,), jnp.int32).at[idx // W].add(picks)
    return floor + counts


@dataclasses.dataclass(frozen=True)
class AdaptiveRowState:
    """Adaptive row-admission state (present only when rows are adaptive).

    ``row_sketch`` accumulates ``A S_Rᵀ`` panel-by-panel, so row ``i``'s
    sketch is exact over the columns this worker has seen; ``backfill``
    holds, for slots admitted in the *current* panel, the pre-panel sketch
    of the admitted row (the sketched image of exactly the missed column
    prefix) consumed by the ``update_r`` backfill; ``admit_off`` records
    the admission offset per slot (−1 = unfilled) and doubles as the
    "freshly admitted this panel" marker; ``seen_lo`` is the global column
    offset where this worker's stream started (−1 until the first panel),
    bounding the backfillable range. ``gram`` accumulates the prefix Gram
    ``S_pre S_preᵀ`` of the sketch windows *before* the current panel —
    the backfill solve's left-hand side — at O(s_r²·L) per panel instead
    of an O(s_r²·n_pad) rebuild per admission; ``gram_pending`` holds the
    current panel's window Gram, folded into ``gram`` at the next panel so
    ``gram`` stays strictly pre-panel when ``_update_r`` consumes it.
    ``sr_dense`` is the dense ``S_R`` (s_r × n_pad), materialized **once at
    init** and threaded through the stream — the per-panel window Gram and
    the backfill's prefix map are dynamic slices of it, replacing the
    per-panel ``materialize()`` rebuilds that dominated the adaptive-row
    hot path (a full (s_r, L) scatter every panel plus an (s_r, n_pad)
    scatter per admission).
    """

    row_sketch: jax.Array  # (m, s_r) running A S_Rᵀ over seen columns
    backfill: jax.Array  # (r, s_r) pre-panel sketches of this panel's admits
    admit_off: jax.Array  # (r,) int32 admission offset per slot, −1 = unfilled
    gram: jax.Array  # (s_r, s_r) Gram of the S_R windows over [seen_lo, off)
    gram_pending: jax.Array  # (s_r, s_r) current panel's window Gram
    sr_dense: jax.Array  # (s_r, n_pad) dense S_R, precomputed once at init
    n_filled: jax.Array  # () int32 — next free row slot (worker-local range)
    slot_lo: jax.Array  # () int32 — first row slot this worker may fill
    min_gain: jax.Array  # () f32 — row admission threshold multiplier
    seen_lo: jax.Array  # () int32 — first column offset this worker saw, −1 = none
    r_local: int  # static: number of row slots this worker owns
    panel_cap: int  # static: max row admissions per panel


jax.tree_util.register_dataclass(
    AdaptiveRowState,
    data_fields=[
        "row_sketch", "backfill", "admit_off", "gram", "gram_pending",
        "sr_dense", "n_filled", "slot_lo", "min_gain", "seen_lo",
    ],
    meta_fields=["r_local", "panel_cap"],
)


@dataclasses.dataclass(frozen=True)
class AdaptiveCURCtx:
    """Admission/eviction state threaded through the panel stream."""

    col_idx: jax.Array  # (c,) int32, −1 = unfilled slot
    row_idx: jax.Array  # (r,) int32, −1 = unfilled (fixed pre-pass when rows=None)
    S_C: object  # (s_c, m) column-sliceable core sketch
    S_R: object  # (s_r, n_pad)
    ScC: jax.Array  # (s_c, c) — sketches of the admitted columns, by slot
    slot_score: jax.Array  # (c,) f32 — residual energy at admission (retained energy)
    n_filled: jax.Array  # () int32 — next free slot (within this worker's range)
    slot_lo: jax.Array  # () int32 — first slot this worker may fill
    energy: jax.Array  # () f32 — running Σ ||S_C a_j||² over seen columns
    cols_seen: jax.Array  # () f32 — true (unpadded) columns seen
    min_gain: jax.Array  # () f32 — admission threshold multiplier
    swap_gain: jax.Array  # () f32 — eviction threshold multiplier (+inf = off)
    n_evicted: jax.Array  # () int32 — total evictions performed
    rows: Optional[AdaptiveRowState]  # adaptive row admission state, or None
    c_local: int  # static: number of column slots this worker owns
    panel_cap: int  # static: max column admissions per panel
    n: int  # static: true column count of the stream
    # static: eviction enabled (swap_gain was given)? Statically known so the
    # admission-only compile path can use one vectorized scatter per panel
    # instead of the sequential admit-or-evict chain.
    evict: bool = False


jax.tree_util.register_dataclass(
    AdaptiveCURCtx,
    data_fields=[
        "col_idx", "row_idx", "S_C", "S_R", "ScC", "slot_score",
        "n_filled", "slot_lo", "energy", "cols_seen", "min_gain",
        "swap_gain", "n_evicted", "rows",
    ],
    meta_fields=["c_local", "panel_cap", "n", "evict"],
)


def _core_sketches(ctx):
    """Engine hook: the (S_C, S_R) pair driving the shared M update."""
    return ctx.S_C, ctx.S_R


def _whitened_basis(mat: jax.Array) -> jax.Array:
    """Gram-whitened basis ``Q = mat·L⁻ᵀ`` with ``LLᵀ = matᵀmat + λI``.

    ``‖Qᵀy‖² = yᵀ mat (matᵀmat + λI)⁻¹ matᵀ y`` is the (λ-regularized)
    energy of ``y`` inside ``span(mat)``, so ``‖y‖² − ‖Qᵀy‖²`` is the
    projection residual the admission policy scores with. Two properties
    make this the right streaming primitive:

    * all-zero columns of ``mat`` (unfilled slots — the zero-suffixed
      prefix invariant) produce all-zero columns of ``Q``, contributing
      nothing: no fill-count masking needed, cold start included
      (``mat = 0`` ⇒ residual = energy exactly);
    * the factorization is a ``c×c`` Gram + Cholesky + triangular solve —
      O(s_c·c²) like QR but without the tall-matrix Householder pass,
      which dominated the per-panel serial latency of the scoring step.

    ``λ = c·eps·tr(G) + tiny`` is sized so the factorization **cannot** go
    numerically indefinite — the fp32 rounding perturbation of ``G`` is
    bounded by ``eps·tr(G)`` and LAPACK's potrf needs ≈``c×`` that in
    min-eigenvalue headroom — so near-duplicate admitted columns (a true
    rank-deficient Gram) still produce a finite, NaN-free scorer: the
    no-NaN guarantee the floored-QR path of
    :func:`repro.core.gmr._solve_least_squares` gave, restated for the
    Cholesky route. The ridge stays O(1e-6) relative, far below the
    subspace-embedding noise the scores already carry, and the regularized
    projection energy is ≤ the exact one, so residuals stay ≥ 0.
    """
    dt = jnp.float32
    M = mat.astype(dt)
    G = M.T @ M
    lam = G.shape[0] * jnp.finfo(dt).eps * jnp.trace(G) + jnp.finfo(dt).tiny
    L = jnp.linalg.cholesky(G + lam * jnp.eye(G.shape[0], dtype=dt))
    return jax.scipy.linalg.solve_triangular(L, M.T, lower=True).T


def _admitted_basis(ctx: AdaptiveCURCtx) -> jax.Array:
    """Whitened basis of this worker's admitted-slot sketches (per panel —
    every admission changes the span the next panel scores against)."""
    ScC_local = jax.lax.dynamic_slice_in_dim(ctx.ScC, ctx.slot_lo, ctx.c_local, axis=1)
    return _whitened_basis(ScC_local)


def _score_columns(Qm: jax.Array, sc_a: jax.Array) -> tuple:
    """Per-column ``(resid2, energy)`` of the panel sketches against the
    whitened admitted basis ``Qm`` — the XLA half of the scoring triple."""
    y = sc_a.astype(jnp.float32)
    energy = jnp.sum(y * y, axis=0)  # (L,)
    t = Qm.T @ y  # (c_local, L)
    resid2 = jnp.maximum(energy - jnp.sum(t * t, axis=0), 0.0)
    return resid2, energy


def _sketch_panel(ctx: AdaptiveCURCtx, A_L, off):
    """Engine ``sketch_panel`` hook: panel sketch + column scores, fused.

    Computes ``sc_a = S_C A_L`` together with the per-column energies and
    the residual energies against the worker's admitted basis. On TPU with a
    dense ``S_C`` the triple is one VMEM pass of the
    :func:`repro.kernels.ops.panel_score` Pallas kernel (each ``A_L`` tile
    read once, ``sc_a`` never round-trips through HBM); elsewhere the same
    math runs as XLA ops over the structured sketch apply. The whitening of
    the (s_c × c_local) admitted-sketch slice happens outside the kernel —
    it is O(s_c·c²), independent of the panel.
    """
    Qm = _admitted_basis(ctx)
    if jax.default_backend() == "tpu" and isinstance(ctx.S_C, GaussianSketch):
        sc_a, resid2, energy = panel_score(ctx.S_C.mat[:, : A_L.shape[0]], A_L, Qm)
    else:
        sc_a = ctx.S_C.apply(A_L)  # (s_c, L)
        resid2, energy = _score_columns(Qm, sc_a)
    return ctx, sc_a, (resid2, energy)


# ---------------------------------------------------------------------------
# column admission + eviction
# ---------------------------------------------------------------------------


def _admit_or_evict_columns(ctx: AdaptiveCURCtx, C, block, col0, sc_a, resid2, eligible, off):
    """Greedy per-candidate pass over the top-``panel_cap`` residual columns:
    admit into the next free slot while the worker's range has one, else
    evict the weakest admitted slot when the candidate clears ``swap_gain ×``
    its retained-energy score. With eviction enabled the pass is sequential
    but statically unrolled (``panel_cap`` scatter chains — each decision
    changes the slot table the next one sees); admission-only
    (``ctx.evict`` False) is order-independent within a panel, so it
    compiles to **one** batched scatter per buffer, identical outcome. All
    shapes stay static via ``mode='drop'`` OOB scatters.

    The panel's columns live at ``block[:, col0 + j]`` (``col0`` may be
    traced) — the per-panel driver passes ``(A_L, 0)``, the fused scan body
    the un-copied chunk operand, so candidate gathers never materialize the
    (m × L) panel slice."""
    L = sc_a.shape[1]
    c_total = C.shape[1]
    K = min(ctx.panel_cap, L)

    # top-K eligible residual columns, best first (resid2 ≥ 0 > −1 mask)
    cand_res, cand = jax.lax.top_k(jnp.where(eligible, resid2, -1.0), K)
    cand_ok = jnp.take(eligible, cand)
    cand_A = jnp.take(block, col0 + cand, axis=1)  # (m, K)
    cand_sc = jnp.take(sc_a, cand, axis=1)  # (s_c, K)

    if not ctx.evict:
        # Vectorized admission: candidate k (already best-first) lands in
        # slot n_filled + (its rank among the eligible), budget permitting.
        ranks = jnp.cumsum(cand_ok.astype(jnp.int32)) - 1
        free = ctx.slot_lo + ctx.c_local - ctx.n_filled
        admit = cand_ok & (ranks < free)
        slots = jnp.where(admit, ctx.n_filled + ranks, c_total)  # OOB → drop
        C = C.at[:, slots].set(cand_A.astype(C.dtype), mode="drop")
        ctx = dataclasses.replace(
            ctx,
            ScC=ctx.ScC.at[:, slots].set(cand_sc.astype(ctx.ScC.dtype), mode="drop"),
            col_idx=ctx.col_idx.at[slots].set((off + cand).astype(jnp.int32), mode="drop"),
            slot_score=ctx.slot_score.at[slots].set(
                cand_res.astype(ctx.slot_score.dtype), mode="drop"
            ),
            n_filled=ctx.n_filled + jnp.sum(admit).astype(jnp.int32),
        )
        return ctx, C

    slot_ids = jnp.arange(c_total)
    in_range = (slot_ids >= ctx.slot_lo) & (slot_ids < ctx.slot_lo + ctx.c_local)

    def step(k, carry):
        C, ScC, col_idx, slot_score, n_filled, n_evicted = carry
        res, ok = cand_res[k], cand_ok[k]
        has_free = n_filled < ctx.slot_lo + ctx.c_local
        # weakest admitted slot of this worker's range (+inf elsewhere, so an
        # all-masked argmin picks slot 0 but swap_ok is then provably False)
        scores = jnp.where(in_range & (col_idx >= 0), slot_score, jnp.inf)
        victim = jnp.argmin(scores).astype(jnp.int32)
        admit = ok & has_free
        swap = ok & (~has_free) & (res > ctx.swap_gain * scores[victim])
        # slot = free slot | victim | c_total (OOB → scatter dropped)
        slot = jnp.where(admit, n_filled, jnp.where(swap, victim, c_total))
        C = C.at[:, slot].set(cand_A[:, k].astype(C.dtype), mode="drop")
        ScC = ScC.at[:, slot].set(cand_sc[:, k].astype(ScC.dtype), mode="drop")
        col_idx = col_idx.at[slot].set((off + cand[k]).astype(jnp.int32), mode="drop")
        slot_score = slot_score.at[slot].set(res.astype(slot_score.dtype), mode="drop")
        return (
            C, ScC, col_idx, slot_score,
            n_filled + admit.astype(jnp.int32),
            n_evicted + swap.astype(jnp.int32),
        )

    # Sequential because each decision changes the slot table the next one
    # sees; K = panel_cap is a small static constant, so the loop is
    # UNROLLED into the surrounding scan body (no inner fori_loop) and XLA
    # fuses the K scatter chains.
    carry = (C, ctx.ScC, ctx.col_idx, ctx.slot_score, ctx.n_filled, ctx.n_evicted)
    for k in range(K):
        carry = step(k, carry)
    C, ScC, col_idx, slot_score, n_filled, n_evicted = carry
    ctx = dataclasses.replace(
        ctx, ScC=ScC, col_idx=col_idx, slot_score=slot_score,
        n_filled=n_filled, n_evicted=n_evicted,
    )
    return ctx, C


def _admit_rows(ctx: AdaptiveCURCtx, A_L, off):
    """Score every matrix row's accumulated ``A S_Rᵀ`` sketch against the
    admitted rows' live sketches and admit the top residual rows into free
    slots of this worker's row range. Returns the updated ctx (row_idx +
    AdaptiveRowState); the R-side writes happen in ``_update_r``."""
    rows = ctx.rows
    L = A_L.shape[1]
    m = A_L.shape[0]
    r_total = ctx.row_idx.shape[0]

    window = ctx.S_R.cols(off, L)
    a_sr = window.apply_t(A_L)  # (m, s_r) this panel's row sketches
    prev = rows.row_sketch
    row_sketch = prev + a_sr.astype(prev.dtype)
    seen_lo = jnp.where(rows.seen_lo < 0, off.astype(jnp.int32), rows.seen_lo)
    # Rotate the prefix Gram: fold the previous panel's window in, stash the
    # current one — ``gram`` must cover exactly [seen_lo, off) when the
    # update_r backfill consumes it later this panel. The window is a
    # dynamic slice of the init-time dense S_R — no per-panel scatter.
    Sw = jax.lax.dynamic_slice_in_dim(rows.sr_dense, off, L, axis=1)  # (s_r, L)
    gram = rows.gram + rows.gram_pending
    gram_pending = Sw @ Sw.T

    # Residual of every row's sketch against the admitted-row span, with the
    # basis gathered *live* from the accumulator (always-fresh sketches).
    # Like the column path, the basis is restricted to this worker's slot
    # range and projected through a zero-masked orthonormal basis: the range
    # is filled as a zero-suffixed prefix, so ``Q[:, :filled]`` spans it
    # exactly (a full-table gather would interleave other ranges' leading
    # zero columns and break that invariant under sharding).
    row_idx_local = jax.lax.dynamic_slice_in_dim(
        ctx.row_idx, rows.slot_lo, rows.r_local, axis=0
    )
    filled = row_idx_local >= 0
    basis = jnp.take(row_sketch, jnp.clip(row_idx_local, 0), axis=0)  # (r_local, s_r)
    basis = jnp.where(filled[:, None], basis, jnp.zeros((), basis.dtype))
    Qm = _whitened_basis(basis.T)  # (s_r, r_local); unfilled rows self-mask
    t = row_sketch.astype(jnp.float32) @ Qm  # (m, r_local)
    row_energy = jnp.sum(row_sketch * row_sketch, axis=1)  # (m,)
    resid2 = jnp.maximum(row_energy - jnp.sum(t * t, axis=1), 0.0)  # (m,)

    # Threshold: min_gain_rows × the current mean per-row sketch energy.
    # Already-admitted rows are excluded outright (their residual is fp
    # noise, but −1-free bookkeeping is cheaper than trusting that).
    taken = jnp.zeros((m,), bool).at[jnp.where(filled, row_idx_local, m)].set(
        True, mode="drop"
    )
    mean_energy = jnp.sum(row_energy) / m
    eligible = (resid2 > rows.min_gain * mean_energy) & ~taken

    K = min(rows.panel_cap, m)
    _, top = jax.lax.top_k(jnp.where(eligible, resid2, -1.0), K)  # best first
    free = rows.slot_lo + rows.r_local - rows.n_filled
    cap = jnp.minimum(jnp.minimum(free, jnp.sum(eligible)), rows.panel_cap)
    slots = jnp.where(jnp.arange(K) < cap, rows.n_filled + jnp.arange(K), r_total)

    row_idx = ctx.row_idx.at[slots].set(top.astype(jnp.int32), mode="drop")
    admit_off = rows.admit_off.at[slots].set(off.astype(jnp.int32), mode="drop")
    # pre-panel sketches of the fresh admits = sketched image of exactly the
    # missed prefix [seen_lo, off) — the update_r backfill's right-hand side
    backfill = jnp.zeros_like(rows.backfill).at[slots].set(
        jnp.take(prev, top, axis=0).astype(rows.backfill.dtype), mode="drop"
    )
    rows = dataclasses.replace(
        rows,
        row_sketch=row_sketch,
        backfill=backfill,
        admit_off=admit_off,
        gram=gram,
        gram_pending=gram_pending,
        n_filled=rows.n_filled + cap.astype(jnp.int32),
        seen_lo=seen_lo,
    )
    return dataclasses.replace(ctx, row_idx=row_idx, rows=rows)


def _score_and_admit(ctx: AdaptiveCURCtx, C, block, col0, sc_a, resid2, col_energy, off):
    """Shared per-panel column policy: threshold, admit/evict, fold the
    energy bookkeeping — the core of ``_update_c`` and ``_fused_step``.

    Admission threshold: min_gain × the mean column energy, where the mean
    is the larger of the running stream mean and the current panel's mean
    (over true, unpadded columns). The panel term matters on each worker's
    first panels — with a 0 running mean every noise column would otherwise
    be "eligible" and greedily exhaust the slot budget before any heavy
    column arrives.
    """
    L = sc_a.shape[1]
    true_cols = jnp.clip(ctx.n - off, 1, L).astype(jnp.float32)
    panel_mean = jnp.sum(col_energy) / true_cols
    run_mean = ctx.energy / jnp.maximum(ctx.cols_seen, 1.0)
    thresh = ctx.min_gain * jnp.maximum(run_mean, panel_mean)
    eligible = resid2 > thresh  # strict: zero-padded tail columns never pass

    ctx, C = _admit_or_evict_columns(ctx, C, block, col0, sc_a, resid2, eligible, off)
    ctx = dataclasses.replace(
        ctx,
        energy=ctx.energy + jnp.sum(col_energy),
        cols_seen=ctx.cols_seen + jnp.clip(ctx.n - off, 0, L).astype(ctx.cols_seen.dtype),
    )
    return ctx, C


def _update_c(ctx: AdaptiveCURCtx, C, A_L, sc_a, off, scores):
    """Engine hook: admit/evict this panel's columns within this worker's
    slot range using the scores pre-computed by the fused ``sketch_panel``
    pass; when rows are adaptive, fold the panel into the row accumulator
    and admit rows too."""
    resid2, col_energy = scores  # (L,), (L,) — see _sketch_panel
    ctx, C = _score_and_admit(ctx, C, A_L, 0, sc_a, resid2, col_energy, off)
    if ctx.rows is not None:
        ctx = _admit_rows(ctx, A_L, off)
    return ctx, C


def _update_r(ctx: AdaptiveCURCtx, R, A_L, off):
    """Engine ``update_r`` hook: write the panel block for the current
    (post-admission) ``row_idx`` — unfilled slots stay zero — then backfill
    the missed column prefix of any row admitted *this* panel from its
    sketched reconstruction ``x = S_preᵀ (S_pre S_preᵀ + λI)⁻¹ y``, where
    ``S_pre`` is ``S_R`` masked to the columns this worker has already
    consumed and ``y`` the per-slot pre-panel sketch kept in
    ``rows.backfill``."""
    blk = jnp.take(A_L, jnp.clip(ctx.row_idx, 0), axis=0)
    blk = jnp.where((ctx.row_idx >= 0)[:, None], blk, jnp.zeros((), blk.dtype))
    R = jax.lax.dynamic_update_slice_in_dim(R, blk.astype(R.dtype), off, axis=1)
    rows = ctx.rows
    if rows is None:
        return R

    fresh = (rows.admit_off == off) & (ctx.row_idx >= 0)  # admitted this panel

    def do_backfill(R):
        # G = S_pre S_preᵀ is pre-accumulated window-by-window (rows.gram);
        # only the map back to columns needs the materialized prefix window.
        G = rows.gram  # (s_r, s_r) PSD Gram of the prefix [seen_lo, off)
        lam = 1e-6 * jnp.trace(G) / G.shape[0] + jnp.finfo(jnp.float32).tiny
        Z = jnp.linalg.solve(G + lam * jnp.eye(G.shape[0], dtype=G.dtype),
                             rows.backfill.T.astype(jnp.float32))  # (s_r, r)
        col_ids = jnp.arange(R.shape[1])
        mask = (col_ids >= rows.seen_lo) & (col_ids < off)  # backfillable prefix
        Sm = rows.sr_dense * mask[None, :]  # dense S_R precomputed at init
        Xb = (Sm.T @ Z).T  # (r, n_pad) min-norm row reconstructions
        keep = fresh[:, None] & mask[None, :]
        return jnp.where(keep, Xb.astype(R.dtype), R)

    return jax.lax.cond(jnp.any(fresh), do_backfill, lambda R: R, R)


# ---------------------------------------------------------------------------
# fused-scan hooks (Route A) and the panel-update megakernel (Route B)
# ---------------------------------------------------------------------------


def _chunk_fold(ctx: AdaptiveCURCtx, C, R, block, bcol0, start, width):
    """Fused-scan hook: the whole chunk's fixed-row ``R`` stripe in one pass.

    Adaptive *columns* are inherently per-panel (each admission changes the
    basis the next panel scores against) and stay in ``_fused_step``; the
    fixed ``row_idx`` side is panel-invariant, so the chunk's row stripe is
    gathered once — bitwise the values the per-panel ``_update_r`` copies.
    Adaptive rows never reach here (``_supports_fused`` keeps them on the
    legacy body).
    """
    stripe = jnp.take(block, jnp.clip(ctx.row_idx, 0), axis=0)
    stripe = jnp.where((ctx.row_idx >= 0)[:, None], stripe, jnp.zeros((), stripe.dtype))
    stripe = jax.lax.dynamic_slice_in_dim(stripe, bcol0, width, axis=1)
    R = jax.lax.dynamic_update_slice_in_dim(R, stripe.astype(R.dtype), start, axis=1)
    return ctx, C, R


def _fused_step(ctx: AdaptiveCURCtx, C, block, bcol, sc_a, off):
    """Engine ``fused_step`` hook: score the pre-sliced panel sketch against
    the current admitted basis and run the admission/eviction policy,
    gathering candidate columns straight from the un-copied chunk operand
    (``block[:, bcol + j]``) — the per-panel (m × L) ``A_L`` slice the fused
    body exists to remove. Decision-for-decision (and bitwise, for
    column-independent sketch families) equal to the per-panel oracle."""
    Qm = _admitted_basis(ctx)
    resid2, col_energy = _score_columns(Qm, sc_a)
    ctx, C = _score_and_admit(ctx, C, block, bcol, sc_a, resid2, col_energy, off)
    return ctx, C, (resid2, col_energy)


def _kernel_ok(ctx: AdaptiveCURCtx) -> bool:
    """Static (trace-time) gate for the Route-B megakernel: TPU backend (or
    the forced test route), admission-only columns, fixed rows, and dense
    gaussian core sketches on both sides (the kernel contracts ``S_C.mat``
    and a dynamic window of ``S_R.mat`` directly)."""
    return (
        kernel_route_enabled()
        and not ctx.evict
        and ctx.rows is None
        and isinstance(ctx.S_C, GaussianSketch)
        and isinstance(ctx.S_R, GaussianSketch)
    )


def _supports_fused(ctx: AdaptiveCURCtx) -> bool:
    """Route-A gate: adaptive rows are per-panel by construction (the row
    accumulator + backfill chain can't be hoisted), and when the megakernel
    route is live the scan keeps the legacy per-panel body so Route B fires
    every panel instead."""
    return ctx.rows is None and not _kernel_ok(ctx)


def _panel_kernel(ctx: AdaptiveCURCtx, C, M, A_L, off):
    """Engine ``panel_kernel`` hook (Route B): one fused Pallas launch for
    the sketch, scoring, admission decision, ``C`` scatter and ``M`` fold
    (:func:`repro.kernels.ops.panel_update` — C/M aliased in place, ``sc_a``
    never round-trips HBM). Returns ``None`` at trace time when the config
    is outside the kernel's contract; the engine then runs the standard
    path. The whitening and the ctx slot-table scatters stay outside — they
    are O(s_c·c²) / O(s_c·L), independent of ``m``."""
    if not _kernel_ok(ctx):
        return None
    L = A_L.shape[1]
    c_total = C.shape[1]
    Qm = _admitted_basis(ctx)
    # S_R window for the M fold: M += sc_a @ S_R[:, off:off+L]ᵀ
    srt = jax.lax.dynamic_slice_in_dim(ctx.S_R.mat, off, L, axis=1).T  # (L, s_r)
    run_mean = ctx.energy / jnp.maximum(ctx.cols_seen, 1.0)
    true_cols = jnp.clip(ctx.n - off, 1, L).astype(jnp.float32)
    free = ctx.slot_lo + ctx.c_local - ctx.n_filled
    C, M, sc_a, resid2, energy, slots = kernel_panel_update(
        ctx.S_C.mat[:, : A_L.shape[0]], A_L, srt, Qm, C, M,
        min_gain=ctx.min_gain, run_mean=run_mean, true_cols=true_cols,
        n_filled=ctx.n_filled, free=free, panel_cap=ctx.panel_cap,
    )
    # slot-table bookkeeping: slots[j] is the C slot column j was admitted
    # into, or the c_total sentinel (OOB → scatter dropped)
    ctx = dataclasses.replace(
        ctx,
        ScC=ctx.ScC.at[:, slots].set(sc_a.astype(ctx.ScC.dtype), mode="drop"),
        col_idx=ctx.col_idx.at[slots].set(
            (off + jnp.arange(L)).astype(jnp.int32), mode="drop"
        ),
        slot_score=ctx.slot_score.at[slots].set(
            resid2.astype(ctx.slot_score.dtype), mode="drop"
        ),
        n_filled=ctx.n_filled + jnp.sum(slots < c_total).astype(jnp.int32),
        energy=ctx.energy + jnp.sum(energy),
        cols_seen=ctx.cols_seen + jnp.clip(ctx.n - off, 0, L).astype(ctx.cols_seen.dtype),
    )
    return ctx, C, M, sc_a, (resid2, energy)


# ---------------------------------------------------------------------------
# distributed hooks (disjoint-slot semantics; see repro.stream.distributed)
# ---------------------------------------------------------------------------


def _prep_shard(ctx: AdaptiveCURCtx, num_workers: int) -> AdaptiveCURCtx:
    """Static per-run shard prep: split the column (and row) slot budgets
    into ``/W`` per-worker ranges; raises when a budget doesn't divide."""
    if ctx.c_local % num_workers:
        raise ValueError(
            f"column budget c={ctx.c_local} must divide across {num_workers} workers"
        )
    rows = ctx.rows
    if rows is not None:
        if rows.r_local % num_workers:
            raise ValueError(
                f"row budget r={rows.r_local} must divide across {num_workers} workers"
            )
        rows = dataclasses.replace(rows, r_local=rows.r_local // num_workers)
    return dataclasses.replace(ctx, c_local=ctx.c_local // num_workers, rows=rows)


def _bind_shard(ctx: AdaptiveCURCtx, w) -> AdaptiveCURCtx:
    """Bind worker ``w`` (may be traced) to its disjoint slot ranges."""
    lo = (w * ctx.c_local).astype(jnp.int32)
    rows = ctx.rows
    if rows is not None:
        lo_r = (w * rows.r_local).astype(jnp.int32)
        rows = dataclasses.replace(rows, slot_lo=lo_r, n_filled=lo_r)
    return dataclasses.replace(ctx, slot_lo=lo, n_filled=lo, rows=rows)


def _merge_ctx(ctxs):
    """In-process merge of per-worker ctxs: slot ranges are disjoint, so the
    per-slot state sums exactly; ``row_sketch`` sums to the full-stream
    ``A S_Rᵀ`` because workers consumed disjoint column ranges."""
    base = ctxs[0]
    rows = None
    if base.rows is not None:
        rows = dataclasses.replace(
            base.rows,
            row_sketch=sum((c.rows.row_sketch for c in ctxs[1:]), base.rows.row_sketch),
            backfill=jnp.zeros_like(base.rows.backfill),  # per-panel scratch
            gram=jnp.zeros_like(base.rows.gram),  # worker-local prefix state
            gram_pending=jnp.zeros_like(base.rows.gram_pending),
            admit_off=jnp.max(jnp.stack([c.rows.admit_off for c in ctxs]), axis=0),
            n_filled=sum((c.rows.n_filled - c.rows.slot_lo) for c in ctxs).astype(jnp.int32),
            slot_lo=jnp.zeros((), jnp.int32),
            seen_lo=jnp.zeros((), jnp.int32),
            r_local=base.row_idx.shape[0],
        )
    return dataclasses.replace(
        base,
        ScC=sum((c.ScC for c in ctxs[1:]), base.ScC),  # slot ranges are disjoint
        col_idx=jnp.max(jnp.stack([c.col_idx for c in ctxs]), axis=0),  # −1 = unfilled
        row_idx=jnp.max(jnp.stack([c.row_idx for c in ctxs]), axis=0),
        slot_score=sum((c.slot_score for c in ctxs[1:]), base.slot_score),
        n_filled=sum((c.n_filled - c.slot_lo) for c in ctxs).astype(jnp.int32),
        slot_lo=jnp.zeros((), jnp.int32),
        energy=sum(c.energy for c in ctxs),
        cols_seen=sum(c.cols_seen for c in ctxs),
        n_evicted=sum(c.n_evicted for c in ctxs).astype(jnp.int32),
        rows=rows,
        c_local=base.col_idx.shape[0],
    )


def _merge_state(state: PanelState) -> PanelState:
    """Post-merge cross-worker **row dedup** (engine ``merge_state`` hook).

    Matrix rows are global — unlike the disjoint per-worker column ranges —
    so two workers can admit the *same* heavy row into different slots, and
    the merged state then spends two budget slots on one row (the
    rank-deficient core solve absorbs the duplication, but the budget is
    wasted). Reconciliation, entirely in the merged state:

    * every filled slot's **canonical** slot is the lowest-numbered slot
      holding the same row index;
    * each duplicate slot's ``R`` row is **added into** its canonical slot —
      workers consumed disjoint column ranges (and backfill only writes
      inside a worker's seen range), so the duplicates' column supports are
      disjoint and the sum is the union of what every admitting worker saw
      of that row;
    * the duplicate slots themselves are then zeroed and freed
      (``row_idx``/``admit_off`` → −1, ``n_filled`` decremented), restoring
      the unfilled-slot invariants the finalizer masks on.

    Canonical-slot selection is deterministic, so the scan and per-panel
    sharded drivers stay decision-for-decision equal. No-op when rows are
    fixed (duplicates are then the caller's explicit choice) and on
    single-host streams (in-stream admission already excludes admitted
    rows, so duplicates cannot arise without a merge).
    """
    ctx = state.ctx
    if ctx.rows is None:
        return state
    idx = ctx.row_idx
    r = idx.shape[0]
    filled = idx >= 0
    same = (idx[:, None] == idx[None, :]) & filled[:, None] & filled[None, :]
    canon = jnp.argmax(same, axis=0)  # lowest slot holding the same row
    dup = filled & (canon != jnp.arange(r))
    # T[i, j] = 1 ⇔ slot j's content lands in slot i. Duplicate slots are
    # never anyone's canonical slot, so T @ R consolidates *and* zeroes
    # them in one pass.
    T = (jnp.arange(r)[:, None] == jnp.where(filled, canon, r)[None, :])
    R = T.astype(state.R.dtype) @ state.R
    rows = ctx.rows
    # canonical slots keep the group's earliest admission offset
    admit_grp = jnp.min(
        jnp.where(same, rows.admit_off[None, :], jnp.iinfo(jnp.int32).max), axis=1
    )
    admit_off = jnp.where(dup, -1, jnp.where(filled, admit_grp, rows.admit_off))
    rows = dataclasses.replace(
        rows,
        admit_off=admit_off.astype(jnp.int32),
        n_filled=rows.n_filled - jnp.sum(dup).astype(jnp.int32),
    )
    ctx = dataclasses.replace(
        ctx, row_idx=jnp.where(dup, -1, idx).astype(jnp.int32), rows=rows
    )
    return dataclasses.replace(state, R=R, ctx=ctx)


def _collective_ctx(ctx: AdaptiveCURCtx, axis) -> AdaptiveCURCtx:
    """shard_map all-reduce mirror of :func:`_merge_ctx` (psum for the
    disjoint per-slot state, pmax for −1-sentinel index maps)."""
    rows = ctx.rows
    if rows is not None:
        rows = dataclasses.replace(
            rows,
            row_sketch=jax.lax.psum(rows.row_sketch, axis),
            backfill=jnp.zeros_like(rows.backfill),
            gram=jnp.zeros_like(rows.gram),  # worker-local prefix state
            gram_pending=jnp.zeros_like(rows.gram_pending),
            admit_off=jax.lax.pmax(rows.admit_off, axis),
            n_filled=jax.lax.psum(rows.n_filled - rows.slot_lo, axis).astype(jnp.int32),
            slot_lo=jnp.zeros((), jnp.int32),
            seen_lo=jnp.zeros((), jnp.int32),
        )
    return dataclasses.replace(
        ctx,
        ScC=jax.lax.psum(ctx.ScC, axis),
        col_idx=jax.lax.pmax(ctx.col_idx, axis),
        row_idx=jax.lax.pmax(ctx.row_idx, axis),
        slot_score=jax.lax.psum(ctx.slot_score, axis),
        n_filled=jax.lax.psum(ctx.n_filled - ctx.slot_lo, axis).astype(jnp.int32),
        slot_lo=jnp.zeros((), jnp.int32),
        energy=jax.lax.psum(ctx.energy, axis),
        cols_seen=jax.lax.psum(ctx.cols_seen, axis),
        n_evicted=jax.lax.psum(ctx.n_evicted, axis).astype(jnp.int32),
        rows=rows,
    )


ADAPTIVE_CUR_OPS = PanelOps(
    name="adaptive_cur",
    core_sketches=_core_sketches,
    sketch_panel=_sketch_panel,
    update_c=_update_c,
    update_r=_update_r,
    prep_shard=_prep_shard,
    bind_shard=_bind_shard,
    merge_ctx=_merge_ctx,
    collective_ctx=_collective_ctx,
    merge_state=_merge_state,
    chunk_fold=_chunk_fold,
    fused_step=_fused_step,
    supports_fused=_supports_fused,
    panel_kernel=_panel_kernel,
)

# Telemetered twin of ADAPTIVE_CUR_OPS — same hooks plus the per-panel
# diagnostics fold. A module-level instance (not a per-init replace) so every
# telemetered init shares one ops object and the engine's jit caches stay hot.
ADAPTIVE_CUR_TEL_OPS = dataclasses.replace(
    ADAPTIVE_CUR_OPS, telemetry=adaptive_stream_telemetry
)


def adaptive_cur_init(
    key,
    m: int,
    n: int,
    c: int,
    row_idx: Optional[jax.Array] = None,
    *,
    r: Optional[int] = None,
    s_c: Optional[int] = None,
    s_r: Optional[int] = None,
    eps: float = 0.05,
    rho_est: float = 2.0,
    sketch: str = "countsketch",
    osnap_p: int = 2,
    min_gain: float = 2.0,
    panel_cap: Optional[int] = None,
    swap_gain: Optional[float] = None,
    min_gain_rows: Optional[float] = None,
    panel_cap_rows: Optional[int] = None,
    dtype=jnp.float32,
    sketches=None,
    panel: Optional[int] = None,
    telemetry: bool = False,
) -> PanelState:
    """Allocate an adaptive streaming-CUR state with an empty column budget.

    Args:
        key: PRNG key for the core sketches (ignored when ``sketches`` given).
        m, n: stream shape — ``A`` is (m, n), arriving as column panels.
        c: column budget; slots are filled in-stream by residual admission.
        row_idx: fixed pre-pass row indices (r,). Pass ``None`` together with
            ``r=`` to enable adaptive in-stream **row admission** instead.
        r: row budget when ``row_idx is None`` (adaptive rows).
        s_c, s_r: core sketch sizes; default to the Table-2
            :func:`repro.cur.cur.cur_sketch_sizes` for ``(c, r, eps, rho_est)``.
        eps, rho_est: Table-2 sketch-size parameters.
        sketch: column-sliceable core sketch family
            (``countsketch`` / ``osnap`` / ``gaussian``).
        osnap_p: nonzeros per column for the OSNAP family.
        min_gain: data-relative column admission threshold — a column must
            carry ``min_gain ×`` the mean column energy *outside* the current
            admitted basis.
        panel_cap: max column admissions (or evictions) per panel; defaults
            to ``max(1, c // 8)`` so the budget survives past the first panels.
        swap_gain: **eviction** threshold — once the budget is full, an
            eligible candidate evicts the weakest admitted slot when its
            residual clears ``swap_gain ×`` that slot's retained-energy
            score. ``None`` (default) disables eviction (v1 admission-only).
        min_gain_rows: row admission threshold (default: ``min_gain``) — a
            row must carry ``min_gain_rows ×`` the mean per-row sketch energy
            outside the admitted row span.
        panel_cap_rows: max row admissions per panel (default ``max(1, r//8)``).
        dtype: accumulator dtype.
        sketches: optional pre-drawn ``(S_C, S_R)`` pair (shared randomness).
        panel: fixed streaming panel width — pre-pads ``R``/``S_R`` so ragged
            tails can be zero-padded exactly (see :mod:`repro.stream.engine`).
        telemetry: attach an in-scan diagnostics frame
            (:class:`repro.obs.telemetry.TelemetryFrame` — admission/eviction
            counts, score quantiles, and the a-posteriori error estimator's
            test sketch; see :func:`repro.obs.estimate_rel_error`). Requires
            ``panel=``; factors are bit-identical with it on or off.

    Returns:
        A :class:`~repro.stream.engine.PanelState` wired to
        :data:`ADAPTIVE_CUR_OPS`; drive it with ``stream_panels`` /
        ``simulate_sharded_stream`` / ``mesh_sharded_stream`` and finish with
        :func:`adaptive_cur_finalize`.
    """
    from ..cur.cur import cur_sketch_sizes  # lazy: repro.cur imports repro.stream

    adaptive_rows = row_idx is None
    if adaptive_rows:
        if r is None:
            raise ValueError("pass `row_idx` (fixed rows) or `r=` (adaptive rows)")
        row_idx_arr = jnp.full((r,), -1, jnp.int32)
    else:
        if r is not None:
            raise ValueError(
                "`r=` is the adaptive-row budget and requires `row_idx=None`; "
                "with fixed `row_idx` the budget is its length"
            )
        # Copy, not view: the scan path donates the state's buffers, and a
        # zero-copy asarray would hand the caller's own array to the donor.
        row_idx_arr = jnp.array(row_idx, jnp.int32)
        r = row_idx_arr.shape[0]
    n_pad = padded_n(n, panel) if panel else n
    if sketches is None:
        sizes = cur_sketch_sizes(c, r, eps=eps, rho=rho_est)
        s_c = min(s_c or sizes["s_c"], m)
        s_r = min(s_r or sizes["s_r"], n)
        k_sc, k_sr = jax.random.split(key)
        S_C = draw_sketch(k_sc, sketch, s_c, m, p=osnap_p, dtype=dtype)
        S_R = draw_sketch(k_sr, sketch, s_r, n, p=osnap_p, dtype=dtype)
    else:
        S_C, S_R = fresh_pytree(sketches)  # donation-safe copies
        s_c, s_r = S_C.s, S_R.s
    S_R.cols(0, 1)  # fail fast on non-sliceable families
    S_R = S_R.pad_cols(n_pad)
    rows = None
    if adaptive_rows:
        rows = AdaptiveRowState(
            row_sketch=jnp.zeros((m, s_r), jnp.float32),
            backfill=jnp.zeros((r, s_r), jnp.float32),
            admit_off=jnp.full((r,), -1, jnp.int32),
            gram=jnp.zeros((s_r, s_r), jnp.float32),
            gram_pending=jnp.zeros((s_r, s_r), jnp.float32),
            # dense S_R once, at init: every per-panel window Gram and every
            # backfill prefix map is a slice of this — the streaming loop
            # never materializes a sketch again
            sr_dense=S_R.materialize().astype(jnp.float32),
            n_filled=jnp.zeros((), jnp.int32),
            slot_lo=jnp.zeros((), jnp.int32),
            min_gain=jnp.asarray(
                min_gain if min_gain_rows is None else min_gain_rows, jnp.float32
            ),
            seen_lo=jnp.full((), -1, jnp.int32),
            r_local=r,
            panel_cap=panel_cap_rows if panel_cap_rows is not None else max(1, r // 8),
        )
    ctx = AdaptiveCURCtx(
        col_idx=jnp.full((c,), -1, jnp.int32),
        row_idx=row_idx_arr,
        S_C=S_C,
        S_R=S_R,
        ScC=jnp.zeros((s_c, c), dtype),
        slot_score=jnp.zeros((c,), jnp.float32),
        n_filled=jnp.zeros((), jnp.int32),
        slot_lo=jnp.zeros((), jnp.int32),
        energy=jnp.zeros((), jnp.float32),
        cols_seen=jnp.zeros((), jnp.float32),
        min_gain=jnp.asarray(min_gain, jnp.float32),
        swap_gain=jnp.asarray(
            jnp.inf if swap_gain is None else swap_gain, jnp.float32
        ),
        n_evicted=jnp.zeros((), jnp.int32),
        rows=rows,
        c_local=c,
        panel_cap=panel_cap if panel_cap is not None else max(1, c // 8),
        n=n,
        evict=swap_gain is not None,
    )
    tel = None
    ops = ADAPTIVE_CUR_OPS
    if telemetry:
        if panel is None:
            raise ValueError(
                "telemetry=True requires a fixed panel= width (the diagnostics "
                "frame is indexed by global panel id)"
            )
        # Independent key for the estimator's held-out test sketch: folding a
        # constant into the init key keeps it disjoint from the S_C/S_R draws
        # (which use split(key)) while staying reproducible from one seed.
        tel = init_telemetry(jax.random.fold_in(key, 7), m, n, panel)
        ops = ADAPTIVE_CUR_TEL_OPS
    return PanelState(
        C=jnp.zeros((m, c), dtype),
        R=jnp.zeros((r, n_pad), dtype),
        M=jnp.zeros((s_c, s_r), dtype),
        offset=jnp.zeros((), jnp.int32),
        ctx=ctx,
        ops=ops,
        n=n,
        tel=tel,
    )


def adaptive_cur_finalize(state: PanelState):
    """Fast-GMR core solve on the admitted columns/rows.

    Unfilled slots (zero columns of ``C`` / zero rows of ``R``) get zeroed
    core rows/columns so they cannot inject the floored solve's
    large-but-finite garbage into downstream consumers.

    Returns:
        A :class:`~repro.cur.cur.CURResult`; ``col_idx``/``row_idx`` hold
        the admitted (post-eviction) index sets with −1 in unfilled slots.
    """
    from ..cur.cur import CURResult  # lazy: repro.cur imports repro.stream

    ctx = state.ctx
    R = truncated_R(state)
    RSr = ctx.S_R.apply_t(R)  # (r, s_r)
    U = fast_gmr_core(ctx.ScC, state.M, RSr)  # ScC ≡ S_C C by construction
    filled_c = ctx.col_idx >= 0
    U = jnp.where(filled_c[:, None], U, jnp.zeros((), U.dtype))
    if ctx.rows is not None:
        filled_r = ctx.row_idx >= 0
        U = jnp.where(filled_r[None, :], U, jnp.zeros((), U.dtype))
    return CURResult(C=state.C, U=U, R=R, col_idx=ctx.col_idx, row_idx=ctx.row_idx)


# Compiled at module scope (one trace per shape); the state is NOT donated —
# callers inspect it (n_evicted, admit_off, …) after finalizing.
adaptive_cur_finalize = jax.jit(adaptive_cur_finalize)
