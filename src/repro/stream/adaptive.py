"""Adaptive in-stream column admission for streaming CUR.

Fixed-index streaming CUR must pick its ``col_idx`` before the pass — a
single uniform pre-pass draw misses the heavy columns of spiked spectra.
This module closes that gap (ROADMAP open item 1) with a *residual-driven*
admission policy in the spirit of Wang & Zhang 2016's adaptive sampling,
computable **from the sketches alone** so the single-pass contract is kept:

Per panel the engine already computes ``sc_a = S_C A_L`` for the M update.
For each panel column ``y = S_C a_j`` we score how much of it lies outside
the span of the already-admitted (sketched) columns ``S_C C``:

    ``score_j = || y − (S_C C)(S_C C)⁺ y ||²``

(the sketched least-squares residual; ``S_C`` preserves these norms to
(1±ε) by the subspace-embedding property). A column is *admitted* into the
next free ``C`` slot when its score clears ``min_gain ×`` the mean column
energy — the larger of the running-stream mean and the current panel's mean,
so noise columns are never "eligible by default" on a cold start — with at
most ``panel_cap`` admissions per panel so the budget isn't exhausted early.

Bookkeeping is O(s_c·c) extra memory (the ``ScC`` basis copy) and the scorer
is one (s_c × c_local) QR per panel. Everything is jit-compatible: admission
uses a rank/slot scatter with ``mode='drop'`` so traced shapes stay static.

Distributed: each DP worker admits into its own ``c/W`` slot range
(``prep_shard``/``bind_shard``), so merged states never collide; the merged
result is a valid admission outcome but — unlike the fixed-index paths — not
bitwise equal to single-host admission (workers score against their local
basis only).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.gmr import _solve_least_squares, fast_gmr_core
from ..core.sketching import draw_sketch
from .engine import PanelOps, PanelState, padded_n, truncated_R

__all__ = [
    "AdaptiveCURCtx",
    "ADAPTIVE_CUR_OPS",
    "adaptive_cur_init",
    "adaptive_cur_finalize",
]


@dataclasses.dataclass(frozen=True)
class AdaptiveCURCtx:
    """Admission state threaded through the panel stream."""

    col_idx: jax.Array  # (c,) int32, −1 = unfilled slot
    row_idx: jax.Array  # (r,) int32 — rows stay fixed pre-pass
    S_C: object  # (s_c, m) column-sliceable core sketch
    S_R: object  # (s_r, n_pad)
    ScC: jax.Array  # (s_c, c) — sketches of the admitted columns, by slot
    n_filled: jax.Array  # () int32 — next free slot (within this worker's range)
    slot_lo: jax.Array  # () int32 — first slot this worker may fill
    energy: jax.Array  # () f32 — running Σ ||S_C a_j||² over seen columns
    cols_seen: jax.Array  # () f32 — true (unpadded) columns seen
    min_gain: jax.Array  # () f32 — admission threshold multiplier
    c_local: int  # static: number of slots this worker owns
    panel_cap: int  # static: max admissions per panel
    n: int  # static: true column count of the stream


jax.tree_util.register_dataclass(
    AdaptiveCURCtx,
    data_fields=[
        "col_idx", "row_idx", "S_C", "S_R", "ScC",
        "n_filled", "slot_lo", "energy", "cols_seen", "min_gain",
    ],
    meta_fields=["c_local", "panel_cap", "n"],
)


def _core_sketches(ctx):
    return ctx.S_C, ctx.S_R


def _r_block(ctx, A_L, off):
    return jnp.take(A_L, ctx.row_idx, axis=0)


def _update_c(ctx: AdaptiveCURCtx, C, A_L, sc_a, off):
    """Score this panel's columns against the admitted basis; admit the top
    residual columns into free slots of this worker's range."""
    L = A_L.shape[1]
    c_total = C.shape[1]

    # Sketched residual against the worker's local slot range. The range is
    # filled as a zero-suffixed prefix, which keeps the floored triangular
    # solve in _solve_least_squares an *exact* projection onto the filled
    # span (trailing all-zero columns contribute nothing).
    ScC_local = jax.lax.dynamic_slice_in_dim(ctx.ScC, ctx.slot_lo, ctx.c_local, axis=1)
    X = _solve_least_squares(ScC_local, sc_a)  # (c_local, L)
    resid2 = jnp.sum((sc_a - ScC_local @ X) ** 2, axis=0)  # (L,)

    # Admission threshold: min_gain × the mean column energy, where the mean
    # is the larger of the running stream mean and the current panel's mean
    # (over true, unpadded columns). The panel term matters on each worker's
    # first panels — with a 0 running mean every noise column would otherwise
    # be "eligible" and greedily exhaust the slot budget before any heavy
    # column arrives.
    col_energy = jnp.sum(sc_a * sc_a, axis=0)  # (L,)
    true_cols = jnp.clip(ctx.n - off, 1, L).astype(jnp.float32)
    panel_mean = jnp.sum(col_energy) / true_cols
    run_mean = ctx.energy / jnp.maximum(ctx.cols_seen, 1.0)
    thresh = ctx.min_gain * jnp.maximum(run_mean, panel_mean)
    eligible = resid2 > thresh  # strict: zero-padded tail columns never pass
    # Rank eligible columns by residual energy (ineligible sort last: resid2 ≥ 0 > −1).
    ranked = jnp.argsort(-jnp.where(eligible, resid2, -1.0))
    free = ctx.slot_lo + ctx.c_local - ctx.n_filled
    cap = jnp.minimum(jnp.minimum(free, jnp.sum(eligible)), ctx.panel_cap)
    slots = jnp.where(jnp.arange(L) < cap, ctx.n_filled + jnp.arange(L), c_total)

    C = C.at[:, slots].set(jnp.take(A_L, ranked, axis=1).astype(C.dtype), mode="drop")
    ScC = ctx.ScC.at[:, slots].set(jnp.take(sc_a, ranked, axis=1).astype(ctx.ScC.dtype), mode="drop")
    col_idx = ctx.col_idx.at[slots].set((off + ranked).astype(jnp.int32), mode="drop")

    ctx = dataclasses.replace(
        ctx,
        ScC=ScC,
        col_idx=col_idx,
        n_filled=ctx.n_filled + cap.astype(jnp.int32),
        energy=ctx.energy + jnp.sum(col_energy),
        cols_seen=ctx.cols_seen + jnp.clip(ctx.n - off, 0, L).astype(ctx.cols_seen.dtype),
    )
    return ctx, C


def _prep_shard(ctx: AdaptiveCURCtx, num_workers: int) -> AdaptiveCURCtx:
    if ctx.c_local % num_workers:
        raise ValueError(
            f"column budget c={ctx.c_local} must divide across {num_workers} workers"
        )
    return dataclasses.replace(ctx, c_local=ctx.c_local // num_workers)


def _bind_shard(ctx: AdaptiveCURCtx, w) -> AdaptiveCURCtx:
    lo = (w * ctx.c_local).astype(jnp.int32)
    return dataclasses.replace(ctx, slot_lo=lo, n_filled=lo)


def _merge_ctx(ctxs):
    base = ctxs[0]
    return dataclasses.replace(
        base,
        ScC=sum((c.ScC for c in ctxs[1:]), base.ScC),  # slot ranges are disjoint
        col_idx=jnp.max(jnp.stack([c.col_idx for c in ctxs]), axis=0),  # −1 = unfilled
        n_filled=sum((c.n_filled - c.slot_lo) for c in ctxs).astype(jnp.int32),
        slot_lo=jnp.zeros((), jnp.int32),
        energy=sum(c.energy for c in ctxs),
        cols_seen=sum(c.cols_seen for c in ctxs),
        c_local=base.col_idx.shape[0],
    )


def _collective_ctx(ctx: AdaptiveCURCtx, axis) -> AdaptiveCURCtx:
    return dataclasses.replace(
        ctx,
        ScC=jax.lax.psum(ctx.ScC, axis),
        col_idx=jax.lax.pmax(ctx.col_idx, axis),
        n_filled=jax.lax.psum(ctx.n_filled - ctx.slot_lo, axis).astype(jnp.int32),
        slot_lo=jnp.zeros((), jnp.int32),
        energy=jax.lax.psum(ctx.energy, axis),
        cols_seen=jax.lax.psum(ctx.cols_seen, axis),
    )


ADAPTIVE_CUR_OPS = PanelOps(
    name="adaptive_cur",
    core_sketches=_core_sketches,
    update_c=_update_c,
    r_block=_r_block,
    prep_shard=_prep_shard,
    bind_shard=_bind_shard,
    merge_ctx=_merge_ctx,
    collective_ctx=_collective_ctx,
)


def adaptive_cur_init(
    key,
    m: int,
    n: int,
    c: int,
    row_idx: jax.Array,
    *,
    s_c: Optional[int] = None,
    s_r: Optional[int] = None,
    eps: float = 0.05,
    rho_est: float = 2.0,
    sketch: str = "countsketch",
    osnap_p: int = 2,
    min_gain: float = 2.0,
    panel_cap: Optional[int] = None,
    dtype=jnp.float32,
    sketches=None,
    panel: Optional[int] = None,
) -> PanelState:
    """Allocate an adaptive streaming-CUR state with an empty column budget.

    ``c`` slots are filled in-stream by residual admission; ``row_idx`` stays
    fixed (row selection is a ROADMAP follow-up). ``panel_cap`` defaults to
    ``max(1, c // 8)`` so the budget survives past the first panels;
    ``min_gain`` is the data-relative admission threshold (a column must
    carry ``min_gain×`` the mean column energy *outside* the current basis).
    Pass ``panel=`` to pre-pad ``R``/``S_R`` for ragged-tail zero padding.
    """
    from ..cur.cur import cur_sketch_sizes  # lazy: repro.cur imports repro.stream

    row_idx = jnp.asarray(row_idx, jnp.int32)
    r = row_idx.shape[0]
    n_pad = padded_n(n, panel) if panel else n
    if sketches is None:
        sizes = cur_sketch_sizes(c, r, eps=eps, rho=rho_est)
        s_c = min(s_c or sizes["s_c"], m)
        s_r = min(s_r or sizes["s_r"], n)
        k_sc, k_sr = jax.random.split(key)
        S_C = draw_sketch(k_sc, sketch, s_c, m, p=osnap_p, dtype=dtype)
        S_R = draw_sketch(k_sr, sketch, s_r, n, p=osnap_p, dtype=dtype)
    else:
        S_C, S_R = sketches
        s_c, s_r = S_C.s, S_R.s
    S_R.cols(0, 1)  # fail fast on non-sliceable families
    S_R = S_R.pad_cols(n_pad)
    ctx = AdaptiveCURCtx(
        col_idx=jnp.full((c,), -1, jnp.int32),
        row_idx=row_idx,
        S_C=S_C,
        S_R=S_R,
        ScC=jnp.zeros((s_c, c), dtype),
        n_filled=jnp.zeros((), jnp.int32),
        slot_lo=jnp.zeros((), jnp.int32),
        energy=jnp.zeros((), jnp.float32),
        cols_seen=jnp.zeros((), jnp.float32),
        min_gain=jnp.asarray(min_gain, jnp.float32),
        c_local=c,
        panel_cap=panel_cap if panel_cap is not None else max(1, c // 8),
        n=n,
    )
    return PanelState(
        C=jnp.zeros((m, c), dtype),
        R=jnp.zeros((r, n_pad), dtype),
        M=jnp.zeros((s_c, s_r), dtype),
        offset=jnp.zeros((), jnp.int32),
        ctx=ctx,
        ops=ADAPTIVE_CUR_OPS,
        n=n,
    )


def adaptive_cur_finalize(state: PanelState):
    """Fast-GMR core solve on the admitted columns; unfilled slots (zero
    columns of C) get zeroed core rows so they cannot inject the floored
    solve's large-but-finite garbage into downstream consumers."""
    from ..cur.cur import CURResult  # lazy: repro.cur imports repro.stream

    ctx = state.ctx
    R = truncated_R(state)
    RSr = ctx.S_R.apply_t(R)  # (r, s_r)
    U = fast_gmr_core(ctx.ScC, state.M, RSr)  # ScC ≡ S_C C by construction
    filled = ctx.col_idx >= 0
    U = jnp.where(filled[:, None], U, jnp.zeros((), U.dtype))
    return CURResult(C=state.C, U=U, R=R, col_idx=ctx.col_idx, row_idx=ctx.row_idx)
