"""Unified panel-streaming engine.

The paper's two streaming applications — single-pass SVD (Algorithm 3,
``repro.core.svd``) and streaming CUR (``repro.cur.streaming``) — share one
contract: the input ``A`` arrives as L-column panels ``A_L`` that are never
retained, and three accumulators are maintained per panel

* ``C``  (m × c)   — a column factor (sketched columns for SP-SVD, actual
  selected columns for CUR);
* ``R``  (r × n)   — a row factor filled block-by-block at the panel's
  column offset;
* ``M``  (s_c × s_r) — the running core sketch
  ``M += (S_C A_L) · S_R[:, cols]ᵀ`` via the ``cols()`` sketch-window
  primitive of :mod:`repro.core.sketching`.

**Symmetric (tied-operand) streams.** A :class:`PanelOps` may declare
``symmetric=True`` for square streams where the row factor is *tied* to the
column factor — SPSD / kernel matrices with ``R = Cᵀ``
(:mod:`repro.spsd.streaming`, ``repro.cur.symmetric_cur``). The engine then
skips the redundant R half of every panel update entirely: the state's ``R``
is a zero-row placeholder ``(0, n_pad)`` (so the scan/donation/merge/psum
machinery is untouched), :func:`truncated_R` *derives* ``R = Cᵀ`` from the
column factor, and the per-panel work drops to the C update + the shared M
accumulation. Both sketches of ``core_sketches`` live on the same
``n``-dimensional operand space (one sketch family over one index set
instead of two); they may still be independent draws — Algorithm 2's
analysis requires ``S₁ ⊥ S₂``.

This module owns that contract once. Applications plug in a
:class:`PanelOps` — three pure functions describing how their ``C``
contribution and ``R`` block are computed from a panel — and get the shared
machinery for free: a scan-compiled whole-stream driver
(:func:`stream_panels`, the default — one ``lax.scan`` program per chunk
with the input state's buffers donated so C/R/M update in place), a
jit-cached per-panel step (:func:`panel_update` /
:data:`jitted_panel_update`, retained behind ``jit="per-panel"`` as the
parity oracle), zero-padded ragged-tail handling (exact because
``pad_cols()`` sketch windows past the true column count are zero-scaled),
and DP-sharded ingestion with exact psum/merge finalize
(:mod:`repro.stream.distributed`).

Panel width does not change the mathematics: ``Σ_L S_C A_L S_R[:, cols]ᵀ =
S_C A S_Rᵀ`` exactly, so any panel partition — including the per-worker
partitions of the distributed path — reproduces the one-shot accumulators up
to fp32 summation order.

**Donation contract:** the scan path donates the input state's buffers to
the output state (``donate_argnums``), so a caller must treat
``stream_panels(state, …)`` as *consuming* ``state`` — keep only the
returned state. Chunked ingestion (repeated calls on the same logical
stream) composes naturally: each call consumes the previous call's output.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..obs.spans import span
from ..obs.telemetry import EVENT_QUARANTINED, fold_psi_chunk

__all__ = [
    "PanelOps",
    "PanelState",
    "panel_update",
    "jitted_panel_update",
    "stream_panels",
    "scan_chunk",
    "scan_panels",
    "padded_n",
    "fresh_pytree",
    "copy_selected_columns",
    "truncated_R",
    "with_quarantine",
    "zero_nonfinite_panels",
]


def copy_selected_columns(col_idx, C, A_L, off):
    """Slot-copy C update shared by the fixed-index plug-ins: every panel
    column whose global index appears in ``col_idx`` lands in that slot.

    ``off`` may be traced; out-of-panel (and −1-sentinel) slots pass
    through unchanged. Used by streaming CUR (``repro.cur.streaming``) and
    streaming SPSD (``repro.spsd.streaming``) so the panel window math
    lives in one place.
    """
    L = A_L.shape[1]
    rel = col_idx - off
    in_panel = (rel >= 0) & (rel < L)
    picked = jnp.take(A_L, jnp.clip(rel, 0, L - 1), axis=1)  # (m, c)
    return jnp.where(in_panel[None, :], picked.astype(C.dtype), C)


@dataclasses.dataclass(frozen=True)
class PanelOps:
    """The per-application slice of the streaming contract (static metadata).

    All callables must be jit-traceable. ``ctx`` is an application-defined
    pytree holding sketches / indices / adaptive state; the engine threads it
    through every update.
    """

    name: str
    # ctx -> (S_C-like, S_R-like): the core sketches driving the M update.
    core_sketches: Callable[[Any], tuple]
    # (ctx, C, A_L, sc_a, off) -> (ctx', C'): fold one panel into C.
    # ``sc_a = S_C @ A_L`` is pre-computed by the engine (shared with the M
    # update) so residual-scoring policies get it for free. When
    # ``sketch_panel`` (below) is set, update_c instead receives a sixth
    # positional argument — the scores tuple returned by that hook.
    update_c: Callable[..., tuple]
    # Optional fused panel-sketch hook: (ctx, A_L, off) -> (ctx', sc_a,
    # scores). When set it REPLACES the engine's own ``S_C.apply(A_L)`` so an
    # application can compute ``sc_a`` *and* per-column scores in one fused
    # pass (on TPU, one VMEM pass via the kernels.panel_score Pallas kernel
    # instead of three HBM round-trips); ``scores`` is forwarded verbatim to
    # ``update_c`` as its sixth argument. Must be jit-traceable and must
    # return ``sc_a`` bit-compatible with ``S_C.apply(A_L)``'s contract (it
    # also feeds the shared M update).
    sketch_panel: Optional[Callable] = None
    # (ctx, A_L, off) -> (r, L) block written into R[:, off:off+L]. May be
    # omitted when update_r (below) is provided instead.
    r_block: Optional[Callable[..., jax.Array]] = None
    # Optional full-control R update: (ctx, R, A_L, off) -> R'. When set it
    # REPLACES the r_block/dynamic_update_slice path, so applications that
    # must write outside the current panel window — e.g. adaptive row
    # admission backfilling a late-admitted row's column prefix from its
    # sketched reconstruction (repro.stream.adaptive) — can do so. The hook
    # receives the post-update_c ctx, so per-panel admission decisions made
    # in update_c are visible. Must be jit-traceable and must only *add*
    # information at columns < off + L (the single-pass contract: future
    # columns have not been seen).
    update_r: Optional[Callable] = None
    # Optional distributed hooks (see repro.stream.distributed):
    # prep_shard(ctx, num_workers) -> ctx   — static, once per run (meta edits)
    # bind_shard(ctx, w) -> ctx             — per worker, w may be traced
    # merge_ctx(ctxs) -> ctx                — in-process merge of worker ctxs
    # collective_ctx(ctx, axis_name) -> ctx — shard_map all-reduce of ctx state
    prep_shard: Optional[Callable] = None
    bind_shard: Optional[Callable] = None
    merge_ctx: Optional[Callable] = None
    collective_ctx: Optional[Callable] = None
    # merge_state(state) -> state — optional post-merge reconciliation run by
    # every distributed driver AFTER the accumulators and ctx are merged
    # (in-process merge, fused simulate, and the shard_map body alike). Unlike
    # merge_ctx it sees the full PanelState, so cross-worker repairs that
    # touch the accumulators — e.g. the adaptive row-admission dedup zeroing
    # duplicate R rows (repro.stream.adaptive) — live here. Must be
    # jit-traceable and deterministic (the mesh path evaluates it replicated
    # on every shard).
    merge_state: Optional[Callable] = None
    # Optional in-scan telemetry hook (repro.obs.telemetry):
    # (tel, ctx_pre, ctx_post, A_L, sc_a, scores, off) -> tel'. Runs AFTER
    # the C/R/M updates of a panel, only when the state actually carries a
    # telemetry frame (state.tel is not None), and may only derive
    # diagnostics — factors are bit-identical with telemetry on or off, and
    # an untelemetered state (tel=None contributes no pytree leaves)
    # compiles to the identical scan program. Contract: the hook may read
    # A_L's static shape only, never its values — the fused scan route
    # passes a (0, panel) placeholder so the panel is not re-sliced.
    telemetry: Optional[Callable] = None
    # --- fused scan-body hooks (Route A — see scan_chunk/scan_panels) -----
    # Declaring chunk_fold opts the ops into the fused scan body: the
    # engine hoists the chunk sketch sca = S_C.apply(window) out of the
    # scan, runs chunk_fold ONCE per chunk for all whole-chunk work, and
    # the per-panel body shrinks to slicing sc_a out of sca + the M fold +
    # fused_step. The per-panel driver (panel_update) stays the parity
    # oracle; a fused ops must produce factors matching it to the scan
    # parity tolerances (bitwise where those tests demand it).
    #
    # chunk_fold(ctx, C, R, block, bcol0, start, width) -> (ctx', C', R'):
    # fold everything panel-invariant over the whole chunk in one pass —
    # fixed-index C column copies, fixed-row R gather + one window write.
    # ``block`` columns [bcol0, bcol0+width) are the chunk's global columns
    # [start, start+width); bcol0/start may be traced.
    chunk_fold: Optional[Callable] = None
    # fused_step(ctx, C, block, bcol, sc_a, off) -> (ctx', C', scores):
    # the genuinely per-panel remainder (adaptive admission/eviction).
    # ``sc_a`` is the pre-sliced panel sketch; candidate columns must be
    # gathered from ``block`` at column ``bcol`` (+ the in-panel index)
    # instead of materializing A_L — that slice is the traffic the fused
    # body removes. None ⇒ no per-panel C/ctx work (fixed-index ops).
    fused_step: Optional[Callable] = None
    # supports_fused(ctx) -> bool — static (trace-time) predicate gating
    # the fused route per state; None ⇒ always. Used to keep configs whose
    # per-panel work cannot be hoisted (e.g. adaptive row admission) on the
    # legacy body.
    supports_fused: Optional[Callable] = None
    # --- Pallas megakernel hook (Route B — see kernels.panel_update) ------
    # panel_kernel(ctx, C, M, A_L, off) -> None | (ctx', C', M', sc_a,
    # scores). Tried FIRST by panel_update: when the hook accepts (TPU
    # backend or a forced test route, kernel-compatible sketches/config) it
    # replaces the sketch + M fold + update_c with one fused kernel launch;
    # returning None at trace time declines and the standard path runs.
    # R-side and telemetry handling are unchanged around it.
    panel_kernel: Optional[Callable] = None
    # Tied-operand (symmetric) stream: the row factor is R = Cᵀ by
    # definition (SPSD / kernel matrices), so the engine skips the R half of
    # every panel update and `truncated_R` derives R from C. Symmetric ops
    # must not declare r_block/update_r, and their state's R must be the
    # (0, n_pad) placeholder.
    symmetric: bool = False

    def __post_init__(self):
        """Fail fast at construction: a symmetric (tied-operand) ops derives
        ``R = Cᵀ`` and must not declare an R hook; otherwise the R update
        must come from exactly one of ``r_block`` / ``update_r`` (a missing
        hook would surface as an opaque NoneType call inside the jitted
        step)."""
        if self.symmetric:
            if self.r_block is not None or self.update_r is not None:
                raise ValueError(
                    f"PanelOps {self.name!r} is symmetric (R = Cᵀ is derived); "
                    "it must not declare r_block / update_r"
                )
        elif (self.r_block is None) == (self.update_r is None):
            raise ValueError(
                f"PanelOps {self.name!r} needs exactly one of r_block / update_r"
            )


@dataclasses.dataclass
class PanelState:
    """Streaming accumulators + application context.

    ``R`` is allocated at the padded width ``ceil(n/panel)·panel`` when a
    fixed panel width is declared at init; ``n`` records the true column
    count so finalizers can truncate.

    ``tel`` is the optional in-scan diagnostics frame
    (:class:`repro.obs.telemetry.TelemetryFrame`): ``None`` — the default —
    contributes no pytree leaves, so untelemetered states keep their
    pre-telemetry treedef, jit cache keys and donation layout.

    ``quarantined`` is the optional graceful-degradation counter
    (:func:`with_quarantine`): ``None`` — the default — contributes no
    leaves and compiles to the exact pre-quarantine program; a ``()`` int32
    arms the in-scan non-finite panel guard, which zero-scales any panel
    carrying a NaN/Inf entry (its contribution to C/R/M becomes *exactly*
    that of an all-zero panel) and counts it here instead of letting one
    corrupt panel poison every accumulator downstream.
    """

    C: jax.Array  # (m, c)
    R: jax.Array  # (r, n_pad)
    M: jax.Array  # (s_c, s_r)
    offset: jax.Array  # () int32 — columns consumed so far (global)
    ctx: Any  # application pytree (sketches, indices, adaptive state)
    ops: PanelOps  # static
    n: int  # static: true column count
    tel: Any = None  # optional in-scan telemetry frame (repro.obs)
    quarantined: Any = None  # optional () int32 — non-finite panels zeroed in-scan

    def __getattr__(self, name):
        # Back-compat with the pre-engine SPSVDState / StreamingCURState
        # surfaces: delegate unknown attributes (S_C, col_idx, …) to ctx.
        ctx = object.__getattribute__(self, "ctx")
        try:
            return getattr(ctx, name)
        except AttributeError:
            raise AttributeError(
                f"{type(self).__name__} has no attribute {name!r} (nor does its ctx)"
            ) from None

    @property
    def sketches(self):
        """Legacy ``SPSVDState.sketches`` alias for the application ctx."""
        return self.ctx


jax.tree_util.register_dataclass(
    PanelState,
    data_fields=["C", "R", "M", "offset", "ctx", "tel", "quarantined"],
    meta_fields=["ops", "n"],
)


def with_quarantine(state: PanelState) -> PanelState:
    """Arm the in-scan non-finite panel guard on ``state``.

    Returns the state with a zeroed ``()`` int32 ``quarantined`` counter
    leaf. From then on every :func:`panel_update` checks the incoming panel
    for NaN/Inf: a bad panel is zero-scaled (contributing exactly what an
    all-zero panel would to C/R/M and the telemetry fold), the counter is
    incremented, and — when the state carries telemetry — the panel's
    ``EVENT_QUARANTINED`` bit is set in ``tel.events``. Idempotent; the
    default un-armed state compiles to the byte-identical pre-quarantine
    program because ``quarantined=None`` contributes no pytree leaves.
    """
    if state.quarantined is not None:
        return state
    return dataclasses.replace(state, quarantined=jnp.zeros((), jnp.int32))


def zero_nonfinite_panels(block, panel: int):
    """Zero every ``panel``-wide column group of ``block`` that carries a
    NaN/Inf entry.

    Host-callable *and* jit-traceable pre-filter matching the in-scan
    quarantine guard's semantics at block granularity: the engine's scan
    entry points run the estimator Ψ fold over the raw chunk *before* the
    per-panel guard executes, so a quarantine-armed state sanitizes the
    fold's input here — a quarantined panel must contribute zero to Ψ just
    as it contributes zero to C/R/M. ``block`` columns are assumed
    panel-aligned at column 0 (the engine always folds from a panel
    boundary); a ragged tail is treated as its own (partial) panel.
    """
    m, w = block.shape
    num_panels = padded_n(w, panel) // panel
    padded = jnp.pad(block, ((0, 0), (0, num_panels * panel - w)))
    fin = jnp.all(
        jnp.isfinite(padded.reshape(m, num_panels, panel)), axis=(0, 2)
    )  # (num_panels,) — per-panel finite flag
    mask = jnp.repeat(fin, panel)[:w]
    return jnp.where(mask[None, :], block, jnp.zeros((), block.dtype))


def padded_n(n: int, panel: int) -> int:
    """Column count rounded up to a whole number of panels."""
    return ((n + panel - 1) // panel) * panel


def fresh_pytree(tree):
    """Deep-copy every array leaf of a pytree.

    Init functions route caller-provided arrays (index sets, shared
    sketches) through this so the scan path's buffer donation can never
    invalidate an array the caller still holds."""
    return jax.tree_util.tree_map(
        lambda x: jnp.array(x) if isinstance(x, jax.Array) else x, tree
    )


def panel_update(state: PanelState, A_L: jax.Array) -> PanelState:
    """Consume one L-column panel. jit-compatible (L static per panel width).

    ``state.offset`` may be traced (the distributed path binds it to
    ``axis_index · shard_n``); all window arithmetic is dynamic-slice based.
    """
    L = A_L.shape[1]
    off = state.offset
    ops = state.ops

    quarantined = state.quarantined
    bad = None
    if quarantined is not None:
        # Graceful degradation (see with_quarantine): a NaN/Inf panel is
        # zero-scaled so its contribution to C/R/M is exactly an all-zero
        # panel's, and counted instead of poisoning the accumulators.
        bad = ~jnp.all(jnp.isfinite(A_L))
        A_L = jnp.where(bad, jnp.zeros((), A_L.dtype), A_L)
        quarantined = quarantined + bad.astype(jnp.int32)

    fast = None
    if ops.panel_kernel is not None:
        # Route B: one fused Pallas launch replaces the sketch, the M fold
        # and update_c when the hook accepts (None = trace-time decline).
        fast = ops.panel_kernel(state.ctx, state.C, state.M, A_L, off)
    if fast is not None:
        ctx, C, M, sc_a, scores = fast
    else:
        S_C, S_R = ops.core_sketches(state.ctx)
        if ops.sketch_panel is not None:
            # fused path: the application computes sc_a together with its
            # per-column scores (one pass; see kernels.panel_score on TPU)
            ctx, sc_a, scores = ops.sketch_panel(state.ctx, A_L, off)
        else:
            ctx, sc_a, scores = state.ctx, S_C.apply(A_L), None
        M = state.M + S_R.cols(off, L).apply_t(sc_a).astype(state.M.dtype)

        if scores is None:
            ctx, C = ops.update_c(ctx, state.C, A_L, sc_a, off)
        else:
            ctx, C = ops.update_c(ctx, state.C, A_L, sc_a, off, scores)
    if ops.symmetric:
        R = state.R  # tied operand: R = Cᵀ is derived, nothing to accumulate
    elif ops.update_r is not None:
        R = ops.update_r(ctx, state.R, A_L, off)
    else:
        r_blk = ops.r_block(ctx, A_L, off).astype(state.R.dtype)
        R = jax.lax.dynamic_update_slice_in_dim(state.R, r_blk, off, axis=1)

    # Telemetry fold runs last — it observes the panel's outcome (pre/post
    # ctx) and only writes the diagnostics frame, never the factors.
    tel = state.tel
    if ops.telemetry is not None and tel is not None:
        tel = ops.telemetry(tel, state.ctx, ctx, A_L, sc_a, scores, off)
    if bad is not None and tel is not None:
        # Flag the quarantine in the panel's event bitmask. `.add` composes
        # with the hook's `.set` above — the hook never writes this bit.
        t = off // tel.panel
        flag = jnp.where(bad, EVENT_QUARANTINED, 0).astype(jnp.int32)
        tel = dataclasses.replace(tel, events=tel.events.at[t].add(flag))

    return dataclasses.replace(
        state, C=C, R=R, M=M, offset=off + L, ctx=ctx, tel=tel,
        quarantined=quarantined,
    )


# Module-scope jit: one trace per (shapes, ops) pair for the whole process —
# callers that used to rebuild ``jax.jit(update)`` per invocation retraced on
# every call. Retained as the per-panel parity oracle for the scan path.
jitted_panel_update = jax.jit(panel_update)


def _fused_route_ok(state: PanelState) -> bool:
    """Static (trace-time) check: may this state take the fused scan body?

    Requires the ops to have opted in (``chunk_fold``), an un-armed
    quarantine guard (the in-scan NaN zero-scaling is inherently per-panel
    — chaos parity stays on the legacy body), and the ops' own
    ``supports_fused`` predicate to accept the ctx.
    """
    ops = state.ops
    return (
        ops.chunk_fold is not None
        and state.quarantined is None
        and (ops.supports_fused is None or ops.supports_fused(state.ctx))
    )


def _fused_scan(
    state: PanelState, block: jax.Array, bcol0, window: jax.Array,
    num_panels: int, panel: int,
) -> PanelState:
    """Fused scan body (Route A): chunk-hoisted sketch + thin per-panel loop.

    The legacy scan body re-slices the (m × L) panel out of the operand and
    re-applies ``S_C`` to it every step — O(m·L) HBM traffic per panel for
    data whose per-panel products are tiny. Here the chunk sketch
    ``sca = S_C.apply(window)`` is computed ONCE per chunk (exactly the
    per-panel sketches side by side: every supported sketch family's
    ``apply`` is column-independent), all panel-invariant factor writes are
    folded once by ``ops.chunk_fold``, and the scan body shrinks to an
    (s_c × L) slice of ``sca``, the per-panel ``M`` fold — kept per panel
    so the fp32 summation order matches the per-panel oracle — and the
    ops' ``fused_step`` (admission policies; None for fixed-index ops).

    ``window`` is the contiguous (m × num_panels·panel) column range being
    consumed (``block`` itself for chunk operands, a dynamic window slice
    for full-stream operands); ``block``/``bcol0`` are forwarded to the
    hooks so per-panel candidate gathers index the un-copied operand.
    """
    ops = state.ops
    start = state.offset
    S_C, S_R = ops.core_sketches(state.ctx)
    sca = S_C.apply(window)  # (s_c, width) — all panel sketches, one pass
    ctx, C, R = ops.chunk_fold(
        state.ctx, state.C, state.R, block, bcol0, start, num_panels * panel
    )
    has_tel = ops.telemetry is not None and state.tel is not None
    # telemetry hooks read A_L's static shape only (see PanelOps.telemetry)
    placeholder = jnp.zeros((0, panel), block.dtype)

    def body(carry, t):
        ctx, C, M, tel = carry
        off = start + t * panel
        sc_a = jax.lax.dynamic_slice_in_dim(sca, t * panel, panel, axis=1)
        M = M + S_R.cols(off, panel).apply_t(sc_a).astype(M.dtype)
        ctx_pre, scores = ctx, None
        if ops.fused_step is not None:
            ctx, C, scores = ops.fused_step(
                ctx, C, block, bcol0 + t * panel, sc_a, off
            )
        if has_tel:
            tel = ops.telemetry(tel, ctx_pre, ctx, placeholder, sc_a, scores, off)
        return (ctx, C, M, tel), None

    (ctx, C, M, tel), _ = jax.lax.scan(
        body, (ctx, C, state.M, state.tel), jnp.arange(num_panels, dtype=jnp.int32)
    )
    return dataclasses.replace(
        state, C=C, R=R, M=M, offset=start + num_panels * panel, ctx=ctx, tel=tel
    )


def scan_chunk(
    state: PanelState, A_chunk: jax.Array, panel: int, *, fused: bool = True
) -> PanelState:
    """Consume a pre-padded chunk (width = whole panels) via one ``lax.scan``.

    Traceable core of the compiled streaming path: the whole chunk becomes a
    single XLA loop whose carry is the :class:`PanelState`, so the C/R/M
    buffers update in place across panels instead of being re-materialized
    at every dispatch boundary. ``A_chunk.shape[1]`` must be a multiple of
    ``panel`` (callers zero-pad the ragged tail — exact, see
    :func:`stream_panels`); panels are consumed left-to-right at the state's
    running offset, bit-for-bit the same per-panel math as
    :func:`panel_update`. The chunk is indexed *relative* to its own first
    column — use :func:`scan_panels` when the operand is the full stream
    array (no chunk copy).

    ``fused`` (static) selects the fused scan body (:func:`_fused_scan`)
    when the ops support it; pass ``False`` to force the legacy per-panel
    body (the census tooling compares the two compiled programs).
    """
    num_panels = A_chunk.shape[1] // panel
    if state.ops.telemetry is not None and state.tel is not None:
        # estimator Ψ fold hoisted out of the scan body: one GEMM over the
        # whole chunk (inside the carry it costs ~3× standalone wall-time);
        # the chunk is consumed atomically by this program, so Ψ and the
        # factors agree at every program boundary
        psi_in = A_chunk
        if state.quarantined is not None:
            # the fold sees the raw chunk before the per-panel guard runs —
            # drop quarantined panels here too, or one NaN poisons Ψ
            psi_in = zero_nonfinite_panels(A_chunk, panel)
        state = dataclasses.replace(
            state, tel=fold_psi_chunk(state.tel, psi_in, state.offset)
        )

    if fused and _fused_route_ok(state):
        return _fused_scan(state, A_chunk, 0, A_chunk, num_panels, panel)

    def body(st, t):
        A_L = jax.lax.dynamic_slice_in_dim(A_chunk, t * panel, panel, axis=1)
        return panel_update(st, A_L), None

    state, _ = jax.lax.scan(body, state, jnp.arange(num_panels, dtype=jnp.int32))
    return state


def scan_panels(
    state: PanelState, A: jax.Array, num_panels: int, panel: int, *, fused: bool = True
) -> PanelState:
    """Scan ``num_panels`` panels of the *full* ``A`` at the state's offset.

    Same loop as :func:`scan_chunk` but sliced at **absolute** offsets
    (``state.offset + t·panel``), so ``A`` stays a loop-invariant operand
    and no per-caller chunk copy is ever materialized (the fused
    sharded-simulate path reads one shared ``A`` for every worker). Caller
    must guarantee ``offset + num_panels·panel ≤ A.shape[1]`` — ragged
    tails go through the zero-padded :func:`scan_chunk` path instead.

    ``fused`` (static) selects the fused scan body (:func:`_fused_scan`)
    when the ops support it, with the chunk sketch applied to the dynamic
    window ``A[:, offset : offset + num_panels·panel]``; ``False`` forces
    the legacy per-panel body.
    """
    offs = state.offset + jnp.arange(num_panels, dtype=jnp.int32) * panel
    if state.ops.telemetry is not None and state.tel is not None:
        # chunk-level Ψ fold (see scan_chunk); the dynamic window slice
        # fuses into the GEMM — no chunk copy is materialized
        block = jax.lax.dynamic_slice_in_dim(
            A, state.offset, num_panels * panel, axis=1
        )
        if state.quarantined is not None:
            block = zero_nonfinite_panels(block, panel)
        state = dataclasses.replace(
            state, tel=fold_psi_chunk(state.tel, block, state.offset)
        )

    if fused and _fused_route_ok(state):
        window = jax.lax.dynamic_slice_in_dim(
            A, state.offset, num_panels * panel, axis=1
        )
        return _fused_scan(state, A, state.offset, window, num_panels, panel)

    def body(st, off):
        A_L = jax.lax.dynamic_slice_in_dim(A, off, panel, axis=1)
        return panel_update(st, A_L), None

    state, _ = jax.lax.scan(body, state, offs)
    return state


# The compiled whole-stream entry points: one trace per (shapes, panel, ops)
# for the process lifetime, with the carried state DONATED — on backends
# with buffer donation the input accumulators are reused for the output, so
# streaming is allocation-free in steady state. Callers must not reuse the
# input state afterwards (see module docstring).
_scan_stream_chunk = jax.jit(
    scan_chunk, static_argnames=("panel", "fused"), donate_argnums=(0,)
)
_scan_stream_panels = jax.jit(
    scan_panels, static_argnames=("num_panels", "panel", "fused"), donate_argnums=(0,)
)

_JIT_MODES = ("scan", "per-panel", True, False)


def stream_panels(
    state: PanelState, A: jax.Array, panel: int, *, stop: Optional[int] = None,
    jit="scan", fused: bool = True,
) -> PanelState:
    """Drive columns ``[offset, stop)`` of ``A`` through the engine in
    fixed-width panels, zero-padding the ragged tail. Host-side driver:
    ``state.offset`` must be concrete.

    ``jit`` selects the execution strategy:

    * ``"scan"`` (default, also accepts ``True``) — the whole chunk runs as
      one compiled ``lax.scan`` program (:func:`scan_chunk`) with the input
      state's buffers donated: no per-panel dispatch, no per-panel
      accumulator re-materialization. The input ``state`` is *consumed*.
    * ``"per-panel"`` — one :data:`jitted_panel_update` dispatch per panel
      (the pre-scan behaviour; kept as the parity oracle).
    * ``False`` — eager per-panel execution (debugging).

    The tail padding is exact — not approximate — because the state's
    sketches were extended with ``pad_cols`` at init: windows past the true
    column count are zero-scaled, and the padded columns of ``A_L`` are zero,
    so the padded block contributes nothing to C, R or M.

    ``fused`` (static, scan modes only) forwards to
    :func:`scan_chunk`/:func:`scan_panels`: ``True`` (default) takes the
    fused scan body when the ops support it, ``False`` forces the legacy
    per-panel body.
    """
    if jit not in _JIT_MODES:
        raise ValueError(f"jit must be one of {_JIT_MODES}, got {jit!r}")
    n = A.shape[1]
    start = int(state.offset)
    stop = min(n, state.n) if stop is None else stop
    if state.R.shape[1] < padded_n(stop - start, panel) + start:
        raise ValueError(
            f"state was initialised without room for panel={panel} tail padding "
            f"(R width {state.R.shape[1]}, need {start + padded_n(stop - start, panel)}); "
            "pass `panel=` at init"
        )
    if stop <= start:
        return state
    if jit in ("scan", True):
        width = stop - start
        num_panels = padded_n(width, panel) // panel
        with span(f"stream/{state.ops.name}/scan"):
            if width == num_panels * panel:
                # aligned: slice panels straight out of the shared A — no copy
                return _scan_stream_panels(
                    state, A, num_panels=num_panels, panel=panel, fused=fused
                )
            chunk = A[:, start:stop]
            chunk = jnp.pad(chunk, ((0, 0), (0, num_panels * panel - width)))
            return _scan_stream_chunk(state, chunk, panel=panel, fused=fused)
    step = jitted_panel_update if jit == "per-panel" else panel_update
    with span(f"stream/{state.ops.name}/per-panel"):
        if state.ops.telemetry is not None and state.tel is not None:
            # parity with the scan path: Ψ folds once over the consumed
            # window, not per panel (same sum up to float association)
            block = A[:, start:stop]
            if state.quarantined is not None:
                block = zero_nonfinite_panels(block, panel)
            state = dataclasses.replace(
                state, tel=fold_psi_chunk(state.tel, block, start)
            )
        for off in range(start, stop, panel):
            width = min(panel, stop - off)
            A_L = jax.lax.dynamic_slice_in_dim(A, off, width, axis=1)
            if width != panel:
                A_L = jnp.pad(A_L, ((0, 0), (0, panel - width)))
            state = step(state, A_L)
    return state


def truncated_R(state: PanelState) -> jax.Array:
    """``R`` restricted to the true (unpadded) column range.

    For symmetric (tied-operand) streams the engine never accumulates R —
    it is *derived* here as ``Cᵀ`` (``C`` rows are never padded, so no
    truncation is needed).
    """
    if state.ops.symmetric:
        return state.C.T
    return state.R[:, : state.n]
