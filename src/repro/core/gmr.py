"""Generalized matrix regression (paper §1, §3).

``X* = argmin_X ||A − C X R||_F``  with closed form  ``X* = C† A R†``.

* :func:`exact_gmr` — the O(nnz(A)·min(c,r) + mc² + nr²) oracle.
* :func:`fast_gmr` — Algorithm 1: ``X̃ = (S_C C)† (S_C A S_Rᵀ) (R S_Rᵀ)†``.
* :func:`fast_gmr_core` — the sketched solve given pre-sketched pieces (the
  form streaming/serving callers use, e.g. Algorithm 3 step 11 and the
  gradient-compression reconstruction).
* :func:`rho` — the problem constant ρ of Eqn. (3.2) governing which branch
  of ``max{c/√ε, c/(ε ρ²)}`` the sketch-size bound takes.
* :func:`error_ratio` — the §6.1 evaluation metric.

Sketched pseudo-inverse solves are performed in fp32 (or better) by
Householder QR with a sign-preserving absolute floor on the R diagonal
(:func:`_solve_least_squares` — *not* ``jnp.linalg.lstsq``, whose SVD-based
rank handling is slower and NaNs on all-zero operands), never by
materializing pinv of a tall matrix — the sketched operands are
(s_c × c) / (r × s_r), so this is the O(s_c c² + s_r r²) cost of Theorem 1
with better conditioning than normal equations. See the
:func:`_solve_least_squares` docstring for the floor's numerical contract.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .sketching import draw_sketch

__all__ = ["exact_gmr", "fast_gmr", "fast_gmr_core", "rho", "error_ratio", "sketched_fro_norm"]


def _solve_least_squares(B: jax.Array, Y: jax.Array) -> jax.Array:
    """argmin_X ||B X − Y||_F for tall ``B`` via Householder QR, fp32+.

    Numerical contract (the "sign-preserving absolute floor"):

    * ``R``'s diagonal entries are replaced by
      ``sign(d) · max(|d|, floor)`` with
      ``floor = max(eps·max|d|·k, sqrt(tiny))`` — a *relative* rank floor
      (`eps·max|d|·k`, the usual lstsq/pinv cutoff) backed by an *absolute*
      one (`sqrt(tiny) ≈ 1e-19` in fp32) so the triangular solve's pivots
      are nonzero even when the whole operand is zero.
    * Output is therefore always **finite**: against an O(1) RHS a floored
      pivot yields entries up to O(1/floor) ≈ 1e19, inside fp32 range. No
      NaN/Inf is ever produced (all-zero sketched blocks from CountSketch
      collisions, unfilled streaming slots).
    * When ``B``'s nonzero columns form a well-conditioned prefix followed
      by all-zero columns (the streaming engines' zero-suffixed-slot
      invariant), the floored rows multiply those zero columns, so
      ``B @ X`` is the **exact projection** of ``Y`` onto the filled span —
      garbage rows of ``X`` cannot leak into the residual. Consumers that
      use ``X`` itself (not ``B @ X``) must mask unfilled slots, as
      ``adaptive_cur_finalize`` does.
    * The floor preserves the pivot's sign, so the solution varies
      continuously as a pivot crosses zero (no sign flip at ±floor).
    """
    dt = jnp.promote_types(B.dtype, jnp.float32)
    Q, Rf = jnp.linalg.qr(B.astype(dt))
    # Solve R X = Qᵀ Y. Guard rank deficiency with a sign-preserving absolute
    # floor on R's diagonal: the relative floor alone is 0 for an all-zero
    # operand (CountSketch-collision-wiped blocks, unfilled streaming slots),
    # which would leave zero pivots → division by zero → NaN core. The
    # absolute fallback keeps 1/floor finite in fp32 even against O(1) RHS.
    finfo = jnp.finfo(dt)
    d = jnp.diagonal(Rf)
    rel = jnp.asarray(finfo.eps, dt) * jnp.max(jnp.abs(d)) * Rf.shape[0]
    floor = jnp.maximum(rel, jnp.sqrt(jnp.asarray(finfo.tiny, dt)))
    safe = jnp.where(d < 0, -1.0, 1.0) * jnp.maximum(jnp.abs(d), floor)
    Rf = Rf.at[jnp.arange(Rf.shape[0]), jnp.arange(Rf.shape[0])].set(safe)
    X = jax.scipy.linalg.solve_triangular(Rf, Q.T.astype(dt) @ Y.astype(dt), lower=False)
    return X


def exact_gmr(A: jax.Array, C: jax.Array, R: jax.Array) -> jax.Array:
    """``X* = C† A R†`` — the exact GMR solution (Eqn. 1.1)."""
    left = _solve_least_squares(C, A)  # C† A
    X = _solve_least_squares(R.T, left.T).T  # (C† A) R†
    return X


def fast_gmr_core(ScC: jax.Array, ScASr: jax.Array, RSr: jax.Array) -> jax.Array:
    """``X̃ = (S_C C)† (S_C A S_Rᵀ) (R S_Rᵀ)†`` given the three sketched pieces.

    Cost O(s_c c² + s_r r² + s_c s_r min(c, r)) — independent of m, n
    (Theorem 1, Eqn. 3.4).
    """
    left = _solve_least_squares(ScC, ScASr)  # (S_C C)† (S_C A S_Rᵀ)
    X = _solve_least_squares(RSr.T, left.T).T
    return X


def fast_gmr(
    key,
    A: jax.Array,
    C: jax.Array,
    R: jax.Array,
    s_c: int,
    s_r: int,
    *,
    sketch_c: str = "gaussian",
    sketch_r: Optional[str] = None,
    probs_c: Optional[jax.Array] = None,
    probs_r: Optional[jax.Array] = None,
) -> jax.Array:
    """Algorithm 1 (Fast GMR).

    Draws ``S_C (s_c × m)`` and ``S_R (s_r × n)`` of the requested families
    and returns ``X̃`` satisfying the (1+ε) bound of Theorem 1 when
    ``s_c, s_r`` follow Table 2.
    """
    m, n = A.shape
    sketch_r = sketch_r or sketch_c
    k_c, k_r = jax.random.split(key)
    S_C = draw_sketch(k_c, sketch_c, s_c, m, probs=probs_c, dtype=A.dtype)
    S_R = draw_sketch(k_r, sketch_r, s_r, n, probs=probs_r, dtype=A.dtype)

    ScC = S_C.apply(C)  # (s_c, c)
    RSr = S_R.apply_t(R)  # (r, s_r)
    ScASr = S_R.apply_t(S_C.apply(A))  # (s_c, s_r)
    return fast_gmr_core(ScC, ScASr, RSr)


def rho(A: jax.Array, C: jax.Array, R: jax.Array) -> jax.Array:
    """Problem constant ρ (Eqn. 3.2).

    ρ = ||A − CC†ARR†||_F / ( ||(I−CC†)ARR†||_F + ||CC†A(I−RR†)||_F ).
    Computed via orthonormal bases (QR) of C and Rᵀ for stability.
    """
    dt = jnp.promote_types(A.dtype, jnp.float32)
    A = A.astype(dt)
    Uc, _ = jnp.linalg.qr(C.astype(dt))
    Vr, _ = jnp.linalg.qr(R.T.astype(dt))
    P_A = Uc @ (Uc.T @ A)  # CC†A
    A_Vr = (A @ Vr) @ Vr.T  # ARR†
    P_A_Vr = Uc @ ((Uc.T @ A @ Vr) @ Vr.T)  # CC†ARR†
    num = jnp.linalg.norm(A - P_A_Vr)
    den = jnp.linalg.norm(A_Vr - P_A_Vr) + jnp.linalg.norm(P_A - P_A_Vr)
    return num / jnp.maximum(den, jnp.finfo(dt).tiny)


def error_ratio(A: jax.Array, C: jax.Array, X: jax.Array, R: jax.Array) -> jax.Array:
    """§6.1 metric: ``||A − C X R||_F / ||A − C X* R||_F − 1``."""
    dt = jnp.promote_types(A.dtype, jnp.float32)
    Xstar = exact_gmr(A, C, R)
    num = jnp.linalg.norm(A.astype(dt) - (C @ X @ R).astype(dt))
    den = jnp.linalg.norm(A.astype(dt) - (C @ Xstar @ R).astype(dt))
    return num / jnp.maximum(den, jnp.finfo(dt).tiny) - 1.0


def sketched_fro_norm(key, B: jax.Array, s1: int, s2: int) -> jax.Array:
    """§6.1's CountSketch Frobenius-norm estimator ``||S₁ B S₂||_F ≈ ||B||_F``."""
    k1, k2 = jax.random.split(key)
    S1 = draw_sketch(k1, "countsketch", s1, B.shape[0], dtype=B.dtype)
    S2 = draw_sketch(k2, "countsketch", s2, B.shape[1], dtype=B.dtype)
    return jnp.linalg.norm(S2.apply_t(S1.apply(B)))
