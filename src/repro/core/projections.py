"""Convex-cone projections (paper §3.2, Proposition 1, Eqns. 3.5/3.6)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["sym_project", "psd_project"]


def sym_project(X: jax.Array) -> jax.Array:
    """Π_{H^n}(X) = (X + Xᵀ)/2 (Eqn. 3.5)."""
    return 0.5 * (X + X.T)


def psd_project(X: jax.Array) -> jax.Array:
    """Π_{H^n₊}(X): symmetrize, eigendecompose, clip negative spectrum (Eqn. 3.6).

    Runs in fp32+ regardless of input dtype; the sketched core matrices this
    is applied to are c×c (Remark 3: O(c³) — negligible).
    """
    dt = jnp.promote_types(X.dtype, jnp.float32)
    Xs = sym_project(X.astype(dt))
    w, V = jnp.linalg.eigh(Xs)
    w = jnp.maximum(w, 0.0)
    return ((V * w[None, :]) @ V.T).astype(X.dtype)
