"""Compatibility shim: the §4 SPSD implementation moved to ``repro.spsd``.

The batch algorithms (Nyström / optimal core / fast-SPSD Wang'16b /
**Algorithm 2** ``faster_spsd``) now live in :mod:`repro.spsd.batch` as the
batch half of the layered ``repro/spsd/`` subsystem — the streaming half
(:mod:`repro.spsd.streaming`) runs the same approximation single-pass over
kernel-column panels via the symmetric mode of the :mod:`repro.stream`
engine. This module re-exports the batch surface so every historical
import path (``repro.core.spsd`` and the ``repro.core`` package alike)
keeps working unchanged.
"""

from ..spsd.batch import (  # noqa: F401 — re-exports
    KernelOracle,
    SPSDResult,
    fast_spsd_wang,
    faster_spsd,
    leverage_sampling_sketches,
    matrix_oracle,
    nystrom,
    optimal_core,
    rbf_kernel_oracle,
    spsd_error_ratio,
)

__all__ = [
    "rbf_kernel_oracle",
    "matrix_oracle",
    "KernelOracle",
    "SPSDResult",
    "leverage_sampling_sketches",
    "nystrom",
    "optimal_core",
    "fast_spsd_wang",
    "faster_spsd",
    "spsd_error_ratio",
]
