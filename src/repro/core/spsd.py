"""SPSD / kernel-matrix approximation (paper §4).

Implements, with identical call signatures so benchmarks can sweep them:

* :func:`nystrom`            — Williams & Seeger 2001 (conventional baseline)
* :func:`optimal_core`       — X = C† K (C†)ᵀ (the target the paper compares to)
* :func:`fast_spsd_wang`     — Wang et al. 2016b, Eqn. (4.1): one sketch S,
                               X̂ = (SC)† (S K Sᵀ) (Cᵀ Sᵀ)†
* :func:`faster_spsd`        — **Algorithm 2 (ours/paper)**: two independent
                               leverage-score sampling sketches + PSD projection,
                               observing only nc + s² kernel entries (Theorem 3)

All sampling-based paths work through a *kernel-entry oracle* so only the
entries the algorithm touches are ever computed — the paper's headline
query-complexity win. ``entries_observed`` is reported for Table-4-style
accounting.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from .gmr import _solve_least_squares, fast_gmr_core
from .leverage import leverage_scores
from .projections import psd_project

__all__ = [
    "rbf_kernel_oracle",
    "KernelOracle",
    "nystrom",
    "optimal_core",
    "fast_spsd_wang",
    "faster_spsd",
    "spsd_error_ratio",
]

# A kernel oracle maps (row_idx | None, col_idx | None) -> K[rows][:, cols].
KernelOracle = Callable[[Optional[jax.Array], Optional[jax.Array]], jax.Array]


def rbf_kernel_oracle(X: jax.Array, sigma: float) -> KernelOracle:
    """RBF oracle over data ``X (n, d)``: K_ij = exp(−σ ||xᵢ − xⱼ||²) (§6.2)."""

    def oracle(rows, cols):
        Xr = X if rows is None else jnp.take(X, rows, axis=0)
        Xc = X if cols is None else jnp.take(X, cols, axis=0)
        sq = (
            jnp.sum(Xr * Xr, axis=1)[:, None]
            - 2.0 * (Xr @ Xc.T)
            + jnp.sum(Xc * Xc, axis=1)[None, :]
        )
        return jnp.exp(-sigma * jnp.maximum(sq, 0.0))

    return oracle


@dataclasses.dataclass
class SPSDResult:
    """Column matrix C, core X (K ≈ C X Cᵀ), and the entry-observation count."""

    C: jax.Array
    X: jax.Array
    col_idx: jax.Array
    entries_observed: int


def _uniform_columns(key, n: int, c: int) -> jax.Array:
    return jax.random.choice(key, n, (c,), replace=False)


def nystrom(key, oracle: KernelOracle, n: int, c: int) -> SPSDResult:
    """Conventional Nyström: X = W† with W the c×c intersection block."""
    idx = _uniform_columns(key, n, c)
    C = oracle(None, idx)  # (n, c)
    W = jnp.take(C, idx, axis=0)  # (c, c) — already-observed entries
    dt = jnp.promote_types(C.dtype, jnp.float32)
    X = jnp.linalg.pinv(W.astype(dt), rtol=1e-6).astype(C.dtype)
    return SPSDResult(C=C, X=X, col_idx=idx, entries_observed=n * c)


def optimal_core(key, oracle: KernelOracle, n: int, c: int) -> SPSDResult:
    """X = C† K (C†)ᵀ — requires observing all n² entries (the upper bound)."""
    idx = _uniform_columns(key, n, c)
    C = oracle(None, idx)
    K = oracle(None, None)
    left = _solve_least_squares(C, K)  # C† K
    X = _solve_least_squares(C, left.T).T  # C† K (C†)ᵀ
    return SPSDResult(C=C, X=psd_project(X), col_idx=idx, entries_observed=n * n)


def fast_spsd_wang(key, oracle: KernelOracle, n: int, c: int, s: int) -> SPSDResult:
    """Wang et al. 2016b (Eqn. 4.1): single leverage-score sampling sketch S.

    X̂ = (SC)† (S K Sᵀ) (Cᵀ Sᵀ)† — symmetric by construction, but needs
    s = O(c√(n/ε)) for the (1+ε) bound (Table 4), i.e. O(nc²/ε) entries.
    """
    k_col, k_s = jax.random.split(key)
    idx = _uniform_columns(k_col, n, c)
    C = oracle(None, idx)
    lev = leverage_scores(C)
    probs = lev / jnp.sum(lev)
    sidx = jax.random.choice(k_s, n, (s,), replace=True, p=probs)
    scale = 1.0 / jnp.sqrt(s * probs[sidx])
    SC = C[sidx] * scale[:, None]
    SKS = oracle(sidx, sidx) * (scale[:, None] * scale[None, :])
    X = fast_gmr_core(SC, SKS, SC.T)
    return SPSDResult(
        C=C, X=psd_project(X), col_idx=idx, entries_observed=n * c + s * s
    )


def faster_spsd(key, oracle: KernelOracle, n: int, c: int, s: int) -> SPSDResult:
    """**Algorithm 2** — the paper's faster SPSD approximation.

    1. uniform-sample c columns → C (nc entries);
    2. leverage scores of C;
    3. two *independent* leverage-sampling sketches S₁, S₂ (s×n);
    4. X̃ = (S₁C)† (S₁ K S₂ᵀ) (Cᵀ S₂ᵀ)†  — only s² extra entries;
    5. X̃₊ = Π_PSD(X̃)  (Theorem 2 keeps the (1+ε) bound after projection).
    """
    k_col, k_s1, k_s2 = jax.random.split(key, 3)
    idx = _uniform_columns(k_col, n, c)
    C = oracle(None, idx)
    lev = leverage_scores(C)
    probs = lev / jnp.sum(lev)

    i1 = jax.random.choice(k_s1, n, (s,), replace=True, p=probs)
    sc1 = 1.0 / jnp.sqrt(s * probs[i1])
    i2 = jax.random.choice(k_s2, n, (s,), replace=True, p=probs)
    sc2 = 1.0 / jnp.sqrt(s * probs[i2])

    S1C = C[i1] * sc1[:, None]  # (s, c) — rows of already-observed C
    CS2 = (C[i2] * sc2[:, None]).T  # (c, s)
    S1KS2 = oracle(i1, i2) * (sc1[:, None] * sc2[None, :])  # s² fresh entries

    X = fast_gmr_core(S1C, S1KS2, CS2)
    return SPSDResult(
        C=C, X=psd_project(X), col_idx=idx, entries_observed=n * c + s * s
    )


def spsd_error_ratio(K: jax.Array, res: SPSDResult) -> jax.Array:
    """§6.2 metric: ||K − C X Cᵀ||_F / ||K||_F."""
    dt = jnp.promote_types(K.dtype, jnp.float32)
    approx = (res.C @ res.X @ res.C.T).astype(dt)
    return jnp.linalg.norm(K.astype(dt) - approx) / jnp.linalg.norm(K.astype(dt))
