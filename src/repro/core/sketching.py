"""Matrix sketching library (paper §2.3).

Implements every sketching family the paper's Table 1/2 analyses cover:

* Gaussian projection
* Subsampled randomized Hadamard transform (SRHT)
* CountSketch (Clarkson & Woodruff, 2013)
* OSNAP (Nelson & Nguyen, 2013)
* Row sampling (uniform / leverage-score, Drineas et al. 2006b)
* Composed sketches ``S2 ∘ S1`` (e.g. Gaussian ∘ OSNAP as used by Algorithm 3)

Every sketch is a small pytree-registered dataclass with three operations:

* ``apply(A)``     — ``S @ A``          (A is (m, n), S is (s, m))
* ``apply_t(A)``   — ``A @ S.T``        (A is (n, m))
* ``materialize()``— dense ``S`` (tests/small problems only)

plus ``cols(offset, size)`` which restricts the *source* dimension to a
contiguous column window — the streaming primitive Algorithm 3 needs to
consume ``A`` in L-column panels (``M += S_C A_L S_R[:, cols]ᵀ``) — and
``pad_cols(total)`` which extends the source dimension with *zero-scaled*
columns so that ``cols()`` windows reaching past the true source dim stay
valid slices that contribute nothing (the contract zero-padded ragged tail
panels rely on; see ``repro.stream.engine``).

All randomness is fully determined by an explicit ``jax.random`` key so that
sketches drawn on different data-parallel workers from a shared seed are
bit-identical (gradient compression relies on ``Σᵢ(Gᵢ Ω) = (Σᵢ Gᵢ) Ω``).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "GaussianSketch",
    "SRHTSketch",
    "CountSketch",
    "OSNAPSketch",
    "RowSampling",
    "ComposedSketch",
    "draw_sketch",
    "fwht",
    "SKETCH_KINDS",
]


def _register(cls, data: tuple, meta: tuple):
    return jax.tree_util.register_dataclass(cls, data_fields=list(data), meta_fields=list(meta))


def _bcast_vec(v: jax.Array, ndim: int) -> jax.Array:
    """Reshape a length-k vector to (k, 1, …, 1) for broadcasting against an
    ndim-dimensional operand (3.10-safe stand-in for ``v[:, *([None]*(ndim-1))]``)."""
    return v.reshape(v.shape[:1] + (1,) * (ndim - 1))


# ---------------------------------------------------------------------------
# Gaussian projection
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GaussianSketch:
    """Dense ``S ∈ R^{s×m}`` with iid N(0, 1/s) entries (paper §2.3)."""

    mat: jax.Array  # (s, m)

    @staticmethod
    def draw(key, s: int, m: int, dtype=jnp.float32) -> "GaussianSketch":
        mat = jax.random.normal(key, (s, m), dtype) * (1.0 / np.sqrt(s))
        return GaussianSketch(mat)

    @property
    def s(self) -> int:
        return self.mat.shape[0]

    @property
    def m(self) -> int:
        return self.mat.shape[1]

    def apply(self, A: jax.Array) -> jax.Array:
        return self.mat[:, : A.shape[0]] @ A  # [:m] slice: padded sketch on unpadded A

    def apply_t(self, A: jax.Array) -> jax.Array:
        return A @ self.mat[:, : A.shape[-1]].T

    def materialize(self) -> jax.Array:
        return self.mat

    def cols(self, offset: int, size: int) -> "GaussianSketch":
        return GaussianSketch(jax.lax.dynamic_slice_in_dim(self.mat, offset, size, axis=1))

    def pad_cols(self, total: int) -> "GaussianSketch":
        if total <= self.m:
            return self
        pad = jnp.zeros((self.s, total - self.m), self.mat.dtype)
        return GaussianSketch(jnp.concatenate([self.mat, pad], axis=1))


_register(GaussianSketch, ("mat",), ())


# ---------------------------------------------------------------------------
# SRHT
# ---------------------------------------------------------------------------


def fwht(x: jax.Array) -> jax.Array:
    """Unnormalised fast Walsh–Hadamard transform along axis 0.

    ``x.shape[0]`` must be a power of two. O(m log m) per column.
    """
    m = x.shape[0]
    if m & (m - 1):
        raise ValueError(f"FWHT needs a power-of-two leading dim, got {m}")
    tail = x.shape[1:]
    h = 1
    while h < m:
        x = x.reshape(m // (2 * h), 2, h, *tail)
        a, b = x[:, 0], x[:, 1]
        x = jnp.stack([a + b, a - b], axis=1).reshape(m, *tail)
        h *= 2
    return x


@dataclasses.dataclass(frozen=True)
class SRHTSketch:
    """``S = sqrt(m/s) · P · (H/√m) · D`` (paper §2.3, Tropp 2011).

    ``m`` is internally padded to the next power of two; padded rows of the
    source are treated as zeros.
    """

    signs: jax.Array  # (m_pad,) ±1
    row_idx: jax.Array  # (s,) sampled rows of the transformed matrix
    m: int  # true source dim (static)
    m_pad: int  # padded source dim (static)

    @staticmethod
    def draw(key, s: int, m: int, dtype=jnp.float32) -> "SRHTSketch":
        m_pad = 1 << int(np.ceil(np.log2(max(m, 2))))
        k_sign, k_row = jax.random.split(key)
        signs = jax.random.rademacher(k_sign, (m_pad,), dtype)
        row_idx = jax.random.randint(k_row, (s,), 0, m_pad)
        return SRHTSketch(signs=signs, row_idx=row_idx, m=m, m_pad=m_pad)

    @property
    def s(self) -> int:
        return self.row_idx.shape[0]

    def apply(self, A: jax.Array) -> jax.Array:
        m = A.shape[0]
        pad = self.m_pad - m
        x = A * _bcast_vec(self.signs[:m], A.ndim)
        if pad:
            x = jnp.concatenate([x, jnp.zeros((pad, *A.shape[1:]), A.dtype)], axis=0)
        x = fwht(x) * (1.0 / np.sqrt(self.s))
        return jnp.take(x, self.row_idx, axis=0)

    def apply_t(self, A: jax.Array) -> jax.Array:
        return self.apply(A.T).T

    def materialize(self) -> jax.Array:
        return self.apply(jnp.eye(self.m, dtype=self.signs.dtype))

    def cols(self, offset: int, size: int):  # pragma: no cover - structural
        raise NotImplementedError("SRHT is not column-sliceable; use CountSketch/OSNAP for streaming")


_register(SRHTSketch, ("signs", "row_idx"), ("m", "m_pad"))


# ---------------------------------------------------------------------------
# CountSketch
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CountSketch:
    """One ±1 entry per column, position uniform (Clarkson & Woodruff 2013).

    ``apply`` is a signed segment-sum — the JAX-native statement of the
    O(nnz(A)) input-sparsity algorithm. The TPU-tiled variant lives in
    ``repro.kernels.countsketch``.
    """

    hashes: jax.Array  # (m,) int32 in [0, s)
    signs: jax.Array  # (m,) ±1
    s: int  # static

    @staticmethod
    def draw(key, s: int, m: int, dtype=jnp.float32) -> "CountSketch":
        k_h, k_s = jax.random.split(key)
        hashes = jax.random.randint(k_h, (m,), 0, s)
        signs = jax.random.rademacher(k_s, (m,), dtype)
        return CountSketch(hashes=hashes, signs=signs, s=s)

    @property
    def m(self) -> int:
        return self.hashes.shape[0]

    def apply(self, A: jax.Array) -> jax.Array:
        m = A.shape[0]
        signed = A * _bcast_vec(self.signs[:m], A.ndim)
        return jax.ops.segment_sum(signed, self.hashes[:m], num_segments=self.s)

    def apply_t(self, A: jax.Array) -> jax.Array:
        return self.apply(A.T).T

    def materialize(self) -> jax.Array:
        S = jnp.zeros((self.s, self.m), self.signs.dtype)
        return S.at[self.hashes, jnp.arange(self.m)].set(self.signs)

    def cols(self, offset: int, size: int) -> "CountSketch":
        return CountSketch(
            hashes=jax.lax.dynamic_slice_in_dim(self.hashes, offset, size),
            signs=jax.lax.dynamic_slice_in_dim(self.signs, offset, size),
            s=self.s,
        )

    def pad_cols(self, total: int) -> "CountSketch":
        if total <= self.m:
            return self
        pad = total - self.m
        return CountSketch(
            hashes=jnp.concatenate([self.hashes, jnp.zeros((pad,), self.hashes.dtype)]),
            signs=jnp.concatenate([self.signs, jnp.zeros((pad,), self.signs.dtype)]),
            s=self.s,
        )


_register(CountSketch, ("hashes", "signs"), ("s",))


# ---------------------------------------------------------------------------
# OSNAP
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OSNAPSketch:
    """``p`` ±1/√p entries per column (Nelson & Nguyen 2013).

    Implemented as the mean of ``p`` independent CountSketches scaled by
    1/√p (the "with replacement" OSNAP variant standard in practice; the
    subspace-embedding property is preserved, validated in tests).
    """

    hashes: jax.Array  # (p, m)
    signs: jax.Array  # (p, m)
    s: int
    p: int

    @staticmethod
    def draw(key, s: int, m: int, p: int = 2, dtype=jnp.float32) -> "OSNAPSketch":
        k_h, k_s = jax.random.split(key)
        hashes = jax.random.randint(k_h, (p, m), 0, s)
        signs = jax.random.rademacher(k_s, (p, m), dtype) * (1.0 / np.sqrt(p))
        return OSNAPSketch(hashes=hashes, signs=signs, s=s, p=p)

    @property
    def m(self) -> int:
        return self.hashes.shape[1]

    def apply(self, A: jax.Array) -> jax.Array:
        m = A.shape[0]

        def one(h, sg):
            signed = A * _bcast_vec(sg[:m], A.ndim)
            return jax.ops.segment_sum(signed, h[:m], num_segments=self.s)

        return jnp.sum(jax.vmap(one)(self.hashes, self.signs), axis=0)

    def apply_t(self, A: jax.Array) -> jax.Array:
        return self.apply(A.T).T

    def materialize(self) -> jax.Array:
        S = jnp.zeros((self.s, self.m), self.signs.dtype)
        for i in range(self.p):
            S = S.at[self.hashes[i], jnp.arange(self.m)].add(self.signs[i])
        return S

    def cols(self, offset: int, size: int) -> "OSNAPSketch":
        return OSNAPSketch(
            hashes=jax.lax.dynamic_slice_in_dim(self.hashes, offset, size, axis=1),
            signs=jax.lax.dynamic_slice_in_dim(self.signs, offset, size, axis=1),
            s=self.s,
            p=self.p,
        )

    def pad_cols(self, total: int) -> "OSNAPSketch":
        if total <= self.m:
            return self
        pad = total - self.m
        return OSNAPSketch(
            hashes=jnp.concatenate([self.hashes, jnp.zeros((self.p, pad), self.hashes.dtype)], axis=1),
            signs=jnp.concatenate([self.signs, jnp.zeros((self.p, pad), self.signs.dtype)], axis=1),
            s=self.s,
            p=self.p,
        )


_register(OSNAPSketch, ("hashes", "signs"), ("s", "p"))


# ---------------------------------------------------------------------------
# Row sampling (uniform / leverage-score)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RowSampling:
    """Sample-and-rescale sketch: row i w.p. pᵢ, scaled 1/√(s pᵢ) (paper §2.3)."""

    idx: jax.Array  # (s,)
    scale: jax.Array  # (s,)
    m: int

    @staticmethod
    def draw(key, s: int, m: int, probs: Optional[jax.Array] = None, dtype=jnp.float32) -> "RowSampling":
        if probs is None:
            probs = jnp.full((m,), 1.0 / m, dtype)
        else:
            probs = probs.astype(dtype) / jnp.sum(probs)
        idx = jax.random.choice(key, m, (s,), replace=True, p=probs)
        scale = 1.0 / jnp.sqrt(s * probs[idx])
        return RowSampling(idx=idx, scale=scale, m=m)

    @property
    def s(self) -> int:
        return self.idx.shape[0]

    def apply(self, A: jax.Array) -> jax.Array:
        rows = jnp.take(A, self.idx, axis=0)
        return rows * _bcast_vec(self.scale, A.ndim)

    def apply_t(self, A: jax.Array) -> jax.Array:
        return jnp.take(A, self.idx, axis=1) * self.scale[None, :]

    def materialize(self) -> jax.Array:
        S = jnp.zeros((self.s, self.m), self.scale.dtype)
        return S.at[jnp.arange(self.s), self.idx].add(self.scale)

    def cols(self, offset: int, size: int) -> "RowSampling":
        """Restrict to the source-column window ``[offset, offset+size)``.

        A sampling matrix has one nonzero per row (at column ``idx[i]``), so
        the window restriction re-bases in-window indices and zero-scales
        out-of-window rows — samples outside the window contribute nothing,
        which is exactly the ``S[:, offset:offset+size]`` slice. ``offset``
        may be traced (the streaming engine slides the window per panel).
        """
        rel = self.idx - offset
        in_window = (rel >= 0) & (rel < size)
        return RowSampling(
            idx=jnp.clip(rel, 0, size - 1),
            scale=jnp.where(in_window, self.scale, jnp.zeros((), self.scale.dtype)),
            m=size,
        )

    def pad_cols(self, total: int) -> "RowSampling":
        """Extend the source dim with zero columns (never sampled).

        Sampled indices always lie in ``[0, m)``, so windows past the true
        source dim contain no samples and ``cols()`` zero-scales them — the
        exact ragged-tail contract of :mod:`repro.stream.engine` holds with
        no stored-array change.
        """
        if total <= self.m:
            return self
        return RowSampling(idx=self.idx, scale=self.scale, m=total)


_register(RowSampling, ("idx", "scale"), ("m",))


# ---------------------------------------------------------------------------
# Composition (e.g. Gaussian ∘ OSNAP used by Algorithm 3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ComposedSketch:
    """``S = outer ∘ inner`` — apply ``inner`` first, then ``outer``.

    The paper's Remark 1 / Algorithm 3 pattern: a cheap input-sparsity
    sketch (OSNAP) followed by a Gaussian projection to compact size.
    """

    inner: object
    outer: object

    @property
    def s(self) -> int:
        return self.outer.s

    @property
    def m(self) -> int:
        return self.inner.m

    def apply(self, A: jax.Array) -> jax.Array:
        return self.outer.apply(self.inner.apply(A))

    def apply_t(self, A: jax.Array) -> jax.Array:
        return self.outer.apply_t(self.inner.apply_t(A))

    def materialize(self) -> jax.Array:
        return self.outer.apply(self.inner.materialize())

    def cols(self, offset: int, size: int) -> "ComposedSketch":
        return ComposedSketch(inner=self.inner.cols(offset, size), outer=self.outer)

    def pad_cols(self, total: int) -> "ComposedSketch":
        return ComposedSketch(inner=self.inner.pad_cols(total), outer=self.outer)


_register(ComposedSketch, ("inner", "outer"), ())


# ---------------------------------------------------------------------------
# Factory
# ---------------------------------------------------------------------------

SKETCH_KINDS = ("gaussian", "srht", "countsketch", "osnap", "uniform", "leverage")


def draw_sketch(key, kind: str, s: int, m: int, *, probs=None, p: int = 2, dtype=jnp.float32):
    """Draw an ``(s, m)`` sketch of the requested family.

    ``probs`` is required for kind="leverage" (the leverage-score
    distribution of the matrix being protected, per Tables 2/3).
    """
    if kind == "gaussian":
        return GaussianSketch.draw(key, s, m, dtype)
    if kind == "srht":
        return SRHTSketch.draw(key, s, m, dtype)
    if kind == "countsketch":
        return CountSketch.draw(key, s, m, dtype)
    if kind == "osnap":
        return OSNAPSketch.draw(key, s, m, p=p, dtype=dtype)
    if kind == "uniform":
        return RowSampling.draw(key, s, m, probs=None, dtype=dtype)
    if kind == "leverage":
        if probs is None:
            raise ValueError("leverage sampling requires `probs`")
        return RowSampling.draw(key, s, m, probs=probs, dtype=dtype)
    if kind == "osnap+gaussian":
        k1, k2 = jax.random.split(key)
        s0 = min(m, max(2 * s, s + 8))
        inner = OSNAPSketch.draw(k1, s0, m, p=p, dtype=dtype)
        outer = GaussianSketch.draw(k2, s, s0, dtype)
        return ComposedSketch(inner=inner, outer=outer)
    raise ValueError(f"unknown sketch kind {kind!r}; expected one of {SKETCH_KINDS + ('osnap+gaussian',)}")
