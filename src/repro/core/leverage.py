"""Leverage scores (paper §2.1 notation; Drineas et al. 2012 estimation).

Row leverage scores of ``A (m×n)``, m ≥ n:  ℓᵢ = ||Q_{i,:}||² where Q is an
orthonormal basis of range(A). Σℓᵢ = rank(A). Used by Tables 2/3's
leverage-sampling sketches and by Algorithm 2 step 3.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .sketching import draw_sketch

__all__ = ["leverage_scores", "approx_leverage_scores"]


def leverage_scores(A: jax.Array) -> jax.Array:
    """Exact row leverage scores via QR — O(m n²)."""
    dt = jnp.promote_types(A.dtype, jnp.float32)
    Q, _ = jnp.linalg.qr(A.astype(dt))
    return jnp.sum(Q * Q, axis=1)


def approx_leverage_scores(key, A: jax.Array, s: int | None = None) -> jax.Array:
    """Sketched leverage scores (Drineas et al. 2012).

    ℓ̂ᵢ = ||A_{i,:} · R⁻¹ · G||² with R from QR of a row-sketch S·A and a
    small Gaussian G for the JL reduction. O(nnz(A) + n³) instead of O(mn²).
    """
    m, n = A.shape
    s = s or min(m, max(4 * n, n + 8))
    k1, k2 = jax.random.split(key)
    S = draw_sketch(k1, "countsketch", s, m, dtype=A.dtype)
    dt = jnp.promote_types(A.dtype, jnp.float32)
    _, Rf = jnp.linalg.qr(S.apply(A).astype(dt))
    # Solve Rᵀ Zᵀ = Aᵀ → Z = A R⁻¹ without forming R⁻¹
    Z = jax.scipy.linalg.solve_triangular(Rf, A.astype(dt).T, lower=False, trans="T").T
    jl = max(8, int(jnp.ceil(jnp.log2(m))) * 2)
    G = jax.random.normal(k2, (n, jl), dt) / jnp.sqrt(jl)
    return jnp.sum((Z @ G) ** 2, axis=1)
