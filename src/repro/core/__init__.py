"""The paper's primary contribution: Fast Generalized Matrix Regression
(Ye, Wang, Zhang & Zhang, 2019) and its applications, in pure JAX.

Public surface:

* sketching      — the §2.3 sketch families (Gaussian/SRHT/CountSketch/OSNAP/sampling)
* gmr            — exact GMR + Algorithm 1 (Fast GMR) + Theorem-1 utilities
* projections    — §3.2 convex projections (Π_sym, Π_PSD)
* spsd           — §4: Nyström / fast-SPSD (Wang'16b) / **Algorithm 2** / optimal core
                   (now a shim over the layered :mod:`repro.spsd` subsystem,
                   whose streaming half runs Algorithm 2 single-pass over
                   kernel panels via the symmetric :mod:`repro.stream` engine)
* svd            — §5: **Algorithm 3** streaming Fast SP-SVD + Tropp'17 baseline
* leverage       — exact & sketched leverage scores

The §1 CUR application lives in the sibling :mod:`repro.cur` subsystem
(selection → fast core → streaming → batched serving); its headline
symbols are re-exported here lazily so ``from repro.core import fast_cur``
works without an import cycle.
"""

from .sketching import (
    ComposedSketch,
    CountSketch,
    GaussianSketch,
    OSNAPSketch,
    RowSampling,
    SRHTSketch,
    draw_sketch,
    fwht,
)
from .gmr import exact_gmr, fast_gmr, fast_gmr_core, rho, error_ratio, sketched_fro_norm
from .projections import psd_project, sym_project
from .leverage import approx_leverage_scores, leverage_scores
from .svd import (
    fast_sp_svd,
    practical_sp_svd,
    sp_svd_finalize,
    sp_svd_init,
    sp_svd_sizes,
    sp_svd_update,
    spsvd_engine_finalize,
    spsvd_engine_init,
    svd_error_ratio,
)

_CUR_EXPORTS = (
    "CURResult", "cur_error_ratio", "cur_reconstruct", "cur_relative_error",
    "cur_sketch_sizes", "exact_cur", "fast_cur", "select_columns", "select_rows",
    "streaming_cur_finalize", "streaming_cur_init", "streaming_cur_update",
    "batched_fast_cur", "symmetric_cur", "spsd_to_cur",
)

# The §4 SPSD surface now lives in the layered repro.spsd subsystem; it is
# re-exported here lazily — like the CUR surface — because repro.spsd's
# modules import repro.core submodules at load time (an eager import here
# would re-enter repro.spsd mid-initialization whenever repro.spsd is the
# first package imported).
_SPSD_EXPORTS = (
    "SPSDResult", "faster_spsd", "fast_spsd_wang", "leverage_sampling_sketches",
    "matrix_oracle", "nystrom", "optimal_core", "rbf_kernel_oracle",
    "spsd_error_ratio",
    "streaming_spsd_init", "streaming_spsd_finalize",
    "adaptive_spsd_init", "adaptive_spsd_finalize",
)


def __getattr__(name):  # PEP 562: lazy re-exports (cycle-free)
    if name in _CUR_EXPORTS:
        from .. import cur as _cur

        return getattr(_cur, name)
    if name in _SPSD_EXPORTS:
        from .. import spsd as _spsd

        return getattr(_spsd, name)
    if name == "spsd":
        # the submodule itself was an eager attribute before the move;
        # keep `repro.core.spsd` attribute access working lazily too
        import importlib

        return importlib.import_module(".spsd", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "ComposedSketch", "CountSketch", "GaussianSketch", "OSNAPSketch", "RowSampling",
    "SRHTSketch", "draw_sketch", "fwht",
    "exact_gmr", "fast_gmr", "fast_gmr_core", "rho", "error_ratio", "sketched_fro_norm",
    "psd_project", "sym_project",
    "approx_leverage_scores", "leverage_scores",
    "fast_sp_svd", "practical_sp_svd", "sp_svd_finalize", "sp_svd_init", "sp_svd_sizes",
    "sp_svd_update", "spsvd_engine_finalize", "spsvd_engine_init", "svd_error_ratio",
    *_CUR_EXPORTS,
    *_SPSD_EXPORTS,
]
