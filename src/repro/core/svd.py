"""Single-pass SVD (paper §5).

* **Algorithm 3 (Fast SP-SVD, ours/paper)** — streaming API
  (:func:`sp_svd_init` / :func:`sp_svd_update` / :func:`sp_svd_finalize`)
  mirroring the paper's while-loop over L-column panels, plus a one-shot
  convenience :func:`fast_sp_svd`.
* **Algorithm 4 (Practical SP-SVD, Tropp et al. 2017)** — the baseline,
  :func:`practical_sp_svd`.

Sketch construction follows Algorithm 3 step 3: OSNAP (p = O(1) nonzeros
per column) composed with Gaussian projections for Ψ̃/Ω̃, and plain OSNAP
for the inner S_C/S_R. Space: C (m×c) + R (r×n) + M (s_c×s_r) — the
O((m+n)k/ε) footprint of Theorem 4; the input panels are never retained.

The per-panel accumulator mechanics live in the shared
:mod:`repro.stream.engine` (``PanelState`` + ``SP_SVD_OPS``); this module
keeps the Algorithm-3 surface as thin wrappers. The engine-level
constructor/finalizer pair (:func:`spsvd_engine_init` /
:func:`spsvd_engine_finalize`, explicit sketch sizes, jit/vmap-safe) is the
layer downstream plug-ins — e.g. the serving KV-cache compressor — build
on; the classic loop names delegate to it. ``fast_sp_svd`` streams
through the engine's scan-compiled whole-stream path — one ``lax.scan``
program per (shape, panel) with the carried state's buffers donated, the
ragged tail zero-padded to the panel width (exact: ``pad_cols`` sketch
windows past ``n`` are zero-scaled), and the per-panel jitted step
available behind ``jit="per-panel"`` for parity checks. DP-sharded
ingestion comes for free via :mod:`repro.stream.distributed`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..stream.engine import (
    PanelOps,
    PanelState,
    padded_n,
    panel_update,
    stream_panels,
    truncated_R,
)
from .gmr import _solve_least_squares, fast_gmr_core
from .sketching import CountSketch, GaussianSketch, OSNAPSketch, draw_sketch

__all__ = [
    "SPSVDSketches",
    "SPSVDState",
    "SP_SVD_OPS",
    "sp_svd_sizes",
    "spsvd_engine_init",
    "spsvd_engine_finalize",
    "sp_svd_init",
    "sp_svd_update",
    "sp_svd_finalize",
    "fast_sp_svd",
    "practical_sp_svd",
    "svd_error_ratio",
]


def sp_svd_sizes(k: int, eps: float, gamma: float = 0.25) -> dict:
    """Algorithm 3 step 2 sketch sizes (constants chosen per §6.3's recipe)."""
    ke = k / eps
    c = r = int(np.ceil(3 * ke))
    c0 = r0 = int(np.ceil(3 * ke ** (1.0 + gamma)))
    s = int(np.ceil(3 * k / eps**1.5))
    return dict(c=c, r=r, c0=c0, r0=r0, s_c=s, s_r=s)


@dataclasses.dataclass(frozen=True)
class SPSVDSketches:
    """The six sketching operators of Algorithm 3 step 3."""

    psi: OSNAPSketch  # (r0, m)
    g_r: GaussianSketch  # (r, r0)
    omega: OSNAPSketch  # (c0, n)
    g_c: GaussianSketch  # (c, c0)
    s_c: OSNAPSketch  # (s_c, m)
    s_r: OSNAPSketch  # (s_r, n)


jax.tree_util.register_dataclass(
    SPSVDSketches, data_fields=["psi", "g_r", "omega", "g_c", "s_c", "s_r"], meta_fields=[]
)


# ---------------------------------------------------------------------------
# PanelStream plug-in (Algorithm 3 steps 6–8): ctx is the SPSVDSketches.
# ---------------------------------------------------------------------------


def _svd_core_sketches(sk: SPSVDSketches):
    return sk.s_c, sk.s_r


def _svd_update_c(sk: SPSVDSketches, C, A_L, sc_a, off):
    # C += A_L · Ω̃[cols]  with  Ω̃[cols] = Ω[:, cols]ᵀ · G_Cᵀ  (never materialized)
    L = A_L.shape[1]
    a_omega = sk.omega.cols(off, L).apply_t(A_L)  # A_L (m,L) × Ω[:,cols]ᵀ (L,c0) → (m, c0)
    return sk, C + sk.g_c.apply_t(a_omega)  # (m, c)


def _svd_r_block(sk: SPSVDSketches, A_L, off):
    # R[:, cols] = G_R · (Ψ A_L)
    return sk.g_r.apply(sk.psi.apply(A_L))  # (r, L)


SP_SVD_OPS = PanelOps(
    name="sp_svd",
    core_sketches=_svd_core_sketches,
    update_c=_svd_update_c,
    r_block=_svd_r_block,
)

# Streaming state: the generic engine state with ctx = SPSVDSketches
# (``state.sketches`` resolves to ctx for back-compat).
SPSVDState = PanelState


def spsvd_engine_init(
    key,
    m: int,
    n: int,
    *,
    sizes: dict,
    dtype=jnp.float32,
    osnap_p: int = 2,
    panel: Optional[int] = None,
) -> SPSVDState:
    """Engine-level Algorithm 3 state constructor (explicit ``sizes``).

    Draws the six sketching operators and allocates zero accumulators
    (Algorithm 3 steps 2–4), returning a :class:`repro.stream.PanelState`
    ready for ``panel_update``/``scan_panels``/``stream_panels``. This is
    the constructor serving-side plug-ins build on; :func:`sp_svd_init`
    layers the paper's k/eps sizing recipe on top.

    ``panel`` declares a fixed streaming width: the n-dim sketches and the
    ``R`` accumulator are zero-pad-extended to a whole number of panels so a
    ragged final panel can be zero-padded instead of retraced (the sketches
    themselves are drawn over ``n`` — padding never consumes randomness, so
    results are identical across panel choices). vmap-compatible: all draw
    paths use traced-key-safe jax.random primitives.
    """
    c, r, c0, r0, s_c, s_r = (sizes[x] for x in ("c", "r", "c0", "r0", "s_c", "s_r"))
    n_pad = padded_n(n, panel) if panel else n
    keys = jax.random.split(key, 6)
    sk = SPSVDSketches(
        psi=OSNAPSketch.draw(keys[0], r0, m, p=osnap_p, dtype=dtype),
        g_r=GaussianSketch.draw(keys[1], r, r0, dtype=dtype),
        omega=OSNAPSketch.draw(keys[2], c0, n, p=osnap_p, dtype=dtype).pad_cols(n_pad),
        g_c=GaussianSketch.draw(keys[3], c, c0, dtype=dtype),
        s_c=OSNAPSketch.draw(keys[4], s_c, m, p=osnap_p, dtype=dtype),
        s_r=OSNAPSketch.draw(keys[5], s_r, n, p=osnap_p, dtype=dtype).pad_cols(n_pad),
    )
    return SPSVDState(
        C=jnp.zeros((m, c), dtype),
        R=jnp.zeros((r, n_pad), dtype),
        M=jnp.zeros((s_c, s_r), dtype),
        offset=jnp.zeros((), jnp.int32),
        ctx=sk,
        ops=SP_SVD_OPS,
        n=n,
    )


def sp_svd_init(
    key,
    m: int,
    n: int,
    *,
    k: Optional[int] = None,
    eps: float = 0.5,
    sizes: Optional[dict] = None,
    dtype=jnp.float32,
    osnap_p: int = 2,
    panel: Optional[int] = None,
) -> SPSVDState:
    """Draw sketches and allocate zero accumulators (Algorithm 3 steps 2–4).

    Thin wrapper over :func:`spsvd_engine_init` that resolves the paper's
    k/eps sizing recipe (:func:`sp_svd_sizes`) when explicit ``sizes`` are
    not given.
    """
    if sizes is None:
        if k is None:
            raise ValueError("pass either `k` (+eps) or explicit `sizes`")
        sizes = sp_svd_sizes(k, eps)
    return spsvd_engine_init(key, m, n, sizes=sizes, dtype=dtype, osnap_p=osnap_p, panel=panel)


def sp_svd_update(state: SPSVDState, A_L: jax.Array) -> SPSVDState:
    """Consume one L-column panel (Algorithm 3 steps 6–8). jit-compatible."""
    return panel_update(state, A_L)


def spsvd_engine_finalize(
    state: SPSVDState, k: Optional[int] = None
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Algorithm 3 steps 10–13: QR bases, sketched core solve, small SVD.

    Returns (U, Σ, V) with ``A ≈ U diag(Σ) Vᵀ``; ranks are c/r (not k) unless
    ``k`` is given, matching §6.3's "without fixed rank" protocol. Pure jax —
    safe under jit/vmap (the serving head-batch path maps it over heads).
    """
    sk = state.ctx
    R = truncated_R(state)
    dt = jnp.promote_types(state.C.dtype, jnp.float32)
    U_C, _ = jnp.linalg.qr(state.C.astype(dt))  # (m, c)
    V_R, _ = jnp.linalg.qr(R.T.astype(dt))  # (n, r)

    ScU = sk.s_c.apply(U_C.astype(state.C.dtype)).astype(dt)  # (s_c, c)
    SrV = sk.s_r.apply(V_R.astype(state.C.dtype)).astype(dt)  # (s_r, r)
    # N = (S_C U_C)† M (V_Rᵀ S_Rᵀ)†  — Fast GMR core (Eqn. 5.3)
    N = fast_gmr_core(ScU, state.M.astype(dt), SrV.T)

    U_N, S, V_Nt = jnp.linalg.svd(N, full_matrices=False)
    U = U_C @ U_N
    V = V_R @ V_Nt.T
    if k is not None:
        U, S, V = U[:, :k], S[:k], V[:, :k]
    return U, S, V


def sp_svd_finalize(
    state: SPSVDState, k: Optional[int] = None
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Legacy Algorithm-3 finalize name — thin shim over :func:`spsvd_engine_finalize`."""
    return spsvd_engine_finalize(state, k=k)


def fast_sp_svd(
    key,
    A: jax.Array,
    *,
    k: Optional[int] = None,
    eps: float = 0.5,
    sizes: Optional[dict] = None,
    panel: int = 512,
    fixed_rank: Optional[int] = None,
    jit="scan",
):
    """One-shot Algorithm 3: stream ``A`` through the panel loop internally.

    The stream runs on the engine's scan-compiled path by default — the
    whole panel loop is one compiled program per (m, n, panel) shape for the
    process lifetime, with every panel (including a ragged tail, zero-padded
    to ``panel``) consumed in place. ``jit="per-panel"`` falls back to one
    jitted dispatch per panel (the parity oracle; see
    :func:`repro.stream.stream_panels`).
    """
    m, n = A.shape
    state = sp_svd_init(key, m, n, k=k, eps=eps, sizes=sizes, dtype=A.dtype, panel=panel)
    state = stream_panels(state, A, panel, jit=jit)
    return sp_svd_finalize(state, k=fixed_rank)


def practical_sp_svd(
    key,
    A: jax.Array,
    *,
    c: int,
    r: int,
    sketch: str = "gaussian",
    fixed_rank: Optional[int] = None,
):
    """Algorithm 4 (Tropp et al. 2017) — the baseline Practical SP-SVD.

    C = A Ω̃, R = Ψ̃ A, N' = (Ψ̃ U_C)† (R V_R); same single-pass structure but
    the core is *not* a GMR solution (§5.3's comparison point).
    """
    m, n = A.shape
    k_psi, k_om = jax.random.split(key)
    psi = draw_sketch(k_psi, sketch, r, m, dtype=A.dtype)  # Ψ̃ (r, m)
    omega = draw_sketch(k_om, sketch, c, n, dtype=A.dtype)  # Ω̃ᵀ (c, n)

    C = omega.apply_t(A)  # A Ω̃ (m, c)
    R = psi.apply(A)  # Ψ̃ A (r, n)

    dt = jnp.promote_types(A.dtype, jnp.float32)
    U_C, _ = jnp.linalg.qr(C.astype(dt))
    V_R, _ = jnp.linalg.qr(R.T.astype(dt))

    PsiU = psi.apply(U_C.astype(A.dtype)).astype(dt)  # (r, c)
    N = _solve_least_squares(PsiU, (R.astype(dt) @ V_R))  # (c, r)

    U_N, S, V_Nt = jnp.linalg.svd(N, full_matrices=False)
    U = U_C @ U_N
    V = V_R @ V_Nt.T
    if fixed_rank is not None:
        U, S, V = U[:, :fixed_rank], S[:fixed_rank], V[:, :fixed_rank]
    return U, S, V


def svd_error_ratio(A: jax.Array, U, S, V, k: int) -> jax.Array:
    """§6.3 metric: ||A − UΣVᵀ||_F / ||A − A_k||_F − 1 (can be negative)."""
    dt = jnp.promote_types(A.dtype, jnp.float32)
    approx = (U * S[None, :]) @ V.T
    num = jnp.linalg.norm(A.astype(dt) - approx.astype(dt))
    sv = jnp.linalg.svd(A.astype(dt), compute_uv=False)
    den = jnp.sqrt(jnp.sum(sv[k:] ** 2))
    return num / jnp.maximum(den, jnp.finfo(dt).tiny) - 1.0
