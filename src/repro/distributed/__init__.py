"""Mesh/sharding rules and collective helpers."""
from .sharding import ParallelismRules, param_shardings, param_pspecs, cache_shardings, batch_pspec, leaf_pspec, explain, activation_sharding, shard_act
