"""Logical-axis sharding rules (MaxText-style) → PartitionSpecs per tensor.

The mesh is (pod?, data, model). Policy knobs per arch live in
``ParallelismRules``; the §Perf hillclimb edits these, not model code.

Conventions:
* TP ("model" axis): attention q/o width, FFN hidden, MoE expert dim,
  vocab dim of the embedding/lm_head, Mamba-2 inner width / heads.
* DP ("pod","data"): the batch dim of activations.
* FSDP (optional): weights additionally sharded over the data axes on
  their non-TP dim (kimi-k2-1t, llama-vision-90b — TP-only shards exceed
  a v5e's 16 GB HBM).
* A dim is only sharded if divisible by the axis size — otherwise the rule
  silently degrades to replication (recorded by ``explain()``).
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelismRules:
    tp_axis: str = "model"
    dp_axes: Tuple[str, ...] = ("data",)  # ("pod","data") on the multi-pod mesh
    fsdp: bool = False
    fsdp_axes: Tuple[str, ...] = ("data",)
    shard_vocab: bool = True
    # sequence parallelism (§Perf C1): shard the S axis of activations over
    # tp_axis and replicate weights (tp_enabled=False). Wins for SSM prefill,
    # where cross-shard traffic is only conv halos + chunk states.
    tp_enabled: bool = True
    seq_parallel: bool = False

    def with_mesh(self, mesh: Mesh) -> "ParallelismRules":
        names = tuple(mesh.axis_names)
        dp = tuple(a for a in ("pod", "data") if a in names)
        return dataclasses.replace(self, dp_axes=dp, fsdp_axes=("data",))


# leaf-name → semantic layout of the LAST dims. Semantics:
#   tp   — shard over tp_axis;   fsdp — shard over fsdp_axes when rules.fsdp
#   ep   — expert dim over tp_axis;   vocab — over tp_axis when shard_vocab
#   -    — never sharded
_LEAF_LAYOUTS = {
    # attention / generic projections: (in, out)
    "w_q": ("fsdp", "tp"),
    "w_k": ("fsdp", "tp"),
    "w_v": ("fsdp", "tp"),
    "w_o": ("tp", "fsdp"),
    # FFN
    "w_gate": ("fsdp", "tp"),
    "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    # embedding / head
    "tok": ("vocab", "fsdp"),
    "lm_head": ("fsdp", "vocab"),
    # MLA
    "w_dkv": ("fsdp", "-"),
    "w_uk": ("-", "tp"),
    "w_uv": ("-", "tp"),
    # Mamba-2
    "w_z": ("fsdp", "tp"),
    "w_x": ("fsdp", "tp"),
    "w_bc": ("fsdp", "-"),
    "w_dt": ("fsdp", "-"),
    "conv_x_w": ("-", "tp"),
    "conv_x_b": ("tp",),
    "conv_bc_w": ("-", "-"),
    "conv_bc_b": ("-",),
    "dt_bias": ("-",),
    "a_log": ("-",),
    "d_skip": ("-",),
    "norm_scale": ("tp",),
    # MoE
    "router": ("fsdp", "-"),
    # misc
    "vision_proj": ("-", "fsdp"),
    "gate": (),
    "scale": ("-",),
}

# MoE expert tensors are 3-D (E, in, out) and shadow FFN names — resolved by rank.
_MOE_LAYOUTS = {
    "w_gate": ("ep", "fsdp", "-"),
    "w_up": ("ep", "fsdp", "-"),
    "w_down": ("ep", "-", "fsdp"),
}


def _axis_for(sem: str, rules: ParallelismRules):
    if sem == "dp":
        return rules.dp_axes
    if sem == "tp" or sem == "ep":
        return rules.tp_axis if rules.tp_enabled else None
    if sem == "vocab":
        return rules.tp_axis if (rules.shard_vocab and rules.tp_enabled) else None
    if sem == "fsdp":
        return rules.fsdp_axes if rules.fsdp else None
    if sem == "seq":
        return rules.tp_axis if rules.seq_parallel else None
    return None


def _divisible(dim: int, axis, mesh: Mesh) -> bool:
    if axis is None:
        return True
    sizes = [mesh.shape[a] for a in (axis if isinstance(axis, tuple) else (axis,))]
    return dim % int(np.prod(sizes)) == 0


def leaf_pspec(path, leaf, rules: ParallelismRules, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf based on its path tail + rank."""
    name = None
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            name = entry.key
            break
    in_moe = any(
        isinstance(e, jax.tree_util.DictKey) and e.key == "ffn" for e in path
    ) and leaf.ndim >= 3 and name in _MOE_LAYOUTS
    layout = _MOE_LAYOUTS[name] if in_moe else _LEAF_LAYOUTS.get(name)
    if layout is None:
        return P()
    # leaves inside stacked scan segments carry a leading repeat dim
    extra = leaf.ndim - len(layout)
    spec = [None] * extra
    for sem, dim in zip(layout, leaf.shape[extra:]):
        axis = _axis_for(sem, rules)
        spec.append(axis if _divisible(dim, axis, mesh) else None)
    return P(*spec)


def param_shardings(params, rules: ParallelismRules, mesh: Mesh):
    """NamedSharding pytree matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, leaf_pspec(path, leaf, rules, mesh)), params
    )


def param_pspecs(params, rules: ParallelismRules, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: leaf_pspec(path, leaf, rules, mesh), params
    )


# ---------------------------------------------------------------------------
# Activation / input specs
# ---------------------------------------------------------------------------


def batch_pspec(rules: ParallelismRules) -> P:
    """(B, S) token batches: batch over DP axes (+ seq over tp_axis in SP mode)."""
    return P(rules.dp_axes, rules.tp_axis if rules.seq_parallel else None)


def cache_pspec(path, leaf, rules: ParallelismRules, mesh: Mesh, *, seq_shard: bool) -> P:
    """KV-cache leaves.

    Default: batch over DP, KV-heads over TP when divisible.
    ``seq_shard`` (long_500k, batch=1): sequence dim over the DP axes
    instead — distributed decode attention (LSE combine via SPMD).
    """
    name = None
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            name = entry.key
            break
    extra_dims = leaf.ndim
    if name in ("k", "v"):  # (B, S|window|patches, KV, hd) (+repeat prefix)
        extra = leaf.ndim - 4
        b, s, kv, hd = leaf.shape[extra:]
        spec = [None] * extra
        if seq_shard:
            spec += [None, rules.dp_axes if _divisible(s, rules.dp_axes, mesh) else None]
        else:
            spec += [rules.dp_axes if _divisible(b, rules.dp_axes, mesh) else None, None]
        spec += [rules.tp_axis if _divisible(kv, rules.tp_axis, mesh) else None, None]
        return P(*spec)
    if name == "latent":  # (B, S, r+rope)
        extra = leaf.ndim - 3
        b, s, r = leaf.shape[extra:]
        spec = [None] * extra
        if seq_shard:
            spec += [None, rules.dp_axes if _divisible(s, rules.dp_axes, mesh) else None, None]
        else:
            spec += [rules.dp_axes if _divisible(b, rules.dp_axes, mesh) else None, None, None]
        return P(*spec)
    if name == "ssm":  # (B, H, N, P)
        extra = leaf.ndim - 4
        b, h, n, p_ = leaf.shape[extra:]
        spec = [None] * extra
        spec += [rules.dp_axes if _divisible(b, rules.dp_axes, mesh) else None]
        spec += [rules.tp_axis if _divisible(h, rules.tp_axis, mesh) else None, None, None]
        return P(*spec)
    if name in ("conv_x", "conv_bc"):  # (B, K-1, C)
        extra = leaf.ndim - 3
        b, k, cdim = leaf.shape[extra:]
        spec = [None] * extra + [rules.dp_axes if _divisible(b, rules.dp_axes, mesh) else None, None]
        spec += [rules.tp_axis if (name == "conv_x" and _divisible(cdim, rules.tp_axis, mesh)) else None]
        return P(*spec)
    if name == "length":
        return P()
    return P()


def cache_shardings(cache, rules: ParallelismRules, mesh: Mesh, *, seq_shard: bool = False):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_pspec(path, leaf, rules, mesh, seq_shard=seq_shard)
        ),
        cache,
    )


# ---------------------------------------------------------------------------
# Activation sharding constraints (context-scoped, set at trace time)
# ---------------------------------------------------------------------------

_ACT_CTX: contextvars.ContextVar = contextvars.ContextVar("repro_act_sharding", default=None)

# semantic layouts for the LAST dims of an activation; leading dims → None.
#   dp — batch over the DP axes; tp — over the model axis; "-" — unsharded
_ACT_KINDS = {
    "btd": ("dp", "seq", "-"),  # (B, S, D) residual stream
    "btf": ("dp", "seq", "tp"),  # (B, S, F) FFN hidden
    "bthd": ("dp", "seq", "tp", "-"),  # (B, S, H, hd) per-head
    "btv": ("dp", "seq", "tp"),  # (B, S, V) logits
    "pecd": ("dp", "tp", "-", "-"),  # (P, E, cap, D) MoE dispatch: token
    #                            groups over data, experts over model (without
    #                            the dp dim every data rank recomputes all
    #                            experts — measured 16x on kimi, §Perf B5)
    "te": ("dp", "-"),  # (T, E) router logits
}


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: ParallelismRules):
    """Enable ``shard_act`` constraints while tracing model code."""
    tok = _ACT_CTX.set((mesh, rules))
    try:
        yield
    finally:
        _ACT_CTX.reset(tok)


def shard_map_compat(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=True):
    """``jax.shard_map`` across jax versions.

    Modern jax exposes ``jax.shard_map(..., axis_names=, check_vma=)``;
    0.4.x has ``jax.experimental.shard_map.shard_map(..., auto=, check_rep=)``
    where ``auto`` is the complement of the manual ``axis_names``.
    """
    if hasattr(jax, "shard_map"):
        kwargs = {} if axis_names is None else {"axis_names": set(axis_names)}
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma, **kwargs
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = frozenset() if axis_names is None else frozenset(mesh.axis_names) - frozenset(axis_names)
    if auto:
        # partial-auto on 0.4.x dies deep in the partitioner (bare
        # NotImplementedError / XLA tile-validation errors) — fail loud here
        raise NotImplementedError(
            f"partial-auto shard_map (manual={sorted(frozenset(axis_names))}, "
            f"auto={sorted(auto)}) requires jax >= 0.6; this jax only supports "
            "fully-manual shard_map"
        )
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )


def axis_size_compat(axis_name) -> int:
    """Size of a named mesh axis inside shard_map, across jax versions."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_act(x, kind: str):
    """with_sharding_constraint by semantic kind; no-op outside the context
    and for dims not divisible by their assigned axes. Axes the value is
    already *manual* over (inside shard_map, e.g. the compressed-gradient
    step's dp axes) are dropped from the constraint — they are per-shard
    there, not partitioner-managed."""
    ctx = _ACT_CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    layout = _ACT_KINDS[kind]
    if x.ndim < len(layout):
        return x
    typeof = getattr(jax, "typeof", None)  # absent pre-0.6: no VMA tracking
    manual = getattr(typeof(x), "vma", frozenset()) if typeof else frozenset()
    if manual:
        # inside a shard_map manual region constraints over the (auto-typed)
        # mesh are rejected for vma-carrying values; the partial-auto
        # partitioner propagates TP shardings from the parameters instead
        return x
    extra = x.ndim - len(layout)
    spec = [None] * extra
    for sem, dim in zip(layout, x.shape[extra:]):
        axis = _axis_for(sem, rules)
        spec.append(axis if (axis and _divisible(dim, axis, mesh)) else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def explain(params, rules: ParallelismRules, mesh: Mesh) -> str:
    """Human-readable table of leaf → spec (+ replication fallbacks)."""
    lines = []

    def visit(path, leaf):
        spec = leaf_pspec(path, leaf, rules, mesh)
        key = jax.tree_util.keystr(path)
        lines.append(f"{key:60s} {str(leaf.shape):24s} {spec}")
        return leaf

    jax.tree_util.tree_map_with_path(visit, params)
    return "\n".join(lines)
