"""Decode-native compressed KV cache: the panel engine carried through decode.

:mod:`repro.serve.kv_compress` compresses a *finished* prefix; this module
keeps the compression **live during generation**. Each converted attention
layer's cache is a :class:`CompressedKV` — a pytree carrying, per
(batch, kv-head):

* the streaming Algorithm-3 engine state
  (:class:`repro.stream.PanelState`, vmapped over ``(B, KV)``) that has
  consumed every token up to ``eng_len``;
* the last finalized factors ``H ≈ V_s Σ Uᵀ`` covering ``fac_len`` tokens;
* a small dense *recent* ring ``(B, refresh_every, KV, hd)`` holding the
  tokens newer than ``fac_len`` exactly.

Every decoded token is appended to the recent buffer; once
``decode_panel`` tokens are pending past ``eng_len`` they are folded into
the engine as one panel (:func:`repro.stream.panel_update`, the same
single-pass update as prefill), and once ``refresh_every`` tokens have
accumulated past ``fac_len`` the engine is **refactorized**
(:func:`repro.core.svd.spsvd_engine_finalize` — QR bases + sketched GMR
core, the numerically robust incremental maintenance of Tropp et al.'s
practical single-pass sketching) and the recent buffer is reset.
Attention is exact over the recent window and rank-``r`` over the
refactorized prefix, with **one joint softmax** across both score blocks.

Everything is shape-static and ``lax.cond``-gated, so the whole policy
lives inside the one jitted decode step — one compiled program serves the
entire batch. Adaptive per-head rank
(``KVCompressionConfig(adaptive=True)``) re-allocates the shared
``KV·rank`` budget at every refresh via
:func:`repro.stream.allocate_shared_budget`.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.svd import spsvd_engine_finalize
from repro.models.config import ATTN, ModelConfig
from repro.models.transformer import segments
from repro.obs.metrics import MetricsRegistry, default_registry
from repro.obs.spans import span
from repro.stream.engine import PanelState, panel_update, scan_panels

from .kv_compress import (
    KVCompressionConfig,
    LowRankKV,
    _allocate_ranks,
    _engine_init,
    _fac_width,
)

__all__ = [
    "CompressedKV",
    "cache_nbytes",
    "compress_prefill_cache",
    "init_compressed_kv",
]


def _head_keys(key, B: int, KV: int):
    # documented derivation (tests replicate it): one key per (batch, head)
    return jax.random.split(key, B * KV).reshape(B, KV)


@dataclasses.dataclass
class CompressedKV:
    """Per-layer compressed KV cache state (pytree; ``kc`` is static meta).

    Invariants: ``fac_len <= eng_len <= length``; tokens ``[0, fac_len)``
    are represented by ``k_fac``/``v_fac``; tokens ``[fac_len, length)``
    sit densely in ``recent_*`` at slot ``pos - fac_len``; tokens
    ``[0, eng_len)`` have been folded into ``k_eng``/``v_eng``;
    ``eng_len - fac_len`` is always a multiple of ``decode_panel`` and
    strictly less than ``refresh_every``.
    """

    k_eng: PanelState  # engine states vmapped over (B, KV)
    v_eng: PanelState
    k_fac: LowRankKV  # v_s (B,KV,n_max,fw)  sigma (B,KV,fw)  u (B,KV,hd,fw)
    v_fac: LowRankKV
    recent_k: jax.Array  # (B, refresh_every, KV, hd) model dtype
    recent_v: jax.Array
    fac_len: jax.Array  # () int32 — tokens covered by the factors
    eng_len: jax.Array  # () int32 — tokens folded into the engine
    kc: KVCompressionConfig

    def append_attend(self, q, k, v, length):
        """Append one decoded token and attend against the full history.

        ``q``: (B, 1, H, hd) RoPE'd queries; ``k``/``v``: (B, 1, KV, hd)
        the new token's projections; ``length``: tokens already cached.
        Returns ``(o, cache)`` with ``o`` (B, 1, H, hd) — the drop-in
        contract of :func:`repro.models.attention.decode_attention` plus
        the updated cache. Traced end-to-end: the fold/refresh policy is
        ``lax.cond``-gated so this inlines into the jitted decode step.
        """
        kc = self.kc
        slot = length - self.fac_len
        rk = jax.lax.dynamic_update_slice(
            self.recent_k, k.astype(self.recent_k.dtype), (0, slot, 0, 0)
        )
        rv = jax.lax.dynamic_update_slice(
            self.recent_v, v.astype(self.recent_v.dtype), (0, slot, 0, 0)
        )
        cache = dataclasses.replace(self, recent_k=rk, recent_v=rv)
        new_len = length + 1
        cache = jax.lax.cond(
            new_len - cache.eng_len == kc.decode_panel,
            partial(_fold_panel, new_len=new_len),
            lambda c: c,
            cache,
        )
        return _attend(cache, q, new_len), cache


jax.tree_util.register_dataclass(
    CompressedKV,
    data_fields=[
        "k_eng", "v_eng", "k_fac", "v_fac",
        "recent_k", "recent_v", "fac_len", "eng_len",
    ],
    meta_fields=["kc"],
)


def _fold_panel(cache: CompressedKV, *, new_len) -> CompressedKV:
    # fold the decode_panel pending tokens [eng_len, new_len) into the
    # engine — one panel_update per head, vmapped over (B, KV); then
    # refactorize if refresh_every tokens have accumulated past the factors
    kc = cache.kc
    dp = kc.decode_panel
    B, W, KV, hd = cache.recent_k.shape
    start = cache.eng_len - cache.fac_len
    win_k = jax.lax.dynamic_slice(cache.recent_k, (0, start, 0, 0), (B, dp, KV, hd))
    win_v = jax.lax.dynamic_slice(cache.recent_v, (0, start, 0, 0), (B, dp, KV, hd))
    fold = jax.vmap(jax.vmap(panel_update))
    k_eng = fold(cache.k_eng, win_k.transpose(0, 2, 3, 1).astype(jnp.float32))
    v_eng = fold(cache.v_eng, win_v.transpose(0, 2, 3, 1).astype(jnp.float32))
    cache = dataclasses.replace(
        cache, k_eng=k_eng, v_eng=v_eng, eng_len=cache.eng_len + dp
    )
    return jax.lax.cond(
        cache.eng_len - cache.fac_len == kc.refresh_every,
        _refresh,
        lambda c: c,
        cache,
    )


def _finalize_heads(eng: PanelState, kc: KVCompressionConfig, fw: int) -> LowRankKV:
    # (B, KV)-vmapped Algorithm-3 finalize at the stored factor width; rows
    # of V past eng_len are exactly zero (Householder QR of zero rows) and
    # masked by fac_len regardless
    U, sig, V = jax.vmap(jax.vmap(lambda st: spsvd_engine_finalize(st, k=fw)))(eng)
    fac = LowRankKV(v_s=V, sigma=sig, u=U)
    if kc.adaptive:
        sigma, _ = _allocate_ranks(fac.sigma, kc)
        fac = LowRankKV(v_s=fac.v_s, sigma=sigma, u=fac.u)
    return fac


def _refresh(cache: CompressedKV) -> CompressedKV:
    # refactorize: new factors now cover everything the engine has seen;
    # the recent window restarts empty at the new fac_len
    kc = cache.kc
    fw = cache.k_fac.sigma.shape[-1]
    return dataclasses.replace(
        cache,
        k_fac=_finalize_heads(cache.k_eng, kc, fw),
        v_fac=_finalize_heads(cache.v_eng, kc, fw),
        recent_k=jnp.zeros_like(cache.recent_k),
        recent_v=jnp.zeros_like(cache.recent_v),
        fac_len=cache.eng_len,
    )


def _attend(cache: CompressedKV, q, new_len):
    # joint softmax over the rank-r factor scores (prefix, pos < fac_len)
    # and the exact recent scores (pos in [fac_len, new_len)); fp32 like
    # decode_attention, cast back to the query dtype
    B, _, H, hd = q.shape
    W = cache.recent_k.shape[1]
    KV = cache.recent_k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)

    kf, vf = cache.k_fac, cache.v_fac
    uq = jnp.einsum("bkdr,bkgd->bkgr", kf.u, qg) * kf.sigma[:, :, None, :]
    s_fac = jnp.einsum("bksr,bkgr->bkgs", kf.v_s, uq) * scale  # (B,KV,G,n_max)
    n_max = s_fac.shape[-1]
    s_fac = jnp.where(jnp.arange(n_max)[None, None, None] < cache.fac_len, s_fac, -1e30)

    rk = cache.recent_k.astype(jnp.float32)
    s_rec = jnp.einsum("bkgd,bwkd->bkgw", qg, rk) * scale  # (B,KV,G,W)
    n_rec = new_len - cache.fac_len
    s_rec = jnp.where(jnp.arange(W)[None, None, None] < n_rec, s_rec, -1e30)

    p = jax.nn.softmax(jnp.concatenate([s_fac, s_rec], axis=-1), axis=-1)
    p_fac, p_rec = p[..., :n_max], p[..., n_max:]

    pv = jnp.einsum("bkgs,bksr->bkgr", p_fac, vf.v_s) * vf.sigma[:, :, None, :]
    o = jnp.einsum("bkgr,bkdr->bkgd", pv, vf.u)
    o = o + jnp.einsum("bkgw,bwkd->bkgd", p_rec, cache.recent_v.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def init_compressed_kv(
    key,
    kc: KVCompressionConfig,
    *,
    batch: int,
    n_kv_heads: int,
    head_dim: int,
    n_max: int,
    dtype=jnp.float32,
) -> CompressedKV:
    """Fresh empty compressed cache for ``n_max`` total tokens.

    Key derivation (parity tests replicate it): the K engines draw from
    ``fold_in(key, 0)`` and the V engines from ``fold_in(key, 1)``, each
    split into ``batch·n_kv_heads`` per-head keys row-major over
    ``(batch, kv_head)``.
    """
    fw = _fac_width(head_dim, kc)
    init_one = lambda k: _engine_init(k, head_dim, n_max, kc)
    eng = []
    for half in range(2):  # 0 → K, 1 → V
        keys = _head_keys(jax.random.fold_in(key, half), batch, n_kv_heads)
        eng.append(jax.vmap(jax.vmap(init_one))(keys))
    zero_fac = LowRankKV(
        v_s=jnp.zeros((batch, n_kv_heads, n_max, fw), jnp.float32),
        sigma=jnp.zeros((batch, n_kv_heads, fw), jnp.float32),
        u=jnp.zeros((batch, n_kv_heads, head_dim, fw), jnp.float32),
    )
    recent = jnp.zeros((batch, kc.refresh_every, n_kv_heads, head_dim), dtype)
    return CompressedKV(
        k_eng=eng[0],
        v_eng=eng[1],
        k_fac=zero_fac,
        v_fac=dataclasses.replace(zero_fac),
        recent_k=recent,
        recent_v=recent,
        fac_len=jnp.zeros((), jnp.int32),
        eng_len=jnp.zeros((), jnp.int32),
        kc=kc,
    )


def _convert_core(key, k_dense, v_dense, prompt_len: int, kc: KVCompressionConfig):
    # dense ATTN cache (B, n_max, KV, hd) ×2 → CompressedKV with the first
    # prompt_len tokens streamed through the engine and factorized; the
    # engine's column domain is the full n_max so decode keeps appending
    B, n_max, KV, hd = k_dense.shape
    fw = _fac_width(hd, kc)
    panel = min(kc.panel, prompt_len)
    n_full = prompt_len // panel

    def one(head_key, hist_T):  # hist_T (hd, n_max), first prompt_len cols valid
        st = _engine_init(head_key, hd, n_max, kc)
        if n_full:
            st = scan_panels(st, hist_T, n_full, panel)
        if prompt_len % panel:
            st = panel_update(st, hist_T[:, n_full * panel : prompt_len])
        U, sig, V = spsvd_engine_finalize(st, k=fw)
        return st, LowRankKV(v_s=V, sigma=sig, u=U)

    halves = []
    for half, dense in enumerate((k_dense, v_dense)):
        keys = _head_keys(jax.random.fold_in(key, half), B, KV)
        hists = dense.transpose(0, 2, 3, 1).astype(jnp.float32)  # (B,KV,hd,n_max)
        halves.append(jax.vmap(jax.vmap(one))(keys, hists))
    (k_eng, k_fac), (v_eng, v_fac) = halves
    if kc.adaptive:
        k_fac = LowRankKV(k_fac.v_s, _allocate_ranks(k_fac.sigma, kc)[0], k_fac.u)
        v_fac = LowRankKV(v_fac.v_s, _allocate_ranks(v_fac.sigma, kc)[0], v_fac.u)
    recent = jnp.zeros((B, kc.refresh_every, KV, hd), k_dense.dtype)
    plen = jnp.asarray(prompt_len, jnp.int32)
    return CompressedKV(
        k_eng=k_eng, v_eng=v_eng, k_fac=k_fac, v_fac=v_fac,
        recent_k=recent, recent_v=recent, fac_len=plen, eng_len=plen, kc=kc,
    )


# one compiled conversion program per (shape, prompt_len, kc) — all
# same-shaped ATTN layers of a model share a single trace
_convert_one = jax.jit(_convert_core, static_argnames=("prompt_len", "kc"))


@partial(jax.jit, static_argnames=("prompt_len", "kc"))
def _convert_rep(keys, k_dense, v_dense, prompt_len: int, kc: KVCompressionConfig):
    # scanned-segment variant: all n_repeat layers convert in one program
    per_rep = lambda kk, kd, vd: _convert_core(kk, kd, vd, prompt_len, kc)
    return jax.vmap(per_rep)(keys, k_dense, v_dense)


def compress_prefill_cache(
    key,
    cfg: ModelConfig,
    cache: dict,
    kc: KVCompressionConfig,
    *,
    registry: Optional[MetricsRegistry] = None,
) -> dict:
    """Convert every global-attention (``ATTN``) layer cache in a prefilled
    decode cache to :class:`CompressedKV`; other mixers (local/ring caches,
    MLA latents, SSM state — already O(1) or structurally different) pass
    through untouched.

    Layer ``i`` (flat position over segments × unit, counting every spec)
    converts with ``fold_in(key, i)``; scanned segments convert all
    repeats in one vmapped program. Returns a new cache dict sharing the
    unconverted entries.
    """
    reg = registry if registry is not None else default_registry()
    prompt_len = int(cache["length"])
    seg_caches = []
    li = 0
    n_conv = 0
    with span("serve/kv_cache/convert", reg):
        for seg, seg_cache in zip(segments(cfg), cache["segments"]):
            pos_caches = []
            for pos, spec in enumerate(seg.unit):
                c = seg_cache[pos]
                if spec.mixer == ATTN:
                    lk = jax.random.fold_in(key, li)
                    if seg.n_repeat == 1:
                        c = _convert_one(lk, c["k"], c["v"], prompt_len=prompt_len, kc=kc)
                        n_conv += 1
                    else:
                        reps = jax.random.split(lk, seg.n_repeat)
                        c = _convert_rep(reps, c["k"], c["v"], prompt_len, kc)
                        n_conv += seg.n_repeat
                li += 1
                pos_caches.append(c)
            seg_caches.append(tuple(pos_caches))
    out = {"segments": tuple(seg_caches), "length": cache["length"]}
    if reg.enabled:
        reg.inc("serve/kv_layers_converted", n_conv)
        reg.set_gauge("serve/kv_cache_bytes", cache_nbytes(out))
    return out


def cache_nbytes(cache) -> int:
    """Total bytes of every array leaf of a cache pytree — honest accounting:
    for a :class:`CompressedKV` this includes the carried engine state and
    recent buffers, not just the factors."""
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))
