"""Serving substrate: generation loop + streaming-SVD KV compression.

Layers: :mod:`~repro.serve.kv_compress` (prefill-time head-batch
compression as a :mod:`repro.stream` panel-engine plug-in),
:mod:`~repro.serve.kv_cache` (the decode-native
:class:`~repro.serve.kv_cache.CompressedKV` cache that keeps folding
generated tokens into the carried engine state), and
:mod:`~repro.serve.decode` (the fused single-dispatch-per-token
generation loop). See ``docs/serving.md``.
"""
from .decode import generate, sample_token
from .kv_cache import CompressedKV, cache_nbytes, compress_prefill_cache, init_compressed_kv
from .kv_compress import (
    KVCompressionConfig,
    LowRankKV,
    compress_head_batch,
    compress_history,
    compression_error,
    lowrank_decode_attention,
)

__all__ = [
    "CompressedKV", "KVCompressionConfig", "LowRankKV",
    "cache_nbytes", "compress_head_batch", "compress_history",
    "compress_prefill_cache", "compression_error", "generate",
    "init_compressed_kv", "lowrank_decode_attention", "sample_token",
]
