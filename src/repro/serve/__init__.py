"""Serving substrate: generation loop + streaming-SVD KV compression."""
from .decode import generate, sample_token
from .kv_compress import KVCompressionConfig, LowRankKV, compress_head_batch, compress_history, compression_error, lowrank_decode_attention
