"""Streaming low-rank KV-cache compression via Fast SP-SVD (paper Alg. 3).

The K (and V) history of an attention head is a tall matrix H ∈ R^{S×d}.
During prefill we stream Hᵀ through Algorithm 3's panel loop (one pass,
O((S+d)·r) memory) and keep rank-r factors

    H ≈ V_s Σ Uᵀ        (V_s ∈ R^{S×r},  U ∈ R^{d×r})

Decode then attends in factor space:
    scores  = H q  ≈ V_s (Σ (Uᵀ q))        cost S·r + r·d   (vs S·d)
    output  = pᵀ V_hist ≈ ((pᵀ V_s^v) Σ_v) U_vᵀ

Memory: (S+d)·r vs S·d floats per head → d/r× cache compression.

The compressor is a :mod:`repro.stream` plug-in: per-head state is the
engine's :class:`~repro.stream.PanelState` built by
:func:`repro.core.svd.spsvd_engine_init`, prefill runs as **one fused
``lax.scan`` program per head-batch** (the pure panel core is vmapped over
(batch, kv-head) and jitted once per shape), and the same engine state is
carried *into decode* by :mod:`repro.serve.kv_cache`, which folds newly
generated tokens panel-by-panel and periodically refactorizes — the
paper's single-pass streaming regime applied to the KV memory wall
(beyond-paper integration; see ``docs/serving.md``).

Per-head **adaptive rank** (``KVCompressionConfig(adaptive=True)``) reuses
the streaming-CUR budget machinery
(:func:`repro.stream.allocate_shared_budget`): the shared budget
``KV·rank`` per request is spent greedily on the heads with the heaviest
spectra, so a spiked head can keep up to ``max_rank`` directions while a
flat head falls back to ``min_rank``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.svd import spsvd_engine_finalize, spsvd_engine_init
from repro.obs.metrics import MetricsRegistry, default_registry
from repro.obs.spans import span
from repro.stream.adaptive import allocate_shared_budget
from repro.stream.engine import panel_update, scan_panels, stream_panels


@dataclasses.dataclass(frozen=True)
class KVCompressionConfig:
    """Static configuration of the KV compressor (hashable → jit-static).

    ``rank``/``oversample``/``panel`` govern prefill compression; the
    remaining fields govern the decode-native path
    (:mod:`repro.serve.kv_cache`) and adaptive per-head rank.
    """

    rank: int = 16
    oversample: int = 4  # c = r = oversample·rank for the Alg. 3 sketches
    panel: int = 1024  # prefill streaming panel (tokens)
    decode_panel: int = 64  # decode-native fold width (generated tokens)
    refresh_every: int = 256  # refactorize after this many folded tokens
    adaptive: bool = False  # per-head rank from a shared KV·rank budget
    min_rank: int = 4  # adaptive floor per head
    max_rank: Optional[int] = None  # adaptive cap per head (default 2·rank)

    def __post_init__(self):
        """Validate the decode/adaptive schedule at construction time."""
        if self.refresh_every % self.decode_panel:
            raise ValueError(
                f"refresh_every={self.refresh_every} must be a multiple of "
                f"decode_panel={self.decode_panel} (refresh fires on fold boundaries)"
            )
        if self.adaptive and self.min_rank > self.rank:
            raise ValueError(
                f"adaptive floor min_rank={self.min_rank} exceeds the per-head "
                f"budget share rank={self.rank}"
            )


@dataclasses.dataclass
class LowRankKV:
    """Factors per head-batch: H ≈ V_s diag(sigma) Uᵀ."""

    v_s: jax.Array  # (..., S, r)
    sigma: jax.Array  # (..., r)
    u: jax.Array  # (..., d, r)


jax.tree_util.register_dataclass(LowRankKV, data_fields=["v_s", "sigma", "u"], meta_fields=[])


def _sizes(d: int, kc: KVCompressionConfig) -> dict:
    # c is capped by the source dim d (C spans at most R^d), but the GMR
    # sketches must stay strictly larger than c to be subspace embeddings —
    # s_c = c (square sketch) destroys the core solve, so never clamp them.
    c = min(d, kc.oversample * kc.rank)
    return dict(c=c, r=c, c0=2 * c, r0=2 * c, s_c=3 * c, s_r=3 * c)


def _fac_width(d: int, kc: KVCompressionConfig) -> int:
    # stored factor width: the uniform rank, or the adaptive cap (budget is
    # enforced by sigma masking — see _allocate_ranks)
    c = _sizes(d, kc)["c"]
    if not kc.adaptive:
        return min(c, kc.rank)
    cap = kc.max_rank if kc.max_rank is not None else 2 * kc.rank
    return min(c, cap)


def _engine_init(key, d: int, n_cols: int, kc: KVCompressionConfig, *, panel=None):
    # osnap_p=4: at KV head dims the inner S_C/S_R must embed all of R^d;
    # p=2 leaves ~10% odds of a double hash collision annihilating a
    # direction (cond(S_C U_C) ~ 1e7 → 0.1+ reconstruction error).
    return spsvd_engine_init(
        key, d, n_cols, sizes=_sizes(d, kc), dtype=jnp.float32, osnap_p=4, panel=panel
    )


def _compress_core(key, hist: jax.Array, kc: KVCompressionConfig) -> LowRankKV:
    # pure-jax per-head core (vmap/jit-safe): scan the full panels of
    # Hᵀ (d, S) at absolute offsets, fold the ragged tail as one exact
    # static-width panel, finalize at the stored factor width.
    S, d = hist.shape
    panel = min(kc.panel, S)
    state = _engine_init(key, d, S, kc)
    hist_T = hist.T.astype(jnp.float32)
    n_full = S // panel
    if n_full:
        state = scan_panels(state, hist_T, n_full, panel)
    if S % panel:
        state = panel_update(state, hist_T[:, n_full * panel :])
    U, sig, V = spsvd_engine_finalize(state, k=_fac_width(d, kc))
    return LowRankKV(v_s=V, sigma=sig, u=U)


def compress_history(key, hist: jax.Array, kc: KVCompressionConfig) -> LowRankKV:
    """hist: (S, d) one head's K or V history → rank-r factors (single pass).

    Host-level convenience wrapper: streams Aᵀ = histᵀ (d, S) through the
    engine's scan-compiled :func:`repro.stream.stream_panels` driver (state
    buffers donated, ragged tail zero-padded exactly). The batched serving
    path (:func:`compress_head_batch`) maps the same panel core over
    (batch, kv-head) instead, so both produce identical factors for a
    shared key.
    """
    S, d = hist.shape
    panel = min(kc.panel, S)
    state = _engine_init(key, d, S, kc, panel=panel)
    with span("serve/kv_compress/prefill"):
        state = stream_panels(state, hist.T.astype(jnp.float32), panel)
    with span("serve/kv_compress/finalize"):
        U, sig, V = spsvd_engine_finalize(state, k=_fac_width(d, kc))
    return LowRankKV(v_s=V, sigma=sig, u=U)


@partial(jax.jit, static_argnames="kc")
def _compress_batch(keys, hist, kc: KVCompressionConfig):
    # one compiled program per (B, KV, S, d, kc): the scan over panels is
    # vmapped across batch and head axes — prefill compression for a whole
    # request batch is a single fused dispatch
    per_head = lambda k, h: _compress_core(k, h, kc)
    return jax.vmap(jax.vmap(per_head))(keys, hist)


@partial(jax.jit, static_argnames="kc")
def _allocate_ranks(sigma, kc: KVCompressionConfig):
    # shared budget KV·rank per request, spent on σ² marginals (descending
    # per head by construction) — the admission greedy at head granularity
    B, KV, fw = sigma.shape
    floor = min(kc.min_rank, fw)
    alloc = jax.vmap(
        lambda s: allocate_shared_budget(s * s, KV * kc.rank, floor=floor, cap=fw)
    )(sigma)
    keep = jnp.arange(fw)[None, None, :] < alloc[:, :, None]
    return jnp.where(keep, sigma, 0.0), alloc


def compress_head_batch(
    key,
    hist: jax.Array,
    kc: KVCompressionConfig,
    *,
    registry: Optional[MetricsRegistry] = None,
) -> LowRankKV:
    """hist: (B, KV, S, d) → vmapped factors (B, KV, ...).

    One fused scan program per head-batch shape (see
    :func:`_compress_batch`). With ``kc.adaptive`` the per-head rank is
    re-allocated from the shared ``KV·rank`` budget by zeroing the tail of
    each head's ``sigma`` (factors are stored at the ``max_rank`` width;
    masked directions contribute nothing to decode attention).

    When the active registry (``registry=`` or the process default) is
    enabled, compression-quality metrics are recorded via **one** batched
    device computation and a **single** host transfer
    (:meth:`repro.obs.metrics.MetricsRegistry.record_kv_compression`):
    the ``serve/kv_rel_err`` histogram (one relative reconstruction error
    per head), the ``serve/kv_compression_ratio`` gauge, the
    ``serve/kv_heads_compressed`` counter, and — adaptive only — the
    ``serve/kv_head_rank`` histogram of allocated ranks.
    """
    reg = registry if registry is not None else default_registry()
    B, KV, S, d = hist.shape
    keys = jax.random.split(key, B * KV).reshape(B, KV)
    ranks = None
    with span("serve/kv_compress/head_batch", reg):
        fac = _compress_batch(keys, hist, kc)
        if kc.adaptive:
            sigma, ranks = _allocate_ranks(fac.sigma, kc)
            fac = LowRankKV(v_s=fac.v_s, sigma=sigma, u=fac.u)
    if reg.enabled and not isinstance(hist, jax.core.Tracer):
        errs = _batched_error(hist, fac)
        r = fac.sigma.shape[-1]
        reg.record_kv_compression(errs, ratio=(S * d) / ((S + d + 1) * r), ranks=ranks)
    return fac


_batched_error = jax.jit(
    lambda hist, fac: jax.vmap(jax.vmap(lambda h, f: compression_error(h, f)))(hist, fac)
)


def lowrank_decode_attention(
    q: jax.Array,
    k_fac: LowRankKV,
    v_fac: LowRankKV,
    length: jax.Array,
) -> jax.Array:
    """q: (B, KV, G, d) grouped queries; factors (B, KV, ...). Returns (B,KV,G,d)."""
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    # scores = V_s (Σ (Uᵀ q))
    uq = jnp.einsum("bkdr,bkgd->bkgr", k_fac.u, q.astype(jnp.float32))
    uq = uq * k_fac.sigma[:, :, None, :]
    s = jnp.einsum("bksr,bkgr->bkgs", k_fac.v_s, uq) * scale  # (B,KV,G,S)
    S = s.shape[-1]
    mask = jnp.arange(S) < length
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # output = ((p V_s^v) Σ_v) U_vᵀ
    pv = jnp.einsum("bkgs,bksr->bkgr", p, v_fac.v_s) * v_fac.sigma[:, :, None, :]
    return jnp.einsum("bkgr,bkdr->bkgd", pv, v_fac.u)


def compression_error(hist: jax.Array, fac: LowRankKV) -> jax.Array:
    """Relative Frobenius reconstruction error of one head's factors."""
    rec = (fac.v_s * fac.sigma[None, :]) @ fac.u.T
    return jnp.linalg.norm(hist - rec) / jnp.maximum(jnp.linalg.norm(hist), 1e-30)
