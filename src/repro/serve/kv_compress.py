"""Streaming low-rank KV-cache compression via Fast SP-SVD (paper Alg. 3).

The K (and V) history of an attention head is a tall matrix H ∈ R^{S×d}.
During prefill we stream Hᵀ through Algorithm 3's panel loop (one pass,
O((S+d)·r) memory) and keep rank-r factors

    H ≈ V_s Σ Uᵀ        (V_s ∈ R^{S×r},  U ∈ R^{d×r})

Decode then attends in factor space:
    scores  = H q  ≈ V_s (Σ (Uᵀ q))        cost S·r + r·d   (vs S·d)
    output  = pᵀ V_hist ≈ ((pᵀ V_s^v) Σ_v) U_vᵀ

Memory: (S+d)·r vs S·d floats per head → d/r× cache compression.
This is the paper's single-pass-SVD motivation re-targeted at the
long-context KV memory wall (beyond-paper integration; see DESIGN.md §4.2).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.svd import sp_svd_finalize, sp_svd_init, sp_svd_update
from repro.obs.metrics import MetricsRegistry, default_registry
from repro.obs.spans import span


@dataclasses.dataclass(frozen=True)
class KVCompressionConfig:
    rank: int = 16
    oversample: int = 4  # c = r = oversample·rank for the Alg. 3 sketches
    panel: int = 1024  # prefill streaming panel (tokens)


@dataclasses.dataclass
class LowRankKV:
    """Factors per head-batch: H ≈ V_s diag(sigma) Uᵀ."""

    v_s: jax.Array  # (..., S, r)
    sigma: jax.Array  # (..., r)
    u: jax.Array  # (..., d, r)


def _sizes(d: int, kc: KVCompressionConfig) -> dict:
    # c is capped by the source dim d (C spans at most R^d), but the GMR
    # sketches must stay strictly larger than c to be subspace embeddings —
    # s_c = c (square sketch) destroys the core solve, so never clamp them.
    c = min(d, kc.oversample * kc.rank)
    return dict(c=c, r=c, c0=2 * c, r0=2 * c, s_c=3 * c, s_r=3 * c)


def compress_history(key, hist: jax.Array, kc: KVCompressionConfig) -> LowRankKV:
    """hist: (S, d) one head's K or V history → rank-r factors (single pass).

    Streams Aᵀ = histᵀ (d, S) column panels through Algorithm 3.
    """
    S, d = hist.shape
    sizes = _sizes(d, kc)
    # osnap_p=4: at KV head dims the inner S_C/S_R must embed all of R^d;
    # p=2 leaves ~10% odds of a double hash collision annihilating a
    # direction (cond(S_C U_C) ~ 1e7 → 0.1+ reconstruction error).
    state = sp_svd_init(key, d, S, sizes=sizes, dtype=jnp.float32, osnap_p=4)
    panel = min(kc.panel, S)
    n_full = S // panel
    with span("serve/kv_compress/prefill"):
        for i in range(n_full):
            state = sp_svd_update(state, hist[i * panel : (i + 1) * panel].T.astype(jnp.float32))
        if S % panel:
            state = sp_svd_update(state, hist[n_full * panel :].T.astype(jnp.float32))
    with span("serve/kv_compress/finalize"):
        U, sig, V = sp_svd_finalize(state, k=kc.rank)  # A=histᵀ: U (d,r), V (S,r)
    return LowRankKV(v_s=V, sigma=sig, u=U)


def compress_head_batch(
    key,
    hist: jax.Array,
    kc: KVCompressionConfig,
    *,
    registry: Optional[MetricsRegistry] = None,
) -> LowRankKV:
    """hist: (B, KV, S, d) → vmapped factors (B, KV, ...).

    When the active registry (``registry=`` or the process default) is
    enabled, per-head compression-quality metrics are recorded *outside*
    the vmapped compute: a ``serve/kv_rel_err`` histogram (one relative
    reconstruction error per head — costs one rank-r reconstruction per
    head, observability only), the ``serve/kv_compression_ratio`` gauge
    (dense vs factor floats), and a ``serve/kv_heads_compressed`` counter.
    """
    reg = registry if registry is not None else default_registry()
    B, KV, S, d = hist.shape
    keys = jax.random.split(key, B * KV).reshape(B, KV)
    fn = lambda k, h: compress_history(k, h, kc)
    inner = jax.vmap(fn, in_axes=(0, 0))
    outer = jax.vmap(inner, in_axes=(0, 0))
    with span("serve/kv_compress/head_batch", reg):
        out = outer(keys, hist)
    fac = LowRankKV(v_s=out.v_s, sigma=out.sigma, u=out.u)
    if reg.enabled and not isinstance(hist, jax.core.Tracer):
        errs = jax.vmap(jax.vmap(compression_error))(hist, fac)
        for e in np.asarray(errs).ravel():
            reg.observe("serve/kv_rel_err", float(e))
        reg.inc("serve/kv_heads_compressed", B * KV)
        r = fac.sigma.shape[-1]
        reg.set_gauge("serve/kv_compression_ratio", (S * d) / ((S + d + 1) * r))
    return fac


jax.tree_util.register_dataclass(LowRankKV, data_fields=["v_s", "sigma", "u"], meta_fields=[])


def lowrank_decode_attention(
    q: jax.Array,
    k_fac: LowRankKV,
    v_fac: LowRankKV,
    length: jax.Array,
) -> jax.Array:
    """q: (B, KV, G, d) grouped queries; factors (B, KV, ...). Returns (B,KV,G,d)."""
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    # scores = V_s (Σ (Uᵀ q))
    uq = jnp.einsum("bkdr,bkgd->bkgr", k_fac.u, q.astype(jnp.float32))
    uq = uq * k_fac.sigma[:, :, None, :]
    s = jnp.einsum("bksr,bkgr->bkgs", k_fac.v_s, uq) * scale  # (B,KV,G,S)
    S = s.shape[-1]
    mask = jnp.arange(S) < length
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # output = ((p V_s^v) Σ_v) U_vᵀ
    pv = jnp.einsum("bkgs,bksr->bkgr", p, v_fac.v_s) * v_fac.sigma[:, :, None, :]
    return jnp.einsum("bkgr,bkdr->bkgd", pv, v_fac.u)


def compression_error(hist: jax.Array, fac: LowRankKV) -> jax.Array:
    """Relative Frobenius reconstruction error of one head's factors."""
    rec = (fac.v_s * fac.sigma[None, :]) @ fac.u.T
    return jnp.linalg.norm(hist - rec) / jnp.maximum(jnp.linalg.norm(hist), 1e-30)
