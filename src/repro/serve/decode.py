"""Serving drivers: batched generation loop over prefill + decode_step."""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import decode_step, init_cache, prefill
from repro.models.config import ModelConfig


def sample_token(key, logits: jax.Array, temperature: float = 0.0) -> jax.Array:
    """logits (B, 1, V) → (B, 1) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits[:, 0] / temperature)[:, None].astype(jnp.int32)


def generate(
    params,
    cfg: ModelConfig,
    prompt: jax.Array,
    n_tokens: int,
    *,
    key=None,
    temperature: float = 0.0,
    vision: Optional[jax.Array] = None,
    dense_moe: bool = False,
):
    """Greedy/temperature generation. prompt: (B, S). Returns (B, n_tokens)."""
    B, S = prompt.shape
    key = key if key is not None else jax.random.key(0)
    cache_len = S + n_tokens
    logits, cache = prefill(params, cfg, prompt, cache_len, vision=vision, dense_moe=dense_moe)

    step = jax.jit(partial(decode_step, dense_moe=dense_moe), static_argnums=(1,))

    toks = []
    tok = sample_token(key, logits, temperature)
    toks.append(tok)
    for i in range(n_tokens - 1):
        key = jax.random.fold_in(key, i)
        logits, cache = step(params, cfg, cache, tok)
        tok = sample_token(key, logits, temperature)
        toks.append(tok)
    return jnp.concatenate(toks, axis=1)
