"""Serving drivers: batched generation loop over prefill + decode_step.

The per-token loop runs one fused jitted dispatch per token
(:func:`_fused_decode_step`): the decode step, the RNG fold and the token
sampling all live in a single module-scope compiled program (one trace per
(config, shapes, temperature, dense_moe) for the process lifetime) with
the carried cache donated. With ``kv_compress=`` the prefilled
global-attention caches are converted to decode-native compressed caches
(:mod:`repro.serve.kv_cache`) before the loop, so the same single program
folds generated tokens into the streaming factorization as it decodes.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import decode_step, init_cache, prefill
from repro.models.config import ModelConfig

from .kv_compress import KVCompressionConfig
from .kv_cache import compress_prefill_cache


def sample_token(key, logits: jax.Array, temperature: float = 0.0) -> jax.Array:
    """logits (B, 1, V) → (B, 1) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits[:, 0] / temperature)[:, None].astype(jnp.int32)


@partial(jax.jit, static_argnums=(1, 6, 7), donate_argnums=(2,))
def _fused_decode_step(params, cfg, cache, tok, key, step_i, temperature, dense_moe):
    # single dispatch per token: decode + RNG fold + sampling in one
    # program. The key chain reproduces the legacy host loop exactly:
    # key_{i+1} = fold_in(key_i, i), sampled with key_{i+1}.
    key_i = jax.random.fold_in(key, step_i)
    logits, cache = decode_step(params, cfg, cache, tok, dense_moe=dense_moe)
    return sample_token(key_i, logits, temperature), cache, key_i


def generate(
    params,
    cfg: ModelConfig,
    prompt: jax.Array,
    n_tokens: int,
    *,
    key=None,
    temperature: float = 0.0,
    vision: Optional[jax.Array] = None,
    dense_moe: bool = False,
    kv_compress: Optional[KVCompressionConfig] = None,
    registry=None,
):
    """Greedy/temperature generation. prompt: (B, S). Returns (B, n_tokens).

    ``kv_compress`` switches every global-attention layer onto the
    decode-native compressed cache after prefill (see
    :func:`repro.serve.kv_cache.compress_prefill_cache`; the conversion key
    is ``fold_in(key, n_tokens)``, disjoint from the sampling chain).
    ``registry`` forwards a :class:`repro.obs.metrics.MetricsRegistry` to
    the conversion for cache-size metrics.
    """
    B, S = prompt.shape
    key = key if key is not None else jax.random.key(0)
    cache_len = S + n_tokens
    logits, cache = prefill(params, cfg, prompt, cache_len, vision=vision, dense_moe=dense_moe)
    if kv_compress is not None:
        ckey = jax.random.fold_in(key, n_tokens)
        cache = compress_prefill_cache(ckey, cfg, cache, kv_compress, registry=registry)

    toks = [sample_token(key, logits, temperature)]
    for i in range(n_tokens - 1):
        tok, cache, key = _fused_decode_step(
            params, cfg, cache, toks[-1], key, jnp.asarray(i, jnp.int32),
            temperature, dense_moe,
        )
        toks.append(tok)
    return jnp.concatenate(toks, axis=1)
