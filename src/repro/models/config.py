"""Unified model configuration for the 10 assigned architectures.

A model is a *pattern* of residual blocks. Each block has a mixer
(attention variant / Mamba-2 SSD / cross-attention) and an optional FFN
(dense SwiGLU/GELU or MoE). The pattern is compiled into repeated
*segments* so that ``jax.lax.scan`` over stacked per-repeat parameters
keeps HLO size O(#distinct block kinds) regardless of depth.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

# Mixer kinds
ATTN = "attn"            # GQA + RoPE, full causal
ATTN_LOCAL = "attn_local"  # GQA + RoPE, sliding window
MLA = "mla"              # DeepSeek-V2 multi-head latent attention
MAMBA2 = "mamba2"        # Mamba-2 SSD
CROSS = "cross"          # cross-attention over modality embeddings
SHARED_ATTN = "shared_attn"  # Zamba2-style block with weights shared across occurrences

# FFN kinds
DENSE = "dense"
MOE = "moe"
NONE = "none"


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One residual layer: (mixer, ffn)."""

    mixer: str
    ffn: str = DENSE

    @property
    def signature(self) -> Tuple[str, str]:
        return (self.mixer, self.ffn)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    pattern: Tuple[BlockSpec, ...]

    head_dim: int = 128
    # Attention
    rope_theta: float = 1e4
    rope_theta_global: Optional[float] = None  # per-layer override for global layers
    window: Optional[int] = None  # sliding window for ATTN_LOCAL
    attn_chunk: int = 512  # online-softmax block size
    # MLA (DeepSeek-V2)
    kv_lora_rank: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128
    # Mamba-2 SSD
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # >1: dispatch per token-shard group (set = DP size) so the capacity
    # buffer scatter is local to each data rank — without it the partitioner
    # replicates expert compute across the data axis (§Perf B5)
    moe_dispatch_shards: int = 1
    # Modality (vlm/audio stubs)
    d_vision: int = 0
    n_patches: int = 0
    # Numerics
    dtype: str = "bfloat16"
    activation: str = "silu"  # silu (SwiGLU) | gelu
    # Attention autodiff implementation:
    #   scan_ad     — differentiate through the online-softmax scan (baseline;
    #                 saves stacked per-pair residuals → memory-heavy backward)
    #   custom_vjp  — flash backward: save only (q,k,v,out,lse), recompute p
    #                 per block pair (§Perf iteration A1; default after validation
    #                 — the paper-faithful baseline artifacts used scan_ad)
    attn_impl: str = "custom_vjp"
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    logit_softcap: Optional[float] = None

    # ---- derived ----
    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def __post_init__(self):
        assert len(self.pattern) == self.n_layers, (
            f"{self.name}: pattern has {len(self.pattern)} blocks, n_layers={self.n_layers}"
        )

    def validate_tpu_alignment(self):
        """Warn-level checks that TP-sharded dims are 128-multiples (MXU lanes)."""
        issues = []
        if self.n_heads and (self.n_heads * self.head_dim) % 128:
            issues.append(f"attn width {self.n_heads * self.head_dim} not 128-aligned")
        if self.d_ff % 128:
            issues.append(f"d_ff {self.d_ff} not 128-aligned")
        return issues


@dataclasses.dataclass(frozen=True)
class Segment:
    """A run of layers expressed as (unit pattern) × n_repeat for scan."""

    unit: Tuple[BlockSpec, ...]
    n_repeat: int


def compile_pattern(pattern: Sequence[BlockSpec], max_unit: int = 8) -> Tuple[Segment, ...]:
    """Factor a layer pattern into scan-friendly segments.

    Finds the smallest unit length u ≤ max_unit such that a maximal suffix
    of the pattern is a whole number of u-sized repeats of one unit; any
    non-conforming prefix becomes its own (unit, 1) segments. This covers
    every assigned arch: uniform stacks (u=1), DeepSeek/Kimi's dense-first
    prefix, Gemma-3's 5:1 unit (u=6), Zamba2's 6-layer unit + tail, and the
    VLM's [4×self + cross] unit (u=5).
    """
    n = len(pattern)
    best = None  # (prefix_len, unit_len) minimizing HLO size ~ prefix_len + unit_len
    for u in range(1, max_unit + 1):
        # longest suffix that is repeats of its first u blocks
        for prefix in range(0, n):
            if (n - prefix) % u:
                continue
            unit = tuple(pattern[prefix : prefix + u])
            reps = (n - prefix) // u
            if all(
                pattern[prefix + i * u + j].signature == unit[j].signature
                for i in range(reps)
                for j in range(u)
            ):
                cost = prefix + u
                if best is None or cost < best[0]:
                    best = (cost, prefix, u)
                break  # smallest prefix for this u
    assert best is not None
    _, prefix, u = best
    segments = []
    for i in range(prefix):
        segments.append(Segment(unit=(pattern[i],), n_repeat=1))
    reps = (n - prefix) // u
    if reps:
        segments.append(Segment(unit=tuple(pattern[prefix : prefix + u]), n_repeat=reps))
    return tuple(segments)
