"""Modality frontend STUBS for the [audio]/[vlm] architectures.

Per the assignment contract, the backbone is real and the frontend is a
stub: ``input_specs()`` (in each arch config) provides *precomputed*
frame/patch embeddings. These helpers generate matching synthetic inputs
for smoke tests and examples.

* musicgen-large  — the EnCodec codec is the stub; the backbone consumes
  codec *token ids* over the 2048-entry vocabulary (the assignment's
  vocab=2048), so its inputs look like ordinary LM tokens.
* llama-3.2-vision-90b — the ViT tower is the stub; cross-attention layers
  consume precomputed patch embeddings (B, n_patches, d_vision).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def synth_audio_tokens(key, cfg: ModelConfig, batch: int, seq: int) -> jax.Array:
    """Stand-in for EnCodec output: uniform codec token ids."""
    return jax.random.randint(key, (batch, seq), 0, cfg.vocab_size, jnp.int32)


def synth_patch_embeddings(key, cfg: ModelConfig, batch: int) -> jax.Array:
    """Stand-in for the ViT tower output: (B, n_patches, d_vision) bf16."""
    return jax.random.normal(key, (batch, cfg.n_patches, cfg.d_vision), jnp.float32).astype(
        cfg.param_dtype
    )
