"""Residual blocks: pre-norm mixer (+ pre-norm FFN) with per-kind caches.

Every block kind exposes:
  init_block(key, spec, cfg)                      → params
  block_train(params, spec, cfg, x, extras)       → (x, aux_loss)
  block_prefill(params, spec, cfg, x, cache_len, extras) → (x, aux, cache)
  block_decode(params, spec, cfg, x, cache, length, extras) → (x, cache)
  init_block_cache(spec, cfg, batch, cache_len)   → cache pytree

Cache layouts (the serving memory story):
  attn        : K/V (B, cache_len, KV, hd)         — full history
  attn_local  : K/V (B, window, KV, hd)            — ring buffer
  mla         : latent (B, cache_len, r+rope)      — MLA's compressed cache
  mamba2      : conv (B, K-1, C) + state (B,H,N,P) — O(1) in sequence length
  cross       : K/V (B, n_patches, KV, hd)         — static after prefill
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import mla as mla_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .attention import attention_train, decode_attention, flash_attention
from .config import (
    ATTN,
    ATTN_LOCAL,
    CROSS,
    DENSE,
    MAMBA2,
    MLA,
    MOE,
    NONE,
    SHARED_ATTN,
    BlockSpec,
    ModelConfig,
)
from .layers import apply_rope, ffn, init_ffn, init_rmsnorm, rmsnorm, truncated_normal_init


# ---------------------------------------------------------------------------
# GQA attention mixer
# ---------------------------------------------------------------------------


def _init_gqa(key, cfg: ModelConfig) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(D)
    return {
        "w_q": truncated_normal_init(ks[0], (D, H * hd), cfg.param_dtype, s),
        "w_k": truncated_normal_init(ks[1], (D, KV * hd), cfg.param_dtype, s),
        "w_v": truncated_normal_init(ks[2], (D, KV * hd), cfg.param_dtype, s),
        "w_o": truncated_normal_init(ks[3], (H * hd, D), cfg.param_dtype, 1.0 / np.sqrt(H * hd)),
    }


def _theta_for(spec_mixer: str, cfg: ModelConfig) -> float:
    if spec_mixer in (ATTN, SHARED_ATTN) and cfg.rope_theta_global:
        return cfg.rope_theta_global
    return cfg.rope_theta


def _gqa_qkv(params, x, positions, cfg: ModelConfig, theta: float):
    from repro.distributed.sharding import shard_act

    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = shard_act((x @ params["w_q"]).reshape(B, S, H, hd), "bthd")
    k = shard_act((x @ params["w_k"]).reshape(B, S, KV, hd), "bthd")
    v = shard_act((x @ params["w_v"]).reshape(B, S, KV, hd), "bthd")
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def _gqa_train(params, spec_mixer, cfg, x):
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    q, k, v = _gqa_qkv(params, x, positions, cfg, _theta_for(spec_mixer, cfg))
    window = cfg.window if spec_mixer == ATTN_LOCAL else None
    o = attention_train(q, k, v, window=window, chunk=cfg.attn_chunk, impl=cfg.attn_impl)
    return o.reshape(B, S, -1) @ params["w_o"]


def _gqa_prefill(params, spec_mixer, cfg, x, cache_len):
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    q, k, v = _gqa_qkv(params, x, positions, cfg, _theta_for(spec_mixer, cfg))
    window = cfg.window if spec_mixer == ATTN_LOCAL else None
    o = flash_attention(q, k, v, window=window, chunk=cfg.attn_chunk)

    if spec_mixer == ATTN_LOCAL:
        w = cfg.window
        keep = min(S, w)
        tail_k, tail_v = k[:, S - keep :], v[:, S - keep :]
        slots = (np.arange(S - keep, S)) % w
        ck = jnp.zeros((B, w, cfg.n_kv_heads, cfg.head_dim), k.dtype).at[:, slots].set(tail_k)
        cv = jnp.zeros((B, w, cfg.n_kv_heads, cfg.head_dim), v.dtype).at[:, slots].set(tail_v)
        cache = {"k": ck, "v": cv}
    else:
        pad = cache_len - S
        cache = {
            "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
            "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
        }
    return o.reshape(B, S, -1) @ params["w_o"], cache


def _gqa_decode(params, spec_mixer, cfg, x, cache, length):
    B = x.shape[0]
    positions = jnp.broadcast_to(length[None, None], (B, 1))
    q, k, v = _gqa_qkv(params, x, positions, cfg, _theta_for(spec_mixer, cfg))
    if not isinstance(cache, dict):
        # pluggable cache backend (e.g. repro.serve.kv_cache.CompressedKV):
        # owns its own append + attention under decode_attention's contract
        o, cache = cache.append_attend(q, k, v, length)
        return o.reshape(B, 1, -1) @ params["w_o"], cache
    if spec_mixer == ATTN_LOCAL:
        w = cfg.window
        slot = length % w
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        # ring: every slot with index < min(length+1, w) tokens is valid; all
        # contents are within-window by construction → plain length mask on slots.
        o = decode_attention(q, ck, cv, jnp.minimum(length + 1, w))
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), length, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), length, axis=1)
        o = decode_attention(q, ck, cv, length + 1)
    return o.reshape(B, 1, -1) @ params["w_o"], {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# Cross-attention mixer (VLM)
# ---------------------------------------------------------------------------


def _init_cross(key, cfg: ModelConfig) -> dict:
    p = _init_gqa(key, cfg)
    p["gate"] = jnp.zeros((), jnp.float32)  # tanh-gated residual (llama-vision style)
    return p


def _cross_kv(params, vis, cfg: ModelConfig):
    B, P, _ = vis.shape
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    k = (vis @ params["w_k"]).reshape(B, P, KV, hd)
    v = (vis @ params["w_v"]).reshape(B, P, KV, hd)
    return k, v


def _cross_attend(params, cfg, x, k, v):
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    KV = cfg.n_kv_heads
    q = (x @ params["w_q"]).reshape(B, S, H, hd)
    qg = q.reshape(B, S, KV, H // KV, hd)
    s = jnp.einsum("bqkgd,bpkd->bkgqp", qg, k).astype(jnp.float32) / math.sqrt(hd)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqp,bpkd->bqkgd", p.astype(v.dtype), v).reshape(B, S, H * hd)
    return (jnp.tanh(params["gate"]) * (o @ params["w_o"])).astype(x.dtype)


# ---------------------------------------------------------------------------
# Block-level dispatch
# ---------------------------------------------------------------------------


def init_block(key, spec: BlockSpec, cfg: ModelConfig) -> dict:
    k_mix, k_ffn, k_n1, k_n2 = jax.random.split(key, 4)
    p: dict = {"norm1": init_rmsnorm(cfg.d_model, cfg.param_dtype)}
    if spec.mixer in (ATTN, ATTN_LOCAL):
        p["mixer"] = _init_gqa(k_mix, cfg)
    elif spec.mixer == SHARED_ATTN:
        p["mixer"] = {}  # weights live in the model-level shared collection
    elif spec.mixer == MLA:
        p["mixer"] = mla_mod.init_mla(k_mix, cfg)
    elif spec.mixer == MAMBA2:
        p["mixer"] = ssm_mod.init_mamba2(k_mix, cfg)
    elif spec.mixer == CROSS:
        p["mixer"] = _init_cross(k_mix, cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn != NONE:
        p["norm2"] = init_rmsnorm(cfg.d_model, cfg.param_dtype)
        if spec.ffn == DENSE:
            p["ffn"] = init_ffn(k_ffn, cfg.d_model, cfg.d_ff, cfg.param_dtype, cfg.activation)
        elif spec.ffn == MOE:
            p["ffn"] = moe_mod.init_moe(k_ffn, cfg)
        else:
            raise ValueError(spec.ffn)
    return p


def _apply_ffn(params, spec: BlockSpec, cfg: ModelConfig, x, dense_moe: bool):
    if spec.ffn == NONE:
        return x, jnp.zeros((), jnp.float32)
    h = rmsnorm(params["norm2"], x, cfg.norm_eps)
    if spec.ffn == DENSE:
        return x + ffn(params["ffn"], h, cfg.activation), jnp.zeros((), jnp.float32)
    fn = moe_mod.moe_ffn_dense if dense_moe else moe_mod.moe_ffn
    out, aux = fn(params["ffn"], h, cfg)
    return x + out, aux


def block_train(params, spec: BlockSpec, cfg: ModelConfig, x, extras, *, dense_moe=False):
    from repro.distributed.sharding import shard_act

    x = shard_act(x, "btd")
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    mixer = spec.mixer
    if mixer in (ATTN, ATTN_LOCAL, SHARED_ATTN):
        mp = extras["shared"] if mixer == SHARED_ATTN else params["mixer"]
        x = x + _gqa_train(mp, mixer, cfg, h)
    elif mixer == MLA:
        x = x + mla_mod.mla_train(params["mixer"], h, cfg)
    elif mixer == MAMBA2:
        y, _ = ssm_mod.mamba2_forward(params["mixer"], h, cfg)
        x = x + y
    elif mixer == CROSS:
        k, v = _cross_kv(params["mixer"], extras["vision"], cfg)
        x = x + _cross_attend(params["mixer"], cfg, h, k, v)
    return _apply_ffn(params, spec, cfg, x, dense_moe)


def block_prefill(params, spec: BlockSpec, cfg: ModelConfig, x, cache_len: int, extras, *, dense_moe=False):
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    mixer = spec.mixer
    if mixer in (ATTN, ATTN_LOCAL, SHARED_ATTN):
        mp = extras["shared"] if mixer == SHARED_ATTN else params["mixer"]
        y, cache = _gqa_prefill(mp, mixer, cfg, h, cache_len)
        x = x + y
    elif mixer == MLA:
        y, latent = mla_mod.mla_prefill(params["mixer"], h, cfg, cache_len)
        cache = {"latent": latent}
        x = x + y
    elif mixer == MAMBA2:
        y, (conv_x, conv_bc, state) = ssm_mod.mamba2_forward(params["mixer"], h, cfg)
        cache = {"conv_x": conv_x, "conv_bc": conv_bc, "ssm": state}
        x = x + y
    elif mixer == CROSS:
        k, v = _cross_kv(params["mixer"], extras["vision"], cfg)
        cache = {"k": k, "v": v}
        x = x + _cross_attend(params["mixer"], cfg, h, k, v)
    x, aux = _apply_ffn(params, spec, cfg, x, dense_moe)
    return x, aux, cache


def block_decode(params, spec: BlockSpec, cfg: ModelConfig, x, cache, length, extras, *, dense_moe=False):
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    mixer = spec.mixer
    if mixer in (ATTN, ATTN_LOCAL, SHARED_ATTN):
        mp = extras["shared"] if mixer == SHARED_ATTN else params["mixer"]
        y, cache = _gqa_decode(mp, mixer, cfg, h, cache, length)
        x = x + y
    elif mixer == MLA:
        y, latent = mla_mod.mla_decode(params["mixer"], h, cfg, cache["latent"], length)
        cache = {"latent": latent}
        x = x + y
    elif mixer == MAMBA2:
        y, (conv_x, conv_bc, state) = ssm_mod.mamba2_decode(
            params["mixer"], h, cfg, cache["conv_x"], cache["conv_bc"], cache["ssm"]
        )
        cache = {"conv_x": conv_x, "conv_bc": conv_bc, "ssm": state}
        x = x + y
    elif mixer == CROSS:
        x = x + _cross_attend(params["mixer"], cfg, h, cache["k"], cache["v"])
    x, _ = _apply_ffn(params, spec, cfg, x, dense_moe)
    return x, cache


def init_block_cache(spec: BlockSpec, cfg: ModelConfig, batch: int, cache_len: int):
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    dt = cfg.param_dtype
    mixer = spec.mixer
    if mixer == ATTN_LOCAL:
        w = cfg.window
        return {"k": jnp.zeros((batch, w, KV, hd), dt), "v": jnp.zeros((batch, w, KV, hd), dt)}
    if mixer in (ATTN, SHARED_ATTN):
        return {
            "k": jnp.zeros((batch, cache_len, KV, hd), dt),
            "v": jnp.zeros((batch, cache_len, KV, hd), dt),
        }
    if mixer == MLA:
        return {"latent": jnp.zeros((batch, cache_len, cfg.kv_lora_rank + cfg.rope_head_dim), dt)}
    if mixer == MAMBA2:
        conv_x, conv_bc, state = ssm_mod.init_mamba2_state(cfg, batch)
        return {"conv_x": conv_x, "conv_bc": conv_bc, "ssm": state}
    if mixer == CROSS:
        return {
            "k": jnp.zeros((batch, cfg.n_patches, KV, hd), dt),
            "v": jnp.zeros((batch, cfg.n_patches, KV, hd), dt),
        }
    raise ValueError(mixer)
