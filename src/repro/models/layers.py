"""Shared neural layers: RMSNorm, RoPE, FFN (SwiGLU/GELU), embeddings.

Pure functional: ``init_*`` returns a param pytree; ``apply`` functions take
(params, inputs). Norms and softmaxes compute in fp32 regardless of the
bf16 parameter/compute policy.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig


def truncated_normal_init(key, shape, dtype, scale: float):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def match_vma(x, ref):
    """Make ``x``'s varying-manual-axes match ``ref``'s (shard_map VMA).

    Scan carries initialized from constants are device-invariant; when model
    code runs inside a partially-manual ``shard_map`` (e.g. the compressed
    gradient step) the carry must be marked varying over the manual axes its
    inputs vary over. No-op outside shard_map.
    """
    typeof = getattr(jax, "typeof", None)
    if typeof is None:  # older jax: no VMA tracking, carries need no marking
        return x
    extra = typeof(ref).vma - typeof(x).vma
    return jax.lax.pvary(x, tuple(extra)) if extra else x


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate pairs (non-interleaved / llama layout).

    x: (..., S, H, D); positions: broadcastable to (..., S).
    """
    d = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d, theta))  # (d/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs[None, :]  # (..., S, d/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN (SwiGLU / GELU-MLP)
# ---------------------------------------------------------------------------


def init_ffn(key, d_model: int, d_ff: int, dtype, activation: str = "silu") -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = 1.0 / np.sqrt(d_model)
    scale_out = 1.0 / np.sqrt(d_ff)
    p = {
        "w_up": truncated_normal_init(k2, (d_model, d_ff), dtype, scale_in),
        "w_down": truncated_normal_init(k3, (d_ff, d_model), dtype, scale_out),
    }
    if activation == "silu":  # SwiGLU needs the gate branch
        p["w_gate"] = truncated_normal_init(k1, (d_model, d_ff), dtype, scale_in)
    return p


def ffn(params: dict, x: jax.Array, activation: str = "silu") -> jax.Array:
    from repro.distributed.sharding import shard_act

    up = shard_act(x @ params["w_up"], "btf")
    if activation == "silu":
        h = jax.nn.silu(shard_act(x @ params["w_gate"], "btf")) * up
    elif activation == "gelu":
        h = jax.nn.gelu(up)
    else:
        raise ValueError(activation)
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def init_embedding(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"tok": truncated_normal_init(k1, (cfg.vocab_size, cfg.d_model), cfg.param_dtype, 0.02)}
    if not cfg.tie_embeddings:
        p["lm_head"] = truncated_normal_init(
            k2, (cfg.d_model, cfg.vocab_size), cfg.param_dtype, 1.0 / np.sqrt(cfg.d_model)
        )
    return p


def embed_tokens(params: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return jnp.take(params["tok"], tokens, axis=0)


def lm_logits(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    from repro.distributed.sharding import shard_act

    w = params["tok"].T if cfg.tie_embeddings else params["lm_head"]
    logits = shard_act((x @ w).astype(jnp.float32), "btv")
    if cfg.logit_softcap:
        cap = cfg.logit_softcap
        logits = cap * jnp.tanh(logits / cap)
    return logits
