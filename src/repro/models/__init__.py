"""LM substrate: composable decoder blocks for the 10 assigned architectures."""

from .config import (
    ATTN,
    ATTN_LOCAL,
    CROSS,
    DENSE,
    MAMBA2,
    MLA,
    MOE,
    NONE,
    SHARED_ATTN,
    BlockSpec,
    ModelConfig,
    Segment,
    compile_pattern,
)
from .transformer import (
    decode_step,
    forward_hidden,
    init_cache,
    init_params,
    param_count,
    prefill,
    segments,
    train_logits,
)

__all__ = [
    "ATTN", "ATTN_LOCAL", "CROSS", "DENSE", "MAMBA2", "MLA", "MOE", "NONE", "SHARED_ATTN",
    "BlockSpec", "ModelConfig", "Segment", "compile_pattern",
    "decode_step", "forward_hidden", "init_cache", "init_params", "param_count",
    "prefill", "segments", "train_logits",
]
