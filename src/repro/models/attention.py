"""Attention: GQA with RoPE, full-causal and sliding-window variants.

Training/prefill use a block-triangular online-softmax ("flash-style")
evaluation: a single ``lax.scan`` over the *static* list of lower-triangle
(q-block, kv-block) pairs, so compiled FLOPs are the true causal
``~S²/2·d`` (window variants only touch in-window block pairs) and no
``S×S`` intermediate is ever materialized — the pure-XLA restatement of
the flash-attention insight, sized so each (block, block) tile fits VMEM
on the TPU target.

Decode attends one query against the KV cache with plain einsums; when the
cache's sequence axis is mesh-sharded (the 500k long-context layout), the
fp32 max/sum softmax reductions become the distributed log-sum-exp combine
automatically under SPMD.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _block_pairs(nbq: int, window_blocks: Optional[int]) -> Tuple[np.ndarray, ...]:
    """Static lower-triangle (i, j) block pair list (window-restricted)."""
    I, J, NEW, LAST = [], [], [], []
    for i in range(nbq):
        j_lo = 0 if window_blocks is None else max(0, i - window_blocks)
        for j in range(j_lo, i + 1):
            I.append(i)
            J.append(j)
            NEW.append(j == j_lo)
            LAST.append(j == i)
    return (
        np.asarray(I, np.int32),
        np.asarray(J, np.int32),
        np.asarray(NEW, np.bool_),
        np.asarray(LAST, np.bool_),
    )


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: Optional[int] = None,
    chunk: int = 512,
) -> jax.Array:
    """Causal (optionally sliding-window) attention.

    q: (B, S, H, D); k, v: (B, S, KV, D) with H % KV == 0 (GQA — KV heads are
    never repeated in memory; the einsum groups query heads per KV head).
    Returns (B, S, H, D).
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    Dv = v.shape[3]
    G = H // KV
    scale = 1.0 / math.sqrt(D)

    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        q = jnp.concatenate([q, jnp.zeros((B, pad, H, D), q.dtype)], axis=1)
        k = jnp.concatenate([k, jnp.zeros((B, pad, KV, D), k.dtype)], axis=1)
        v = jnp.concatenate([v, jnp.zeros((B, pad, KV, Dv), v.dtype)], axis=1)
    Sp = S + pad
    nb = Sp // c

    wb = None if window is None else (window + c - 1) // c
    I, J, NEW, LAST = _block_pairs(nb, wb)
    I, J = jnp.asarray(I), jnp.asarray(J)
    NEW, LAST = jnp.asarray(NEW), jnp.asarray(LAST)

    qg = q.reshape(B, Sp, KV, G, D)
    out = jnp.zeros((B, Sp, H, Dv), jnp.float32)

    def body(carry, t):
        m, l, acc, out = carry
        i, j = I[t], J[t]
        qi = jax.lax.dynamic_slice_in_dim(qg, i * c, c, axis=1)  # (B,c,KV,G,D)
        kj = jax.lax.dynamic_slice_in_dim(k, j * c, c, axis=1)  # (B,c,KV,D)
        vj = jax.lax.dynamic_slice_in_dim(v, j * c, c, axis=1)

        s = jnp.einsum("bqkgd,bpkd->bkgqp", qi, kj).astype(jnp.float32) * scale
        qpos = i * c + jnp.arange(c)
        kpos = j * c + jnp.arange(c)
        mask = kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        mask &= (kpos < S)[None, :]  # padding
        s = jnp.where(mask[None, None, None], s, NEG_INF)

        # online softmax update; reset stats at each q-block's first kv block
        m = jnp.where(NEW[t], jnp.full_like(m, NEG_INF), m)
        l = jnp.where(NEW[t], jnp.zeros_like(l), l)
        acc = jnp.where(NEW[t], jnp.zeros_like(acc), acc)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))  # (B,KV,G,c)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqp,bpkd->bkgqd", p.astype(v.dtype), vj).astype(jnp.float32)
        acc = acc * alpha[..., None] + pv

        def flush(out):
            blk = (acc / jnp.maximum(l, 1e-37)[..., None]).astype(jnp.float32)
            blk = jnp.transpose(blk, (0, 3, 1, 2, 4)).reshape(B, c, H, Dv)
            return jax.lax.dynamic_update_slice_in_dim(out, blk, i * c, axis=1)

        out = jnp.where(LAST[t], flush(out), out)
        return (m_new, l, acc, out), None

    from .layers import match_vma

    m0 = match_vma(jnp.full((B, KV, G, c), NEG_INF, jnp.float32), q)
    l0 = match_vma(jnp.zeros((B, KV, G, c), jnp.float32), q)
    acc0 = match_vma(jnp.zeros((B, KV, G, c, Dv), jnp.float32), q)
    out = match_vma(out, q)
    (_, _, _, out), _ = jax.lax.scan(body, (m0, l0, acc0, out), jnp.arange(I.shape[0]))
    return out[:, :S].astype(q.dtype)


# ---------------------------------------------------------------------------
# custom-VJP flash attention (§Perf iteration A1)
#
# Differentiating through the online-softmax scan (flash_attention above)
# makes JAX stack per-(q,kv)-pair residuals — p blocks etc. — which XLA
# carries as full-size buffers with convert round-trips every iteration
# (measured: ~60% of llama train_4k HBM traffic). The flash backward saves
# only (q, k, v, out, lse) and recomputes p per block pair.
# ---------------------------------------------------------------------------


def _pad_qkv(q, k, v, c):
    B, S, H, D = q.shape
    KV, Dv = k.shape[2], v.shape[3]
    pad = (-S) % c
    if pad:
        q = jnp.concatenate([q, jnp.zeros((B, pad, H, D), q.dtype)], axis=1)
        k = jnp.concatenate([k, jnp.zeros((B, pad, KV, D), k.dtype)], axis=1)
        v = jnp.concatenate([v, jnp.zeros((B, pad, KV, Dv), v.dtype)], axis=1)
    return q, k, v, pad


def _pair_mask(i, j, c, S, window):
    qpos = i * c + jnp.arange(c)
    kpos = j * c + jnp.arange(c)
    mask = kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    mask &= (kpos < S)[None, :]
    return mask


def _flash_fwd_impl(q, k, v, window, chunk):
    """Forward with log-sum-exp emitted: out (B,S,H,Dv), lse (B,KV,G,S) fp32."""
    from .layers import match_vma

    B, S0, H, D = q.shape
    c = min(chunk, S0)
    q, k, v, pad = _pad_qkv(q, k, v, c)
    Sp = S0 + pad
    KV, Dv = k.shape[2], v.shape[3]
    G = H // KV
    nb = Sp // c
    scale = 1.0 / math.sqrt(D)
    wb = None if window is None else (window + c - 1) // c
    I, J, NEW, LAST = map(jnp.asarray, _block_pairs(nb, wb))

    qg = q.reshape(B, Sp, KV, G, D)
    out = match_vma(jnp.zeros((B, Sp, H, Dv), q.dtype), q)
    lse = match_vma(jnp.zeros((B, KV, G, Sp), jnp.float32), q)

    def body(carry, t):
        m, l, acc, out, lse = carry
        i, j = I[t], J[t]
        qi = jax.lax.dynamic_slice_in_dim(qg, i * c, c, axis=1)
        kj = jax.lax.dynamic_slice_in_dim(k, j * c, c, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * c, c, axis=1)
        s = jnp.einsum("bqkgd,bpkd->bkgqp", qi, kj).astype(jnp.float32) * scale
        s = jnp.where(_pair_mask(i, j, c, S0, window)[None, None, None], s, NEG_INF)

        m = jnp.where(NEW[t], jnp.full_like(m, NEG_INF), m)
        l = jnp.where(NEW[t], jnp.zeros_like(l), l)
        acc = jnp.where(NEW[t], jnp.zeros_like(acc), acc)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqp,bpkd->bkgqd", p.astype(v.dtype), vj).astype(jnp.float32)
        acc = acc * alpha[..., None] + pv

        blk = acc / jnp.maximum(l, 1e-37)[..., None]
        blk = jnp.transpose(blk, (0, 3, 1, 2, 4)).reshape(B, c, H, Dv).astype(out.dtype)
        out_new = jax.lax.dynamic_update_slice_in_dim(out, blk, i * c, axis=1)
        lse_new = jax.lax.dynamic_update_slice_in_dim(
            lse, m_new + jnp.log(jnp.maximum(l, 1e-37)), i * c, axis=3
        )
        out = jnp.where(LAST[t], out_new, out)
        lse = jnp.where(LAST[t], lse_new, lse)
        return (m_new, l, acc, out, lse), None

    m0 = match_vma(jnp.full((B, KV, G, c), NEG_INF, jnp.float32), q)
    l0 = match_vma(jnp.zeros((B, KV, G, c), jnp.float32), q)
    acc0 = match_vma(jnp.zeros((B, KV, G, c, Dv), jnp.float32), q)
    (_, _, _, out, lse), _ = jax.lax.scan(body, (m0, l0, acc0, out, lse), jnp.arange(I.shape[0]))
    return out[:, :S0], lse[..., :S0]


def _flash_bwd_impl(q, k, v, out, lse, dout, window, chunk):
    from .layers import match_vma

    B, S0, H, D = q.shape
    c = min(chunk, S0)
    q, k, v, pad = _pad_qkv(q, k, v, c)
    Sp = S0 + pad
    KV, Dv = k.shape[2], v.shape[3]
    G = H // KV
    nb = Sp // c
    scale = 1.0 / math.sqrt(D)
    wb = None if window is None else (window + c - 1) // c
    I, J, _, _ = _block_pairs(nb, wb)
    I, J = jnp.asarray(I), jnp.asarray(J)

    if pad:
        out = jnp.concatenate([out, jnp.zeros((B, pad, H, Dv), out.dtype)], axis=1)
        dout = jnp.concatenate([dout, jnp.zeros((B, pad, H, Dv), dout.dtype)], axis=1)
        lse = jnp.concatenate([lse, jnp.zeros((B, KV, G, pad), lse.dtype)], axis=3)

    qg = q.reshape(B, Sp, KV, G, D)
    og = out.reshape(B, Sp, KV, G, Dv)
    dog = dout.reshape(B, Sp, KV, G, Dv)
    Dvec = jnp.einsum("bskgd,bskgd->bkgs", dog.astype(jnp.float32), og.astype(jnp.float32))

    dq = match_vma(jnp.zeros((B, Sp, KV, G, D), jnp.float32), q)
    dk = match_vma(jnp.zeros((B, Sp, KV, D), jnp.float32), q)
    dv = match_vma(jnp.zeros((B, Sp, KV, Dv), jnp.float32), q)

    def body(carry, t):
        dq, dk, dv = carry
        i, j = I[t], J[t]
        qi = jax.lax.dynamic_slice_in_dim(qg, i * c, c, axis=1)
        kj = jax.lax.dynamic_slice_in_dim(k, j * c, c, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * c, c, axis=1)
        doi = jax.lax.dynamic_slice_in_dim(dog, i * c, c, axis=1)
        lse_i = jax.lax.dynamic_slice_in_dim(lse, i * c, c, axis=3)
        D_i = jax.lax.dynamic_slice_in_dim(Dvec, i * c, c, axis=3)

        s = jnp.einsum("bqkgd,bpkd->bkgqp", qi, kj).astype(jnp.float32) * scale
        s = jnp.where(_pair_mask(i, j, c, S0, window)[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse_i[..., None])

        dp = jnp.einsum("bqkgd,bpkd->bkgqp", doi, vj).astype(jnp.float32)
        ds = p * (dp - D_i[..., None]) * scale

        dv_j = jnp.einsum("bkgqp,bqkgd->bpkd", p.astype(doi.dtype), doi).astype(jnp.float32)
        dq_i = jnp.einsum("bkgqp,bpkd->bqkgd", ds.astype(kj.dtype), kj).astype(jnp.float32)
        dk_j = jnp.einsum("bkgqp,bqkgd->bpkd", ds.astype(qi.dtype), qi).astype(jnp.float32)

        def accum(buf, upd, pos):
            cur = jax.lax.dynamic_slice_in_dim(buf, pos * c, c, axis=1)
            return jax.lax.dynamic_update_slice_in_dim(buf, cur + upd, pos * c, axis=1)

        return (accum(dq, dq_i, i), accum(dk, dk_j, j), accum(dv, dv_j, j)), None

    (dq, dk, dv), _ = jax.lax.scan(body, (dq, dk, dv), jnp.arange(I.shape[0]))
    dq = dq.reshape(B, Sp, H, D)[:, :S0].astype(q.dtype)
    return dq, dk[:, :S0].astype(k.dtype), dv[:, :S0].astype(v.dtype)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention_vjp(q, k, v, window=None, chunk=512):
    out, _ = _flash_fwd_impl(q, k, v, window, chunk)
    return out


def _flash_vjp_fwd(q, k, v, window, chunk):
    out, lse = _flash_fwd_impl(q, k, v, window, chunk)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(window, chunk, res, dout):
    q, k, v, out, lse = res
    return _flash_bwd_impl(q, k, v, out, lse, dout, window, chunk)


flash_attention_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def attention_train(q, k, v, *, window=None, chunk=512, impl="scan_ad"):
    """Training attention entry point: select the autodiff implementation."""
    if impl == "custom_vjp":
        return flash_attention_vjp(q, k, v, window, chunk)
    return flash_attention(q, k, v, window=window, chunk=chunk)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    length: jax.Array,
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """One-token attention against a (possibly sequence-sharded) cache.

    q: (B, 1, H, D); caches: (B, Smax, KV, D); ``length`` — tokens valid.
    fp32 softmax; SPMD inserts the cross-shard max/sum when Smax is sharded.
    """
    B, _, H, D = q.shape
    KV = k_cache.shape[2]
    Dv = v_cache.shape[3]
    G = H // KV
    Smax = k_cache.shape[1]
    scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,bpkd->bkgp", qg, k_cache).astype(jnp.float32) * scale
    pos = jnp.arange(Smax)
    mask = pos < length
    if window is not None:
        mask &= pos >= (length - window)
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgp,bpkd->bkgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, Dv)
