"""Mixture-of-Experts FFN (kimi-k2 384e top-8, deepseek-v2-lite 64e top-6 + 2 shared).

Routing: softmax top-k gate with renormalisation + load-balance aux loss
(Switch-style). Dispatch: capacity-bounded scatter into per-expert buffers
``(E, cap, D)`` — under the production mesh activations are replicated over
the `model` axis (TP), so sharding experts on `model` makes dispatch local
to each model rank and the only added communication is the output psum the
row-parallel FFN already pays. No (T, E, cap) one-hot is ever materialized
(384 experts × 32k tokens would be ~10⁹ entries).

Shared experts (DeepSeek) are a plain dense SwiGLU over all tokens.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import ffn, init_ffn, truncated_normal_init


def moe_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    cap = int(np.ceil(n_tokens * cfg.moe_top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, cap)


def init_moe(key, cfg: ModelConfig) -> dict:
    D, E, Fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / np.sqrt(D), 1.0 / np.sqrt(Fe)
    p = {
        "router": truncated_normal_init(ks[0], (D, E), jnp.float32, s_in),
        "w_gate": truncated_normal_init(ks[1], (E, D, Fe), cfg.param_dtype, s_in),
        "w_up": truncated_normal_init(ks[2], (E, D, Fe), cfg.param_dtype, s_in),
        "w_down": truncated_normal_init(ks[3], (E, Fe, D), cfg.param_dtype, s_out),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_ffn(
            ks[4], D, cfg.n_shared_experts * Fe, cfg.param_dtype, cfg.activation
        )
    return p


def moe_ffn(params: dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (out, aux_loss)."""
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.moe_top_k
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32)) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Load-balance aux loss (Switch): E · Σ_e f_e · p̄_e
    me = jnp.mean(probs, axis=0)  # (E,)
    onehot_top1 = jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32)
    fe = jnp.mean(onehot_top1, axis=0)
    aux = E * jnp.sum(fe * me)

    from repro.distributed.sharding import shard_act

    # Dispatch in P independent token groups (P = DP size under the production
    # mesh). The batched scatter/gather then has a leading dim aligned with the
    # `data` sharding of the tokens, so dispatch AND expert compute stay local
    # per data rank; with P=1 the partitioner replicates expert compute across
    # data (measured 16x overcompute on kimi — §Perf B5).
    P = max(1, cfg.moe_dispatch_shards)
    if T % P:
        P = 1
    Tl = T // P
    cap = moe_capacity(Tl, cfg)

    flat_e = expert_idx.reshape(P, Tl * k)  # group-local expert ids
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (P, Tl·k, E)
    pos = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=1) - 1, flat_e[..., None], axis=2
    )[..., 0]  # (P, Tl·k) rank among same-expert assignments within the group
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)  # overflow rows land in a spill slot

    # Batched scatter into (P, E, cap+1, D); spill slot dropped after compute.
    src = jnp.repeat(xt.reshape(P, Tl, D), k, axis=1)  # (P, Tl·k, D)
    buf = jnp.zeros((P, E, cap + 1, D), x.dtype)
    buf = jax.vmap(lambda b, e, s, u: b.at[e, s].add(u, mode="drop"))(buf, flat_e, slot, src)
    buf = shard_act(buf, "pecd")

    # Expert compute (E over `model`, groups over `data`)
    if cfg.activation == "silu":
        h = jax.nn.silu(jnp.einsum("pecd,edf->pecf", buf, params["w_gate"]))
        h = h * jnp.einsum("pecd,edf->pecf", buf, params["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("pecd,edf->pecf", buf, params["w_up"]))
    out_buf = shard_act(jnp.einsum("pecf,efd->pecd", h, params["w_down"]), "pecd")

    # Gather back and combine with gates (dropped tokens contribute 0).
    gathered = jax.vmap(lambda b, e, s: b[e, s])(out_buf, flat_e, slot)  # (P, Tl·k, D)
    gathered = jnp.where((keep & (slot < cap))[..., None], gathered, 0.0)
    combined = jnp.sum(
        gathered.reshape(T, k, D) * gate_vals[..., None].astype(gathered.dtype), axis=1
    )

    out = combined.reshape(B, S, D)
    if cfg.n_shared_experts:
        out = out + ffn(params["shared"], x, cfg.activation)
    return out, aux


def moe_ffn_dense(params: dict, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Dropless reference path (smoke tests / tiny configs): loops experts."""
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.moe_top_k
    xt = x.reshape(T, D)
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    me = jnp.mean(probs, axis=0)
    fe = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(fe * me)

    # weight per (token, expert)
    w_te = jnp.zeros((T, E), jnp.float32)
    w_te = w_te.at[jnp.arange(T)[:, None], expert_idx].add(gate_vals)

    def one_expert(e, acc):
        if cfg.activation == "silu":
            h = jax.nn.silu(xt @ params["w_gate"][e]) * (xt @ params["w_up"][e])
        else:
            h = jax.nn.gelu(xt @ params["w_up"][e])
        return acc + (h @ params["w_down"][e]) * w_te[:, e][:, None].astype(x.dtype)

    # python loop — this path is for tiny smoke configs (E ≤ 8) only
    acc = jnp.zeros_like(xt)
    for e in range(E):
        acc = one_expert(e, acc)
    out = acc.reshape(B, S, D)
    if cfg.n_shared_experts:
        out = out + ffn(params["shared"], x, cfg.activation)
    return out, aux
