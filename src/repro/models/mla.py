"""Multi-head Latent Attention (DeepSeek-V2) — used by deepseek-v2-lite.

K/V are compressed through a shared latent ``c_kv ∈ R^{kv_lora_rank}`` plus a
decoupled RoPE key of ``rope_head_dim``; the decode cache stores only
``(kv_lora_rank + rope_head_dim)`` floats per token — MLA's entire point.

Train/prefill decompress the latent into per-head K/V and reuse the
flash-attention core. Decode attends in latent space is possible; we keep
the decompress-then-attend form (clearer, same cache footprint) and note
the absorbed-matmul variant as a §Perf lever.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .attention import attention_train, decode_attention, flash_attention
from .config import ModelConfig
from .layers import apply_rope, truncated_normal_init


def init_mla(key, cfg: ModelConfig) -> dict:
    D, H = cfg.d_model, cfg.n_heads
    nope, rope_d, v_d = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    ks = jax.random.split(key, 5)
    s = 1.0 / np.sqrt(D)
    sr = 1.0 / np.sqrt(r)
    return {
        "w_q": truncated_normal_init(ks[0], (D, H * (nope + rope_d)), cfg.param_dtype, s),
        "w_dkv": truncated_normal_init(ks[1], (D, r + rope_d), cfg.param_dtype, s),
        "w_uk": truncated_normal_init(ks[2], (r, H * nope), cfg.param_dtype, sr),
        "w_uv": truncated_normal_init(ks[3], (r, H * v_d), cfg.param_dtype, sr),
        "w_o": truncated_normal_init(ks[4], (H * v_d, D), cfg.param_dtype, 1.0 / np.sqrt(H * v_d)),
    }


def _project(params, x, positions, cfg: ModelConfig):
    """Returns q (B,S,H,nope+rope), latent c_kv (B,S,r), k_rope (B,S,1,rope)."""
    B, S, _ = x.shape
    H, nope, rope_d = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim
    q = (x @ params["w_q"]).reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    dkv = x @ params["w_dkv"]  # (B,S,r+rope)
    c_kv, k_rope = dkv[..., : cfg.kv_lora_rank], dkv[..., cfg.kv_lora_rank :]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,rope)
    return q, c_kv, k_rope


def _decompress(params, c_kv, k_rope, cfg: ModelConfig):
    """Latent → per-head K (nope+rope) and V."""
    B, S, _ = c_kv.shape
    H, nope, v_d = cfg.n_heads, cfg.nope_head_dim, cfg.v_head_dim
    k_nope = (c_kv @ params["w_uk"]).reshape(B, S, H, nope)
    v = (c_kv @ params["w_uv"]).reshape(B, S, H, v_d)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, cfg.rope_head_dim))], axis=-1)
    return k, v


def mla_train(params, x, cfg: ModelConfig) -> jax.Array:
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    q, c_kv, k_rope = _project(params, x, positions, cfg)
    k, v = _decompress(params, c_kv, k_rope, cfg)
    o = attention_train(q, k, v, chunk=cfg.attn_chunk, impl=cfg.attn_impl)
    return o.reshape(B, S, -1) @ params["w_o"]


def mla_prefill(params, x, cfg: ModelConfig, cache_len: int):
    """Returns output and the latent cache (B, cache_len, r + rope)."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    q, c_kv, k_rope = _project(params, x, positions, cfg)
    k, v = _decompress(params, c_kv, k_rope, cfg)
    o = flash_attention(q, k, v, chunk=cfg.attn_chunk)

    latent = jnp.concatenate([c_kv, k_rope[:, :, 0, :]], axis=-1)
    pad = cache_len - S
    cache = jnp.pad(latent, ((0, 0), (0, pad), (0, 0)))
    return o.reshape(B, S, -1) @ params["w_o"], cache


def mla_decode(params, x, cfg: ModelConfig, cache: jax.Array, length: jax.Array):
    """x: (B,1,D); cache: (B,Smax,r+rope) latent cache; returns (out, cache)."""
    B = x.shape[0]
    positions = jnp.broadcast_to(length[None, None], (B, 1))
    q, c_kv, k_rope = _project(params, x, positions, cfg)
    new_entry = jnp.concatenate([c_kv, k_rope[:, :, 0, :]], axis=-1)  # (B,1,r+rope)
    cache = jax.lax.dynamic_update_slice_in_dim(cache, new_entry.astype(cache.dtype), length, axis=1)

    c_all, kr_all = cache[..., : cfg.kv_lora_rank], cache[..., cfg.kv_lora_rank :]
    k, v = _decompress(params, c_all, kr_all[:, :, None, :], cfg)
    o = decode_attention(q, k, v, length + 1)
    return o.reshape(B, 1, -1) @ params["w_o"], cache
