"""Mamba-2 SSD (state-space duality) mixer — mamba2-1.3b / zamba2 hybrid.

Chunked SSD algorithm (Dao & Gu 2024, arXiv:2405.21060):

  per chunk of Q tokens: intra-chunk quadratic term (C Bᵀ ⊙ decay-L) · X,
  inter-chunk linear recurrence over per-chunk states S_k ∈ R^{N×P} per head.

Decode carries (conv window, SSM state) — O(1) per token, which is why the
``long_500k`` cell runs for this family.

Projections are stored as separate matrices (w_z / w_x / w_bc / w_dt) and
the depthwise conv is split into an x-part and a B/C-part so that tensor
parallelism shards the d_inner/head dims cleanly: B/C are group-shared and
replicated (tiny), all wide tensors shard on heads, and the only mixer
collective is the row-parallel psum of w_out.

Shapes: x (B,S,D); inner width d_inner = expand·D split into H heads of P;
B/C projections have G groups of state size N.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import rmsnorm, truncated_normal_init


def _dims(cfg: ModelConfig):
    d_in = cfg.d_inner
    H = cfg.ssm_heads or d_in // cfg.ssm_head_dim
    P = d_in // H
    G, N = cfg.ssm_groups, cfg.ssm_state
    return d_in, H, P, G, N


def init_mamba2(key, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    d_in, H, P, G, N = _dims(cfg)
    ks = jax.random.split(key, 8)
    s = 1.0 / np.sqrt(D)
    dt = jnp.exp(
        jax.random.uniform(ks[0], (H,)) * (np.log(0.1) - np.log(0.001)) + np.log(0.001)
    )
    return {
        "w_z": truncated_normal_init(ks[1], (D, d_in), cfg.param_dtype, s),
        "w_x": truncated_normal_init(ks[2], (D, d_in), cfg.param_dtype, s),
        "w_bc": truncated_normal_init(ks[3], (D, 2 * G * N), cfg.param_dtype, s),
        "w_dt": truncated_normal_init(ks[4], (D, H), cfg.param_dtype, s),
        "conv_x_w": truncated_normal_init(ks[5], (cfg.ssm_conv, d_in), cfg.param_dtype, 0.3),
        "conv_x_b": jnp.zeros((d_in,), cfg.param_dtype),
        "conv_bc_w": truncated_normal_init(ks[6], (cfg.ssm_conv, 2 * G * N), cfg.param_dtype, 0.3),
        "conv_bc_b": jnp.zeros((2 * G * N,), cfg.param_dtype),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),  # softplus⁻¹(dt)
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), cfg.param_dtype),
        "w_out": truncated_normal_init(ks[7], (d_in, D), cfg.param_dtype, 1.0 / np.sqrt(d_in)),
    }


def _causal_conv(u, w, b, state=None):
    """Depthwise causal conv, kernel K. state: (B, K-1, C) carried for decode."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], K - 1, u.shape[-1]), u.dtype)
    else:
        pad = state
    up = jnp.concatenate([pad, u], axis=1)  # (B, S+K-1, C)
    out = sum(up[:, i : i + u.shape[1]] * w[i][None, None] for i in range(K))
    new_state = up[:, -(K - 1) :] if K > 1 else None
    return jax.nn.silu(out + b[None, None]), new_state


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan.

    xh: (B,S,H,P) inputs; dt: (B,S,H) fp32 step sizes; A: (H,) fp32 (<0);
    Bm/Cm: (B,S,G,N). Returns y (B,S,H,P) and final state (B,H,N,P).
    """
    Bsz, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // Q
    rep = H // G

    # reshape to (B, nc, Q, ...)
    xh = xh.reshape(Bsz, nc, Q, H, P)
    dt = dt.reshape(Bsz, nc, Q, H)
    Bm = Bm.reshape(Bsz, nc, Q, G, N)
    Cm = Cm.reshape(Bsz, nc, Q, G, N)

    a = dt * A[None, None, None, :]  # (B,nc,Q,H) log-decay increments (<0)
    cum = jnp.cumsum(a, axis=2)  # within-chunk cumulative
    total = cum[:, :, -1]  # (B,nc,H)

    # -- intra-chunk (quadratic) --
    # L[i,j] = exp(cum_i − cum_j) for i ≥ j
    Li = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    Lexp = jnp.where(mask[None, None, :, :, None], jnp.exp(Li), 0.0)
    CB = jnp.einsum("bcqgn,bcpgn->bcqpg", Cm, Bm)  # (B,nc,Q,Q,G)
    CB = jnp.repeat(CB, rep, axis=-1)  # (B,nc,Q,Q,H)
    xdt = xh * dt[..., None]  # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bcqph,bcphd->bcqhd", CB * Lexp, xdt.astype(jnp.float32))

    # -- per-chunk states: S_c = Σ_j exp(total − cum_j) B_j ⊗ (x_j dt_j) --
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)  # (B,nc,Q,H)
    Brep = jnp.repeat(Bm, rep, axis=3)  # (B,nc,Q,H,N)
    S_local = jnp.einsum("bcqhn,bcqhd->bchnd", Brep, (xdt * decay_to_end[..., None]).astype(jnp.float32))

    # -- inter-chunk recurrence over chunk index c: S = exp(total_c)·S_prev + S_local --
    decay_chunk = jnp.exp(total)  # (B,nc,H)

    def scan_fn(S_prev, inp):
        d_c, S_loc = inp  # (B,H), (B,H,N,P)
        S_new = S_prev * d_c[..., None, None] + S_loc
        return S_new, S_prev

    from .layers import match_vma

    S0 = match_vma(jnp.zeros((Bsz, H, N, P), jnp.float32), xh)
    S_final, S_prevs = jax.lax.scan(
        scan_fn,
        S0,
        (jnp.moveaxis(decay_chunk, 1, 0), jnp.moveaxis(S_local, 1, 0)),
    )
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)  # (B,nc,H,N,P) state entering each chunk

    # -- inter-chunk output: y_j += C_j · (exp(cum_j) ⊙ S_prev) --
    Crep = jnp.repeat(Cm, rep, axis=3)  # (B,nc,Q,H,N)
    y_inter = jnp.einsum("bcqhn,bchnd->bcqhd", Crep * jnp.exp(cum)[..., None], S_prevs)

    y = (y_intra + y_inter).reshape(Bsz, Sp, H, P)[:, :S]
    return y, S_final


def _project(params, x, cfg: ModelConfig):
    z = x @ params["w_z"]
    xr = x @ params["w_x"]
    bc = x @ params["w_bc"]
    dt_raw = x @ params["w_dt"]
    return z, xr, bc, dt_raw


def mamba2_forward(params, x, cfg: ModelConfig, conv_x=None, conv_bc=None, ssm_state=None):
    """Full-sequence forward (train/prefill). Returns (y, (conv_x, conv_bc, state))."""
    d_in, H, P, G, N = _dims(cfg)
    Bsz, S, _ = x.shape
    z, xr, bc, dt_raw = _project(params, x, cfg)
    xr, conv_x = _causal_conv(xr, params["conv_x_w"], params["conv_x_b"], conv_x)
    bc, conv_bc = _causal_conv(bc, params["conv_bc_w"], params["conv_bc_b"], conv_bc)

    xh = xr.reshape(Bsz, S, H, P)
    Bm = bc[..., : G * N].reshape(Bsz, S, G, N)
    Cm = bc[..., G * N :].reshape(Bsz, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None])
    A = -jnp.exp(params["a_log"])

    y, ssm_state = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, d_in).astype(x.dtype)
    y = rmsnorm({"scale": params["norm_scale"]}, y * jax.nn.silu(z), cfg.norm_eps)
    return y @ params["w_out"], (conv_x, conv_bc, ssm_state)


def mamba2_decode(params, x, cfg: ModelConfig, conv_x, conv_bc, ssm_state):
    """Single-token step. x: (B,1,D); states as returned by forward/init."""
    d_in, H, P, G, N = _dims(cfg)
    Bsz = x.shape[0]
    z, xr, bc, dt_raw = _project(params, x, cfg)

    def conv_step(u, w, b, state):
        win = jnp.concatenate([state, u], axis=1)  # (B,K,C)
        out = jnp.einsum("bkc,kc->bc", win, w) + b[None]
        return jax.nn.silu(out)[:, None], win[:, 1:]

    xr, conv_x = conv_step(xr, params["conv_x_w"], params["conv_x_b"], conv_x)
    bc, conv_bc = conv_step(bc, params["conv_bc_w"], params["conv_bc_b"], conv_bc)

    xh = xr.reshape(Bsz, H, P)
    Bm = bc[..., : G * N].reshape(Bsz, G, N)
    Cm = bc[..., G * N :].reshape(Bsz, G, N)
    rep = H // G
    Brep = jnp.repeat(Bm, rep, axis=1)  # (B,H,N)
    Crep = jnp.repeat(Cm, rep, axis=1)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"][None])  # (B,H)
    A = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt * A[None])  # (B,H)

    ssm_state = ssm_state * decay[..., None, None] + jnp.einsum(
        "bhn,bhd->bhnd", Brep, (xh * dt[..., None]).astype(jnp.float32)
    )
    y = jnp.einsum("bhn,bhnd->bhd", Crep, ssm_state)
    y = y + params["d_skip"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, 1, d_in).astype(x.dtype)
    y = rmsnorm({"scale": params["norm_scale"]}, y * jax.nn.silu(z), cfg.norm_eps)
    return y @ params["w_out"], (conv_x, conv_bc, ssm_state)


def init_mamba2_state(cfg: ModelConfig, batch: int):
    d_in, H, P, G, N = _dims(cfg)
    return (
        jnp.zeros((batch, cfg.ssm_conv - 1, d_in), cfg.param_dtype),
        jnp.zeros((batch, cfg.ssm_conv - 1, 2 * G * N), cfg.param_dtype),
        jnp.zeros((batch, H, N, P), jnp.float32),
    )
