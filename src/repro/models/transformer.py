"""Pattern-grouped decoder: scan-over-layer-segments transformer.

The layer pattern is factored into ``Segment``s (config.compile_pattern);
each repeated segment is executed with ``jax.lax.scan`` over parameters
stacked along the repeat axis, keeping compiled HLO size O(#distinct block
kinds) — 61-layer / 1T-param stacks lower in ~1 s.

Entry points:
  init_params    — full parameter pytree (vmapped init for stacked segments)
  train_logits   — (B,S) tokens → (B,S,V) logits + MoE aux loss
  prefill        — prompt → last-position logits + KV/state cache
  decode_step    — one token + cache → logits + updated cache
  init_cache     — zeroed cache for a given batch/cache_len
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import blocks as blk
from .config import SHARED_ATTN, ModelConfig, Segment, compile_pattern
from .layers import embed_tokens, init_embedding, init_rmsnorm, lm_logits, rmsnorm, truncated_normal_init


def _has_shared(cfg: ModelConfig) -> bool:
    return any(s.mixer == SHARED_ATTN for s in cfg.pattern)


def _has_vision(cfg: ModelConfig) -> bool:
    return cfg.d_vision > 0


def segments(cfg: ModelConfig) -> Tuple[Segment, ...]:
    return compile_pattern(cfg.pattern)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig) -> dict:
    segs = segments(cfg)
    keys = jax.random.split(key, len(segs) + 3)
    params: dict = {"embed": init_embedding(keys[0], cfg)}

    seg_params = []
    for si, seg in enumerate(segs):
        k_seg = keys[1 + si]
        pos_params = []
        for pos, spec in enumerate(seg.unit):
            k_pos = jax.random.fold_in(k_seg, pos)
            if seg.n_repeat == 1:
                pos_params.append(blk.init_block(k_pos, spec, cfg))
            else:
                reps = jax.random.split(k_pos, seg.n_repeat)
                pos_params.append(jax.vmap(lambda k, sp=spec: blk.init_block(k, sp, cfg))(reps))
        seg_params.append(tuple(pos_params))
    params["segments"] = tuple(seg_params)

    if _has_shared(cfg):
        params["shared"] = blk._init_gqa(keys[-3], cfg)
    if _has_vision(cfg):
        params["vision_proj"] = truncated_normal_init(
            keys[-2], (cfg.d_vision, cfg.d_model), cfg.param_dtype, 1.0 / np.sqrt(cfg.d_vision)
        )
    params["final_norm"] = init_rmsnorm(cfg.d_model, cfg.param_dtype)
    return params


def _extras(params, cfg: ModelConfig, vision: Optional[jax.Array]):
    ex = {}
    if _has_shared(cfg):
        ex["shared"] = params["shared"]
    if _has_vision(cfg):
        if vision is None:
            raise ValueError(f"{cfg.name} requires `vision` embeddings (modality stub output)")
        ex["vision"] = vision.astype(cfg.param_dtype) @ params["vision_proj"]
    return ex


# ---------------------------------------------------------------------------
# Train forward
# ---------------------------------------------------------------------------


REMAT_POLICIES = {
    "full": None,  # jax.checkpoint default: save nothing, recompute all
    "dots": "dots_with_no_batch_dims_saveable",
}


def _maybe_remat(fn, remat):
    if remat is None:
        return fn
    if remat == "full":
        return jax.checkpoint(fn)
    policy = getattr(jax.checkpoint_policies, REMAT_POLICIES[remat])
    return jax.checkpoint(fn, policy=policy)


def forward_hidden(params, cfg: ModelConfig, tokens, vision=None, *, dense_moe=False, remat=None):
    from .layers import match_vma

    x = embed_tokens(params["embed"], tokens, cfg)
    ex = _extras(params, cfg, vision)
    aux = match_vma(jnp.zeros((), jnp.float32), x)

    for seg, seg_params in zip(segments(cfg), params["segments"]):
        if seg.n_repeat == 1:

            def unit_fn(x, aux, seg_params, ex, _seg=seg):
                for pos, spec in enumerate(_seg.unit):
                    x, a = blk.block_train(seg_params[pos], spec, cfg, x, ex, dense_moe=dense_moe)
                    aux = aux + a
                return x, aux

            x, aux = _maybe_remat(unit_fn, remat)(x, aux, seg_params, ex)
        else:

            def body(carry, rep_params, _seg=seg):
                x, aux = carry
                for pos, spec in enumerate(_seg.unit):
                    x, a = blk.block_train(rep_params[pos], spec, cfg, x, ex, dense_moe=dense_moe)
                    aux = aux + a
                return (x, aux), None

            (x, aux), _ = jax.lax.scan(_maybe_remat(body, remat), (x, aux), seg_params)
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def train_logits(params, cfg: ModelConfig, tokens, vision=None, *, dense_moe=False, remat=None):
    h, aux = forward_hidden(params, cfg, tokens, vision, dense_moe=dense_moe, remat=remat)
    return lm_logits(params["embed"], h, cfg), aux


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    seg_caches = []
    for seg in segments(cfg):
        pos_caches = []
        for spec in seg.unit:
            c = blk.init_block_cache(spec, cfg, batch, cache_len)
            if seg.n_repeat > 1:
                c = jax.tree.map(lambda l: jnp.broadcast_to(l[None], (seg.n_repeat, *l.shape)), c)
            pos_caches.append(c)
        seg_caches.append(tuple(pos_caches))
    return {"segments": tuple(seg_caches), "length": jnp.zeros((), jnp.int32)}


def prefill(params, cfg: ModelConfig, tokens, cache_len: int, vision=None, *, dense_moe=False):
    from .layers import match_vma

    B, S = tokens.shape
    x = embed_tokens(params["embed"], tokens, cfg)
    ex = _extras(params, cfg, vision)
    aux = match_vma(jnp.zeros((), jnp.float32), x)

    seg_caches = []
    for seg, seg_params in zip(segments(cfg), params["segments"]):
        if seg.n_repeat == 1:
            pos_caches = []
            for pos, spec in enumerate(seg.unit):
                x, a, c = blk.block_prefill(seg_params[pos], spec, cfg, x, cache_len, ex, dense_moe=dense_moe)
                aux = aux + a
                pos_caches.append(c)
            seg_caches.append(tuple(pos_caches))
        else:

            def body(carry, rep_params, _seg=seg):
                x, aux = carry
                caches = []
                for pos, spec in enumerate(_seg.unit):
                    x, a, c = blk.block_prefill(rep_params[pos], spec, cfg, x, cache_len, ex, dense_moe=dense_moe)
                    aux = aux + a
                    caches.append(c)
                return (x, aux), tuple(caches)

            (x, aux), stacked = jax.lax.scan(body, (x, aux), seg_params)
            seg_caches.append(stacked)

    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params["embed"], h[:, -1:], cfg)
    cache = {"segments": tuple(seg_caches), "length": jnp.asarray(S, jnp.int32)}
    return logits, cache


def decode_step(params, cfg: ModelConfig, cache: dict, token: jax.Array, *, dense_moe=False):
    """token: (B, 1) int32. Returns (logits (B,1,V), updated cache)."""
    x = embed_tokens(params["embed"], token, cfg)
    ex = {}  # cross blocks read K/V from cache at decode; no vision input needed
    if _has_shared(cfg):
        ex["shared"] = params["shared"]
    length = cache["length"]

    seg_caches = []
    for seg, seg_params, seg_cache in zip(segments(cfg), params["segments"], cache["segments"]):
        if seg.n_repeat == 1:
            pos_caches = []
            for pos, spec in enumerate(seg.unit):
                x, c = blk.block_decode(seg_params[pos], spec, cfg, x, seg_cache[pos], length, ex, dense_moe=dense_moe)
                pos_caches.append(c)
            seg_caches.append(tuple(pos_caches))
        else:

            def body(x, xs, _seg=seg):
                rep_params, rep_cache = xs
                caches = []
                for pos, spec in enumerate(_seg.unit):
                    x, c = blk.block_decode(rep_params[pos], spec, cfg, x, rep_cache[pos], length, ex, dense_moe=dense_moe)
                    caches.append(c)
                return x, tuple(caches)

            x, stacked = jax.lax.scan(body, x, (seg_params, seg_cache))
            seg_caches.append(stacked)

    h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params["embed"], h, cfg)
    new_cache = {"segments": tuple(seg_caches), "length": length + 1}
    return logits, new_cache


def param_count(params) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
