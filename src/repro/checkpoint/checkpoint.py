"""Sharded numpy checkpointing with atomic manifests and elastic restore.

Layout:
  <dir>/step_<N>.tmp/...   (written)  →  os.rename  →  <dir>/step_<N>/
    manifest.json          step, leaf index {path: {shape, dtype, file}},
                           extra metadata (data state, PRNG, config name)
    <leaf files>.npy       one per pytree leaf (host-gathered)

* Atomicity: the manifest-bearing directory only appears under its final
  name after every array file is fully written (tmp-dir + rename), and
  every file inside the tmp dir is itself written to a ``.part`` temp and
  promoted with ``os.replace`` — no path through ``save`` ever leaves a
  half-written file under a name a reader would open. ``durable=False``
  keeps the rename discipline but skips the per-file fsync (process-crash
  fault model; see ``_atomic_write``).
* Torn-write tolerance: ``list_steps``/``latest_step``/``restore`` treat a
  checkpoint directory as valid only if its manifest parses *and* every
  leaf file it indexes exists non-empty — a crash during save (or a
  partially synced directory after power loss) is silently skipped and
  resume falls back to the newest intact step instead of crashing.
* keep_last_k garbage collection.
* Packed layout: ``save(..., pack=True)`` writes ``step_<N>.ckpt`` — magic +
  JSON header + concatenated raw leaf bytes in **one** atomic file write —
  for small states checkpointed at high cadence (the resilient stream
  driver), where the per-leaf directory's ~25 syscalls per save dominate.
  ``restore``/``list_steps``/GC handle both layouts transparently.
* Elastic restore: arrays are loaded host-side and ``jax.device_put`` with
  the *target* shardings — the saved mesh shape is irrelevant, so a
  checkpoint taken on 512 chips restores onto 8 (tested) or vice versa.
* Async: ``save(..., async_=True)`` snapshots to host then writes on a
  worker thread (training continues).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SANITIZE = re.compile(r"[^A-Za-z0-9_.-]+")

# numpy-native dtypes round-trip through .npy; ml_dtypes (bfloat16, fp8)
# come back as void — store those as a uint view + the true name in the manifest
_NATIVE_DTYPES = {
    "float64", "float32", "float16", "int64", "int32", "int16", "int8",
    "uint64", "uint32", "uint16", "uint8", "bool",
}


_DTYPE_NAMES: dict = {}  # str(dtype) is surprisingly hot at stream-ckpt cadence


def _to_savable(arr: np.ndarray):
    name = _DTYPE_NAMES.get(arr.dtype)
    if name is None:
        name = _DTYPE_NAMES.setdefault(arr.dtype, str(arr.dtype))
    if name in _NATIVE_DTYPES:
        return arr, name, False
    view = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
    return view, name, True


def _from_saved(arr: np.ndarray, dtype_name: str, viewed: bool):
    if viewed:
        return arr.view(np.dtype(dtype_name))
    return arr


def _leaf_name(path) -> str:
    return _SANITIZE.sub("_", jax.tree_util.keystr(path)).strip("_") or "root"


# ---- packed single-file layout -------------------------------------------
#
# <dir>/step_<N>.ckpt = MAGIC + u64le header length + header JSON + payload
# (concatenated raw leaf bytes). A small state (a PanelState is O(sketch
# size), ~hundreds of KB) pays ~25 syscalls + a pretty-printed JSON per
# save in the directory layout; the packed form is one write + one rename,
# which is what makes high-cadence stream checkpointing affordable.
# Validity = magic + header parse + exact file size; same .part/os.replace
# atomicity as every other write.

_PACK_MAGIC = b"RPCKPT1\n"
_PACK_SUFFIX = ".ckpt"


def _pack_parts(step: int, host, extra: Optional[dict]):
    """``(header_bytes, payload_chunks)`` for the packed layout — chunks are
    written straight to the (buffered) file, never joined into one blob."""
    index = {}
    chunks = []
    off = 0
    for path, arr in host:
        savable, dtype_name, viewed = _to_savable(arr)
        buf = np.ascontiguousarray(savable).tobytes()
        index[jax.tree_util.keystr(path)] = {
            "offset": off,
            "nbytes": len(buf),
            "shape": list(arr.shape),
            "dtype": dtype_name,
            "store": _DTYPE_NAMES.setdefault(savable.dtype, str(savable.dtype)),
            "viewed": viewed,
        }
        chunks.append(buf)
        off += len(buf)
    header = json.dumps(
        {"step": step, "leaves": index, "extra": extra or {}, "payload_bytes": off},
        separators=(",", ":"),
    ).encode()
    return b"".join([_PACK_MAGIC, len(header).to_bytes(8, "little"), header]), chunks


def _read_packed_manifest(path: str):
    """Parse a packed checkpoint's header; ``None`` if torn (bad magic,
    unparseable header, or file size != header + declared payload)."""
    try:
        size = os.path.getsize(path)
        with open(path, "rb") as f:
            if f.read(len(_PACK_MAGIC)) != _PACK_MAGIC:
                return None
            hlen = int.from_bytes(f.read(8), "little")
            header = json.loads(f.read(hlen))
        data_start = len(_PACK_MAGIC) + 8 + hlen
        if size != data_start + int(header["payload_bytes"]):
            return None
        header["_data_start"] = data_start
        return header
    except (OSError, ValueError, KeyError, TypeError):
        return None


def _atomic_write(dest: str, writer, *, durable: bool = True):
    """Write ``dest`` via a ``.part`` temp promoted with ``os.replace``.

    ``writer`` receives an open binary file object. A crash mid-write
    leaves only the ``.part`` file — nothing ever opens a half-written
    file under the destination name. ``durable=False`` skips the
    per-file ``fsync``: rename atomicity (and therefore torn-write
    detection) still holds against *process* crashes, but a power loss /
    kernel crash may lose page-cache contents — callers whose fault model
    is process death (e.g. the resilient stream driver) trade that for a
    write measured in syscalls instead of disk flushes."""
    part = dest + ".part"
    with open(part, "wb") as f:
        writer(f)
        f.flush()
        if durable:
            os.fsync(f.fileno())
    os.replace(part, dest)


def _flatten(tree):
    return jax.tree_util.tree_flatten_with_path(tree)


def save(
    directory: str,
    step: int,
    tree: Any,
    *,
    extra: Optional[dict] = None,
    keep_last: int = 3,
    async_: bool = False,
    durable: bool = True,
    pack: bool = False,
):
    """Write a checkpoint. Returns the final path (or a Thread if async).

    The host snapshot is taken synchronously even when ``async_=True`` —
    the caller may donate the live buffers to the very next step, so only
    the file I/O moves to the worker thread. ``durable=False`` drops the
    per-file fsync (process-crash atomicity only — see
    :func:`_atomic_write`). ``pack=True`` writes the single-file
    ``step_<N>.ckpt`` layout (one write + one rename) instead of the
    per-leaf directory — ``restore``/``list_steps`` read both."""
    leaves, _ = _flatten(tree)
    values = jax.device_get([leaf for _, leaf in leaves])  # one batched sync
    host = [(path, np.asarray(v)) for (path, _), v in zip(leaves, values)]

    if pack:
        header, chunks = _pack_parts(step, host, extra)

        def _write_packed():
            os.makedirs(directory, exist_ok=True)
            final = os.path.join(directory, f"step_{step:08d}{_PACK_SUFFIX}")

            def _writer(f):
                f.write(header)
                for buf in chunks:
                    f.write(buf)

            _atomic_write(final, _writer, durable=durable)
            _gc(directory, keep_last)
            return final

        if async_:
            t = threading.Thread(target=_write_packed, daemon=True)
            t.start()
            return t
        return _write_packed()

    def _write():
        os.makedirs(directory, exist_ok=True)
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        index = {}
        for path, arr in host:
            name = _leaf_name(path)
            fname = name + ".npy"
            savable, dtype_name, viewed = _to_savable(arr)
            _atomic_write(
                os.path.join(tmp, fname), lambda f: np.save(f, savable),
                durable=durable,
            )
            index[jax.tree_util.keystr(path)] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": dtype_name,
                "viewed": viewed,
            }
        manifest = {"step": step, "leaves": index, "extra": extra or {}}
        _atomic_write(
            os.path.join(tmp, "manifest.json"),
            lambda f: f.write(json.dumps(manifest, indent=1).encode()),
            durable=durable,
        )
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(directory, keep_last)
        return final

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    return _write()


def _gc(directory: str, keep_last: int):
    if keep_last <= 0:
        return
    # raw listing, not list_steps: torn checkpoints are garbage too, and GC
    # runs on every save — it must not pay manifest validation
    steps = set()
    for d in os.listdir(directory):
        m = re.fullmatch(rf"step_(\d+)(?:{re.escape(_PACK_SUFFIX)})?", d)
        if m:
            steps.add(int(m.group(1)))
    for s in sorted(steps)[:-keep_last]:
        base = os.path.join(directory, f"step_{s:08d}")
        shutil.rmtree(base, ignore_errors=True)
        try:
            os.unlink(base + _PACK_SUFFIX)
        except OSError:
            pass


def _read_manifest(ckpt_dir: str) -> Optional[dict]:
    """Parse and validate a checkpoint directory's manifest.

    Returns the manifest dict only if it parses *and* every leaf file it
    indexes exists non-empty; otherwise ``None`` — the directory is a torn
    write (crash during save, partial sync) and must not be restored."""
    try:
        with open(os.path.join(ckpt_dir, "manifest.json")) as f:
            manifest = json.load(f)
        leaves = manifest["leaves"]
    except (OSError, ValueError, KeyError, TypeError):
        return None
    for entry in leaves.values():
        try:
            if os.path.getsize(os.path.join(ckpt_dir, entry["file"])) <= 0:
                return None
        except (OSError, KeyError, TypeError):
            return None
    return manifest


def list_steps(directory: str):
    """Steps with *intact* checkpoints (torn/corrupt ones skipped), across
    both the per-leaf directory and packed single-file layouts."""
    if not os.path.isdir(directory):
        return []
    out = set()
    for d in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and _read_manifest(os.path.join(directory, d)) is not None:
            out.add(int(m.group(1)))
            continue
        m = re.fullmatch(rf"step_(\d+){re.escape(_PACK_SUFFIX)}", d)
        if m and _read_packed_manifest(os.path.join(directory, d)) is not None:
            out.add(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, template: Any, *, step: Optional[int] = None, shardings=None):
    """Load into the structure of ``template`` (values ignored).

    ``shardings``: optional matching pytree of NamedSharding for elastic
    placement onto the *current* mesh. Returns (tree, manifest_extra, step).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no intact checkpoints under {directory}")
    ckpt = os.path.join(directory, f"step_{step:08d}")
    packed = _read_packed_manifest(ckpt + _PACK_SUFFIX)
    manifest = packed if packed is not None else _read_manifest(ckpt)
    if manifest is None:
        raise FileNotFoundError(
            f"checkpoint at step {step} under {directory} is missing or torn "
            "(manifest unreadable or leaf files incomplete)"
        )
    payload = b""
    if packed is not None:
        with open(ckpt + _PACK_SUFFIX, "rb") as f:
            f.seek(packed["_data_start"])
            payload = f.read()

    leaves, tdef = _flatten(template)
    shard_leaves = (
        tdef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves)
    )
    out = []
    for (path, tmpl), shard in zip(leaves, shard_leaves):
        key = jax.tree_util.keystr(path)
        if key not in manifest["leaves"]:
            raise KeyError(f"checkpoint at step {step} is missing leaf {key}")
        entry = manifest["leaves"][key]
        if packed is not None:
            arr = np.frombuffer(
                payload[entry["offset"] : entry["offset"] + entry["nbytes"]],
                np.dtype(entry["store"]),
            ).reshape(entry["shape"])
        else:
            arr = np.load(os.path.join(ckpt, entry["file"]))
        arr = _from_saved(arr, entry["dtype"], entry.get("viewed", False))
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != template {tmpl.shape}")
        if shard is not None:
            out.append(jax.device_put(arr.astype(tmpl.dtype), shard))
        else:
            out.append(jax.numpy.asarray(arr, tmpl.dtype))
    tree = jax.tree_util.tree_unflatten(tdef, out)
    return tree, manifest.get("extra", {}), step
