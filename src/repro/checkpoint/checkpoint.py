"""Sharded numpy checkpointing with atomic manifests and elastic restore.

Layout:
  <dir>/step_<N>.tmp/...   (written)  →  os.rename  →  <dir>/step_<N>/
    manifest.json          step, leaf index {path: {shape, dtype, file}},
                           extra metadata (data state, PRNG, config name)
    <leaf files>.npy       one per pytree leaf (host-gathered)

* Atomicity: the manifest-bearing directory only appears under its final
  name after every array file is fully written (tmp-dir + rename).
* keep_last_k garbage collection.
* Elastic restore: arrays are loaded host-side and ``jax.device_put`` with
  the *target* shardings — the saved mesh shape is irrelevant, so a
  checkpoint taken on 512 chips restores onto 8 (tested) or vice versa.
* Async: ``save(..., async_=True)`` snapshots to host then writes on a
  worker thread (training continues).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SANITIZE = re.compile(r"[^A-Za-z0-9_.-]+")

# numpy-native dtypes round-trip through .npy; ml_dtypes (bfloat16, fp8)
# come back as void — store those as a uint view + the true name in the manifest
_NATIVE_DTYPES = {
    "float64", "float32", "float16", "int64", "int32", "int16", "int8",
    "uint64", "uint32", "uint16", "uint8", "bool",
}


def _to_savable(arr: np.ndarray):
    name = str(arr.dtype)
    if name in _NATIVE_DTYPES:
        return arr, name, False
    view = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
    return view, name, True


def _from_saved(arr: np.ndarray, dtype_name: str, viewed: bool):
    if viewed:
        return arr.view(np.dtype(dtype_name))
    return arr


def _leaf_name(path) -> str:
    return _SANITIZE.sub("_", jax.tree_util.keystr(path)).strip("_") or "root"


def _flatten(tree):
    return jax.tree_util.tree_flatten_with_path(tree)


def save(
    directory: str,
    step: int,
    tree: Any,
    *,
    extra: Optional[dict] = None,
    keep_last: int = 3,
    async_: bool = False,
):
    """Write a checkpoint. Returns the final path (or a Thread if async)."""
    leaves, _ = _flatten(tree)
    host = [(path, np.asarray(jax.device_get(leaf))) for path, leaf in leaves]

    def _write():
        os.makedirs(directory, exist_ok=True)
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        index = {}
        for path, arr in host:
            name = _leaf_name(path)
            fname = name + ".npy"
            savable, dtype_name, viewed = _to_savable(arr)
            np.save(os.path.join(tmp, fname), savable)
            index[jax.tree_util.keystr(path)] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": dtype_name,
                "viewed": viewed,
            }
        manifest = {"step": step, "leaves": index, "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(directory, keep_last)
        return final

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    return _write()


def _gc(directory: str, keep_last: int):
    steps = sorted(list_steps(directory))
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


def list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(directory, d, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, template: Any, *, step: Optional[int] = None, shardings=None):
    """Load into the structure of ``template`` (values ignored).

    ``shardings``: optional matching pytree of NamedSharding for elastic
    placement onto the *current* mesh. Returns (tree, manifest_extra, step).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    ckpt = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(ckpt, "manifest.json")) as f:
        manifest = json.load(f)

    leaves, tdef = _flatten(template)
    shard_leaves = (
        tdef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves)
    )
    out = []
    for (path, tmpl), shard in zip(leaves, shard_leaves):
        key = jax.tree_util.keystr(path)
        if key not in manifest["leaves"]:
            raise KeyError(f"checkpoint at step {step} is missing leaf {key}")
        entry = manifest["leaves"][key]
        arr = np.load(os.path.join(ckpt, entry["file"]))
        arr = _from_saved(arr, entry["dtype"], entry.get("viewed", False))
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != template {tmpl.shape}")
        if shard is not None:
            out.append(jax.device_put(arr.astype(tmpl.dtype), shard))
        else:
            out.append(jax.numpy.asarray(arr, tmpl.dtype))
    tree = jax.tree_util.tree_unflatten(tdef, out)
    return tree, manifest.get("extra", {}), step
