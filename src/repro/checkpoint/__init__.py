"""Sharded checkpointing + fault tolerance."""
from .checkpoint import save, restore, latest_step, list_steps
from .fault_tolerance import LoopReport, run_resilient_loop
