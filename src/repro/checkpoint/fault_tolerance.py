"""Fault tolerance: restart-from-latest, straggler watchdog, crash injection.

``run_resilient_loop`` wraps a step function with:
  * periodic (async-capable) checkpointing of the full train state + data
    iterator state,
  * automatic restore-from-latest and replay on any step exception
    (bounded retries),
  * a step-time watchdog that flags stragglers (> ``straggler_factor`` ×
    rolling median) — on a real fleet this is where the re-shard /
    hot-spare hook fires; here it logs and counts (unit-tested via an
    injected delay),
  * deterministic crash injection for tests (``fail_at_step``).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

import jax

from . import checkpoint as ckpt

log = logging.getLogger("repro.fault_tolerance")


@dataclasses.dataclass
class LoopReport:
    steps_run: int = 0
    restarts: int = 0
    stragglers: int = 0
    final_metrics: Optional[dict] = None
    losses: list = dataclasses.field(default_factory=list)
    # extra metadata of the last checkpoint restored from (initial resume or
    # mid-run restart); None if the loop never restored
    restored_extra: Optional[dict] = None


def run_resilient_loop(
    *,
    state,
    step_fn: Callable,  # (state, batch, step:int) -> (state, metrics)
    batch_fn: Callable,  # step:int -> batch
    n_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 50,
    keep_last: int = 2,
    max_restarts: int = 3,
    straggler_factor: float = 3.0,
    fail_at_step: Optional[int] = None,
    state_shardings=None,
    extra_meta: Optional[dict] = None,
) -> LoopReport:
    report = LoopReport()

    def _carry_extra(extra: dict):
        """Preserve restored checkpoint metadata across the restart: record
        it on the report and fold it (minus the loop-owned ``data_state``)
        back into what subsequent saves write, caller keys winning."""
        nonlocal extra_meta
        report.restored_extra = extra
        carried = {k: v for k, v in extra.items() if k != "data_state"}
        extra_meta = {**carried, **(extra_meta or {})}

    # resume if a checkpoint exists
    start = 0
    if ckpt.latest_step(ckpt_dir) is not None:
        state, extra, start = ckpt.restore(ckpt_dir, state, shardings=state_shardings)
        _carry_extra(extra)
        log.info("resumed from step %d", start)

    step = start
    step_times = []
    restarts = 0
    injected = {"done": False}

    while step < n_steps:
        try:
            t0 = time.perf_counter()
            if fail_at_step is not None and step == fail_at_step and not injected["done"]:
                injected["done"] = True
                raise RuntimeError(f"injected node failure at step {step}")
            batch = batch_fn(step)
            state, metrics = step_fn(state, batch, step)
            jax.block_until_ready(metrics)
            dt = time.perf_counter() - t0
            if len(step_times) >= 5:
                med = sorted(step_times)[len(step_times) // 2]
                if dt > straggler_factor * med:
                    report.stragglers += 1
                    log.warning("straggler: step %d took %.3fs (median %.3fs)", step, dt, med)
            step_times.append(dt)
            if len(step_times) > 64:
                step_times.pop(0)

            report.steps_run += 1
            report.final_metrics = {k: float(v) for k, v in metrics.items()}
            report.losses.append(report.final_metrics.get("loss", 0.0))
            step += 1
            if step % ckpt_every == 0 or step == n_steps:
                ckpt.save(
                    ckpt_dir,
                    step,
                    state,
                    extra={"data_state": {"step": step}, **(extra_meta or {})},
                    keep_last=keep_last,
                )
        except Exception as e:  # noqa: BLE001 — any step failure triggers restart
            restarts += 1
            report.restarts = restarts
            if restarts > max_restarts:
                raise RuntimeError(f"exceeded {max_restarts} restarts") from e
            log.warning("step %d failed (%s); restoring latest checkpoint", step, e)
            last = ckpt.latest_step(ckpt_dir)
            if last is None:
                step = 0  # no checkpoint yet — replay from scratch
            else:
                state, extra, step = ckpt.restore(
                    ckpt_dir, state, shardings=state_shardings
                )
                _carry_extra(extra)
            # the first post-restart step recompiles; a stale median would
            # flag it as a straggler and then drag the median itself
            step_times = []
    return report
