"""Training substrate: optimizer, GMR gradient compression, step builders."""
from .optimizer import OptimizerConfig, adamw_update, init_opt_state, lr_at, global_norm
from .grad_compress import CompressionConfig, compress, decompress, compressed_mean_grads, compression_ratio, is_compressible
from .train_step import cross_entropy, init_train_state, make_compressed_train_step, make_loss_fn, make_train_step
