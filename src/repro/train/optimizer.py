"""AdamW with cosine schedule, global-norm clipping, dtype policy.

Hand-rolled (no optax in the container): moments in fp32, parameter update
applied in the parameter dtype. ``master=True`` keeps an fp32 master copy
(recommended on real bf16 runs; off by default to halve optimizer HBM in
the dry-run memory story — recorded in EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    master: bool = False
    # "bfloat16" halves optimizer HBM (moments computed in fp32, stored bf16)
    moments_dtype: str = "float32"


def lr_at(step, oc: OptimizerConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = step / jnp.maximum(1.0, oc.warmup_steps)
    t = (step - oc.warmup_steps) / jnp.maximum(1.0, oc.total_steps - oc.warmup_steps)
    t = jnp.clip(t, 0.0, 1.0)
    cos = oc.min_lr_ratio + (1 - oc.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return oc.lr * jnp.where(step < oc.warmup_steps, warm, cos)


def init_opt_state(params, oc: OptimizerConfig) -> dict:
    mdt = jnp.dtype(oc.moments_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    st = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if oc.master:
        st["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return st


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in jax.tree.leaves(tree))
    )


def adamw_update(grads, opt_state, params, oc: OptimizerConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    if oc.clip_norm is not None:
        scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = oc.b1, oc.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    lr = lr_at(step, oc)

    mdt = jnp.dtype(oc.moments_dtype)

    def upd(g, m, v, p, master=None):
        g32 = g.astype(jnp.float32)
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m / bc1
        vhat = v / bc2
        base = (master if master is not None else p).astype(jnp.float32)
        new = base - lr * (mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay * base)
        return m.astype(mdt), v.astype(mdt), new

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    flat_p = tdef.flatten_up_to(params)
    flat_master = tdef.flatten_up_to(opt_state["master"]) if oc.master else [None] * len(flat_p)

    new_m, new_v, new_p, new_master = [], [], [], []
    for g, m, v, p, mm in zip(flat_g, flat_m, flat_v, flat_p, flat_master):
        m2, v2, full = upd(g, m, v, p, mm)
        new_m.append(m2)
        new_v.append(v2)
        new_p.append(full.astype(p.dtype))
        if oc.master:
            new_master.append(full)

    new_state = {
        "m": jax.tree.unflatten(tdef, new_m),
        "v": jax.tree.unflatten(tdef, new_v),
        "step": step,
    }
    if oc.master:
        new_state["master"] = jax.tree.unflatten(tdef, new_master)
    return jax.tree.unflatten(tdef, new_p), new_state, {"grad_norm": gnorm, "lr": lr}
