"""Train-step builders: plain pjit path and GMR-compressed-gradient path.

* :func:`make_train_step` — standard SPMD step: value_and_grad under jit,
  DP reduction inserted by the partitioner, AdamW update. Knobs: remat
  policy, microbatch accumulation.
* :func:`make_compressed_train_step` — the paper's Algorithm 1 replacing
  the dense DP all-reduce (train/grad_compress.py). Built with
  ``jax.shard_map`` *manual* over the DP axes and *auto* over `model`, so
  tensor parallelism stays partitioner-managed while DP communication is
  explicit and sketched.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (
    ParallelismRules,
    axis_size_compat,
    batch_pspec,
    param_pspecs,
    shard_map_compat,
)
from repro.models import train_logits
from repro.models.config import ModelConfig

from .grad_compress import CompressionConfig, compressed_mean_grads, init_error_state, is_compressible
from .optimizer import OptimizerConfig, adamw_update, init_opt_state


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL. logits (B,S,V) fp32, labels (B,S) int32.

    The gold logit is gathered by masked reduction, not take_along_axis:
    with a vocab-sharded V axis the mask+sum stays local per shard and the
    partitioner finishes with a psum, whereas a gather on the sharded axis
    forces an all-gather of the full logits.
    """
    logz = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    onehot = labels[..., None] == jax.lax.broadcasted_iota(jnp.int32, (1, 1, V), 2)
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    return jnp.mean(logz - gold)


def make_loss_fn(cfg: ModelConfig, *, remat=None, dense_moe=False):
    def loss_fn(params, batch):
        logits, aux = train_logits(
            params, cfg, batch["tokens"], batch.get("vision"), dense_moe=dense_moe, remat=remat
        )
        ce = cross_entropy(logits[:, :-1], batch["labels"][:, 1:] if "labels" in batch else batch["tokens"][:, 1:])
        loss = ce + cfg.router_aux_weight * aux
        return loss, {"ce": ce, "aux": aux}

    return loss_fn


def _grads_microbatched(loss_fn, params, batch, n_micro: int):
    """lax.scan gradient accumulation over leading-batch splits."""
    if n_micro <= 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def resplit(x):
        b = x.shape[0]
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    micro = {k: resplit(v) for k, v in batch.items()}

    def body(carry, mb):
        acc, loss_acc = carry
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc = jax.tree.map(jnp.add, acc, g)
        return (acc, loss_acc + loss), metrics

    from repro.models.layers import match_vma

    ref = batch["tokens"]
    zeros = jax.tree.map(lambda p: match_vma(jnp.zeros(p.shape, jnp.float32), ref), params)
    (gsum, loss_sum), metrics = jax.lax.scan(body, (zeros, match_vma(jnp.asarray(0.0), ref)), micro)
    grads = jax.tree.map(lambda g: g / n_micro, gsum)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    return loss_sum / n_micro, metrics, grads


def init_train_state(key, cfg: ModelConfig, oc: OptimizerConfig):
    from repro.models import init_params

    params = init_params(key, cfg)
    return {"params": params, "opt": init_opt_state(params, oc)}


def make_train_step(
    cfg: ModelConfig,
    oc: OptimizerConfig,
    *,
    remat: Optional[str] = "dots",
    microbatch: int = 1,
    dense_moe: bool = False,
):
    """Plain SPMD train step: (state, batch) → (state, metrics)."""
    loss_fn = make_loss_fn(cfg, remat=remat, dense_moe=dense_moe)

    def train_step(state, batch):
        loss, metrics, grads = _grads_microbatched(loss_fn, state["params"], batch, microbatch)
        params, opt, opt_metrics = adamw_update(grads, state["opt"], state["params"], oc)
        metrics = {"loss": loss, **metrics, **opt_metrics}
        return {"params": params, "opt": opt}, metrics

    return train_step


def make_compressed_train_step(
    cfg: ModelConfig,
    oc: OptimizerConfig,
    ccfg: CompressionConfig,
    mesh: Mesh,
    rules: ParallelismRules,
    *,
    remat: Optional[str] = "dots",
    dense_moe: bool = False,
):
    """GMR-compressed DP step. State gains an `err` EF pytree with a
    leading worker dim (sharded over the DP axes); `key` drives the shared
    per-step sketches.

    Returns (train_step, make_state_specs) where train_step(state, batch, key).
    """
    if rules.fsdp:
        raise ValueError(
            "gradient compression replaces the DP all-reduce; with FSDP the DP "
            "reduction is a reduce-scatter of sharded weights — unsupported combination"
        )
    loss_fn = make_loss_fn(cfg, remat=remat, dense_moe=dense_moe)
    dp = rules.dp_axes

    def inner(params, opt, err, batch, key):
        # local grads (batch is per-DP-shard here; no automatic DP psum since
        # the dp axes are manual)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        err_local = jax.tree.map(lambda e: e[0], err)  # drop worker dim
        # resolve EF placeholders for non-compressible leaves to zeros_like(grad)
        err_local = jax.tree.map(
            lambda e, g: e if is_compressible(g, ccfg) else jnp.zeros(g.shape, jnp.float32),
            err_local,
            grads,
        )
        gbar, new_err, cstats = compressed_mean_grads(
            grads, err_local, key, ccfg, dp, with_stats=True
        )
        new_err = jax.tree.map(
            lambda e, g: (e if is_compressible(g, ccfg) else jnp.zeros((1,), jnp.float32))[None],
            new_err,
            grads,
        )
        params, opt, opt_metrics = adamw_update(gbar, opt, params, oc)
        nw = 1
        for a in dp:
            nw *= axis_size_compat(a)
        # psum local metrics so every output except `err` is dp-invariant
        # (check_vma=True verifies this; partial-manual + check_vma=False is
        # broken in jax 0.8.2 — see DESIGN.md §Environment). The per-step
        # compression-quality stats ride along: worker-varying ones (EF norm,
        # reconstruction error) become DP means, config-static ones stay put.
        metrics = {k: jax.lax.psum(v, dp) / nw for k, v in {**metrics, **cstats}.items()}
        metrics = {"loss": jax.lax.psum(loss, dp) / nw, **metrics, **opt_metrics}
        return params, opt, new_err, metrics

    def err_spec(e):
        return P(dp, *([None] * (e.ndim - 1)))

    def train_step(state, batch, key):
        params, opt, err = state["params"], state["opt"], state["err"]
        pspec = jax.tree.map(lambda _: P(), params)
        ospec = jax.tree.map(lambda _: P(), opt)
        espec = jax.tree.map(err_spec, err)
        bspec = {k: P(dp, *([None] * (v.ndim - 1))) for k, v in batch.items()}
        mspec = P()

        metric_keys = (
            "loss", "ce", "aux", "grad_norm", "lr",
            "comp/wire_floats", "comp/dense_floats", "comp/ratio",
            "comp/ef_norm", "comp/rel_err",
        )
        fn = shard_map_compat(
            inner,
            mesh=mesh,
            in_specs=(pspec, ospec, espec, bspec, P()),
            out_specs=(pspec, ospec, espec, {k: mspec for k in metric_keys}),
            axis_names=set(dp),
            check_vma=True,
        )
        params, opt, err, metrics = jax.jit(fn)(params, opt, err, batch, key)
        return {"params": params, "opt": opt, "err": err}, metrics

    def init_err(params):
        nw = int(np.prod([mesh.shape[a] for a in dp]))
        return init_error_state(params, ccfg, nw)

    return train_step, init_err
