"""GMR gradient compression — the paper's Algorithm 1 as a distributed-
training communication primitive.

Data-parallel all-reduce of a weight gradient ``G (m×n)`` moves m·n floats
per step per worker. Instead each worker:

  1. draws the *same* sketches from a step-shared seed:
     Ω (n×c), Ψ (r×m) Gaussian outer sketches and S_C (s_c×m), S_R (s_r×n)
     inner sketches (paper §6.1 protocol: c=r, s=a·c);
  2. forms  C = GΩ,  R = ΨG,  M = S_C G S_Rᵀ  — all *linear* in G;
  3. psums (C, R, M)  — (m+n)·c + s² floats instead of m·n;
  4. reconstructs  Ĝ = C · (S_C C)† M (R S_Rᵀ)† · R  (Algorithm 1 verbatim,
     with A = ΣᵢGᵢ, never materialized);
  5. keeps a local error-feedback residual e ← (G+e) − Ĝ folded into the
     next step (Ĝ is biased; EF restores convergence — standard for
     PowerSGD-family compressors; validated in examples/train_lm.py).

Linearity of step 2 is what makes the compressed psum exact:
``Σᵢ(Gᵢ Ω) = (Σᵢ Gᵢ) Ω`` — the sketch of the sum is the sum of sketches.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gmr import fast_gmr_core
from repro.core.sketching import draw_sketch
from repro.distributed.sharding import axis_size_compat


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    rank: int = 64  # c = r — outer sketch size
    sketch_factor: int = 4  # a: inner sketch size s = a·rank (paper §6.1)
    min_dim: int = 512  # compress only 2-D leaves with both dims ≥ this
    inner_sketch: str = "gaussian"
    error_feedback: bool = True

    @property
    def s(self) -> int:
        return self.sketch_factor * self.rank


def is_compressible(leaf, ccfg: CompressionConfig) -> bool:
    """2-D weights, or scan-stacked (L, m, n) weights (compressed per layer
    with shared sketches — linearity holds independently per slice)."""
    if leaf.ndim == 2:
        return min(leaf.shape) >= ccfg.min_dim
    if leaf.ndim == 3:
        return min(leaf.shape[1:]) >= ccfg.min_dim
    return False


def compression_ratio(params, ccfg: CompressionConfig) -> float:
    """Dense vs compressed DP-all-reduce volume over the whole tree."""
    dense = comp = 0
    for leaf in jax.tree.leaves(params):
        n = int(np.prod(leaf.shape))
        dense += n
        if is_compressible(leaf, ccfg):
            L = leaf.shape[0] if leaf.ndim == 3 else 1
            m, nn = leaf.shape[-2:]
            comp += L * ((m + nn) * ccfg.rank + ccfg.s * ccfg.s)
        else:
            comp += n
    return dense / comp


def _sketches_for(key, shape, ccfg: CompressionConfig):
    m, n = shape
    c = ccfg.rank
    ks = jax.random.split(key, 4)
    omega = draw_sketch(ks[0], "gaussian", c, n)  # right outer: C = G Ωᵀ' (n×c)
    psi = draw_sketch(ks[1], "gaussian", c, m)  # left outer: R = Ψ G
    s_c = draw_sketch(ks[2], ccfg.inner_sketch, ccfg.s, m)
    s_r = draw_sketch(ks[3], ccfg.inner_sketch, ccfg.s, n)
    return omega, psi, s_c, s_r


def compress(key, G: jax.Array, ccfg: CompressionConfig):
    """Local sketching (step 2). Returns the (C, R, M) triple — linear in G.

    Stacked (L, m, n) gradients are sketched per slice with shared sketches
    (vmapped); the triple gains a leading L dim.
    """
    if G.ndim == 3:
        omega, psi, s_c, s_r = _sketches_for(key, G.shape[1:], ccfg)

        def one(g):
            gf = g.astype(jnp.float32)
            return omega.apply(gf.T).T, psi.apply(gf), s_r.apply_t(s_c.apply(gf))

        return jax.vmap(one)(G)
    omega, psi, s_c, s_r = _sketches_for(key, G.shape, ccfg)
    Gf = G.astype(jnp.float32)
    C = omega.apply(Gf.T).T  # G Ωᵀ: (m, c)
    R = psi.apply(Gf)  # Ψ G: (c, n)
    M = s_r.apply_t(s_c.apply(Gf))  # S_C G S_Rᵀ: (s, s)
    return C, R, M


def decompress(key, triple, shape, ccfg: CompressionConfig) -> jax.Array:
    """Algorithm 1 reconstruction from the (psum-reduced) triple."""
    C, R, M = triple
    if len(shape) == 3:
        omega, psi, s_c, s_r = _sketches_for(key, shape[1:], ccfg)

        def one(C, R, M):
            X = fast_gmr_core(s_c.apply(C), M, s_r.apply(R.T).T)
            return C @ (X @ R)

        return jax.vmap(one)(C, R, M)
    omega, psi, s_c, s_r = _sketches_for(key, shape, ccfg)
    ScC = s_c.apply(C)  # (s, c)
    RSr = s_r.apply(R.T).T  # (c, s)
    X = fast_gmr_core(ScC, M, RSr)
    return C @ (X @ R)


def compressed_mean_grads(
    grads,
    err,
    key,
    ccfg: CompressionConfig,
    axes: Tuple[str, ...],
    *,
    with_stats: bool = False,
):
    """Inside shard_map(manual over ``axes``): replace the dense DP psum.

    grads: local gradient pytree. err: local EF residual pytree (zeros tree
    when EF disabled). Returns (global mean-ish grads, new err).
    Small leaves take the dense psum path unchanged.

    ``with_stats=True`` additionally returns a dict of *traced* per-step
    compression-quality scalars (this runs inside shard_map — no host
    metrics registry here; the train step psums them into its metrics, and
    the host loop can then forward them to :mod:`repro.obs.metrics`):

    * ``comp/wire_floats`` / ``comp/dense_floats`` — floats actually
      all-reduced vs the dense-gradient volume (static per config);
    * ``comp/ratio`` — their quotient, the realized compression ratio;
    * ``comp/ef_norm`` — this worker's error-feedback residual norm
      ``√Σ‖e‖²`` over compressible leaves (EF health: should stay O(‖g‖),
      not grow step over step);
    * ``comp/rel_err`` — this worker's relative reconstruction error
      ``‖(g+e) − ĝ‖ / ‖g+e‖`` over compressible leaves.
    """
    nworkers = 1
    for a in axes:
        nworkers *= axis_size_compat(a)

    flat, tdef = jax.tree.flatten(grads)
    flat_err = tdef.flatten_up_to(err)
    out, out_err = [], []
    wire = dense = 0  # static float counts (python ints — config-determined)
    ef_sq = local_sq = resid_sq = jnp.zeros((), jnp.float32)
    for i, (g, e) in enumerate(zip(flat, flat_err)):
        dense += int(np.prod(g.shape))
        if is_compressible(g, ccfg):
            k = jax.random.fold_in(key, i)
            local = g.astype(jnp.float32) + (e if ccfg.error_feedback else 0.0)
            triple = compress(k, local, ccfg)
            triple = tuple(jax.lax.psum(t, axes) / nworkers for t in triple)
            ghat = decompress(k, triple, g.shape, ccfg)
            new_e = (local - ghat) if ccfg.error_feedback else jnp.zeros_like(local)
            out.append(ghat.astype(g.dtype))
            out_err.append(new_e)
            if with_stats:
                wire += sum(int(np.prod(t.shape)) for t in triple)
                ef_sq = ef_sq + jnp.sum(new_e * new_e)
                local_sq = local_sq + jnp.sum(local * local)
                resid_sq = resid_sq + jnp.sum((local - ghat) ** 2)
        else:
            out.append(jax.lax.psum(g, axes) / nworkers)
            out_err.append(jnp.zeros_like(e))
            wire += int(np.prod(g.shape))
    result = jax.tree.unflatten(tdef, out), jax.tree.unflatten(tdef, out_err)
    if not with_stats:
        return result
    stats = {
        "comp/wire_floats": jnp.asarray(wire, jnp.float32),
        "comp/dense_floats": jnp.asarray(dense, jnp.float32),
        "comp/ratio": jnp.asarray(dense / max(wire, 1), jnp.float32),
        "comp/ef_norm": jnp.sqrt(ef_sq),
        "comp/rel_err": jnp.sqrt(resid_sq)
        / jnp.maximum(jnp.sqrt(local_sq), jnp.finfo(jnp.float32).tiny),
    }
    return (*result, stats)


def init_error_state(params, ccfg: CompressionConfig, nworkers: int):
    """EF residuals: one per DP worker, stored with a leading worker dim."""

    def leaf(p):
        if is_compressible(p, ccfg):
            return jnp.zeros((nworkers, *p.shape), jnp.float32)
        return jnp.zeros((nworkers, 1), jnp.float32)  # placeholder, unused

    return jax.tree.map(leaf, params)
