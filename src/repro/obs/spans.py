"""Named profiling spans: wall-clock records + ``jax.profiler`` annotations.

:func:`span` is a context manager instrumenting the host side of a dispatch:
it pushes a :class:`~repro.obs.metrics.SpanRecord` (start, duration, nesting
depth) into the active :class:`~repro.obs.metrics.MetricsRegistry` and wraps
the body in a :class:`jax.profiler.TraceAnnotation`, so the same names show
up in TensorBoard/perfetto traces when a profiler session is live.

Span naming scheme (see ``docs/observability.md`` for the catalog):
``layer/subject/stage`` — e.g. ``stream/adaptive_cur/scan``,
``stream/adaptive_cur/sharded``, ``serve/kv_compress/prefill``,
``obs/estimate_rel_error``.

Async-dispatch caveat: JAX returns before the device finishes, so a span
around a bare jitted call measures dispatch, not execution. Block inside the
span (``jax.block_until_ready(out)``) when device wall-clock is the thing
being measured — the benchmark drivers do.

With the default registry disabled the context manager is a no-op ``yield``
(no clock read, no annotation), so spans baked into library code — the
engine's scan drivers — cost one attribute check in production.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Optional

import jax

from .metrics import MetricsRegistry, SpanRecord, default_registry

__all__ = ["span", "render_timeline"]


@contextmanager
def span(name: str, registry: Optional[MetricsRegistry] = None):
    """Record a named wall-clock span into ``registry`` (default: the
    process registry) and annotate the profiler trace. No-op when the
    registry is disabled."""
    reg = registry if registry is not None else default_registry()
    if not reg.enabled:
        yield
        return
    depth = len(reg._span_stack)
    reg._span_stack.append(name)
    start = time.perf_counter()
    try:
        with jax.profiler.TraceAnnotation(name):
            yield
    finally:
        duration = time.perf_counter() - start
        reg._span_stack.pop()
        reg.spans.append(
            SpanRecord(name=name, start=start - reg.epoch, duration=duration, depth=depth)
        )


def render_timeline(registry: Optional[MetricsRegistry] = None, width: int = 40) -> str:
    """ASCII timeline of the registry's recorded spans.

    One line per span in start order — indentation shows nesting, the bar
    shows the span's extent relative to the whole recorded window::

        stream/adaptive_cur/scan      12.31ms |   ####             |
          obs/estimate_rel_error       3.02ms |       ##           |

    Returns ``"(no spans recorded)"`` when the registry has none.
    """
    reg = registry if registry is not None else default_registry()
    spans = sorted(reg.spans, key=lambda s: s.start)
    if not spans:
        return "(no spans recorded)"
    t0 = min(s.start for s in spans)
    t1 = max(s.start + s.duration for s in spans)
    window = max(t1 - t0, 1e-9)
    lines = []
    for s in spans:
        lo = int((s.start - t0) / window * width)
        hi = max(int((s.start + s.duration - t0) / window * width), lo + 1)
        bar = " " * lo + "#" * (hi - lo) + " " * (width - hi)
        label = "  " * s.depth + s.name
        lines.append(f"{label:<44} {s.duration * 1e3:>9.2f}ms |{bar}|")
    return "\n".join(lines)
