"""In-scan telemetry: a per-panel diagnostics pytree carried through the
streaming engine.

The panel engine (:mod:`repro.stream.engine`) runs the whole stream as one
donated-buffer ``lax.scan`` per chunk — there is no place to hang a host
callback without breaking scan compilation. Telemetry therefore rides *in*
the scan carry: a :class:`TelemetryFrame` of **fixed-shape** arrays lives in
``PanelState.tel`` and an application-chosen ``PanelOps.telemetry`` hook
folds one panel's diagnostics into it per engine step. Everything is indexed
by the **global panel id** ``t = offset // panel``, which gives the frame
three properties the rest of the repo's streaming algebra already relies on:

* *opt-in and inert*: ``tel=None`` (the default) contributes no pytree
  leaves, so the scan program, donation layout and jit cache keys are
  byte-identical to an untelemetered stream (asserted via
  ``launch/hlo_census.py`` in ``tests/test_obs.py``);
* *read-only with respect to the factors*: the hook runs after the C/R/M
  updates and only writes ``tel`` — factors are bit-identical with telemetry
  on or off;
* *distributed-exact*: per-panel slots are written by exactly one worker
  (workers own disjoint panel ranges), and the running sums
  (``energy_mass``, ``psi``, ``panels_seen``) are sums of per-panel
  contributions — so worker frames merge by summation
  (:meth:`TelemetryFrame.merge` in-process, :meth:`TelemetryFrame.collective`
  under ``shard_map``) with the same disjoint-write algebra as C/R/M.

The frame also carries the **a-posteriori error estimator**'s test sketch:
``psi`` accumulates ``Ψ = A Ω_test``, folded by the engine as **one GEMM per
consumed chunk** (:func:`fold_psi_chunk` — a rank-``q`` matmul inside the
scan carry costs ~3× its standalone wall-time, so the engine hoists it out
of the scan body; the chunk is consumed atomically by the same program, so
``Ψ`` and the factors still cover exactly the same columns at every program
boundary). :func:`repro.obs.error_estimate.estimate_rel_error` compares
``Ψ`` against the factors' action on the same ``Ω_test`` — see
``docs/observability.md`` for the Tropp test-sketch argument.

Per-panel values are **panel-local**, never cumulative (a cumulative value
would break the merge-by-sum contract): ``admitted[t]`` is the number of
columns admitted *in* panel ``t``, ``occupancy[t]`` the (worker-local) slot
occupancy *after* panel ``t``, and so on. Decode ``events`` with the
``EVENT_*`` bitmask constants.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "TelemetryFrame",
    "init_telemetry",
    "adaptive_stream_telemetry",
    "fixed_stream_telemetry",
    "fold_psi_chunk",
    "telemetry_summary",
    "EVENT_ADMIT",
    "EVENT_EVICT",
    "EVENT_ROW_ADMIT",
    "EVENT_BUDGET_FULL",
    "EVENT_QUARANTINED",
]

# ``events`` bitmask: what happened in panel t.
EVENT_ADMIT = 1  # ≥1 column admitted
EVENT_EVICT = 2  # ≥1 column evicted (adaptive swap_gain policy)
EVENT_ROW_ADMIT = 4  # ≥1 row admitted (adaptive rows)
EVENT_BUDGET_FULL = 8  # the worker's column budget is full after this panel
EVENT_QUARANTINED = 16  # panel carried NaN/Inf and was zero-scaled in-scan

_QUANTILES = (0.0, 25.0, 50.0, 75.0, 100.0)


@dataclasses.dataclass(frozen=True)
class TelemetryFrame:
    """Fixed-shape per-panel diagnostics, carried in ``PanelState.tel``.

    ``P = padded_n(n, panel) // panel`` panel slots; all per-panel arrays are
    indexed by the global panel id and written by exactly one worker
    (disjoint panel ranges), so frames merge/psum by summation. ``omega`` is
    the estimator's test sketch — a constant, bit-identical on every worker,
    excluded from every reduction.
    """

    admitted: jax.Array  # (P,) int32 — columns admitted in panel t
    evicted: jax.Array  # (P,) int32 — columns evicted in panel t
    rows_admitted: jax.Array  # (P,) int32 — rows admitted in panel t
    occupancy: jax.Array  # (P,) int32 — filled slots after panel t (worker-local)
    events: jax.Array  # (P,) int32 — EVENT_* bitmask for panel t
    panel_scores: jax.Array  # (P, panel) f32 — raw per-panel column scores (padded cols 0)
    panel_energy: jax.Array  # (P,) f32 — Σ sketched column energy of panel t
    energy_mass: jax.Array  # () f32 — running Σ panel_energy over seen panels
    psi: jax.Array  # (m, q) f32 — running test sketch Ψ = A Ω_test
    omega: jax.Array  # (n_pad, q) f32 — the test sketch Ω_test (constant)
    panels_seen: jax.Array  # () int32 — panels folded into this frame
    panel: int  # static: panel width the frame is indexed by
    n: int  # static: true column count of the stream

    def merge(self, frames):
        """Sum worker frames into the single-stream frame (in-process merge).

        Per-panel slots are disjoint writes into zero-init arrays and the
        scalars/``psi`` are running sums, so summation is exact — the same
        algebra :func:`repro.stream.distributed.merge_states` uses for
        C/R/M. ``omega`` is identical on every worker and kept once.
        """

        def tot(get):
            return sum((get(f) for f in frames[1:]), get(frames[0]))

        return dataclasses.replace(
            frames[0],
            admitted=tot(lambda f: f.admitted),
            evicted=tot(lambda f: f.evicted),
            rows_admitted=tot(lambda f: f.rows_admitted),
            occupancy=tot(lambda f: f.occupancy),
            events=tot(lambda f: f.events),
            panel_scores=tot(lambda f: f.panel_scores),
            panel_energy=tot(lambda f: f.panel_energy),
            energy_mass=tot(lambda f: f.energy_mass),
            psi=tot(lambda f: f.psi),
            panels_seen=tot(lambda f: f.panels_seen),
            omega=self.omega,
        )

    def collective(self, axis) -> "TelemetryFrame":
        """``shard_map`` mirror of :meth:`merge`: one psum per leaf, with the
        constant ``omega`` excluded (reducing it would scale it by W)."""
        ps = lambda x: jax.lax.psum(x, axis)  # noqa: E731 — local shorthand
        return dataclasses.replace(
            self,
            admitted=ps(self.admitted),
            evicted=ps(self.evicted),
            rows_admitted=ps(self.rows_admitted),
            occupancy=ps(self.occupancy),
            events=ps(self.events),
            panel_scores=ps(self.panel_scores),
            panel_energy=ps(self.panel_energy),
            energy_mass=ps(self.energy_mass),
            psi=ps(self.psi),
            panels_seen=ps(self.panels_seen),
            omega=self.omega,
        )


jax.tree_util.register_dataclass(
    TelemetryFrame,
    data_fields=[
        "admitted", "evicted", "rows_admitted", "occupancy", "events",
        "panel_scores", "panel_energy", "energy_mass", "psi", "omega",
        "panels_seen",
    ],
    meta_fields=["panel", "n"],
)


def init_telemetry(key, m: int, n: int, panel: int, *, q: int = 16) -> TelemetryFrame:
    """Allocate a zero :class:`TelemetryFrame` + draw the estimator sketch.

    Args:
        key: PRNG key for the test sketch ``Ω_test`` — must be independent of
            the state's core sketches (the init functions fold a constant
            into their own key), or the estimator loses its held-out status.
        m: row count of the stream (``n`` for symmetric/kernel streams).
        n: true column count of the stream.
        panel: fixed panel width the stream will be driven with — the frame
            is indexed by ``offset // panel``, so driving the state with a
            different width scrambles the per-panel slots.
        q: test-sketch width. The estimator's relative accuracy concentrates
            like ``O(1/√q)`` (Tropp et al. 2017, §6) — the default 16 keeps
            it comfortably inside the 2× acceptance band at negligible cost
            (one rank-``q`` panel matmul per step).

    Returns:
        A zeroed frame with ``Ω_test ~ N(0,1)`` rows (padded rows ≥ ``n``
        zeroed, so zero-padded tail panels contribute nothing to ``Ψ``).
    """
    n_pad = ((n + panel - 1) // panel) * panel
    num_panels = n_pad // panel
    omega = jax.random.normal(key, (n_pad, q), jnp.float32)
    omega = jnp.where(jnp.arange(n_pad)[:, None] < n, omega, 0.0)
    return TelemetryFrame(
        admitted=jnp.zeros((num_panels,), jnp.int32),
        evicted=jnp.zeros((num_panels,), jnp.int32),
        rows_admitted=jnp.zeros((num_panels,), jnp.int32),
        occupancy=jnp.zeros((num_panels,), jnp.int32),
        events=jnp.zeros((num_panels,), jnp.int32),
        panel_scores=jnp.zeros((num_panels, panel), jnp.float32),
        panel_energy=jnp.zeros((num_panels,), jnp.float32),
        energy_mass=jnp.zeros((), jnp.float32),
        psi=jnp.zeros((m, q), jnp.float32),
        omega=omega,
        panels_seen=jnp.zeros((), jnp.int32),
        panel=panel,
        n=n,
    )


def _fold_panel(tel: TelemetryFrame, A_L, sc_a, scores, off):
    """Application-independent slice of the per-panel fold: raw score row
    and energy mass. Returns the updated frame and the global panel id ``t``.

    Deliberately cheap — everything here lives in the scan carry, where ops
    cost ~3–6× their standalone wall-time (the ≤1.3× overhead gate is the
    budget). Score *quantiles* are therefore not computed in-scan: the raw
    ``(panel,)`` score row is stored (one dynamic-update-slice) and
    :func:`telemetry_summary` takes nearest-rank quantiles host-side. The
    estimator's ``Ψ`` update is likewise hoisted out of the scan body — the
    engine folds it once per chunk via :func:`fold_psi_chunk`."""
    L = A_L.shape[1]
    t = off // tel.panel
    if scores is None:
        y = sc_a.astype(jnp.float32)
        energy = jnp.sum(y * y, axis=0)  # (L,) sketched column energy
        svec = energy
    else:
        svec, energy = (s.astype(jnp.float32) for s in scores)
    valid = (off + jnp.arange(L)) < tel.n  # mask zero-padded tail columns
    energy = jnp.where(valid, energy, 0.0)
    tel = dataclasses.replace(
        tel,
        panel_scores=tel.panel_scores.at[t].set(jnp.where(valid, svec, 0.0)),
        panel_energy=tel.panel_energy.at[t].set(jnp.sum(energy)),
        energy_mass=tel.energy_mass + jnp.sum(energy),
        panels_seen=tel.panels_seen + 1,
    )
    return tel, t


def fold_psi_chunk(tel: TelemetryFrame, A_block, off) -> TelemetryFrame:
    """Fold a consumed block of columns into the estimator sketch:
    ``Ψ += A_block · Ω_test[off : off+W]`` as **one** GEMM.

    Called by the engine's scan entry points (and the per-panel fallback
    driver) on the whole block a program consumes, *outside* the
    ``lax.scan`` body — same result as a per-panel fold up to float
    summation order, at the standalone-GEMM price instead of the in-carry
    price. Zero-padded tail columns multiply zeroed ``Ω_test`` rows, so
    padding stays exact. ``off`` may be a tracer (the state's running
    offset)."""
    w = jax.lax.dynamic_slice_in_dim(tel.omega, off, A_block.shape[1], axis=0)
    return dataclasses.replace(tel, psi=tel.psi + A_block.astype(jnp.float32) @ w)


def fixed_stream_telemetry(tel, ctx, ctx_new, A_L, sc_a, scores, off):
    """``PanelOps.telemetry`` hook for the fixed-index plug-ins
    (``streaming_cur``, ``streaming_spsd``): "admission" is a selected
    column's panel streaming by, derived from the static ``col_idx`` table
    (identical on every worker, so per-panel counts are global)."""
    tel, t = _fold_panel(tel, A_L, sc_a, scores, off)
    L = A_L.shape[1]
    idx = ctx_new.col_idx
    adm = jnp.sum((idx >= off) & (idx < off + L)).astype(jnp.int32)
    occ = jnp.sum((idx >= 0) & (idx < off + L)).astype(jnp.int32)
    full = occ >= idx.shape[0]
    events = jnp.where(adm > 0, EVENT_ADMIT, 0) + jnp.where(full, EVENT_BUDGET_FULL, 0)
    return dataclasses.replace(
        tel,
        admitted=tel.admitted.at[t].set(adm),
        occupancy=tel.occupancy.at[t].set(occ),
        events=tel.events.at[t].set(events.astype(jnp.int32)),
    )


def adaptive_stream_telemetry(tel, ctx, ctx_new, A_L, sc_a, scores, off):
    """``PanelOps.telemetry`` hook for the adaptive policy
    (``adaptive_cur``, ``adaptive_spsd``): admission/eviction deltas are read
    off the pre-/post-update :class:`~repro.stream.adaptive.AdaptiveCURCtx`
    counters. Occupancy is **worker-local** under sharding (each worker
    audits its own slot range); merged frames keep the admitting worker's
    view, which is the post-hoc audit trail eviction analysis needs."""
    tel, t = _fold_panel(tel, A_L, sc_a, scores, off)
    adm = (ctx_new.n_filled - ctx.n_filled).astype(jnp.int32)
    ev = (ctx_new.n_evicted - ctx.n_evicted).astype(jnp.int32)
    occ = (ctx_new.n_filled - ctx_new.slot_lo).astype(jnp.int32)
    full = ctx_new.n_filled >= ctx_new.slot_lo + ctx_new.c_local
    if ctx_new.rows is not None:
        radm = (ctx_new.rows.n_filled - ctx.rows.n_filled).astype(jnp.int32)
    else:
        radm = jnp.zeros((), jnp.int32)
    events = (
        jnp.where(adm > 0, EVENT_ADMIT, 0)
        + jnp.where(ev > 0, EVENT_EVICT, 0)
        + jnp.where(radm > 0, EVENT_ROW_ADMIT, 0)
        + jnp.where(full, EVENT_BUDGET_FULL, 0)
    )
    return dataclasses.replace(
        tel,
        admitted=tel.admitted.at[t].set(adm),
        evicted=tel.evicted.at[t].set(ev),
        rows_admitted=tel.rows_admitted.at[t].set(radm),
        occupancy=tel.occupancy.at[t].set(occ),
        events=tel.events.at[t].set(events.astype(jnp.int32)),
    )


def telemetry_summary(state_or_tel) -> dict:
    """Host-side audit view of a streamed frame (the post-hoc eviction audit).

    Accepts a :class:`~repro.stream.engine.PanelState` (reads ``.tel``) or a
    :class:`TelemetryFrame`. Returns plain numpy/python values: the per-panel
    arrays, decoded event names per panel, and scalar totals — ready for
    :meth:`repro.obs.metrics.MetricsRegistry.record_stream_telemetry` or a
    notebook.
    """
    tel = getattr(state_or_tel, "tel", state_or_tel)
    if tel is None:
        raise ValueError("state has no telemetry (init with telemetry=True)")
    names = (
        (EVENT_ADMIT, "admit"), (EVENT_EVICT, "evict"),
        (EVENT_ROW_ADMIT, "row_admit"), (EVENT_BUDGET_FULL, "budget_full"),
        (EVENT_QUARANTINED, "quarantined"),
    )
    events = np.asarray(tel.events)
    # Nearest-rank score quantiles per panel, computed here (host-side)
    # from the raw in-scan score rows — see _fold_panel for why the scan
    # does not sort. Valid-count per panel comes from the global column
    # range; unseen panels are all-zero rows and quantile to zeros.
    scores = np.asarray(tel.panel_scores, np.float32)
    P, L = scores.shape
    score_q = np.zeros((P, len(_QUANTILES)), np.float32)
    for t in range(P):
        cnt = int(np.clip(tel.n - t * tel.panel, 0, L))
        if cnt > 0:
            srt = np.sort(scores[t, :cnt])
            ranks = np.clip(
                np.round(np.asarray(_QUANTILES) / 100.0 * (cnt - 1)), 0, cnt - 1
            ).astype(np.int64)
            score_q[t] = srt[ranks]
    return {
        "admitted": np.asarray(tel.admitted),
        "evicted": np.asarray(tel.evicted),
        "rows_admitted": np.asarray(tel.rows_admitted),
        "occupancy": np.asarray(tel.occupancy),
        "panel_scores": scores,
        "score_q": score_q,
        "panel_energy": np.asarray(tel.panel_energy),
        "events": [[nm for bit, nm in names if e & bit] for e in events],
        "energy_mass": float(tel.energy_mass),
        "panels_seen": int(tel.panels_seen),
        "total_admitted": int(np.sum(np.asarray(tel.admitted))),
        "total_evicted": int(np.sum(np.asarray(tel.evicted))),
        "total_rows_admitted": int(np.sum(np.asarray(tel.rows_admitted))),
    }
