"""Observability for the streaming engine: in-scan telemetry, a-posteriori
error estimation, and host-side metrics/spans.

Three layers, strictly opt-in at every level:

* :mod:`repro.obs.telemetry` — a fixed-shape per-panel diagnostics pytree
  (:class:`TelemetryFrame`) carried through the engine's ``lax.scan`` via
  the ``PanelOps.telemetry`` hook; off by default (``tel=None`` ⇒ the scan
  program is byte-identical to an untelemetered stream).
* :mod:`repro.obs.error_estimate` — ``estimate_rel_error``: a running
  relative Frobenius-error estimate from the independent test sketch
  ``Ψ = A Ω_test`` the telemetry frame maintains in-stream (Tropp et al.'s
  a-posteriori argument; no second pass over ``A``).
* :mod:`repro.obs.metrics` / :mod:`repro.obs.spans` — a host-side registry
  of counters/gauges/histograms with a JSON-lines dump, and
  ``jax.profiler``-annotated wall-clock spans with a ``render_timeline``
  report; the process default registry starts disabled.

Enable per stream with ``telemetry=True`` on the plug-in inits
(``adaptive_cur_init``, ``streaming_cur_init``, ``streaming_spsd_init``,
``adaptive_spsd_init``); see ``docs/observability.md`` for the metric
catalog and the estimator derivation.
"""

from .error_estimate import estimate_rel_error, low_rank_apply
from .metrics import MetricsRegistry, SpanRecord, default_registry, set_registry
from .spans import render_timeline, span
from .telemetry import (
    EVENT_ADMIT,
    EVENT_BUDGET_FULL,
    EVENT_EVICT,
    EVENT_QUARANTINED,
    EVENT_ROW_ADMIT,
    TelemetryFrame,
    adaptive_stream_telemetry,
    fixed_stream_telemetry,
    init_telemetry,
    telemetry_summary,
)

__all__ = [
    "TelemetryFrame",
    "init_telemetry",
    "adaptive_stream_telemetry",
    "fixed_stream_telemetry",
    "telemetry_summary",
    "EVENT_ADMIT",
    "EVENT_EVICT",
    "EVENT_ROW_ADMIT",
    "EVENT_BUDGET_FULL",
    "EVENT_QUARANTINED",
    "estimate_rel_error",
    "low_rank_apply",
    "MetricsRegistry",
    "SpanRecord",
    "default_registry",
    "set_registry",
    "render_timeline",
    "span",
]
