"""Host-side metrics registry: counters / gauges / histograms + JSON-lines.

The in-scan half of observability (:mod:`repro.obs.telemetry`) lives inside
the jitted stream and is device-resident by design. This module is the
*host* half: a process-local :class:`MetricsRegistry` that benchmark
drivers, serving paths and training loops write structured metrics into,
and that dumps one JSON object per line (``dump_jsonl``) so CI can archive
it next to the ``BENCH_*.json`` artifacts.

Three instrument kinds, all keyed by a flat string name (convention:
``layer/subject_unit``, e.g. ``serve/kv_rel_err``, ``stream/admitted``):

* **counter** — monotonically increasing total (:meth:`MetricsRegistry.inc`);
* **gauge** — last-write-wins scalar (:meth:`MetricsRegistry.set_gauge`);
* **histogram** — every observation retained, summarized at dump time with
  count/mean/min/p50/p90/max (:meth:`MetricsRegistry.observe`).

The registry also collects the span records emitted by
:func:`repro.obs.spans.span` (wall-clock + nesting depth) — one shared sink
so a single ``dump_jsonl`` captures the whole run.

The module-level default registry starts **disabled**: every instrument
method is a cheap early-return, so library code can emit unconditionally
(``serve/kv_compress``'s per-call metrics, the engine's spans) without
taxing production paths. Opt in per process with ``set_registry`` or
``default_registry().enabled = True``.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Optional

import numpy as np

__all__ = [
    "MetricsRegistry",
    "SpanRecord",
    "default_registry",
    "set_registry",
]


@dataclasses.dataclass
class SpanRecord:
    """One closed :func:`repro.obs.spans.span`: wall-clock + nesting depth.

    ``start`` is seconds since the registry's epoch (its construction time),
    ``duration`` seconds of host wall-clock — dispatch time, not device time,
    unless the caller blocked on the result inside the span.
    """

    name: str
    start: float
    duration: float
    depth: int


class MetricsRegistry:
    """Process-local sink for counters, gauges, histograms and spans.

    Disabled registries (``enabled=False``) turn every write into an
    early-return, so instrumented library code costs one attribute check
    when observability is off.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.counters: dict = {}
        self.gauges: dict = {}
        self.histograms: dict = {}
        self.spans: list = []
        self.epoch = time.perf_counter()
        self._span_stack: list = []  # open span names (depth tracking)

    def inc(self, name: str, value: float = 1) -> None:
        """Add ``value`` to counter ``name`` (created at 0)."""
        if not self.enabled:
            return
        self.counters[name] = self.counters.get(name, 0) + value

    def set_gauge(self, name: str, value: float) -> None:
        """Set gauge ``name`` (last write wins)."""
        if not self.enabled:
            return
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        """Record one observation into histogram ``name``."""
        if not self.enabled:
            return
        self.histograms.setdefault(name, []).append(float(value))

    def histogram_summary(self, name: str) -> dict:
        """count/mean/min/p50/p90/max summary of histogram ``name``."""
        obs = np.asarray(self.histograms[name], np.float64)
        return {
            "count": int(obs.size),
            "mean": float(obs.mean()),
            "min": float(obs.min()),
            "p50": float(np.percentile(obs, 50)),
            "p90": float(np.percentile(obs, 90)),
            "max": float(obs.max()),
        }

    def record_stream_telemetry(self, state_or_tel, prefix: str = "stream") -> None:
        """Fold a streamed :class:`~repro.obs.telemetry.TelemetryFrame` into
        host metrics: scalar totals become counters/gauges, the per-panel
        score medians and energies become histograms (one observation per
        seen panel). One device→host transfer per array, after the stream —
        never inside it."""
        if not self.enabled:
            return
        from .telemetry import telemetry_summary

        s = telemetry_summary(state_or_tel)
        self.inc(f"{prefix}/admitted", s["total_admitted"])
        self.inc(f"{prefix}/evicted", s["total_evicted"])
        self.inc(f"{prefix}/rows_admitted", s["total_rows_admitted"])
        self.inc(f"{prefix}/panels", s["panels_seen"])
        self.set_gauge(f"{prefix}/energy_mass", s["energy_mass"])
        occ = s["occupancy"]
        if occ.size:
            self.set_gauge(f"{prefix}/final_occupancy", float(occ[-1]))
        for t in range(s["panels_seen"]):
            self.observe(f"{prefix}/panel_score_p50", float(s["score_q"][t, 2]))
            self.observe(f"{prefix}/panel_energy", float(s["panel_energy"][t]))

    def record_kv_compression(self, errs, *, ratio=None, ranks=None, prefix="serve") -> None:
        """Fold a head-batch of KV-compression quality metrics into the host
        registry with **one** device→host transfer per array: ``errs`` (any
        shape of per-head relative reconstruction errors) feeds the
        ``{prefix}/kv_rel_err`` histogram and the
        ``{prefix}/kv_heads_compressed`` counter; optional ``ratio`` (host
        scalar) sets the ``{prefix}/kv_compression_ratio`` gauge; optional
        ``ranks`` (adaptive per-head allocations) feed the
        ``{prefix}/kv_head_rank`` histogram."""
        if not self.enabled:
            return
        e = np.asarray(errs, np.float64).ravel()  # the single transfer
        for v in e:
            self.observe(f"{prefix}/kv_rel_err", float(v))
        self.inc(f"{prefix}/kv_heads_compressed", int(e.size))
        if ratio is not None:
            self.set_gauge(f"{prefix}/kv_compression_ratio", float(ratio))
        if ranks is not None:
            for r in np.asarray(ranks, np.float64).ravel():
                self.observe(f"{prefix}/kv_head_rank", float(r))

    def to_records(self) -> list:
        """Flatten the registry into dump-ready dicts (one per instrument)."""
        recs = [
            {"type": "counter", "name": k, "value": v}
            for k, v in sorted(self.counters.items())
        ]
        recs += [
            {"type": "gauge", "name": k, "value": v}
            for k, v in sorted(self.gauges.items())
        ]
        recs += [
            {"type": "histogram", "name": k, **self.histogram_summary(k)}
            for k in sorted(self.histograms)
        ]
        recs += [
            {
                "type": "span",
                "name": s.name,
                "start_s": round(s.start, 6),
                "duration_s": round(s.duration, 6),
                "depth": s.depth,
            }
            for s in self.spans
        ]
        return recs

    def dump_jsonl(self, path) -> None:
        """Write :meth:`to_records` as JSON-lines (one object per line)."""
        with open(path, "w") as fh:
            for rec in self.to_records():
                fh.write(json.dumps(rec) + "\n")


_default = MetricsRegistry(enabled=False)


def default_registry() -> MetricsRegistry:
    """The process-wide registry library code emits into (starts disabled)."""
    return _default


def set_registry(registry: Optional[MetricsRegistry]) -> MetricsRegistry:
    """Swap the process-wide registry; returns the previous one so callers
    (tests, benchmark drivers) can restore it. ``None`` installs a fresh
    disabled registry."""
    global _default
    prev = _default
    _default = registry if registry is not None else MetricsRegistry(enabled=False)
    return prev
