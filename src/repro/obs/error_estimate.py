"""In-stream a-posteriori error estimation via an independent test sketch.

**The Tropp test-sketch argument** (Tropp, Yurtsever, Udell & Cevher 2017,
§6; see PAPERS.md). Draw ``Ω_test ∈ R^{n×q}`` with iid ``N(0,1)`` entries,
*independent* of every sketch the factors are built from, and maintain

    ``Ψ = A Ω_test``

single-pass alongside the factors (one rank-``q`` panel matmul per engine
step — :func:`repro.obs.telemetry._fold_panel` does exactly this inside the
scan). For any approximation ``Â`` assembled without looking at ``Ω_test``,
the error matrix ``E = A − Â`` is independent of ``Ω_test``, and the
Gaussian identity ``E‖E Ω_test‖_F² = q·‖E‖_F²`` makes

    ``est = ‖Ψ − Â Ω_test‖_F / ‖Ψ‖_F``

an unbiased-in-square, ``O(1/√q)``-concentrated estimate of the true
relative Frobenius error ``‖A − Â‖_F / ‖A‖_F`` — both numerator and
denominator concentrate multiplicatively within ``1 ± O(1/√q)`` (a χ²_q
tail bound), so at the default ``q = 16`` the estimate sits well inside a
2× band of the truth with high probability; ``tests/test_obs.py`` checks
that band empirically on the three synthetic stream families. Crucially the
estimate needs **no second pass over A**: ``Ψ`` was accumulated in-stream
and ``Â Ω_test`` is evaluated factor-wise below.

``Â Ω_test`` is never materialized as ``Â``: for CUR factors it is
``C (U (R Ω_test))`` — three skinny matmuls — and for SPSD factors
``C (X (Cᵀ Ω_test))``.

Mid-stream semantics: for the CUR plug-ins the estimate is already
consistent before the stream ends — ``R`` (and ``Ψ``) are zero on unseen
columns, so ``est`` reports the error *over the columns seen so far*. For
the symmetric (SPSD) plug-ins ``Â = C X Cᵀ`` acts on all ``n`` rows of
``Ω_test`` while ``Ψ`` only covers seen columns, so call the estimator
after the stream has been fully consumed.

This module deliberately imports no streaming modules at top level — the
plug-ins (``stream.adaptive``, ``cur.streaming``, ``spsd.streaming``)
import :mod:`repro.obs.telemetry`, so finalizers are resolved lazily per
``ops.name`` to keep the import graph acyclic.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["estimate_rel_error", "low_rank_apply"]


def _finalizer(name: str):
    """Resolve the plug-in finalizer for ``ops.name`` (lazy imports)."""
    if name == "streaming_cur":
        from repro.cur.streaming import streaming_cur_finalize

        return streaming_cur_finalize, "cur"
    if name == "adaptive_cur":
        from repro.stream.adaptive import adaptive_cur_finalize

        return adaptive_cur_finalize, "cur"
    if name == "streaming_spsd":
        from repro.spsd.streaming import streaming_spsd_finalize

        return streaming_spsd_finalize, "spsd"
    if name == "adaptive_spsd":
        from repro.spsd.streaming import adaptive_spsd_finalize

        return adaptive_spsd_finalize, "spsd"
    raise ValueError(
        f"no estimator wiring for PanelOps {name!r} — pass apply_fn= with the "
        "factors' action V ↦ Â V"
    )


def low_rank_apply(state, V: jnp.ndarray) -> jnp.ndarray:
    """The current factors' action ``Â V`` without materializing ``Â``.

    Finalizes ``state`` (finalizers are module-scope jits that do **not**
    donate, so the state stays usable) and applies the factors skinny-first:
    ``C (U (R V))`` for CUR plug-ins, ``C (X (Cᵀ V))`` for the symmetric
    SPSD plug-ins. ``V`` is ``(n, q)`` or ``(n_pad, q)`` — padded rows are
    sliced off to match the truncated factors.
    """
    fin, kind = _finalizer(state.ops.name)
    res = fin(state)
    if kind == "cur":
        Vn = V[: res.R.shape[1]].astype(jnp.float32)
        return res.C.astype(jnp.float32) @ (
            res.U.astype(jnp.float32) @ (res.R.astype(jnp.float32) @ Vn)
        )
    Vn = V[: res.C.shape[0]].astype(jnp.float32)
    return res.C.astype(jnp.float32) @ (
        res.X.astype(jnp.float32) @ (res.C.T.astype(jnp.float32) @ Vn)
    )


def estimate_rel_error(state, *, apply_fn=None) -> jnp.ndarray:
    """Running a-posteriori relative Frobenius error of the state's factors.

    ``‖Ψ − Â Ω_test‖_F / ‖Ψ‖_F`` with ``Ψ = A Ω_test`` accumulated in-stream
    (see module docstring for the derivation and the mid-stream caveats).
    Single-pass: never touches ``A``.

    Args:
        state: a telemetered :class:`~repro.stream.engine.PanelState`
            (init with ``telemetry=True``).
        apply_fn: optional override ``(state, V) -> Â V`` for plug-ins the
            built-in :func:`low_rank_apply` dispatch doesn't know.

    Returns:
        A scalar ``float32`` estimate of ``‖A − Â‖_F / ‖A‖_F`` (over the
        seen columns, mid-stream). A zero stream (``Ψ = 0``) returns 0.
    """
    tel = state.tel
    if tel is None:
        raise ValueError(
            "estimate_rel_error needs in-stream telemetry: init the state "
            "with telemetry=True so Ψ = A·Ω_test is accumulated"
        )
    ahat_omega = (apply_fn or low_rank_apply)(state, tel.omega)
    num = jnp.linalg.norm(tel.psi - ahat_omega.astype(jnp.float32))
    den = jnp.linalg.norm(tel.psi)
    return jnp.where(den > 0, num / jnp.maximum(den, jnp.finfo(jnp.float32).tiny), 0.0)
