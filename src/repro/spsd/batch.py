"""Batch SPSD / kernel-matrix approximation (paper §4).

The batch half of the :mod:`repro.spsd` subsystem. Implements, with
identical call signatures so benchmarks can sweep them:

* :func:`nystrom`            — Williams & Seeger 2001 (conventional baseline)
* :func:`optimal_core`       — X = C† K (C†)ᵀ (the target the paper compares to)
* :func:`fast_spsd_wang`     — Wang et al. 2016b, Eqn. (4.1): one sketch S,
                               X̂ = (SC)† (S K Sᵀ) (Cᵀ Sᵀ)†
* :func:`faster_spsd`        — **Algorithm 2 (ours/paper)**: two independent
                               leverage-score sampling sketches + PSD projection,
                               observing only nc + s² kernel entries (Theorem 3)

All sampling-based paths work through a *kernel-entry oracle* so only the
entries the algorithm touches are ever computed — the paper's headline
query-complexity win. ``entries_observed`` is reported for Table-4-style
accounting.

The leverage-sampling sketches are :class:`repro.core.sketching.RowSampling`
operators (:func:`leverage_sampling_sketches`), shared verbatim with the
single-pass streaming path (:mod:`repro.spsd.streaming`) so streamed and
batch results are comparable on identical randomness; ``faster_spsd`` and
``optimal_core`` accept ``col_idx``/``sketches`` injection for exactly that
purpose (and for ``repro.cur.symmetric_cur``'s policy-driven column
selection).

These APIs remain re-exported unchanged from :mod:`repro.core` (via the
``repro.core.spsd`` compatibility shim) — existing callers are unaffected
by the ``repro/spsd/`` layering.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.gmr import _solve_least_squares, fast_gmr_core
from ..core.leverage import leverage_scores
from ..core.projections import psd_project
from ..core.sketching import RowSampling

__all__ = [
    "rbf_kernel_oracle",
    "matrix_oracle",
    "KernelOracle",
    "SPSDResult",
    "leverage_sampling_sketches",
    "nystrom",
    "optimal_core",
    "fast_spsd_wang",
    "faster_spsd",
    "spsd_error_ratio",
]

# A kernel oracle maps (row_idx | None, col_idx | None) -> K[rows][:, cols].
KernelOracle = Callable[[Optional[jax.Array], Optional[jax.Array]], jax.Array]


def rbf_kernel_oracle(X: jax.Array, sigma: float) -> KernelOracle:
    """RBF oracle over data ``X (n, d)``: K_ij = exp(−σ ||xᵢ − xⱼ||²) (§6.2)."""

    def oracle(rows, cols):
        Xr = X if rows is None else jnp.take(X, rows, axis=0)
        Xc = X if cols is None else jnp.take(X, cols, axis=0)
        sq = (
            jnp.sum(Xr * Xr, axis=1)[:, None]
            - 2.0 * (Xr @ Xc.T)
            + jnp.sum(Xc * Xc, axis=1)[None, :]
        )
        return jnp.exp(-sigma * jnp.maximum(sq, 0.0))

    return oracle


def matrix_oracle(K: jax.Array) -> KernelOracle:
    """Entry oracle over an already-materialized SPSD matrix ``K (n, n)``.

    Lets the oracle-bound batch paths (and ``repro.cur.symmetric_cur``) run
    on dense matrices; ``entries_observed`` then counts the entries the
    algorithm *would* have queried, preserving the Theorem-3 accounting.
    """

    def oracle(rows, cols):
        Kr = K if rows is None else jnp.take(K, rows, axis=0)
        return Kr if cols is None else jnp.take(Kr, cols, axis=1)

    return oracle


@dataclasses.dataclass
class SPSDResult:
    """Column matrix C, core X (K ≈ C X Cᵀ), and the entry-observation count.

    Registered as a pytree (``entries_observed`` is static metadata) so the
    streaming finalizers can return it from jitted code.
    """

    C: jax.Array
    X: jax.Array
    col_idx: jax.Array
    entries_observed: int


jax.tree_util.register_dataclass(
    SPSDResult, data_fields=["C", "X", "col_idx"], meta_fields=["entries_observed"]
)


def _validate_sizes(n: int, c: int, s: Optional[int] = None) -> None:
    """Clear errors for impossible sample sizes (matching repro.cur.selection).

    ``c`` columns are drawn *without* replacement, so ``0 < c ≤ n`` is a hard
    requirement — ``jax.random.choice(replace=False)`` otherwise fails with
    an opaque shape error deep in the sampler. The ``s`` sketch rows are
    drawn *with* replacement (Table 3), so ``s > n`` is legal; only ``s ≤ 0``
    is rejected.
    """
    if not 0 < c <= n:
        raise ValueError(f"need 0 < c <= n sampled columns, got c={c}, n={n}")
    if s is not None and s <= 0:
        raise ValueError(f"need s > 0 sketch rows, got s={s} (n={n})")


def _uniform_columns(key, n: int, c: int) -> jax.Array:
    return jax.random.choice(key, n, (c,), replace=False)


def _resolve_columns(key, oracle: KernelOracle, n: int, c: int, col_idx):
    """Uniform column draw, or the caller's explicit (policy-driven) indices."""
    if col_idx is None:
        col_idx = _uniform_columns(key, n, c)
    else:
        col_idx = jnp.asarray(col_idx, jnp.int32)
        if col_idx.shape[0] != c:
            raise ValueError(f"col_idx has {col_idx.shape[0]} entries, expected c={c}")
    return col_idx, oracle(None, col_idx)


def _leverage_pair(k1, k2, C: jax.Array, s: int) -> Tuple[RowSampling, RowSampling]:
    probs = leverage_scores(C)
    probs = probs / jnp.sum(probs)
    n = C.shape[0]
    return (
        RowSampling.draw(k1, s, n, probs=probs, dtype=jnp.float32),
        RowSampling.draw(k2, s, n, probs=probs, dtype=jnp.float32),
    )


def leverage_sampling_sketches(key, C: jax.Array, s: int) -> Tuple[RowSampling, RowSampling]:
    """Algorithm 2 steps 2–3: two *independent* ``(s, n)`` leverage-score
    sampling sketches w.r.t. ``range(C)``.

    Returned as :class:`repro.core.sketching.RowSampling` operators so the
    identical pair can drive both the batch solve (:func:`faster_spsd`
    ``sketches=``) and the single-pass streaming solve
    (:func:`repro.spsd.streaming.streaming_spsd_init` ``sketches=``) —
    the parity contract tested in ``tests/test_spsd_stream.py``.
    """
    k1, k2 = jax.random.split(key)
    return _leverage_pair(k1, k2, C, s)


def _sampled_block(oracle: KernelOracle, S1: RowSampling, S2: RowSampling) -> jax.Array:
    """``S₁ K S₂ᵀ`` via s² oracle entries (sampling sketches only)."""
    return oracle(S1.idx, S2.idx) * (S1.scale[:, None] * S2.scale[None, :])


def _require_sampling(sketches) -> Tuple[RowSampling, RowSampling]:
    S1, S2 = sketches
    if not (isinstance(S1, RowSampling) and isinstance(S2, RowSampling)):
        raise TypeError(
            "batch SPSD sketch injection requires RowSampling operators — the "
            "entry-oracle contract needs explicit sampled indices (S K Sᵀ must "
            "cost s² entries, not n²)"
        )
    return S1, S2


def nystrom(key, oracle: KernelOracle, n: int, c: int) -> SPSDResult:
    """Conventional Nyström: X = W† with W the c×c intersection block."""
    _validate_sizes(n, c)
    idx = _uniform_columns(key, n, c)
    C = oracle(None, idx)  # (n, c)
    W = jnp.take(C, idx, axis=0)  # (c, c) — already-observed entries
    dt = jnp.promote_types(C.dtype, jnp.float32)
    X = jnp.linalg.pinv(W.astype(dt), rtol=1e-6).astype(C.dtype)
    return SPSDResult(C=C, X=X, col_idx=idx, entries_observed=n * c)


def optimal_core(
    key, oracle: KernelOracle, n: int, c: int, *, col_idx=None
) -> SPSDResult:
    """X = C† K (C†)ᵀ — requires observing all n² entries (the upper bound).

    ``col_idx`` overrides the uniform column draw (policy-driven selection,
    e.g. ``repro.cur.symmetric_cur``).
    """
    _validate_sizes(n, c)
    idx, C = _resolve_columns(key, oracle, n, c, col_idx)
    K = oracle(None, None)
    left = _solve_least_squares(C, K)  # C† K
    X = _solve_least_squares(C, left.T).T  # C† K (C†)ᵀ
    return SPSDResult(C=C, X=psd_project(X), col_idx=idx, entries_observed=n * n)


def fast_spsd_wang(key, oracle: KernelOracle, n: int, c: int, s: int) -> SPSDResult:
    """Wang et al. 2016b (Eqn. 4.1): single leverage-score sampling sketch S.

    X̂ = (SC)† (S K Sᵀ) (Cᵀ Sᵀ)† — symmetric by construction, but needs
    s = O(c√(n/ε)) for the (1+ε) bound (Table 4), i.e. O(nc²/ε) entries.
    """
    _validate_sizes(n, c, s)
    k_col, k_s = jax.random.split(key)
    idx = _uniform_columns(k_col, n, c)
    C = oracle(None, idx)
    probs = leverage_scores(C)
    S = RowSampling.draw(k_s, s, n, probs=probs / jnp.sum(probs), dtype=jnp.float32)
    SC = S.apply(C)
    SKS = _sampled_block(oracle, S, S)
    X = fast_gmr_core(SC, SKS, SC.T)
    return SPSDResult(
        C=C, X=psd_project(X), col_idx=idx, entries_observed=n * c + s * s
    )


def faster_spsd(
    key,
    oracle: KernelOracle,
    n: int,
    c: int,
    s: int,
    *,
    col_idx=None,
    sketches: Optional[Tuple[RowSampling, RowSampling]] = None,
) -> SPSDResult:
    """**Algorithm 2** — the paper's faster SPSD approximation.

    1. uniform-sample c columns → C (nc entries);
    2. leverage scores of C;
    3. two *independent* leverage-sampling sketches S₁, S₂ (s×n);
    4. X̃ = (S₁C)† (S₁ K S₂ᵀ) (Cᵀ S₂ᵀ)†  — only s² extra entries;
    5. X̃₊ = Π_PSD(X̃)  (Theorem 2 keeps the (1+ε) bound after projection).

    ``col_idx`` overrides step 1 (policy-driven selection —
    ``repro.cur.symmetric_cur`` routes every ``repro.cur.selection`` policy
    through here); ``sketches=(S₁, S₂)`` overrides steps 2–3 with pre-drawn
    :class:`~repro.core.sketching.RowSampling` operators (shared randomness
    with :mod:`repro.spsd.streaming` for the batch↔streaming parity tests).
    """
    _validate_sizes(n, c, s)
    k_col, k_s1, k_s2 = jax.random.split(key, 3)
    idx, C = _resolve_columns(k_col, oracle, n, c, col_idx)
    if sketches is None:
        S1, S2 = _leverage_pair(k_s1, k_s2, C, s)
    else:
        S1, S2 = _require_sampling(sketches)

    S1C = S1.apply(C)  # (s, c) — rows of already-observed C, rescaled
    CS2 = S2.apply(C).T  # (c, s)
    S1KS2 = _sampled_block(oracle, S1, S2)  # s² fresh entries

    X = fast_gmr_core(S1C, S1KS2, CS2)
    return SPSDResult(
        C=C, X=psd_project(X), col_idx=idx, entries_observed=n * c + s * s
    )


def spsd_error_ratio(K: jax.Array, res: SPSDResult) -> jax.Array:
    """§6.2 metric: ||K − C X Cᵀ||_F / ||K||_F."""
    dt = jnp.promote_types(K.dtype, jnp.float32)
    approx = (res.C @ res.X @ res.C.T).astype(dt)
    return jnp.linalg.norm(K.astype(dt) - approx) / jnp.linalg.norm(K.astype(dt))
