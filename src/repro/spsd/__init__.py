"""SPSD / kernel-matrix approximation subsystem (paper §4, Algorithm 2).

Layered like the sibling :mod:`repro.cur` subsystem:

* :mod:`repro.spsd.batch`     — the oracle-bound batch paths: Nyström,
  optimal core, fast-SPSD (Wang et al. 2016b) and **Algorithm 2**
  (``faster_spsd``), plus the shared leverage-sampling sketch construction
  and the entry-observation accounting (Theorem 3).
* :mod:`repro.spsd.streaming` — single-pass SPSD over column panels of
  ``K`` through the **symmetric (tied-operand)** mode of the
  :mod:`repro.stream` engine (``R = Cᵀ`` derived, no row accumulator),
  with fixed or adaptively-admitted kernel columns and DP-sharded
  ingestion for free.

Symmetric CUR — the same ``K ≈ C X Cᵀ`` factorization driven by the
:mod:`repro.cur.selection` policies — lives in
``repro.cur.symmetric_cur`` and delegates its core solve here.

The batch APIs remain re-exported unchanged from :mod:`repro.core`
(``from repro.core import faster_spsd`` keeps working).
"""

from .batch import (
    KernelOracle,
    SPSDResult,
    fast_spsd_wang,
    faster_spsd,
    leverage_sampling_sketches,
    matrix_oracle,
    nystrom,
    optimal_core,
    rbf_kernel_oracle,
    spsd_error_ratio,
)
from .streaming import (
    ADAPTIVE_SPSD_OPS,
    STREAMING_SPSD_OPS,
    SPSDStreamCtx,
    adaptive_spsd_finalize,
    adaptive_spsd_init,
    streaming_spsd_finalize,
    streaming_spsd_init,
)

__all__ = [
    "KernelOracle", "SPSDResult", "fast_spsd_wang", "faster_spsd",
    "leverage_sampling_sketches", "matrix_oracle", "nystrom", "optimal_core",
    "rbf_kernel_oracle", "spsd_error_ratio",
    "ADAPTIVE_SPSD_OPS", "STREAMING_SPSD_OPS", "SPSDStreamCtx",
    "adaptive_spsd_finalize", "adaptive_spsd_init",
    "streaming_spsd_finalize", "streaming_spsd_init",
]
