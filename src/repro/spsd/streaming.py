"""Single-pass streaming SPSD approximation (Algorithm 2 over a kernel
*stream*), as a symmetric plug-in of the panel engine.

The batch path (:mod:`repro.spsd.batch`) assumes an entry oracle it can
query at will. At serving scale the kernel often arrives instead as column
panels ``K_L`` that are produced once and never retained — exactly the
streaming contract of :mod:`repro.stream.engine`, with one structural
difference: the operand is **symmetric**, so the row factor is *tied* to
the column factor (``R = Cᵀ``) and accumulating it would be redundant.
This module plugs SPSD into the engine's ``symmetric=True`` mode:

* ``C``: the selected kernel columns land in their slots as their panels
  stream by (fixed ``col_idx``), or are *admitted in-stream* by the
  adaptive residual-scoring policy of :mod:`repro.stream.adaptive` applied
  to kernel columns (:func:`adaptive_spsd_init` — same fused
  ``sketch_panel`` scoring, admission/eviction knobs and disjoint-slot
  sharding hooks, reused verbatim with ``rows=None``);
* ``M += S₁ K_L S₂[:, cols]ᵀ`` — the engine's shared core-sketch update;
  both sketches live on the same n-dimensional index space (one family,
  two independent draws — Algorithm 2 requires ``S₁ ⊥ S₂``);
* no R half at all: the engine skips it, and ``truncated_R`` derives
  ``R = Cᵀ``.

Finalize solves ``X̃ = (S₁C)† M (Cᵀ S₂ᵀ)†`` and projects onto the PSD cone
(Theorem 2), returning the same :class:`~repro.spsd.batch.SPSDResult`
contract as the batch paths. With the *same* ``col_idx`` and the same
:class:`~repro.core.sketching.RowSampling` pair
(:func:`repro.spsd.batch.leverage_sampling_sketches`), the streamed result
matches batch :func:`~repro.spsd.batch.faster_spsd` exactly up to fp32
order — each ``M`` entry receives exactly one nonzero panel contribution —
the parity contract of ``tests/test_spsd_stream.py``, which holds under
DP-sharded ingestion too (:mod:`repro.stream.distributed`; tied-operand
states shard with one psum and a mirrored merge, no R traffic).

Memory: C (n·c) + M (s²) — the stream itself is never retained. Every
kernel entry flows through the update once, so ``entries_observed`` is n²
by construction; the streaming win is *memory and passes*, not queries.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.gmr import fast_gmr_core
from ..core.projections import psd_project
from ..core.sketching import draw_sketch
from ..obs.telemetry import (
    adaptive_stream_telemetry,
    fixed_stream_telemetry,
    init_telemetry,
)
from ..stream.adaptive import (
    AdaptiveCURCtx,
    _bind_shard,
    _collective_ctx,
    _chunk_fold,
    _core_sketches,
    _fused_step,
    _merge_ctx,
    _panel_kernel,
    _prep_shard,
    _sketch_panel,
    _supports_fused,
    _update_c,
)
from ..stream.engine import (
    PanelOps,
    PanelState,
    copy_selected_columns,
    fresh_pytree,
    padded_n,
)
from .batch import SPSDResult

__all__ = [
    "SPSDStreamCtx",
    "STREAMING_SPSD_OPS",
    "STREAMING_SPSD_TEL_OPS",
    "ADAPTIVE_SPSD_OPS",
    "ADAPTIVE_SPSD_TEL_OPS",
    "streaming_spsd_init",
    "streaming_spsd_finalize",
    "adaptive_spsd_init",
    "adaptive_spsd_finalize",
]


@dataclasses.dataclass(frozen=True)
class SPSDStreamCtx:
    """Fixed column selection + the tied-operand core sketch pair.

    Both sketches are (s, n) operators over the *same* index space (the
    stream is square); ``S2`` is the column-sliceable one driving the
    ``M`` window updates and is padded to ``n_pad`` at init.
    """

    col_idx: jax.Array  # (c,)
    S1: object  # (s, n) left core sketch
    S2: object  # (s, n_pad) right core sketch (column-sliceable)


jax.tree_util.register_dataclass(
    SPSDStreamCtx, data_fields=["col_idx", "S1", "S2"], meta_fields=[]
)


def _spsd_core_sketches(ctx: SPSDStreamCtx):
    return ctx.S1, ctx.S2


def _spsd_update_c(ctx: SPSDStreamCtx, C, K_L, sc_a, off):
    # selected kernel columns that live in this panel → their C slots
    return ctx, copy_selected_columns(ctx.col_idx, C, K_L, off)


def _spsd_chunk_fold(ctx: SPSDStreamCtx, C, R, block, bcol0, start, width):
    """Fused-scan hook: the whole chunk's fixed-index C copies in one gather
    (the symmetric half of :func:`repro.cur.streaming._cur_chunk_fold` — no
    R side, ``R = Cᵀ`` is derived)."""
    rel = ctx.col_idx - start
    in_chunk = (rel >= 0) & (rel < width)
    picked = jnp.take(block, bcol0 + jnp.clip(rel, 0, width - 1), axis=1)
    C = jnp.where(in_chunk[None, :], picked.astype(C.dtype), C)
    return ctx, C, R


STREAMING_SPSD_OPS = PanelOps(
    name="streaming_spsd",
    core_sketches=_spsd_core_sketches,
    update_c=_spsd_update_c,
    chunk_fold=_spsd_chunk_fold,
    symmetric=True,
)


# Adaptive in-stream column admission over kernel columns: the column half
# of the adaptive-CUR policy applies verbatim (scores are computed from the
# sketches alone; ``rows=None`` disables the row machinery), with the
# symmetric engine skipping the R half. The disjoint-slot sharding hooks —
# and both fused routes (the hoisted-sketch scan body and the Route-B
# panel-update megakernel; the (0,)-row ``row_idx`` makes the R stripe of
# the shared ``_chunk_fold`` a no-op) — come along for free.
ADAPTIVE_SPSD_OPS = PanelOps(
    name="adaptive_spsd",
    core_sketches=_core_sketches,
    sketch_panel=_sketch_panel,
    update_c=_update_c,
    prep_shard=_prep_shard,
    bind_shard=_bind_shard,
    merge_ctx=_merge_ctx,
    collective_ctx=_collective_ctx,
    chunk_fold=_chunk_fold,
    fused_step=_fused_step,
    supports_fused=_supports_fused,
    panel_kernel=_panel_kernel,
    symmetric=True,
)

# Telemetered twins — same hooks plus the per-panel diagnostics folds; one
# module-level instance each so telemetered inits share jit caches.
STREAMING_SPSD_TEL_OPS = dataclasses.replace(
    STREAMING_SPSD_OPS, telemetry=fixed_stream_telemetry
)
ADAPTIVE_SPSD_TEL_OPS = dataclasses.replace(
    ADAPTIVE_SPSD_OPS, telemetry=adaptive_stream_telemetry
)


def _draw_pair(key, sketch: str, s: int, n: int, osnap_p: int, dtype):
    k1, k2 = jax.random.split(key)
    S1 = draw_sketch(k1, sketch, s, n, p=osnap_p, dtype=dtype)
    S2 = draw_sketch(k2, sketch, s, n, p=osnap_p, dtype=dtype)
    return S1, S2


def _resolve_sketch_pair(key, n, c, s, sketch, osnap_p, dtype, sketches, panel):
    """Shared init plumbing for both streaming-SPSD variants: validate the
    budget sizes (matching the batch paths' ``_validate_sizes`` convention),
    draw or donation-copy the ``(S₁, S₂)`` pair, fail fast on
    non-sliceable families, and pad ``S₂`` to the panel-aligned width.

    Returns ``(S1, S2_padded, n_pad)``.
    """
    if not 0 < c <= n:
        raise ValueError(f"need 0 < c <= n column slots, got c={c}, n={n}")
    if sketches is None:
        if s is not None and s <= 0:
            raise ValueError(f"need s > 0 sketch rows, got s={s} (n={n})")
        s = min(s or 10 * c, n)
        S1, S2 = _draw_pair(key, sketch, s, n, osnap_p, dtype)
    else:
        S1, S2 = fresh_pytree(sketches)  # donation-safe copies
    S2.cols(0, 1)  # fail fast on non-sliceable families (srht)
    n_pad = padded_n(n, panel) if panel else n
    return S1, S2.pad_cols(n_pad), n_pad


def _maybe_telemetry(telemetry: bool, key, n: int, panel, base_ops, tel_ops):
    """Shared telemetry plumbing for the SPSD inits: allocate the diagnostics
    frame (``m = n`` — the stream is square) on an estimator key folded off
    the init key, and swap in the telemetered ops twin."""
    if not telemetry:
        return None, base_ops
    if panel is None:
        raise ValueError(
            "telemetry=True requires a fixed panel= width (the diagnostics "
            "frame is indexed by global panel id)"
        )
    return init_telemetry(jax.random.fold_in(key, 7), n, n, panel), tel_ops


def streaming_spsd_init(
    key,
    n: int,
    col_idx: jax.Array,
    *,
    s: Optional[int] = None,
    sketch: str = "countsketch",
    osnap_p: int = 2,
    dtype=jnp.float32,
    sketches: Optional[Tuple] = None,
    panel: Optional[int] = None,
    telemetry: bool = False,
) -> PanelState:
    """Allocate a fixed-index streaming-SPSD state (symmetric engine plug-in).

    Args:
        key: PRNG key for the core sketch pair (ignored when ``sketches``
            given).
        n: stream size — ``K`` is (n, n), arriving as column panels.
        col_idx: selected kernel columns, (c,) int32 (uniform pre-pass, or
            any :func:`repro.cur.select_columns` policy via a prior
            epoch / sketch — see ``repro.cur.symmetric_cur`` for the batch
            equivalent).
        s: core sketch size; defaults to the paper's §6.2 "≈ optimal"
            operating point ``min(10·c, n)``.
        sketch: sketch family for both draws (``countsketch`` / ``osnap`` /
            ``gaussian``; any column-sliceable family).
        osnap_p: nonzeros per column for the OSNAP family.
        dtype: accumulator dtype.
        sketches: optional pre-drawn ``(S₁, S₂)`` pair — e.g. the
            leverage-sampling pair of
            :func:`repro.spsd.batch.leverage_sampling_sketches` for exact
            batch parity.
        panel: fixed streaming panel width — pre-pads ``S₂`` so ragged
            tails are zero-padded exactly (see :mod:`repro.stream.engine`).
        telemetry: attach an in-scan diagnostics frame + the a-posteriori
            error estimator's test sketch (:func:`repro.obs.estimate_rel_error`
            — call it after the stream is fully consumed; the symmetric
            ``C X Cᵀ`` acts on all rows, so the mid-stream estimate is
            biased). Requires ``panel=``; factors are bit-identical with it
            on or off.

    Returns:
        A :class:`~repro.stream.engine.PanelState` wired to
        :data:`STREAMING_SPSD_OPS` (note the ``(0, n_pad)`` R placeholder —
        R is derived as ``Cᵀ``). Drive it with ``stream_panels`` /
        ``simulate_sharded_stream`` / ``mesh_sharded_stream`` and finish
        with :func:`streaming_spsd_finalize`.
    """
    # Copy, not view: the scan path donates the state's buffers.
    col_idx = jnp.array(col_idx, jnp.int32)
    c = col_idx.shape[0]
    if c and not (0 <= int(jnp.min(col_idx)) and int(jnp.max(col_idx)) < n):
        raise ValueError(
            f"col_idx entries must lie in [0, {n}), got range "
            f"[{int(jnp.min(col_idx))}, {int(jnp.max(col_idx))}] — an "
            "out-of-range index would leave its C slot permanently zero"
        )
    S1, S2, n_pad = _resolve_sketch_pair(
        key, n, c, s, sketch, osnap_p, dtype, sketches, panel
    )
    ctx = SPSDStreamCtx(col_idx=col_idx, S1=S1, S2=S2)
    tel, ops = _maybe_telemetry(telemetry, key, n, panel, STREAMING_SPSD_OPS,
                                STREAMING_SPSD_TEL_OPS)
    return PanelState(
        C=jnp.zeros((n, c), dtype),
        R=jnp.zeros((0, n_pad), dtype),  # tied operand: R = Cᵀ is derived
        M=jnp.zeros((S1.s, S2.s), dtype),
        offset=jnp.zeros((), jnp.int32),
        ctx=ctx,
        ops=ops,
        n=n,
        tel=tel,
    )


def streaming_spsd_finalize(state: PanelState) -> SPSDResult:
    """Algorithm 2 core solve on the streamed pieces + PSD projection.

    ``X̃ = (S₁C)† M (Cᵀ S₂ᵀ)†`` with ``M = S₁ K S₂ᵀ`` accumulated panel by
    panel; matches batch :func:`repro.spsd.batch.faster_spsd` exactly (up
    to fp32 order) on identical ``col_idx``/``sketches``.
    ``entries_observed`` is n² — every kernel entry flowed through the
    stream once (the streaming win is memory and single-pass access, not
    query count).
    """
    ctx = state.ctx
    S1C = ctx.S1.apply(state.C)  # (s, c)
    CS2 = ctx.S2.apply(state.C).T  # (c, s)
    X = psd_project(fast_gmr_core(S1C, state.M, CS2))
    return SPSDResult(
        C=state.C, X=X, col_idx=ctx.col_idx, entries_observed=state.n * state.n
    )


def adaptive_spsd_init(
    key,
    n: int,
    c: int,
    *,
    s: Optional[int] = None,
    sketch: str = "countsketch",
    osnap_p: int = 2,
    min_gain: float = 2.0,
    panel_cap: Optional[int] = None,
    swap_gain: Optional[float] = None,
    dtype=jnp.float32,
    sketches: Optional[Tuple] = None,
    panel: Optional[int] = None,
    telemetry: bool = False,
) -> PanelState:
    """Adaptive streaming SPSD: kernel columns are *admitted in-stream*.

    Reuses the residual-scoring column policy of
    :mod:`repro.stream.adaptive` (fused ``sketch_panel`` scoring,
    ``min_gain`` admission, optional ``swap_gain`` eviction, per-worker
    disjoint slot ranges under sharding) on the symmetric engine — the row
    machinery is off (``rows=None``) because ``R = Cᵀ`` is derived.

    Args mirror :func:`repro.stream.adaptive.adaptive_cur_init` (columns
    only); ``s`` defaults to ``min(10·c, n)`` as in
    :func:`streaming_spsd_init`. Finish with
    :func:`adaptive_spsd_finalize`.
    """
    S1, S2, n_pad = _resolve_sketch_pair(
        key, n, c, s, sketch, osnap_p, dtype, sketches, panel
    )
    ctx = AdaptiveCURCtx(
        col_idx=jnp.full((c,), -1, jnp.int32),
        row_idx=jnp.zeros((0,), jnp.int32),  # tied operand: no row budget
        S_C=S1,
        S_R=S2,
        ScC=jnp.zeros((S1.s, c), dtype),
        slot_score=jnp.zeros((c,), jnp.float32),
        n_filled=jnp.zeros((), jnp.int32),
        slot_lo=jnp.zeros((), jnp.int32),
        energy=jnp.zeros((), jnp.float32),
        cols_seen=jnp.zeros((), jnp.float32),
        min_gain=jnp.asarray(min_gain, jnp.float32),
        swap_gain=jnp.asarray(jnp.inf if swap_gain is None else swap_gain, jnp.float32),
        n_evicted=jnp.zeros((), jnp.int32),
        rows=None,
        c_local=c,
        panel_cap=panel_cap if panel_cap is not None else max(1, c // 8),
        n=n,
        evict=swap_gain is not None,
    )
    tel, ops = _maybe_telemetry(telemetry, key, n, panel, ADAPTIVE_SPSD_OPS,
                                ADAPTIVE_SPSD_TEL_OPS)
    return PanelState(
        C=jnp.zeros((n, c), dtype),
        R=jnp.zeros((0, n_pad), dtype),  # tied operand: R = Cᵀ is derived
        M=jnp.zeros((S1.s, S2.s), dtype),
        offset=jnp.zeros((), jnp.int32),
        ctx=ctx,
        ops=ops,
        n=n,
        tel=tel,
    )


def adaptive_spsd_finalize(state: PanelState) -> SPSDResult:
    """Core solve on the admitted kernel columns + PSD projection.

    Unfilled slots (zero C columns) get their core rows *and* columns
    zeroed before the projection, so the floored solve's finite garbage
    cannot leak into ``C X Cᵀ`` (zeroing a symmetric row/col pair of a PSD
    matrix keeps it PSD, and zero C columns contribute nothing either way).
    """
    ctx = state.ctx
    CS2 = ctx.S_R.apply(state.C).T  # (c, s)
    X = fast_gmr_core(ctx.ScC, state.M, CS2)  # ScC ≡ S₁ C by construction
    filled = ctx.col_idx >= 0
    X = jnp.where(filled[:, None] & filled[None, :], X, jnp.zeros((), X.dtype))
    return SPSDResult(
        C=state.C,
        X=psd_project(X),
        col_idx=ctx.col_idx,
        entries_observed=state.n * state.n,
    )


# Compiled at module scope (one trace per shape); states are NOT donated —
# callers inspect them (col_idx, n_evicted, …) after finalizing.
streaming_spsd_finalize = jax.jit(streaming_spsd_finalize)
adaptive_spsd_finalize = jax.jit(adaptive_spsd_finalize)
