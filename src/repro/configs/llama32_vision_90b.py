"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, cross-attn image layers (hf:meta-llama/Llama-3.2-11B-Vision
family scaled to 90B).

100 layers = 20 × [4 self-attn + 1 cross-attn]. The ViT tower is a STUB:
``input_specs`` provide precomputed patch embeddings (B, n_patches=2048,
d_vision=1280) which a learned projector lifts to d_model; cross layers
are tanh-gated (gate init 0) as in the reference model.
"""

from repro.models.config import ATTN, CROSS, DENSE, BlockSpec, ModelConfig
from .base import FULL_ATTN_SHAPES

ARCH_ID = "llama-3.2-vision-90b"
SUPPORTED_SHAPES = FULL_ATTN_SHAPES


def _pattern(n_units: int, self_per_unit: int = 4):
    unit = [BlockSpec(ATTN, DENSE)] * self_per_unit + [BlockSpec(CROSS, DENSE)]
    return tuple(unit * n_units)


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        n_layers=100,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=128256,
        pattern=_pattern(20),
        rope_theta=5e5,
        d_vision=1280,
        n_patches=2048,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="vlm",
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        pattern=_pattern(1),
        d_vision=32,
        n_patches=16,
        dtype="float32",
    )
