"""phi4-mini-3.8b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064 (arXiv:2412.08905). RoPE + SwiGLU + GQA, head_dim 128.
"""

from repro.models.config import ATTN, DENSE, ModelConfig
from .base import FULL_ATTN_SHAPES, uniform_pattern

ARCH_ID = "phi4-mini-3.8b"
SUPPORTED_SHAPES = FULL_ATTN_SHAPES


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=200064,
        pattern=uniform_pattern(32, ATTN),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=3,
        d_model=48,
        n_heads=6,
        n_kv_heads=2,
        head_dim=8,
        d_ff=96,
        vocab_size=256,
        pattern=uniform_pattern(3, ATTN),
        dtype="float32",
    )
