"""Config substrate: assigned input shapes + registry helpers.

Each arch module defines ``full_config()`` (exact assignment numbers),
``smoke_config()`` (reduced same-family config for CPU tests), and
``SUPPORTED_SHAPES``. The four assigned LM shape cells:

  train_4k     seq=4096    global_batch=256   (train_step)
  prefill_32k  seq=32768   global_batch=32    (prefill)
  decode_32k   seq=32768   global_batch=128   (serve_step: 1 token vs cache)
  long_500k    seq=524288  global_batch=1     (serve_step; sub-quadratic only)
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.models.config import (
    ATTN,
    ATTN_LOCAL,
    CROSS,
    DENSE,
    MAMBA2,
    MLA,
    MOE,
    NONE,
    SHARED_ATTN,
    BlockSpec,
    ModelConfig,
)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

ALL_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
# pure full-attention archs skip long_500k (assignment rule; see DESIGN.md §Arch-applicability)
FULL_ATTN_SHAPES = ("train_4k", "prefill_32k", "decode_32k")


def uniform_pattern(n: int, mixer: str, ffn: str = DENSE) -> Tuple[BlockSpec, ...]:
    return tuple(BlockSpec(mixer, ffn) for _ in range(n))
