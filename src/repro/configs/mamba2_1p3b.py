"""mamba2-1.3b [ssm] — 48L d_model=2048 (attn-free) vocab=50280, ssm_state=128.

SSD (state-space duality), arXiv:2405.21060. d_inner = 2·d_model = 4096,
64 heads of dim 64, 1 B/C group, chunk 256 (the reference Mamba-2 1.3b
hyper-parameters).
"""

from repro.models.config import MAMBA2, NONE, ModelConfig
from .base import ALL_SHAPES, uniform_pattern

ARCH_ID = "mamba2-1.3b"
SUPPORTED_SHAPES = ALL_SHAPES  # SSM decode is O(1)-state → long_500k runs


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        pattern=uniform_pattern(48, MAMBA2, NONE),
        ssm_state=128,
        ssm_heads=64,
        ssm_head_dim=64,
        ssm_groups=1,
        ssm_expand=2,
        ssm_chunk=256,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="ssm",
        n_layers=4,
        d_model=64,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=256,
        pattern=uniform_pattern(4, MAMBA2, NONE),
        ssm_state=16,
        ssm_heads=4,
        ssm_head_dim=32,
        ssm_groups=1,
        ssm_expand=2,
        ssm_chunk=16,
        dtype="float32",
    )
