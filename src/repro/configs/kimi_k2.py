"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048
vocab=163840, MoE 384 experts top-8 (+1 shared) — trillion-param MoE.

Layer 0 is dense (d_ff 18432, the DeepSeek-V3-lineage warmup layer);
layers 1–60 are MoE. head_dim=128 → 8192 attention width.
"""

from repro.models.config import ATTN, DENSE, MOE, BlockSpec, ModelConfig
from .base import FULL_ATTN_SHAPES

ARCH_ID = "kimi-k2-1t-a32b"
SUPPORTED_SHAPES = FULL_ATTN_SHAPES  # pure full attention → long_500k skipped


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=18432,  # dense warmup layer
        vocab_size=163840,
        pattern=(BlockSpec(ATTN, DENSE),) + tuple(BlockSpec(ATTN, MOE) for _ in range(60)),
        n_experts=384,
        n_shared_experts=1,
        moe_top_k=8,
        d_ff_expert=2048,
        rope_theta=5e4,
        moe_dispatch_shards=16,  # §Perf B5: dispatch local per data rank
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        pattern=(BlockSpec(ATTN, DENSE),) + tuple(BlockSpec(ATTN, MOE) for _ in range(2)),
        n_experts=8,
        n_shared_experts=1,
        moe_top_k=2,
        d_ff_expert=32,
        dtype="float32",
    )
