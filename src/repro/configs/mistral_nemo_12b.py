"""mistral-nemo-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072, 128k ctx (hf:mistralai/Mistral-Nemo-Base-2407). head_dim 128,
rope θ=1M.
"""

from repro.models.config import ATTN, DENSE, ModelConfig
from .base import FULL_ATTN_SHAPES, uniform_pattern

ARCH_ID = "mistral-nemo-12b"
SUPPORTED_SHAPES = FULL_ATTN_SHAPES


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        pattern=uniform_pattern(40, ATTN),
        rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        pattern=uniform_pattern(3, ATTN),
        dtype="float32",
    )
