"""musicgen-large [audio] — 48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048, decoder-only over EnCodec tokens (arXiv:2306.05284).

Backbone only per the assignment: the EnCodec codec is a STUB; inputs are
codec token ids over the 2048-entry vocabulary
(repro.models.modality.synth_audio_tokens). head_dim 64, GELU FFN (the
MusicGen transformer uses non-gated GELU MLPs).
"""

from repro.models.config import ATTN, DENSE, ModelConfig
from .base import FULL_ATTN_SHAPES, uniform_pattern

ARCH_ID = "musicgen-large"
SUPPORTED_SHAPES = FULL_ATTN_SHAPES


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=2048,
        pattern=uniform_pattern(48, ATTN),
        activation="gelu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="audio",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=64,
        pattern=uniform_pattern(3, ATTN),
        activation="gelu",
        dtype="float32",
    )
