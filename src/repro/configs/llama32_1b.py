"""llama3.2-1b [dense] — 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256 (hf:meta-llama/Llama-3.2-1B). head_dim 64, tied embeddings,
rope_theta 500k.
"""

from repro.models.config import ATTN, DENSE, ModelConfig
from .base import FULL_ATTN_SHAPES, uniform_pattern

ARCH_ID = "llama3.2-1b"
SUPPORTED_SHAPES = FULL_ATTN_SHAPES


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=128256,
        pattern=uniform_pattern(16, ATTN),
        rope_theta=5e5,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        pattern=uniform_pattern(3, ATTN),
        tie_embeddings=True,
        dtype="float32",
    )
