"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff=1408(expert)
vocab=102400, MoE 64e top-6 + 2 shared experts, MLA kv_lora=512
(arXiv:2405.04434).

MLA head dims: nope 128 + decoupled rope 64, v 128. Layer 0 dense
(d_ff 10944), layers 1–26 MoE. (The assignment note "160 routed" conflicts
with its own header "MoE 64e"; we follow the header, which matches the
HF deepseek-v2-lite card.)
"""

from repro.models.config import DENSE, MLA, MOE, BlockSpec, ModelConfig
from .base import FULL_ATTN_SHAPES

ARCH_ID = "deepseek-v2-lite-16b"
SUPPORTED_SHAPES = FULL_ATTN_SHAPES  # MLA is full attention → long_500k skipped


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=192,  # nope 128 + rope 64
        d_ff=10944,  # dense first layer
        vocab_size=102400,
        pattern=(BlockSpec(MLA, DENSE),) + tuple(BlockSpec(MLA, MOE) for _ in range(26)),
        kv_lora_rank=512,
        nope_head_dim=128,
        rope_head_dim=64,
        v_head_dim=128,
        n_experts=64,
        n_shared_experts=2,
        moe_top_k=6,
        d_ff_expert=1408,
        moe_dispatch_shards=16,  # §Perf B5
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=24,
        d_ff=128,
        vocab_size=256,
        pattern=(BlockSpec(MLA, DENSE),) + tuple(BlockSpec(MLA, MOE) for _ in range(2)),
        kv_lora_rank=32,
        nope_head_dim=16,
        rope_head_dim=8,
        v_head_dim=16,
        n_experts=8,
        n_shared_experts=2,
        moe_top_k=2,
        d_ff_expert=32,
        dtype="float32",
    )
