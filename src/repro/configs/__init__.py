"""Architecture registry: ``--arch <id>`` → config module.

>>> from repro.configs import get_arch, ARCH_IDS
>>> cfg = get_arch("llama3.2-1b").full_config()
"""

from __future__ import annotations

from . import (
    base,
    deepseek_v2_lite,
    gemma3_12b,
    kimi_k2,
    llama32_1b,
    llama32_vision_90b,
    mamba2_1p3b,
    mistral_nemo_12b,
    musicgen_large,
    phi4_mini,
    zamba2_1p2b,
)
from .base import ALL_SHAPES, FULL_ATTN_SHAPES, SHAPES, ShapeCell

_MODULES = (
    mamba2_1p3b,
    zamba2_1p2b,
    kimi_k2,
    deepseek_v2_lite,
    llama32_1b,
    phi4_mini,
    gemma3_12b,
    mistral_nemo_12b,
    musicgen_large,
    llama32_vision_90b,
)

ARCHS = {m.ARCH_ID: m for m in _MODULES}
ARCH_IDS = tuple(ARCHS)


def get_arch(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return ARCHS[arch_id]


def supported_cells():
    """All (arch, shape) dry-run cells, including documented skips."""
    cells = []
    for arch_id, mod in ARCHS.items():
        for shape in ALL_SHAPES:
            cells.append((arch_id, shape, shape in mod.SUPPORTED_SHAPES))
    return cells
