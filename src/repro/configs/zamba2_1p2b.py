"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64. Mamba2 backbone + *shared* attention blocks
(arXiv:2411.15242): one attention+FFN block whose weights are reused at
every attention position — the Zamba signature.

Pattern: 2 mamba prefix + 6 × [5 mamba + 1 shared-attn] (shared positions
7, 13, 19, 25, 31, 37).
"""

from repro.models.config import DENSE, MAMBA2, NONE, SHARED_ATTN, BlockSpec, ModelConfig
from .base import ALL_SHAPES

ARCH_ID = "zamba2-1.2b"
SUPPORTED_SHAPES = ALL_SHAPES  # hybrid → long_500k runs


def _pattern(n_mamba_prefix: int, n_units: int, unit_mamba: int):
    pat = [BlockSpec(MAMBA2, NONE)] * n_mamba_prefix
    for _ in range(n_units):
        pat += [BlockSpec(MAMBA2, NONE)] * unit_mamba + [BlockSpec(SHARED_ATTN, DENSE)]
    return tuple(pat)


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=32000,
        pattern=_pattern(2, 6, 5),
        ssm_state=64,
        ssm_heads=64,
        ssm_head_dim=64,
        ssm_groups=1,
        ssm_expand=2,
        ssm_chunk=256,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="hybrid",
        n_layers=8,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        pattern=_pattern(2, 2, 2),
        ssm_state=16,
        ssm_heads=4,
        ssm_head_dim=32,
        ssm_groups=1,
        ssm_expand=2,
        ssm_chunk=16,
        dtype="float32",
    )
