"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144, 5:1 local:global attention, 128k context.

head_dim 256; local layers: 1024-token sliding window, rope θ=10k;
global layers: full attention, rope θ=1M. Local layers keep a ring-buffer
KV cache of window size → the 500k decode cell is dominated by the 8
global layers only, so we run long_500k for this arch (hybrid-attention;
see DESIGN.md §Arch-applicability).
"""

from repro.models.config import ATTN, ATTN_LOCAL, DENSE, BlockSpec, ModelConfig
from .base import ALL_SHAPES

ARCH_ID = "gemma3-12b"
SUPPORTED_SHAPES = ALL_SHAPES


def _pattern(n_units: int):
    unit = [BlockSpec(ATTN_LOCAL, DENSE)] * 5 + [BlockSpec(ATTN, DENSE)]
    return tuple(unit * n_units)


def full_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=48,
        d_model=3840,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=15360,
        vocab_size=262144,
        pattern=_pattern(8),
        window=1024,
        rope_theta=1e4,
        rope_theta_global=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        family="dense",
        n_layers=6,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        pattern=_pattern(1),
        window=32,
        rope_theta=1e4,
        rope_theta_global=1e6,
        dtype="float32",
    )
